"""Multi-device sharded SpMV: tuned sharded vs single-device tuned vs CSR.

Times a torso1-class (heavy power-law tail) matrix three ways: whole-matrix
CSR, the single-device tuned path (``Planner().build``), and the sharded
tier at 2/4/8 shards — per-shard tuned formats (dispatch mode) and the
shard_map SPMD path on 8 simulated devices.

Simulated host devices (``--xla_force_host_platform_device_count``) share
the machine's cores; on a single-core CI container they add *no* parallel
hardware, so sharded wall-clock there carries the full serialization
penalty.  Each ``row_nd*`` row therefore reports two numbers: measured
wall time (``wall_us``), and the per-shard critical path (``us_per_call``
of the ``*_critical`` rows — the max per-shard SpMV time, i.e. what the
mesh's wall-clock becomes when every shard actually owns a device and the
reassembly collective is free).  On a multi-core host the wall numbers
themselves show the win; the committed snapshot pins the critical-path
model alongside the measured walls.

    PYTHONPATH=src python -m benchmarks.sharded_spmv [--quick] [--json DIR]
    PYTHONPATH=src python -m benchmarks.run --only sharded --quick
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import List

from .common import ITERS, Row

N_FULL = 16384
N_QUICK = 4096
DEVICES = 8

# runs under forced host devices in a subprocess: the parent's jax has
# already locked its device count
_INNER = r"""
import json, sys
import jax, jax.numpy as jnp
import numpy as np
from repro.core.autotune import time_fn
from repro.core.plan import Planner
from repro.core.spmv import spmv
from repro.core.suite import synthesize_power_law
from repro.sharding import build_sharded

n, iters = int(sys.argv[1]), int(sys.argv[2])
rows = []
csr = synthesize_power_law(n=n, mu=16.0, alpha=1.5, seed=0)
x = jnp.ones((csr.n_cols,), jnp.float32)

t_csr = time_fn(jax.jit(spmv), csr, x, iters=iters)
rows.append(["csr_whole", t_csr * 1e6,
             {"n": csr.n_rows, "nnz": csr.nnz}])

P = Planner().build(csr)
t_single = time_fn(lambda v: P.spmv(v), x, iters=iters)
rows.append(["tuned_single", t_single * 1e6,
             {"fmt": P.fmt,
              "speedup_vs_csr": round(t_csr / t_single, 2)}])

for nd in (2, 4, 8):
    spm = build_sharded(csr, n_shards=nd, axis="row", mode="dispatch")
    t_wall = time_fn(lambda v: spm.spmv(v), x, iters=iters)
    t_shards = [time_fn(lambda v, pm=pm: pm.spmv(v), x, iters=iters)
                for pm in spm.planned]
    t_crit = max(t_shards)
    nnzs = [m for m in spm.shard_nnz]
    rows.append([f"row_nd{nd}_critical", t_crit * 1e6,
                 {"metric": "max_shard_spmv",
                  "wall_us": round(t_wall * 1e6, 2),
                  "formats": ";".join(sorted(set(spm.plan.shard_formats()))),
                  "imbalance_nnz": round(max(nnzs) / (sum(nnzs) / nd), 3),
                  "speedup_vs_single": round(t_single / t_crit, 2),
                  "speedup_vs_csr": round(t_csr / t_crit, 2)}])

for axis in ("row", "col"):
    spm = build_sharded(csr, n_shards=len(jax.devices()), axis=axis)
    t_wall = time_fn(lambda v: spm.spmv(v), x, iters=iters)
    rows.append([f"{axis}_nd{len(jax.devices())}_shard_map", t_wall * 1e6,
                 {"mode": spm.mode, "metric": "wall",
                  "devices": len(jax.devices()),
                  "speedup_vs_csr": round(t_csr / t_wall, 2)}])

print("ROWS_JSON=" + json.dumps(rows))
"""


def run(scale: float = None, iters: int = ITERS,
        devices: int = DEVICES) -> List[Row]:
    n = N_FULL if scale is None else max(1024, int(N_FULL * scale / 0.08))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={devices}")
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "src"),
            env.get("PYTHONPATH")) if p)
    out = subprocess.run([sys.executable, "-c", _INNER, str(n), str(iters)],
                         capture_output=True, text=True, env=env,
                         timeout=1800)
    if out.returncode != 0:
        raise RuntimeError(f"sharded bench subprocess failed:\n{out.stderr}")
    payload = [line for line in out.stdout.splitlines()
               if line.startswith("ROWS_JSON=")][-1]
    rows = json.loads(payload[len("ROWS_JSON="):])
    return [Row(name=f"sharded/powerlaw/{name}", us_per_call=us,
                derived=derived) for name, us, derived in rows]


def main() -> None:
    import argparse
    from .common import print_rows
    from .run import write_snapshot
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help=f"n={N_QUICK} smoke run (CI / snapshot refresh)")
    ap.add_argument("--json", default=None, metavar="DIR",
                    help="write BENCH_sharded.json into DIR")
    ap.add_argument("--devices", type=int, default=DEVICES)
    args = ap.parse_args()
    import time
    scale = 0.08 * N_QUICK / N_FULL if args.quick else None
    t0 = time.time()
    rows = run(scale=scale, devices=args.devices)
    print_rows(rows)
    if args.json:
        path = write_snapshot(args.json, "sharded", rows, time.time() - t0,
                              scale, args.quick)
        print(f"# wrote {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
