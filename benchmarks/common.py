"""Shared benchmark plumbing: CSV rows, suite construction, timing."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List

SCALE = 0.08          # suite scale for CPU wall-clock runs (stats invariant)
ITERS = 3


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: Dict[str, Any]

    def csv(self) -> str:
        d = ";".join(f"{k}={v}" for k, v in self.derived.items())
        return f"{self.name},{self.us_per_call:.2f},{d}"


def print_rows(rows: List[Row]) -> None:
    print("name,us_per_call,derived")
    for r in rows:
        print(r.csv())
