"""Batched SpMM vs a per-vector SpMV loop, across B and formats.

The paper's amortization rule ``k (t_crs - t_f) > t_trans`` strengthens to
``k * B * (t_crs - t_f) > t_trans`` when each call carries B right-hand
sides — but only if SpMM actually beats B back-to-back SpMVs.  This sweep
measures exactly that ratio on the pathological suite (memplus, torso1 —
the matrices whose heavy tails break whole-matrix ELL), per format and
batch width:

    speedup(B) = B * t_spmv / t_spmm(B)

JSON output (``--json``) is uploaded as a CI artifact so the ratio is
tracked per commit.

    PYTHONPATH=src python -m benchmarks.run --only spmm_batch
    PYTHONPATH=src python -m benchmarks.spmm_batch --json out.json
"""
from __future__ import annotations

import argparse
import json
from typing import List

import jax
import jax.numpy as jnp

from repro.core import spmm, spmv
from repro.core.autotune import offline_phase, time_fn
from repro.core.suite import TABLE1, paper_suite, synthesize
from repro.core.transform import TRANSFORMS_HOST

from .common import ITERS, Row, SCALE

BATCHES = (1, 8, 32, 128)
FORMATS = ("csr", "sell", "hybrid")
MATRICES = ("memplus", "torso1")
DSTAR_FORMATS = ("ell_row", "sell", "coo_row")


def _bench_matrix(name: str, csr, batches, formats, iters: int) -> List[Row]:
    rows: List[Row] = []
    jit_spmv = jax.jit(spmv)
    jit_spmm = jax.jit(spmm)
    for fmt in formats:
        obj = TRANSFORMS_HOST[fmt](csr)
        x = jnp.ones((csr.n_cols,), jnp.float32)
        t_vec = time_fn(jit_spmv, obj, x, iters=iters)
        for b in batches:
            X = jnp.ones((csr.n_cols, b), jnp.float32)
            t_mm = time_fn(jit_spmm, obj, X, iters=iters)
            # the "loop" baseline: B independent single-vector calls
            t_loop = b * t_vec
            rows.append(Row(
                name=f"spmm_batch/{name}/{fmt}/B{b}",
                us_per_call=t_mm * 1e6,
                derived={"n": csr.n_rows, "nnz": csr.nnz, "batch": b,
                         "us_spmv_loop": f"{t_loop * 1e6:.2f}",
                         "speedup_vs_loop": f"{t_loop / t_mm:.2f}"}))
    return rows


def run(scale: float = SCALE, iters: int = ITERS,
        batches=BATCHES, formats=FORMATS) -> List[Row]:
    rows: List[Row] = []
    for mname in MATRICES:
        spec = [s for s in TABLE1 if s.name == mname][0]
        csr = synthesize(spec, scale=scale)
        rows.extend(_bench_matrix(mname, csr, batches, formats, iters))
    return rows


def dstar_sweep(scale: float = SCALE, iters: int = ITERS,
                batches=BATCHES, formats=DSTAR_FORMATS) -> List[Row]:
    """Per-B D* crossover table: re-run the off-line phase at each batch
    width and report the learned threshold D*_f.

    The batch-aware rule ``k * B * (t_crs - t_f) > t_trans`` predicts D*
    grows with B (a transformation amortized over B-wide panels tolerates
    a heavier tail), so the table is the measured crossover of format f
    becoming profitable as a function of batch — the ROADMAP follow-up to
    the PR-2/PR-4 serving work, landed in docs/serving.md."""
    suite = paper_suite(scale=scale, skip_ell_overflow=True)
    rows: List[Row] = []
    for b in batches:
        db = offline_phase(suite, formats=formats, iters=iters, batch=b,
                           machine=f"dstar-B{b}")
        for f in formats:
            # also report the mean measured R at this batch, for context
            rs = [r.formats[f].r for r in db.records if f in r.formats]
            rows.append(Row(
                name=f"dstar/B{b}/{f}", us_per_call=0.0,
                derived={"batch": b, "d_star": f"{db.d_star[f]:.3f}",
                         "mean_r": f"{sum(rs) / max(len(rs), 1):.2f}"}))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=SCALE)
    ap.add_argument("--iters", type=int, default=ITERS)
    ap.add_argument("--json", default=None,
                    help="also write results as JSON (CI artifact)")
    ap.add_argument("--dstar", action="store_true",
                    help="also run the per-B D* crossover sweep")
    args = ap.parse_args()
    rows = run(scale=args.scale, iters=args.iters)
    if args.dstar:
        rows.extend(dstar_sweep(scale=args.scale, iters=args.iters))
    from .common import print_rows
    print_rows(rows)
    if args.json:
        with open(args.json, "w") as f:
            json.dump([{"name": r.name, "us_per_call": r.us_per_call,
                        **r.derived} for r in rows], f, indent=1)


if __name__ == "__main__":
    main()
