"""Batched SpMM vs a per-vector SpMV loop, across B and formats.

The paper's amortization rule ``k (t_crs - t_f) > t_trans`` strengthens to
``k * B * (t_crs - t_f) > t_trans`` when each call carries B right-hand
sides — but only if SpMM actually beats B back-to-back SpMVs.  This sweep
measures exactly that ratio on the pathological suite (memplus, torso1 —
the matrices whose heavy tails break whole-matrix ELL), per format and
batch width:

    speedup(B) = B * t_spmv / t_spmm(B)

JSON output (``--json``) is uploaded as a CI artifact so the ratio is
tracked per commit.

    PYTHONPATH=src python -m benchmarks.run --only spmm_batch
    PYTHONPATH=src python -m benchmarks.spmm_batch --json out.json
"""
from __future__ import annotations

import argparse
import json
from typing import List

import jax
import jax.numpy as jnp

from repro.core import spmm, spmv
from repro.core.autotune import time_fn
from repro.core.suite import TABLE1, synthesize
from repro.core.transform import TRANSFORMS_HOST

from .common import ITERS, Row, SCALE

BATCHES = (1, 8, 32, 128)
FORMATS = ("csr", "sell", "hybrid")
MATRICES = ("memplus", "torso1")


def _bench_matrix(name: str, csr, batches, formats, iters: int) -> List[Row]:
    rows: List[Row] = []
    jit_spmv = jax.jit(spmv)
    jit_spmm = jax.jit(spmm)
    for fmt in formats:
        obj = TRANSFORMS_HOST[fmt](csr)
        x = jnp.ones((csr.n_cols,), jnp.float32)
        t_vec = time_fn(jit_spmv, obj, x, iters=iters)
        for b in batches:
            X = jnp.ones((csr.n_cols, b), jnp.float32)
            t_mm = time_fn(jit_spmm, obj, X, iters=iters)
            # the "loop" baseline: B independent single-vector calls
            t_loop = b * t_vec
            rows.append(Row(
                name=f"spmm_batch/{name}/{fmt}/B{b}",
                us_per_call=t_mm * 1e6,
                derived={"n": csr.n_rows, "nnz": csr.nnz, "batch": b,
                         "us_spmv_loop": f"{t_loop * 1e6:.2f}",
                         "speedup_vs_loop": f"{t_loop / t_mm:.2f}"}))
    return rows


def run(scale: float = SCALE, iters: int = ITERS,
        batches=BATCHES, formats=FORMATS) -> List[Row]:
    rows: List[Row] = []
    for mname in MATRICES:
        spec = [s for s in TABLE1 if s.name == mname][0]
        csr = synthesize(spec, scale=scale)
        rows.extend(_bench_matrix(mname, csr, batches, formats, iters))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=SCALE)
    ap.add_argument("--iters", type=int, default=ITERS)
    ap.add_argument("--json", default=None,
                    help="also write results as JSON (CI artifact)")
    args = ap.parse_args()
    rows = run(scale=args.scale, iters=args.iters)
    from .common import print_rows
    print_rows(rows)
    if args.json:
        with open(args.json, "w") as f:
            json.dump([{"name": r.name, "us_per_call": r.us_per_call,
                        **r.derived} for r in rows], f, indent=1)


if __name__ == "__main__":
    main()
