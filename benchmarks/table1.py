"""Table 1: the test-matrix suite — synthesized stats vs the paper's values."""
from __future__ import annotations

from typing import List

from repro.core import MatrixStats
from repro.core.suite import TABLE1, synthesize

from .common import Row


def run(scale: float = 0.08) -> List[Row]:
    rows = []
    for spec in TABLE1:
        m = synthesize(spec, scale=scale)
        st = MatrixStats.of(m)
        rows.append(Row(
            name=f"table1/{spec.name}",
            us_per_call=0.0,
            derived={
                "n": st.n, "nnz": st.nnz,
                "mu": f"{st.mu:.2f}", "mu_paper": spec.mu,
                "sigma": f"{st.sigma:.2f}", "sigma_paper": spec.sigma,
                "d_mat": f"{st.d_mat:.3f}", "d_mat_paper": spec.d_mat,
            }))
    return rows
