"""MoE-dispatch auto-tuning: the paper's method applied inside the LM.

Sweeps routing imbalance (temperature on router logits), measures the ELL
(capacity) vs CSR (dropless ragged) dispatch wall time, and reports the
D_mat = sigma/mu of tokens-per-expert for each point — the MoE analogue of
the D_mat–R_ell graph, from which DEFAULT_D_STAR is read."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core import time_fn
from repro.models import init
from repro.models.moe import (dispatch_d_mat, learn_d_star, moe_csr,
                              moe_ell)

from .common import Row


def run() -> List[Row]:
    cfg = smoke_config(get_config("dbrx-132b")).replace(
        d_model=128, d_ff=256, n_experts=8, top_k=2, n_layers=2)
    params = init(cfg, jax.random.PRNGKey(0))["scan"]["pos0"]["moe"]
    params = jax.tree.map(lambda a: a[0], params)  # one layer's weights
    B, S = 8, 256
    rows: List[Row] = []
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)).astype(np.float32))

    ell_fn = jax.jit(lambda ids, gw: moe_ell(params, x, ids, gw, cfg))
    csr_fn = jax.jit(lambda ids, gw: moe_csr(
        params, x.reshape(B * S, cfg.d_model), ids.reshape(B * S, cfg.top_k),
        gw.reshape(B * S, cfg.top_k), cfg))

    points = []
    for skew in (0.0, 1.0, 2.0, 4.0, 8.0):
        # bias router towards expert 0 to create imbalance
        logits = rng.normal(size=(B * S, cfg.n_experts)) + \
            skew * np.eye(1, cfg.n_experts, 0)
        gw, ids = jax.lax.top_k(jax.nn.softmax(jnp.asarray(
            logits, jnp.float32)), cfg.top_k)
        gw = (gw / gw.sum(-1, keepdims=True)).astype(jnp.float32)
        d_mat = float(dispatch_d_mat(ids, cfg.n_experts))
        ids_b = ids.reshape(B, S, cfg.top_k)
        gw_b = gw.reshape(B, S, cfg.top_k)
        t_ell = time_fn(ell_fn, ids_b, gw_b, iters=3)
        t_csr = time_fn(csr_fn, ids.astype(jnp.int32), gw, iters=3)
        # drop fraction under ELL capacity at this imbalance
        C = max(8, int(cfg.capacity_factor * S * cfg.top_k / cfg.n_experts))
        counts = np.zeros(cfg.n_experts)
        for b in range(B):
            cb = np.bincount(np.asarray(ids_b[b]).ravel(),
                             minlength=cfg.n_experts)
            counts += np.maximum(cb - C, 0)
        dropped = counts.sum() / (B * S * cfg.top_k)
        rows.append(Row(
            name=f"moe_dispatch/skew{skew}",
            us_per_call=t_ell * 1e6,
            derived={"d_mat": f"{d_mat:.3f}",
                     "t_ell_us": f"{t_ell*1e6:.1f}",
                     "t_csr_us": f"{t_csr*1e6:.1f}",
                     "sp_ell_vs_csr": f"{t_csr/t_ell:.2f}",
                     "ell_drop_frac": f"{dropped:.3f}"}))
        points.append((d_mat, t_ell, t_csr, dropped))
    # the off-line phase product: learned D* for the dispatch rule
    rows.append(Row(name="moe_dispatch/D_star", us_per_call=0.0,
                    derived={"d_star": f"{learn_d_star(points):.3f}",
                             "max_drop_frac": 0.05}))
    return rows
