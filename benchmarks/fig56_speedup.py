"""Figs 5 & 6: SP_crs/fmt — SpMV speedup of each format over CRS.

Two columns per (matrix, format), mirroring the paper's two machines:
  * ``sp_cpu``  — measured wall-clock on this host (the paper's scalar SMP,
    SR16000 analogue);
  * ``sp_tpu_model`` — MachineModel roofline prediction for the TPU v5e
    target (the paper's vector machine, ES2 analogue — same mechanism:
    ELL's full-lane reductions vs CRS's short segmented reductions).

The paper's thread sweep becomes a row-shard sweep on real hardware; on
the single CPU device we report the 1-thread point (where the paper also
sees the cleanest format effects, §4.3 conclusion 1)."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro.core import (MachineModel, MatrixStats, TRANSFORMS_HOST, spmv,
                        time_fn)
from repro.core.suite import paper_suite

from .common import ITERS, Row, SCALE

FORMATS = ("coo_row", "coo_col", "ell_row", "ell_col", "sell")


def run(scale: float = SCALE) -> List[Row]:
    suite = paper_suite(scale=scale, skip_ell_overflow=True)
    model = MachineModel()
    rows: List[Row] = []
    for name, csr in suite:
        stats = MatrixStats.of(csr)
        x = jnp.ones((csr.n_cols,), jnp.float32)
        jit_spmv = jax.jit(spmv)
        t_crs = time_fn(jit_spmv, csr, x, iters=ITERS)
        t_crs_tpu = model.t_spmv("csr", stats)
        for f in FORMATS:
            fmt = TRANSFORMS_HOST[f](csr)
            t = time_fn(jit_spmv, fmt, x, iters=ITERS)
            t_tpu = model.t_spmv(f, stats, width=(
                fmt.width if hasattr(fmt, "width") else None))
            rows.append(Row(
                name=f"fig56/{name}/{f}",
                us_per_call=t * 1e6,
                derived={"sp_cpu": f"{t_crs / t:.2f}",
                         "sp_tpu_model": f"{t_crs_tpu / t_tpu:.2f}",
                         "d_mat": f"{stats.d_mat:.3f}"}))
    return rows
