"""Hybrid partitioned SpMV: block-size / strategy sweep over skewed matrices.

The whole-matrix tuner falls back to CRS on anything with a heavy row tail
(the paper's torso1 ELL overflow).  This sweep shows the per-row-block
tuner recovering the ELL win on the regular blocks: for each matrix it
times whole-matrix CSR SpMV against the hybrid operator under several
partitioning strategies and block sizes, and reports the per-block format
mix and the build (transformation) cost alongside.

    PYTHONPATH=src python -m benchmarks.run --only hybrid
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro.core import spmv
from repro.core.autotune import time_fn
from repro.core.suite import TABLE1, synthesize, synthesize_power_law
from repro.partition import build_hybrid, spmv_hybrid

from .common import ITERS, Row, SCALE


SWEEP = (
    ("fixed_256", "fixed", {"block_rows": 256}),
    ("fixed_1024", "fixed", {"block_rows": 1024}),
    ("balanced_8", "balanced_nnz", {"n_blocks": 8}),
    ("variance_16", "variance", {"max_blocks": 16, "min_rows": 64}),
)


def _bench_matrix(name: str, csr, iters: int) -> List[Row]:
    x = jnp.ones((csr.n_cols,), jnp.float32)
    jit_csr = jax.jit(spmv)
    t_csr = time_fn(jit_csr, csr, x, iters=iters)
    rows = [Row(name=f"hybrid/{name}/csr", us_per_call=t_csr * 1e6,
                derived={"n": csr.n_rows, "nnz": csr.nnz})]
    for label, strategy, kw in SWEEP:
        hyb, rep = build_hybrid(csr, strategy=strategy, **kw)
        jit_h = jax.jit(spmv_hybrid)
        t_h = time_fn(jit_h, hyb, x, iters=iters)
        fmts = ";".join(f"{k}:{v}" for k, v in
                        sorted(rep.format_counts().items()))
        rows.append(Row(
            name=f"hybrid/{name}/{label}", us_per_call=t_h * 1e6,
            derived={"blocks": rep.n_blocks, "formats": fmts,
                     "speedup_vs_csr": f"{t_csr / t_h:.2f}",
                     "t_build_ms": f"{(rep.t_partition + rep.t_transform) * 1e3:.1f}"}))
    return rows


def run(scale: float = SCALE, iters: int = ITERS) -> List[Row]:
    rows: List[Row] = []
    # skew sweep: power-law tails of increasing heaviness
    for alpha, n in ((3.0, 8192), (2.0, 8192), (1.3, 8192)):
        rows.extend(_bench_matrix(f"powerlaw_a{alpha}",
                                  synthesize_power_law(n=n, alpha=alpha),
                                  iters))
    # the paper's pathological cases, synthesized at benchmark scale
    for mname in ("memplus", "torso1"):
        spec = [s for s in TABLE1 if s.name == mname][0]
        rows.extend(_bench_matrix(mname, synthesize(spec, scale=scale),
                                  iters))
    return rows


if __name__ == "__main__":
    from .common import print_rows
    print_rows(run())
