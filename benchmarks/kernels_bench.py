"""Kernel-level SpMV: Pallas (interpret) vs pure-jnp reference wall times
plus the arithmetic-intensity-derived TPU projection per matrix.

The interpret-mode timing is NOT a TPU number (it executes the kernel body
in Python); what matters is (a) numerical agreement with the oracle and
(b) the static byte/flop accounting used in §Roofline.  Wall-clock columns
compare the jnp reference paths (the auto-tuner's measured backend)."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro.core import MatrixStats, host_csr_to_ell, spmv, time_fn
from repro.core.suite import paper_suite
from repro.kernels import ops, ref

from .common import Row


def run(scale: float = 0.04) -> List[Row]:
    suite = paper_suite(scale=scale,
                        include=["chem_master1", "xenon1", "memplus",
                                 "sme3Da"])
    rows: List[Row] = []
    for name, csr in suite:
        stats = MatrixStats.of(csr)
        ell = host_csr_to_ell(csr)
        x = jnp.ones((csr.n_cols,), jnp.float32)
        t_ref = time_fn(jax.jit(spmv), ell, x, iters=3)
        d = jnp.asarray(ell.data)
        c = jnp.asarray(ell.cols)
        y_kernel = ops.ell_spmv_raw(d, c, x, interpret=True)
        y_ref = ref.ell_spmv_ref(d, c, x)
        err = float(jnp.max(jnp.abs(y_kernel - y_ref)))
        # static accounting: ELL bytes/flops per SpMV
        padded = ell.n_rows * ell.width
        bytes_moved = padded * (4 + 4) + csr.n_cols * 4 + ell.n_rows * 4
        flops = 2 * padded
        rows.append(Row(
            name=f"kernels/ell_spmv/{name}",
            us_per_call=t_ref * 1e6,
            derived={"kernel_vs_ref_maxerr": f"{err:.2e}",
                     "bytes": bytes_moved, "flops": flops,
                     "tpu_mem_bound_us":
                         f"{bytes_moved / 819e9 * 1e6:.2f}",
                     "d_mat": f"{stats.d_mat:.3f}"}))
    return rows
