"""Kernel-level benchmark: tuned vs default launch geometry per
(format, op), and the native row-segmented CSR kernel vs the old
CSR-via-COO detour.

Matrices are chosen per format the way the paper's auto-tuner would route
them: CSR is benched on torso1 — the suite's flagship heavy-tail matrix
(D_mat 5.72), exactly the kind the D_mat–R rule keeps in CRS (the paper
removed torso1's ELL run for memory overflow) — while the regular,
transform-friendly chem_master1 carries the ELL/SELL/COO/BCSR rows.

Every (format, op) pair runs through ``core.kernel_tune.KernelTuner`` —
the default launch is always one of the timed candidates, so the reported
``tuned_speedup = t_default / t_best`` is >= 1.0 by construction (equality
means the default was already the winner).  The CSR rows additionally time
``ops.spmv_csr_via_coo`` (the pre-native path, at the geometry it shipped
with) head-to-head against the tuned native kernel, interleaving the two
and taking per-path minima so scheduler drift cancels; ``native_vs_coo``
is that ratio.

Interpret-mode caveat: off-TPU the Pallas kernels execute in the
interpreter, so absolute times are not TPU numbers — the *relative*
geometry ranking and the regression-guard properties (tuned >= default,
native CSR SpMV > detour) are what the CI smoke step checks.

    PYTHONPATH=src python -m benchmarks.kernels_bench [--quick]
        [--scale S] [--iters N] [--json OUT.json]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import MatrixStats, dispatch, host_csr_to_ell, spmv, time_fn
from repro.core.kernel_tune import KernelTuner
from repro.core.suite import paper_suite
from repro.core.transform import TRANSFORMS_HOST
from repro.kernels import ops, ref

from .common import Row

# matrix -> formats benched on it (formats where the D_mat–R rule would
# actually land that matrix; see module docstring).  ccs rides with csr on
# the heavy-tail matrix: the paper's Phase-I product is exactly what a
# CRS-bound matrix transforms to when column structure is the regular one.
BENCH_PLAN: Tuple[Tuple[str, Tuple[str, ...]], ...] = (
    ("torso1", ("csr", "ccs", "coo_row")),
    ("chem_master1", ("ell_row", "sell", "coo_row", "bcsr")),
)
LEGACY_BASELINES: Dict[Tuple[str, str], Callable] = {
    ("csr", "spmv"): ops.spmv_csr_via_coo,
    ("csr", "spmm"): ops.spmm_csr_via_coo,
}


def _interleaved(fa: Callable[[], None], fb: Callable[[], None],
                 iters: int) -> Tuple[float, float]:
    """Per-path best-of with A/B interleaving — slow drift (GC, noisy
    neighbours) hits both paths equally instead of whichever ran second."""
    fa()
    fb()
    ta = tb = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fa()
        ta = min(ta, time.perf_counter() - t0)
        t0 = time.perf_counter()
        fb()
        tb = min(tb, time.perf_counter() - t0)
    return ta, tb


def run(scale: float = 0.01, iters: int = 3, batch: int = 8,
        plan: Optional[Tuple] = None) -> List[Row]:
    plan = plan or BENCH_PLAN
    suite = dict(paper_suite(scale=scale,
                             include=[name for name, _ in plan]))
    tuner = KernelTuner(interpret=True, iters=iters, warmup=1)
    rows: List[Row] = []
    for mat_name, formats in plan:
        csr = suite[mat_name]
        stats = MatrixStats.of(csr)
        for op in ("spmv", "spmm"):
            if op == "spmv":
                x = jnp.ones((csr.n_cols,), jnp.float32)
            else:
                x = jnp.ones((csr.n_cols, batch), jnp.float32)
            for fmt in formats:
                obj = TRANSFORMS_HOST[fmt](csr)
                impl = dispatch.get_impl(fmt, op, tier="kernel",
                                         fallback=False)
                rec = tuner.tune(obj, op=op, batch=(1 if op == "spmv"
                                                    else batch),
                                 impl=impl, stats=stats)
                derived = {
                    "d_mat": f"{stats.d_mat:.3f}",
                    "t_default_us": f"{rec.t_default * 1e6:.1f}",
                    "tuned_speedup": f"{rec.speedup:.3f}",
                    "geometry": json.dumps(rec.geometry.to_dict()),
                }
                if op == "spmm":
                    derived["batch"] = batch
                base = LEGACY_BASELINES.get((fmt, op))
                if base is not None:
                    jb = jax.jit(lambda m, v, _f=base:
                                 _f(m, v, interpret=True))
                    jn = jax.jit(lambda m, v, _f=impl, _g=rec.geometry:
                                 _f(m, v, interpret=True, tuning=_g))
                    t_coo, t_native = _interleaved(
                        lambda: jax.block_until_ready(jb(obj, x)),
                        lambda: jax.block_until_ready(jn(obj, x)),
                        max(iters, 6))
                    derived["t_via_coo_us"] = f"{t_coo * 1e6:.1f}"
                    derived["native_vs_coo"] = f"{t_coo / t_native:.3f}"
                rows.append(Row(name=f"kernels/{fmt}_{op}/{mat_name}",
                                us_per_call=rec.t_best * 1e6,
                                derived=derived))
        # numerical sanity against the pure-jnp oracle (ELL), kept from the
        # original benchmark so the section still guards kernel parity
        if "ell_row" in formats:
            ell = host_csr_to_ell(csr)
            x1 = jnp.ones((csr.n_cols,), jnp.float32)
            d, c = jnp.asarray(ell.data), jnp.asarray(ell.cols)
            err = float(jnp.max(jnp.abs(
                ops.ell_spmv_raw(d, c, x1, interpret=True) -
                ref.ell_spmv_ref(d, c, x1))))
            t_ref = time_fn(jax.jit(spmv), ell, x1, iters=iters)
            rows.append(Row(name=f"kernels/ell_ref/{mat_name}",
                            us_per_call=t_ref * 1e6,
                            derived={"kernel_vs_ref_maxerr": f"{err:.2e}",
                                     "d_mat": f"{stats.d_mat:.3f}"}))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced plan / few iters (CI smoke)")
    ap.add_argument("--scale", type=float, default=None)
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--json", default=None, help="also dump rows as JSON")
    args = ap.parse_args()
    scale = args.scale if args.scale is not None else 0.01
    iters = args.iters if args.iters is not None else (1 if args.quick else 3)
    plan = (("torso1", ("csr", "ccs")),
            ("chem_master1", ("ell_row", "coo_row"))) if args.quick else None
    rows = run(scale=scale, iters=iters, batch=args.batch, plan=plan)
    from .common import print_rows
    print_rows(rows)
    if args.json:
        with open(args.json, "w") as f:
            json.dump([{"name": r.name, "us_per_call": r.us_per_call,
                        **r.derived} for r in rows], f, indent=1)
    bad = [r.name for r in rows
           if float(r.derived.get("tuned_speedup", 1)) < 1.0]
    assert not bad, f"tuned geometry slower than default: {bad}"


if __name__ == "__main__":
    main()
