"""Telemetry overhead: what an instrumentation site costs.

The observability substrate promises that disabled telemetry is free to
the hot path — one flag check plus a shared no-op span.  This section
measures that promise directly: the per-call cost of a representative
instrumentation site (a span plus a guarded counter, exactly the pattern
``dispatch``/``transform``/``SpMVService`` use) with telemetry off, on
with no sinks, and on with an in-memory sink, each expressed as a
percentage of one CRS SpMV — the smallest unit of real work the library
does.  The acceptance bar is disabled overhead < 1% of an SpMV.
"""
from __future__ import annotations

import time
from typing import Callable, List

import jax
import jax.numpy as jnp

from repro.core import spmv, time_fn
from repro.core.suite import paper_suite
from repro.obs import FakeClock, InMemorySink, Telemetry

from .common import ITERS, Row, SCALE

SITE_CALLS = 20_000


def _per_call(fn: Callable[[], None], n: int = SITE_CALLS) -> float:
    fn()  # warm attribute caches
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n


def _site(tel: Telemetry) -> Callable[[], None]:
    """One representative instrumentation site: a span around the work
    plus the guarded counter the pipeline's hot paths use."""
    def site() -> None:
        with tel.span("bench.site", fmt="csr", op="spmv"):
            pass
        if tel.enabled:
            tel.counter("bench.calls", fmt="csr").inc()
    return site


def run(scale: float = SCALE) -> List[Row]:
    name, csr = paper_suite(scale=scale, skip_ell_overflow=True,
                            include=("ex19",))[0]
    x = jnp.ones((csr.n_cols,), jnp.float32)
    t_spmv = time_fn(jax.jit(spmv), csr, x, iters=ITERS)

    off = Telemetry()                                   # the default
    on = Telemetry(enabled=True, clock=FakeClock())
    sunk = Telemetry(enabled=True, clock=FakeClock(),
                     sinks=[InMemorySink()])
    rows: List[Row] = []
    for label, tel in (("disabled_site", off), ("enabled_span", on),
                       ("enabled_span_sink", sunk)):
        t = _per_call(_site(tel))
        rows.append(Row(
            name=f"obs/{label}",
            us_per_call=t * 1e6,
            derived={"pct_of_spmv": f"{100.0 * t / t_spmv:.4f}",
                     "spmv_ref": name}))
    return rows


def run_guard(scale: float = SCALE) -> List[Row]:
    """What the degradation ladder costs when nothing is degrading: a
    GuardedImpl call on the happy path (breaker closed, no finite probe,
    no budget).  The machinery — breaker admit, unarmed fault-registry
    lookup, per-rung bookkeeping — is input-independent, so it is measured
    around a trivial rung (subtracting the rung itself) and expressed
    against one real tuned SpMV; timing the wrapped SpMV directly would
    drown the few-µs delta in jit-dispatch jitter.  The acceptance bar is
    < 2% of one SpMV."""
    from repro.serve.guard import guard_ladder

    name, csr = paper_suite(scale=scale, skip_ell_overflow=True,
                            include=("ex19",))[0]
    x = jnp.ones((csr.n_cols,), jnp.float32)
    t_spmv = time_fn(jax.jit(spmv), csr, x, iters=ITERS)

    def rung(v):
        return v

    guard = guard_ladder("bench", "spmv",
                         [("tuned", rung), ("csr", rung)],
                         fmt="csr", probe_finite=False)
    t_bare = _per_call(lambda: rung(x))
    t_guard = _per_call(lambda: guard(x))
    overhead = max(t_guard - t_bare, 0.0)
    return [
        Row(name="guard/machinery", us_per_call=overhead * 1e6,
            derived={"pct_of_spmv": f"{100.0 * overhead / t_spmv:.4f}",
                     "breaker": "closed", "probe": "off",
                     "spmv_us": f"{t_spmv * 1e6:.2f}",
                     "spmv_ref": name}),
    ]
