"""Fig 7: TT — transformation overhead in units of one CRS SpMV.

(The paper prints eq. (2) as t_crs/t_trans but its Fig. 7 reads overheads
of '0.01x-0.51x'; we report the self-consistent t_trans/t_crs — see
repro.core.autotune module docstring.)"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro.core import TRANSFORMS_HOST, spmv, time_fn
from repro.core.autotune import time_host
from repro.core.suite import paper_suite

from .common import ITERS, Row, SCALE

FORMATS = ("coo_row", "coo_col", "ell_row", "sell")


def run(scale: float = SCALE) -> List[Row]:
    suite = paper_suite(scale=scale, skip_ell_overflow=True)
    rows: List[Row] = []
    for name, csr in suite:
        x = jnp.ones((csr.n_cols,), jnp.float32)
        t_crs = time_fn(jax.jit(spmv), csr, x, iters=ITERS)
        for f in FORMATS:
            t_trans = time_host(TRANSFORMS_HOST[f], csr, iters=2)
            rows.append(Row(
                name=f"fig7/{name}/{f}",
                us_per_call=t_trans * 1e6,
                derived={"tt": f"{t_trans / t_crs:.2f}"}))
    return rows
