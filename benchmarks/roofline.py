"""§Roofline: aggregate the dry-run artifacts into the per-(arch x shape)
three-term table (single-pod 16x16, per the spec)."""
from __future__ import annotations

import glob
import json
import os
from typing import List

from .common import Row

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def run(mesh: str = "16x16") -> List[Row]:
    rows: List[Row] = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        d = json.load(open(f))
        name = f"roofline/{d['arch']}/{d['shape']}"
        if d["status"] == "skip":
            rows.append(Row(name=name, us_per_call=0.0,
                            derived={"status": "SKIP(design)"}))
            continue
        if d["status"] != "ok":
            rows.append(Row(name=name, us_per_call=0.0,
                            derived={"status": "ERROR"}))
            continue
        r = d["roofline"]
        a = d.get("analytic")
        if a:   # prefer the trip-count-aware analytic terms (DESIGN.md §8)
            t_c, t_m, t_l = a["t_compute"], a["t_memory"], a["t_collective"]
            bneck = a["bottleneck"]
            useful = a["useful_ratio"]
        else:
            t_c, t_m, t_l = r["t_compute"], r["t_memory"], r["t_collective"]
            bneck = r["bottleneck"]
            useful = r["useful_ratio"]
        t_dom = max(t_c, t_m, t_l)
        rows.append(Row(
            name=name,
            us_per_call=t_dom * 1e6,   # roofline-bound step time
            derived={
                "t_compute_ms": f"{t_c*1e3:.2f}",
                "t_memory_ms": f"{t_m*1e3:.2f}",
                "t_collective_ms": f"{t_l*1e3:.2f}",
                "bottleneck": bneck,
                "useful_ratio": f"{useful:.3f}",
                "whlo_compute_ms": f"{r['t_compute']*1e3:.2f}",
                "whlo_collective_ms": f"{r['t_collective']*1e3:.2f}",
                "peak_gb": f"{d['memory']['peak_bytes']/1e9:.2f}",
                "fits_16gb": d["memory"]["fits_16gb"],
            }))
    return rows
