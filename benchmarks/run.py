"""Benchmark driver — one section per paper table/figure plus the
integration, kernel, and observability suites.  Prints
``name,us_per_call,derived`` CSV; ``--json DIR`` additionally writes one
``BENCH_<section>.json`` snapshot per section (the machine-readable form
CI archives and ``benchmarks/snapshots/`` pins).

    PYTHONPATH=src python -m benchmarks.run [--only table1,fig8]
        [--scale S] [--quick] [--json DIR]

``--quick`` runs the scale-aware sections at a smoke scale — seconds,
not minutes — for CI and for refreshing committed snapshots.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from .common import print_rows


SECTIONS = ("table1", "fig56", "fig7", "fig8", "hybrid", "spmm_batch",
            "dstar", "moe", "kernels", "roofline", "obs", "guard",
            "sharded", "stream")

QUICK_SCALE = 0.02


def snapshot_path(json_dir: str, section: str) -> str:
    return os.path.join(json_dir, f"BENCH_{section}.json")


def write_snapshot(json_dir: str, section: str, rows, wall_s: float,
                   scale, quick: bool) -> str:
    """One section's rows as a JSON snapshot (sorted keys, trailing
    newline — byte-stable for committed copies)."""
    os.makedirs(json_dir, exist_ok=True)
    path = snapshot_path(json_dir, section)
    doc = {
        "section": section,
        "generated_by": "benchmarks.run",
        "quick": bool(quick),
        "scale": scale,
        "wall_s": round(wall_s, 2),
        "rows": [{"name": r.name, "us_per_call": round(r.us_per_call, 2),
                  "derived": r.derived} for r in rows],
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True, default=str)
        f.write("\n")
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help=f"comma list of {SECTIONS}")
    ap.add_argument("--scale", type=float, default=None,
                    help="suite scale override (default per-section)")
    ap.add_argument("--quick", action="store_true",
                    help=f"smoke scale ({QUICK_SCALE}) for scale-aware "
                         "sections; the CI/snapshot path")
    ap.add_argument("--json", default=None, metavar="DIR",
                    help="also write BENCH_<section>.json per section")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else set(SECTIONS)
    unknown = only - set(SECTIONS)
    if unknown:
        ap.error(f"unknown sections {sorted(unknown)}; "
                 f"choose from {SECTIONS}")
    scale = args.scale if args.scale is not None \
        else (QUICK_SCALE if args.quick else None)

    rows = []
    t0 = time.time()

    def section(name, fn, **kw):
        if name not in only:
            return
        t = time.time()
        out = list(fn(**kw))
        rows.extend(out)
        dt = time.time() - t
        print(f"# {name}: {dt:.1f}s", file=sys.stderr)
        if args.json:
            path = write_snapshot(args.json, name, out, dt,
                                  kw.get("scale"), args.quick)
            print(f"# wrote {path}", file=sys.stderr)

    from . import (fig56_speedup, fig7_overhead, fig8_graph, hybrid_blocks,
                   kernels_bench, moe_dispatch, obs_overhead, roofline,
                   sharded_spmv, spmm_batch, stream_updates, table1)
    scale_kw = {"scale": scale} if scale is not None else {}
    section("table1", table1.run, **scale_kw)
    section("fig56", fig56_speedup.run, **scale_kw)
    section("fig7", fig7_overhead.run, **scale_kw)
    section("fig8", fig8_graph.run, **scale_kw)
    section("hybrid", hybrid_blocks.run, **scale_kw)
    section("spmm_batch", spmm_batch.run, **scale_kw)
    section("dstar", spmm_batch.dstar_sweep, **scale_kw)
    section("moe", moe_dispatch.run)
    section("kernels", kernels_bench.run)
    section("roofline", roofline.run)
    section("obs", obs_overhead.run, **scale_kw)
    section("stream", stream_updates.run, **scale_kw)
    section("guard", obs_overhead.run_guard, **scale_kw)
    # runs in a subprocess under 8 forced host devices (the parent's jax
    # has already locked its device count)
    section("sharded", sharded_spmv.run, **scale_kw)

    print_rows(rows)
    print(f"# total: {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
