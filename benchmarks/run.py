"""Benchmark driver — one section per paper table/figure plus the
integration and roofline suites.  Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run [--only table1,fig8] [--scale S]
"""
from __future__ import annotations

import argparse
import sys
import time

from .common import print_rows


SECTIONS = ("table1", "fig56", "fig7", "fig8", "hybrid", "spmm_batch",
            "dstar", "moe", "kernels", "roofline")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help=f"comma list of {SECTIONS}")
    ap.add_argument("--scale", type=float, default=None,
                    help="suite scale override (default per-section)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else set(SECTIONS)

    rows = []
    t0 = time.time()

    def section(name, fn, **kw):
        if name not in only:
            return
        t = time.time()
        rows.extend(fn(**kw))
        print(f"# {name}: {time.time()-t:.1f}s", file=sys.stderr)

    from . import (fig56_speedup, fig7_overhead, fig8_graph, hybrid_blocks,
                   kernels_bench, moe_dispatch, roofline, spmm_batch, table1)
    scale_kw = {"scale": args.scale} if args.scale else {}
    section("table1", table1.run, **scale_kw)
    section("fig56", fig56_speedup.run, **scale_kw)
    section("fig7", fig7_overhead.run, **scale_kw)
    section("fig8", fig8_graph.run, **scale_kw)
    section("hybrid", hybrid_blocks.run, **scale_kw)
    section("spmm_batch", spmm_batch.run, **scale_kw)
    section("dstar", spmm_batch.dstar_sweep, **scale_kw)
    section("moe", moe_dispatch.run)
    section("kernels", kernels_bench.run)
    section("roofline", roofline.run)

    print_rows(rows)
    print(f"# total: {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
