"""Incremental vs full re-transform cost for mutating matrices.

The streaming tier's claim is economic: absorbing a delta into the bound
container must cost a small fraction of the full CRS→SELL re-transform
the paper's ``t_trans`` prices — otherwise drift-triggered re-planning
would be the cheaper answer and ``repro.stream`` would be pointless.
This section measures the claim directly:

* ``stream/csr_append_1pct`` — a DeltaBatch appending ≤1% new nnz into
  the CSR tail slack, against one full CRS→SELL transform of the same
  matrix.  The acceptance bar is ≤10% of the re-transform.
* ``stream/sell_point_updates`` — point updates absorbed by per-slice
  SELL rewrites, against the same full re-transform.
* ``stream/replan_trigger`` — trigger precision of the drift policy:
  an oscillation across D* inside the hysteresis band must fire zero
  re-plans; a genuine drift past the band must fire exactly one.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core.plan import apply_transform
from repro.core.suite import paper_suite
from repro.stream.delta import DeltaBatch, apply_delta
from repro.stream.drift import ReplanPolicy

from .common import Row, SCALE

ITERS = 5

#: %-of-retransform on a toy matrix measures interpreter constants, not
#: the O(Δnnz)-vs-O(nnz) economics, so the suite scale is clamped: the
#: append rows always price the paper matrix at full size (the whole
#: section still runs in well under a second)
MIN_SCALE = 1.0


def _copy(csr):
    from repro.core.formats import CSR
    return CSR(data=np.asarray(csr.data).copy(),
               cols=np.asarray(csr.cols).copy(),
               indptr=np.asarray(csr.indptr).copy(),
               shape=csr.shape, nnz=csr.nnz)


def _time(fn, iters=ITERS) -> float:
    fn()  # warm caches / one-time imports
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _time_mutating(mk, fn, iters=ITERS) -> float:
    """Time ``fn(state)`` only — ``mk()`` rebuilds the state each round
    because the apply mutates its inputs in place."""
    fn(mk())  # warm
    best = float("inf")
    for _ in range(iters):
        st = mk()
        t0 = time.perf_counter()
        fn(st)
        best = min(best, time.perf_counter() - t0)
    return best


def _append_delta(rng, csr, frac=0.01, row_len=None) -> DeltaBatch:
    """Whole-row appends totalling ~``frac`` of the matrix's nnz; rows
    default to the matrix's own mean row length."""
    if row_len is None:
        row_len = max(int(csr.nnz // max(csr.n_rows, 1)), 1)
    budget = max(int(csr.nnz * frac), row_len)
    cols, vals = [], []
    while budget > 0:
        ln = min(row_len, budget, csr.n_cols)
        c = np.sort(rng.choice(csr.n_cols, size=ln,
                               replace=False)).astype(np.int64)
        cols.append(c)
        vals.append(rng.standard_normal(ln).astype(np.float32))
        budget -= ln
    return DeltaBatch(n_cols=csr.n_cols, append_cols=tuple(cols),
                      append_vals=tuple(vals))


def _overwrite_delta(rng, csr, n) -> DeltaBatch:
    """Point updates aimed at *stored* entries — the in-place hit path."""
    k = np.sort(rng.choice(csr.nnz, size=min(n, csr.nnz), replace=False))
    ip = np.asarray(csr.indptr)
    rows = (np.searchsorted(ip, k, side="right") - 1).astype(np.int64)
    cols = np.asarray(csr.cols)[k].astype(np.int64)
    return DeltaBatch(n_cols=csr.n_cols, update_rows=rows,
                      update_cols=cols,
                      update_vals=rng.standard_normal(
                          k.size).astype(np.float32))


def run(scale: float = SCALE) -> List[Row]:
    rng = np.random.default_rng(42)
    name, csr = paper_suite(scale=max(scale, MIN_SCALE),
                            skip_ell_overflow=True, include=("ex19",))[0]
    rows: List[Row] = []

    t_full = _time(lambda: apply_transform("sell", csr))

    # -- incremental CSR tail append, <=1% new nnz --------------------------
    # steady state: the first append past the pad bought growth-factor
    # headroom, so subsequent appends are pure O(Δnnz) tail writes; the
    # one-time realloc is reported separately
    delta = _append_delta(rng, csr, frac=0.01)
    # one row wider than the pad-rounding slack, so the warm-up append
    # actually reallocates and buys the growth-factor headroom
    grow = _append_delta(rng, csr, frac=0.0,
                         row_len=int(csr.nnz_pad - csr.nnz) + 1)
    t_cold = _time_mutating(
        lambda: _copy(csr),
        lambda m: apply_delta(m, delta, fmt="csr", validate=False))
    t_app = _time_mutating(
        lambda: apply_delta(_copy(csr), grow, fmt="csr",
                            validate=False).csr,
        lambda m: apply_delta(m, delta, fmt="csr", validate=False))
    rows.append(Row(
        name="stream/csr_append_1pct", us_per_call=t_app * 1e6,
        derived={"pct_of_full_retransform": f"{100.0 * t_app / t_full:.2f}",
                 "accept_le": "10",
                 "cold_realloc_us": f"{t_cold * 1e6:.2f}",
                 "appended_nnz": delta.nnz_delta, "nnz": csr.nnz,
                 "full_sell_us": f"{t_full * 1e6:.2f}",
                 "matrix": name}))

    # -- incremental SELL point updates -------------------------------------
    upd = _overwrite_delta(rng, csr, max(csr.nnz // 1000, 8))
    t_sell = _time_mutating(
        lambda: (_copy(csr), apply_transform("sell", csr)),
        lambda st: apply_delta(st[0], upd, container=st[1], fmt="sell",
                               validate=False))
    rows.append(Row(
        name="stream/sell_point_updates", us_per_call=t_sell * 1e6,
        derived={"pct_of_full_retransform": f"{100.0 * t_sell / t_full:.2f}",
                 "updates": int(upd.update_rows.shape[0]),
                 "matrix": name}))

    # -- re-plan trigger precision ------------------------------------------
    osc = ReplanPolicy(d_star=1.0, hysteresis=0.15, fmt="sell",
                       min_deltas_between=0)
    osc_replans = sum(osc.decide(1.1 if i % 2 else 0.9,
                                 current_fmt="sell").replan
                      for i in range(50))
    drift = ReplanPolicy(d_star=1.0, hysteresis=0.15, fmt="sell",
                         min_deltas_between=0)
    drift_replans = sum(drift.decide(d, current_fmt="sell").replan
                        for d in (0.5, 0.8, 1.05, 2.0))
    t_dec = _time(lambda: osc.decide(0.9, current_fmt="sell"))
    rows.append(Row(
        name="stream/replan_trigger", us_per_call=t_dec * 1e6,
        derived={"oscillation_replans": osc_replans, "accept_osc": "0",
                 "drift_replans": drift_replans, "accept_drift": "1"}))
    return rows
