"""Fig 8: the D_mat–R_ell graph and the learned D* threshold.

Runs the full off-line phase on this machine (measured, SR16000-analogue)
and against the TPU MachineModel (ES2-analogue), then prints the graph
points and D* per format for c = 1.0 — the paper's central artifact."""
from __future__ import annotations

from typing import List

from repro.core import MachineModel, MatrixStats, offline_phase
from repro.core.suite import paper_suite

from .common import ITERS, Row, SCALE

FORMATS = ("ell_row", "ell_col", "coo_row", "sell")


def run(scale: float = SCALE) -> List[Row]:
    suite = paper_suite(scale=scale, skip_ell_overflow=True)
    db = offline_phase(suite, formats=FORMATS, c=1.0, machine="host-cpu",
                       iters=ITERS)
    model = MachineModel()
    rows: List[Row] = []
    for rec in db.records:
        for f in FORMATS:
            m = rec.formats[f]
            stats = MatrixStats(n=rec.n, nnz=rec.nnz, mu=rec.mu,
                                sigma=rec.sigma, d_mat=rec.d_mat,
                                max_row=0, min_row=0)
            sp_t = model.t_spmv("csr", stats) / model.t_spmv(f, stats)
            tt_t = model.t_trans(f, stats) / model.t_spmv("csr", stats)
            rows.append(Row(
                name=f"fig8/{rec.name}/{f}",
                us_per_call=m.t_spmv * 1e6,
                derived={"d_mat": f"{rec.d_mat:.3f}",
                         "r_cpu": f"{m.r:.3f}",
                         "r_tpu_model": f"{sp_t / max(tt_t, 1e-9):.3f}",
                         "sp": f"{m.sp:.2f}", "tt": f"{m.tt:.2f}"}))
    for f in FORMATS:
        rows.append(Row(name=f"fig8/D_star/{f}", us_per_call=0.0,
                        derived={"d_star_cpu": f"{db.d_star[f]:.3f}",
                                 "c": db.c}))
    return rows
