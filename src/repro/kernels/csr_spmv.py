"""Native row-segmented CSR SpMV / SpMM Pallas TPU kernels.

Until this module existed the kernel tier served CSR by expanding IRP to
IROW at call time and running the COO kernel — one sequential grid with the
whole y vector resident in VMEM.  This kernel keeps CSR native and restores
row parallelism:

  * the grid is ``(row_blocks, slabs_per_block)`` (SpMM adds a parallel k
    axis): each row block owns a private ``(block_rows,)`` output tile, so
    row blocks are *parallel* — there is no whole-matrix y in VMEM and no
    global sequential walk;
  * a row block's nonzeros are contiguous in CSR order
    (``IRP[i*br] : IRP[(i+1)*br]``), so its slabs are located by *scalar
    prefetch*: ``slab_start[i] = IRP[i*br] // block_nnz`` feeds the
    BlockSpec index map and the VAL/ICOL slabs stream straight out of the
    row block's own span — the TPU form of the paper's per-thread
    contiguous CRS walk (§3.1's outer parallelization);
  * within a slab, each entry's local row is recovered from the row block's
    IRP window by a compare-count (a vectorized ``searchsorted``), then a
    short local scatter-add accumulates into the (VMEM-resident) row tile.

``slabs_per_block`` must statically bound ``ceil(span / block_nnz) + 1``
over all row blocks.  It is data-dependent, which is exactly why the launch
geometry auto-tuner (``core/kernel_tune.py``) exists: tuning happens with
the concrete matrix in hand, and the winning :class:`TileGeometry` carries
the exact bound into traced hot paths.  Callers without a bound pass
``slab_starts=None`` and the kernel degrades to a full sequential sweep per
row block (always correct, never fast) — see ``slabs_needed``.

Padding conventions match the rest of the repo: pad entries are
(val=0, col=0) and fall outside every row block's IRP window.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def slabs_needed(indptr, block_rows: int, block_nnz: int) -> int:
    """Exact ``slabs_per_block`` for a concrete IRP: the static per-row-block
    slab count that guarantees every nonzero is visited.  Slab starts are
    floor-aligned to ``block_nnz`` boundaries, so a block needs the slabs
    from ``floor(first / bn)`` through ``floor((last - 1) / bn)``."""
    ip = np.asarray(indptr)
    n_rows = ip.shape[0] - 1
    edges = ip[np.minimum(np.arange(0, n_rows + block_rows, block_rows),
                          n_rows)]
    starts, ends = edges[:-1], edges[1:]
    if starts.size == 0:
        return 1
    needed = np.where(ends > starts,
                      (ends - 1) // block_nnz - starts // block_nnz + 1, 1)
    return max(int(needed.max()), 1)


def _row_windows(indptr: jax.Array, n_rows: int, block_rows: int) -> jax.Array:
    """(R, block_rows + 1) IRP windows, one per row block; rows past the end
    get the final pointer (empty rows).  One clipped gather — windows
    overlap by one entry, so a reshape can't produce them."""
    r = -(-n_rows // block_rows)
    ip = jnp.asarray(indptr)
    if r == 1 and block_rows == n_rows:
        return ip[None, :]
    idx = (jnp.arange(r, dtype=jnp.int32)[:, None] * block_rows +
           jnp.arange(block_rows + 1, dtype=jnp.int32)[None, :])
    return ip[jnp.minimum(idx, n_rows)]


def _pad_slabs(a: jax.Array, n_slabs: int, block_nnz: int) -> jax.Array:
    target = n_slabs * block_nnz
    if a.shape[0] < target:
        a = jnp.pad(a, (0, target - a.shape[0]))
    return a


def _slab_schedule(indptr, r: int, block_rows: int, block_nnz: int,
                   total: int, slabs_per_block: int):
    """(spb, slab_start) for the (row_blocks, spb) grid.  Tight slab starts
    are clamped to ``total - spb`` so the furthest reachable slab is always
    the last real one — a clamped window still covers its block's span
    (the span's last slab is < total), and no extra padding slabs exist."""
    if slabs_per_block:
        spb = min(slabs_per_block, total)
        start = jnp.asarray(indptr)[::block_rows][:r] // block_nnz
        return spb, jnp.minimum(start, total - spb)
    return total, jnp.zeros((r,), jnp.int32)


def _local_rows(ip_window: jax.Array, k0, bn: int, ip_dtype,
                interpret: bool = True, masked: bool = True):
    """Local row id of each global nnz index in ``[k0, k0 + bn)`` within
    one row block's IRP window, plus the in-window validity mask —
    semantically ``searchsorted(window, k, 'right') - 1``.

    The slab's indices are a *contiguous* range, so the search inverts into
    an O(br + bn) scatter + prefix sum over the row *boundaries* (each
    window pointer marks where the local row increments) — strictly less
    work than any per-entry search, and the concrete edge this kernel holds
    over the CSR-via-COO detour, whose IROW expansion must binary-search
    every nonzero on every call.  The compiled path keeps the VPU-lowerable
    O(bn x br) compare-count form (Mosaic has no 1D scatter).

    ``masked=False`` skips the validity mask (returns ``valid=None``): with
    a single row block every stored entry belongs to it and the tail pads
    carry val=0, contributing nothing wherever they scatter."""
    br = ip_window.shape[0] - 1
    k0 = jnp.asarray(k0, ip_dtype)
    if interpret:
        marks = jnp.zeros((bn + 1,), jnp.int32).at[
            jnp.clip(ip_window - k0, 0, bn)].add(1)
        lrow = jnp.cumsum(marks[:bn]) - 1
    else:
        k = k0 + jax.lax.broadcasted_iota(ip_dtype, (bn,), 0)
        lrow = jnp.sum(ip_window[None, :] <= k[:, None], axis=1) - 1
    valid = None
    if masked:
        k = k0 + jax.lax.broadcasted_iota(ip_dtype, (bn,), 0)
        valid = (k >= ip_window[0]) & (k < ip_window[br])
    return jnp.clip(lrow, 0, br - 1), valid


def _csr_spmv_kernel(interpret, masked, slab_ref, data_ref, cols_ref,
                     win_ref, x_ref, y_ref):
    i, j = pl.program_id(0), pl.program_id(1)
    bn = data_ref.shape[0]
    lrow, valid = _local_rows(win_ref[0, :], (slab_ref[i] + j) * bn, bn,
                              jnp.int32, interpret, masked)
    contrib = (data_ref[...].astype(jnp.float32) *
               x_ref[...].astype(jnp.float32)[cols_ref[...]])
    if valid is not None:
        contrib = jnp.where(valid, contrib, 0.0)
    partial = jnp.zeros_like(y_ref).at[lrow].add(contrib)

    @pl.when(j == 0)
    def _init():
        y_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        y_ref[...] = y_ref[...] + partial


@functools.partial(jax.jit, static_argnames=("block_rows", "block_nnz",
                                             "slabs_per_block", "interpret"))
def csr_spmv(data: jax.Array, cols: jax.Array, indptr: jax.Array,
             x: jax.Array, *, block_rows: int = 256, block_nnz: int = 2048,
             slabs_per_block: int = 0, interpret: bool = True) -> jax.Array:
    """y = A @ x, A in CSR (VAL/ICOL padded with zeros past IRP[-1]).

    ``slabs_per_block``: static bound from :func:`slabs_needed` (scalar-
    prefetched tight slab starts); 0 selects the always-correct full sweep
    (every row block scans every slab).  Returns (n_rows,) float32; callers
    cast (the ops wrapper keeps the repo's f32-accumulate convention)."""
    n_rows = indptr.shape[0] - 1
    r = -(-n_rows // block_rows)
    total = -(-data.shape[0] // block_nnz)
    spb, slab_start = _slab_schedule(indptr, r, block_rows, block_nnz,
                                     total, slabs_per_block)
    win = _row_windows(indptr, n_rows, block_rows)
    data = _pad_slabs(data, total, block_nnz)
    cols = _pad_slabs(cols, total, block_nnz)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(r, spb),
        in_specs=[
            pl.BlockSpec((block_nnz,), lambda i, j, s: (s[i] + j,)),
            pl.BlockSpec((block_nnz,), lambda i, j, s: (s[i] + j,)),
            pl.BlockSpec((1, block_rows + 1), lambda i, j, s: (i, 0)),
            pl.BlockSpec(x.shape, lambda i, j, s: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i, j, s: (i,)),
    )
    y = pl.pallas_call(
        functools.partial(_csr_spmv_kernel, interpret, r > 1),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r * block_rows,), jnp.float32),
        interpret=interpret,
    )(slab_start.astype(jnp.int32), data, cols, win, x)
    return y[:n_rows]


def _csr_spmm_kernel(interpret, masked, slab_ref, data_ref, cols_ref,
                     win_ref, x_ref, y_ref):
    i, j = pl.program_id(0), pl.program_id(2)
    bn = data_ref.shape[0]
    lrow, valid = _local_rows(win_ref[0, :], (slab_ref[i] + j) * bn, bn,
                              jnp.int32, interpret, masked)
    gathered = x_ref[...].astype(jnp.float32)[cols_ref[...], :]
    contrib = data_ref[...].astype(jnp.float32)[:, None] * gathered
    if valid is not None:
        contrib = jnp.where(valid[:, None], contrib, 0.0)
    partial = jnp.zeros_like(y_ref).at[lrow, :].add(contrib)

    @pl.when(j == 0)
    def _init():
        y_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        y_ref[...] = y_ref[...] + partial


@functools.partial(jax.jit, static_argnames=("block_rows", "block_nnz",
                                             "block_k", "slabs_per_block",
                                             "interpret"))
def csr_spmm(data: jax.Array, cols: jax.Array, indptr: jax.Array,
             x: jax.Array, *, block_rows: int = 256, block_nnz: int = 2048,
             block_k: int = 128, slabs_per_block: int = 0,
             interpret: bool = True) -> jax.Array:
    """Y = A @ X, A in CSR, X (n_cols, k) -> Y (n_rows, k) float32.

    Grid = (row_blocks, k_blocks, slabs); slabs are the innermost
    (sequential accumulation) axis, rows and k parallel."""
    n_rows = indptr.shape[0] - 1
    n_cols, kk = x.shape
    assert kk % block_k == 0, (kk, block_k)
    r = -(-n_rows // block_rows)
    total = -(-data.shape[0] // block_nnz)
    spb, slab_start = _slab_schedule(indptr, r, block_rows, block_nnz,
                                     total, slabs_per_block)
    win = _row_windows(indptr, n_rows, block_rows)
    data = _pad_slabs(data, total, block_nnz)
    cols = _pad_slabs(cols, total, block_nnz)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(r, kk // block_k, spb),
        in_specs=[
            pl.BlockSpec((block_nnz,), lambda i, c, j, s: (s[i] + j,)),
            pl.BlockSpec((block_nnz,), lambda i, c, j, s: (s[i] + j,)),
            pl.BlockSpec((1, block_rows + 1), lambda i, c, j, s: (i, 0)),
            pl.BlockSpec((n_cols, block_k), lambda i, c, j, s: (0, c)),
        ],
        out_specs=pl.BlockSpec((block_rows, block_k),
                               lambda i, c, j, s: (i, c)),
    )
    y = pl.pallas_call(
        functools.partial(_csr_spmm_kernel, interpret, r > 1),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r * block_rows, kk), jnp.float32),
        interpret=interpret,
    )(slab_start.astype(jnp.int32), data, cols, win, x)
    return y[:n_rows]
