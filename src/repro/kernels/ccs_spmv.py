"""Native column-segmented CCS SpMV / SpMM Pallas TPU kernels.

CCS is the paper's Phase-I target (CRS -> CCS is the transformation the
whole auto-tuning method is built to amortize), yet until this module it
was the last registered format served only by the pure-jnp reference.
This kernel is the column-space mirror of the row-segmented CSR design in
``csr_spmv.py``:

  * the grid is ``(col_blocks, slabs_per_block)`` (SpMM adds a parallel k
    axis): each column block owns a private ``(block_cols,)`` *input* tile
    of x — the exact dual of CSR, where each row block owns a private
    output tile;
  * a column block's nonzeros are contiguous in CCS order
    (``IRP_T[j*bc] : IRP_T[(j+1)*bc]``), so its slabs are located by
    *scalar prefetch*: ``slab_start[j] = IRP_T[j*bc] // block_nnz`` feeds
    the BlockSpec index map and the VAL/IROW slabs stream straight out of
    the column block's own span;
  * within a slab, each entry's local column is recovered from the column
    block's IRP_T window by the same O(bc + bn) scatter + prefix sum
    (interpret) / compare-count (compiled) split as CSR's row recovery;
    the entry's contribution ``val * x_tile[lcol]`` is then
    scatter-accumulated by its stored global row index into the output.

The output is the whole ``(n_rows,)`` y resident in VMEM (as in the COO
kernel): CCS scatters to arbitrary rows, so there is no private output
tile — the parallelism this kernel buys is on the *x side* (each column
block streams only its own VAL/IROW slabs plus a ``(block_cols,)`` x
tile), and the column-window recovery replaces the per-entry column array
a COO detour would have to materialize and re-search on every call.

``slabs_per_block`` is data-dependent exactly as in CSR — see
``csr_spmv.slabs_needed`` (shared here, applied to the column pointer).
Callers without a bound pass 0 and the kernel degrades to the
always-correct full sequential sweep per column block.

Padding conventions: pad entries are (val=0, row=0) and fall outside every
column block's IRP_T window.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .csr_spmv import (_local_rows, _pad_slabs, _row_windows, _slab_schedule,
                       slabs_needed)

__all__ = ["ccs_spmv", "ccs_spmm", "slabs_needed"]


def _pad_cols(x: jax.Array, block_cols: int) -> jax.Array:
    """Pad x's column axis (axis 0) so it splits into whole column tiles."""
    n_cols = x.shape[0]
    target = -(-n_cols // block_cols) * block_cols
    if target == n_cols:
        return x
    return jnp.pad(x, ((0, target - n_cols),) + ((0, 0),) * (x.ndim - 1))


def _ccs_spmv_kernel(interpret, masked, slab_ref, data_ref, rows_ref,
                     win_ref, x_ref, y_ref):
    i, j = pl.program_id(0), pl.program_id(1)
    bn = data_ref.shape[0]
    lcol, valid = _local_rows(win_ref[0, :], (slab_ref[i] + j) * bn, bn,
                              jnp.int32, interpret, masked)
    contrib = (data_ref[...].astype(jnp.float32) *
               x_ref[...].astype(jnp.float32)[lcol])
    if valid is not None:
        contrib = jnp.where(valid, contrib, 0.0)

    @pl.when((i == 0) & (j == 0))
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    y_ref[...] = y_ref[...].at[rows_ref[...]].add(contrib)


@functools.partial(jax.jit, static_argnames=("n_rows", "block_cols",
                                             "block_nnz", "slabs_per_block",
                                             "interpret"))
def ccs_spmv(data: jax.Array, rows: jax.Array, indptr: jax.Array,
             x: jax.Array, *, n_rows: int, block_cols: int = 256,
             block_nnz: int = 2048, slabs_per_block: int = 0,
             interpret: bool = True) -> jax.Array:
    """y = A @ x, A in CCS (VAL/IROW padded with zeros past IRP_T[-1]).

    ``slabs_per_block``: static bound from :func:`slabs_needed` over the
    column pointer (scalar-prefetched tight slab starts); 0 selects the
    always-correct full sweep (every column block scans every slab).
    Returns (n_rows,) float32; callers cast (the ops wrapper keeps the
    repo's f32-accumulate convention)."""
    n_cols = indptr.shape[0] - 1
    c = -(-n_cols // block_cols)
    total = -(-data.shape[0] // block_nnz)
    spb, slab_start = _slab_schedule(indptr, c, block_cols, block_nnz,
                                     total, slabs_per_block)
    win = _row_windows(indptr, n_cols, block_cols)
    data = _pad_slabs(data, total, block_nnz)
    rows = _pad_slabs(rows, total, block_nnz)
    xp = _pad_cols(x, block_cols)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(c, spb),
        in_specs=[
            pl.BlockSpec((block_nnz,), lambda i, j, s: (s[i] + j,)),
            pl.BlockSpec((block_nnz,), lambda i, j, s: (s[i] + j,)),
            pl.BlockSpec((1, block_cols + 1), lambda i, j, s: (i, 0)),
            pl.BlockSpec((block_cols,), lambda i, j, s: (i,)),
        ],
        out_specs=pl.BlockSpec((n_rows,), lambda i, j, s: (0,)),
    )
    return pl.pallas_call(
        functools.partial(_ccs_spmv_kernel, interpret, c > 1),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_rows,), jnp.float32),
        interpret=interpret,
    )(slab_start.astype(jnp.int32), data, rows, win, xp)


def _ccs_spmm_kernel(interpret, masked, slab_ref, data_ref, rows_ref,
                     win_ref, x_ref, y_ref):
    i, j = pl.program_id(1), pl.program_id(2)
    bn = data_ref.shape[0]
    lcol, valid = _local_rows(win_ref[0, :], (slab_ref[i] + j) * bn, bn,
                              jnp.int32, interpret, masked)
    contrib = (data_ref[...].astype(jnp.float32)[:, None] *
               x_ref[...].astype(jnp.float32)[lcol, :])
    if valid is not None:
        contrib = jnp.where(valid[:, None], contrib, 0.0)

    @pl.when((i == 0) & (j == 0))
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    y_ref[...] = y_ref[...].at[rows_ref[...], :].add(contrib)


@functools.partial(jax.jit, static_argnames=("n_rows", "block_cols",
                                             "block_nnz", "block_k",
                                             "slabs_per_block", "interpret"))
def ccs_spmm(data: jax.Array, rows: jax.Array, indptr: jax.Array,
             x: jax.Array, *, n_rows: int, block_cols: int = 256,
             block_nnz: int = 2048, block_k: int = 128,
             slabs_per_block: int = 0, interpret: bool = True) -> jax.Array:
    """Y = A @ X, A in CCS, X (n_cols, k) -> Y (n_rows, k) float32.

    Grid = (k_blocks, col_blocks, slabs); the k axis is parallel (each k
    block owns its own (n_rows, block_k) output panel), columns and slabs
    accumulate sequentially into it."""
    n_cols = indptr.shape[0] - 1
    kk = x.shape[1]
    assert kk % block_k == 0, (kk, block_k)
    c = -(-n_cols // block_cols)
    total = -(-data.shape[0] // block_nnz)
    spb, slab_start = _slab_schedule(indptr, c, block_cols, block_nnz,
                                     total, slabs_per_block)
    win = _row_windows(indptr, n_cols, block_cols)
    data = _pad_slabs(data, total, block_nnz)
    rows = _pad_slabs(rows, total, block_nnz)
    xp = _pad_cols(x, block_cols)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(kk // block_k, c, spb),
        in_specs=[
            pl.BlockSpec((block_nnz,), lambda kb, i, j, s: (s[i] + j,)),
            pl.BlockSpec((block_nnz,), lambda kb, i, j, s: (s[i] + j,)),
            pl.BlockSpec((1, block_cols + 1), lambda kb, i, j, s: (i, 0)),
            pl.BlockSpec((block_cols, block_k), lambda kb, i, j, s: (i, kb)),
        ],
        out_specs=pl.BlockSpec((n_rows, block_k),
                               lambda kb, i, j, s: (0, kb)),
    )
    return pl.pallas_call(
        functools.partial(_ccs_spmm_kernel, interpret, c > 1),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_rows, kk), jnp.float32),
        interpret=interpret,
    )(slab_start.astype(jnp.int32), data, rows, win, xp)
