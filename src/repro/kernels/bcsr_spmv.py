"""BCSR block-tiled SpMV / SpMM Pallas TPU kernels.

BCSR is the paper's named future work ("transformation to other formats,
such as BCSR, which enables cache blocking").  Storage is ``b x b`` dense
blocks in CSR order over block rows; on TPU each stored block is a small
dense tile, so SpMV becomes a stream of tiny dense matvecs (einsum over the
tile axes — MXU/VPU work, no per-scalar gather) and the "cache blocking"
the paper anticipates maps onto VMEM slabs.

Launch structure mirrors ``csr_spmv`` one level up, over *block* rows:

  * grid = (block_row_tiles, slabs_per_tile) (SpMM adds a parallel k axis);
  * a tile of ``rows_per_tile`` block rows owns a private
    ``(rows_per_tile * b,)`` output strip — tiles are parallel;
  * the tile's stored blocks are contiguous in the block-CSR order, so slab
    placement is scalar-prefetched from the block IRP
    (``slab_start[i] = IRP[i*rpt] // block_nnz``), with the same
    full-sweep fallback when no static slab bound is available;
  * within a slab each stored block's local block row comes from the IRP
    window compare-count, and the ``(slab, b)`` matvec results scatter-add
    into the strip.

Pad blocks (beyond IRP[-1]) are all-zero and fall outside every window.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .csr_spmv import (_local_rows, _row_windows, _slab_schedule,
                       slabs_needed)

__all__ = ["bcsr_spmv", "bcsr_spmm", "slabs_needed"]


def _pad_block_slabs(a: jax.Array, n_slabs: int, block_nnz: int) -> jax.Array:
    target = n_slabs * block_nnz
    if a.shape[0] < target:
        pads = [(0, target - a.shape[0])] + [(0, 0)] * (a.ndim - 1)
        a = jnp.pad(a, pads)
    return a


def _gather_x_blocks(x_ref, bc: jax.Array, b: int) -> jax.Array:
    """(slab, b) slices of the x vector addressed by block column."""
    idx = bc[:, None] * b + jax.lax.broadcasted_iota(jnp.int32, (1, b), 1)
    return x_ref[...].astype(jnp.float32)[idx]


def _bcsr_spmv_kernel(interpret, masked, slab_ref, data_ref, bcols_ref,
                      win_ref, x_ref, y_ref):
    i, j = pl.program_id(0), pl.program_id(1)
    bn = data_ref.shape[0]
    b = data_ref.shape[1]
    lrow, valid = _local_rows(win_ref[0, :], (slab_ref[i] + j) * bn, bn,
                              jnp.int32, interpret, masked)
    xg = _gather_x_blocks(x_ref, bcols_ref[...], b)           # (bn, b)
    tiles = jnp.einsum("pij,pj->pi", data_ref[...].astype(jnp.float32), xg)
    if valid is not None:
        tiles = jnp.where(valid[:, None], tiles, 0.0)         # (bn, b)
    rows = lrow[:, None] * b + jax.lax.broadcasted_iota(jnp.int32, (1, b), 1)
    partial = jnp.zeros_like(y_ref).at[rows.reshape(-1)].add(
        tiles.reshape(-1))

    @pl.when(j == 0)
    def _init():
        y_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        y_ref[...] = y_ref[...] + partial


@functools.partial(jax.jit, static_argnames=("rows_per_tile", "block_nnz",
                                             "slabs_per_block", "interpret"))
def bcsr_spmv(data: jax.Array, block_cols: jax.Array, indptr: jax.Array,
              x: jax.Array, *, rows_per_tile: int = 32, block_nnz: int = 512,
              slabs_per_block: int = 0, interpret: bool = True) -> jax.Array:
    """y = A @ x, A in BCSR: data (nblocks_pad, b, b), block IRP
    (n_block_rows + 1,), x padded to a multiple of b.  Returns
    (n_block_rows * b,) float32 (callers slice to n_rows)."""
    nbr = indptr.shape[0] - 1
    b = data.shape[1]
    r = -(-nbr // rows_per_tile)
    total = -(-data.shape[0] // block_nnz)
    spb, slab_start = _slab_schedule(indptr, r, rows_per_tile, block_nnz,
                                     total, slabs_per_block)
    win = _row_windows(indptr, nbr, rows_per_tile)
    data = _pad_block_slabs(data, total, block_nnz)
    block_cols = _pad_block_slabs(block_cols, total, block_nnz)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(r, spb),
        in_specs=[
            pl.BlockSpec((block_nnz, b, b), lambda i, j, s: (s[i] + j, 0, 0)),
            pl.BlockSpec((block_nnz,), lambda i, j, s: (s[i] + j,)),
            pl.BlockSpec((1, rows_per_tile + 1), lambda i, j, s: (i, 0)),
            pl.BlockSpec(x.shape, lambda i, j, s: (0,)),
        ],
        out_specs=pl.BlockSpec((rows_per_tile * b,), lambda i, j, s: (i,)),
    )
    y = pl.pallas_call(
        functools.partial(_bcsr_spmv_kernel, interpret, r > 1),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r * rows_per_tile * b,), jnp.float32),
        interpret=interpret,
    )(slab_start.astype(jnp.int32), data, block_cols, win, x)
    return y[: nbr * b]


def _bcsr_spmm_kernel(interpret, masked, slab_ref, data_ref, bcols_ref,
                      win_ref, x_ref, y_ref):
    i, j = pl.program_id(0), pl.program_id(2)
    bn = data_ref.shape[0]
    b = data_ref.shape[1]
    lrow, valid = _local_rows(win_ref[0, :], (slab_ref[i] + j) * bn, bn,
                              jnp.int32, interpret, masked)
    idx = (bcols_ref[...][:, None] * b +
           jax.lax.broadcasted_iota(jnp.int32, (1, b), 1))
    xg = x_ref[...].astype(jnp.float32)[idx, :]               # (bn, b, bk)
    tiles = jnp.einsum("pij,pjc->pic", data_ref[...].astype(jnp.float32), xg)
    if valid is not None:
        tiles = jnp.where(valid[:, None, None], tiles, 0.0)
    rows = lrow[:, None] * b + jax.lax.broadcasted_iota(jnp.int32, (1, b), 1)
    partial = jnp.zeros_like(y_ref).at[rows.reshape(-1), :].add(
        tiles.reshape(-1, tiles.shape[-1]))

    @pl.when(j == 0)
    def _init():
        y_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        y_ref[...] = y_ref[...] + partial


@functools.partial(jax.jit, static_argnames=("rows_per_tile", "block_nnz",
                                             "block_k", "slabs_per_block",
                                             "interpret"))
def bcsr_spmm(data: jax.Array, block_cols: jax.Array, indptr: jax.Array,
              x: jax.Array, *, rows_per_tile: int = 32, block_nnz: int = 512,
              block_k: int = 128, slabs_per_block: int = 0,
              interpret: bool = True) -> jax.Array:
    """Y = A @ X, A in BCSR, X ((n_col_blocks * b), k) -> (nbr * b, k) f32.

    Grid = (row_tiles, k_blocks, slabs); slabs innermost (sequential)."""
    nbr = indptr.shape[0] - 1
    b = data.shape[1]
    n_cols_pad, kk = x.shape
    assert kk % block_k == 0, (kk, block_k)
    r = -(-nbr // rows_per_tile)
    total = -(-data.shape[0] // block_nnz)
    spb, slab_start = _slab_schedule(indptr, r, rows_per_tile, block_nnz,
                                     total, slabs_per_block)
    win = _row_windows(indptr, nbr, rows_per_tile)
    data = _pad_block_slabs(data, total, block_nnz)
    block_cols = _pad_block_slabs(block_cols, total, block_nnz)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(r, kk // block_k, spb),
        in_specs=[
            pl.BlockSpec((block_nnz, b, b),
                         lambda i, c, j, s: (s[i] + j, 0, 0)),
            pl.BlockSpec((block_nnz,), lambda i, c, j, s: (s[i] + j,)),
            pl.BlockSpec((1, rows_per_tile + 1), lambda i, c, j, s: (i, 0)),
            pl.BlockSpec((n_cols_pad, block_k), lambda i, c, j, s: (0, c)),
        ],
        out_specs=pl.BlockSpec((rows_per_tile * b, block_k),
                               lambda i, c, j, s: (i, c)),
    )
    y = pl.pallas_call(
        functools.partial(_bcsr_spmm_kernel, interpret, r > 1),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r * rows_per_tile * b, kk),
                                       jnp.float32),
        interpret=interpret,
    )(slab_start.astype(jnp.int32), data, block_cols, win, x)
    return y[: nbr * b]
