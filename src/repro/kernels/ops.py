"""jit'd public wrappers around the Pallas kernels.

Responsibilities:
  * pad raw arrays to TPU block alignment (val=0/col=0 padding — the
    paper's own ELL zero-fill convention, so padding never changes results);
  * accept the ``repro.core.formats`` pytree classes;
  * provide custom VJPs so the kernels are trainable (y = A@x  =>
    dx = A^T dy via a COO scatter; dA = dy_r * x_c at the stored positions);
  * auto-select interpret mode off-TPU;
  * accept a per-call launch geometry (``tuning=`` — a
    ``core.kernel_tune.TileGeometry``); ``None`` fields fall back to the
    built-in defaults below, so the kernel launch-geometry auto-tuner can
    override exactly the knobs it searched;
  * register every format-level wrapper in the ``repro.core.dispatch``
    registry under the ``"kernel"`` tier — ``KERNEL_SPMV_IMPLS`` /
    ``KERNEL_SPMM_IMPLS`` below are views of that registry, kept for
    callers that want a plain dict.

CSR is served by the native row-segmented kernel (``kernels/csr_spmv.py``);
the old CSR-via-COO detour survives only as ``spmv_csr_via_coo`` /
``spmm_csr_via_coo`` so benchmarks can measure what replacing it bought.
CCS is served by the column-segmented mirror (``kernels/ccs_spmv.py``) —
every registered base format now has a native kernel.  SELL accepts a
*per-bucket* launch geometry (a ``TileGeometry`` carrying a
``buckets`` table, a ``{width: TileGeometry}`` mapping, or a positional
sequence) so each bucket launches with its own tile shape.
"""
from __future__ import annotations

import functools
from typing import Dict, Mapping, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch as _dispatch
from repro.core.formats import BCSR, CCS, COO, CSR, ELL, BucketedELL
from repro.core.kernel_tune import TileGeometry
from . import bcsr_spmv as _bcsr
from . import ccs_spmv as _ccsk
from . import coo_spmv as _coo
from . import csr_spmv as _csr
from . import ell_spmv as _ell


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret(flag: Optional[bool]) -> bool:
    return (not _on_tpu()) if flag is None else flag


def _pad_to(x: jax.Array, axis: int, mult: int, value=0) -> jax.Array:
    size = x.shape[axis]
    target = ((size + mult - 1) // mult) * mult
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads, constant_values=value)


def _align8(n: int) -> int:
    return max(8, 8 * ((int(n) + 7) // 8))


def _block_sizes(n_rows: int, width: int) -> Tuple[int, int]:
    """Default tile shape: rows capped at 256 sublanes; the band tile is the
    smallest 8-aligned width covering the band, capped at 128 lanes — a
    40-wide band used to be padded to 128 lanes (up to 16x wasted work per
    tile), now it gets a 40-lane tile."""
    br = min(256, _align8(n_rows))
    bw = min(128, _align8(width))
    return br, bw


def _block_k(k: int) -> int:
    return min(128, _align8(k))


def _geom(tuning: Optional[TileGeometry], name: str, default: int,
          cap: Optional[int] = None) -> int:
    v = getattr(tuning, name, None) if tuning is not None else None
    v = default if v is None else _align8(v)
    return min(v, cap) if cap is not None else v


# ---------------------------------------------------------------------------
# raw-array entry points (padding + alignment)
# ---------------------------------------------------------------------------
def ell_spmv_raw(data: jax.Array, cols: jax.Array, x: jax.Array,
                 interpret: Optional[bool] = None,
                 tuning: Optional[TileGeometry] = None) -> jax.Array:
    n_rows, width = data.shape
    br0, bw0 = _block_sizes(n_rows, width)
    br = _geom(tuning, "block_rows", br0, cap=_align8(n_rows))
    bw = _geom(tuning, "block_w", bw0, cap=_align8(width))
    data = _pad_to(_pad_to(data, 0, br), 1, bw)
    cols = _pad_to(_pad_to(cols, 0, br), 1, bw)
    y = _ell.ell_spmv(data, cols, x, block_rows=br, block_w=bw,
                      interpret=_interpret(interpret))
    return y[:n_rows]


def ell_spmm_raw(data: jax.Array, cols: jax.Array, x: jax.Array,
                 interpret: Optional[bool] = None,
                 tuning: Optional[TileGeometry] = None) -> jax.Array:
    n_rows, width = data.shape
    k = x.shape[1]
    _, bw0 = _block_sizes(n_rows, width)
    br = _geom(tuning, "block_rows", min(128, _align8(n_rows)),
               cap=_align8(n_rows))
    bw = _geom(tuning, "block_w", bw0, cap=_align8(width))
    bk = _geom(tuning, "block_k", _block_k(k), cap=_align8(k))
    data = _pad_to(_pad_to(data, 0, br), 1, bw)
    cols = _pad_to(_pad_to(cols, 0, br), 1, bw)
    xp = _pad_to(x, 1, bk)
    y = _ell.ell_spmm(data, cols, xp, block_rows=br, block_w=bw, block_k=bk,
                      interpret=_interpret(interpret))
    return y[:n_rows, :k]


def coo_spmv_raw(data: jax.Array, rows: jax.Array, cols: jax.Array,
                 x: jax.Array, n_rows: int,
                 interpret: Optional[bool] = None,
                 tuning: Optional[TileGeometry] = None) -> jax.Array:
    bn = _geom(tuning, "block_nnz", min(4096, _align8(data.shape[0])),
               cap=_align8(data.shape[0]))
    data = _pad_to(data, 0, bn)
    rows = _pad_to(rows, 0, bn)
    cols = _pad_to(cols, 0, bn)
    return _coo.coo_spmv(data, rows, cols, x, n_rows=n_rows, block_nnz=bn,
                         interpret=_interpret(interpret))


def coo_spmm_raw(data: jax.Array, rows: jax.Array, cols: jax.Array,
                 x: jax.Array, n_rows: int,
                 interpret: Optional[bool] = None,
                 tuning: Optional[TileGeometry] = None) -> jax.Array:
    k = x.shape[1]
    bn = _geom(tuning, "block_nnz", min(4096, _align8(data.shape[0])),
               cap=_align8(data.shape[0]))
    bk = _geom(tuning, "block_k", _block_k(k), cap=_align8(k))
    data = _pad_to(data, 0, bn)
    rows = _pad_to(rows, 0, bn)
    cols = _pad_to(cols, 0, bn)
    xp = _pad_to(x, 1, bk)
    y = _coo.coo_spmm(data, rows, cols, xp, n_rows=n_rows, block_nnz=bn,
                      block_k=bk, interpret=_interpret(interpret))
    return y[:, :k]


# ---------------------------------------------------------------------------
# differentiable ELL SpMV (core op used inside models)
# ---------------------------------------------------------------------------
@jax.custom_vjp
def ell_spmv_ad(data: jax.Array, cols: jax.Array, x: jax.Array) -> jax.Array:
    return ell_spmv_raw(data, cols, x)


def _ell_fwd(data, cols, x):
    return ell_spmv_ad(data, cols, x), (data, cols, x)


def _ell_bwd(res, dy):
    data, cols, x = res
    # dx[c] = sum_{r,k: cols[r,k]=c} data[r,k] * dy[r]   (A^T dy, COO scatter)
    dx = jnp.zeros_like(x).at[cols.reshape(-1)].add(
        (data * dy[:, None]).reshape(-1).astype(x.dtype))
    # dA[r,k] = dy[r] * x[cols[r,k]]
    ddata = (dy[:, None] * x[cols]).astype(data.dtype)
    return ddata, None, dx


ell_spmv_ad.defvjp(_ell_fwd, _ell_bwd)


# ---------------------------------------------------------------------------
# format-level entry points (what the auto-tuner plugs in)
# ---------------------------------------------------------------------------
def _ell_arrays(m: ELL):
    data, cols = jnp.asarray(m.data), jnp.asarray(m.cols)
    if m.order == "col":
        data, cols = data.T, cols.T
    return data, cols


def spmv_ell(m: ELL, x: jax.Array, interpret: Optional[bool] = None,
             tuning: Optional[TileGeometry] = None) -> jax.Array:
    data, cols = _ell_arrays(m)
    return ell_spmv_raw(data, cols, x, interpret, tuning)


def spmm_ell(m: ELL, x: jax.Array, interpret: Optional[bool] = None,
             tuning: Optional[TileGeometry] = None) -> jax.Array:
    data, cols = _ell_arrays(m)
    return ell_spmm_raw(data, cols, x, interpret, tuning)


def spmv_coo(m: COO, x: jax.Array, interpret: Optional[bool] = None,
             tuning: Optional[TileGeometry] = None) -> jax.Array:
    return coo_spmv_raw(jnp.asarray(m.data), jnp.asarray(m.rows),
                        jnp.asarray(m.cols), x, m.n_rows, interpret, tuning)


def spmm_coo(m: COO, x: jax.Array, interpret: Optional[bool] = None,
             tuning: Optional[TileGeometry] = None) -> jax.Array:
    return coo_spmm_raw(jnp.asarray(m.data), jnp.asarray(m.rows),
                        jnp.asarray(m.cols), x, m.n_rows, interpret, tuning)


# ---------------------------------------------------------------------------
# CSR — native row-segmented kernel (kernels/csr_spmv.py)
# ---------------------------------------------------------------------------
def _csr_slab_bound(m: CSR, br: int, bn: int,
                    tuning: Optional[TileGeometry]) -> int:
    """Static slab-coverage bound: exact when the index structure is
    concrete; from the tuned geometry under trace; 0 (always-correct full
    sweep) otherwise."""
    ip = m.indptr
    if not isinstance(ip, jax.core.Tracer):
        return _csr.slabs_needed(np.asarray(ip), br, bn)
    if tuning is not None and tuning.slabs_per_block is not None:
        return int(tuning.slabs_per_block)
    return 0


def spmv_csr(m: CSR, x: jax.Array, interpret: Optional[bool] = None,
             tuning: Optional[TileGeometry] = None) -> jax.Array:
    """CSR through the native row-segmented kernel (no COO detour)."""
    br = _geom(tuning, "block_rows", min(256, _align8(m.n_rows)),
               cap=_align8(m.n_rows))
    bn = _geom(tuning, "block_nnz", min(2048, _align8(m.nnz_pad)),
               cap=_align8(m.nnz_pad))
    spb = _csr_slab_bound(m, br, bn, tuning)
    y = _csr.csr_spmv(jnp.asarray(m.data), jnp.asarray(m.cols),
                      jnp.asarray(m.indptr), x, block_rows=br, block_nnz=bn,
                      slabs_per_block=spb, interpret=_interpret(interpret))
    return y.astype(jnp.result_type(m.data.dtype, x.dtype))


def spmm_csr(m: CSR, x: jax.Array, interpret: Optional[bool] = None,
             tuning: Optional[TileGeometry] = None) -> jax.Array:
    k = x.shape[1]
    br = _geom(tuning, "block_rows", min(256, _align8(m.n_rows)),
               cap=_align8(m.n_rows))
    bn = _geom(tuning, "block_nnz", min(2048, _align8(m.nnz_pad)),
               cap=_align8(m.nnz_pad))
    bk = _geom(tuning, "block_k", _block_k(k), cap=_align8(k))
    spb = _csr_slab_bound(m, br, bn, tuning)
    xp = _pad_to(x, 1, bk)
    y = _csr.csr_spmm(jnp.asarray(m.data), jnp.asarray(m.cols),
                      jnp.asarray(m.indptr), xp, block_rows=br, block_nnz=bn,
                      block_k=bk, slabs_per_block=spb,
                      interpret=_interpret(interpret))
    return y[:, :k].astype(jnp.result_type(m.data.dtype, x.dtype))


def _csr_as_coo_arrays(m: CSR):
    """The jit-able IRP->IROW expansion — the pre-native CSR kernel path,
    kept for the tuned-vs-detour benchmark comparison."""
    ip = jnp.asarray(m.indptr)
    k = jnp.arange(m.nnz_pad, dtype=ip.dtype)
    rows = jnp.clip(jnp.searchsorted(ip, k, side="right") - 1, 0, m.n_rows - 1)
    rows = jnp.where(k < m.nnz, rows, 0).astype(jnp.int32)
    data = jnp.where(k < m.nnz, jnp.asarray(m.data), 0)
    return data, rows, jnp.asarray(m.cols)


def spmv_csr_via_coo(m: CSR, x: jax.Array,
                     interpret: Optional[bool] = None,
                     tuning: Optional[TileGeometry] = None) -> jax.Array:
    """Legacy CSR path: IRP->IROW expansion + the COO kernel (benchmark
    baseline only — the registry serves :func:`spmv_csr`)."""
    data, rows, cols = _csr_as_coo_arrays(m)
    return coo_spmv_raw(data, rows, cols, x, m.n_rows, interpret, tuning)


def spmm_csr_via_coo(m: CSR, x: jax.Array,
                     interpret: Optional[bool] = None,
                     tuning: Optional[TileGeometry] = None) -> jax.Array:
    data, rows, cols = _csr_as_coo_arrays(m)
    return coo_spmm_raw(data, rows, cols, x, m.n_rows, interpret, tuning)


# ---------------------------------------------------------------------------
# CCS — native column-segmented kernel (kernels/ccs_spmv.py)
# ---------------------------------------------------------------------------
def _ccs_slab_bound(m: CCS, bc: int, bn: int,
                    tuning: Optional[TileGeometry]) -> int:
    """Static slab-coverage bound over the *column* pointer: exact when the
    index structure is concrete; from the tuned geometry under trace; 0
    (always-correct full sweep) otherwise."""
    ip = m.indptr
    if not isinstance(ip, jax.core.Tracer):
        return _ccsk.slabs_needed(np.asarray(ip), bc, bn)
    if tuning is not None and tuning.slabs_per_block is not None:
        return int(tuning.slabs_per_block)
    return 0


def spmv_ccs(m: CCS, x: jax.Array, interpret: Optional[bool] = None,
             tuning: Optional[TileGeometry] = None) -> jax.Array:
    """CCS through the native column-segmented kernel.  ``block_rows`` is
    the segmented-axis tile, so for CCS it tiles *columns* (the kernel's
    ``block_cols``) — one knob, one meaning: rows for CSR, columns here."""
    bc = _geom(tuning, "block_rows", min(256, _align8(m.n_cols)),
               cap=_align8(m.n_cols))
    bn = _geom(tuning, "block_nnz", min(2048, _align8(m.nnz_pad)),
               cap=_align8(m.nnz_pad))
    spb = _ccs_slab_bound(m, bc, bn, tuning)
    y = _ccsk.ccs_spmv(jnp.asarray(m.data), jnp.asarray(m.rows),
                       jnp.asarray(m.indptr), x, n_rows=m.n_rows,
                       block_cols=bc, block_nnz=bn, slabs_per_block=spb,
                       interpret=_interpret(interpret))
    return y.astype(jnp.result_type(m.data.dtype, x.dtype))


def spmm_ccs(m: CCS, x: jax.Array, interpret: Optional[bool] = None,
             tuning: Optional[TileGeometry] = None) -> jax.Array:
    k = x.shape[1]
    bc = _geom(tuning, "block_rows", min(256, _align8(m.n_cols)),
               cap=_align8(m.n_cols))
    bn = _geom(tuning, "block_nnz", min(2048, _align8(m.nnz_pad)),
               cap=_align8(m.nnz_pad))
    bk = _geom(tuning, "block_k", _block_k(k), cap=_align8(k))
    spb = _ccs_slab_bound(m, bc, bn, tuning)
    xp = _pad_to(x, 1, bk)
    y = _ccsk.ccs_spmm(jnp.asarray(m.data), jnp.asarray(m.rows),
                       jnp.asarray(m.indptr), xp, n_rows=m.n_rows,
                       block_cols=bc, block_nnz=bn, block_k=bk,
                       slabs_per_block=spb, interpret=_interpret(interpret))
    return y[:, :k].astype(jnp.result_type(m.data.dtype, x.dtype))


# ---------------------------------------------------------------------------
# BCSR — block-tiled kernel (kernels/bcsr_spmv.py)
# ---------------------------------------------------------------------------
def _bcsr_geometry(m: BCSR, tuning: Optional[TileGeometry]):
    rpt = max(1, min(_geom(tuning, "block_rows", min(32, m.n_block_rows or 1)),
                     m.n_block_rows or 1))
    bnb = max(1, min(_geom(tuning, "block_nnz",
                           min(512, _align8(m.nblocks_pad))),
                     _align8(m.nblocks_pad)))
    ip = m.indptr
    if not isinstance(ip, jax.core.Tracer):
        spb = _bcsr.slabs_needed(np.asarray(ip), rpt, bnb)
    elif tuning is not None and tuning.slabs_per_block is not None:
        spb = int(tuning.slabs_per_block)
    else:
        spb = 0
    return rpt, bnb, spb


def exact_slab_bound(m, tuning: Optional[TileGeometry] = None) -> int:
    """Concrete slab-coverage bound for a CSR/BCSR instance at the
    wrapper's own *effective* launch geometry (tile knobs get clamped to
    the instance, so the bound must be derived post-clamp).  For baking
    one bound into a geometry shared by sibling blocks, take the max over
    the blocks — a larger bound only adds masked slabs, never drops
    entries."""
    t = tuning.without_slab_bound() if tuning is not None else None
    if isinstance(m, CSR):
        br = _geom(t, "block_rows", min(256, _align8(m.n_rows)),
                   cap=_align8(m.n_rows))
        bn = _geom(t, "block_nnz", min(2048, _align8(m.nnz_pad)),
                   cap=_align8(m.nnz_pad))
        return _csr.slabs_needed(np.asarray(m.indptr), br, bn)
    if isinstance(m, CCS):
        bc = _geom(t, "block_rows", min(256, _align8(m.n_cols)),
                   cap=_align8(m.n_cols))
        bn = _geom(t, "block_nnz", min(2048, _align8(m.nnz_pad)),
                   cap=_align8(m.nnz_pad))
        return _ccsk.slabs_needed(np.asarray(m.indptr), bc, bn)
    if isinstance(m, BCSR):
        return _bcsr_geometry(m, t)[2]
    raise TypeError(f"no slab-coverage bound for {type(m)}")


def spmv_bcsr(m: BCSR, x: jax.Array, interpret: Optional[bool] = None,
              tuning: Optional[TileGeometry] = None) -> jax.Array:
    rpt, bnb, spb = _bcsr_geometry(m, tuning)
    xp = _pad_to(x, 0, m.block)
    y = _bcsr.bcsr_spmv(jnp.asarray(m.data), jnp.asarray(m.block_cols),
                        jnp.asarray(m.indptr), xp, rows_per_tile=rpt,
                        block_nnz=bnb, slabs_per_block=spb,
                        interpret=_interpret(interpret))
    return y[: m.n_rows].astype(jnp.result_type(m.data.dtype, x.dtype))


def spmm_bcsr(m: BCSR, x: jax.Array, interpret: Optional[bool] = None,
              tuning: Optional[TileGeometry] = None) -> jax.Array:
    k = x.shape[1]
    rpt, bnb, spb = _bcsr_geometry(m, tuning)
    bk = _geom(tuning, "block_k", _block_k(k), cap=_align8(k))
    xp = _pad_to(_pad_to(x, 0, m.block), 1, bk)
    y = _bcsr.bcsr_spmm(jnp.asarray(m.data), jnp.asarray(m.block_cols),
                        jnp.asarray(m.indptr), xp, rows_per_tile=rpt,
                        block_nnz=bnb, block_k=bk, slabs_per_block=spb,
                        interpret=_interpret(interpret))
    return y[: m.n_rows, :k].astype(jnp.result_type(m.data.dtype, x.dtype))


# ---------------------------------------------------------------------------
# SELL / hybrid containers
# ---------------------------------------------------------------------------
SellTuning = Union[TileGeometry, Sequence[Optional[TileGeometry]],
                   Mapping[int, TileGeometry]]


def _sell_tunings(m: BucketedELL, tuning: Optional[SellTuning]
                  ) -> Tuple[Optional[TileGeometry], ...]:
    """Resolve the per-bucket launch geometry for a SELL container.

    ``tuning`` may be: ``None`` (defaults everywhere); one
    :class:`TileGeometry` — broadcast, unless it carries a ``buckets``
    table, in which case each bucket looks up its *width* and falls back
    to the table-less top-level knobs; a ``{width: TileGeometry}`` mapping;
    or a positional sequence (one entry per bucket, ``None`` allowed)."""
    n = len(m.buckets)
    if tuning is None:
        return (None,) * n
    if isinstance(tuning, Mapping):
        return tuple(tuning.get(b.width) for b in m.buckets)
    if isinstance(tuning, (list, tuple)):
        if len(tuning) != n:
            raise ValueError(f"per-bucket tuning sequence has {len(tuning)} "
                             f"entries for {n} buckets")
        return tuple(tuning)
    if tuning.buckets:
        table = dict(tuning.buckets)
        base = tuning.broadcast()
        return tuple(table.get(b.width, base) for b in m.buckets)
    return (tuning,) * n


def spmv_sell(m: BucketedELL, x: jax.Array,
              interpret: Optional[bool] = None,
              tuning: Optional[SellTuning] = None) -> jax.Array:
    # an all-zero matrix may carry an empty bucket list — the product is
    # exactly zeros of (n_rows,) in x's dtype, not None
    perm = jnp.asarray(m.perm)
    y = jnp.zeros((m.n_rows,), x.dtype)
    for off, b, g in zip(m.row_offsets, m.buckets, _sell_tunings(m, tuning)):
        yb = ell_spmv_raw(jnp.asarray(b.data), jnp.asarray(b.cols), x,
                          interpret, g)
        y = y.at[perm[off:off + b.n_rows]].set(yb.astype(y.dtype))
    return y


def spmm_sell(m: BucketedELL, x: jax.Array,
              interpret: Optional[bool] = None,
              tuning: Optional[SellTuning] = None) -> jax.Array:
    perm = jnp.asarray(m.perm)
    y = jnp.zeros((m.n_rows, x.shape[1]), x.dtype)
    for off, b, g in zip(m.row_offsets, m.buckets, _sell_tunings(m, tuning)):
        yb = ell_spmm_raw(jnp.asarray(b.data), jnp.asarray(b.cols), x,
                          interpret, g)
        y = y.at[perm[off:off + b.n_rows]].set(yb.astype(y.dtype))
    return y


def _kernel_block_impls(op: str, interpret: Optional[bool],
                        tuning: Optional[Dict[str, TileGeometry]] = None):
    """Per-block overrides for the hybrid container: every kernel-tier impl
    except hybrid itself, with ``interpret`` (and any per-format tuned
    geometry) bound."""
    out = {}
    for f, impl in _dispatch.impl_table(op, "kernel",
                                        exclude=("hybrid",)).items():
        g = (tuning or {}).get(f)
        out[f] = functools.partial(impl, interpret=interpret, tuning=g)
    return out


def spmv_hybrid(m, x: jax.Array,
                interpret: Optional[bool] = None,
                tuning: Optional[Dict[str, TileGeometry]] = None
                ) -> jax.Array:
    """Partitioned hybrid matrix: each row block through its own format's
    Pallas kernel (reassembly lives in the partition subsystem).  ``tuning``
    maps format name -> TileGeometry for the per-block kernels."""
    from repro.partition import spmv_hybrid as _hyb
    return _hyb(m, x, impls=_kernel_block_impls("spmv", interpret, tuning))


def spmm_hybrid(m, x: jax.Array,
                interpret: Optional[bool] = None,
                tuning: Optional[Dict[str, TileGeometry]] = None
                ) -> jax.Array:
    from repro.partition import spmm_hybrid as _hyb
    return _hyb(m, x, impls=_kernel_block_impls("spmm", interpret, tuning))


# ---------------------------------------------------------------------------
# registry: the kernel tier of repro.core.dispatch
# ---------------------------------------------------------------------------
for _fmt, _spmv_fn, _spmm_fn in (
    ("csr", spmv_csr, spmm_csr),
    ("ccs", spmv_ccs, spmm_ccs),
    ("coo_row", spmv_coo, spmm_coo),
    ("coo_col", spmv_coo, spmm_coo),
    ("ell_row", spmv_ell, spmm_ell),
    ("ell_col", spmv_ell, spmm_ell),
    ("sell", spmv_sell, spmm_sell),
    ("bcsr", spmv_bcsr, spmm_bcsr),
    ("hybrid", spmv_hybrid, spmm_hybrid),
):
    _dispatch.register_impl(_fmt, "spmv", _spmv_fn, tier="kernel")
    _dispatch.register_impl(_fmt, "spmm", _spmm_fn, tier="kernel")

# read-only dict views of the registry, recomputed on access so later
# registrations are never missed — the single source of truth stays in
# core/dispatch.  Mutating the returned dict has no effect; add or override
# implementations with
# ``repro.core.dispatch.register_impl(fmt, op, fn, tier="kernel")``.
def __getattr__(name: str):
    if name == "KERNEL_SPMV_IMPLS":
        return _dispatch.impl_table("spmv", "kernel")
    if name == "KERNEL_SPMM_IMPLS":
        return _dispatch.impl_table("spmm", "kernel")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = ["ell_spmv_raw", "ell_spmm_raw", "coo_spmv_raw", "coo_spmm_raw",
           "ell_spmv_ad", "spmv_ell", "spmm_ell", "spmv_coo", "spmm_coo",
           "spmv_csr", "spmm_csr", "spmv_csr_via_coo", "spmm_csr_via_coo",
           "spmv_ccs", "spmm_ccs",
           "spmv_bcsr", "spmm_bcsr", "exact_slab_bound",
           "spmv_sell", "spmm_sell",
           "spmv_hybrid", "spmm_hybrid", "KERNEL_SPMV_IMPLS",
           "KERNEL_SPMM_IMPLS"]
