"""jit'd public wrappers around the Pallas kernels.

Responsibilities:
  * pad raw arrays to TPU block alignment (val=0/col=0 padding — the
    paper's own ELL zero-fill convention, so padding never changes results);
  * accept the ``repro.core.formats`` pytree classes;
  * provide custom VJPs so the kernels are trainable (y = A@x  =>
    dx = A^T dy via a COO scatter; dA = dy_r * x_c at the stored positions);
  * auto-select interpret mode off-TPU;
  * register every format-level wrapper in the ``repro.core.dispatch``
    registry under the ``"kernel"`` tier — ``KERNEL_SPMV_IMPLS`` /
    ``KERNEL_SPMM_IMPLS`` below are views of that registry, kept for
    callers that want a plain dict.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import dispatch as _dispatch
from repro.core.formats import COO, CSR, ELL, BucketedELL
from . import coo_spmv as _coo
from . import ell_spmv as _ell


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret(flag: Optional[bool]) -> bool:
    return (not _on_tpu()) if flag is None else flag


def _pad_to(x: jax.Array, axis: int, mult: int, value=0) -> jax.Array:
    size = x.shape[axis]
    target = ((size + mult - 1) // mult) * mult
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads, constant_values=value)


def _block_sizes(n_rows: int, width: int) -> Tuple[int, int]:
    """Pick aligned block sizes that keep the working set well inside VMEM
    (default tiles: 256x128 f32 = 128 KiB/operand)."""
    br = min(256, max(8, 8 * ((n_rows + 7) // 8)))
    bw = 128 if width > 8 else 8
    return br, bw


def _block_k(k: int) -> int:
    return min(128, max(8, 8 * ((k + 7) // 8)))


# ---------------------------------------------------------------------------
# raw-array entry points (padding + alignment)
# ---------------------------------------------------------------------------
def ell_spmv_raw(data: jax.Array, cols: jax.Array, x: jax.Array,
                 interpret: Optional[bool] = None) -> jax.Array:
    n_rows, width = data.shape
    br, bw = _block_sizes(n_rows, width)
    data = _pad_to(_pad_to(data, 0, br), 1, bw)
    cols = _pad_to(_pad_to(cols, 0, br), 1, bw)
    y = _ell.ell_spmv(data, cols, x, block_rows=br, block_w=bw,
                      interpret=_interpret(interpret))
    return y[:n_rows]


def ell_spmm_raw(data: jax.Array, cols: jax.Array, x: jax.Array,
                 interpret: Optional[bool] = None) -> jax.Array:
    n_rows, width = data.shape
    k = x.shape[1]
    br = min(128, max(8, 8 * ((n_rows + 7) // 8)))
    bw = 128 if width > 8 else 8
    bk = _block_k(k)
    data = _pad_to(_pad_to(data, 0, br), 1, bw)
    cols = _pad_to(_pad_to(cols, 0, br), 1, bw)
    xp = _pad_to(x, 1, bk)
    y = _ell.ell_spmm(data, cols, xp, block_rows=br, block_w=bw, block_k=bk,
                      interpret=_interpret(interpret))
    return y[:n_rows, :k]


def coo_spmv_raw(data: jax.Array, rows: jax.Array, cols: jax.Array,
                 x: jax.Array, n_rows: int,
                 interpret: Optional[bool] = None) -> jax.Array:
    bn = min(4096, max(8, 8 * ((data.shape[0] + 7) // 8)))
    data = _pad_to(data, 0, bn)
    rows = _pad_to(rows, 0, bn)
    cols = _pad_to(cols, 0, bn)
    return _coo.coo_spmv(data, rows, cols, x, n_rows=n_rows, block_nnz=bn,
                         interpret=_interpret(interpret))


def coo_spmm_raw(data: jax.Array, rows: jax.Array, cols: jax.Array,
                 x: jax.Array, n_rows: int,
                 interpret: Optional[bool] = None) -> jax.Array:
    k = x.shape[1]
    bn = min(4096, max(8, 8 * ((data.shape[0] + 7) // 8)))
    bk = _block_k(k)
    data = _pad_to(data, 0, bn)
    rows = _pad_to(rows, 0, bn)
    cols = _pad_to(cols, 0, bn)
    xp = _pad_to(x, 1, bk)
    y = _coo.coo_spmm(data, rows, cols, xp, n_rows=n_rows, block_nnz=bn,
                      block_k=bk, interpret=_interpret(interpret))
    return y[:, :k]


# ---------------------------------------------------------------------------
# differentiable ELL SpMV (core op used inside models)
# ---------------------------------------------------------------------------
@jax.custom_vjp
def ell_spmv_ad(data: jax.Array, cols: jax.Array, x: jax.Array) -> jax.Array:
    return ell_spmv_raw(data, cols, x)


def _ell_fwd(data, cols, x):
    return ell_spmv_ad(data, cols, x), (data, cols, x)


def _ell_bwd(res, dy):
    data, cols, x = res
    # dx[c] = sum_{r,k: cols[r,k]=c} data[r,k] * dy[r]   (A^T dy, COO scatter)
    dx = jnp.zeros_like(x).at[cols.reshape(-1)].add(
        (data * dy[:, None]).reshape(-1).astype(x.dtype))
    # dA[r,k] = dy[r] * x[cols[r,k]]
    ddata = (dy[:, None] * x[cols]).astype(data.dtype)
    return ddata, None, dx


ell_spmv_ad.defvjp(_ell_fwd, _ell_bwd)


# ---------------------------------------------------------------------------
# format-level entry points (what the auto-tuner plugs in)
# ---------------------------------------------------------------------------
def _ell_arrays(m: ELL):
    data, cols = jnp.asarray(m.data), jnp.asarray(m.cols)
    if m.order == "col":
        data, cols = data.T, cols.T
    return data, cols


def spmv_ell(m: ELL, x: jax.Array, interpret: Optional[bool] = None) -> jax.Array:
    data, cols = _ell_arrays(m)
    return ell_spmv_raw(data, cols, x, interpret)


def spmm_ell(m: ELL, x: jax.Array, interpret: Optional[bool] = None) -> jax.Array:
    data, cols = _ell_arrays(m)
    return ell_spmm_raw(data, cols, x, interpret)


def spmv_coo(m: COO, x: jax.Array, interpret: Optional[bool] = None) -> jax.Array:
    return coo_spmv_raw(jnp.asarray(m.data), jnp.asarray(m.rows),
                        jnp.asarray(m.cols), x, m.n_rows, interpret)


def spmm_coo(m: COO, x: jax.Array, interpret: Optional[bool] = None) -> jax.Array:
    return coo_spmm_raw(jnp.asarray(m.data), jnp.asarray(m.rows),
                        jnp.asarray(m.cols), x, m.n_rows, interpret)


def _csr_as_coo_arrays(m: CSR):
    """The jit-able IRP->IROW expansion shared by the CSR kernel paths.

    Pure CSR's per-row segmented reduction has no efficient TPU mapping
    (DESIGN.md §2) — the row expansion is the TPU-idiomatic equivalent."""
    ip = jnp.asarray(m.indptr)
    k = jnp.arange(m.nnz_pad, dtype=ip.dtype)
    rows = jnp.clip(jnp.searchsorted(ip, k, side="right") - 1, 0, m.n_rows - 1)
    rows = jnp.where(k < m.nnz, rows, 0).astype(jnp.int32)
    data = jnp.where(k < m.nnz, jnp.asarray(m.data), 0)
    return data, rows, jnp.asarray(m.cols)


def spmv_csr(m: CSR, x: jax.Array, interpret: Optional[bool] = None) -> jax.Array:
    """CSR via the IRP->IROW expansion + the COO kernel."""
    data, rows, cols = _csr_as_coo_arrays(m)
    return coo_spmv_raw(data, rows, cols, x, m.n_rows, interpret)


def spmm_csr(m: CSR, x: jax.Array, interpret: Optional[bool] = None) -> jax.Array:
    data, rows, cols = _csr_as_coo_arrays(m)
    return coo_spmm_raw(data, rows, cols, x, m.n_rows, interpret)


def spmv_sell(m: BucketedELL, x: jax.Array,
              interpret: Optional[bool] = None) -> jax.Array:
    # an all-zero matrix may carry an empty bucket list — the product is
    # exactly zeros of (n_rows,) in x's dtype, not None
    perm = jnp.asarray(m.perm)
    y = jnp.zeros((m.n_rows,), x.dtype)
    for off, b in zip(m.row_offsets, m.buckets):
        yb = ell_spmv_raw(jnp.asarray(b.data), jnp.asarray(b.cols), x,
                          interpret)
        y = y.at[perm[off:off + b.n_rows]].set(yb.astype(y.dtype))
    return y


def spmm_sell(m: BucketedELL, x: jax.Array,
              interpret: Optional[bool] = None) -> jax.Array:
    perm = jnp.asarray(m.perm)
    y = jnp.zeros((m.n_rows, x.shape[1]), x.dtype)
    for off, b in zip(m.row_offsets, m.buckets):
        yb = ell_spmm_raw(jnp.asarray(b.data), jnp.asarray(b.cols), x,
                          interpret)
        y = y.at[perm[off:off + b.n_rows]].set(yb.astype(y.dtype))
    return y


def _kernel_block_impls(op: str, interpret: Optional[bool]):
    """Per-block overrides for the hybrid container: every kernel-tier impl
    except hybrid itself, with ``interpret`` bound."""
    return {f: functools.partial(impl, interpret=interpret)
            for f, impl in _dispatch.impl_table(op, "kernel",
                                                exclude=("hybrid",)).items()}


def spmv_hybrid(m, x: jax.Array,
                interpret: Optional[bool] = None) -> jax.Array:
    """Partitioned hybrid matrix: each row block through its own format's
    Pallas kernel (reassembly lives in the partition subsystem)."""
    from repro.partition import spmv_hybrid as _hyb
    return _hyb(m, x, impls=_kernel_block_impls("spmv", interpret))


def spmm_hybrid(m, x: jax.Array,
                interpret: Optional[bool] = None) -> jax.Array:
    from repro.partition import spmm_hybrid as _hyb
    return _hyb(m, x, impls=_kernel_block_impls("spmm", interpret))


# ---------------------------------------------------------------------------
# registry: the kernel tier of repro.core.dispatch
# ---------------------------------------------------------------------------
for _fmt, _spmv_fn, _spmm_fn in (
    ("csr", spmv_csr, spmm_csr),
    ("coo_row", spmv_coo, spmm_coo),
    ("coo_col", spmv_coo, spmm_coo),
    ("ell_row", spmv_ell, spmm_ell),
    ("ell_col", spmv_ell, spmm_ell),
    ("sell", spmv_sell, spmm_sell),
    ("hybrid", spmv_hybrid, spmm_hybrid),
):
    _dispatch.register_impl(_fmt, "spmv", _spmv_fn, tier="kernel")
    _dispatch.register_impl(_fmt, "spmm", _spmm_fn, tier="kernel")

# read-only dict views of the registry, recomputed on access so later
# registrations (e.g. a future bcsr Pallas kernel) are never missed — the
# single source of truth stays in core/dispatch.  Mutating the returned
# dict has no effect; add or override implementations with
# ``repro.core.dispatch.register_impl(fmt, op, fn, tier="kernel")``.
def __getattr__(name: str):
    if name == "KERNEL_SPMV_IMPLS":
        return _dispatch.impl_table("spmv", "kernel")
    if name == "KERNEL_SPMM_IMPLS":
        return _dispatch.impl_table("spmm", "kernel")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = ["ell_spmv_raw", "ell_spmm_raw", "coo_spmv_raw", "coo_spmm_raw",
           "ell_spmv_ad", "spmv_ell", "spmm_ell", "spmv_coo", "spmm_coo",
           "spmv_csr", "spmm_csr", "spmv_sell", "spmm_sell", "spmv_hybrid",
           "spmm_hybrid", "KERNEL_SPMV_IMPLS", "KERNEL_SPMM_IMPLS"]
