"""jit'd public wrappers around the Pallas kernels.

Responsibilities:
  * pad raw arrays to TPU block alignment (val=0/col=0 padding — the
    paper's own ELL zero-fill convention, so padding never changes results);
  * accept the ``repro.core.formats`` pytree classes;
  * provide custom VJPs so the kernels are trainable (y = A@x  =>
    dx = A^T dy via a COO scatter; dA = dy_r * x_c at the stored positions);
  * auto-select interpret mode off-TPU.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import COO, CSR, ELL, BucketedELL
from . import coo_spmv as _coo
from . import ell_spmv as _ell


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret(flag: Optional[bool]) -> bool:
    return (not _on_tpu()) if flag is None else flag


def _pad_to(x: jax.Array, axis: int, mult: int, value=0) -> jax.Array:
    size = x.shape[axis]
    target = ((size + mult - 1) // mult) * mult
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads, constant_values=value)


def _block_sizes(n_rows: int, width: int) -> Tuple[int, int]:
    """Pick aligned block sizes that keep the working set well inside VMEM
    (default tiles: 256x128 f32 = 128 KiB/operand)."""
    br = min(256, max(8, 8 * ((n_rows + 7) // 8)))
    bw = 128 if width > 8 else 8
    return br, bw


# ---------------------------------------------------------------------------
# raw-array entry points (padding + alignment)
# ---------------------------------------------------------------------------
def ell_spmv_raw(data: jax.Array, cols: jax.Array, x: jax.Array,
                 interpret: Optional[bool] = None) -> jax.Array:
    n_rows, width = data.shape
    br, bw = _block_sizes(n_rows, width)
    data = _pad_to(_pad_to(data, 0, br), 1, bw)
    cols = _pad_to(_pad_to(cols, 0, br), 1, bw)
    y = _ell.ell_spmv(data, cols, x, block_rows=br, block_w=bw,
                      interpret=_interpret(interpret))
    return y[:n_rows]


def ell_spmm_raw(data: jax.Array, cols: jax.Array, x: jax.Array,
                 interpret: Optional[bool] = None) -> jax.Array:
    n_rows, width = data.shape
    k = x.shape[1]
    br = min(128, max(8, 8 * ((n_rows + 7) // 8)))
    bw = 128 if width > 8 else 8
    bk = min(128, max(8, 8 * ((k + 7) // 8)))
    data = _pad_to(_pad_to(data, 0, br), 1, bw)
    cols = _pad_to(_pad_to(cols, 0, br), 1, bw)
    xp = _pad_to(x, 1, bk)
    y = _ell.ell_spmm(data, cols, xp, block_rows=br, block_w=bw, block_k=bk,
                      interpret=_interpret(interpret))
    return y[:n_rows, :k]


def coo_spmv_raw(data: jax.Array, rows: jax.Array, cols: jax.Array,
                 x: jax.Array, n_rows: int,
                 interpret: Optional[bool] = None) -> jax.Array:
    bn = min(4096, max(8, 8 * ((data.shape[0] + 7) // 8)))
    data = _pad_to(data, 0, bn)
    rows = _pad_to(rows, 0, bn)
    cols = _pad_to(cols, 0, bn)
    return _coo.coo_spmv(data, rows, cols, x, n_rows=n_rows, block_nnz=bn,
                         interpret=_interpret(interpret))


# ---------------------------------------------------------------------------
# differentiable ELL SpMV (core op used inside models)
# ---------------------------------------------------------------------------
@jax.custom_vjp
def ell_spmv_ad(data: jax.Array, cols: jax.Array, x: jax.Array) -> jax.Array:
    return ell_spmv_raw(data, cols, x)


def _ell_fwd(data, cols, x):
    return ell_spmv_ad(data, cols, x), (data, cols, x)


def _ell_bwd(res, dy):
    data, cols, x = res
    # dx[c] = sum_{r,k: cols[r,k]=c} data[r,k] * dy[r]   (A^T dy, COO scatter)
    dx = jnp.zeros_like(x).at[cols.reshape(-1)].add(
        (data * dy[:, None]).reshape(-1).astype(x.dtype))
    # dA[r,k] = dy[r] * x[cols[r,k]]
    ddata = (dy[:, None] * x[cols]).astype(data.dtype)
    return ddata, None, dx


ell_spmv_ad.defvjp(_ell_fwd, _ell_bwd)


# ---------------------------------------------------------------------------
# format-level entry points (what the auto-tuner plugs in)
# ---------------------------------------------------------------------------
def spmv_ell(m: ELL, x: jax.Array, interpret: Optional[bool] = None) -> jax.Array:
    data, cols = jnp.asarray(m.data), jnp.asarray(m.cols)
    if m.order == "col":
        data, cols = data.T, cols.T
    return ell_spmv_raw(data, cols, x, interpret)


def spmv_coo(m: COO, x: jax.Array, interpret: Optional[bool] = None) -> jax.Array:
    return coo_spmv_raw(jnp.asarray(m.data), jnp.asarray(m.rows),
                        jnp.asarray(m.cols), x, m.n_rows, interpret)


def spmv_csr(m: CSR, x: jax.Array, interpret: Optional[bool] = None) -> jax.Array:
    """CSR via the jit-able IRP->IROW expansion + the COO kernel.

    Pure CSR's per-row segmented reduction has no efficient TPU mapping
    (DESIGN.md §2) — the row expansion is the TPU-idiomatic equivalent."""
    ip = jnp.asarray(m.indptr)
    k = jnp.arange(m.nnz_pad, dtype=ip.dtype)
    rows = jnp.clip(jnp.searchsorted(ip, k, side="right") - 1, 0, m.n_rows - 1)
    rows = jnp.where(k < m.nnz, rows, 0).astype(jnp.int32)
    data = jnp.where(k < m.nnz, jnp.asarray(m.data), 0)
    return coo_spmv_raw(data, rows, jnp.asarray(m.cols), x, m.n_rows,
                        interpret)


def spmv_sell(m: BucketedELL, x: jax.Array,
              interpret: Optional[bool] = None) -> jax.Array:
    perm = jnp.asarray(m.perm)
    y = None
    for off, b in zip(m.row_offsets, m.buckets):
        yb = ell_spmv_raw(jnp.asarray(b.data), jnp.asarray(b.cols), x,
                          interpret)
        if y is None:
            y = jnp.zeros((m.n_rows,), yb.dtype)
        y = y.at[perm[off:off + b.n_rows]].set(yb)
    return y


def spmv_hybrid(m, x: jax.Array,
                interpret: Optional[bool] = None) -> jax.Array:
    """Partitioned hybrid matrix: each row block through its own format's
    Pallas kernel (reassembly lives in the partition subsystem)."""
    from repro.partition import spmv_hybrid as _dispatch
    impls = {f: functools.partial(impl, interpret=interpret)
             for f, impl in KERNEL_SPMV_IMPLS.items() if f != "hybrid"}
    return _dispatch(m, x, impls=impls)


KERNEL_SPMV_IMPLS = {
    "csr": spmv_csr,
    "coo_row": spmv_coo,
    "coo_col": spmv_coo,
    "ell_row": spmv_ell,
    "ell_col": spmv_ell,
    "sell": spmv_sell,
    "hybrid": spmv_hybrid,
}

__all__ = ["ell_spmv_raw", "ell_spmm_raw", "coo_spmv_raw", "ell_spmv_ad",
           "spmv_ell", "spmv_coo", "spmv_csr", "spmv_sell", "spmv_hybrid",
           "KERNEL_SPMV_IMPLS"]
