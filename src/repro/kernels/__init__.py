"""Pallas TPU kernels for the paper's compute hot spot (SpMV/SpMM) with
jit wrappers (ops) and pure-jnp oracles (ref)."""
from . import ops, ref
from .ell_spmv import ell_spmv, ell_spmm
from .coo_spmv import coo_spmv
from .csr_spmv import csr_spmv, csr_spmm
from .ccs_spmv import ccs_spmv, ccs_spmm
from .bcsr_spmv import bcsr_spmv, bcsr_spmm
from .decode_attention import decode_attention_int8
