"""Fused int8-KV flash-decode attention Pallas kernel.

§Perf Cell A follow-up: after weight-stationary serving, dbrx decode_32k
is memory-bound and the residual gap to the analytic bound is the
*materialized f32 dequantized KV cache* (XLA convert+multiply buffers).
This kernel streams the int8 codes + bf16 scales through VMEM and
dequantizes inside the block — the f32 cache copy never exists in HBM.

Napkin math (dbrx decode_32k, per device): int8 K+V slices 2.7 GB read
once = 3.3 ms at 819 GB/s, vs the XLA path's additional ~10.7 GB f32
write+read of the dequantized copies (~16 ms) — a ~4x cut of the
dominant memory term.

Layout: grid = (B, KV, S_chunks); the sequence axis is the sequential
innermost axis carrying the online-softmax state (m, l, acc) in VMEM
scratch — flash-decoding with int8 operands.  key_pos (B, S) carries the
absolute position per cache slot (-1 = empty; ring/linear caches and
per-slot lengths handled uniformly, matching models.attention)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, kq_ref, ks_ref, vq_ref, vs_ref, kpos_ref, qpos_ref,
            o_ref, m_scr, l_scr, acc_scr, *, window: Optional[int],
            n_chunks: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)              # (G, Dh)
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    # fused dequantization — int8 codes never leave VMEM as f32
    kf = (kq_ref[0, :, 0, :].astype(jnp.float32) *
          ks_ref[0, :, 0].astype(jnp.float32)[:, None])   # (S_blk, Dh)
    s = (q * scale) @ kf.T                            # (G, S_blk)

    kpos = kpos_ref[0]                                # (S_blk,)
    qpos = qpos_ref[0]
    valid = (kpos >= 0) & (kpos <= qpos)
    if window is not None:
        valid &= kpos > (qpos - window)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_scr[...]
    m_cur = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])                   # (G, S_blk)
    l_cur = l_scr[...] * alpha + p.sum(axis=-1)
    vf = (vq_ref[0, :, 0, :].astype(jnp.float32) *
          vs_ref[0, :, 0].astype(jnp.float32)[:, None])   # (S_blk, Dh)
    acc = acc_scr[...] * alpha[:, None] + p @ vf
    m_scr[...] = m_cur
    l_scr[...] = l_cur
    acc_scr[...] = acc

    @pl.when(j == n_chunks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "s_chunk", "interpret"))
def decode_attention_int8(q: jax.Array, k_q: jax.Array, k_s: jax.Array,
                          v_q: jax.Array, v_s: jax.Array,
                          key_pos: jax.Array, q_pos: jax.Array, *,
                          window: Optional[int] = None, s_chunk: int = 512,
                          interpret: bool = True) -> jax.Array:
    """q (B,KV,G,Dh) -> out (B,KV,G,Dh).

    k_q/v_q (B,S,KV,Dh) int8; k_s/v_s (B,S,KV) scales; key_pos (B,S) int32
    absolute positions (-1 empty); q_pos (B,) int32.  S must be a multiple
    of s_chunk (ops wrapper pads with key_pos=-1)."""
    B, KV, G, Dh = q.shape
    S = k_q.shape[1]
    s_chunk = min(s_chunk, S)
    assert S % s_chunk == 0, (S, s_chunk)
    n_chunks = S // s_chunk
    grid = (B, KV, n_chunks)
    kernel = functools.partial(_kernel, window=window, n_chunks=n_chunks)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, Dh), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, s_chunk, 1, Dh), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, s_chunk, 1), lambda b, h, j: (b, j, h)),
            pl.BlockSpec((1, s_chunk, 1, Dh), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, s_chunk, 1), lambda b, h, j: (b, j, h)),
            pl.BlockSpec((1, s_chunk), lambda b, h, j: (b, j)),
            pl.BlockSpec((1,), lambda b, h, j: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dh), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k_q, k_s, v_q, v_s, key_pos, q_pos)
