"""ELL SpMV / SpMM Pallas TPU kernel — the paper's hero format.

TPU mapping of the paper's §3.3/§3.4 parallelizations:
  * the N-loop (rows) becomes the *parallel* grid axis, tiled in
    ``block_rows`` chunks (paper's "outer" parallelization);
  * the NE-loop (band) becomes the sequential accumulation axis, tiled in
    ``block_w`` lanes (paper's "inner" parallelization) — both schedules
    coexist in one kernel because the mesh/grid split covers both.

VMEM strategy: the dense x vector is pinned whole in VMEM (n_cols * 4 B;
up to ~1M columns fits the ~16 MB of a v5e core alongside the tiles), while
the (rows, width) VAL/ICOL panels stream through in
(block_rows, block_w) blocks.  The inner product is a VPU gather
(x[ICOL-block]) followed by a dense multiply-reduce over the minor
(lane-aligned) axis — full lane utilization, unlike CSR's short
row-segmented reductions.  This is exactly why the paper's ES2 vector
pipes love ELL; the TPU inherits the preference.

Block alignment: block_rows % 8 == 0 (sublane), block_w % 128 == 0 (lane).
The ops.py wrapper pads inputs to these multiples (pad entries: val=0,
col=0 — contributing zero, the paper's own padding convention).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ell_spmv_kernel(data_ref, cols_ref, x_ref, y_ref):
    """Grid = (row_blocks, w_blocks); w is the sequential accumulation axis.
    Accumulation is always f32 (standard MXU/VPU practice for bf16 inputs)."""
    j = pl.program_id(1)
    x = x_ref[...]
    gathered = x[cols_ref[...]]                 # (block_rows, block_w) gather
    partial = jnp.sum(data_ref[...].astype(jnp.float32) *
                      gathered.astype(jnp.float32), axis=1)

    @pl.when(j == 0)
    def _init():
        y_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        y_ref[...] = y_ref[...] + partial


@functools.partial(jax.jit, static_argnames=("block_rows", "block_w",
                                             "interpret"))
def ell_spmv(data: jax.Array, cols: jax.Array, x: jax.Array, *,
             block_rows: int = 256, block_w: int = 128,
             interpret: bool = True) -> jax.Array:
    """y = A @ x, A in ELL-Row: data/cols (n_rows, width), x (n_cols,).

    Shapes must already be block-aligned (see ops.ell_spmv for the padding
    wrapper).  Returns (n_rows,) in x.dtype's result type."""
    n_rows, width = data.shape
    assert n_rows % block_rows == 0 and width % block_w == 0, (
        f"unaligned ELL shapes {data.shape} for blocks "
        f"({block_rows},{block_w})")
    grid = (n_rows // block_rows, width // block_w)
    out_dtype = jnp.result_type(data.dtype, x.dtype)
    y32 = pl.pallas_call(
        _ell_spmv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, block_w), lambda i, j: (i, j)),
            pl.BlockSpec((block_rows, block_w), lambda i, j: (i, j)),
            pl.BlockSpec(x.shape, lambda i, j: (0,)),     # x whole in VMEM
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_rows,), jnp.float32),
        interpret=interpret,
    )(data, cols, x)
    return y32.astype(out_dtype)


def _ell_spmm_kernel(data_ref, cols_ref, x_ref, y_ref):
    """SpMM: multi-vector RHS x (n_cols, k).  Grid = (row_blocks, k_blocks,
    w_blocks); w is innermost (sequential accumulation — consecutive visits
    to each output block, as TPU Pallas requires), rows/k parallel."""
    j = pl.program_id(2)
    x = x_ref[...]                               # (n_cols, block_k)
    gathered = x[cols_ref[...], :]               # (br, bw, block_k)
    partial = jnp.einsum("rw,rwk->rk", data_ref[...], gathered,
                         preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _init():
        y_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        y_ref[...] = y_ref[...] + partial


@functools.partial(jax.jit, static_argnames=("block_rows", "block_w",
                                             "block_k", "interpret"))
def ell_spmm(data: jax.Array, cols: jax.Array, x: jax.Array, *,
             block_rows: int = 128, block_w: int = 128, block_k: int = 128,
             interpret: bool = True) -> jax.Array:
    """Y = A @ X, A in ELL-Row, X (n_cols, k) -> Y (n_rows, k)."""
    n_rows, width = data.shape
    n_cols, k = x.shape
    assert n_rows % block_rows == 0 and width % block_w == 0 \
        and k % block_k == 0, (data.shape, x.shape)
    grid = (n_rows // block_rows, k // block_k, width // block_w)
    out_dtype = jnp.result_type(data.dtype, x.dtype)
    y32 = pl.pallas_call(
        _ell_spmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, block_w), lambda i, kk, j: (i, j)),
            pl.BlockSpec((block_rows, block_w), lambda i, kk, j: (i, j)),
            pl.BlockSpec((n_cols, block_k), lambda i, kk, j: (0, kk)),
        ],
        out_specs=pl.BlockSpec((block_rows, block_k),
                               lambda i, kk, j: (i, kk)),
        out_shape=jax.ShapeDtypeStruct((n_rows, k), jnp.float32),
        interpret=interpret,
    )(data, cols, x)
    return y32.astype(out_dtype)
