"""Pure-jnp oracles for the Pallas kernels — identical math, no tiling.

Accumulation is f32 with a final cast to the input result type, matching
the kernels (standard MXU/VPU practice for bf16 inputs).  These operate on
raw arrays (not the pytree format classes) so kernel tests can sweep
shapes/dtypes directly; ``repro.core.spmv`` provides the format-level
references."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ell_spmv_ref(data: jax.Array, cols: jax.Array, x: jax.Array) -> jax.Array:
    out_dtype = jnp.result_type(data.dtype, x.dtype)
    y32 = (data.astype(jnp.float32) * x.astype(jnp.float32)[cols]).sum(axis=1)
    return y32.astype(out_dtype)


def ell_spmm_ref(data: jax.Array, cols: jax.Array, x: jax.Array) -> jax.Array:
    out_dtype = jnp.result_type(data.dtype, x.dtype)
    y32 = jnp.einsum("rw,rwk->rk", data.astype(jnp.float32),
                     x.astype(jnp.float32)[cols])
    return y32.astype(out_dtype)


def coo_spmv_ref(data: jax.Array, rows: jax.Array, cols: jax.Array,
                 x: jax.Array, n_rows: int) -> jax.Array:
    out_dtype = jnp.result_type(data.dtype, x.dtype)
    contrib = data.astype(jnp.float32) * x.astype(jnp.float32)[cols]
    y32 = jnp.zeros((n_rows,), jnp.float32).at[rows].add(contrib)
    return y32.astype(out_dtype)


def sell_spmv_ref(perm: jax.Array, bucket_arrays, row_offsets, n_rows: int,
                  x: jax.Array) -> jax.Array:
    """bucket_arrays: sequence of (data, cols) pairs."""
    y = None
    for (data, cols), off in zip(bucket_arrays, row_offsets):
        yb = ell_spmv_ref(data, cols, x)
        if y is None:
            y = jnp.zeros((n_rows,), yb.dtype)
        y = y.at[perm[off:off + data.shape[0]]].set(yb)
    return y


def decode_attention_int8_ref(q, k_q, k_s, v_q, v_s, key_pos, q_pos,
                              window=None):
    """Oracle for the fused int8-KV decode kernel: dequantize, then the
    masked max/exp/sum attention (mirrors models.attention math)."""
    kf = k_q.astype(jnp.float32) * k_s.astype(jnp.float32)[..., None]
    vf = v_q.astype(jnp.float32) * v_s.astype(jnp.float32)[..., None]
    Dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(Dh, jnp.float32))
    s = jnp.einsum("bkgd,bskd->bkgs", q.astype(jnp.float32) * scale, kf)
    valid = (key_pos >= 0) & (key_pos <= q_pos[:, None])
    if window is not None:
        valid &= key_pos > (q_pos[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskd->bkgd", p / jnp.maximum(l, 1e-30), vf)
    return out.astype(q.dtype)
