"""COO SpMV Pallas kernel (covers CSR via the IRP->IROW row expansion).

TPU adaptation note (DESIGN.md §2): the paper's COO outer-loop OpenMP
schedule gives each thread an nnz slab plus a private YY(N) partial vector
reduced at the end.  The TPU version keeps that exact structure: the grid
walks nnz slabs *sequentially* (grid axis marked arbitrary) and accumulates
into a full-length y resident in VMEM — VMEM is the "private YY" and the
sequential grid replaces the end reduction.  The within-slab scatter-add is
a VPU serial scatter on real TPUs; this is precisely the irregularity that
makes COO/CSR lose to ELL on vector hardware (the paper's central finding),
so this kernel exists as the *baseline* the auto-tuner migrates away from.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _coo_spmv_kernel(data_ref, rows_ref, cols_ref, x_ref, y_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    contrib = (data_ref[...].astype(jnp.float32) *
               x_ref[...].astype(jnp.float32)[cols_ref[...]])
    y_ref[...] = y_ref[...].at[rows_ref[...]].add(contrib)


@functools.partial(jax.jit, static_argnames=("n_rows", "block_nnz",
                                             "interpret"))
def coo_spmv(data: jax.Array, rows: jax.Array, cols: jax.Array,
             x: jax.Array, *, n_rows: int, block_nnz: int = 4096,
             interpret: bool = True) -> jax.Array:
    """y = A @ x, A in COO (any order; padded entries must be (0,0,0.0))."""
    (nnz_pad,) = data.shape
    assert nnz_pad % block_nnz == 0, (nnz_pad, block_nnz)
    grid = (nnz_pad // block_nnz,)
    out_dtype = jnp.result_type(data.dtype, x.dtype)
    y32 = pl.pallas_call(
        _coo_spmv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_nnz,), lambda i: (i,)),
            pl.BlockSpec((block_nnz,), lambda i: (i,)),
            pl.BlockSpec((block_nnz,), lambda i: (i,)),
            pl.BlockSpec(x.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((n_rows,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n_rows,), jnp.float32),
        interpret=interpret,
    )(data, rows, cols, x)
    return y32.astype(out_dtype)


def _coo_spmm_kernel(data_ref, rows_ref, cols_ref, x_ref, y_ref):
    """Multi-RHS COO: x (n_cols, block_k) panel pinned per k-block; the nnz
    slabs walk sequentially (innermost grid axis) scatter-adding (slab,
    block_k) contribution panels into the VMEM-resident y — the SpMM form
    of the paper's per-thread YY accumulation, one panel per lane group."""
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    contrib = (data_ref[...].astype(jnp.float32)[:, None] *
               x_ref[...].astype(jnp.float32)[cols_ref[...], :])
    y_ref[...] = y_ref[...].at[rows_ref[...], :].add(contrib)


@functools.partial(jax.jit, static_argnames=("n_rows", "block_nnz",
                                             "block_k", "interpret"))
def coo_spmm(data: jax.Array, rows: jax.Array, cols: jax.Array,
             x: jax.Array, *, n_rows: int, block_nnz: int = 4096,
             block_k: int = 128, interpret: bool = True) -> jax.Array:
    """Y = A @ X, A in COO, X (n_cols, k) -> Y (n_rows, k).

    Grid = (k_blocks, nnz_blocks); nnz is the sequential accumulation axis
    (marked by position — consecutive visits to each output block), k is
    parallel.  Padded entries must be (row=0, col=0, val=0.0)."""
    (nnz_pad,) = data.shape
    n_cols, k = x.shape
    assert nnz_pad % block_nnz == 0, (nnz_pad, block_nnz)
    assert k % block_k == 0, (k, block_k)
    grid = (k // block_k, nnz_pad // block_nnz)
    out_dtype = jnp.result_type(data.dtype, x.dtype)
    y32 = pl.pallas_call(
        _coo_spmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_nnz,), lambda kk, i: (i,)),
            pl.BlockSpec((block_nnz,), lambda kk, i: (i,)),
            pl.BlockSpec((block_nnz,), lambda kk, i: (i,)),
            pl.BlockSpec((n_cols, block_k), lambda kk, i: (0, kk)),
        ],
        out_specs=pl.BlockSpec((n_rows, block_k), lambda kk, i: (0, kk)),
        out_shape=jax.ShapeDtypeStruct((n_rows, k), jnp.float32),
        interpret=interpret,
    )(data, rows, cols, x)
    return y32.astype(out_dtype)
