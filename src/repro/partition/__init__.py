"""Partitioned hybrid-format SpMV: per-row-block auto-tuning subsystem.

Splits a CSR matrix into row blocks (fixed / nnz-balanced / greedy
variance-splitting), runs the D_mat–R decision per block under the memory
policy, and materializes a ``HybridMatrix`` whose blocks each carry their
own storage format.  See docs/partitioning.md."""
from .strategies import (PARTITIONERS, partition_balanced_nnz,
                         partition_fixed, partition_for_devices,
                         partition_variance)
from .hybrid import (BLOCK_FORMATS, BlockDecision, HybridMatrix,
                     HybridReport, build_hybrid, choose_block_format,
                     host_csr_to_hybrid, slice_csr, slice_csr_cols,
                     spmm_hybrid, spmv_hybrid, take_rows_csr)

__all__ = [
    "PARTITIONERS", "partition_fixed", "partition_balanced_nnz",
    "partition_variance", "partition_for_devices",
    "BLOCK_FORMATS", "HybridMatrix", "BlockDecision", "HybridReport",
    "build_hybrid", "choose_block_format", "host_csr_to_hybrid",
    "slice_csr", "slice_csr_cols", "take_rows_csr", "spmv_hybrid",
    "spmm_hybrid",
]
