"""Row-block partitioning strategies for the hybrid-format subsystem.

The paper's auto-tuner makes one whole-matrix decision from D_mat = sigma/mu,
so a single skewed row stalls ELL for the entire matrix (max_row padding).
Splitting into row blocks and deciding per block (adaptive row-grouped CSR,
Heller & Oberhuber; shared-memory partitioned SpMV, Bergmans et al.) keeps
the per-block D_mat low where the matrix is regular and isolates the heavy
tail into blocks that fall back to CRS/COO on their own.

Every strategy maps a row-length vector to *boundaries*: a strictly
increasing int64 array ``[0, b_1, ..., n_rows]``.  Block i covers permuted
rows ``boundaries[i]:boundaries[i+1]``.  Strategies operate on the (possibly
length-sorted) row space; sorting is the caller's choice (``build_hybrid``).
"""
from __future__ import annotations

from typing import Callable, Dict

import numpy as np


def _as_lens(row_lens) -> np.ndarray:
    lens = np.asarray(row_lens, dtype=np.int64)
    if lens.ndim != 1:
        raise ValueError(f"row_lens must be 1-D, got shape {lens.shape}")
    return lens


def _validate(boundaries: np.ndarray, n: int) -> np.ndarray:
    b = np.asarray(boundaries, dtype=np.int64)
    assert b[0] == 0 and b[-1] == n and np.all(np.diff(b) > 0), b
    return b


# ---------------------------------------------------------------------------
# fixed-size blocks
# ---------------------------------------------------------------------------
def partition_fixed(row_lens, block_rows: int = 1024) -> np.ndarray:
    """Uniform blocks of ``block_rows`` rows (last block may be short)."""
    n = _as_lens(row_lens).shape[0]
    block_rows = max(int(block_rows), 1)
    b = np.arange(0, n, block_rows, dtype=np.int64)
    return _validate(np.append(b, n), n)


# ---------------------------------------------------------------------------
# nnz-balanced blocks
# ---------------------------------------------------------------------------
def partition_balanced_nnz(row_lens, n_blocks: int = 8) -> np.ndarray:
    """~Equal nonzeros per block: cut the nnz prefix sum at k/n_blocks.

    This is the load-balancing split of partitioned SpMV — each block does
    the same work even when row lengths are wildly skewed."""
    lens = _as_lens(row_lens)
    n = lens.shape[0]
    n_blocks = int(np.clip(n_blocks, 1, n))
    csum = np.cumsum(lens)
    total = csum[-1] if csum.size else 0
    if total == 0:
        return partition_fixed(lens, max(n // n_blocks, 1))
    targets = total * np.arange(1, n_blocks, dtype=np.float64) / n_blocks
    cuts = np.searchsorted(csum, targets, side="left") + 1
    b = np.concatenate([[0], np.unique(np.clip(cuts, 1, n - 1)), [n]]) \
        if n > 1 else np.array([0, n])
    return _validate(np.unique(b), n)


# ---------------------------------------------------------------------------
# greedy variance splitting
# ---------------------------------------------------------------------------
def _best_split(lens: np.ndarray, s: int, e: int):
    """Best single cut of segment [s, e) by within-segment SSE reduction.

    Prefix sums give the SSE of every (left, right) pair in O(e - s):
      SSE(a, b) = sum(l^2) - sum(l)^2 / (b - a).
    Returns (cut, gain) with gain = SSE(s,e) - SSE(s,cut) - SSE(cut,e).
    """
    seg = lens[s:e].astype(np.float64)
    m = seg.shape[0]
    if m < 2:
        return None, 0.0
    c1 = np.cumsum(seg)
    c2 = np.cumsum(seg * seg)
    k = np.arange(1, m, dtype=np.float64)          # left sizes
    sse_l = c2[:-1] - c1[:-1] ** 2 / k
    sse_r = (c2[-1] - c2[:-1]) - (c1[-1] - c1[:-1]) ** 2 / (m - k)
    sse_all = c2[-1] - c1[-1] ** 2 / m
    gains = sse_all - (sse_l + sse_r)
    i = int(np.argmax(gains))
    return s + i + 1, float(gains[i])


def partition_variance(row_lens, max_blocks: int = 16, min_rows: int = 64,
                       min_gain: float = 1.0) -> np.ndarray:
    """Greedy recursive splitting that minimizes within-block row-length
    variance — the per-block analogue of driving D_mat toward zero.

    Repeatedly cut the segment whose best split yields the largest SSE
    reduction, until ``max_blocks`` segments exist, no split clears
    ``min_gain``, or segments would drop under ``min_rows`` rows.  On a
    length-sorted row space this isolates the heavy tail into its own
    block(s) and leaves near-uniform blocks elsewhere.
    """
    lens = _as_lens(row_lens)
    n = lens.shape[0]
    if n == 0:
        raise ValueError("cannot partition an empty matrix")
    segments = [(0, n)]
    while len(segments) < max_blocks:
        best = None  # (gain, seg_idx, cut)
        for si, (s, e) in enumerate(segments):
            if e - s < 2 * min_rows:
                continue
            cut, gain = _best_split(lens, s, e)
            if cut is None or cut - s < min_rows or e - cut < min_rows:
                # clamp the cut into the feasible band and re-score
                cut = int(np.clip(cut or s + min_rows, s + min_rows,
                                  e - min_rows))
                seg = lens[s:e].astype(np.float64)
                k = cut - s
                sse = lambda v: float(np.sum(v * v) - v.sum() ** 2 / len(v))
                gain = sse(seg) - sse(seg[:k]) - sse(seg[k:])
            if gain > min_gain and (best is None or gain > best[0]):
                best = (gain, si, cut)
        if best is None:
            break
        _, si, cut = best
        s, e = segments[si]
        segments[si:si + 1] = [(s, cut), (cut, e)]
    boundaries = np.array(sorted({s for s, _ in segments} | {n}),
                          dtype=np.int64)
    return _validate(boundaries, n)


PARTITIONERS: Dict[str, Callable[..., np.ndarray]] = {
    "fixed": partition_fixed,
    "balanced_nnz": partition_balanced_nnz,
    "variance": partition_variance,
}

__all__ = ["partition_fixed", "partition_balanced_nnz", "partition_variance",
           "PARTITIONERS"]
