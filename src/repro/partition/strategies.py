"""Row-block partitioning strategies for the hybrid-format subsystem.

The paper's auto-tuner makes one whole-matrix decision from D_mat = sigma/mu,
so a single skewed row stalls ELL for the entire matrix (max_row padding).
Splitting into row blocks and deciding per block (adaptive row-grouped CSR,
Heller & Oberhuber; shared-memory partitioned SpMV, Bergmans et al.) keeps
the per-block D_mat low where the matrix is regular and isolates the heavy
tail into blocks that fall back to CRS/COO on their own.

Every strategy maps a row-length vector to *boundaries*: a strictly
increasing int64 array ``[0, b_1, ..., n_rows]``.  Block i covers permuted
rows ``boundaries[i]:boundaries[i+1]``.  Strategies operate on the (possibly
length-sorted) row space; sorting is the caller's choice (``build_hybrid``).
"""
from __future__ import annotations

from typing import Callable, Dict

import numpy as np


def _as_lens(row_lens) -> np.ndarray:
    lens = np.asarray(row_lens, dtype=np.int64)
    if lens.ndim != 1:
        raise ValueError(f"row_lens must be 1-D, got shape {lens.shape}")
    return lens


def _validate(boundaries: np.ndarray, n: int) -> np.ndarray:
    b = np.asarray(boundaries, dtype=np.int64)
    assert b[0] == 0 and b[-1] == n and np.all(np.diff(b) > 0), b
    return b


# ---------------------------------------------------------------------------
# fixed-size blocks
# ---------------------------------------------------------------------------
def partition_fixed(row_lens, block_rows: int = 1024) -> np.ndarray:
    """Uniform blocks of ``block_rows`` rows (last block may be short)."""
    n = _as_lens(row_lens).shape[0]
    block_rows = max(int(block_rows), 1)
    b = np.arange(0, n, block_rows, dtype=np.int64)
    return _validate(np.append(b, n), n)


# ---------------------------------------------------------------------------
# nnz-balanced blocks
# ---------------------------------------------------------------------------
def partition_balanced_nnz(row_lens, n_blocks: int = 8) -> np.ndarray:
    """~Equal nonzeros per block: cut the nnz prefix sum at k/n_blocks.

    This is the load-balancing split of partitioned SpMV — each block does
    the same work even when row lengths are wildly skewed."""
    lens = _as_lens(row_lens)
    n = lens.shape[0]
    n_blocks = int(np.clip(n_blocks, 1, n))
    csum = np.cumsum(lens)
    total = csum[-1] if csum.size else 0
    if total == 0:
        return partition_fixed(lens, max(n // n_blocks, 1))
    targets = total * np.arange(1, n_blocks, dtype=np.float64) / n_blocks
    cuts = np.searchsorted(csum, targets, side="left") + 1
    b = np.concatenate([[0], np.unique(np.clip(cuts, 1, n - 1)), [n]]) \
        if n > 1 else np.array([0, n])
    return _validate(np.unique(b), n)


# ---------------------------------------------------------------------------
# greedy variance splitting
# ---------------------------------------------------------------------------
def _best_split(lens: np.ndarray, s: int, e: int):
    """Best single cut of segment [s, e) by within-segment SSE reduction.

    Prefix sums give the SSE of every (left, right) pair in O(e - s):
      SSE(a, b) = sum(l^2) - sum(l)^2 / (b - a).
    Returns (cut, gain) with gain = SSE(s,e) - SSE(s,cut) - SSE(cut,e).
    """
    seg = lens[s:e].astype(np.float64)
    m = seg.shape[0]
    if m < 2:
        return None, 0.0
    c1 = np.cumsum(seg)
    c2 = np.cumsum(seg * seg)
    k = np.arange(1, m, dtype=np.float64)          # left sizes
    sse_l = c2[:-1] - c1[:-1] ** 2 / k
    sse_r = (c2[-1] - c2[:-1]) - (c1[-1] - c1[:-1]) ** 2 / (m - k)
    sse_all = c2[-1] - c1[-1] ** 2 / m
    gains = sse_all - (sse_l + sse_r)
    i = int(np.argmax(gains))
    return s + i + 1, float(gains[i])


def partition_variance(row_lens, max_blocks: int = 16, min_rows: int = 64,
                       min_gain: float = 1.0) -> np.ndarray:
    """Greedy recursive splitting that minimizes within-block row-length
    variance — the per-block analogue of driving D_mat toward zero.

    Repeatedly cut the segment whose best split yields the largest SSE
    reduction, until ``max_blocks`` segments exist, no split clears
    ``min_gain``, or segments would drop under ``min_rows`` rows.  On a
    length-sorted row space this isolates the heavy tail into its own
    block(s) and leaves near-uniform blocks elsewhere.
    """
    lens = _as_lens(row_lens)
    n = lens.shape[0]
    if n == 0:
        raise ValueError("cannot partition an empty matrix")
    segments = [(0, n)]
    while len(segments) < max_blocks:
        best = None  # (gain, seg_idx, cut)
        for si, (s, e) in enumerate(segments):
            if e - s < 2 * min_rows:
                continue
            cut, gain = _best_split(lens, s, e)
            if cut is None or cut - s < min_rows or e - cut < min_rows:
                # clamp the cut into the feasible band and re-score
                cut = int(np.clip(cut or s + min_rows, s + min_rows,
                                  e - min_rows))
                seg = lens[s:e].astype(np.float64)
                k = cut - s
                sse = lambda v: float(np.sum(v * v) - v.sum() ** 2 / len(v))
                gain = sse(seg) - sse(seg[:k]) - sse(seg[k:])
            if gain > min_gain and (best is None or gain > best[0]):
                best = (gain, si, cut)
        if best is None:
            break
        _, si, cut = best
        s, e = segments[si]
        segments[si:si + 1] = [(s, cut), (cut, e)]
    boundaries = np.array(sorted({s for s, _ in segments} | {n}),
                          dtype=np.int64)
    return _validate(boundaries, n)


PARTITIONERS: Dict[str, Callable[..., np.ndarray]] = {
    "fixed": partition_fixed,
    "balanced_nnz": partition_balanced_nnz,
    "variance": partition_variance,
}


# ---------------------------------------------------------------------------
# device-count granularity (the sharding tier's view of the strategies)
# ---------------------------------------------------------------------------
def _split_heaviest(boundaries: np.ndarray, lens: np.ndarray) -> np.ndarray:
    """Add one cut: bisect the slab with the most nnz at its nnz midpoint
    (falling back to the row midpoint for empty slabs)."""
    csum = np.concatenate([[0], np.cumsum(lens)])
    slab_nnz = csum[boundaries[1:]] - csum[boundaries[:-1]]
    slab_rows = np.diff(boundaries)
    # only slabs with >= 2 rows can be split again
    candidates = np.where(slab_rows >= 2, slab_nnz, -1)
    i = int(np.argmax(candidates))
    if candidates[i] < 0:
        raise ValueError("cannot split further: every slab has one row")
    s, e = int(boundaries[i]), int(boundaries[i + 1])
    target = (csum[s] + csum[e]) / 2.0
    cut = int(np.searchsorted(csum[s:e], target, side="left")) + s
    cut = int(np.clip(cut, s + 1, e - 1))
    return np.insert(boundaries, i + 1, cut)


def partition_for_devices(row_lens, n_devices: int,
                          strategy: str = "balanced_nnz",
                          **strategy_kw) -> np.ndarray:
    """Exactly ``n_devices`` slabs — the strategies lifted to device-count
    granularity for the sharding tier.

    The block partitioners are free to emit however many blocks the data
    suggests; a device mesh needs *exactly one slab per device*.  The
    named strategy proposes boundaries (fixed/balanced_nnz are asked for
    ``n_devices`` blocks directly; variance keeps its own knobs capped at
    ``n_devices``), then the result is refined to the exact count:
    too few -> bisect the heaviest slab at its nnz midpoint; too many ->
    merge the lightest adjacent pair.  Unlike ``build_hybrid`` the row
    space is *never* sorted here — device slabs must stay contiguous in
    the original row order so shard outputs reassemble by concatenation
    alone (no scatter collective)."""
    lens = _as_lens(row_lens)
    n = lens.shape[0]
    n_devices = int(n_devices)
    if n_devices < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    if n_devices > n:
        raise ValueError(f"cannot cut {n} rows into {n_devices} device "
                         f"slabs (need >= 1 row per device)")
    if strategy == "fixed":
        # equal row counts, ignoring block_rows: the device analogue
        b = np.round(np.linspace(0, n, n_devices + 1)).astype(np.int64)
    elif strategy == "balanced_nnz":
        b = partition_balanced_nnz(lens, n_blocks=n_devices)
    elif strategy == "variance":
        kw = dict(strategy_kw)
        kw.setdefault("min_rows", max(1, n // (4 * n_devices)))
        kw["max_blocks"] = n_devices
        b = partition_variance(lens, **kw)
    elif strategy in PARTITIONERS:
        b = PARTITIONERS[strategy](lens, **strategy_kw)
    else:
        raise KeyError(f"unknown strategy {strategy!r}; "
                       f"one of {sorted(PARTITIONERS)}")
    b = np.unique(np.clip(np.asarray(b, dtype=np.int64), 0, n))
    while b.shape[0] - 1 < n_devices:
        b = _split_heaviest(b, lens)
    while b.shape[0] - 1 > n_devices:
        # merge the adjacent pair with the least combined nnz
        csum = np.concatenate([[0], np.cumsum(lens)])
        slab_nnz = csum[b[1:]] - csum[b[:-1]]
        i = int(np.argmin(slab_nnz[:-1] + slab_nnz[1:]))
        b = np.delete(b, i + 1)
    return _validate(b, n)


__all__ = ["partition_fixed", "partition_balanced_nnz", "partition_variance",
           "partition_for_devices", "PARTITIONERS"]
