"""Partitioned hybrid-format SpMV: per-row-block auto-tuning.

The whole-matrix auto-tuner (core/autotune.py) answers "which single format
for this matrix"; one heavy row forces the answer to CRS.  This module
answers the finer question per row block: partition the (optionally
length-sorted) row space, compute per-block ``MatrixStats``, run the same
D_mat–R decision machinery *per block* under the same ``MemoryPolicy``
budget, and materialize a ``HybridMatrix`` — a pytree of per-block format
objects plus the row permutation.  SpMV dispatches each block to the
existing per-format implementations and reassembles the output.

Transformation time is accounted per block (``HybridReport``) and, because
``host_csr_to_hybrid`` is registered in ``core.transform.TRANSFORMS_HOST``,
the whole-pipeline cost is measured by ``offline_phase`` exactly like any
other format — R_hybrid feeds back into the D_mat–R graph.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch as _dispatch
from repro.core.autotune import (MachineModel, TuningDB, decide_cost_model,
                                 decide_generalized, decide_paper)
from repro.core.formats import CSR, MatrixStats, memory_bytes
from repro.core.policy import MemoryPolicy
from repro.core.transform import TRANSFORMS_HOST, pad_to_multiple

from .strategies import PARTITIONERS

# formats a block may land in (csr = stay; no nested hybrid)
BLOCK_FORMATS = ("ell_row", "ell_col", "coo_row", "coo_col", "sell")


# ---------------------------------------------------------------------------
# the hybrid container
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class HybridMatrix:
    """Per-row-block storage: ``blocks[i]`` covers permuted rows
    ``row_offsets[i] : row_offsets[i] + blocks[i].n_rows`` and holds the
    format named by ``formats[i]``.  ``perm[i]`` = original row of permuted
    row i (identity when the partitioner did not sort)."""
    perm: Any                       # (n_rows,) permuted -> original row
    blocks: Tuple[Any, ...]         # CSR | COO | ELL | BucketedELL per block
    row_offsets: Tuple[int, ...]    # static: start (permuted) row per block
    formats: Tuple[str, ...]        # static: format name per block
    shape: Tuple[int, int]
    nnz: int
    identity_perm: bool = False     # static: True -> outputs just concatenate

    @property
    def n_rows(self) -> int:
        return self.shape[0]

    @property
    def n_cols(self) -> int:
        return self.shape[1]

    @property
    def n_blocks(self) -> int:
        return len(self.blocks)

    def block_rows(self, i: int) -> int:
        return int(self.blocks[i].n_rows)

    def format_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for f in self.formats:
            out[f] = out.get(f, 0) + 1
        return out

    def todense(self) -> np.ndarray:
        dense_blocks = [b.todense() for b in self.blocks]
        out = np.zeros(self.shape, dtype=dense_blocks[0].dtype)
        perm = np.asarray(self.perm)
        for off, dense_b in zip(self.row_offsets, dense_blocks):
            out[perm[off:off + dense_b.shape[0]]] += dense_b
        return out


jax.tree_util.register_dataclass(
    HybridMatrix, data_fields=["perm", "blocks"],
    meta_fields=["row_offsets", "formats", "shape", "nnz", "identity_perm"])


# ---------------------------------------------------------------------------
# CSR row-slicing (host)
# ---------------------------------------------------------------------------
def take_rows_csr(m: CSR, rows: np.ndarray, pad: int = 8) -> CSR:
    """Sub-CSR over an arbitrary (ordered) row subset; full column space."""
    ip = np.asarray(m.indptr)
    lens = (ip[1:] - ip[:-1])[rows]
    nnz = int(lens.sum())
    indptr = np.zeros(len(rows) + 1, dtype=np.int32)
    np.cumsum(lens, out=indptr[1:])
    src_d, src_c = np.asarray(m.data), np.asarray(m.cols)
    data = np.zeros(max(pad_to_multiple(nnz, pad), pad), dtype=src_d.dtype)
    cols = np.zeros_like(data, dtype=np.int32)
    # gather each row's [start, start+len) span into the packed layout
    if nnz:
        starts = ip[rows]
        idx = np.concatenate([np.arange(s, s + l)
                              for s, l in zip(starts, lens)]) if len(rows) \
            else np.zeros(0, np.int64)
        data[:nnz] = src_d[idx]
        cols[:nnz] = src_c[idx]
    return CSR(data=data, cols=cols, indptr=indptr,
               shape=(len(rows), m.n_cols), nnz=nnz)


def slice_csr_cols(m: CSR, c0: int, c1: int, pad: int = 8) -> CSR:
    """Column slab [c0, c1): keep entries whose column falls in the slab,
    rebased to column 0 — the column-sharding analogue of ``slice_csr``.
    Full row space (every shard of a column-sharded matrix owns all rows
    and contributes a partial y that is psum-reduced)."""
    ip = np.asarray(m.indptr)
    data = np.asarray(m.data)[:m.nnz]
    cols = np.asarray(m.cols)[:m.nnz]
    lens = (ip[1:] - ip[:-1]).astype(np.int64)
    rows = np.repeat(np.arange(m.n_rows, dtype=np.int64), lens)
    sel = (cols >= c0) & (cols < c1)
    d, c, r = data[sel], cols[sel] - c0, rows[sel]  # stays row-major sorted
    nnz = int(d.size)
    new_lens = np.bincount(r, minlength=m.n_rows)
    indptr = np.zeros(m.n_rows + 1, dtype=np.int32)
    np.cumsum(new_lens, out=indptr[1:])
    nnz_pad = max(pad_to_multiple(nnz, pad), pad)
    dd = np.zeros(nnz_pad, dtype=data.dtype)
    cc = np.zeros(nnz_pad, dtype=np.int32)
    dd[:nnz], cc[:nnz] = d, c
    return CSR(data=dd, cols=cc, indptr=indptr,
               shape=(m.n_rows, c1 - c0), nnz=nnz)


def slice_csr(m: CSR, r0: int, r1: int, pad: int = 8) -> CSR:
    """Contiguous row slice [r0, r1) — O(block nnz) views + one copy."""
    ip = np.asarray(m.indptr)
    s, e = int(ip[r0]), int(ip[r1])
    nnz = e - s
    data = np.asarray(m.data)[s:e]
    cols = np.asarray(m.cols)[s:e]
    nnz_pad = max(pad_to_multiple(nnz, pad), pad)
    d = np.zeros(nnz_pad, dtype=data.dtype)
    c = np.zeros(nnz_pad, dtype=np.int32)
    d[:nnz], c[:nnz] = data, cols
    return CSR(data=d, cols=c,
               indptr=(ip[r0:r1 + 1] - s).astype(np.int32),
               shape=(r1 - r0, m.n_cols), nnz=nnz)


# ---------------------------------------------------------------------------
# per-block decision (reuses core/autotune + core/policy)
# ---------------------------------------------------------------------------
def choose_block_format(stats: MatrixStats,
                        db: Optional[TuningDB] = None,
                        rule: str = "auto",
                        model: Optional[MachineModel] = None,
                        policy: Optional[MemoryPolicy] = None,
                        expected_iterations: int = 100,
                        formats: Sequence[str] = BLOCK_FORMATS,
                        batch: int = 1) -> str:
    """One block's format via the same machinery as the whole-matrix tuner.

    Candidates are first filtered by the memory policy (estimate vs the
    block's own CSR estimate), then ranked by the paper rule, the
    generalized DB prediction, or the roofline cost model.  ``batch`` is
    the expected RHS count per call — amortization runs over
    ``expected_iterations * batch`` products."""
    policy = policy or MemoryPolicy()
    csr_bytes = max(policy.estimate_bytes("csr", stats), 1)

    def fits(f: str) -> bool:
        b = policy.estimate_bytes(f, stats)
        ok = b <= policy.budget_ratio * csr_bytes
        if policy.hard_bytes:
            ok = ok and b <= policy.hard_bytes
        return ok

    cand = [f for f in formats if fits(f)]
    if not cand:
        return "csr"
    if db is not None and rule == "paper":
        return decide_paper(db, stats).fmt if "ell_row" in cand else "csr"
    if db is not None:
        return decide_generalized(db, stats, expected_iterations,
                                  formats=cand,
                                  memory_budget_ratio=policy.budget_ratio,
                                  batch=batch).fmt
    return decide_cost_model(model or MachineModel(), stats,
                             expected_iterations, formats=cand,
                             batch=batch).fmt


# ---------------------------------------------------------------------------
# build
# ---------------------------------------------------------------------------
@dataclass
class BlockDecision:
    """One row block's outcome.  ``plan`` is the leaf
    :class:`~repro.core.plan.ExecutionPlan` for the block — the portable
    decision artifact (format + transform recipe + fingerprint) that the
    Planner and the serving layer compose into whole-matrix hybrid plans;
    ``fmt`` is kept as the flat view of ``plan.fmt``."""
    fmt: str
    rows: Tuple[int, int]       # [start, end) in the permuted row space
    d_mat: float
    nnz: int
    bytes: int
    t_transform: float
    plan: Optional[Any] = None  # core.plan.ExecutionPlan (leaf)


@dataclass
class HybridReport:
    strategy: str
    n_blocks: int
    t_partition: float
    t_transform: float          # total per-block materialization seconds
    decisions: List[BlockDecision] = field(default_factory=list)

    def format_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for d in self.decisions:
            out[d.fmt] = out.get(d.fmt, 0) + 1
        return out


def build_hybrid(m: CSR,
                 strategy: str = "variance",
                 db: Optional[TuningDB] = None,
                 rule: str = "auto",
                 model: Optional[MachineModel] = None,
                 policy: Optional[MemoryPolicy] = None,
                 expected_iterations: int = 100,
                 sort_rows: Optional[bool] = None,
                 formats: Sequence[str] = BLOCK_FORMATS,
                 batch: int = 1,
                 **strategy_kw) -> Tuple[HybridMatrix, HybridReport]:
    """Partition -> per-block stats -> per-block decision -> materialize.

    ``sort_rows`` (default: True for the variance strategy) length-sorts the
    row space first so contiguous blocks are homogeneous — the sigma-sort of
    SELL-C-sigma lifted to the whole decision problem."""
    if strategy not in PARTITIONERS:
        raise KeyError(f"unknown strategy {strategy!r}; "
                       f"one of {sorted(PARTITIONERS)}")
    if sort_rows is None:
        sort_rows = strategy == "variance"
    lens = m.row_lengths().astype(np.int64)

    t0 = time.perf_counter()
    if sort_rows:
        perm = np.argsort(-lens, kind="stable").astype(np.int32)
    else:
        perm = np.arange(m.n_rows, dtype=np.int32)
    boundaries = PARTITIONERS[strategy](lens[perm], **strategy_kw)
    t_partition = time.perf_counter() - t0

    # per-block decisions ship as leaf ExecutionPlans (portable artifacts
    # the Planner / serving layer compose into whole-matrix hybrid plans)
    from repro.core.plan import leaf_plan
    rule_used = ("paper" if db is not None and rule == "paper"
                 else "generalized" if db is not None else "cost_model")

    blocks: List[Any] = []
    fmts: List[str] = []
    offsets: List[int] = []
    decisions: List[BlockDecision] = []
    t_transform = 0.0
    for s, e in zip(boundaries[:-1], boundaries[1:]):
        s, e = int(s), int(e)
        sub = (slice_csr(m, s, e) if not sort_rows
               else take_rows_csr(m, perm[s:e]))
        stats = MatrixStats.of(sub)
        fmt = choose_block_format(stats, db=db, rule=rule, model=model,
                                  policy=policy,
                                  expected_iterations=expected_iterations,
                                  formats=formats, batch=batch)
        t1 = time.perf_counter()
        obj = TRANSFORMS_HOST[fmt](sub)
        dt = time.perf_counter() - t1
        t_transform += dt
        blocks.append(obj)
        fmts.append(fmt)
        offsets.append(s)
        decisions.append(BlockDecision(
            fmt=fmt, rows=(s, e), d_mat=stats.d_mat, nnz=stats.nnz,
            bytes=memory_bytes(obj), t_transform=dt,
            plan=leaf_plan(sub, stats, fmt, rule_used, batch=batch,
                           expected_iterations=expected_iterations,
                           machine=db.machine if db is not None else "")))

    hyb = HybridMatrix(perm=perm, blocks=tuple(blocks),
                       row_offsets=tuple(offsets), formats=tuple(fmts),
                       shape=m.shape, nnz=m.nnz,
                       identity_perm=not sort_rows)
    report = HybridReport(strategy=strategy, n_blocks=len(blocks),
                          t_partition=t_partition, t_transform=t_transform,
                          decisions=decisions)
    return hyb, report


def host_csr_to_hybrid(m: CSR, strategy: str = "variance",
                       **kw) -> HybridMatrix:
    """``TRANSFORMS_HOST``-compatible entry point (cost-model decisions when
    no TuningDB is supplied).  ``offline_phase`` times this call as a whole,
    so R_hybrid lands on the D_mat–R graph like any other transformation."""
    hyb, _ = build_hybrid(m, strategy=strategy, **kw)
    return hyb


# ---------------------------------------------------------------------------
# execution — per-block implementations resolved through core/dispatch
# ---------------------------------------------------------------------------
def _block_impl(fmt: str, op: str,
                impls: Optional[Dict[str, Callable]]) -> Callable:
    fn = (impls or {}).get(fmt)
    return fn if fn is not None else _dispatch.get_impl(fmt, op)


def spmv_hybrid(m: HybridMatrix, x: jax.Array,
                impls: Optional[Dict[str, Callable]] = None) -> jax.Array:
    """y = A @ x: each block through its format's SpMV, then reassemble.

    ``impls`` maps format name -> callable(block, x) (e.g. the Pallas
    wrappers in ``kernels/ops.py``); formats not overridden resolve to the
    reference tier of the ``core/dispatch`` registry."""
    outs = [_block_impl(fmt, "spmv", impls)(b, x)
            for fmt, b in zip(m.formats, m.blocks)]
    y = jnp.concatenate(outs) if len(outs) > 1 else outs[0]
    if m.identity_perm:
        return y
    return jnp.zeros(m.n_rows, y.dtype).at[jnp.asarray(m.perm)].set(y)


def spmm_hybrid(m: HybridMatrix, x: jax.Array,
                impls: Optional[Dict[str, Callable]] = None) -> jax.Array:
    """Multi-vector RHS: x (n_cols, B) -> (n_rows, B) — each block's own
    SpMM, reassembling the (rows, B) panels through the row permutation."""
    outs = [_block_impl(fmt, "spmm", impls)(b, x)
            for fmt, b in zip(m.formats, m.blocks)]
    y = jnp.concatenate(outs, axis=0) if len(outs) > 1 else outs[0]
    if m.identity_perm:
        return y
    return jnp.zeros((m.n_rows, x.shape[1]),
                     y.dtype).at[jnp.asarray(m.perm)].set(y)


# the hybrid container is a first-class format: one registration here is
# the only place it is wired into the dispatch stack
_dispatch.register_format("hybrid", HybridMatrix)
_dispatch.register_impl("hybrid", "spmv", spmv_hybrid)
_dispatch.register_impl("hybrid", "spmm", spmm_hybrid)


__all__ = ["BLOCK_FORMATS", "HybridMatrix", "BlockDecision", "HybridReport",
           "take_rows_csr", "slice_csr", "slice_csr_cols",
           "choose_block_format", "build_hybrid", "host_csr_to_hybrid",
           "spmv_hybrid", "spmm_hybrid"]
