from .model import (decode_step, forward, init, init_caches, loss_fn,
                    model_spec, n_active_params, n_params, prefill)
