"""Mixture-of-Experts with auto-tuned dispatch format — the paper's
technique living inside the LM (DESIGN.md §4.2).

The token->expert dispatch matrix is a sparse matrix: rows = experts,
row length = tokens routed to that expert.  Two dispatch data layouts:

  * **ELL path** (``moe_dispatch="ell"``): fixed-capacity padded buffers
    (E, C, d) — exactly the ELL format (constant row width, zero fill,
    overflow dropped).  Dense einsums, shards perfectly over the expert
    axis; the classic TPU MoE.
  * **CSR path** (``moe_dispatch="csr"``): dropless — tokens sorted by
    expert (the CSR row-major order), grouped GEMM via
    ``jax.lax.ragged_dot`` with ``group_sizes`` as the row-pointer
    differences.  No drops, no pad, but ragged compute.

``moe_dispatch="auto"`` applies the paper's on-line rule *per step on
device*: D_mat = sigma/mu of tokens-per-expert (the load-imbalance
statistic); D_mat < D* -> ELL (uniform rows: padding is cheap, vector
format wins), else CSR (skewed rows: padding/drops too costly).  Both
branches are compiled once and selected by ``lax.cond`` — run-time data
transformation at zero recompile cost."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.rules import ParamSpec, with_logical_constraint as wlc

# Default D* for the dispatch rule; overridable per call. Learned off-line
# by benchmarks/moe_dispatch.py (the MoE analogue of the D_mat–R_ell graph).
DEFAULT_D_STAR = 0.5


def moe_spec(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": ParamSpec((d, e), ("embed", "experts")),
        "w_gate": ParamSpec((e, d, ff), ("experts", "embed", "ffn")),
        "w_up": ParamSpec((e, d, ff), ("experts", "embed", "ffn")),
        "w_down": ParamSpec((e, ff, d), ("experts", "ffn", "embed")),
    }


# ---------------------------------------------------------------------------
# router
# ---------------------------------------------------------------------------
def route(params, x_flat: jax.Array, cfg: ModelConfig):
    """x_flat: (T, d) -> (expert_ids (T, k), gate_w (T, k), aux_loss)."""
    logits = (x_flat.astype(jnp.float32)
              @ params["router"].astype(jnp.float32))          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, expert_ids = jax.lax.top_k(probs, cfg.top_k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss
    T, E = logits.shape
    me = probs.mean(axis=0)                                     # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[expert_ids.reshape(-1)].add(
        1.0 / (T * cfg.top_k))
    aux = E * jnp.sum(me * ce)
    return expert_ids, gate_w.astype(x_flat.dtype), aux


def dispatch_d_mat(expert_ids: jax.Array, n_experts: int) -> jax.Array:
    """The paper's D_mat = sigma/mu over tokens-per-expert (eq. 4)."""
    counts = jnp.zeros((n_experts,), jnp.float32).at[
        expert_ids.reshape(-1)].add(1.0)
    mu = counts.mean()
    sigma = counts.std()
    return sigma / jnp.maximum(mu, 1e-9)


def learn_d_star(points, max_drop_frac: float = 0.05) -> float:
    """The paper's off-line step (4) applied to MoE dispatch.

    ``points``: iterable of (d_mat, t_ell, t_csr, ell_drop_frac) measured
    by benchmarks/moe_dispatch.py.  ELL "qualifies" at a given imbalance
    when it is faster than CSR *and* its capacity drops stay within the
    quality budget; D* = max qualifying D_mat (0.0 if none)."""
    qual = [d for d, t_ell, t_csr, drop in points
            if t_ell < t_csr and drop <= max_drop_frac]
    return max(qual) if qual else 0.0


# ---------------------------------------------------------------------------
# expert FFN (SwiGLU), shared by both dispatch paths
# ---------------------------------------------------------------------------
def _expert_ffn(params, buf: jax.Array, ct) -> jax.Array:
    """buf: (E, C, d) -> (E, C, d)."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf,
                               params["w_gate"].astype(ct)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(ct))
    return jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(ct))


# ---------------------------------------------------------------------------
# ELL (capacity) dispatch — per-sequence batched (GShard group = sequence)
# ---------------------------------------------------------------------------
def moe_ell(params, x: jax.Array, expert_ids: jax.Array,
            gate_w: jax.Array, cfg: ModelConfig,
            capacity: Optional[int] = None) -> jax.Array:
    """Fixed-width buffers (B, E, C, d); overflow dropped (mode='drop') —
    ELL semantics: constant row width, zero padding.

    The scatter/gather is *batched over sequences* (vmap), so under pjit
    the scatter stays local to the data shard that owns the sequence; the
    (batch -> experts) buffer resharding between dispatch and expert
    compute is exactly the EP all-to-all.  (A global flat scatter makes
    GSPMD replicate the whole token stream — 'involuntary full
    rematerialization' — observed at 280 GB/device on dbrx train_4k.)"""
    ct = x.dtype
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    # capacity floor is 1, not 8: at decode (S=1) the top_k experts are
    # distinct, so C=1 is exact — a floor of 8 made every expert compute 8
    # padded slots per sequence (measured 15x FLOP inflation on dbrx
    # decode_32k; the ELL zero-padding pathology, §Perf iteration 2)
    C = capacity or max(1, int(cfg.capacity_factor * S * k / E))
    C = min(C, S * k)

    def dispatch_one(xs, ids):                 # xs (S,d), ids (S,k)
        flat_e = ids.reshape(-1)               # (S*k,)
        oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        pos = jnp.cumsum(oh, axis=0) - oh
        pos_in_e = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
        x_rep = jnp.repeat(xs, k, axis=0)      # (S*k, d)
        buf = jnp.zeros((E, C, d), ct).at[flat_e, pos_in_e].set(
            x_rep, mode="drop")
        return buf, flat_e, pos_in_e

    buf, flat_e, pos_in_e = jax.vmap(dispatch_one)(x, expert_ids)
    # (batch, experts) buffer: resharding to expert-parallel layout is the
    # all-to-all of a production MoE.  d carries "embed_act" so the serve
    # rules keep it aligned with the weights' FSDP axis (§Perf).
    buf = wlc(buf, ("batch", "experts", None, "embed_act"))
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf,
                               params["w_gate"].astype(ct)))
    h = h * jnp.einsum("becd,edf->becf", buf, params["w_up"].astype(ct))
    out_buf = jnp.einsum("becf,efd->becd", h, params["w_down"].astype(ct))
    out_buf = wlc(out_buf, ("batch", "experts", None, "embed_act"))

    def combine_one(ob, flat_e_b, pos_b, gw):  # ob (E,C,d)
        in_cap = pos_b < C
        g = ob[flat_e_b, jnp.minimum(pos_b, C - 1)]       # (S*k, d)
        g = jnp.where(in_cap[:, None], g, 0)
        w = gw.reshape(-1)[:, None].astype(ct)
        return (g * w).reshape(S, k, d).sum(axis=1)

    return jax.vmap(combine_one)(out_buf, flat_e, pos_in_e, gate_w)


# ---------------------------------------------------------------------------
# CSR (dropless, sorted) dispatch
# ---------------------------------------------------------------------------
def moe_csr(params, x_flat: jax.Array, expert_ids: jax.Array,
            gate_w: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Sort tokens by expert (CSR row order); grouped GEMM with ragged rows
    via ragged_dot; group_sizes = row-pointer diffs.  Dropless."""
    ct = x_flat.dtype
    T, d = x_flat.shape
    E, k = cfg.n_experts, cfg.top_k
    flat_e = expert_ids.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)              # CSR ordering
    inv = jnp.argsort(order, stable=True)
    xs = jnp.repeat(x_flat, k, axis=0)[order]             # (T*k, d) sorted
    group_sizes = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)

    h = jax.nn.silu(jax.lax.ragged_dot(xs, params["w_gate"].astype(ct),
                                       group_sizes))
    h = h * jax.lax.ragged_dot(xs, params["w_up"].astype(ct), group_sizes)
    out_sorted = jax.lax.ragged_dot(h, params["w_down"].astype(ct),
                                    group_sizes)
    out = out_sorted[inv]                                  # undo sort
    w = gate_w.reshape(-1)[:, None].astype(ct)
    return (out * w).reshape(T, k, d).sum(axis=1)


# ---------------------------------------------------------------------------
# block-level apply with the auto-tuning rule
# ---------------------------------------------------------------------------
def moe_apply(params, x: jax.Array, cfg: ModelConfig,
              d_star: float = DEFAULT_D_STAR,
              seq_chunk: int = 4096) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss).  Dispatch per cfg.moe_dispatch.

    Long sequences (prefill_32k) run the ELL dispatch in ``seq_chunk``
    slices via lax.scan: capacity is per chunk (GShard 'group' semantics)
    and the (B, E, C, d) dispatch buffers stay bounded by the chunk —
    without this, 32k-token prefill materializes ~4 GB of pre-all-to-all
    buffers per MoE layer."""
    B, S, d = x.shape
    x_flat = x.reshape(B * S, d)
    expert_ids_f, gate_w_f, aux = route(params, x_flat, cfg)
    expert_ids = expert_ids_f.reshape(B, S, cfg.top_k)
    gate_w = gate_w_f.reshape(B, S, cfg.top_k)

    if cfg.moe_dispatch == "ell":
        if S > seq_chunk and S % seq_chunk == 0:
            nch = S // seq_chunk
            xs = (x.reshape(B, nch, seq_chunk, d).swapaxes(0, 1),
                  expert_ids.reshape(B, nch, seq_chunk, cfg.top_k
                                     ).swapaxes(0, 1),
                  gate_w.reshape(B, nch, seq_chunk, cfg.top_k
                                 ).swapaxes(0, 1))
            _, ys = jax.lax.scan(
                lambda _, c: (None, moe_ell(params, c[0], c[1], c[2], cfg)),
                None, xs)
            y = ys.swapaxes(0, 1).reshape(B, S, d)
        else:
            y = moe_ell(params, x, expert_ids, gate_w, cfg)
    elif cfg.moe_dispatch == "csr":
        y = moe_csr(params, x_flat, expert_ids_f, gate_w_f, cfg
                    ).reshape(B, S, d)
    elif cfg.moe_dispatch == "auto":
        # the paper's on-line phase, on device, per step: D_mat < D* -> ELL
        d_mat = dispatch_d_mat(expert_ids_f, cfg.n_experts)
        y = jax.lax.cond(
            d_mat < d_star,
            lambda: moe_ell(params, x, expert_ids, gate_w, cfg),
            lambda: moe_csr(params, x_flat, expert_ids_f, gate_w_f, cfg
                            ).reshape(B, S, d),
        )
    else:
        raise ValueError(cfg.moe_dispatch)
    return y, aux


__all__ = ["moe_spec", "moe_apply", "moe_ell", "moe_csr", "route",
           "dispatch_d_mat", "DEFAULT_D_STAR"]
