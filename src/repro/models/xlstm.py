"""xLSTM blocks [arXiv:2405.04517]: mLSTM (matrix memory, exp-input-gate
with max-stabilizer) and sLSTM (scalar memory with true recurrence and
per-head block-diagonal recurrent weights).

mLSTM per head (state C: (dk, dv), normalizer n: (dk,), stabilizer m):
    m_t = max(logsig(f~) + m_{t-1}, i~_t)
    f'  = exp(logsig(f~) + m_{t-1} - m_t);   i' = exp(i~ - m_t)
    C_t = f' C_{t-1} + i' k_t (x) v_t;       n_t = f' n_{t-1} + i' k_t
    y_t = (q_t . C_t) / max(|q_t . n_t|, 1)

sLSTM has no parallel form (the paper's point: real recurrence) — a
lax.scan over time in both train and decode.  The mLSTM here is the
step-scan baseline; a chunked parallel form is a perf-pass candidate."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.rules import ParamSpec, with_logical_constraint as wlc
from .layers import rms_norm


def scan_chunked_remat(step, carry0, xs, inner: int = 64):
    """scan with two-level rematerialization: the outer scan (over chunks of
    ``inner`` steps) checkpoints only chunk-boundary carries; the inner
    steps are recomputed in backward.  Peak memory falls from O(S) saved
    carries to O(S/inner + inner) — the difference between 130 GB and
    ~10 GB for the mLSTM matrix memory on train_4k."""
    L = jax.tree.leaves(xs)[0].shape[0]
    inner = min(inner, L)
    while L % inner:
        inner //= 2
    n_outer = L // inner
    xs_r = jax.tree.map(
        lambda a: a.reshape((n_outer, inner) + a.shape[1:]), xs)

    @jax.checkpoint
    def outer(carry, chunk):
        return jax.lax.scan(step, carry, chunk)

    carry, ys = jax.lax.scan(outer, carry0, xs_r)
    ys = jax.tree.map(lambda a: a.reshape((L,) + a.shape[2:]), ys)
    return carry, ys


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def _mlstm_dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    d_in = cfg.mlstm_expand * cfg.d_model
    H = cfg.n_heads
    dv = d_in // H
    dk = max(dv // 2, 8)
    return d_in, H, dk, dv


def mlstm_spec(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    d_in, H, dk, dv = _mlstm_dims(cfg)
    # separate projections (individually shardable; fused widths are not
    # generally divisible by the TP degree)
    return {
        "w_z": ParamSpec((d, d_in), ("embed", "inner")),
        "w_q": ParamSpec((d, H * dk), ("embed", "inner")),
        "w_k": ParamSpec((d, H * dk), ("embed", "inner")),
        "w_v": ParamSpec((d, d_in), ("embed", "inner")),
        "w_if": ParamSpec((d, 2 * H), ("embed", None)),
        "conv_w": ParamSpec((4, d_in), ("conv", "inner")),
        "conv_b": ParamSpec((d_in,), ("inner",), init="zeros"),
        "norm": ParamSpec((d_in,), (None,), init="ones"),
        "out_proj": ParamSpec((d_in, d), ("inner", "embed")),
    }


def _mlstm_proj(cfg: ModelConfig, params, x: jax.Array, ct):
    d_in, H, dk, dv = _mlstm_dims(cfg)
    z = x @ params["w_z"].astype(ct)
    q = x @ params["w_q"].astype(ct)
    k = x @ params["w_k"].astype(ct)
    v = x @ params["w_v"].astype(ct)
    gates = x @ params["w_if"].astype(ct)
    i_raw, f_raw = jnp.split(gates, 2, axis=-1)
    return z, q, k, v, i_raw, f_raw


def init_mlstm_cache(cfg: ModelConfig, batch: int, dtype):
    d_in, H, dk, dv = _mlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, H, dk, dv), jnp.float32),
        "n": jnp.zeros((batch, H, dk), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, 3, d_in), dtype),
    }


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    K = w.shape[0]
    u_pad = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(u_pad[:, i:i + u.shape[1], :] * w[i][None, None, :]
              for i in range(K))
    return jax.nn.silu(out + b[None, None, :])


def _mlstm_step(carry, xs):
    C, n, m = carry
    q, k, v, i_raw, f_raw = xs     # q,k: (B,H,dk); v: (B,H,dv); gates (B,H)
    logf = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(logf + m, i_raw)
    f_p = jnp.exp(logf + m - m_new)
    i_p = jnp.exp(i_raw - m_new)
    C_new = f_p[..., None, None] * C + i_p[..., None, None] * (
        k[..., :, None] * v[..., None, :])
    n_new = f_p[..., None] * n + i_p[..., None] * k
    num = jnp.einsum("bhk,bhkv->bhv", q, C_new)
    den = jnp.abs(jnp.einsum("bhk,bhk->bh", q, n_new))
    y = num / jnp.maximum(den, 1.0)[..., None]
    return (C_new, n_new, m_new), y


def mlstm_apply(params, x: jax.Array, cfg: ModelConfig, *,
                cache: Optional[Dict[str, Any]] = None
                ) -> Tuple[jax.Array, Optional[Dict]]:
    ct = cfg.compute_dtype
    B, S, d = x.shape
    d_in, H, dk, dv = _mlstm_dims(cfg)
    z, q, k, v, i_raw, f_raw = _mlstm_proj(cfg, params, x, ct)
    z = wlc(z, ("batch", "seq", "inner"))
    v = wlc(v, ("batch", "seq", "inner"))

    if cache is None or S > 1:
        vc = _causal_conv(v, params["conv_w"].astype(ct),
                          params["conv_b"].astype(ct))
        qs = q.reshape(B, S, H, dk).astype(jnp.float32)
        ks = k.reshape(B, S, H, dk).astype(jnp.float32) / jnp.sqrt(float(dk))
        vs = vc.reshape(B, S, H, dv).astype(jnp.float32)
        gi = i_raw.reshape(B, S, H).astype(jnp.float32)
        gf = f_raw.reshape(B, S, H).astype(jnp.float32)
        if cache is None:
            carry0 = (jnp.zeros((B, H, dk, dv), jnp.float32),
                      jnp.zeros((B, H, dk), jnp.float32),
                      jnp.full((B, H), -1e30, jnp.float32))
        else:
            carry0 = (cache["C"], cache["n"], cache["m"])
        xs = tuple(jnp.moveaxis(a, 1, 0) for a in (qs, ks, vs, gi, gf))
        (Cf, nf, mf), ys = scan_chunked_remat(_mlstm_step, carry0, xs)
        y = jnp.moveaxis(ys, 0, 1)
        if cache is None:
            new_cache = None
        else:  # prefill
            tail = jnp.concatenate(
                [cache["conv"], v.astype(cache["conv"].dtype)],
                axis=1)[:, -3:, :]
            new_cache = {"C": Cf, "n": nf, "m": mf, "conv": tail}
    else:
        conv_win = jnp.concatenate(
            [cache["conv"], v.astype(cache["conv"].dtype)], axis=1)
        w = params["conv_w"].astype(ct)
        vc = jax.nn.silu(jnp.einsum("bkc,kc->bc", conv_win, w) +
                         params["conv_b"].astype(ct))
        qs = q[:, 0].reshape(B, H, dk).astype(jnp.float32)
        ks = k[:, 0].reshape(B, H, dk).astype(jnp.float32) / jnp.sqrt(float(dk))
        vs = vc.reshape(B, H, dv).astype(jnp.float32)
        gi = i_raw[:, 0].reshape(B, H).astype(jnp.float32)
        gf = f_raw[:, 0].reshape(B, H).astype(jnp.float32)
        (C, n, m), y1 = _mlstm_step((cache["C"], cache["n"], cache["m"]),
                                    (qs, ks, vs, gi, gf))
        y = y1[:, None]                                    # (B,1,H,dv)
        new_cache = {"C": C, "n": n, "m": m,
                     "conv": conv_win[:, 1:].astype(cache["conv"].dtype)}

    y = y.reshape(B, S, d_in).astype(ct)
    y = rms_norm({"scale": params["norm"]}, y, cfg.norm_eps)
    y = y * jax.nn.silu(z)
    out = y @ params["out_proj"].astype(ct)
    return wlc(out, ("batch", "seq_sp" if cfg.use_seq_sp else "seq", "embed_act")), new_cache


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def _slstm_dims(cfg: ModelConfig) -> Tuple[int, int]:
    H = cfg.n_heads
    dh = cfg.d_model // H
    return H, dh


def slstm_spec(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    H, dh = _slstm_dims(cfg)
    # §Perf: the recurrent R matmul runs once per *time step* inside the
    # scan; sharding its contraction dim inserts an all-reduce per step.
    # Replicating R (16 MB) keeps every step local.
    r_axes = (None, None, "inner") if cfg.xlstm_shard_recurrent \
        else (None, None, None)
    return {
        "in_proj": ParamSpec((d, 4 * d), ("embed", "inner")),   # z,i,f,o
        "R": ParamSpec((H, dh, 4 * dh), r_axes, scale=0.1),     # recurrent
        "norm": ParamSpec((d,), (None,), init="ones"),
        "out_proj": ParamSpec((d, d), ("embed", "embed_act")),
    }


def init_slstm_cache(cfg: ModelConfig, batch: int, dtype):
    H, dh = _slstm_dims(cfg)
    z = jnp.zeros((batch, H, dh), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, H, dh), -1e30,
                                                  jnp.float32)}


def _slstm_step(R, carry, wx):
    """wx: (B, H, dh, 4) pre-activations from the input projection."""
    c, n, h, m = carry
    rec = jnp.einsum("bhd,hde->bhe", h, R)                # (B,H,4*dh)
    B, H, dh4 = rec.shape
    dh = dh4 // 4
    pre = wx + rec.reshape(B, H, dh, 4)
    z_t = jnp.tanh(pre[..., 0])
    i_raw = pre[..., 1]
    f_raw = pre[..., 2]
    o_t = jax.nn.sigmoid(pre[..., 3])
    logf = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(logf + m, i_raw)
    f_p = jnp.exp(logf + m - m_new)
    i_p = jnp.exp(i_raw - m_new)
    c_new = f_p * c + i_p * z_t
    n_new = f_p * n + i_p
    h_new = o_t * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_apply(params, x: jax.Array, cfg: ModelConfig, *,
                cache: Optional[Dict[str, Any]] = None
                ) -> Tuple[jax.Array, Optional[Dict]]:
    ct = cfg.compute_dtype
    B, S, d = x.shape
    H, dh = _slstm_dims(cfg)
    wx = (x @ params["in_proj"].astype(ct)).astype(jnp.float32)
    wx = wx.reshape(B, S, H, dh, 4)
    R = params["R"].astype(jnp.float32)

    if cache is None or S > 1:
        if cache is None:
            z0 = jnp.zeros((B, H, dh), jnp.float32)
            carry0 = (z0, z0, z0, jnp.full((B, H, dh), -1e30, jnp.float32))
        else:
            carry0 = (cache["c"], cache["n"], cache["h"], cache["m"])
        (c, n, h, m), ys = scan_chunked_remat(
            lambda cr, w: _slstm_step(R, cr, w), carry0,
            jnp.moveaxis(wx, 1, 0))
        y = jnp.moveaxis(ys, 0, 1)                        # (B,S,H,dh)
        new_cache = (None if cache is None
                     else {"c": c, "n": n, "h": h, "m": m})
    else:
        (c, n, h, m), y1 = _slstm_step(
            R, (cache["c"], cache["n"], cache["h"], cache["m"]), wx[:, 0])
        y = y1[:, None]
        new_cache = {"c": c, "n": n, "h": h, "m": m}

    y = y.reshape(B, S, d).astype(ct)
    y = rms_norm({"scale": params["norm"]}, y, cfg.norm_eps)
    out = y @ params["out_proj"].astype(ct)
    return wlc(out, ("batch", "seq_sp" if cfg.use_seq_sp else "seq", "embed_act")), new_cache


__all__ = ["mlstm_spec", "mlstm_apply", "init_mlstm_cache",
           "slstm_spec", "slstm_apply", "init_slstm_cache"]
