"""Shared NN layers: RMSNorm, RoPE, MLP, embeddings — spec + apply pairs.

Every module is a (``*_spec``, ``*_apply``) pair: the spec declares shapes
and logical axes once (single source of truth for init AND sharding), the
apply is a pure function.  Weights live in fp32; applies cast to the
config compute dtype (bf16 by default)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.rules import ParamSpec


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def rms_norm_spec(d: int) -> Dict[str, ParamSpec]:
    return {"scale": ParamSpec((d,), (None,), init="ones")}


def rms_norm(params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                   # (half,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]                   # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------
def mlp_spec(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, ff = cfg.d_model, cfg.d_ff
    return {
        "w_gate": ParamSpec((d, ff), ("embed", "ffn")),
        "w_up": ParamSpec((d, ff), ("embed", "ffn")),
        "w_down": ParamSpec((ff, d), ("ffn", "embed")),
    }


def mlp_apply(params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    ct = cfg.compute_dtype
    h = jax.nn.silu(x @ params["w_gate"].astype(ct)) * (x @ params["w_up"].astype(ct))
    return h @ params["w_down"].astype(ct)


# ---------------------------------------------------------------------------
# embeddings / lm head
# ---------------------------------------------------------------------------
def padded_vocab(cfg: ModelConfig, mult: int = 128) -> int:
    """Vocab rounded up for even sharding (jit argument shardings require
    divisibility).  Extra rows are never indexed; extra logit columns are
    masked to -inf in lm_head_apply, so the model function is unchanged."""
    return ((cfg.vocab_size + mult - 1) // mult) * mult


def embed_spec(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    # embed_tp_lookup (§Perf): shard the table over model on the d dim and
    # keep vocab replicated -> the token gather is fully local per shard
    # (each device gathers its d-slice for all tokens).  GSPMD otherwise
    # falls back to "involuntary full rematerialization" of the
    # vocab-sharded table on every lookup (observed: GB-scale all-gathers
    # per microbatch on the 262k-vocab archs).
    axes = (None, "embed_tp") if cfg.embed_tp_lookup else ("vocab", "embed")
    spec = {"tok": ParamSpec((padded_vocab(cfg), cfg.d_model),
                             axes, init="embed")}
    if cfg.frontend is not None:
        # stub frontend projection: precomputed patch/frame embeddings
        # (d_frontend == d_model for the stub) -> model space
        spec["frontend_proj"] = ParamSpec((cfg.d_model, cfg.d_model),
                                          ("embed", "embed_act"))
    return spec


def embed_tokens(params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    ct = cfg.compute_dtype
    return params["tok"].astype(ct)[tokens]


def lm_head_spec(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    if cfg.tie_embeddings:
        return {}
    return {"w": ParamSpec((cfg.d_model, padded_vocab(cfg)),
                           ("embed", "vocab"))}


def lm_head_apply(head_params, embed_params, x: jax.Array,
                  cfg: ModelConfig) -> jax.Array:
    ct = cfg.compute_dtype
    if cfg.tie_embeddings:
        logits = x @ embed_params["tok"].astype(ct).T
    else:
        logits = x @ head_params["w"].astype(ct)
    vp = padded_vocab(cfg)
    if vp != cfg.vocab_size:  # mask pad columns out of the softmax
        neg = jnp.asarray(-1e30, logits.dtype)
        logits = jnp.where(jnp.arange(vp) < cfg.vocab_size, logits, neg)
    return logits


__all__ = ["rms_norm_spec", "rms_norm", "rope_freqs", "apply_rope",
           "mlp_spec", "mlp_apply", "embed_spec", "embed_tokens",
           "lm_head_spec", "lm_head_apply"]
