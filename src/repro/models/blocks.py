"""Block kinds: spec/apply/cache-init triples, composed by model.py.

Residual structure:
  attn/local[_moe]: x += Attn(LN(x)); x += MLP-or-MoE(LN(x))
  mamba[_attn]:     x += Mamba(LN(x)); [+ the zamba2 *shared* attn+MLP block]
  mlstm/slstm:      x += xLSTM(LN(x))   (projections live inside the block)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .attention import (attention_apply, attention_spec, init_kv_cache,
                        kv_cache_len)
from .layers import mlp_apply, mlp_spec, rms_norm, rms_norm_spec
from .moe import moe_apply, moe_spec
from .ssm import init_mamba_cache, mamba_apply, mamba_spec
from .xlstm import (init_mlstm_cache, init_slstm_cache, mlstm_apply,
                    mlstm_spec, slstm_apply, slstm_spec)


def block_spec(kind: str, cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    if kind in ("attn", "local"):
        return {"ln1": rms_norm_spec(d), "attn": attention_spec(cfg),
                "ln2": rms_norm_spec(d), "mlp": mlp_spec(cfg)}
    if kind in ("moe", "local_moe"):
        return {"ln1": rms_norm_spec(d), "attn": attention_spec(cfg),
                "ln2": rms_norm_spec(d), "moe": moe_spec(cfg)}
    if kind in ("mamba", "mamba_attn"):
        return {"ln": rms_norm_spec(d), "mamba": mamba_spec(cfg)}
    if kind == "mlstm":
        return {"ln": rms_norm_spec(d), "mlstm": mlstm_spec(cfg)}
    if kind == "slstm":
        return {"ln": rms_norm_spec(d), "slstm": slstm_spec(cfg)}
    raise KeyError(kind)


def shared_block_spec(cfg: ModelConfig) -> Dict[str, Any]:
    """zamba2's weight-shared attention block (one param set, many calls)."""
    d = cfg.d_model
    return {"ln1": rms_norm_spec(d), "attn": attention_spec(cfg),
            "ln2": rms_norm_spec(d), "mlp": mlp_spec(cfg)}


def init_block_cache(kind: str, cfg: ModelConfig, batch: int, max_len: int,
                     dtype) -> Dict[str, Any]:
    if kind in ("attn", "local", "moe", "local_moe"):
        return {"attn": init_kv_cache(
            cfg, batch, kv_cache_len(cfg, kind, max_len), dtype)}
    if kind == "mamba":
        return {"mamba": init_mamba_cache(cfg, batch, dtype)}
    if kind == "mamba_attn":
        return {"mamba": init_mamba_cache(cfg, batch, dtype),
                "attn": init_kv_cache(cfg, batch, max_len, dtype)}
    if kind == "mlstm":
        return {"mlstm": init_mlstm_cache(cfg, batch, dtype)}
    if kind == "slstm":
        return {"slstm": init_slstm_cache(cfg, batch, dtype)}
    raise KeyError(kind)


def block_apply(kind: str, cfg: ModelConfig, params, x, *,
                shared_params=None, cache=None, cache_len=None
                ) -> Tuple[Any, Optional[Dict], Any]:
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: Optional[Dict[str, Any]] = {} if cache is not None else None

    if kind in ("attn", "local", "moe", "local_moe"):
        window = cfg.window if kind in ("local", "local_moe") else None
        theta = (cfg.rope_theta_global
                 if kind == "attn" and cfg.rope_theta_global else None)
        h, kv = attention_apply(
            params["attn"], rms_norm(params["ln1"], x, cfg.norm_eps), cfg,
            window=window, rope_theta=theta,
            cache=None if cache is None else cache["attn"],
            cache_len=cache_len)
        x = x + h
        if new_cache is not None:
            new_cache["attn"] = kv
        h2_in = rms_norm(params["ln2"], x, cfg.norm_eps)
        if kind in ("moe", "local_moe"):
            h2, aux = moe_apply(params["moe"], h2_in, cfg)
        else:
            h2 = mlp_apply(params["mlp"], h2_in, cfg)
        x = x + h2
        return x, new_cache, aux

    if kind in ("mamba", "mamba_attn"):
        h, mc = mamba_apply(params["mamba"],
                            rms_norm(params["ln"], x, cfg.norm_eps), cfg,
                            cache=None if cache is None else cache["mamba"])
        x = x + h
        if new_cache is not None:
            new_cache["mamba"] = mc
        if kind == "mamba_attn":
            assert shared_params is not None, "zamba2 needs shared attn params"
            h, kv = attention_apply(
                shared_params["attn"],
                rms_norm(shared_params["ln1"], x, cfg.norm_eps), cfg,
                cache=None if cache is None else cache["attn"],
                cache_len=cache_len)
            x = x + h
            x = x + mlp_apply(shared_params["mlp"],
                              rms_norm(shared_params["ln2"], x, cfg.norm_eps),
                              cfg)
            if new_cache is not None:
                new_cache["attn"] = kv
        return x, new_cache, aux

    if kind == "mlstm":
        h, c = mlstm_apply(params["mlstm"],
                           rms_norm(params["ln"], x, cfg.norm_eps), cfg,
                           cache=None if cache is None else cache["mlstm"])
        if new_cache is not None:
            new_cache["mlstm"] = c
        return x + h, new_cache, aux

    if kind == "slstm":
        h, c = slstm_apply(params["slstm"],
                           rms_norm(params["ln"], x, cfg.norm_eps), cfg,
                           cache=None if cache is None else cache["slstm"])
        if new_cache is not None:
            new_cache["slstm"] = c
        return x + h, new_cache, aux

    raise KeyError(kind)


__all__ = ["block_spec", "shared_block_spec", "init_block_cache",
           "block_apply"]
