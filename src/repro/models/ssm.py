"""Mamba-2 (SSD) block — zamba2's backbone.

State-space recurrence per head h with scalar decay:
    a_t = exp(dt_t * A_h)            (A_h < 0)
    H_t = a_t * H_{t-1} + dt_t * B_t (x) x_t        H: (state, head_dim)
    y_t = C_t . H_t + D_h * x_t

Training uses the *chunked* SSD algorithm (intra-chunk quadratic term +
inter-chunk carried state), the production form on TPU: the quadratic
intra-chunk term is an MXU-friendly (L x L) matmul and the carried state
keeps memory O(chunk).  Decode is the one-step recurrence with an
(state x head_dim) cache per head plus a (conv_w-1)-deep conv cache."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.rules import ParamSpec, with_logical_constraint as wlc
from .layers import rms_norm


def _dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    d_in = cfg.ssm_expand * cfg.d_model
    heads = d_in // cfg.ssm_head_dim
    return d_in, heads, cfg.ssm_head_dim, cfg.ssm_state


def mamba_spec(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    d_in, H, hd, N = _dims(cfg)
    conv_ch = d_in + 2 * N
    return {
        # [z (d_in) | x (d_in) | B (N) | C (N) | dt (H)]
        "in_proj": ParamSpec((d, 2 * d_in + 2 * N + H), ("embed", "inner")),
        "conv_w": ParamSpec((cfg.ssm_conv, conv_ch), ("conv", "inner")),
        "conv_b": ParamSpec((conv_ch,), ("inner",), init="zeros"),
        "A_log": ParamSpec((H,), (None,), init="zeros"),
        "D": ParamSpec((H,), (None,), init="ones"),
        "dt_bias": ParamSpec((H,), (None,), init="zeros"),
        "norm": ParamSpec((d_in,), (None,), init="ones"),
        "out_proj": ParamSpec((d_in, d), ("inner", "embed")),
    }


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv: u (B, L, C), w (K, C)."""
    K = w.shape[0]
    u_pad = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(u_pad[:, i:i + u.shape[1], :] * w[i][None, None, :]
              for i in range(K))
    return jax.nn.silu(out + b[None, None, :])


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    d_in, H, hd, N = _dims(cfg)
    z, xin, Bm, Cm, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + N, 2 * d_in + 2 * N], axis=-1)
    return z, xin, Bm, Cm, dt


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype):
    d_in, H, hd, N = _dims(cfg)
    conv_ch = d_in + 2 * N
    return {
        "h": jnp.zeros((batch, H, N, hd), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
    }


def mamba_apply(params, x: jax.Array, cfg: ModelConfig, *,
                cache: Optional[Dict[str, Any]] = None,
                chunk: int = 256) -> Tuple[jax.Array, Optional[Dict]]:
    """x: (B, S, d).  Train/prefill when cache is None (chunked SSD);
    decode one step when cache is given (S == 1)."""
    ct = cfg.compute_dtype
    B, S, d = x.shape
    d_in, H, hd, N = _dims(cfg)
    proj = x @ params["in_proj"].astype(ct)               # (B,S,...)
    proj = wlc(proj, ("batch", "seq", "inner"))
    z, xin, Bm, Cm, dt_raw = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)

    A = -jnp.exp(params["A_log"].astype(jnp.float32))     # (H,) negative
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32))  # (B,S,H)

    if cache is None or S > 1:
        conv_out = _causal_conv(conv_in, params["conv_w"].astype(ct),
                                params["conv_b"].astype(ct))
        xc, Bc, Cc = jnp.split(conv_out, [d_in, d_in + N], axis=-1)
        xh = xc.reshape(B, S, H, hd).astype(jnp.float32)
        h0 = None if cache is None else cache["h"]
        y, h_fin = _ssd_chunked(xh, Bc.astype(jnp.float32),
                                Cc.astype(jnp.float32), dt, A, chunk=chunk,
                                h0=h0)                    # (B,S,H,hd) f32
        y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xh
        if cache is None:
            new_cache = None
        else:  # prefill: final SSM state + last (K-1) conv inputs
            K = cfg.ssm_conv
            tail = jnp.concatenate(
                [cache["conv"], conv_in.astype(cache["conv"].dtype)],
                axis=1)[:, -(K - 1):, :]
            new_cache = {"h": h_fin, "conv": tail}
    else:
        # decode: conv over [cache | current], one recurrence step
        conv_win = jnp.concatenate([cache["conv"],
                                    conv_in.astype(cache["conv"].dtype)],
                                   axis=1)                # (B, K, C)
        w = params["conv_w"].astype(ct)
        co = jnp.einsum("bkc,kc->bc", conv_win, w) + params["conv_b"].astype(ct)
        co = jax.nn.silu(co)[:, None, :]                  # (B,1,C)
        xc, Bc, Cc = jnp.split(co, [d_in, d_in + N], axis=-1)
        xh = xc.reshape(B, 1, H, hd).astype(jnp.float32)[:, 0]   # (B,H,hd)
        Bt = Bc[:, 0].astype(jnp.float32)                 # (B,N)
        Ct = Cc[:, 0].astype(jnp.float32)
        dt1 = dt[:, 0]                                    # (B,H)
        a = jnp.exp(dt1 * A[None, :])                     # (B,H)
        h_new = (a[:, :, None, None] * cache["h"] +
                 jnp.einsum("bh,bn,bhd->bhnd", dt1, Bt, xh))
        y = jnp.einsum("bn,bhnd->bhd", Ct, h_new)
        y = y + params["D"].astype(jnp.float32)[None, :, None] * xh
        y = y[:, None]                                    # (B,1,H,hd)
        new_cache = {"h": h_new,
                     "conv": conv_win[:, 1:].astype(cache["conv"].dtype)}

    y = y.reshape(B, S, d_in).astype(ct)
    y = rms_norm({"scale": params["norm"]}, y, cfg.norm_eps)
    y = y * jax.nn.silu(z)
    out = y @ params["out_proj"].astype(ct)
    return wlc(out, ("batch", "seq_sp" if cfg.use_seq_sp else "seq", "embed_act")), new_cache


def _ssd_chunked(x: jax.Array, Bm: jax.Array, Cm: jax.Array, dt: jax.Array,
                 A: jax.Array, *, chunk: int,
                 h0: Optional[jax.Array] = None):
    """Chunked SSD: x (B,S,H,hd), Bm/Cm (B,S,N), dt (B,S,H), A (H,).

    Per chunk of length L:
      intra: y[t] += sum_{s<=t} exp(lam_t - lam_s) dt_s (C_t.B_s) x_s
      inter: y[t] += exp(lam_t) C_t . Hprev ;
             Hnew = exp(lam_L) Hprev + sum_s exp(lam_L - lam_s) dt_s B_s x_s^T
    """
    B, S, H, hd = x.shape
    N = Bm.shape[-1]
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    nc = S // L

    xc = x.reshape(B, nc, L, H, hd)
    Bc = Bm.reshape(B, nc, L, N)
    Cc = Cm.reshape(B, nc, L, N)
    dtc = dt.reshape(B, nc, L, H)

    def step(h_prev, xs):
        xk, bk, ck, dtk = xs            # (B,L,H,hd),(B,L,N),(B,L,N),(B,L,H)
        loga = dtk * A[None, None, :]                     # (B,L,H) <= 0
        lam = jnp.cumsum(loga, axis=1)                    # (B,L,H)
        # intra-chunk quadratic term
        cb = jnp.einsum("bln,bmn->blm", ck, bk)           # (B,L,L)
        decay = lam[:, :, None, :] - lam[:, None, :, :]   # (B,L,L,H) t,s
        mask = (jnp.arange(L)[:, None] >= jnp.arange(L)[None, :])
        M = jnp.where(mask[None, :, :, None],
                      jnp.exp(decay) * cb[..., None] * dtk[:, None, :, :], 0.0)
        y = jnp.einsum("blsh,bshd->blhd", M, xk)
        # inter-chunk: contribution of carried state
        y = y + jnp.exp(lam)[..., None] * jnp.einsum(
            "bln,bhnd->blhd", ck, h_prev)
        # state update
        lam_L = lam[:, -1:, :]                            # (B,1,H)
        w = jnp.exp(lam_L - lam) * dtk                    # (B,L,H)
        h_new = (jnp.exp(lam_L)[:, 0, :, None, None] * h_prev +
                 jnp.einsum("blh,bln,blhd->bhnd", w, bk, xk))
        return h_new, y

    if h0 is None:
        h0 = jnp.zeros((B, H, N, hd), jnp.float32)
    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (xc, Bc, Cc, dtc))
    # checkpoint the chunk body: recompute the (L x L) intra-chunk decay
    # matrices in backward instead of saving them
    h_fin, ys = jax.lax.scan(jax.checkpoint(step), h0, xs)  # (nc,B,L,H,hd)
    return jnp.moveaxis(ys, 0, 1).reshape(B, S, H, hd), h_fin


__all__ = ["mamba_spec", "mamba_apply", "init_mamba_cache"]
