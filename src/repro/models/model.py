"""The LM: embed -> lax.scan over layer-pattern repetitions (+ unrolled
remainder) -> final norm -> logits.  One code path serves all ten
architectures; HLO size is O(period), not O(depth) (DESIGN.md §7).

Public API:
  model_spec(cfg)                -> ParamSpec tree (init + sharding source)
  init(cfg, key)                 -> params
  forward(params, batch, cfg)    -> (logits, aux)         [train/prefill]
  loss_fn(params, batch, cfg)    -> scalar loss
  init_caches(cfg, B, max_len)   -> decode cache tree
  decode_step(params, tokens, caches, cache_len, cfg)
                                 -> (logits, new_caches)  [one token]
  prefill(params, batch, caches, cfg) -> (logits, caches) [fill caches]
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.rules import (ParamSpec, init_params,
                                  param_count, stack_spec,
                                  with_logical_constraint as wlc)
from .blocks import (block_apply, block_spec, init_block_cache,
                     shared_block_spec)
from .layers import (embed_spec, embed_tokens, lm_head_apply, lm_head_spec,
                     rms_norm, rms_norm_spec)


# ---------------------------------------------------------------------------
# spec
# ---------------------------------------------------------------------------
def model_spec(cfg: ModelConfig) -> Dict[str, Any]:
    spec: Dict[str, Any] = {
        "embed": embed_spec(cfg),
        "final_norm": rms_norm_spec(cfg.d_model),
        "head": lm_head_spec(cfg),
    }
    reps = cfg.scan_reps
    if reps > 0:
        spec["scan"] = {
            f"pos{i}": stack_spec(block_spec(kind, cfg), reps, "layers")
            for i, kind in enumerate(cfg.layer_pattern)}
    spec["rem"] = {f"rem{i}": block_spec(kind, cfg)
                   for i, kind in enumerate(cfg.remainder_pattern)}
    if any(k == "mamba_attn" for k in cfg.layer_pattern +
           cfg.remainder_pattern):
        spec["shared"] = shared_block_spec(cfg)
    return spec


def init(cfg: ModelConfig, key: jax.Array, dtype=jnp.float32):
    return init_params(key, model_spec(cfg), dtype)


def n_params(cfg: ModelConfig) -> int:
    return param_count(model_spec(cfg))


def n_active_params(cfg: ModelConfig) -> int:
    """Active parameters per token (MoE: top_k of n_experts)."""
    total = param_count(model_spec(cfg))
    if cfg.n_experts and cfg.top_k:
        from .moe import moe_spec
        moe_per_layer = param_count(moe_spec(cfg))
        n_moe_layers = sum(k in ("moe", "local_moe")
                           for k in cfg.layer_pattern) * cfg.scan_reps
        n_moe_layers += sum(k in ("moe", "local_moe")
                            for k in cfg.remainder_pattern)
        router = cfg.d_model * cfg.n_experts
        expert_part = moe_per_layer - router
        inactive = expert_part * (1 - cfg.top_k / cfg.n_experts)
        total -= int(n_moe_layers * inactive)
    return total


# ---------------------------------------------------------------------------
# forward (train / prefill without cache)
# ---------------------------------------------------------------------------
def _embed_inputs(params, batch: Dict[str, jax.Array], cfg: ModelConfig):
    x = embed_tokens(params["embed"], batch["tokens"], cfg)
    if cfg.frontend is not None and "frontend_embeds" in batch:
        ct = cfg.compute_dtype
        fe = batch["frontend_embeds"].astype(ct) @ \
            params["embed"]["frontend_proj"].astype(ct)
        x = jnp.concatenate([fe, x], axis=1)
    x = x * jnp.sqrt(float(cfg.d_model)).astype(x.dtype)
    return wlc(x, ("batch", "seq_sp" if cfg.use_seq_sp else "seq", "embed_act"))


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def backbone(params, batch: Dict[str, jax.Array], cfg: ModelConfig
             ) -> Tuple[jax.Array, jax.Array]:
    """embed -> blocks -> final norm.  Returns (hidden (B,S,d), aux)."""
    x = _embed_inputs(params, batch, cfg)
    shared = params.get("shared")

    def rep_fn(carry, stacked_slice):
        x, aux = carry
        for i, kind in enumerate(cfg.layer_pattern):
            x, _, a = block_apply(kind, cfg, stacked_slice[f"pos{i}"], x,
                                  shared_params=shared)
            aux = aux + a
        return (x, aux), None

    aux = jnp.zeros((), jnp.float32)
    if cfg.scan_reps > 0:
        (x, aux), _ = jax.lax.scan(_maybe_remat(rep_fn, cfg), (x, aux),
                                   params["scan"])
    for i, kind in enumerate(cfg.remainder_pattern):
        x, _, a = block_apply(kind, cfg, params["rem"][f"rem{i}"], x,
                              shared_params=shared)
        aux = aux + a
    return rms_norm(params["final_norm"], x, cfg.norm_eps), aux


def forward(params, batch: Dict[str, jax.Array], cfg: ModelConfig
            ) -> Tuple[jax.Array, jax.Array]:
    """batch: {"tokens": (B,S') [, "frontend_embeds": (B,F,d)]} ->
    (logits (B,S,V_pad), aux)."""
    x, aux = backbone(params, batch, cfg)
    logits = lm_head_apply(params.get("head"), params["embed"], x, cfg)
    return wlc(logits, ("batch", "seq", "vocab")), aux


def loss_fn(params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            aux_weight: float = 0.01, seq_chunk: int = 512) -> jax.Array:
    """Causal LM loss; labels < 0 are masked (frontend positions, padding).

    The softmax cross-entropy is *sequence-chunked* (scan + remat over
    seq_chunk slices) so the (B, S, V) logits tensor never materializes —
    for a 262k vocab at 4k seq that is the difference between ~15 GB and
    ~0.5 GB of per-device loss temporaries."""
    x, aux = backbone(params, batch, cfg)               # (B, S, d)
    labels = batch["labels"]
    if cfg.frontend is not None and "frontend_embeds" in batch:
        F = batch["frontend_embeds"].shape[1]
        pad = jnp.full(labels.shape[:1] + (F,), -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)

    B, S, d = x.shape
    chunk = min(seq_chunk, S)
    n_chunks = S // chunk if S % chunk == 0 else 1
    chunk = S // n_chunks

    @jax.checkpoint
    def chunk_nll(x_c, y_c):
        logits = lm_head_apply(params.get("head"), params["embed"], x_c, cfg)
        logits = wlc(logits, ("batch", "seq", "vocab")).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(y_c, 0)[..., None], axis=-1)[..., 0]
        mask = (y_c >= 0).astype(jnp.float32)
        return ((logz - gold) * mask).sum(), mask.sum()

    def scan_fn(carry, xs):
        nll_sum, cnt = carry
        n, c = chunk_nll(*xs)
        return (nll_sum + n, cnt + c), None

    xs = (x.reshape(B, n_chunks, chunk, d).swapaxes(0, 1),
          labels.reshape(B, n_chunks, chunk).swapaxes(0, 1))
    (nll, cnt), _ = jax.lax.scan(
        scan_fn, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        xs)
    return nll / jnp.maximum(cnt, 1.0) + aux_weight * aux


# ---------------------------------------------------------------------------
# decode with caches
# ---------------------------------------------------------------------------
def init_caches(cfg: ModelConfig, batch: int, max_len: int, dtype):
    caches: Dict[str, Any] = {}
    if cfg.scan_reps > 0:
        def stack(tree):
            return jax.tree.map(
                lambda a: jnp.zeros((cfg.scan_reps,) + a.shape, a.dtype), tree)
        caches["scan"] = {
            f"pos{i}": stack(init_block_cache(kind, cfg, batch, max_len,
                                              dtype))
            for i, kind in enumerate(cfg.layer_pattern)}
    caches["rem"] = {f"rem{i}": init_block_cache(kind, cfg, batch, max_len,
                                                 dtype)
                     for i, kind in enumerate(cfg.remainder_pattern)}
    return caches


def _run_with_caches(params, x, caches, cache_len, cfg: ModelConfig,
                     unroll: bool = False):
    """unroll=True (decode): python-loop over repetitions with per-layer
    dynamic_update_slice into the stacked cache buffers — XLA aliases the
    donated cache in place, where a lax.scan would copy the full stacked
    cache through xs/ys (measured: +16 GB of temps on decode_32k)."""
    shared = params.get("shared")

    def rep_fn(x, stacked_slice, cache_slice):
        new_cache_slice = {}
        for i, kind in enumerate(cfg.layer_pattern):
            x, nc, _ = block_apply(kind, cfg, stacked_slice[f"pos{i}"], x,
                                   shared_params=shared,
                                   cache=cache_slice[f"pos{i}"],
                                   cache_len=cache_len)
            new_cache_slice[f"pos{i}"] = nc
        return x, new_cache_slice

    new_caches: Dict[str, Any] = {}
    if cfg.scan_reps > 0:
        if unroll:
            big = caches["scan"]
            for r in range(cfg.scan_reps):
                p_r = jax.tree.map(
                    lambda a: jax.lax.index_in_dim(a, r, keepdims=False),
                    params["scan"])
                c_r = jax.tree.map(
                    lambda a: jax.lax.index_in_dim(a, r, keepdims=False),
                    big)
                x, nc = rep_fn(x, p_r, c_r)
                big = jax.tree.map(
                    lambda full, new: jax.lax.dynamic_update_index_in_dim(
                        full, new.astype(full.dtype), r, 0), big, nc)
            new_caches["scan"] = big
        else:
            x, new_caches["scan"] = jax.lax.scan(
                lambda xx, xs: rep_fn(xx, xs[0], xs[1]), x,
                (params["scan"], caches["scan"]))
    new_caches["rem"] = {}
    for i, kind in enumerate(cfg.remainder_pattern):
        x, nc, _ = block_apply(kind, cfg, params["rem"][f"rem{i}"], x,
                               shared_params=shared,
                               cache=caches["rem"][f"rem{i}"],
                               cache_len=cache_len)
        new_caches["rem"][f"rem{i}"] = nc
    return x, new_caches


def decode_step(params, tokens: jax.Array, caches, cache_len,
                cfg: ModelConfig):
    """tokens: (B, 1) -> (logits (B,1,V), new_caches).  cache_len: () int32
    = number of positions already in the caches."""
    x = embed_tokens(params["embed"], tokens, cfg)
    x = x * jnp.sqrt(float(cfg.d_model)).astype(x.dtype)
    x = wlc(x, ("batch", None, "embed_act"))
    x, new_caches = _run_with_caches(params, x, caches, cache_len, cfg)
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_head_apply(params.get("head"), params["embed"], x, cfg)
    return logits, new_caches


def prefill(params, batch: Dict[str, jax.Array], caches, cfg: ModelConfig):
    """Fill caches from a fresh sequence; returns (logits, new_caches)."""
    x = _embed_inputs(params, batch, cfg)
    x, new_caches = _run_with_caches(params, x, caches,
                                     jnp.zeros((), jnp.int32), cfg)
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_head_apply(params.get("head"), params["embed"], x, cfg)
    return logits, new_caches


__all__ = ["model_spec", "init", "n_params", "n_active_params", "forward",
           "loss_fn", "init_caches", "decode_step", "prefill"]
