"""Attention: GQA/MHA with RoPE, optional qk-norm, sliding window, logit
softcap; blockwise (flash-style) online-softmax for train/prefill so 32k x
32k score matrices never materialize; KV-cache decode with a
sharding-friendly masked softmax (GSPMD inserts the flash-decoding partial
combine when the cache's sequence axis is sharded — context parallelism
for long_500k).

Windowed ("local") layers use a *ring-buffer* KV cache of exactly
``window`` slots, so a 524k-context decode only ever holds window-sized
caches for local layers — the mechanism that makes gemma3/mixtral/h2o
long_500k cells feasible (DESIGN.md §5).

Head-count padding for tensor parallelism is resolved in the config
(``resolve_for_tp``; exactness argument there)."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.rules import ParamSpec, with_logical_constraint as wlc
from .layers import apply_rope, rms_norm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------
def attention_spec(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, h, kv, hd = cfg.d_model, cfg.eff_heads, cfg.eff_kv_heads, cfg.head_dim
    spec = {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", None)),
        "wk": ParamSpec((d, kv, hd), ("embed", "kv_heads", None)),
        "wv": ParamSpec((d, kv, hd), ("embed", "kv_heads", None)),
        "wo": ParamSpec((h, hd, d), ("heads", None, "embed")),
    }
    if cfg.qk_norm:
        spec["q_norm"] = ParamSpec((hd,), (None,), init="ones")
        spec["k_norm"] = ParamSpec((hd,), (None,), init="ones")
    return spec


# ---------------------------------------------------------------------------
# blockwise (flash) attention for train/prefill
# ---------------------------------------------------------------------------
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    q_offset=0, window: Optional[int] = None,
                    softcap: float = 0.0, kv_chunk: int = 1024) -> jax.Array:
    """Online-softmax attention.

    q: (B, Sq, KV, G, Dh) — query heads grouped by kv head;
    k, v: (B, Sk, KV, Dh).  Returns (B, Sq, KV, G, Dh).
    The kv axis is scanned in ``kv_chunk`` blocks carrying (m, l, acc)."""
    B, Sq, KV, G, Dh = q.shape
    Sk = k.shape[1]
    kv_chunk = min(kv_chunk, Sk)
    assert Sk % kv_chunk == 0, (Sk, kv_chunk)
    n_chunks = Sk // kv_chunk
    scale = 1.0 / jnp.sqrt(jnp.asarray(Dh, jnp.float32))

    q32 = q.astype(jnp.float32) * scale
    q_pos = q_offset + jnp.arange(Sq)

    k_c = k.reshape(B, n_chunks, kv_chunk, KV, Dh)
    v_c = v.reshape(B, n_chunks, kv_chunk, KV, Dh)

    def step(carry, xs):
        m_prev, l_prev, acc = carry
        kc, vc, c_idx = xs                       # (B, kv_chunk, KV, Dh)
        s = jnp.einsum("bqkgd,bskd->bqkgs", q32, kc.astype(jnp.float32))
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = c_idx * kv_chunk + jnp.arange(kv_chunk)
        mask = q_pos[:, None] >= k_pos[None, :]   # causal (Sq, kv_chunk)
        if window is not None:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_cur = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[..., None])
        l_cur = l_prev * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bqkgs,bskd->bqkgd", p, vc.astype(jnp.float32))
        acc = acc * alpha[..., None] + pv
        return (m_cur, l_cur, acc), None

    m0 = jnp.full((B, Sq, KV, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, KV, G), jnp.float32)
    acc0 = jnp.zeros((B, Sq, KV, G, Dh), jnp.float32)
    xs = (jnp.moveaxis(k_c, 1, 0), jnp.moveaxis(v_c, 1, 0),
          jnp.arange(n_chunks))
    # checkpoint the chunk body: backward recomputes the (Sq x kv_chunk)
    # score/probability tensors instead of saving them — the flash-attention
    # backward.  Without this, train-step peak memory is dominated by saved
    # f32 p-tensors (observed ~40 GB/device on gemma3 train_4k).
    (m_f, l_f, acc), _ = jax.lax.scan(jax.checkpoint(step), (m0, l0, acc0),
                                      xs)
    out = acc / jnp.maximum(l_f, 1e-30)[..., None]
    return out.astype(q.dtype)


def flash_attention_swa(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        window: int, softcap: float = 0.0,
                        q_chunk: int = 1024) -> jax.Array:
    """Banded flash attention for sliding-window layers (§Perf).

    The plain flash path scans ALL KV chunks and masks — O(S^2) compute
    even though each query only sees ``window`` keys.  Here the *query*
    axis is scanned in ``q_chunk`` blocks and each block attends a
    static-width ``window + q_chunk`` KV slice fetched with
    dynamic_slice — O(S*(W+C)) compute: ~6.4x fewer attention FLOPs on a
    32k prefill with W=4096, C=1024.

    q: (B, Sq, KV, G, Dh); k, v: (B, Sk, KV, Dh); Sq == Sk."""
    B, Sq, KV, G, Dh = q.shape
    Sk = k.shape[1]
    q_chunk = min(q_chunk, Sq)
    assert Sq % q_chunk == 0, (Sq, q_chunk)
    n_q = Sq // q_chunk
    band = min(window + q_chunk, Sk)

    q_c = jnp.moveaxis(q.reshape(B, n_q, q_chunk, KV, G, Dh), 1, 0)

    def one_block(qi_and_block):
        qi, q_blk = qi_and_block                    # (), (B,C,KV,G,Dh)
        q_start = qi * q_chunk
        k_start = jnp.clip(q_start + q_chunk - band, 0, Sk - band)
        k_blk = jax.lax.dynamic_slice_in_dim(k, k_start, band, axis=1)
        v_blk = jax.lax.dynamic_slice_in_dim(v, k_start, band, axis=1)
        # flash masks causality/window from absolute positions via q_offset
        return flash_attention(q_blk, k_blk, v_blk,
                               q_offset=q_start - k_start, window=window,
                               softcap=softcap, kv_chunk=band)

    out = jax.lax.map(one_block, (jnp.arange(n_q), q_c))
    return jnp.moveaxis(out, 0, 1).reshape(B, Sq, KV, G, Dh)


# ---------------------------------------------------------------------------
# decode attention over a KV cache (full or ring)
# ---------------------------------------------------------------------------
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     key_pos: jax.Array, q_pos: jax.Array, *,
                     window: Optional[int] = None,
                     softcap: float = 0.0) -> jax.Array:
    """q: (B, 1, KV, G, Dh); caches: (B, Smax, KV, Dh).

    ``key_pos`` (B, Smax) gives the absolute position stored in each cache
    slot (-1 = empty) — uniform treatment of linear and ring caches and of
    per-sequence lengths (continuous batching).  ``q_pos``: (B,).
    Masked max/exp/sum form so GSPMD can shard Smax (context parallelism)
    and synthesize the flash-decoding partial combine."""
    B, _, KV, G, Dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(Dh, jnp.float32))
    q32 = q[:, 0].astype(jnp.float32) * scale          # (B, KV, G, Dh)
    s = jnp.einsum("bkgd,bskd->bkgs", q32, k_cache.astype(jnp.float32))
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    valid = (key_pos >= 0) & (key_pos <= q_pos[:, None])
    if window is not None:
        valid &= key_pos > (q_pos[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskd->bkgd", p / jnp.maximum(l, 1e-30),
                     v_cache.astype(jnp.float32))
    return out[:, None].astype(q.dtype)                 # (B, 1, KV, G, Dh)


# ---------------------------------------------------------------------------
# KV cache construction
# ---------------------------------------------------------------------------
def kv_cache_len(cfg: ModelConfig, kind: str, max_len: int) -> int:
    """Ring caches for windowed layers: bounded at the window size."""
    if kind in ("local", "local_moe") and cfg.window:
        return min(cfg.window, max_len)
    return max_len


def init_kv_cache(cfg: ModelConfig, batch: int, slots: int, dtype,
                  quant: Optional[bool] = None):
    """quant=True: int8 cache with per-(token, head) bf16 scales — the
    production serving layout (halves KV bytes; ~0.3% attention error)."""
    kv, hd = cfg.eff_kv_heads, cfg.head_dim
    quant = cfg.kv_quant if quant is None else quant
    if quant:
        return {
            "k": jnp.zeros((batch, slots, kv, hd), jnp.int8),
            "k_s": jnp.zeros((batch, slots, kv), jnp.bfloat16),
            "v": jnp.zeros((batch, slots, kv, hd), jnp.int8),
            "v_s": jnp.zeros((batch, slots, kv), jnp.bfloat16),
        }
    return {
        "k": jnp.zeros((batch, slots, kv, hd), dtype),
        "v": jnp.zeros((batch, slots, kv, hd), dtype),
    }


def _quantize_kv(x: jax.Array):
    """x (..., hd) -> (int8 codes, bf16 scales (...))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def _dequantize_kv(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)


# ---------------------------------------------------------------------------
# attention block apply (projections + rope + attn + out)
# ---------------------------------------------------------------------------
def attention_apply(params, x: jax.Array, cfg: ModelConfig, *,
                    window: Optional[int] = None,
                    rope_theta: Optional[float] = None,
                    cache: Optional[Dict[str, Any]] = None,
                    cache_len=None) -> Tuple[jax.Array, Optional[Dict]]:
    """x: (B, S, d).

    * cache is None             -> train/prefill-no-cache (flash path);
    * cache given, S == 1       -> single-token decode at position cache_len;
    * cache given, S > 1        -> prefill-and-fill-cache (fresh sequence).
    Ring caches (slots == window < needed length) are handled transparently.
    """
    ct = cfg.compute_dtype
    B, S, _ = x.shape
    KV, G, Dh = cfg.eff_kv_heads, cfg.q_per_kv, cfg.head_dim
    theta = rope_theta if rope_theta is not None else cfg.rope_theta

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(ct))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(ct))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(ct))
    if cfg.qk_norm:
        q = rms_norm({"scale": params["q_norm"]}, q, cfg.norm_eps)
        k = rms_norm({"scale": params["k_norm"]}, k, cfg.norm_eps)

    if cache is None:
        positions = jnp.arange(S)
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
        q = wlc(q, ("batch", "seq", "heads", None))
        k = wlc(k, ("batch", "seq", "kv_heads", None))
        qg = q.reshape(B, S, KV, G, Dh)
        if cfg.swa_banded and window is not None and \
                window + cfg.flash_kv_chunk < S:
            # banded path: skip fully-masked chunks (§Perf; see cfg note)
            out = flash_attention_swa(qg, k, v, window=window,
                                      softcap=cfg.attn_logit_softcap,
                                      q_chunk=cfg.flash_kv_chunk)
        else:
            out = flash_attention(qg, k, v, window=window,
                                  softcap=cfg.attn_logit_softcap,
                                  kv_chunk=cfg.flash_kv_chunk)
        new_cache = None
    elif S == 1:
        # cache_len: () shared length, or (B,) per-sequence lengths
        # (continuous batching)
        quant = "k_s" in cache
        pos_b = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (B,))
        q = apply_rope(q, pos_b[:, None], theta)
        k = apply_rope(k, pos_b[:, None], theta)
        slots = cache["k"].shape[1]
        slot_b = pos_b % slots                            # ring-aware write
        bidx = jnp.arange(B)
        new_cache = dict(cache)
        if quant:
            kq, ks = _quantize_kv(k[:, 0])
            vq, vs = _quantize_kv(v[:, 0])
            new_cache["k"] = cache["k"].at[bidx, slot_b].set(kq)
            new_cache["k_s"] = cache["k_s"].at[bidx, slot_b].set(ks)
            new_cache["v"] = cache["v"].at[bidx, slot_b].set(vq)
            new_cache["v_s"] = cache["v_s"].at[bidx, slot_b].set(vs)
            k_read = _dequantize_kv(new_cache["k"], new_cache["k_s"])
            v_read = _dequantize_kv(new_cache["v"], new_cache["v_s"])
        else:
            new_cache["k"] = cache["k"].at[bidx, slot_b].set(
                k[:, 0].astype(cache["k"].dtype))
            new_cache["v"] = cache["v"].at[bidx, slot_b].set(
                v[:, 0].astype(cache["v"].dtype))
            k_read, v_read = new_cache["k"], new_cache["v"]
        # absolute position held by each slot after the write
        idx = jnp.arange(slots)
        key_pos = pos_b[:, None] - ((pos_b[:, None] - idx[None, :]) % slots)
        qg = q.reshape(B, 1, KV, G, Dh)
        out = decode_attention(qg, k_read, v_read, key_pos, pos_b,
                               window=window,
                               softcap=cfg.attn_logit_softcap)
    else:
        # prefill a fresh sequence AND fill the cache with the last `slots`
        quant = "k_s" in cache
        positions = jnp.arange(S)
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
        qg = q.reshape(B, S, KV, G, Dh)
        if cfg.swa_banded and window is not None and \
                window + cfg.flash_kv_chunk < S:
            out = flash_attention_swa(qg, k, v, window=window,
                                      softcap=cfg.attn_logit_softcap,
                                      q_chunk=cfg.flash_kv_chunk)
        else:
            out = flash_attention(qg, k, v, window=window,
                                  softcap=cfg.attn_logit_softcap,
                                  kv_chunk=cfg.flash_kv_chunk)
        slots = cache["k"].shape[1]
        if quant:
            k_w, k_sw = _quantize_kv(k)       # (B,S,KV,hd), (B,S,KV)
            v_w, v_sw = _quantize_kv(v)
            writes = {"k": k_w, "k_s": k_sw, "v": v_w, "v_s": v_sw}
        else:
            writes = {"k": k.astype(cache["k"].dtype),
                      "v": v.astype(cache["v"].dtype)}
        new_cache = dict(cache)
        for name, val in writes.items():
            if slots >= S:
                new_cache[name] = jax.lax.dynamic_update_slice_in_dim(
                    cache[name], val.astype(cache[name].dtype), 0, axis=1)
            else:  # ring: keep the last `slots` positions at ring slots
                ring_slots = positions[S - slots:] % slots
                new_cache[name] = cache[name].at[:, ring_slots].set(
                    val[:, S - slots:].astype(cache[name].dtype))

    out = out.reshape(B, S, cfg.eff_heads, Dh)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(ct))
    return wlc(y, ("batch", "seq_sp" if cfg.use_seq_sp else "seq", "embed_act")), new_cache


__all__ = ["attention_spec", "attention_apply", "flash_attention",
           "decode_attention", "init_kv_cache", "kv_cache_len", "NEG_INF"]
