"""Telemetry exporters: event sinks, Prometheus text, trace validation.

Three consumption paths for one :class:`~repro.obs.telemetry.Telemetry`:

* **JSONL event stream** (:class:`JsonlSink`) — every finished span and
  point event as one JSON object per line, written through as it
  happens (a crash keeps everything up to the last event).  This is the
  replay format ``python -m repro.obs summarize`` reads, and the raw
  material for the ROADMAP's learned-cost-model and drift-detector
  items.
* **In-memory** (:class:`InMemorySink`) — the test double; also what a
  notebook uses to poke at a session's events.
* **Prometheus text exposition** (:func:`prometheus_text`) — counters,
  gauges, and cumulative ``le``-bucket histograms in the standard
  scrape format, for wiring a long-lived :class:`~repro.serve.SpMVService`
  into a fleet metrics pipeline.

:func:`validate_chrome_trace` is the schema check used by tests, the
CLI, and CI on exported Chrome traces.
"""
from __future__ import annotations

import json
import re
from typing import Any, Dict, IO, List, Optional

from .tracing import as_jsonable


class InMemorySink:
    """Collects every emitted record; ``spans()``/``events()`` filter by
    record type, ``named(name)`` by event/span name."""

    def __init__(self):
        self.records: List[Dict[str, Any]] = []

    def emit(self, rec: Dict[str, Any]) -> None:
        self.records.append(rec)

    def spans(self) -> List[Dict[str, Any]]:
        return [r for r in self.records if r.get("type") == "span"]

    def events(self) -> List[Dict[str, Any]]:
        return [r for r in self.records if r.get("type") == "event"]

    def named(self, name: str) -> List[Dict[str, Any]]:
        return [r for r in self.records if r.get("name") == name]

    def close(self) -> None:
        pass


class JsonlSink:
    """One JSON object per line, flushed per record.

    The file opens lazily on the first record (constructing a sink never
    touches the filesystem) and truncates — each process run is one
    fresh event log."""

    def __init__(self, path: str):
        self.path = path
        self._f: Optional[IO[str]] = None

    def emit(self, rec: Dict[str, Any]) -> None:
        if self._f is None:
            self._f = open(self.path, "w")
        self._f.write(json.dumps(rec, default=as_jsonable) + "\n")
        self._f.flush()

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None


def read_jsonl(path: str) -> List[Dict[str, Any]]:
    """Load a JSONL event stream (skips blank lines, raises on corrupt
    ones with the offending line number)."""
    out: List[Dict[str, Any]] = []
    with open(path) as f:
        for i, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i}: corrupt JSONL record: {e}") \
                    from e
    return out


# ---------------------------------------------------------------------------
# Prometheus text exposition
# ---------------------------------------------------------------------------
_PROM_NAME = re.compile(r"[^a-zA-Z0-9_:]")
_PROM_TYPES = {"counter": "counter", "gauge": "gauge",
               "histogram": "histogram"}


def _prom_name(name: str) -> str:
    n = _PROM_NAME.sub("_", name)
    return n if not n[:1].isdigit() else "_" + n


def _prom_labels(labels: Dict[str, str], extra: str = "") -> str:
    parts = [f'{_prom_name(k)}="{v}"' for k, v in sorted(labels.items())]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def _fmt(v: float) -> str:
    return repr(float(v)) if v != int(v) else str(int(v))


def prometheus_text(tel: Any) -> str:
    """Standard text exposition of every registered metric.  Histograms
    emit cumulative ``le`` buckets (including ``+Inf``) plus ``_sum`` and
    ``_count``, so any Prometheus-compatible scraper ingests the same
    latency data ``stats()`` summarizes."""
    by_name: Dict[str, List] = {}
    kinds: Dict[str, str] = {}
    for kind, name, labels, m in tel.metrics():
        by_name.setdefault(name, []).append((labels, m))
        kinds[name] = kind
    lines: List[str] = []
    for name in sorted(by_name):
        kind = kinds[name]
        pname = _prom_name(name)
        lines.append(f"# TYPE {pname} {_PROM_TYPES[kind]}")
        for labels, m in by_name[name]:
            if kind in ("counter", "gauge"):
                lines.append(f"{pname}{_prom_labels(labels)} {_fmt(m.value)}")
                continue
            cum = 0
            for edge, c in zip(list(m.edges) + ["+Inf"], m.counts):
                cum += c
                le_v = "+Inf" if edge == "+Inf" else repr(float(edge))
                le = 'le="%s"' % le_v
                lines.append(f"{pname}_bucket{_prom_labels(labels, le)} "
                             f"{cum}")
            lines.append(f"{pname}_sum{_prom_labels(labels)} {_fmt(m.sum)}")
            lines.append(f"{pname}_count{_prom_labels(labels)} {m.count}")
    return "\n".join(lines) + ("\n" if lines else "")


# ---------------------------------------------------------------------------
# Chrome trace validation
# ---------------------------------------------------------------------------
def validate_chrome_trace(obj: Any) -> List[str]:
    """Schema check for an exported Chrome trace: returns a list of
    human-readable problems (empty = valid).  Checks the shape
    ``chrome://tracing``/Perfetto actually require: a ``traceEvents``
    array of complete events with string names, numeric ``ts``/``dur``,
    integer ``pid``/``tid``, and JSON-object ``args``."""
    errors: List[str] = []
    if not isinstance(obj, dict):
        return [f"top level must be an object, got {type(obj).__name__}"]
    evs = obj.get("traceEvents")
    if not isinstance(evs, list):
        return ["missing or non-array 'traceEvents'"]
    for i, ev in enumerate(evs):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        if not isinstance(ev.get("name"), str):
            errors.append(f"{where}: missing string 'name'")
        if ev.get("ph") not in ("X", "B", "E", "i", "C", "M"):
            errors.append(f"{where}: unknown phase {ev.get('ph')!r}")
        for k in ("ts",) + (("dur",) if ev.get("ph") == "X" else ()):
            if not isinstance(ev.get(k), (int, float)) \
                    or isinstance(ev.get(k), bool):
                errors.append(f"{where}: missing numeric {k!r}")
        for k in ("pid", "tid"):
            if not isinstance(ev.get(k), int) or isinstance(ev.get(k), bool):
                errors.append(f"{where}: missing integer {k!r}")
        if "args" in ev and not isinstance(ev["args"], dict):
            errors.append(f"{where}: 'args' must be an object")
        if errors[20:]:
            errors.append("... (truncated)")
            break
    return errors


def save_chrome_trace(tel: Any, path: str) -> None:
    """Dump a telemetry's spans as a Chrome trace JSON file."""
    with open(path, "w") as f:
        json.dump(tel.to_chrome_trace(), f, default=as_jsonable)


__all__ = ["InMemorySink", "JsonlSink", "read_jsonl", "prometheus_text",
           "validate_chrome_trace", "save_chrome_trace"]
