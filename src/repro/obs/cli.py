"""``python -m repro.obs`` — inspect telemetry streams and saved plans.

Subcommands (all pure-stdlib; none import jax, so the CLI is fast and
usable on machines without the accelerator stack):

* ``summarize <events.jsonl>`` — per-span latency percentiles, the
  decision table (which rule fired, where on the D_mat axis), tune
  winners, offline t_trans/t_crs/t_f measurements, and serving flush /
  plan-replay counts from one JSONL event stream.
* ``validate <trace.json>`` — Chrome-trace schema check; exit code 1 on
  violations (what CI runs on the quickstart trace artifact).
* ``plan <plan.json>`` — pretty-print a saved ``ExecutionPlan`` (the
  ROADMAP's plan-inspection CLI).
* ``diff <a.json> <b.json>`` — field-by-field diff of two plans; exit
  code 1 when they differ.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from .export import read_jsonl, validate_chrome_trace
from .telemetry import percentile


def _table(headers: Sequence[str], rows: Iterable[Sequence[Any]],
           out) -> None:
    rows = [[str(c) for c in r] for r in rows]
    widths = [max([len(h)] + [len(r[i]) for r in rows])
              for i, h in enumerate(headers)]
    fmt = "  ".join(f"{{:<{w}}}" for w in widths)
    print(fmt.format(*headers), file=out)
    print(fmt.format(*("-" * w for w in widths)), file=out)
    for r in rows:
        print(fmt.format(*r), file=out)


def _us(seconds: float) -> str:
    return f"{seconds * 1e6:.1f}"


def _attr(rec: Dict[str, Any], key: str, default: Any = "") -> Any:
    return (rec.get("attrs") or {}).get(key, default)


# ---------------------------------------------------------------------------
# summarize
# ---------------------------------------------------------------------------
def summarize(path: str, out=None) -> int:
    out = out or sys.stdout
    records = read_jsonl(path)
    spans = [r for r in records if r.get("type") == "span"]
    events = [r for r in records if r.get("type") == "event"]
    print(f"{path}: {len(spans)} spans, {len(events)} events", file=out)

    # -- span latency percentiles -------------------------------------------
    by_name: Dict[str, List[float]] = defaultdict(list)
    for s in spans:
        key = s["name"]
        fmt = _attr(s, "fmt")
        if fmt:
            key = f"{s['name']}[{fmt}]"
        by_name[key].append(float(s.get("dur", 0.0)))
    if by_name:
        print("\n== span latency (us) ==", file=out)
        _table(
            ("span", "count", "total_ms", "p50", "p90", "p99", "max"),
            [(name, len(ds), f"{sum(ds) * 1e3:.2f}",
              _us(percentile(ds, 0.50)), _us(percentile(ds, 0.90)),
              _us(percentile(ds, 0.99)), _us(max(ds)))
             for name, ds in sorted(by_name.items())], out)

    # -- decision table (the replayable D_mat–R points) ---------------------
    decisions = [e for e in events if e["name"] == "plan.decision"]
    if decisions:
        groups: Dict[Tuple[str, str], List[Dict]] = defaultdict(list)
        for e in decisions:
            groups[(str(_attr(e, "rule")), str(_attr(e, "fmt")))].append(e)
        print("\n== plan decisions ==", file=out)
        _table(
            ("rule", "fmt", "count", "d_mat_min", "d_mat_max", "d_star"),
            [(rule, fmt, len(es),
              f"{min(float(_attr(e, 'd_mat', 0) or 0) for e in es):.3f}",
              f"{max(float(_attr(e, 'd_mat', 0) or 0) for e in es):.3f}",
              _fmt_opt(_attr(es[-1], "d_star", None)))
             for (rule, fmt), es in sorted(groups.items())], out)

    # -- offline measurements (paper quantities) ----------------------------
    measures = [e for e in events if e["name"] == "offline.measure"]
    if measures:
        print("\n== offline measurements (t in us) ==", file=out)
        _table(
            ("matrix", "fmt", "batch", "t_crs", "t_f", "t_trans", "r"),
            [(_attr(e, "matrix"), _attr(e, "fmt"), _attr(e, "batch", 1),
              _us(float(_attr(e, "t_crs", 0))),
              _us(float(_attr(e, "t_f", 0))),
              _us(float(_attr(e, "t_trans", 0))),
              f"{float(_attr(e, 'r', 0)):.3f}")
             for e in measures], out)

    # -- tune winners -------------------------------------------------------
    winners = [e for e in events if e["name"] == "tune.winner"]
    if winners:
        print("\n== tune winners ==", file=out)
        _table(
            ("fmt", "op", "batch", "t_best_us", "t_default_us", "speedup",
             "geometry"),
            [(_attr(e, "fmt"), _attr(e, "op"), _attr(e, "batch", 1),
              _us(float(_attr(e, "t_best", 0))),
              _us(float(_attr(e, "t_default", 0))),
              f"{float(_attr(e, 'speedup', 1)):.2f}x",
              json.dumps(_attr(e, "geometry", {})))
             for e in winners], out)

    # -- serving ------------------------------------------------------------
    flushes = [e for e in events if e["name"] == "service.flush"]
    if flushes:
        causes: Dict[str, int] = defaultdict(int)
        vectors: Dict[str, int] = defaultdict(int)
        for e in flushes:
            causes[str(_attr(e, "cause"))] += 1
            vectors[str(_attr(e, "cause"))] += int(_attr(e, "batch", 0) or 0)
        print("\n== service flushes ==", file=out)
        _table(("cause", "flushes", "vectors"),
               [(c, causes[c], vectors[c]) for c in sorted(causes)], out)
    replays = [e for e in events if e["name"] == "service.plan_replay"]
    if replays:
        hits = sum(1 for e in replays if _attr(e, "hit"))
        print(f"\nplan replays: {hits} hit / {len(replays) - hits} miss",
              file=out)
    return 0


def _fmt_opt(v: Any) -> str:
    if v is None or v == "":
        return "-"
    try:
        return f"{float(v):.3f}"
    except (TypeError, ValueError):
        return str(v)


# ---------------------------------------------------------------------------
# validate
# ---------------------------------------------------------------------------
def validate(path: str, out=None) -> int:
    out = out or sys.stdout
    with open(path) as f:
        try:
            obj = json.load(f)
        except json.JSONDecodeError as e:
            print(f"{path}: not valid JSON: {e}", file=out)
            return 1
    errors = validate_chrome_trace(obj)
    if errors:
        for e in errors:
            print(f"{path}: {e}", file=out)
        return 1
    n = len(obj["traceEvents"])
    print(f"{path}: valid Chrome trace ({n} events)", file=out)
    return 0


# ---------------------------------------------------------------------------
# plan pretty-print + diff (raw JSON — no jax import)
# ---------------------------------------------------------------------------
def _load_plan(path: str) -> Dict[str, Any]:
    with open(path) as f:
        obj = json.load(f)
    if not isinstance(obj, dict) or "fmt" not in obj:
        raise ValueError(f"{path}: not an ExecutionPlan JSON "
                         "(missing 'fmt')")
    return obj


def show_plan(path: str, out=None) -> int:
    out = out or sys.stdout
    p = _load_plan(path)
    print(f"ExecutionPlan (schema v{p.get('schema_version', '?')}) "
          f"— {path}", file=out)
    for k in ("fmt", "rule", "tier", "batch", "expected_iterations",
              "machine", "d_mat", "d_star", "expected_gain"):
        if k in p:
            print(f"  {k:<20} {p[k]}", file=out)
    tr = p.get("transform") or {}
    print(f"  {'transform':<20} {tr.get('name')} "
          f"{json.dumps(tr.get('params', {}))}", file=out)
    for op, g in (p.get("geometry") or {}).items():
        print(f"  {'geometry.' + op:<20} {json.dumps(g)}", file=out)
    fp = p.get("fingerprint")
    if fp:
        print(f"  {'fingerprint':<20} n={fp.get('n')} nnz={fp.get('nnz')} "
              f"d_mat={fp.get('d_mat')} sig={fp.get('sig')}", file=out)
    blocks = p.get("blocks")
    if blocks:
        print(f"  blocks ({len(blocks)}):", file=out)
        _table(("rows", "fmt", "rule", "d_mat", "geometry"),
               [(f"{b['rows'][0]}:{b['rows'][1]}", b["plan"].get("fmt"),
                 b["plan"].get("rule"),
                 _fmt_opt(b["plan"].get("d_mat")),
                 json.dumps(b["plan"].get("geometry", {})))
                for b in blocks], out)
    return 0


def _flatten(obj: Any, prefix: str = "") -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(_flatten(v, f"{prefix}.{k}" if prefix else str(k)))
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            out.update(_flatten(v, f"{prefix}[{i}]"))
    else:
        out[prefix] = obj
    return out


def diff_plans(path_a: str, path_b: str, out=None) -> int:
    out = out or sys.stdout
    fa, fb = _flatten(_load_plan(path_a)), _flatten(_load_plan(path_b))
    keys = sorted(set(fa) | set(fb))
    rows = []
    for k in keys:
        va = fa.get(k, "<absent>")
        vb = fb.get(k, "<absent>")
        if va != vb:
            rows.append((k, va, vb))
    if not rows:
        print(f"plans identical ({len(keys)} fields)", file=out)
        return 0
    print(f"{len(rows)} of {len(keys)} fields differ:", file=out)
    _table(("field", path_a, path_b), rows, out)
    return 1


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------
def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Summarize telemetry streams; inspect/diff saved "
                    "ExecutionPlan JSON.")
    sub = ap.add_subparsers(dest="cmd", required=True)
    sub.add_parser("summarize", help="summarize a telemetry JSONL") \
        .add_argument("path")
    sub.add_parser("validate", help="validate a Chrome trace JSON") \
        .add_argument("path")
    sub.add_parser("plan", help="pretty-print an ExecutionPlan JSON") \
        .add_argument("path")
    d = sub.add_parser("diff", help="diff two ExecutionPlan JSON files")
    d.add_argument("path_a")
    d.add_argument("path_b")
    args = ap.parse_args(argv)
    if args.cmd == "summarize":
        return summarize(args.path)
    if args.cmd == "validate":
        return validate(args.path)
    if args.cmd == "plan":
        return show_plan(args.path)
    return diff_plans(args.path_a, args.path_b)


__all__ = ["main", "summarize", "validate", "show_plan", "diff_plans"]
