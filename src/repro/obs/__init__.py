"""repro.obs — the observability substrate for tune → plan → serve.

One process-wide :class:`Telemetry` (counters, gauges, fixed-bucket
latency histograms, nested spans, point events) that the whole pipeline
reports into:

* ``KernelTuner`` — one span per candidate launch, a ``tune.winner``
  event per sweep (geometry + measured time);
* ``offline_phase`` / the ``decide_*`` rules / ``Planner`` — t_trans,
  t_crs, t_f per (matrix, format) and a ``plan.decision`` event naming
  the rule that fired, so every decision is a replayable point on the
  paper's D_mat–R graph;
* ``transform`` — a span per CRS→{COO,ELL,SELL,BCSR,CCS,hybrid} host
  conversion;
* ``dispatch`` — kernel-tier vs reference-tier resolution counters;
* ``SpMVService`` — per-key query-latency histograms, queue-depth
  gauges, flush-cause counters, plan-replay hit/miss.

Telemetry is **off by default** — the hot path pays one flag check.
Enable programmatically::

    from repro import obs
    sink = obs.InMemorySink()
    obs.enable(sink=sink)                  # or obs.enable(jsonl="run.jsonl")
    ... run the pipeline ...
    obs.get().snapshot()                   # the metrics dump
    obs.get().to_chrome_trace()            # chrome://tracing / Perfetto

or from the environment — ``REPRO_TRACE=<prefix>`` enables telemetry and,
at interpreter exit, leaves ``<prefix>.jsonl`` (event stream, written
through as it happens), ``<prefix>.trace.json`` (Chrome trace), and
``<prefix>.metrics.json`` (metrics snapshot).  ``REPRO_TELEMETRY=1``
enables collection with no files.

``python -m repro.obs`` summarizes event streams and pretty-prints/diffs
saved ``ExecutionPlan`` JSON.  See ``docs/observability.md`` for the
full event vocabulary.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, Optional

from .export import (InMemorySink, JsonlSink, prometheus_text, read_jsonl,
                     save_chrome_trace, validate_chrome_trace)
from .telemetry import (DEFAULT_LATENCY_EDGES, Counter, FakeClock, Gauge,
                        Histogram, Telemetry, format_metric, percentile)
from .tracing import NOOP_SPAN, Span, as_jsonable, chrome_trace

_default: Optional[Telemetry] = None
_default_lock = threading.Lock()


def get() -> Telemetry:
    """The process-wide default telemetry (created on first use; honours
    ``REPRO_TRACE`` / ``REPRO_TELEMETRY`` — see the module docstring)."""
    tel = _default
    if tel is None:
        with _default_lock:
            tel = _default
            if tel is None:
                tel = _from_env()
                _set(tel)
    return tel


def _set(tel: Telemetry) -> None:
    global _default
    _default = tel


def set_default(tel: Optional[Telemetry]) -> Optional[Telemetry]:
    """Swap the process-wide telemetry (``None`` resets to lazy env
    bootstrap); returns the previous one so tests can restore it."""
    with _default_lock:
        prev = _default
        _set(tel)
        return prev


def enable(sink: Any = None, jsonl: Optional[str] = None,
           clock: Any = None) -> Telemetry:
    """Turn the default telemetry on (optionally attaching a sink, a
    JSONL path, or a replacement clock) and return it."""
    tel = get()
    tel.enabled = True
    if clock is not None:
        tel.clock = clock
    if sink is not None:
        tel.sinks.append(sink)
    if jsonl is not None:
        tel.sinks.append(JsonlSink(jsonl))
    return tel


def disable() -> Telemetry:
    tel = get()
    tel.enabled = False
    return tel


def enabled() -> bool:
    return get().enabled


# -- delegating conveniences (what instrumented modules call) ---------------
def span(name: str, **attrs: Any):
    return get().span(name, **attrs)


def event(name: str, **attrs: Any) -> Optional[Dict[str, Any]]:
    return get().event(name, **attrs)


def counter(name: str, **labels: Any) -> Counter:
    return get().counter(name, **labels)


def gauge(name: str, **labels: Any) -> Gauge:
    return get().gauge(name, **labels)


def histogram(name: str, **labels: Any) -> Histogram:
    return get().histogram(name, **labels)


def _from_env() -> Telemetry:
    import os
    prefix = os.environ.get("REPRO_TRACE", "")
    flag = os.environ.get("REPRO_TELEMETRY", "")
    tel = Telemetry(enabled=bool(prefix) or flag not in ("", "0"))
    if prefix:
        import atexit
        import json
        tel.sinks.append(JsonlSink(prefix + ".jsonl"))

        def _dump(tel: Telemetry = tel, prefix: str = prefix) -> None:
            with open(prefix + ".trace.json", "w") as f:
                json.dump(tel.to_chrome_trace(), f, default=as_jsonable)
            with open(prefix + ".metrics.json", "w") as f:
                json.dump(tel.snapshot(), f, default=as_jsonable, indent=1)
            tel.close()

        atexit.register(_dump)
    return tel


__all__ = [
    # registry + primitives
    "Telemetry", "Counter", "Gauge", "Histogram", "FakeClock",
    "DEFAULT_LATENCY_EDGES", "Span", "NOOP_SPAN",
    # process-wide default + conveniences
    "get", "set_default", "enable", "disable", "enabled",
    "span", "event", "counter", "gauge", "histogram",
    # export
    "InMemorySink", "JsonlSink", "read_jsonl", "prometheus_text",
    "chrome_trace", "save_chrome_trace", "validate_chrome_trace",
    "as_jsonable", "format_metric", "percentile",
]
