"""Nested, attributed spans and their Chrome-trace export.

A span is one timed region (``tune.candidate``, ``transform``,
``service.flush``) with free-form attributes.  Spans nest per thread —
each completed span records the ``span_id`` of the span that was open
when it started — so a finished trace reconstructs the full call tree of
a tune sweep or a serving session.

Export target is the Chrome trace-event format (the ``traceEvents``
array of complete ``"ph": "X"`` events, microsecond timestamps), which
both ``chrome://tracing`` and Perfetto load directly; see
:func:`chrome_trace`.

This module is dependency-free (stdlib only) and knows nothing about the
rest of the library — :mod:`repro.obs.telemetry` owns the clock and the
span stack and calls into it.
"""
from __future__ import annotations

import os
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional


def as_jsonable(v: Any) -> Any:
    """Best-effort conversion of a span/event attribute to a
    JSON-serializable value (numpy scalars unwrap, ``to_dict``-able
    objects flatten, anything else falls back to ``repr``)."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    item = getattr(v, "item", None)
    if callable(item) and not getattr(v, "shape", None):
        try:
            return item()          # numpy / jax scalar
        except (TypeError, ValueError):
            pass                   # .item() that isn't the numpy protocol
    to_dict = getattr(v, "to_dict", None)
    if callable(to_dict):
        try:
            return as_jsonable(to_dict())
        except (TypeError, ValueError, KeyError, AttributeError):
            pass                   # fall through to the repr() fallback
    if isinstance(v, dict):
        return {str(k): as_jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple, set, frozenset)):
        return [as_jsonable(x) for x in v]
    return repr(v)


@dataclass
class Span:
    """One completed (or still-open) timed region."""
    name: str
    t_start: float
    attrs: Dict[str, Any] = field(default_factory=dict)
    span_id: int = 0
    parent_id: Optional[int] = None
    tid: int = 0
    t_end: Optional[float] = None

    @property
    def dur(self) -> float:
        return (self.t_end if self.t_end is not None else self.t_start) \
            - self.t_start

    def set(self, **attrs: Any) -> "Span":
        """Attach attributes (usable inside the ``with`` block or right
        after it — export reads attrs at dump time)."""
        self.attrs.update(attrs)
        return self

    def to_record(self) -> Dict[str, Any]:
        """The JSONL event-sink form of this span."""
        return {
            "type": "span", "name": self.name, "ts": self.t_start,
            "dur": self.dur, "span_id": self.span_id,
            "parent_id": self.parent_id, "tid": self.tid,
            "attrs": {k: as_jsonable(v) for k, v in self.attrs.items()},
        }


class _NoopSpan:
    """Shared do-nothing span: what ``Telemetry.span`` hands back when
    telemetry is disabled, so instrumented code pays only the flag check."""
    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc: Any) -> bool:
        return False

    def set(self, **attrs: Any) -> "_NoopSpan":
        return self


NOOP_SPAN = _NoopSpan()


class SpanContext:
    """The live context manager behind ``Telemetry.span`` (enabled path).

    Entering opens a :class:`Span` parented to the thread's innermost
    open span; exiting stamps the end time and hands the finished span to
    the telemetry registry (bounded buffer + sinks)."""
    __slots__ = ("_tel", "_name", "_attrs", "span")

    def __init__(self, tel: Any, name: str, attrs: Dict[str, Any]):
        self._tel = tel
        self._name = name
        self._attrs = attrs
        self.span: Optional[Span] = None

    def __enter__(self) -> Span:
        tel = self._tel
        stack = tel._span_stack()
        sp = Span(name=self._name, t_start=tel.clock(), attrs=self._attrs,
                  span_id=tel._next_id(),
                  parent_id=stack[-1].span_id if stack else None,
                  tid=threading.get_ident())
        stack.append(sp)
        self.span = sp
        return sp

    def __exit__(self, *exc: Any) -> bool:
        sp = self.span
        sp.t_end = self._tel.clock()
        stack = self._tel._span_stack()
        if stack and stack[-1] is sp:
            stack.pop()
        elif sp in stack:          # misnested exit: heal the stack
            stack.remove(sp)
        self._tel._finish_span(sp)
        return False


def chrome_trace(spans: Iterable[Span], pid: Optional[int] = None
                 ) -> Dict[str, Any]:
    """Chrome trace-event JSON (``chrome://tracing`` / Perfetto loadable).

    Each span becomes one complete (``"ph": "X"``) event; ``ts``/``dur``
    are microseconds on the telemetry clock's (arbitrary but shared)
    origin.  ``args`` carries the span attributes plus the span/parent
    ids so the tree survives the flat encoding."""
    pid = int(pid if pid is not None else os.getpid())
    events: List[Dict[str, Any]] = []
    for s in spans:
        args = {k: as_jsonable(v) for k, v in s.attrs.items()}
        args["span_id"] = s.span_id
        if s.parent_id is not None:
            args["parent_id"] = s.parent_id
        events.append({
            "name": s.name,
            "cat": s.name.split(".", 1)[0],
            "ph": "X",
            "ts": s.t_start * 1e6,
            "dur": max(s.dur, 0.0) * 1e6,
            "pid": pid,
            "tid": int(s.tid),
            "args": args,
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


__all__ = ["Span", "SpanContext", "NOOP_SPAN", "chrome_trace",
           "as_jsonable"]
