"""Process-wide telemetry registry: counters, gauges, histograms, spans.

The paper's whole method runs on measured quantities — t_trans, t_crs,
t_f, and the D_mat–R graph built from them — so the library's own
measurements must be first-class data, not local variables that die at
function exit.  :class:`Telemetry` is that substrate: a dependency-free
(stdlib-only) registry the tune → plan → serve pipeline reports into.

Design points:

* **Default-off.**  ``Telemetry.enabled`` gates everything; disabled
  calls cost one attribute check (``span`` returns a shared no-op
  context manager, metric mutation is skipped at the call site), so the
  SpMV hot path pays well under 1% overhead.
* **Injectable clock.**  ``clock()`` returns seconds (default
  ``time.perf_counter``); :class:`FakeClock` makes span durations and
  deadline logic deterministic under test.
* **Fixed-bucket histograms.**  Latency histograms use a 1–2–5 ladder
  (:data:`DEFAULT_LATENCY_EDGES`), mergeable across processes and
  exportable as Prometheus text (:func:`repro.obs.export.prometheus_text`).
* **Bounded buffers.**  Spans/events past ``max_records`` are dropped
  (and counted) rather than growing without bound in a long-lived
  service.
"""
from __future__ import annotations

import bisect
import itertools
import math
import threading
import time
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple

from .tracing import NOOP_SPAN, Span, SpanContext, chrome_trace

#: 1–2–5 ladder from 1 µs to 50 s — wide enough for a host transform on a
#: large matrix and fine enough to separate a tuned from an untuned launch
DEFAULT_LATENCY_EDGES: Tuple[float, ...] = tuple(
    m * (10.0 ** e) for e in range(-6, 2) for m in (1.0, 2.0, 5.0))


class FakeClock:
    """Deterministic clock for tests: returns ``start`` and advances by
    ``tick`` per call; ``advance(dt)`` jumps time explicitly (the fake
    analogue of a sleep)."""

    def __init__(self, start: float = 0.0, tick: float = 0.0):
        self.t = float(start)
        self.tick = float(tick)

    def __call__(self) -> float:
        t = self.t
        self.t += self.tick
        return t

    def advance(self, dt: float) -> float:
        self.t += float(dt)
        return self.t


class Counter:
    """Monotonically increasing count."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def to_dict(self) -> Dict[str, Any]:
        return {"value": self.value}


class Gauge:
    """Last-set value (queue depth, cache size)."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def to_dict(self) -> Dict[str, Any]:
        return {"value": self.value}


class Histogram:
    """Fixed-bucket histogram (Prometheus ``le`` semantics: bucket ``i``
    counts observations ``edges[i-1] < v <= edges[i]``; one overflow
    bucket past the last edge)."""
    __slots__ = ("edges", "counts", "sum", "count")

    def __init__(self, edges: Sequence[float] = DEFAULT_LATENCY_EDGES):
        es = tuple(sorted(float(e) for e in edges))
        if not es:
            raise ValueError("histogram needs at least one bucket edge")
        if len(set(es)) != len(es):
            raise ValueError(f"duplicate bucket edges: {edges}")
        self.edges = es
        self.counts = [0] * (len(es) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect.bisect_left(self.edges, v)] += 1
        self.sum += v
        self.count += 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else float("nan")

    def quantile(self, q: float) -> float:
        """Bucket-interpolated quantile estimate (exact only up to bucket
        resolution; the overflow bucket clamps to the last edge)."""
        if not self.count:
            return float("nan")
        target = min(max(q, 0.0), 1.0) * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if c and cum >= target:
                lo = self.edges[i - 1] if i > 0 else 0.0
                hi = self.edges[i] if i < len(self.edges) else self.edges[-1]
                frac = (target - (cum - c)) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
        return self.edges[-1]

    def summary(self) -> Dict[str, float]:
        return {"count": self.count, "sum": self.sum, "mean": self.mean,
                "p50": self.quantile(0.50), "p90": self.quantile(0.90),
                "p99": self.quantile(0.99)}

    def to_dict(self) -> Dict[str, Any]:
        return {"edges": list(self.edges), "counts": list(self.counts),
                "sum": self.sum, "count": self.count}


Labels = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, Any]) -> Labels:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def format_metric(name: str, labels: Dict[str, str]) -> str:
    """Canonical ``name{k=v,...}`` display key (no braces when bare)."""
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in sorted(labels.items()))
    return f"{name}{{{inner}}}"


class Telemetry:
    """The registry.  One process-wide default lives behind
    :func:`repro.obs.get`; tests construct their own with a
    :class:`FakeClock` and an in-memory sink.

    ``sinks`` receive every finished span and point event as a dict
    record (see :mod:`repro.obs.export`); sink failures are counted, not
    raised — telemetry must never take down the serving path."""

    def __init__(self, enabled: bool = False,
                 clock: Optional[Callable[[], float]] = None,
                 sinks: Sequence[Any] = (),
                 max_records: int = 100_000,
                 latency_edges: Sequence[float] = DEFAULT_LATENCY_EDGES):
        self.enabled = bool(enabled)
        self.clock = clock if clock is not None else time.perf_counter
        self.sinks: List[Any] = list(sinks)
        self.max_records = int(max_records)
        self.latency_edges = tuple(latency_edges)
        self.spans: List[Span] = []
        self.events: List[Dict[str, Any]] = []
        self.dropped = 0
        self.sink_errors = 0
        self._metrics: Dict[Tuple[str, str, Labels], Any] = {}
        self._lock = threading.Lock()
        self._local = threading.local()
        self._ids = itertools.count(1)

    # -- metrics -------------------------------------------------------------
    def _metric(self, kind: str, cls: type, name: str,
                labels: Dict[str, Any], *args: Any) -> Any:
        key = (kind, name, _label_key(labels))
        m = self._metrics.get(key)
        if m is None:
            with self._lock:
                m = self._metrics.get(key)
                if m is None:
                    m = self._metrics[key] = cls(*args)
        return m

    def counter(self, name: str, **labels: Any) -> Counter:
        return self._metric("counter", Counter, name, labels)

    def gauge(self, name: str, **labels: Any) -> Gauge:
        return self._metric("gauge", Gauge, name, labels)

    def histogram(self, name: str,
                  edges: Optional[Sequence[float]] = None,
                  **labels: Any) -> Histogram:
        return self._metric("histogram", Histogram, name, labels,
                            edges if edges is not None
                            else self.latency_edges)

    def metrics(self, name: Optional[str] = None,
                kind: Optional[str] = None
                ) -> Iterator[Tuple[str, str, Dict[str, str], Any]]:
        """Iterate ``(kind, name, labels, metric)`` over the registry."""
        with self._lock:
            items = list(self._metrics.items())
        for (k, n, lab), m in items:
            if (name is None or n == name) and (kind is None or k == kind):
                yield k, n, dict(lab), m

    def snapshot(self) -> Dict[str, Any]:
        """All metrics as one JSON-ready dict (the "metrics dump"):
        ``{"counters": {key: value}, "gauges": {...}, "histograms":
        {key: summary+buckets}}`` plus span/event bookkeeping."""
        out: Dict[str, Any] = {"counters": {}, "gauges": {},
                               "histograms": {}}
        for kind, name, labels, m in self.metrics():
            key = format_metric(name, labels)
            if kind == "histogram":
                d = m.summary()
                d["buckets"] = [[e, c] for e, c in
                                zip(list(m.edges) + ["+Inf"], m.counts)]
                out["histograms"][key] = d
            else:
                out[kind + "s"][key] = m.value
        out["spans"] = len(self.spans)
        out["events"] = len(self.events)
        out["dropped"] = self.dropped
        return out

    # -- spans + events ------------------------------------------------------
    def span(self, name: str, **attrs: Any):
        """Context manager timing a region; no-op when disabled.  The
        yielded :class:`Span` accepts ``.set(**attrs)``."""
        if not self.enabled:
            return NOOP_SPAN
        return SpanContext(self, name, attrs)

    def event(self, name: str, **attrs: Any) -> Optional[Dict[str, Any]]:
        """Point event (a tune winner, a plan decision, a flush), parented
        to the innermost open span; no-op when disabled."""
        if not self.enabled:
            return None
        stack = self._span_stack()
        rec = {"type": "event", "name": name, "ts": self.clock(),
               "span_id": stack[-1].span_id if stack else None,
               "attrs": attrs}
        self._append(self.events, rec)
        self._emit(rec if not self.sinks else _jsonable_record(rec))
        return rec

    def _span_stack(self) -> List[Span]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _next_id(self) -> int:
        return next(self._ids)

    def _finish_span(self, sp: Span) -> None:
        self._append(self.spans, sp)
        if self.sinks:
            self._emit(sp.to_record())

    def _append(self, buf: List[Any], item: Any) -> None:
        if len(buf) >= self.max_records:
            self.dropped += 1
            return
        buf.append(item)

    def _emit(self, rec: Dict[str, Any]) -> None:
        for s in self.sinks:
            try:
                s.emit(rec)
            except Exception:
                self.sink_errors += 1

    # -- export + lifecycle --------------------------------------------------
    def to_chrome_trace(self) -> Dict[str, Any]:
        return chrome_trace(self.spans)

    def reset(self) -> None:
        """Clear all metrics, spans, and events (sinks keep what they
        already received)."""
        with self._lock:
            self._metrics.clear()
        self.spans = []
        self.events = []
        self.dropped = 0
        self.sink_errors = 0

    def close(self) -> None:
        for s in self.sinks:
            close = getattr(s, "close", None)
            if callable(close):
                try:
                    close()
                except Exception:
                    self.sink_errors += 1


def _jsonable_record(rec: Dict[str, Any]) -> Dict[str, Any]:
    from .tracing import as_jsonable
    out = dict(rec)
    out["attrs"] = {k: as_jsonable(v) for k, v in rec["attrs"].items()}
    return out


def percentile(values: Sequence[float], q: float) -> float:
    """Exact linear-interpolated percentile of raw samples (the CLI's
    summarizer works on exact span durations, not bucket estimates)."""
    vs = sorted(float(v) for v in values)
    if not vs:
        return float("nan")
    if len(vs) == 1:
        return vs[0]
    pos = min(max(q, 0.0), 1.0) * (len(vs) - 1)
    lo = math.floor(pos)
    frac = pos - lo
    hi = min(lo + 1, len(vs) - 1)
    return vs[lo] * (1 - frac) + vs[hi] * frac


__all__ = [
    "DEFAULT_LATENCY_EDGES", "FakeClock", "Counter", "Gauge", "Histogram",
    "Telemetry", "format_metric", "percentile",
]
