"""Production meshes.  Defined as FUNCTIONS so importing this module never
touches jax device state (required for the 512-placeholder-device dry-run:
jax locks the device count on first init)."""
from __future__ import annotations

import jax


def _mesh(shape, axes):
    # jax < 0.5 has no jax.sharding.AxisType / axis_types kwarg; Auto is
    # the default there, so plain make_mesh is equivalent
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests, elastic-scaling checks)."""
    return _mesh(tuple(shape), tuple(axes))


def data_axis_size(mesh) -> int:
    shape = dict(mesh.shape)
    return shape.get("pod", 1) * shape.get("data", 1)


def model_axis_size(mesh) -> int:
    return dict(mesh.shape).get("model", 1)


__all__ = ["make_production_mesh", "make_mesh", "data_axis_size",
           "model_axis_size"]
