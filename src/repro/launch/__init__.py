# NOTE: repro.launch.dryrun sets XLA_FLAGS at import time; import it only
# as a __main__ entry point.  The other modules are safe to import.
from .mesh import make_production_mesh, make_mesh
