"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh)
cell on placeholder devices; record memory/cost analysis + roofline terms.

MUST be the very first two lines — jax locks the device count on first
init, and the production meshes need 512 host-platform devices:"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_FLAGS")
                           or "--xla_force_host_platform_device_count=512")

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, get_config        # noqa: E402
from repro.launch.analytic import analytic_costs              # noqa: E402
from repro.launch.mesh import (data_axis_size,                # noqa: E402
                               make_production_mesh, model_axis_size)
from repro.launch.roofline import (HBM_BW, ICI_BW,            # noqa: E402
                                   PEAK_FLOPS_BF16, from_compiled)
from repro.launch.steps import jitted_step_for_cell           # noqa: E402
from repro.models.model import n_active_params                # noqa: E402

HBM_PER_CHIP = 16e9   # v5e


def unrolled_cfg(cfg):
    """Expand the layer pattern to full depth: the layer scan becomes a
    single-iteration loop, so ``cost_analysis`` (which counts each while
    body once) reports exact per-step costs for programs with no inner
    time loops — i.e. every decode cell (see launch/analytic.py)."""
    full = (tuple(cfg.layer_pattern) * cfg.scan_reps +
            tuple(cfg.remainder_pattern))
    return cfg.replace(layer_pattern=full, n_layers=len(full))


def skip_reason(cfg, shape) -> str:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("SKIP(design): pure full-attention arch defines no "
                "sub-quadratic mechanism for 524k context (DESIGN.md §5)")
    return ""


def model_flops_for(cfg, shape) -> float:
    n_act = n_active_params(cfg)
    tokens = shape.global_batch * (1 if shape.kind == "decode"
                                   else shape.seq_len)
    mult = 6.0 if shape.kind == "train" else 2.0   # fwd-only = 2*N*D
    return mult * n_act * tokens


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             donate: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 512 if multi_pod else 256
    shape = SHAPES[shape_name]
    cfg = get_config(arch).resolve_for_tp(model_axis_size(mesh))
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "status": "ok"}
    reason = skip_reason(cfg, shape)
    if reason:
        rec.update(status="skip", reason=reason)
        _save(rec, out_dir)
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: {reason}")
        return rec

    t0 = time.time()
    try:
        jfn, args = jitted_step_for_cell(cfg, shape, mesh, donate=donate)
        with mesh:
            lowered = jfn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            hlo_text = compiled.as_text()
        rl = from_compiled(compiled, arch=arch, shape=shape_name,
                           mesh_name=mesh_name, chips=chips,
                           model_flops=model_flops_for(cfg, shape),
                           hlo_text=hlo_text)
        peak = (getattr(mem, "temp_size_in_bytes", 0) +
                getattr(mem, "argument_size_in_bytes", 0) +
                getattr(mem, "output_size_in_bytes", 0) -
                getattr(mem, "alias_size_in_bytes", 0))
        rec.update(
            roofline=rl.to_dict(),
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
                "generated_code_bytes": getattr(
                    mem, "generated_code_size_in_bytes", 0),
                "peak_bytes": peak,
                "fits_16gb": bool(peak < HBM_PER_CHIP),
            },
            timings={"lower_s": t_lower, "compile_s": t_compile},
        )
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: OK "
              f"peak={peak/1e9:.2f}GB "
              f"compute={rl.t_compute*1e3:.2f}ms "
              f"memory={rl.t_memory*1e3:.2f}ms "
              f"collective={rl.t_collective*1e3:.2f}ms "
              f"bottleneck={rl.bottleneck} "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
    # harness reporter: any failure is recorded to the JSON record
    # (status/error/traceback) and printed, never dropped — repro: noqa[RPA001]
    except Exception as e:  # a failure here is a bug in the system
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: "
              f"FAILED {type(e).__name__}: {e}")
    _save(rec, out_dir)
    return rec


def _save(rec: dict, out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(
        out_dir, f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def analyze_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
                 unroll_hlo: bool = False) -> None:
    """Augment an existing cell record with (a) analytic trip-count-aware
    roofline terms and (b), optionally, an exact unrolled-HLO compile
    (decode cells: no inner loops remain, so the HLO numbers are exact)."""
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 512 if multi_pod else 256
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.json")
    with open(path) as f:
        rec = json.load(f)
    if rec.get("status") != "ok":
        return
    mesh = make_production_mesh(multi_pod=multi_pod)
    shape = SHAPES[shape_name]
    cfg = get_config(arch).resolve_for_tp(model_axis_size(mesh))
    cfg_a = cfg.replace(kv_quant=True) if shape.kind != "train" else cfg
    ac = analytic_costs(cfg_a, shape, chips, data_axis_size(mesh),
                        model_axis_size(mesh))
    t_c = ac.flops / PEAK_FLOPS_BF16
    t_m = ac.bytes / HBM_BW
    t_l = ac.collective_bytes / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_l}
    rec["analytic"] = {
        "flops_dev": ac.flops, "bytes_dev": ac.bytes,
        "collective_bytes_dev": ac.collective_bytes,
        "t_compute": t_c, "t_memory": t_m, "t_collective": t_l,
        "bottleneck": max(terms, key=terms.get),
        "model_flops_global": ac.detail["model_flops_global"],
        "useful_ratio": ac.detail["model_flops_global"] /
        (chips * ac.flops) if ac.flops else 0.0,
        "detail": ac.detail,
    }
    if unroll_hlo:
        try:
            ucfg = unrolled_cfg(cfg)
            jfn, a = jitted_step_for_cell(ucfg, shape, mesh, donate=False,
                                          microbatches=1)
            with mesh:
                compiled = jfn.lower(*a).compile()
                hlo_text = compiled.as_text()
            rl = from_compiled(compiled, arch=arch, shape=shape_name,
                               mesh_name=mesh_name, chips=chips,
                               model_flops=model_flops_for(cfg, shape),
                               hlo_text=hlo_text)
            rec["hlo_unrolled"] = rl.to_dict()
        # analysis-only extra; the error lands in the record itself
        # repro: noqa[RPA001]
        except Exception as e:  # analysis-only; keep the base record
            rec["hlo_unrolled"] = {"error": f"{type(e).__name__}: {e}"}
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    a_bn = rec["analytic"]["bottleneck"]
    print(f"[analysis] {arch} x {shape_name} x {mesh_name}: "
          f"analytic compute={t_c*1e3:.2f}ms memory={t_m*1e3:.2f}ms "
          f"collective={t_l*1e3:.2f}ms bottleneck={a_bn}" +
          (" (+unrolled HLO)" if unroll_hlo and
           "error" not in rec.get("hlo_unrolled", {}) else ""))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all' (see repro.configs.ARCH_IDS)")
    ap.add_argument("--shape", default="all",
                    help="shape cell or 'all' (train_4k, prefill_32k, "
                         "decode_32k, long_500k)")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--analysis", action="store_true",
                    help="augment existing records with analytic terms "
                         "(+ exact unrolled HLO for decode cells)")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else (args.arch,)
    shapes = tuple(SHAPES) if args.shape == "all" else (args.shape,)
    meshes = {"single": (False,), "multi": (True,),
              "both": (False, True)}[args.mesh]

    if args.analysis:
        for arch in archs:
            for shape in shapes:
                for mp in meshes:
                    mesh_name = "2x16x16" if mp else "16x16"
                    p = os.path.join(args.out,
                                     f"{arch}__{shape}__{mesh_name}.json")
                    if not os.path.exists(p):
                        continue
                    unroll = SHAPES[shape].kind == "decode" and not mp
                    analyze_cell(arch, shape, mp, args.out,
                                 unroll_hlo=unroll)
        return

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                path = os.path.join(args.out,
                                    f"{arch}__{shape}__{mesh_name}.json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        prev = json.load(f)
                    if prev.get("status") in ("ok", "skip"):
                        print(f"[dryrun] {arch} x {shape} x {mesh_name}: "
                              "cached")
                        results.append(prev)
                        continue
                results.append(run_cell(arch, shape, mp, args.out))

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skip(design), {n_err} error")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
