"""Roofline-term extraction from compiled artifacts (DESIGN.md §8).

  compute    = HLO_FLOPs / (chips * peak_flops)
  memory     = HLO_bytes / (chips * hbm_bw)
  collective = sum(operand bytes of {all-gather, all-reduce, reduce-scatter,
               all-to-all, collective-permute}) / (chips * link_bw)

HLO_FLOPs/HLO_bytes come from ``compiled.cost_analysis()``.  Collective
bytes are parsed from the *post-SPMD* HLO text (per-device operand shapes),
summed over one device's program and charged against one device's link
bandwidth — i.e. per-chip time, the same normalization as the other terms.
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Tuple

# hardware constants (TPU v5e-like, per chip)
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # B/s
ICI_BW = 50e9                  # B/s per link (~bisection per chip)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[16,1024]{1,0}' -> bytes.  Tuples handled by the caller."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, int] = field(default_factory=dict)
    count_by_op: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum *output* operand sizes of every collective op in the HLO.

    Lines look like:
      %ag = bf16[16,4096]{1,0} all-gather(%x), replica_groups=...
      %ar = (f32[8], f32[8]) all-reduce(...), ...
    The shape(s) before the op name are the per-device result sizes."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        for op in COLLECTIVE_OPS:
            # match "<shape> opname(" — opname may carry -start/-done suffix
            m = re.search(r"=\s*(\([^)]*\)|\S+)\s+" + op +
                          r"(?:-start|-done)?\(", s)
            if m:
                if op == "all-gather" and "all-gather-done" in s:
                    continue  # counted at -start
                shape = m.group(1)
                b = _shape_bytes(shape)
                stats.bytes_by_op[op] = stats.bytes_by_op.get(op, 0) + b
                stats.count_by_op[op] = stats.count_by_op.get(op, 0) + 1
                break
    return stats


# ---------------------------------------------------------------------------
# trip-count-weighted accounting (fixes the while-body-once undercount)
# ---------------------------------------------------------------------------
_CONST_RE = re.compile(r"constant\((\d+)\)")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\w+)\[([\d,]*)\]")


def _split_computations(hlo: str) -> Dict[str, str]:
    """computation name -> body text (header line included)."""
    comps: Dict[str, str] = {}
    name = None
    buf: List[str] = []
    for line in hlo.splitlines():
        stripped = line.strip()
        is_hdr = (stripped.endswith("{") and ") -> " in stripped and
                  "=" not in stripped.split("(")[0])
        if is_hdr:
            if name is not None:
                comps[name] = "\n".join(buf)
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)", stripped)
            name = m.group(1) if m else None
            buf = [line]
        elif name is not None:
            buf.append(line)
    if name is not None:
        comps[name] = "\n".join(buf)
    return comps


def _shape_table(hlo: str) -> Dict[str, Tuple[str, List[int]]]:
    """%name -> (dtype, dims) for every defined instruction."""
    table: Dict[str, Tuple[str, List[int]]] = {}
    for line in hlo.splitlines():
        m = _DEF_RE.match(line)
        if m:
            dims = [int(x) for x in m.group(3).split(",")] if m.group(3) \
                else []
            table[m.group(1)] = (m.group(2), dims)
    return table


def _dot_flops(body: str, table: Dict[str, Tuple[str, List[int]]]) -> float:
    """2 * numel(out) * K per dot; K solved from
    numel(lhs)*numel(rhs) == numel(out) * K^2 * numel(batch)^2 ... i.e.
    K = sqrt(lhs*rhs*batch^0 / out) with batch dims read from the lhs."""
    total = 0.0
    for line in body.splitlines():
        s = line.strip()
        if " dot(" not in s:
            continue
        m = _DEF_RE.match(s.replace("ROOT ", ""))
        if not m:
            continue
        out_dims = [int(x) for x in m.group(3).split(",")] if m.group(3) \
            else []
        out_n = 1
        for d in out_dims:
            out_n *= d
        # operands may carry a type prefix ("dot(f32[512,512]{1,0} %a, ...)")
        ops = re.search(
            r"dot\((?:[^%()]*\s)?%([\w.\-]+),\s*(?:[^%()]*\s)?%([\w.\-]+)\)",
            s)
        if not ops:
            continue
        lhs = table.get(ops.group(1))
        rhs = table.get(ops.group(2))
        if lhs is None or rhs is None:
            continue
        lhs_n = rhs_n = 1
        for d in lhs[1]:
            lhs_n *= d
        for d in rhs[1]:
            rhs_n *= d
        batch_n = 1
        bm = re.search(r"lhs_batch_dims=\{([\d,]*)\}", s)
        if bm and bm.group(1):
            for bd in bm.group(1).split(","):
                if int(bd) < len(lhs[1]):
                    batch_n *= lhs[1][int(bd)]
        k = (lhs_n * rhs_n / max(out_n, 1)) ** 0.5 / max(batch_n, 1) ** 0.5
        total += 2.0 * out_n * k
    return total


def _trip_count(cond_body: str) -> int:
    """Largest integer constant in the loop condition (scan bound)."""
    consts = [int(c) for c in _CONST_RE.findall(cond_body)]
    return max(consts) if consts else 1


@dataclass
class WeightedCosts:
    collective_bytes: float
    dot_flops: float
    loops: Dict[str, int] = field(default_factory=dict)


def weighted_costs(hlo: str) -> WeightedCosts:
    """Collective bytes + dot FLOPs with while-loop trip-count weighting.

    Walks the computation call tree from ENTRY; every while body's costs
    are multiplied by its condition's trip count (scan bounds appear as
    the largest constant in the condition computation)."""
    comps = _split_computations(hlo)
    table = _shape_table(hlo)
    # find while ops: map body computation -> trip count (via condition)
    body_trips: Dict[str, int] = {}
    callees: Dict[str, List[str]] = {}
    for name, body in comps.items():
        calls = []
        for m in re.finditer(
                r"(?:to_apply|calls|body|condition)=%?([\w.\-]+)", body):
            calls.append(m.group(1))
        for m in re.finditer(r"branch_computations=\{([^}]*)\}", body):
            calls.extend(x.strip().lstrip("%") for x in m.group(1).split(","))
        callees[name] = [c for c in calls if c in comps]
        for m in re.finditer(r"condition=%?([\w.\-]+), body=%?([\w.\-]+)",
                             body):
            cond, wbody = m.group(1), m.group(2)
            if cond in comps and wbody in comps:
                body_trips[wbody] = _trip_count(comps[cond])

    per_coll = {n: parse_collectives(b).total_bytes for n, b in comps.items()}
    per_flops = {n: _dot_flops(b, table) for n, b in comps.items()}

    entry = None
    for n, b in comps.items():
        if b.splitlines()[0].strip().startswith("ENTRY"):
            entry = n
    if entry is None:   # fall back: the computation nobody calls
        called = {c for cs in callees.values() for c in cs}
        roots = [n for n in comps if n not in called]
        entry = roots[-1] if roots else next(iter(comps))

    loops: Dict[str, int] = {}

    def walk(name: str, mult: float) -> Tuple[float, float]:
        coll = per_coll.get(name, 0) * mult
        fl = per_flops.get(name, 0) * mult
        for c in set(callees.get(name, [])):
            m2 = mult * body_trips.get(c, 1)
            if c in body_trips:
                loops[c] = body_trips[c]
            sub = walk(c, m2)
            coll += sub[0]
            fl += sub[1]
        return coll, fl

    coll, fl = walk(entry, 1.0)
    return WeightedCosts(collective_bytes=coll, dot_flops=fl, loops=loops)


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float            # PER-DEVICE FLOPs, trip-count-weighted dots
    hlo_bytes: float            # PER-DEVICE HBM traffic (cost_analysis raw;
                                # loop bodies counted once — lower bound)
    collective_bytes: float     # per-device collective bytes, trip-weighted
    model_flops: float          # 6*N*D (active N for MoE), GLOBAL
    hlo_flops_body: float = 0.0     # raw cost_analysis (bodies once)
    collective_bytes_body: float = 0.0
    loop_trips: Dict[str, int] = field(default_factory=dict)
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    bottleneck: str = ""
    useful_ratio: float = 0.0   # model_flops / hlo_flops
    bytes_per_device: float = 0.0
    peak_memory_gb: float = 0.0
    collectives: Dict[str, int] = field(default_factory=dict)

    def finalize(self) -> "Roofline":
        # cost_analysis() values are already per-device (verified against a
        # hand-sharded matmul), so each term is per-chip time directly.
        self.t_compute = self.hlo_flops / PEAK_FLOPS_BF16
        self.t_memory = self.hlo_bytes / HBM_BW
        self.t_collective = self.collective_bytes / ICI_BW
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        self.bottleneck = max(terms, key=terms.get)
        self.useful_ratio = (self.model_flops / (self.chips * self.hlo_flops)
                             if self.hlo_flops else 0.0)
        return self

    def to_dict(self) -> dict:
        return asdict(self)


def from_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                  chips: int, model_flops: float,
                  hlo_text: Optional[str] = None) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):   # older jax returns [dict]
        cost = cost[0]
    flops_body = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = parse_collectives(text)
    w = weighted_costs(text)
    try:
        mem = compiled.memory_analysis()
        peak = (getattr(mem, "temp_size_in_bytes", 0) +
                getattr(mem, "argument_size_in_bytes", 0) +
                getattr(mem, "output_size_in_bytes", 0) -
                getattr(mem, "alias_size_in_bytes", 0))
    except (AttributeError, TypeError, RuntimeError, ValueError):
        peak = 0                   # memory_analysis is best-effort per backend
    r = Roofline(arch=arch, shape=shape, mesh=mesh_name, chips=chips,
                 hlo_flops=max(w.dot_flops, flops_body),
                 hlo_bytes=byts,
                 collective_bytes=max(w.collective_bytes,
                                      float(coll.total_bytes)),
                 model_flops=model_flops,
                 hlo_flops_body=flops_body,
                 collective_bytes_body=float(coll.total_bytes),
                 loop_trips=dict(sorted(w.loops.items())[:16]),
                 bytes_per_device=byts,
                 peak_memory_gb=peak / 1e9,
                 collectives=dict(coll.bytes_by_op))
    return r.finalize()


__all__ = ["Roofline", "from_compiled", "parse_collectives",
           "CollectiveStats", "PEAK_FLOPS_BF16", "HBM_BW", "ICI_BW",
           "COLLECTIVE_OPS"]
