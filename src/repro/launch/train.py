"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --scale smoke --steps 100 --mesh 1x1
    PYTHONPATH=src python -m repro.launch.train --arch dbrx-132b \
        --scale full --mesh 16x16 --dry-run     # lower+compile only

Mesh axes: DxM (data x model) or PxDxM (pod x data x model).  Device count
must match the mesh; for placeholder-device experiments set
REPRO_XLA_FLAGS/XLA_FLAGS before launch (see dryrun.py, which owns the
512-device setting)."""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--scale", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--mesh", default="1x1",
                    help="DxM or PxDxM, e.g. 16x16 or 2x16x16")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--dry-run", action="store_true",
                    help="lower+compile the step and exit")
    args = ap.parse_args()

    from repro.configs import get_config, smoke_config
    from repro.configs.base import ShapeConfig
    from repro.data import SyntheticLM, data_config_for
    from repro.launch.mesh import make_mesh, model_axis_size
    from repro.train import TrainConfig, Trainer, run_with_restarts

    dims = tuple(int(x) for x in args.mesh.split("x"))
    axes = {2: ("data", "model"), 3: ("pod", "data", "model")}[len(dims)]
    mesh = make_mesh(dims, axes)

    cfg = get_config(args.arch)
    if args.scale == "smoke":
        cfg = smoke_config(cfg)
    cfg = cfg.resolve_for_tp(model_axis_size(mesh))

    if args.dry_run:
        from repro.launch.steps import jitted_step_for_cell
        shape = ShapeConfig("custom", args.seq, args.batch, "train")
        jfn, in_args = jitted_step_for_cell(
            cfg, shape, mesh, microbatches=args.microbatches)
        with mesh:
            compiled = jfn.lower(*in_args).compile()
            print(compiled.memory_analysis())
            print({k: v for k, v in compiled.cost_analysis().items()
                   if k in ("flops", "bytes accessed")})
        return

    data = SyntheticLM(data_config_for(cfg, args.seq, args.batch))
    tc = TrainConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                     microbatches=args.microbatches)
    with mesh:
        trainer = Trainer(cfg, data, tc)
        state = run_with_restarts(trainer)
    print(f"finished at step {state.step}; "
          f"final loss {trainer.metrics[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
