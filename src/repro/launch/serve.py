"""Production serving launcher: continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        --scale smoke --requests 8 --slots 4
"""
from __future__ import annotations

import argparse
import time


def main() -> None:  # repro: noqa[RPA004] — end-to-end throughput over
    # host-materialized results (eng.run() returns generated tokens)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--scale", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache (production serving default)")
    args = ap.parse_args()

    import jax
    import numpy as np
    from repro.configs import get_config, smoke_config
    from repro.models import init
    from repro.serve import ServeEngine

    cfg = get_config(args.arch)
    if args.scale == "smoke":
        cfg = smoke_config(cfg)
    if args.kv_quant:
        cfg = cfg.replace(kv_quant=True)

    params = init(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(params, cfg, max_batch=args.slots,
                      max_len=args.max_len)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for _ in range(args.requests):
        plen = int(rng.integers(4, args.max_len // 4))
        eng.submit(rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
                   max_new_tokens=args.max_new)
    done = eng.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in done.values())
    print(f"served {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
