"""Step builders (train / prefill / serve), dry-run input specs, and
sharding assignment for every argument tree (DESIGN.md §6).

All shardings are derived from the logical-axis rules; the batch axis
mapping is shape-aware (B=1 long-context decode falls back to sequence
sharding of the KV cache = context parallelism)."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model as M
from repro.optim import adamw
from repro.sharding.rules import (RULES_1POD, RULES_SERVE, RULES_ZERO1,
                                  ShardingRules, axes_tree,
                                  logical_to_sharding, rules_for_mesh,
                                  use_rules)
from .mesh import data_axis_size, model_axis_size


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model inputs for one step of the given shape cell.

    Train/prefill: the full sequence; frontend archs split the sequence
    into (frontend embeddings, text tokens) so total length == seq_len.
    Decode: a single new token (the KV cache is a separate argument)."""
    B = shape.global_batch
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
    F = cfg.frontend_len if cfg.frontend else 0
    S_text = shape.seq_len - F
    out = {"tokens": jax.ShapeDtypeStruct((B, S_text), jnp.int32),
           "labels": jax.ShapeDtypeStruct((B, S_text), jnp.int32)}
    if cfg.frontend:
        out["frontend_embeds"] = jax.ShapeDtypeStruct((B, F, cfg.d_model),
                                                      dtype)
    return out


def cache_specs(cfg: ModelConfig, shape: ShapeConfig,
                dtype=jnp.bfloat16) -> Any:
    """ShapeDtypeStruct tree for the decode caches at this cell's length."""
    return jax.eval_shape(
        lambda: M.init_caches(cfg, shape.global_batch, shape.seq_len, dtype))


def param_specs(cfg: ModelConfig, dtype=jnp.float32) -> Any:
    from repro.sharding.rules import eval_shape_params
    return eval_shape_params(M.model_spec(cfg), dtype)


# ---------------------------------------------------------------------------
# sharding assignment
# ---------------------------------------------------------------------------
def batch_axes_for(B: int, mesh) -> Optional[Tuple[str, ...]]:
    cands = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    while cands:
        size = int(np.prod([dict(mesh.shape)[a] for a in cands]))
        if B % size == 0:
            return cands
        cands = cands[:-1]
    return None


def params_sharding(cfg: ModelConfig, mesh,
                    rules: ShardingRules = RULES_1POD) -> Any:
    return logical_to_sharding(M.model_spec(cfg), mesh, rules)


def opt_sharding(cfg: ModelConfig, mesh,
                 rules: ShardingRules = RULES_1POD) -> adamw.AdamWState:
    ps = params_sharding(cfg, mesh, rules)
    return adamw.AdamWState(step=NamedSharding(mesh, P()), m=ps, v=ps)


def batch_sharding(cfg: ModelConfig, shape: ShapeConfig, mesh) -> Any:
    bax = batch_axes_for(shape.global_batch, mesh)
    specs = input_specs(cfg, shape)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, P(bax, *([None] * (len(s.shape) - 1)))),
        specs)


def cache_sharding(cfg: ModelConfig, shape: ShapeConfig, mesh) -> Any:
    """Heuristic per-leaf placement:
      * batch dim -> (pod, data) when divisible;
      * attn K/V: kv_heads -> model; if batch unshardable, sequence -> (pod,
        data) (context parallelism for long_500k);
      * SSM/xLSTM states: heads (or the widest inner dim) -> model."""
    B = shape.global_batch
    bax = batch_axes_for(B, mesh)
    tp = model_axis_size(mesh)
    dp = data_axis_size(mesh)
    seq_ax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    specs = cache_specs(cfg, shape)

    def leaf(path, s):
        names = [getattr(p, "key", "") for p in path]
        stacked = "scan" in names
        lead = (None,) if stacked else ()
        shp = s.shape[1:] if stacked else s.shape
        name = names[-1]
        ent: list = [None] * len(shp)
        ent[0] = bax  # batch dim (None if not shardable)
        if name in ("k", "v", "k_s", "v_s"):      # (B, S, KV[, hd])
            if shp[2] % tp == 0:
                ent[2] = "model"
            if bax is None and seq_ax and shp[1] % dp == 0:
                ent[1] = seq_ax                   # context parallelism
        elif name == "h" and len(shp) == 4:       # mamba (B, H, N, hd)
            if shp[1] % tp == 0:
                ent[1] = "model"
        elif name == "conv":                      # (B, K, C)
            if shp[2] % tp == 0:
                ent[2] = "model"
        elif name == "C" and len(shp) == 4:       # mlstm (B, H, dk, dv)
            if shp[1] % tp == 0:
                ent[1] = "model"
            elif shp[3] % tp == 0:
                ent[3] = "model"
        elif len(shp) >= 3 and shp[-1] % tp == 0 and name in ("c", "n",
                                                              "m", "h"):
            if shp[1] % tp == 0:
                ent[1] = "model"
        return NamedSharding(mesh, P(*lead, *ent))

    return jax.tree_util.tree_map_with_path(leaf, specs)


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                    microbatches: int = 1, mixed_precision: bool = False):
    """One optimizer step; ``microbatches`` > 1 scans gradient accumulation
    over batch slices (bounds activation transients — the knob that fits
    train_4k in HBM for the 12B+ architectures).  ``mixed_precision``:
    bf16 working params + f32 master in the optimizer state (§Perf: halves
    FSDP all-gather bytes)."""
    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(
                lambda p: M.loss_fn(p, batch, cfg))(params)
        else:
            mb = jax.tree.map(
                lambda a: a.reshape((microbatches, a.shape[0] // microbatches)
                                    + a.shape[1:]), batch)
            from repro.models.model import model_spec
            from repro.sharding.rules import axes_tree
            from repro.sharding.rules import with_logical_constraint as wlc
            g_axes = axes_tree(model_spec(cfg))

            def acc_fn(carry, mbatch):
                loss_sum, gacc = carry
                l, g = jax.value_and_grad(
                    lambda p: M.loss_fn(p, mbatch, cfg))(params)
                # pin per-microbatch grads to the parameter sharding so the
                # cross-data reduction lowers as reduce-scatter, not a
                # full-size all-reduce (§Perf: 2x gradient traffic)
                g = jax.tree.map(lambda gg, ax: wlc(gg, ax), g, g_axes)
                return (loss_sum + l,
                        jax.tree.map(jnp.add, gacc, g)), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, gsum), _ = jax.lax.scan(
                acc_fn, (jnp.zeros((), jnp.float32), zeros), mb)
            loss = loss_sum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
        if mixed_precision:
            new_params, new_state, gnorm = adamw.update_mixed(
                opt_cfg, grads, opt_state)
        else:
            new_params, new_state, gnorm = adamw.update(opt_cfg, grads,
                                                        opt_state, params)
        return new_params, new_state, {"loss": loss, "grad_norm": gnorm}
    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch, caches):
        logits, caches = M.prefill(params, batch, caches, cfg)
        # serving prefill emits the first generated token
        next_tok = jnp.argmax(logits[:, -1:, :], axis=-1)
        return next_tok, caches
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, tokens, caches, cache_len):
        logits, caches = M.decode_step(params, tokens, caches, cache_len, cfg)
        next_tok = jnp.argmax(logits[:, -1:, :], axis=-1)
        return next_tok, caches
    return serve_step


def default_microbatches(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """Pick gradient-accumulation depth so train transients fit 16 GB HBM:
    scale with model width x depth (activation bytes per token-layer)."""
    if shape.kind != "train":
        return 1
    cost = cfg.d_model * cfg.n_layers * shape.seq_len * shape.global_batch
    # empirical anchor: qwen3 (2048 x 28, B=256, S=4k) fits at M=1 (~9 GB)
    anchor = 2048 * 28 * 4096 * 256
    m = 1
    while cost > anchor * m and m < 64:
        m *= 2
    if cfg.n_experts:
        # MoE params+optimizer already eat ~8 GB/chip at 132B — halve the
        # activation transients once more (measured: dbrx 18.0 -> fits)
        m *= 2
    while shape.global_batch % m:
        m //= 2
    return max(m, 1)


def _with_rules(fn, rules: Optional[ShardingRules]):
    if rules is None:
        return fn

    def wrapped(*a, **kw):
        with use_rules(rules):
            return fn(*a, **kw)
    return wrapped


def jitted_step_for_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
                         opt_cfg: Optional[adamw.AdamWConfig] = None,
                         rules: Optional[ShardingRules] = None,
                         donate: bool = True,
                         microbatches: Optional[int] = None,
                         serve_weight_stationary: Optional[bool] = None,
                         zero1: bool = False,
                         kv_quant: Optional[bool] = None,
                         mixed_precision: bool = False):
    """Build (jitted_fn, abstract_args) for one (arch x shape) cell.

    train  -> train_step(params_f32, opt_state, batch)
    prefill-> prefill_step(params_bf16, batch, caches)
    decode -> serve_step(params_bf16, tokens, caches, cache_len)

    §Perf variants: ``serve_weight_stationary`` traces serving under
    RULES_SERVE (d-sharded residual stream, no FSDP weight gathers);
    ``zero1`` replicates parameters and shards only optimizer moments;
    ``kv_quant`` overrides the serving int8-KV default."""
    rules = rules or rules_for_mesh(mesh)
    act_rules = None
    if shape.kind != "train":
        # production serving config: int8 KV cache (halves cache bytes)
        cfg = cfg.replace(kv_quant=True if kv_quant is None else kv_quant)
        # weight-stationary decode is the default (§Perf: 65x collective
        # reduction on dbrx decode_32k); prefill keeps batch-sharded
        # activations (they are large)
        if serve_weight_stationary is None:
            serve_weight_stationary = (shape.kind == "decode")
        if serve_weight_stationary:
            act_rules = RULES_SERVE
    param_rules = RULES_ZERO1 if zero1 else rules
    ps = params_sharding(cfg, mesh, param_rules)
    bsh = batch_sharding(cfg, shape, mesh)
    binp = input_specs(cfg, shape)

    if shape.kind == "train":
        opt_cfg = opt_cfg or adamw.AdamWConfig()
        mb = (microbatches if microbatches is not None
              else default_microbatches(cfg, shape))
        fn = _with_rules(make_train_step(cfg, opt_cfg, microbatches=mb,
                                         mixed_precision=mixed_precision),
                         act_rules)
        # ZeRO-1: moments stay data-sharded even with replicated params
        osh_base = params_sharding(cfg, mesh, rules)
        if mixed_precision:
            osh = adamw.AdamWMixedState(step=NamedSharding(mesh, P()),
                                        m=osh_base, v=osh_base,
                                        master=osh_base)
            pspec32 = param_specs(cfg, jnp.float32)
            args = (param_specs(cfg, jnp.bfloat16),
                    jax.eval_shape(adamw.init_mixed, pspec32), binp)
        else:
            osh = adamw.AdamWState(step=NamedSharding(mesh, P()),
                                   m=osh_base, v=osh_base)
            pspec32 = param_specs(cfg, jnp.float32)
            args = (pspec32, jax.eval_shape(adamw.init, pspec32), binp)
        jfn = jax.jit(fn,
                      in_shardings=(ps, osh, bsh),
                      out_shardings=(ps, osh, NamedSharding(mesh, P())),
                      donate_argnums=(0, 1) if donate else ())
        return jfn, args

    csh = cache_sharding(cfg, shape, mesh)
    cargs = cache_specs(cfg, shape)
    bax = batch_axes_for(shape.global_batch, mesh)
    tok_out = NamedSharding(mesh, P(bax, None))

    if shape.kind == "prefill":
        fn = _with_rules(make_prefill_step(cfg), act_rules)
        jfn = jax.jit(fn,
                      in_shardings=(ps, bsh, csh),
                      out_shardings=(tok_out, csh),
                      donate_argnums=(2,) if donate else ())
        args = (param_specs(cfg, jnp.bfloat16), binp, cargs)
        return jfn, args

    fn = _with_rules(make_serve_step(cfg), act_rules)
    jfn = jax.jit(fn,
                  in_shardings=(ps, bsh["tokens"], csh,
                                NamedSharding(mesh, P())),
                  out_shardings=(tok_out, csh),
                  donate_argnums=(2,) if donate else ())
    args = (param_specs(cfg, jnp.bfloat16), binp["tokens"], cargs,
            jax.ShapeDtypeStruct((), jnp.int32))
    return jfn, args


__all__ = ["input_specs", "cache_specs", "param_specs", "batch_axes_for",
           "params_sharding", "opt_sharding", "batch_sharding",
           "cache_sharding", "make_train_step", "make_prefill_step",
           "make_serve_step", "jitted_step_for_cell"]
