"""Closed-form (trip-count-aware) roofline terms per (arch x shape) cell.

Why this exists: XLA's ``compiled.cost_analysis()`` counts every while-loop
body ONCE, not x trip-count (verified: a 10-step scanned 512^3 matmul
reports 268 MFLOP vs 2.68 GFLOP unrolled).  Every production cell here
scans over layer repetitions, microbatches, attention chunks and recurrent
time steps, so the compiled numbers undercount by the product of trip
counts.  The dry-run therefore records BOTH the raw compiled values and
these analytic terms; cells whose programs contain no inner loops after
layer-unrolling (all decode cells) are additionally compiled in
``--unroll-analysis`` mode, where HLO and analytic numbers can be compared
directly (EXPERIMENTS.md §Roofline shows the agreement).

Conventions:
  * FLOPs: 2*M*N*K per matmul; train = 3x forward (fwd + 2x bwd) + 1x fwd
    remat recompute (remat="full") = 4x fwd.
  * Bytes (per device, per step): parameter reads (bf16 compute copies) +
    gradient/optimizer RW (train) + KV-cache/state RW (decode) + activation
    streams (2 reads + 1 write of the residual stream per block matmul
    chain, bf16).
  * Collectives (per device, per step): FSDP param all-gather (fwd + bwd
    recompute + bwd = 3x per microbatch, bf16) + gradient reduce-scatter
    (f32) + TP activation all-reduces (2 per block) + MoE all-to-all
    (dispatch+combine buffers) + SP/CP gathers for sequence-sharded
    attention.  All divided by per-device link bandwidth in roofline.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.model import n_active_params, n_params


@dataclass
class AnalyticCosts:
    flops: float              # per device
    bytes: float              # per device (HBM)
    collective_bytes: float   # per device (ICI)
    detail: Dict[str, float]


def _attn_flops_fwd(cfg: ModelConfig, B: int, S: int, S_kv: int) -> float:
    d, H, KV, Dh = cfg.d_model, cfg.eff_heads, cfg.eff_kv_heads, cfg.head_dim
    proj = 2 * B * S * d * (H + 2 * KV + H) * Dh        # q,k,v,o
    scores = 2 * B * S * S_kv * H * Dh * 2              # qk^T + pv
    return proj + scores


def _block_flops_fwd(kind: str, cfg: ModelConfig, B: int, S: int,
                     S_kv: int) -> float:
    d, ff = cfg.d_model, cfg.d_ff
    if kind in ("attn", "local", "moe", "local_moe"):
        win = min(cfg.window, S_kv) if kind in ("local", "local_moe") \
            else S_kv
        f = _attn_flops_fwd(cfg, B, S, win)
        if kind in ("moe", "local_moe"):
            # router + top_k expert SwiGLU with capacity padding
            f += 2 * B * S * d * cfg.n_experts
            f += (2 * B * S * d * ff * 3 * cfg.top_k *
                  cfg.capacity_factor)
        else:
            f += 2 * B * S * d * ff * 3
        return f
    if kind in ("mamba", "mamba_attn"):
        d_in = cfg.ssm_expand * d
        N = cfg.ssm_state
        H = d_in // cfg.ssm_head_dim
        hd = cfg.ssm_head_dim
        L = min(256, S)
        f = 2 * B * S * d * (2 * d_in + 2 * N + H)          # in_proj
        f += 2 * B * S * d_in * d                           # out_proj
        f += 2 * B * S * (cfg.ssm_conv * (d_in + 2 * N))    # conv
        f += 2 * B * S * L * N                              # intra CB^T
        f += 2 * B * S * L * H * hd                         # intra M@x
        f += 4 * B * S * N * H * hd                         # state upd+read
        if kind == "mamba_attn":
            f += _attn_flops_fwd(cfg, B, S, S_kv)
            f += 2 * B * S * d * ff * 3
        return f
    if kind == "mlstm":
        d_in = cfg.mlstm_expand * d
        H = cfg.n_heads
        dv = d_in // H
        dk = max(dv // 2, 8)
        f = 2 * B * S * d * (2 * d_in + 2 * H * dk + 2 * H)  # projections
        f += 2 * B * S * d_in * d                            # out_proj
        f += 2 * B * S * H * dk * dv * 3                     # C upd + read
        return f
    if kind == "slstm":
        H = cfg.n_heads
        dh = d // H
        f = 2 * B * S * d * 4 * d                            # in_proj
        f += 2 * B * S * H * dh * 4 * dh                     # recurrent R
        f += 2 * B * S * d * d                               # out_proj
        return f
    raise KeyError(kind)


def _layer_list(cfg: ModelConfig):
    return (list(cfg.layer_pattern) * cfg.scan_reps +
            list(cfg.remainder_pattern))


def analytic_costs(cfg: ModelConfig, shape: ShapeConfig, chips: int,
                   data_shards: int, model_shards: int) -> AnalyticCosts:
    B, S = shape.global_batch, shape.seq_len
    decode = shape.kind == "decode"
    S_q = 1 if decode else S
    S_kv = S
    layers = _layer_list(cfg)
    d = cfg.d_model

    fwd = sum(_block_flops_fwd(k, cfg, B, S_q, S_kv) for k in layers)
    fwd += 2 * B * S_q * d * cfg.vocab_size            # lm head
    mult = 4.0 if shape.kind == "train" else 1.0       # bwd + remat
    flops_total = fwd * mult
    flops_dev = flops_total / chips

    # ---- bytes ---------------------------------------------------------
    Np = n_params(cfg)
    param_bytes_dev = 2 * Np / chips                   # bf16 compute copy
    micro = 1
    if shape.kind == "train":
        from repro.launch.steps import default_microbatches
        micro = default_microbatches(cfg, shape)
    tokens_dev = B * S_q / data_shards
    act_stream = 6 * tokens_dev * d * 2 * len(layers)  # resid r/w, bf16
    byts = param_bytes_dev * (3 if shape.kind == "train" else 1) * micro
    if shape.kind == "train":
        byts += (4 * Np / chips) * 8                   # grads+adam m,v RW f32
    cache_rw_global = 0.0
    if decode:
        kv_bytes = 1 if cfg.kv_quant else 2
        for k in layers:
            slots = None
            if k in ("attn", "moe"):
                slots = S
            elif k in ("local", "local_moe"):
                slots = min(cfg.window, S)
            elif k in ("mamba", "mamba_attn"):
                d_in = cfg.ssm_expand * d
                cache_rw_global += 2 * B * (d_in // cfg.ssm_head_dim) * \
                    cfg.ssm_state * cfg.ssm_head_dim * 4
                slots = S if k == "mamba_attn" else None
            elif k == "mlstm":
                d_in = cfg.mlstm_expand * d
                dv = d_in // cfg.n_heads
                dk = max(dv // 2, 8)
                cache_rw_global += 2 * B * cfg.n_heads * dk * dv * 4
            else:   # slstm
                cache_rw_global += 8 * B * d * 4
            if slots is not None:
                # k+v read once per step (+2% for scales / the write)
                cache_rw_global += (B * slots * cfg.eff_kv_heads *
                                    cfg.head_dim * kv_bytes * 2 * 1.02)
    cache_rw = cache_rw_global / chips
    byts += act_stream + cache_rw

    # ---- collectives ----------------------------------------------------
    coll = 0.0
    if shape.kind == "train":
        coll += 3 * micro * param_bytes_dev            # FSDP gathers
        coll += 4 * Np / chips                         # grad reduce-scatter
    elif not decode:
        coll += param_bytes_dev                        # prefill FSDP gathers
    # decode runs weight-stationary (§Perf): no weight movement at all —
    # only the small activation all-reduces below
    # TP activation all-reduces: 2 per block of the per-device token slice
    coll += 2 * len(layers) * tokens_dev * d * 2 * \
        (0.0 if model_shards == 1 else 1.0)
    if decode:                                          # ws partial-sum ARs
        coll += 2 * len(layers) * B * d * 2
    if cfg.n_experts:
        # MoE all-to-all: dispatch + combine buffers (capacity-padded)
        coll += (2 * tokens_dev * cfg.top_k * cfg.capacity_factor * d * 2 *
                 sum(k in ("moe", "local_moe") for k in layers))
    if decode and B < data_shards:                     # context parallelism
        coll += len(layers) * cfg.eff_kv_heads * cfg.head_dim * 4 * 2

    return AnalyticCosts(
        flops=flops_dev, bytes=byts, collective_bytes=coll,
        detail={"fwd_flops_global": fwd, "mult": mult,
                "param_bytes_dev": param_bytes_dev,
                "act_stream": act_stream, "cache_rw": cache_rw,
                "microbatches": micro,
                "model_flops_global": (6 if shape.kind == "train" else 2) *
                n_active_params(cfg) * B * S_q})


__all__ = ["AnalyticCosts", "analytic_costs"]
