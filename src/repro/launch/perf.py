"""§Perf hillclimbing harness: compile one (arch x shape) cell under a
named optimization variant and record the roofline evidence.

Measurements per variant (all from compiled artifacts on the 16x16 mesh):
  * scanned-HLO: flops/bytes/collective bytes of the production program
    (while bodies counted once — used as *per-body* deltas between
    variants, same-denominator comparisons);
  * unrolled-HLO (decode cells): exact per-step numbers (no inner loops);
  * analytic: trip-count-aware closed-form terms (launch/analytic.py);
  * memory_analysis peak.

    PYTHONPATH=src python -m repro.launch.perf --cell dbrx-132b:decode_32k \
        --variant base
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_FLAGS")
                           or "--xla_force_host_platform_device_count=512")

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402


from repro.configs import SHAPES, get_config                  # noqa: E402
from repro.launch.analytic import analytic_costs              # noqa: E402
from repro.launch.dryrun import model_flops_for, unrolled_cfg  # noqa: E402
from repro.launch.mesh import (data_axis_size,                # noqa: E402
                               make_production_mesh, model_axis_size)
from repro.launch.roofline import (HBM_BW, ICI_BW,            # noqa: E402
                                   PEAK_FLOPS_BF16, from_compiled)
from repro.launch.steps import jitted_step_for_cell           # noqa: E402


# variant name -> (cfg transform, step kwargs)
VARIANTS = {
    "base": (lambda c: c, {}),
    # dbrx decode iterations
    "kv_bf16": (lambda c: c, {"kv_quant": False}),      # pre-int8 baseline
    "kv_int8": (lambda c: c, {"kv_quant": True}),
    "serve_ws": (lambda c: c, {"kv_quant": True,
                               "serve_weight_stationary": True}),
    "serve_ws_bf16": (lambda c: c, {"kv_quant": False,
                                    "serve_weight_stationary": True}),
    "moe_c1": (lambda c: c, {"kv_quant": True}),   # after capacity-floor fix
    "moe_csr": (lambda c: c.replace(moe_dispatch="csr"),
                {"kv_quant": True}),
    "moe_c1_ws": (lambda c: c, {"kv_quant": True,
                                "serve_weight_stationary": True}),
    # gemma3 train iterations
    "embed_tp": (lambda c: c.replace(embed_tp_lookup=True), {}),
    # xlstm train iterations
    "local_rec": (lambda c: c.replace(xlstm_shard_recurrent=False), {}),
    "zero1": (lambda c: c, {"zero1": True}),
    "local_rec_zero1": (lambda c: c.replace(xlstm_shard_recurrent=False),
                        {"zero1": True}),
    "embed_tp_zero1": (lambda c: c.replace(embed_tp_lookup=True),
                       {"zero1": True}),
    "mixed": (lambda c: c, {"mixed_precision": True}),
    "mixed_embed_tp": (lambda c: c.replace(embed_tp_lookup=True),
                       {"mixed_precision": True}),
    "mixed_zero1": (lambda c: c, {"mixed_precision": True, "zero1": True}),
    "flash4k": (lambda c: c.replace(flash_kv_chunk=4096), {}),
}


def run_variant(arch: str, shape_name: str, variant: str,
                out_dir: str = "experiments/perf",
                unroll: bool = None) -> dict:
    mesh = make_production_mesh(multi_pod=False)
    shape = SHAPES[shape_name]
    cfg_fn, kwargs = VARIANTS[variant]
    cfg = cfg_fn(get_config(arch).resolve_for_tp(model_axis_size(mesh)))
    if unroll is None:
        unroll = shape.kind == "decode"

    rec = {"arch": arch, "shape": shape_name, "variant": variant}
    t0 = time.time()
    jfn, args = jitted_step_for_cell(cfg, shape, mesh, **kwargs)
    with mesh:
        compiled = jfn.lower(*args).compile()
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
    rl = from_compiled(compiled, arch=arch, shape=shape_name,
                       mesh_name="16x16", chips=256,
                       model_flops=model_flops_for(cfg, shape),
                       hlo_text=hlo)
    peak = (getattr(mem, "temp_size_in_bytes", 0) +
            getattr(mem, "argument_size_in_bytes", 0) +
            getattr(mem, "output_size_in_bytes", 0) -
            getattr(mem, "alias_size_in_bytes", 0))
    rec["scanned"] = rl.to_dict()
    rec["peak_gb"] = peak / 1e9

    if unroll:
        ucfg = unrolled_cfg(cfg)
        ujfn, uargs = jitted_step_for_cell(ucfg, shape, mesh, donate=False,
                                           microbatches=1, **kwargs)
        with mesh:
            ucompiled = ujfn.lower(*uargs).compile()
            uhlo = ucompiled.as_text()
        url = from_compiled(ucompiled, arch=arch, shape=shape_name,
                            mesh_name="16x16", chips=256,
                            model_flops=model_flops_for(cfg, shape),
                            hlo_text=uhlo)
        rec["unrolled"] = url.to_dict()

    cfg_serve = (cfg if shape.kind == "train"
                 else cfg.replace(kv_quant=kwargs.get("kv_quant", True)))
    ac = analytic_costs(cfg_serve, shape, 256, data_axis_size(mesh),
                        model_axis_size(mesh))
    rec["analytic"] = {
        "t_compute_ms": ac.flops / PEAK_FLOPS_BF16 * 1e3,
        "t_memory_ms": ac.bytes / HBM_BW * 1e3,
        "t_collective_ms": ac.collective_bytes / ICI_BW * 1e3,
    }
    rec["compile_s"] = time.time() - t0

    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{variant}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)

    src = rec.get("unrolled", rec["scanned"])
    print(f"[perf] {arch} x {shape_name} [{variant}]: "
          f"flops/dev={src['hlo_flops']:.3g} "
          f"bytes/dev={src['hlo_bytes']:.3g} "
          f"coll/dev={src['collective_bytes']:.3g} "
          f"peak={rec['peak_gb']:.2f}GB "
          f"({'unrolled' if 'unrolled' in rec else 'scanned'} HLO, "
          f"{rec['compile_s']:.0f}s)")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--variant", required=True,
                    help=f"one of {sorted(VARIANTS)} or comma list")
    ap.add_argument("--out", default="experiments/perf")
    ap.add_argument("--unroll", action="store_true", default=None)
    args = ap.parse_args()
    arch, shape = args.cell.split(":")
    for v in args.variant.split(","):
        run_variant(arch, shape, v, args.out, unroll=args.unroll)


if __name__ == "__main__":
    main()
