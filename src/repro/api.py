"""repro.api — the one-import surface of the auto-tuning pipeline.

The paper's method is a single pipeline: profile the machine (off-line
phase), read the matrix's D_mat, decide the format, transform at run
time, launch.  This module is that pipeline as one importable surface,
organized around the portable decision artifact — the
:class:`~repro.core.plan.ExecutionPlan`:

    from repro import api

    # off-line, once per machine class: suite timings + kernel geometry
    db = api.offline_phase(suite, machine="v5e")
    db.save("tuningdb.v5e.json")

    # plan: decision rule + format + transform recipe + launch geometry,
    # one versioned JSON artifact
    planner = api.Planner(db=db, tuner=api.KernelTuner(db=db))
    plan = planner.plan(csr, batch=8, expected_iterations=1000)
    plan.save("plan.json")

    # replay anywhere: bind to the matrix and serve
    P = api.ExecutionPlan.load("plan.json").bind(csr)
    y = P @ x                      # SpMV
    Y = P @ X                      # SpMM, X: (n_cols, B)

    # or hand the plan to the serving layer (skips re-tuning)
    svc = api.SpMVService()
    svc.register("graph0", csr, plan=plan)

See ``docs/plans.md`` for the plan lifecycle, JSON schema, and the
migration notes from the deprecated entry points (``AutoTunedSpMV``,
direct ``decide_*`` calls).
"""
from repro.core.autotune import (AutoTunedSpMV, Decision, MachineModel,
                                 OfflineRecord, TuningDB, decide_cost_model,
                                 decide_generalized, decide_paper,
                                 offline_phase)
from repro.core.formats import (BCSR, BucketedELL, CCS, COO, CSR, ELL,
                                MatrixStats, MatrixValidationError,
                                memory_bytes)
from repro.core.kernel_tune import (GeometryRecord, KernelTuner,
                                    TileGeometry, candidate_geometries,
                                    nearest_geometry)
from repro.core.plan import (SCHEMA_VERSION, SHARDED_SCHEMA_VERSION,
                             BlockPlan, ExecutionPlan, PlanError,
                             PlanFingerprint, PlanSchemaError, PlannedMatrix,
                             Planner, ShardedPlan, TransformRecipe,
                             apply_transform)
from repro.core.plan_store import PlanStore, fingerprint_key
from repro.core.policy import MemoryPolicy
from repro.core.transform import (TRANSFORMS_HOST, csr_from_dense,
                                  csr_from_rows)
from repro.obs import FakeClock, InMemorySink, JsonlSink, Telemetry
from repro.serve import (AdmissionError, CircuitBreaker, EvictedError,
                         GuardedImpl, GuardError, SpMVService, faults)
from repro.sharding import ShardedPlannedMatrix, build_sharded, shard_csr
from repro import obs

__all__ = [
    # the plan API (the public face)
    "SCHEMA_VERSION", "ExecutionPlan", "PlannedMatrix", "Planner",
    "BlockPlan", "TransformRecipe", "PlanFingerprint", "PlanError",
    "PlanSchemaError", "apply_transform",
    # multi-device sharding (docs/sharding.md)
    "SHARDED_SCHEMA_VERSION", "ShardedPlan", "ShardedPlannedMatrix",
    "build_sharded", "shard_csr",
    # offline phase + persistence
    "offline_phase", "TuningDB", "OfflineRecord", "MachineModel",
    # kernel launch-geometry tuning
    "KernelTuner", "TileGeometry", "GeometryRecord",
    "candidate_geometries", "nearest_geometry",
    # serving + fault tolerance (docs/robustness.md)
    "SpMVService", "GuardedImpl", "CircuitBreaker", "GuardError",
    "AdmissionError", "EvictedError", "faults",
    "PlanStore", "fingerprint_key", "MatrixValidationError",
    # formats + construction
    "CSR", "CCS", "COO", "ELL", "BCSR", "BucketedELL", "MatrixStats",
    "memory_bytes", "csr_from_dense", "csr_from_rows", "TRANSFORMS_HOST",
    # observability (repro.obs is the full surface; these are the usuals)
    "obs", "Telemetry", "InMemorySink", "JsonlSink", "FakeClock",
    # policy + deprecated shims
    "MemoryPolicy", "Decision", "AutoTunedSpMV",
    "decide_paper", "decide_generalized", "decide_cost_model",
]
