"""Multi-device sharded SpMV/SpMM: per-shard ExecutionPlans on a mesh.

The distributed execution tier.  A host CSR is cut into contiguous slabs
along one axis by the partition strategies lifted to device-count
granularity (``partition_for_devices``), the :class:`~repro.core.plan.Planner`
runs independently per slab so every device gets its own format + launch
geometry, and the resulting :class:`ShardedPlannedMatrix` serves
``P @ x`` / ``P @ X`` across the mesh.

Collective structure (see docs/sharding.md for the cost table):

  * ``axis="row"``   — x is replicated, each device multiplies its row slab
    locally, and the outputs reassemble by *concatenation alone* (the
    partitioner never sorts rows, so slabs stay contiguous in the original
    row order and no scatter collective is needed).
  * ``axis="col"``   — x is replicated then each device slices its column
    window (the gather step), multiplies its column slab locally into a
    full-length partial y, and a single ``psum`` reduces the partials.

Execution modes — the resolution of a real tension: per-shard plans are
*heterogeneous* (that is the point), but ``jax.shard_map`` wants one SPMD
program with uniform shapes:

  * ``"shard_map"`` — the collective-scaled path.  Slab CSRs are padded to
    a common (rows_pad, nnz_pad) envelope, stacked with a leading device
    axis sharded ``P("shards")``, and one program runs the reference CSR
    op per device (pad entries are val=0/col=0, so they contribute
    nothing).  Uniform by construction; per-shard format choices are
    recorded in the plan but not applied here.
  * ``"dispatch"``  — the format-faithful path.  Each shard binds its own
    :class:`~repro.core.plan.PlannedMatrix` (own format, tier, geometry),
    placed round-robin across devices; JAX's async dispatch overlaps the
    per-shard launches.  Works with more shards than devices (and on a
    single device, which is how the in-process tests run).
  * ``"auto"``      — ``shard_map`` when the mesh has at least one device
    per shard, else ``dispatch``.  A 1-shard plan degenerates to the
    single-plan path of PR 5 (mode ``"single"``).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

import repro.obs as _obs
from repro.core.formats import CSR, memory_bytes
from repro.core.plan import (PlanError, Planner, ShardedPlan,
                             shard_boundaries, slice_shard)
from repro.core.spmv import spmm_csr, spmv_csr


# ---------------------------------------------------------------------------
# partitioning the host matrix
# ---------------------------------------------------------------------------
def shard_csr(csr: CSR, n_shards: int, axis: str = "row",
              strategy: str = "balanced_nnz",
              **strategy_kw) -> Tuple[np.ndarray, List[CSR]]:
    """Cut ``csr`` into ``n_shards`` slabs along ``axis``; returns
    ``(boundaries, [slab CSRs])``.  Row slabs keep the full column space;
    column slabs keep the full row space with columns rebased to 0."""
    b = shard_boundaries(csr, n_shards, axis=axis, strategy=strategy,
                         **strategy_kw)
    subs = [slice_shard(csr, int(s), int(e), axis=axis)
            for s, e in zip(b[:-1], b[1:])]
    return b, subs


def _slice_for(csr: CSR, boundaries: np.ndarray, axis: str) -> List[CSR]:
    return [slice_shard(csr, int(s), int(e), axis=axis)
            for s, e in zip(boundaries[:-1], boundaries[1:])]


def _imbalance(subs: Sequence[CSR]) -> float:
    nnzs = np.array([m.nnz for m in subs], dtype=np.float64)
    return float(nnzs.max() / max(nnzs.mean(), 1.0))


# ---------------------------------------------------------------------------
# the SPMD envelope (shard_map mode)
# ---------------------------------------------------------------------------
def _stack_shards(subs: Sequence[CSR]):
    """Pad every slab to a common (rows_pad, nnz_pad) envelope and stack
    with a leading device axis.  Pad entries are val=0/col=0 (harmless
    for SpMV) and indptr extends flat, so padded rows produce zeros."""
    rows_pad = max(m.n_rows for m in subs)
    nnz_pad = max(m.nnz_pad for m in subs)
    width_pad = max(m.n_cols for m in subs)
    datas, colss, ips = [], [], []
    for m in subs:
        d = np.zeros(nnz_pad, dtype=np.asarray(m.data).dtype)
        c = np.zeros(nnz_pad, dtype=np.int32)
        d[:m.nnz_pad] = np.asarray(m.data)
        c[:m.nnz_pad] = np.asarray(m.cols)
        ip = np.asarray(m.indptr, dtype=np.int32)
        ipp = np.full(rows_pad + 1, ip[-1], dtype=np.int32)
        ipp[:ip.shape[0]] = ip
        datas.append(d)
        colss.append(c)
        ips.append(ipp)
    return (np.stack(datas), np.stack(colss), np.stack(ips),
            rows_pad, nnz_pad, width_pad)


def _mesh_for(n_shards: int, axis_name: str,
              devices: Optional[Sequence[Any]] = None,
              mesh: Optional[Any] = None):
    """A 1-D mesh of exactly ``n_shards`` devices named ``axis_name`` —
    the caller's mesh when it already fits, else the first ``n_shards``
    of the given (or all) devices."""
    if mesh is not None:
        if axis_name in mesh.axis_names \
                and dict(mesh.shape)[axis_name] == n_shards:
            return mesh
        devices = list(np.asarray(mesh.devices).flatten())
    devs = list(devices if devices is not None else jax.devices())
    if len(devs) < n_shards:
        raise PlanError(
            f"shard_map mode needs >= {n_shards} devices for {n_shards} "
            f"shards; have {len(devs)} (use mode='dispatch', or set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return jax.sharding.Mesh(np.asarray(devs[:n_shards]), (axis_name,))


def _make_shard_map_fns(stacked, axis: str, mesh, axis_name: str,
                        shape: Tuple[int, int], boundaries: np.ndarray):
    """jit-compiled SpMV/SpMM dispatchers over the stacked envelope.

    Row axis: local products, outputs laid out shard-major (out_specs
    ``P(axis_name)``), unpadded outside by static slices — zero
    collectives.  Column axis: replicated x, per-device offset +
    ``dynamic_slice`` (the gather), full-length partials, one psum."""
    data_s, cols_s, ip_s, rows_pad, nnz_pad, width_pad = stacked
    n_rows, n_cols = shape
    sharded = jax.sharding.NamedSharding(mesh, P(axis_name))
    data_s = jax.device_put(jnp.asarray(data_s), sharded)
    cols_s = jax.device_put(jnp.asarray(cols_s), sharded)
    ip_s = jax.device_put(jnp.asarray(ip_s), sharded)
    from jax.experimental.shard_map import shard_map

    if axis == "row":
        rows_per = np.diff(boundaries)

        def _exec(op, x):
            def body(d, c, ip, xx):
                local = CSR(data=d[0], cols=c[0], indptr=ip[0],
                            shape=(rows_pad, n_cols), nnz=nnz_pad)
                return op(local, xx)

            out = shard_map(
                body, mesh=mesh,
                in_specs=(P(axis_name), P(axis_name), P(axis_name), P()),
                out_specs=P(axis_name))(data_s, cols_s, ip_s, x)
            # each device owns rows_pad output rows; keep the valid head
            # of every slab and concatenate — static slices, no collective
            return jnp.concatenate(
                [out[i * rows_pad: i * rows_pad + int(r)]
                 for i, r in enumerate(rows_per)])
    else:
        offs = jax.device_put(
            jnp.asarray(boundaries[:-1], dtype=jnp.int32), sharded)

        def _exec(op, x):
            pads = ((0, width_pad),) + ((0, 0),) * (x.ndim - 1)
            xp = jnp.pad(x, pads)  # slices never clamp

            def body(d, c, ip, off, xx):
                start = (off[0],) + (0,) * (xx.ndim - 1)
                size = (width_pad,) + xx.shape[1:]
                xl = jax.lax.dynamic_slice(xx, start, size)  # the gather
                local = CSR(data=d[0], cols=c[0], indptr=ip[0],
                            shape=(n_rows, width_pad), nnz=nnz_pad)
                return jax.lax.psum(op(local, xl), axis_name)

            return shard_map(
                body, mesh=mesh,
                in_specs=(P(axis_name), P(axis_name), P(axis_name),
                          P(axis_name), P()),
                out_specs=P())(data_s, cols_s, ip_s, offs, xp)

    fns = {"spmv": jax.jit(lambda x: _exec(spmv_csr, x)),
           "spmm": jax.jit(lambda x: _exec(spmm_csr, x))}
    nbytes = int(data_s.nbytes + cols_s.nbytes + ip_s.nbytes)
    return fns, nbytes


# ---------------------------------------------------------------------------
# the bound sharded operator
# ---------------------------------------------------------------------------
class ShardedPlannedMatrix:
    """A :class:`~repro.core.plan.ShardedPlan` applied to a concrete
    matrix.  ``y = P @ x`` dispatches on x's rank exactly like
    :class:`~repro.core.plan.PlannedMatrix` — 1-D serves SpMV,
    ``(n_cols, B)`` serves SpMM — executed across the mesh per the
    resolved mode (see the module docstring)."""

    def __init__(self, plan: ShardedPlan, source: CSR, mode: str,
                 boundaries: np.ndarray, fingerprint_matched: bool,
                 planned: Optional[List[Any]] = None,
                 exec_fns: Optional[Dict[str, Any]] = None,
                 mesh: Optional[Any] = None, nbytes: int = 0,
                 shard_nnz: Optional[List[int]] = None):
        self.plan = plan
        self.source = source
        self.mode = mode
        self.boundaries = np.asarray(boundaries, dtype=np.int64)
        self.fingerprint_matched = fingerprint_matched
        self.planned = planned          # dispatch/single: per-shard bound
        self.mesh = mesh
        self.shard_nnz = list(shard_nnz or [])
        self._exec_fns = exec_fns       # shard_map: jitted dispatchers
        self._nbytes = nbytes
        self._devices = []
        self.shard_guards: List[Dict[str, Any]] = []
        if planned is not None and mode == "dispatch":
            devs = jax.devices()
            self._devices = [devs[i % len(devs)]
                             for i in range(len(planned))]
            for pm, dev in zip(planned, self._devices):
                pm.matrix = jax.device_put(pm.matrix, dev)
            self.shard_guards = self._build_shard_guards()

    def _build_shard_guards(self) -> List[Dict[str, Any]]:
        """Dispatch mode serves shards one by one on the host, so each
        shard gets its own degradation ladder: the bound per-shard impl
        backed by reference-CSR on that shard's source slice.  Exception
        faults demote a single shard instead of failing the whole product;
        finiteness is *not* probed per shard (that would add one device
        sync per shard per call) — the service-level guard already probes
        the assembled output end-to-end."""
        # lazy: sharding must stay importable without the serve package
        from repro.core.spmv import spmv as _spmv_ref
        from repro.core import dispatch as _dispatch
        from repro.serve.guard import guard_ladder
        ref_mv = jax.jit(_spmv_ref)
        ref_mm = jax.jit(_dispatch.get_impl("csr", "spmm", "reference"))
        guards = []
        for i, pm in enumerate(self.planned):
            src = pm.source
            guards.append({
                "spmv": guard_ladder(
                    f"shard{i}", "spmv",
                    [("tuned", lambda xi, _pm=pm: _pm.spmv(xi)),
                     ("csr", lambda xi, _s=src: ref_mv(_s, xi))],
                    fmt=pm.fmt, probe_finite=False),
                "spmm": guard_ladder(
                    f"shard{i}", "spmm",
                    [("tuned", lambda xi, _pm=pm: _pm.spmm(xi)),
                     ("csr", lambda xi, _s=src: ref_mm(_s, xi))],
                    fmt=pm.fmt, probe_finite=False),
            })
        return guards

    def guard_report(self) -> List[Dict[str, Any]]:
        """Per-shard ladder snapshots (dispatch mode; empty otherwise)."""
        return [{op: g.snapshot() for op, g in shard.items()}
                for shard in self.shard_guards]

    # -- views ---------------------------------------------------------------
    fmt = "sharded"

    @property
    def shape(self) -> Tuple[int, int]:
        return self.source.shape

    @property
    def n_rows(self) -> int:
        return self.source.shape[0]

    @property
    def n_cols(self) -> int:
        return self.source.shape[1]

    @property
    def n_shards(self) -> int:
        return self.plan.n_shards

    @property
    def n_blocks(self) -> int:
        # the serving layer's block-count view: one block per shard
        return self.plan.n_shards

    @property
    def axis(self) -> str:
        return self.plan.axis

    def nbytes(self) -> int:
        if self.planned is not None:
            return sum(memory_bytes(pm.matrix) for pm in self.planned)
        return self._nbytes

    def report(self) -> List[Dict[str, Any]]:
        """Per-shard decision summary: slab extent, format, tier, nnz."""
        out = []
        b = self.boundaries
        for i, bp in enumerate(self.plan.shards):
            out.append({"shard": i, "rows": (int(b[i]), int(b[i + 1])),
                        "fmt": bp.plan.fmt, "tier": bp.plan.tier,
                        "nnz": (self.shard_nnz[i]
                                if i < len(self.shard_nnz)
                                else bp.plan.fingerprint.nnz
                                if bp.plan.fingerprint else -1)})
        return out

    # -- execution -----------------------------------------------------------
    def _check(self, x: jnp.ndarray, op: str) -> jnp.ndarray:
        x = jnp.asarray(x)
        want = 1 if op == "spmv" else 2
        if x.ndim != want or x.shape[0] != self.n_cols:
            shape = (f"({self.n_cols},)" if op == "spmv"
                     else f"({self.n_cols}, B)")
            raise ValueError(f"{op} expects x of shape {shape}; "
                             f"got {x.shape}")
        return x

    def _run_dispatch(self, op: str, x: jnp.ndarray,
                      tel) -> jnp.ndarray:
        b = self.boundaries
        parts = []
        for i, pm in enumerate(self.planned):
            with tel.span("shard.spmv", shard=i, fmt=pm.fmt,
                          mode="dispatch"):
                if self.axis == "row":
                    xi = x
                else:
                    with tel.span("shard.gather", shard=i):
                        xi = x[int(b[i]): int(b[i + 1])]
                if self.shard_guards:
                    parts.append(self.shard_guards[i][op](xi))
                else:
                    parts.append(getattr(pm, op)(xi))
        if self._devices:
            # partials live where their shards ran; reassembly needs them
            # on one device (concatenate/add refuse cross-device args)
            home = self._devices[0]
            parts = [jax.device_put(p, home) for p in parts]
        if self.axis == "row":
            return jnp.concatenate(parts)
        total = parts[0]
        for p in parts[1:]:
            total = total + p
        return total

    def _apply(self, op: str, x: jnp.ndarray) -> jnp.ndarray:
        x = self._check(x, op)
        tel = _obs.get()
        with tel.span("sharded.spmv", op=op, mode=self.mode,
                      axis=self.axis, n_shards=self.n_shards):
            if self.mode == "single":
                return getattr(self.planned[0], op)(x)
            if self.mode == "dispatch":
                return self._run_dispatch(op, x, tel)
            if self.axis == "col":
                with tel.span("shard.gather", mode="shard_map",
                              n_shards=self.n_shards):
                    x = jnp.asarray(x)   # replicate once, sliced in-body
            return self._exec_fns[op](x)

    def spmv(self, x) -> jnp.ndarray:
        return self._apply("spmv", x)

    def spmm(self, x) -> jnp.ndarray:
        return self._apply("spmm", x)

    def __matmul__(self, x) -> jnp.ndarray:
        x = jnp.asarray(x)
        return self.spmv(x) if x.ndim == 1 else self.spmm(x)

    def __call__(self, x) -> jnp.ndarray:
        return self @ x

    def __repr__(self) -> str:
        return (f"ShardedPlannedMatrix(n_shards={self.n_shards}, "
                f"axis={self.axis!r}, mode={self.mode!r}, "
                f"shape={self.shape}, formats={self.plan.shard_formats()}, "
                f"fingerprint_matched={self.fingerprint_matched})")


# ---------------------------------------------------------------------------
# binding
# ---------------------------------------------------------------------------
def _resolve_mode(mode: str, n_shards: int,
                  devices: Optional[Sequence[Any]],
                  mesh: Optional[Any]) -> str:
    if n_shards == 1:
        return "single"
    if mode == "auto":
        n_avail = (int(np.asarray(mesh.devices).size) if mesh is not None
                   else len(devices if devices is not None
                            else jax.devices()))
        return "shard_map" if n_avail >= n_shards else "dispatch"
    if mode not in ("shard_map", "dispatch", "single"):
        raise PlanError(f"unknown mode {mode!r}; one of "
                        "('auto', 'shard_map', 'dispatch', 'single')")
    return mode


def build_sharded(csr: CSR, *, plan: Optional[ShardedPlan] = None,
                  planner: Optional[Planner] = None, db: Optional[Any] = None,
                  n_shards: Optional[int] = None, axis: str = "row",
                  strategy: str = "balanced_nnz", mode: str = "auto",
                  devices: Optional[Sequence[Any]] = None,
                  mesh: Optional[Any] = None, batch: int = 1,
                  strategy_kw: Optional[Dict[str, Any]] = None,
                  **plan_kw) -> ShardedPlannedMatrix:
    """Partition + per-shard plan + mesh execution in one call.

    Without ``plan``, a :class:`Planner` (the given one, or a fresh one
    over ``db``) mints a :class:`ShardedPlan` for ``csr`` first.  With
    ``plan``, the recorded decisions replay with zero re-tuning; a
    fingerprint mismatch keeps the recipe — axis, strategy, shard count,
    per-shard formats — but re-partitions on the new matrix (per-shard
    geometry re-resolves exactly like PR 5 single plans)."""
    tel = _obs.get()
    if plan is None:
        planner = planner or Planner(db=db)
        if n_shards is None:
            n_shards = (int(np.asarray(mesh.devices).size)
                        if mesh is not None
                        else len(devices if devices is not None
                                 else jax.devices()))
        plan = planner.plan_sharded(csr, n_shards=n_shards, axis=axis,
                                    strategy=strategy, batch=batch,
                                    strategy_kw=strategy_kw, **plan_kw)
        if db is None:
            db = planner.db
    matched = plan.matches(csr)

    with tel.span("sharded.bind", n_shards=plan.n_shards, axis=plan.axis,
                  matched=matched) as sp:
        if matched:
            boundaries = plan.boundaries()
        else:
            boundaries = shard_boundaries(csr, plan.n_shards,
                                          axis=plan.axis,
                                          strategy=plan.strategy,
                                          **plan.params)
        subs = _slice_for(csr, boundaries, plan.axis)
        imb = _imbalance(subs)
        tel.gauge("sharded.load_imbalance").set(imb)
        shard_nnz = [m.nnz for m in subs]
        resolved = _resolve_mode(mode, plan.n_shards, devices, mesh)
        sp.set(mode=resolved, imbalance=imb)

        if resolved == "shard_map":
            m = _mesh_for(plan.n_shards, plan.mesh_axis, devices, mesh)
            fns, nbytes = _make_shard_map_fns(
                _stack_shards(subs), plan.axis, m, plan.mesh_axis,
                csr.shape, boundaries)
            return ShardedPlannedMatrix(
                plan, csr, "shard_map", boundaries, matched,
                exec_fns=fns, mesh=m, nbytes=nbytes, shard_nnz=shard_nnz)

        planned = [bp.plan.bind(sub, db=db)
                   for bp, sub in zip(plan.shards, subs)]
        return ShardedPlannedMatrix(
            plan, csr, resolved, boundaries, matched, planned=planned,
            shard_nnz=shard_nnz)


__all__ = ["ShardedPlannedMatrix", "build_sharded", "shard_csr"]
