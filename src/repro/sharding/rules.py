"""Logical-axis parameter specs and mesh-shape-agnostic sharding rules.

Every parameter is declared once as a ``ParamSpec`` (shape + logical axis
names + initializer).  ``init_params`` materializes the tree, ``axes_tree``
yields the parallel tree of logical axes, and ``ShardingRules`` maps logical
axes onto whatever mesh is in scope — the same model config therefore lowers
on 1 device, one 256-chip pod, or a 512-chip multi-pod mesh (elastic
scaling; DESIGN.md §6).

Default placement (production posture):
  * ``batch``   -> ("pod", "data")   — DP across pods and the data axis
  * ``embed``   -> "data"            — FSDP/ZeRO-3: weights (and optimizer
                                       states, which inherit param specs)
                                       sharded over the data axis
  * ``heads`` / ``kv_heads`` / ``ffn`` / ``vocab`` / ``experts`` -> "model"
                                       — tensor/expert parallelism
  * ``seq_kv``  -> "data"            — context parallelism for long-context
                                       decode (B=1): the KV cache shards by
                                       sequence; GSPMD inserts the
                                       flash-decoding partial-softmax combine
  * anything unknown                 -> replicated
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Tuple[Optional[str], ...]


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Axes
    init: str = "normal"        # normal | zeros | ones | embed
    scale: Optional[float] = None  # None -> 1/sqrt(fan_in) for "normal"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def stack_spec(spec_tree: Any, reps: int, axis_name: Optional[str] = None) -> Any:
    """Add a leading (reps,) 'layers' dimension to every spec — the stacked
    parameter layout consumed by lax.scan over layer repetitions."""
    def f(s: ParamSpec) -> ParamSpec:
        return ParamSpec(shape=(reps,) + s.shape, axes=(axis_name,) + s.axes,
                         init=s.init, scale=s.scale)
    return jax.tree.map(f, spec_tree,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def _init_leaf(key: jax.Array, s: ParamSpec, dtype) -> jax.Array:
    if s.init == "zeros":
        return jnp.zeros(s.shape, dtype)
    if s.init == "ones":
        return jnp.ones(s.shape, dtype)
    if s.init == "embed":
        return (jax.random.normal(key, s.shape, jnp.float32)).astype(dtype)
    fan_in = s.shape[-2] if len(s.shape) >= 2 else max(s.shape[-1], 1)
    scale = s.scale if s.scale is not None else 1.0 / np.sqrt(fan_in)
    return (scale * jax.random.normal(key, s.shape, jnp.float32)).astype(dtype)


def init_params(key: jax.Array, spec_tree: Any, dtype=jnp.float32) -> Any:
    """Materialize a spec tree into parameter arrays (deterministic per path)."""
    leaves, treedef = jax.tree.flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, max(len(leaves), 1))
    vals = [_init_leaf(k, s, dtype) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def axes_tree(spec_tree: Any) -> Any:
    return jax.tree.map(lambda s: s.axes, spec_tree,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def eval_shape_params(spec_tree: Any, dtype=jnp.float32) -> Any:
    """ShapeDtypeStruct tree (no allocation) — used by the dry-run."""
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
                        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def param_count(spec_tree: Any) -> int:
    return sum(int(np.prod(s.shape)) for s in jax.tree.leaves(
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec)))


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------
MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclass(frozen=True)
class ShardingRules:
    """Map logical axis name -> mesh axis (or tuple of mesh axes)."""
    rules: Dict[str, MeshAxes]

    def spec_for(self, axes: Axes, mesh: Mesh,
                 shape: Optional[Tuple[int, ...]] = None) -> P:
        entries = []
        used: set = set()
        msize = dict(mesh.shape)
        for i, ax in enumerate(axes):
            m = self.rules.get(ax) if ax is not None else None
            # drop mesh axes not present in this mesh (elastic) or already
            # used by an earlier dim (PartitionSpec axes must be unique)
            if isinstance(m, tuple):
                m = tuple(a for a in m if a in mesh.axis_names and a not in used)
                m = m if m else None
            elif isinstance(m, str):
                m = m if (m in mesh.axis_names and m not in used) else None
            # shape-aware: drop when the dim does not divide evenly
            # (activation constraints must not force padding in hot loops)
            if m is not None and shape is not None:
                parts = (np.prod([msize[a] for a in m])
                         if isinstance(m, tuple) else msize[m])
                if shape[i] % int(parts) != 0:
                    if isinstance(m, tuple):
                        # try a prefix that still divides
                        while m and shape[i] % int(np.prod(
                                [msize[a] for a in m])) != 0:
                            m = m[:-1]
                        m = m if m else None
                    else:
                        m = None
            if m is not None:
                used.update(m if isinstance(m, tuple) else (m,))
            entries.append(m)
        return P(*entries)

    def sharding_for(self, axes: Axes, mesh: Mesh,
                     shape: Optional[Tuple[int, ...]] = None) -> NamedSharding:
        return NamedSharding(mesh, self.spec_for(axes, mesh, shape))


RULES_1POD = ShardingRules(rules={
    "batch": ("pod", "data"),
    "embed": "data",            # FSDP axis for weights
    "embed_act": None,          # activations keep embed replicated
    "heads": "model",
    "kv_heads": "model",
    "q_dim": "model",
    "ffn": "model",
    "experts": "model",
    "vocab": "model",
    "embed_tp": "model",        # embed-table d-dim TP (local token gather)
    "seq": None,
    "seq_sp": "model",          # sequence parallelism on the residual stream
    "seq_kv": "data",           # context parallelism (long-context decode)
    "layers": None,
    "conv": None,
    "state": None,
    "inner": "model",           # SSM/xLSTM expanded inner dim
})

# Multi-pod: FSDP/ZeRO additionally spans the pod axis — parameters and
# optimizer states shard across all 512 chips (params+opt for the 132B MoE
# halve from 8.3 to 4.1 GB/chip; the cost is pod-crossing all-gathers,
# which the int8 compression path (optim.compress) targets).
RULES_2POD = ShardingRules(rules={**RULES_1POD.rules,
                                  "embed": ("data", "pod")})

# §Perf (serving): weight-stationary sharding.  Decode activations are
# tiny (B x d bf16 ~ 1.5 MB), so they REPLICATE over batch and shard their
# d dim over 'data' — exactly the weights' FSDP axis.  Every matmul then
# contracts a dim sharded identically on both operands: partial sums +
# KB-scale activation all-reduces replace the GB-scale per-step weight
# all-gathers (measured on dbrx decode_32k).  KV caches stay batch-sharded.
RULES_SERVE = ShardingRules(rules={**RULES_1POD.rules,
                                   "batch": None,
                                   "embed_act": "data"})

# §Perf (small-model training): ZeRO-1 — parameters replicated (they fit),
# optimizer moments still sharded over 'data'.  Per-layer FSDP weight
# all-gathers disappear; the single post-update parameter all-gather
# remains (it is the out_shardings transition).
RULES_ZERO1 = ShardingRules(rules={**RULES_1POD.rules, "embed": None})


def rules_for_mesh(mesh: Mesh) -> ShardingRules:
    return RULES_2POD if "pod" in mesh.axis_names else RULES_1POD


def logical_to_sharding(spec_tree: Any, mesh: Mesh,
                        rules: ShardingRules = RULES_1POD) -> Any:
    """ParamSpec tree -> NamedSharding tree (shape-aware: jit argument
    shardings must divide dims evenly, so non-dividing axes are dropped)."""
    return jax.tree.map(
        lambda s: rules.sharding_for(s.axes, mesh, s.shape), spec_tree,
        is_leaf=lambda x: isinstance(x, ParamSpec))


_ACTIVE_RULES: list = []


class use_rules:
    """Context manager: activation-constraint rules for code traced inside
    (e.g. RULES_SERVE for weight-stationary decode)."""

    def __init__(self, rules: ShardingRules):
        self.rules = rules

    def __enter__(self):
        _ACTIVE_RULES.append(self.rules)
        return self.rules

    def __exit__(self, *exc):
        _ACTIVE_RULES.pop()
        return False


def active_rules() -> ShardingRules:
    return _ACTIVE_RULES[-1] if _ACTIVE_RULES else RULES_1POD


def with_logical_constraint(x: jax.Array, axes: Axes,
                            mesh: Optional[Mesh] = None,
                            rules: Optional[ShardingRules] = None
                            ) -> jax.Array:
    """Annotate an activation with a logical sharding constraint.  A no-op
    outside a mesh context (CPU smoke tests); shape-aware (axes that do not
    divide the dim are dropped).  Rules default to the active context
    (``use_rules``), falling back to RULES_1POD."""
    mesh = mesh or _current_mesh()
    if mesh is None or mesh.empty:
        return x
    rules = rules or active_rules()
    return jax.lax.with_sharding_constraint(
        x, rules.sharding_for(axes, mesh, tuple(x.shape)))


def _current_mesh() -> Optional[Mesh]:
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        return m if m is not None and not m.empty else None
    except (ImportError, AttributeError):
        return None                # private-API probe; jax moved it


__all__ = [
    "ParamSpec", "stack_spec", "init_params", "axes_tree",
    "eval_shape_params", "param_count", "ShardingRules", "RULES_1POD",
    "RULES_2POD", "RULES_SERVE", "RULES_ZERO1", "rules_for_mesh",
    "use_rules", "active_rules", "logical_to_sharding",
    "with_logical_constraint",
]
