"""Sharding tier: mesh-elastic parameter rules and the distributed
SpMV/SpMM executor (``ShardedPlannedMatrix``, docs/sharding.md)."""
from .rules import (ParamSpec, ShardingRules, RULES_1POD, RULES_2POD,
                    RULES_SERVE, RULES_ZERO1, active_rules, axes_tree,
                    eval_shape_params, init_params, logical_to_sharding,
                    param_count, rules_for_mesh, stack_spec, use_rules,
                    with_logical_constraint)
from .spmv import ShardedPlannedMatrix, build_sharded, shard_csr

__all__ = [
    "ParamSpec", "ShardingRules", "RULES_1POD", "RULES_2POD",
    "RULES_SERVE", "RULES_ZERO1", "rules_for_mesh", "use_rules",
    "active_rules", "axes_tree", "eval_shape_params", "init_params",
    "logical_to_sharding", "param_count", "stack_spec",
    "with_logical_constraint",
    "ShardedPlannedMatrix", "build_sharded", "shard_csr",
]
