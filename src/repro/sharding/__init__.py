from .rules import (ParamSpec, ShardingRules, RULES_1POD, RULES_2POD,
                    axes_tree, init_params, logical_to_sharding, param_count,
                    stack_spec, with_logical_constraint)
