"""Guarded execution: degrade down a ladder instead of dying.

The paper's whole premise is that the tuned path (ELL/SELL + run-time
transformation, Pallas launch geometry) is an *optimization over* an
always-correct CRS baseline — ``k·B·(t_crs − t_f) > t_trans`` only pays
off because falling back to CRS is always possible and cheap.  This module
makes that fallback a first-class serving construct:

* :class:`GuardedImpl` — wraps one operator (a ``(key, op)`` pair in the
  service) as an ordered ladder of rungs, e.g.::

      tuned (kernel-tier hybrid)  →  reference-format  →  reference CSR

  A call runs the highest healthy rung; a failure — exception, non-finite
  output (cheap ``isfinite`` probe), or blown wall-clock budget — demotes
  the call down the ladder transparently.  The last rung is the semantic
  oracle and is never probed: whatever it returns is the answer.

* :class:`CircuitBreaker` — per ``(key, format, op)``: after ``failures``
  consecutive tuned-rung failures the breaker *opens* and calls skip the
  broken rung outright (stop paying the failure cost per call); after
  ``cooldown_s`` it goes *half-open* and lets exactly one probe call
  through — success closes it (tuned tier restored), failure re-opens it.

Failure detection, fallbacks, and breaker transitions are exported through
:mod:`repro.obs` (``service.fallback`` / ``guard.failure`` counters,
``guard.breaker`` events) and surface in ``SpMVService.stats()``.

Fault injection (:mod:`repro.serve.faults`) is threaded through the tuned
rung only — ``kernel.raise`` raises before it runs, ``kernel.nan``
poisons its output — so the whole ladder is testable deterministically;
the fallback rungs run clean, which is exactly the claim being tested:
injected tuned-tier failures never change served results.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import repro.obs as _obs
from repro.serve import faults as _faults

#: breaker states
CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"

#: numeric encoding of the state machine for the ``service.breaker_state``
#: gauge (Prometheus gauges carry floats, not strings)
STATE_CODES = {CLOSED: 0, OPEN: 1, HALF_OPEN: 2}


class GuardError(RuntimeError):
    """Every rung of a guarded ladder failed.  Carries the per-rung
    failures so the caller can see the whole cascade, not just the last
    straw."""

    def __init__(self, key: str, op: str,
                 causes: Sequence[Tuple[str, BaseException]]):
        lines = "; ".join(f"{rung}: {e!r}" for rung, e in causes)
        super().__init__(
            f"all {len(causes)} rungs failed for ({key!r}, {op!r}): {lines}")
        self.key = key
        self.op = op
        self.causes = list(causes)


@dataclass
class CircuitBreaker:
    """Closed → open after ``failures`` consecutive failures → half-open
    probe after ``cooldown_s`` → closed on probe success.  All timestamps
    come from ``clock`` so tests drive it with a FakeClock (no sleeps)."""
    key: str = ""
    fmt: str = ""
    op: str = ""
    failures: int = 3
    cooldown_s: float = 30.0
    clock: Callable[[], float] = time.perf_counter
    state: str = CLOSED
    consecutive: int = 0
    opened_at: float = 0.0
    opens: int = 0                 # lifetime closed→open transitions
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False)

    def allow(self) -> bool:
        """Whether the guarded rung may run now.  An open breaker past its
        cooldown transitions to half-open and admits exactly one probe."""
        with self._lock:
            if self.state == CLOSED:
                return True
            if self.state == OPEN:
                if self.clock() - self.opened_at >= self.cooldown_s:
                    self._transition(HALF_OPEN)
                    return True        # the probe call
                return False
            # HALF_OPEN: one probe is already in flight; further calls
            # skip the rung until it reports back
            return False

    def record_success(self) -> None:
        with self._lock:
            self.consecutive = 0
            if self.state != CLOSED:
                self._transition(CLOSED)

    def record_failure(self) -> None:
        with self._lock:
            self.consecutive += 1
            if self.state == HALF_OPEN or (self.state == CLOSED and
                                           self.consecutive >= self.failures):
                self.opened_at = self.clock()
                self.opens += 1
                self._transition(OPEN)

    def _transition(self, to: str) -> None:
        frm, self.state = self.state, to
        tel = _obs.get()
        if tel.enabled:
            tel.event("guard.breaker", key=self.key, fmt=self.fmt,
                      op=self.op, frm=frm, to=to,
                      consecutive=self.consecutive)
            tel.gauge("guard.breaker_open", key=self.key, fmt=self.fmt,
                      op=self.op).set(1.0 if to == OPEN else 0.0)
            # full state machine as a labelled gauge (0=closed, 1=open,
            # 2=half_open) so dashboards see half-open probes, not just
            # the open/closed projection above
            tel.gauge("service.breaker_state", key=self.key, fmt=self.fmt,
                      op=self.op).set(float(STATE_CODES[to]))

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return {"state": self.state,
                    "state_code": STATE_CODES[self.state],
                    "consecutive": self.consecutive,
                    "opens": self.opens, "failures": self.failures,
                    "cooldown_s": self.cooldown_s}


@dataclass
class Rung:
    """One ladder level: a self-contained thunk from input to output."""
    name: str                       # e.g. "tuned", "reference", "csr"
    fn: Callable[[Any], Any]
    #: kernel fault points fire on this rung (the tuned tier only)
    inject: bool = False


class GuardedImpl:
    """One guarded operator: an ordered rung ladder plus the tuned rung's
    circuit breaker.  Stats are kept locally (cheap ints, no telemetry
    dependency) *and* mirrored to ``repro.obs`` when enabled."""

    def __init__(self, key: str, op: str, rungs: Sequence[Rung], *,
                 breaker: Optional[CircuitBreaker] = None,
                 probe_finite: bool = True,
                 budget_s: Optional[float] = None,
                 clock: Callable[[], float] = time.perf_counter,
                 fault_registry: Optional[Any] = None):
        if not rungs:
            raise ValueError("GuardedImpl needs at least one rung")
        self.key = key
        self.op = op
        self.rungs = list(rungs)
        self.breaker = breaker
        self.probe_finite = probe_finite
        self.budget_s = budget_s
        self.clock = clock
        self.faults = fault_registry
        self.calls = 0
        self.short_circuits = 0       # breaker-open skips of the top rung
        self.fallback_calls = 0       # calls served below the top rung
        self.served_by: Dict[str, int] = {r.name: 0 for r in self.rungs}
        self.failures: Dict[str, int] = {}   # "rung/reason" -> count

    # -- failure detection ---------------------------------------------------
    def _finite(self, y: Any) -> bool:
        import jax
        import jax.numpy as jnp
        return bool(jax.device_get(jnp.all(jnp.isfinite(y))))

    def _fail(self, rung: Rung, reason: str, tel) -> None:
        k = f"{rung.name}/{reason}"
        self.failures[k] = self.failures.get(k, 0) + 1
        if self.breaker is not None and rung is self.rungs[0]:
            self.breaker.record_failure()
        if tel.enabled:
            tel.counter("guard.failure", key=self.key, op=self.op,
                        rung=rung.name, reason=reason).inc()

    # -- the ladder ----------------------------------------------------------
    def __call__(self, x: Any) -> Any:
        self.calls += 1
        tel = _obs.get()
        reg = self.faults if self.faults is not None else _faults.get()
        causes: List[Tuple[str, BaseException]] = []
        start = 0
        if (self.breaker is not None and len(self.rungs) > 1
                and not self.breaker.allow()):
            # open breaker: stop paying the failure cost per call
            start = 1
            self.short_circuits += 1
            if tel.enabled:
                tel.counter("guard.short_circuit", key=self.key,
                            op=self.op).inc()
        last = len(self.rungs) - 1
        for i in range(start, len(self.rungs)):
            rung = self.rungs[i]
            try:
                if rung.inject:
                    reg.maybe_raise("kernel.raise")
                t0 = self.clock()
                y = rung.fn(x)
                if rung.inject and reg.should_fire("kernel.nan"):
                    import jax.numpy as jnp
                    y = jnp.full_like(y, jnp.nan)
                if i < last:
                    # the last rung is the oracle: served as-is, unprobed
                    if self.budget_s is not None:
                        import jax
                        jax.block_until_ready(y)
                        if self.clock() - t0 > self.budget_s:
                            self._fail(rung, "budget", tel)
                            causes.append((rung.name, TimeoutError(
                                f"rung {rung.name!r} blew its "
                                f"{self.budget_s}s budget")))
                            continue
                    if self.probe_finite and not self._finite(y):
                        self._fail(rung, "non_finite", tel)
                        causes.append((rung.name, FloatingPointError(
                            f"non-finite output from rung {rung.name!r}")))
                        continue
            except Exception as e:     # noqa: BLE001 — the ladder exists
                #                        to catch whatever the rung throws
                self._fail(rung, "exception", tel)
                causes.append((rung.name, e))
                continue
            # success
            self.served_by[rung.name] += 1
            if self.breaker is not None and i == 0:
                self.breaker.record_success()
            if i > 0:
                self.fallback_calls += 1
                if tel.enabled:
                    tel.counter("service.fallback", key=self.key,
                                op=self.op, rung=rung.name).inc()
                    tel.event("guard.degraded", key=self.key, op=self.op,
                              rung=rung.name,
                              causes=[f"{r}: {type(e).__name__}"
                                      for r, e in causes])
            return y
        raise GuardError(self.key, self.op, causes)

    # -- observability -------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        return {
            "rungs": [r.name for r in self.rungs],
            "calls": self.calls,
            "served_by": dict(self.served_by),
            "fallback_calls": self.fallback_calls,
            "short_circuits": self.short_circuits,
            "failures": dict(self.failures),
            "breaker": (self.breaker.snapshot()
                        if self.breaker is not None else None),
        }


def guard_ladder(key: str, op: str, rungs: Sequence[Tuple[str, Callable]],
                 *, fmt: str = "", breaker_failures: int = 3,
                 breaker_cooldown_s: float = 30.0,
                 probe_finite: bool = True,
                 budget_s: Optional[float] = None,
                 clock: Callable[[], float] = time.perf_counter,
                 breaker: Optional[CircuitBreaker] = None,
                 fault_registry: Optional[Any] = None) -> GuardedImpl:
    """Convenience constructor: ``rungs`` as (name, thunk) pairs, the
    first rung marked as the fault-injectable tuned tier, a fresh breaker
    unless one is shared in."""
    if breaker is None and len(rungs) > 1:
        breaker = CircuitBreaker(key=key, fmt=fmt, op=op,
                                 failures=breaker_failures,
                                 cooldown_s=breaker_cooldown_s, clock=clock)
    built = [Rung(name=n, fn=f, inject=(i == 0))
             for i, (n, f) in enumerate(rungs)]
    return GuardedImpl(key, op, built, breaker=breaker,
                       probe_finite=probe_finite, budget_s=budget_s,
                       clock=clock, fault_registry=fault_registry)


__all__ = ["CLOSED", "OPEN", "HALF_OPEN", "STATE_CODES", "GuardError",
           "CircuitBreaker", "Rung", "GuardedImpl", "guard_ladder"]
