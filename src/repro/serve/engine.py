"""Batched decode engine with continuous batching.

Fixed pool of B slots over one shared cache tree; per-slot sequence
lengths (the decode path takes a (B,) cache_len vector).  New requests are
admitted into idle slots by running a single-sequence prefill and
scatter-inserting its caches at the slot's batch index; completed slots
free immediately — the decode step never waits for the longest request.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                      # (S,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    generated: List[int] = field(default_factory=list)
    done: bool = False


def _insert_cache(caches, slot_caches, b: int):
    """Insert a single-sequence cache tree at batch index b."""
    return jax.tree.map(
        lambda full, one: _insert_leaf(full, one, b), caches, slot_caches)


def _insert_leaf(full: jax.Array, one: jax.Array, b: int) -> jax.Array:
    # cache leaves: stacked (reps, B, ...) or (B, ...); single-seq tree has
    # batch size 1 at the same position
    if full.ndim == one.ndim and one.shape[0] == 1 and \
            full.shape[0] != one.shape[0]:
        return jax.lax.dynamic_update_slice_in_dim(full, one.astype(
            full.dtype), b, axis=0)
    # stacked: batch is axis 1
    return jax.lax.dynamic_update_slice_in_dim(full, one.astype(full.dtype),
                                               b, axis=1)


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, max_batch: int = 4,
                 max_len: int = 256, dtype=jnp.float32):
        self.cfg = cfg
        self.params = params
        self.B = max_batch
        self.max_len = max_len
        self.caches = M.init_caches(cfg, max_batch, max_len, dtype)
        self.lengths = np.zeros(max_batch, np.int32)
        self.active: List[Optional[Request]] = [None] * max_batch
        self.last_tokens = np.zeros((max_batch, 1), np.int32)
        self.queue: List[Request] = []
        self.finished: Dict[int, Request] = {}
        self._next_rid = 0

        self._prefill = jax.jit(
            lambda p, b, c: M.prefill(p, b, c, cfg))
        self._decode = jax.jit(
            lambda p, t, c, n: M.decode_step(p, t, c, n, cfg))

    # -- request lifecycle ----------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               eos_id: Optional[int] = None) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid=rid, prompt=np.asarray(
            prompt, np.int32), max_new_tokens=max_new_tokens, eos_id=eos_id))
        return rid

    def _admit(self) -> None:
        for b in range(self.B):
            if self.active[b] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            S = len(req.prompt)
            one_caches = M.init_caches(self.cfg, 1, self.max_len,
                                       jax.tree.leaves(
                                           self.caches)[0].dtype)
            batch = {"tokens": jnp.asarray(req.prompt[None, :])}
            if self.cfg.frontend:
                batch["frontend_embeds"] = jnp.zeros(
                    (1, self.cfg.frontend_len, self.cfg.d_model),
                    jnp.float32)
            logits, one_caches = self._prefill(self.params, batch,
                                               one_caches)
            first = int(jnp.argmax(logits[0, -1]))
            self.caches = _insert_cache(self.caches, one_caches, b)
            self.active[b] = req
            self.lengths[b] = S + (self.cfg.frontend_len
                                   if self.cfg.frontend else 0)
            req.generated.append(first)
            self.last_tokens[b, 0] = first
            self._maybe_finish(b)

    def _maybe_finish(self, b: int) -> None:
        req = self.active[b]
        if req is None:
            return
        if (len(req.generated) >= req.max_new_tokens or
                (req.eos_id is not None and req.generated and
                 req.generated[-1] == req.eos_id) or
                int(self.lengths[b]) >= self.max_len - 1):
            req.done = True
            self.finished[req.rid] = req
            self.active[b] = None

    # -- one decode step for the whole pool ------------------------------------
    def step(self) -> int:
        self._admit()
        if not any(r is not None for r in self.active):
            return 0
        logits, self.caches = self._decode(
            self.params, jnp.asarray(self.last_tokens), self.caches,
            jnp.asarray(self.lengths))
        next_tokens = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1),
                                 np.int32)
        n_active = 0
        for b in range(self.B):
            req = self.active[b]
            if req is None:
                continue
            self.lengths[b] += 1
            tok = int(next_tokens[b])
            req.generated.append(tok)
            self.last_tokens[b, 0] = tok
            n_active += 1
            self._maybe_finish(b)
        return n_active

    def run(self, max_steps: int = 10_000) -> Dict[int, Request]:
        steps = 0
        while (self.queue or any(r is not None for r in self.active)) \
                and steps < max_steps:
            self.step()
            steps += 1
        return self.finished


__all__ = ["ServeEngine", "Request"]
