"""Deterministic fault injection for the resilience layer (stdlib-only).

The degradation ladder in :mod:`repro.serve.guard` is only trustworthy if
every rung can be *made* to fail on demand, in CI, without flaky
monkeypatching.  This module provides named **fault points** that the
pipeline consults at well-defined sites:

=================  ========================================================
point              where it fires
=================  ========================================================
``kernel.raise``   the tuned (kernel/hybrid) rung of a GuardedImpl raises
                   :class:`InjectedFault` before running
``kernel.nan``     the tuned rung's output is poisoned to NaN after running
                   (exercises the ``isfinite`` probe, not the except path)
``transform.raise``a host format conversion (``transform.host_csr_to_*``)
                   raises :class:`InjectedFault`
``store.corrupt``  :class:`~repro.core.plan_store.PlanStore.put` scribbles
                   over the entry it just wrote (exercises checksum
                   verification + quarantine on the next load)
``clock.skew``     every timestamp the ``SpMVService`` takes jumps forward
                   by ``SKEW_S`` (exercises deadline-flush robustness)
``delta.corrupt``  :func:`repro.stream.delta.apply_delta` poisons the
                   incrementally updated container right before validation
                   (exercises the degrade-to-full-re-transform path: a bad
                   delta apply must never serve wrong results)
=================  ========================================================

Faults are **deterministic**: each armed point draws from its own seeded
``random.Random``, so a probability-``p`` fault fires on the same calls in
every run.  Arm via code::

    from repro.serve import faults
    faults.arm("kernel.nan", prob=1.0, seed=0)
    ...
    faults.clear()                       # or faults.disarm("kernel.nan")

or through the environment — ``REPRO_FAULTS=point:prob:seed`` (comma
separated for several points; ``prob``/``seed`` optional, defaulting to
``1.0``/``0``)::

    REPRO_FAULTS=kernel.nan:1.0:0 python examples/quickstart.py

or scoped, for tests::

    with faults.inject("kernel.raise", prob=1.0, seed=3):
        ...

The registry is intentionally tiny and dependency-free: call sites pay one
dict lookup when nothing is armed, and the module imports no jax — the
*effect* of a fault (raising, poisoning an array) is produced by the call
site, the registry only answers "does this point fire now?" and counts.
"""
from __future__ import annotations

import os
import random
import threading
from typing import Dict, Optional, Tuple

#: the known fault-point vocabulary (arming an unknown point is an error —
#: a typo'd point would otherwise silently never fire)
FAULT_POINTS = ("kernel.raise", "kernel.nan", "transform.raise",
                "store.corrupt", "clock.skew", "delta.corrupt")

#: seconds a fired ``clock.skew`` adds to a timestamp
SKEW_S = 1.0


class InjectedFault(RuntimeError):
    """The failure an armed ``*.raise`` fault point produces.  A distinct
    type so tests (and swallowed-error accounting) can tell injected
    failures from organic ones."""

    def __init__(self, point: str):
        super().__init__(f"injected fault at {point!r}")
        self.point = point


class _Fault:
    __slots__ = ("point", "prob", "seed", "rng", "fired", "checked")

    def __init__(self, point: str, prob: float, seed: int):
        self.point = point
        self.prob = float(prob)
        self.seed = int(seed)
        self.rng = random.Random(int(seed))
        self.fired = 0
        self.checked = 0


class FaultRegistry:
    """Armed fault points + deterministic fire decisions.  One
    process-wide default lives behind :func:`get`; tests may construct
    their own and pass it to a GuardedImpl explicitly."""

    def __init__(self) -> None:
        self._armed: Dict[str, _Fault] = {}
        self._lock = threading.Lock()

    # -- arming --------------------------------------------------------------
    def arm(self, point: str, prob: float = 1.0, seed: int = 0) -> None:
        if point not in FAULT_POINTS:
            raise ValueError(f"unknown fault point {point!r}; one of "
                             f"{FAULT_POINTS}")
        if not (0.0 <= prob <= 1.0):
            raise ValueError(f"fault probability must be in [0, 1]; "
                             f"got {prob}")
        with self._lock:
            self._armed[point] = _Fault(point, prob, seed)

    def disarm(self, point: str) -> None:
        with self._lock:
            self._armed.pop(point, None)

    def clear(self) -> None:
        with self._lock:
            self._armed.clear()

    def armed(self, point: Optional[str] = None):
        """The armed points (names), or whether one specific point is."""
        with self._lock:
            if point is not None:
                return point in self._armed
            return tuple(sorted(self._armed))

    # -- firing --------------------------------------------------------------
    def should_fire(self, point: str) -> bool:
        """Deterministic decision for one arrival at ``point``.  Unarmed
        points cost a single dict lookup and never fire."""
        f = self._armed.get(point)
        if f is None:
            return False
        with self._lock:
            f.checked += 1
            fire = f.prob >= 1.0 or f.rng.random() < f.prob
            if fire:
                f.fired += 1
        return fire

    def maybe_raise(self, point: str) -> None:
        if self.should_fire(point):
            raise InjectedFault(point)

    def skew(self, t: float) -> float:
        """``clock.skew``'s effect: a fired reading jumps ``SKEW_S``
        forward; everything else passes through untouched."""
        return t + SKEW_S if self.should_fire("clock.skew") else t

    def counts(self) -> Dict[str, Dict[str, int]]:
        """Per-point ``{checked, fired}`` — the registry's own stats."""
        with self._lock:
            return {p: {"checked": f.checked, "fired": f.fired}
                    for p, f in self._armed.items()}

    # -- env bootstrap -------------------------------------------------------
    def arm_from_env(self, spec: Optional[str] = None) -> Tuple[str, ...]:
        """Arm every point in a ``REPRO_FAULTS``-style spec
        (``point[:prob[:seed]]``, comma separated).  Malformed entries
        raise — a chaos run with a typo'd spec must fail loudly, not run
        green with no faults."""
        spec = (os.environ.get("REPRO_FAULTS", "")
                if spec is None else spec).strip()
        if not spec:
            return ()
        armed = []
        for part in spec.split(","):
            fields = part.strip().split(":")
            if not fields[0]:
                continue
            point = fields[0]
            prob = float(fields[1]) if len(fields) > 1 and fields[1] else 1.0
            seed = int(fields[2]) if len(fields) > 2 and fields[2] else 0
            self.arm(point, prob=prob, seed=seed)
            armed.append(point)
        return tuple(armed)


class inject:
    """Scoped arming: ``with faults.inject("kernel.raise"): ...`` arms on
    entry and restores the point's previous state on exit."""

    def __init__(self, point: str, prob: float = 1.0, seed: int = 0,
                 registry: Optional[FaultRegistry] = None):
        self.point = point
        self.prob = prob
        self.seed = seed
        self.registry = registry

    def __enter__(self) -> FaultRegistry:
        reg = self.registry if self.registry is not None else get()
        self._reg = reg
        self._was_armed = reg.armed(self.point)
        reg.arm(self.point, prob=self.prob, seed=self.seed)
        return reg

    def __exit__(self, *exc) -> None:
        # restore by disarming; a previously armed point is re-armed fresh
        # (its rng state is not preserved — nesting the same point is rare
        # and deterministic-from-seed either way)
        self._reg.disarm(self.point)
        return None


# ---------------------------------------------------------------------------
# the process-wide default (env-bootstrapped, like repro.obs)
# ---------------------------------------------------------------------------
_default: Optional[FaultRegistry] = None
_default_lock = threading.Lock()


def get() -> FaultRegistry:
    """The process-wide registry (created on first use; arms whatever
    ``REPRO_FAULTS`` names)."""
    global _default
    reg = _default
    if reg is None:
        with _default_lock:
            reg = _default
            if reg is None:
                reg = FaultRegistry()
                reg.arm_from_env()
                _default = reg
    return reg


def set_default(reg: Optional[FaultRegistry]) -> Optional[FaultRegistry]:
    """Swap the process-wide registry (``None`` resets to lazy env
    bootstrap); returns the previous one so tests can restore it."""
    global _default
    with _default_lock:
        prev = _default
        _default = reg
        return prev


# -- delegating conveniences (what instrumented call sites use) -------------
def arm(point: str, prob: float = 1.0, seed: int = 0) -> None:
    get().arm(point, prob=prob, seed=seed)


def disarm(point: str) -> None:
    get().disarm(point)


def clear() -> None:
    get().clear()


def armed(point: Optional[str] = None):
    return get().armed(point)


def should_fire(point: str) -> bool:
    return get().should_fire(point)


def maybe_raise(point: str) -> None:
    get().maybe_raise(point)


def skew(t: float) -> float:
    return get().skew(t)


def counts() -> Dict[str, Dict[str, int]]:
    return get().counts()


def active() -> bool:
    """Whether any point is armed — the one-branch fast-path check hot
    sites may use before paying for labels."""
    return bool(get().armed())


__all__ = ["FAULT_POINTS", "SKEW_S", "InjectedFault", "FaultRegistry",
           "inject", "get", "set_default", "arm", "disarm", "clear",
           "armed", "should_fire", "maybe_raise", "skew", "counts",
           "active"]
