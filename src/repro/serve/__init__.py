from . import faults
from .engine import Request, ServeEngine
from .faults import FaultRegistry, InjectedFault
from .guard import CircuitBreaker, GuardedImpl, GuardError, guard_ladder
from .spmv_service import (AdmissionError, EvictedError, MatrixEntry,
                           SpMVService)

__all__ = [
    "Request", "ServeEngine", "MatrixEntry", "SpMVService",
    # fault tolerance (docs/robustness.md)
    "GuardedImpl", "CircuitBreaker", "GuardError", "guard_ladder",
    "AdmissionError", "EvictedError",
    "faults", "FaultRegistry", "InjectedFault",
]
