from .engine import Request, ServeEngine
from .spmv_service import MatrixEntry, SpMVService

__all__ = ["Request", "ServeEngine", "MatrixEntry", "SpMVService"]
