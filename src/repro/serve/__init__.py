from .engine import Request, ServeEngine
from .spmv_service import MatrixEntry, SpMVService
