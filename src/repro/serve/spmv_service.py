"""Serving path for auto-tuned sparse operators.

Production framing of the paper's on-line phase: clients register a sparse
matrix once (a model's MoE routing table, a graph adjacency, a solver
operator) and then stream many SpMV requests against it.  Registration is
where the run-time transformation happens — per-row-block via the
partition subsystem — and the amortization count ``expected_iterations``
is exactly the paper's k in  k * (t_crs - t_f) > t_trans.

The service keeps one jit-compiled dispatcher per registered matrix
(compiled once per block structure) and exposes the per-matrix decisions
for observability.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.autotune import MachineModel, TuningDB, time_fn
from repro.core.formats import CSR, memory_bytes
from repro.core.spmv import spmv as spmv_csr_ref
from repro.core.policy import MemoryPolicy
from repro.partition import HybridReport, build_hybrid, spmv_hybrid


@dataclass
class MatrixEntry:
    matrix: Any                 # HybridMatrix
    report: HybridReport
    fn: Callable                # jitted spmv for this block structure
    t_build: float
    t_csr: float = 0.0          # measured whole-matrix CSR SpMV (s/call)
    t_hybrid: float = 0.0       # measured hybrid SpMV (s/call)
    n_calls: int = 0
    t_serve: float = 0.0        # cumulative wall seconds inside spmv()

    def formats(self) -> Dict[str, int]:
        return self.report.format_counts()


@dataclass
class SpMVService:
    """Register-once / query-many sparse matrix serving.

    >>> svc = SpMVService()
    >>> svc.register("graph0", csr, expected_iterations=1000)
    >>> y = svc.spmv("graph0", x)
    """
    db: Optional[TuningDB] = None
    model: Optional[MachineModel] = None
    policy: Optional[MemoryPolicy] = None
    strategy: str = "variance"
    impls: Optional[Dict[str, Callable]] = None   # Pallas kernel overrides
    entries: Dict[str, MatrixEntry] = field(default_factory=dict)

    def register(self, key: str, csr: CSR, expected_iterations: int = 100,
                 measure_baseline: bool = True, **build_kw) -> MatrixEntry:
        """Build the per-block-tuned operator for ``csr`` under ``key``.

        ``measure_baseline`` times one whole-matrix CSR SpMV and one hybrid
        SpMV (a few extra calls at registration) so ``stats()`` can report
        true amortization; re-registering a key replaces its operator."""
        t0 = time.perf_counter()
        hyb, report = build_hybrid(
            csr, strategy=self.strategy, db=self.db, model=self.model,
            policy=self.policy, expected_iterations=expected_iterations,
            **build_kw)
        fn = jax.jit(lambda m, x: spmv_hybrid(m, x, impls=self.impls))
        t_build = time.perf_counter() - t0
        t_csr = t_hyb = 0.0
        if measure_baseline:
            x0 = jnp.ones((csr.n_cols,), jnp.float32)
            t_csr = time_fn(jax.jit(spmv_csr_ref), csr, x0, iters=1,
                            warmup=1)
            t_hyb = time_fn(fn, hyb, x0, iters=1, warmup=1)
        entry = MatrixEntry(matrix=hyb, report=report, fn=fn,
                            t_build=t_build, t_csr=t_csr, t_hybrid=t_hyb)
        self.entries[key] = entry
        return entry

    def spmv(self, key: str, x: jax.Array) -> jax.Array:
        entry = self.entries[key]
        t0 = time.perf_counter()
        y = jax.block_until_ready(entry.fn(entry.matrix, jnp.asarray(x)))
        entry.n_calls += 1
        entry.t_serve += time.perf_counter() - t0
        return y

    def evict(self, key: str) -> None:
        self.entries.pop(key, None)

    def stats(self) -> Dict[str, Dict[str, Any]]:
        """Per-matrix observability: block formats, build/serve time, and
        amortization — the paper's k*(t_crs - t_f) > t_trans with k the
        calls served so far (None when the baseline was not measured)."""
        out = {}
        for key, e in self.entries.items():
            saved = (e.n_calls * (e.t_csr - e.t_hybrid)
                     if e.t_csr > 0 else None)
            out[key] = {
                "n_blocks": e.matrix.n_blocks,
                "formats": e.formats(),
                "bytes": memory_bytes(e.matrix),
                "t_build_s": e.t_build,
                "n_calls": e.n_calls,
                "t_serve_s": e.t_serve,
                "amortized": (None if saved is None
                              else saved >= e.t_build),
            }
        return out


__all__ = ["SpMVService", "MatrixEntry"]
