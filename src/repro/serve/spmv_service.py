"""Serving path for auto-tuned sparse operators.

Production framing of the paper's on-line phase: clients register a sparse
matrix once (a model's MoE routing table, a graph adjacency, a solver
operator) and then stream many SpMV/SpMM requests against it.
Registration is where the run-time transformation happens — per-row-block
via the partition subsystem — and the amortization count
``expected_iterations`` is the paper's k in ``k * (t_crs - t_f) >
t_trans``; with B right-hand sides per call it strengthens to
``k * B * (t_crs - t_f) > t_trans``.

Two query paths:

  * direct — ``spmv(key, x)`` / ``spmm(key, X)``: one blocking call, one
    compiled dispatcher per (matrix, op);
  * micro-batched — ``submit(key, x) -> Future`` enqueues a single vector;
    ``flush()`` (or the queue reaching ``max_batch``) stacks the pending
    vectors into one ``(n_cols, B)`` panel and serves them with a *single*
    SpMM call per matrix.  Panels are zero-padded to ``max_batch`` so the
    SpMM dispatcher compiles exactly once per matrix; the ragged last
    micro-batch just carries padding columns that are sliced off.
    ``deadline_ms`` adds a latency bound: ``submit`` flushes as soon as the
    oldest pending future has waited past the deadline (and ``poll()`` lets
    a serving loop sweep overdue queues without new traffic).

With a ``tuner`` (``core.kernel_tune.KernelTuner``), registration also
runs the kernel launch-geometry search once per block format — the paper's
register-once/query-many amortization applied one level down, to the tile
shapes themselves — and every subsequent query reuses the tuned geometry.

Resilience (docs/robustness.md):

  * every query runs through a :class:`~repro.serve.guard.GuardedImpl`
    ladder — tuned → reference-format → reference-CSR — so a broken tuned
    tier (exception, NaN output, blown budget) degrades instead of
    failing; a per-``(key, format, op)`` circuit breaker stops paying the
    failure cost per call and half-open-probes its way back;
  * a :class:`~repro.core.plan_store.PlanStore` (``plan_store=``) shares
    tuned plans across processes — tune once per fleet, not per replica —
    with checksummed atomic persistence and quarantine-on-corruption;
  * the micro-batch queue has admission control: a bounded per-key depth
    (``max_queue``) under a ``reject`` / ``shed_oldest`` / ``block``
    policy, deadline-aware rejection when the predicted wait exceeds
    ``deadline_ms``, and eviction fails outstanding futures with a typed
    :class:`EvictedError` instead of leaving them dangling.

The service keeps jit-compiled dispatchers per registered matrix (compiled
once per block structure), releases them on ``evict``/re-``register`` so
long-lived services don't accumulate stale executables, and exposes the
per-matrix decisions and compile counts for observability.
"""
from __future__ import annotations

import hashlib
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

import repro.obs as _obs
from repro.analyze.findings import PlanLintError
from repro.analyze.planlint import lint_plan as _lint_plan
from repro.core import dispatch as _dispatch
from repro.core.autotune import MachineModel, TuningDB, time_fn
from repro.core.formats import CSR, memory_bytes
from repro.core.kernel_tune import KernelTuner, TileGeometry
from repro.core.plan import (BlockPlan, ExecutionPlan, PlanFingerprint,
                             ShardedPlan, TransformRecipe, bind_tunings,
                             blocks_by_format, rederive_slab_bounds)
from repro.core.spmv import spmv as spmv_ref
from repro.core.policy import MemoryPolicy
from repro.partition import (HybridReport, build_hybrid, spmm_hybrid,
                             spmv_hybrid)
from repro.serve import faults as _faults
from repro.serve.guard import CircuitBreaker, GuardedImpl, guard_ladder


class AdmissionError(RuntimeError):
    """The micro-batch queue refused a ``submit``: per-key depth bound
    reached under the ``reject`` policy, a queued request was shed under
    ``shed_oldest``, or the predicted wait exceeds ``deadline_ms``."""


class EvictedError(KeyError, RuntimeError):
    """The matrix entry was evicted (or re-registered away) while this
    request was outstanding.  Subclasses ``KeyError`` (callers that
    treated eviction as a missing key keep working) and ``RuntimeError``
    (a released dispatcher has always raised one)."""


def _swallow(where: str, err: BaseException) -> None:
    """Account for an intentionally swallowed error — the service keeps
    serving, but silent ``except: pass`` is how failures hide (this PR
    exists because of that), so every swallow lands on a counter."""
    tel = _obs.get()
    if tel.enabled:
        tel.counter("service.swallowed_errors", where=where,
                    kind=type(err).__name__).inc()
        tel.event("service.swallowed_error", where=where, error=repr(err))


def _cache_size(fn: Optional[Callable]) -> int:
    """Compiled-executable count of a jitted dispatcher (0 if unavailable)."""
    try:
        return int(fn._cache_size())  # jax's jit wrapper
    except (AttributeError, TypeError) as e:
        # non-jitted callables (guards, overrides, evicted stubs) simply
        # have no cache; anything else would be a bug worth surfacing
        _swallow("cache_size", e)
        return 0


@dataclass
class MatrixEntry:
    matrix: Any                 # HybridMatrix
    report: HybridReport
    fn: Callable                # jitted spmv for this block structure
    spmm_fn: Callable           # jitted spmm for this block structure
    t_build: float
    t_csr: float = 0.0          # measured whole-matrix CSR SpMV (s/call)
    t_hybrid: float = 0.0       # measured hybrid SpMV (s/call)
    n_calls: int = 0
    t_serve: float = 0.0        # cumulative wall seconds inside spmv()
    n_spmm_calls: int = 0
    n_spmm_cols: int = 0        # total RHS columns served through spmm
    builds: int = 1             # times this key's operator was (re)built
    tunings: Dict[str, Dict[str, TileGeometry]] = field(default_factory=dict)
    plan: Optional[Any] = None  # ExecutionPlan | ShardedPlan this entry serves
    from_plan: bool = False     # registration replayed a supplied plan
    max_batch: Optional[int] = None  # per-key panel width (plan-seeded);
    #                                  None falls through to the service's
    source: Optional[CSR] = None     # kept for the reference-CSR rung
    guards: Dict[str, GuardedImpl] = field(default_factory=dict)
    flush_ema_s: float = 0.0    # EMA of flush latency, drives admission
    shed: int = 0               # requests dropped by shed_oldest
    # pending entries are (future, vector, enqueue time) — the timestamp
    # drives the deadline flush policy
    pending: List[Tuple[Future, jax.Array, float]] = field(
        default_factory=list)
    # guards pending/dead: submit() may race flush()/evict() across threads
    lock: threading.Lock = field(default_factory=threading.Lock, repr=False)
    dead: bool = False          # set by _release; refuses new submits
    # -- streaming (repro.stream): registered with streaming=True ------------
    streaming: bool = False
    sketch: Optional[Any] = None        # stream.drift.DriftSketch
    stream_policy: Optional[Any] = None  # stream.drift.ReplanPolicy
    stream_kw: Dict[str, Any] = field(default_factory=dict)  # re-plan knobs
    deltas: int = 0             # DeltaBatches absorbed by this key
    replans: int = 0            # drift-triggered re-registrations
    last_stream_decision: Optional[Any] = None  # stream.drift.DriftDecision

    def formats(self) -> Dict[str, int]:
        return self.report.format_counts()

    def compile_count(self) -> int:
        return _cache_size(self.fn) + _cache_size(self.spmm_fn)


@dataclass
class SpMVService:
    """Register-once / query-many sparse matrix serving.

    >>> svc = SpMVService()
    >>> svc.register("graph0", csr, expected_iterations=1000)
    >>> y = svc.spmv("graph0", x)
    >>> Y = svc.spmm("graph0", X)            # X: (n_cols, B)
    >>> f = svc.submit("graph0", x); svc.flush(); y = f.result()
    """
    db: Optional[TuningDB] = None
    model: Optional[MachineModel] = None
    policy: Optional[MemoryPolicy] = None
    strategy: str = "variance"
    impls: Optional[Dict[str, Callable]] = None   # Pallas spmv overrides
    spmm_impls: Optional[Dict[str, Callable]] = None  # Pallas spmm overrides
    tuner: Optional[KernelTuner] = None  # launch-geometry search at register
    max_batch: int = 32         # micro-batch flush threshold / panel width
    pad_batches: bool = True    # zero-pad panels to max_batch (one compile)
    deadline_ms: Optional[float] = None  # flush when oldest pending exceeds
    # every timestamp the service takes (deadline ages, serve timings) comes
    # from this clock, so deadline tests run on a FakeClock with no sleeps
    clock: Callable[[], float] = time.perf_counter
    entries: Dict[str, MatrixEntry] = field(default_factory=dict)
    # -- resilience knobs (docs/robustness.md) -------------------------------
    guard: bool = True          # serve through the degradation ladder
    probe_finite: bool = True   # isfinite probe on non-final rungs
    budget_ms: Optional[float] = None    # per-rung wall-clock budget
    breaker_failures: int = 3   # consecutive failures before open
    breaker_cooldown_s: float = 30.0     # open -> half-open probe delay
    plan_store: Optional[Any] = None     # core.plan_store.PlanStore
    max_queue: Optional[int] = None      # per-key pending-depth bound
    admission: str = "reject"   # "reject" | "shed_oldest" | "block"
    # breakers are keyed (key, format, op) and survive evict/re-register —
    # a matrix that keeps breaking stays broken across rebuilds until a
    # half-open probe proves otherwise
    _breakers: Dict[Tuple[str, str, str], CircuitBreaker] = field(
        default_factory=dict, repr=False)
    # fingerprint-keyed plan cache: registering a matrix whose structure
    # matches an evicted/previous registration replays the cached plan
    # instead of re-tuning (survives evict — it lives on the service)
    plan_cache_max: int = 32
    _plan_cache: Dict[Tuple, ExecutionPlan] = field(default_factory=dict,
                                                    repr=False)
    _plan_cache_hits: int = 0
    _plan_cache_misses: int = 0

    def _now(self) -> float:
        """Every service timestamp flows through here so the
        ``clock.skew`` fault point can distort it deterministically."""
        return _faults.skew(self.clock())

    # -- launch-geometry tuning at registration ------------------------------
    def _impl_bases(self) -> Dict[str, Dict[str, Callable]]:
        return {
            "spmv": dict(self.impls) if self.impls is not None
            else _dispatch.impl_table("spmv", "kernel", exclude=("hybrid",)),
            "spmm": dict(self.spmm_impls) if self.spmm_impls is not None
            else _dispatch.impl_table("spmm", "kernel", exclude=("hybrid",)),
        }

    def _tuned_impls(self, hyb) -> Tuple[Optional[Dict], Optional[Dict],
                                         Dict[str, Dict[str, TileGeometry]]]:
        """Run the launch-geometry search once per (op, block format) on
        the biggest block of that format, and bind the winners into the
        per-block impl dicts.  For CSR/CCS/BCSR the slab-coverage bound is
        re-derived over *all* blocks of that format (a bound learned on one
        block must cover its siblings, which share the jitted per-format
        impl)."""
        if self.tuner is None:
            return self.impls, self.spmm_impls, {}
        bases = self._impl_bases()
        by_fmt = blocks_by_format(hyb)
        tunings: Dict[str, Dict[str, TileGeometry]] = {}
        for op, base in bases.items():
            batch = 1 if op == "spmv" else self.max_batch
            per_fmt: Dict[str, TileGeometry] = {}
            for f, blocks in by_fmt.items():
                if f not in base:
                    continue
                big = max(blocks, key=lambda b: getattr(b, "nnz", 0))
                try:
                    rec = self.tuner.tune(big, op=op, batch=batch,
                                          impl=base[f])
                except (KeyError, TypeError):
                    continue
                per_fmt[f] = rec.geometry
            tunings[op] = rederive_slab_bounds(per_fmt, by_fmt)
        return (bind_tunings(bases["spmv"], tunings["spmv"]),
                bind_tunings(bases["spmm"], tunings["spmm"]), tunings)

    def _plan_impls(self, hyb, plan: ExecutionPlan
                    ) -> Tuple[Optional[Dict], Optional[Dict],
                               Dict[str, Dict[str, TileGeometry]]]:
        """Bind a supplied (fingerprint-matched) plan's recorded launch
        geometry into the per-block impl dicts — the register-with-plan
        path that skips the tuner's search entirely.  Reference-tier plans
        serve through the service's configured impls untouched."""
        if plan.tier != "kernel":
            return self.impls, self.spmm_impls, {}
        by_fmt = blocks_by_format(hyb)
        tunings = {op: rederive_slab_bounds(per, by_fmt)
                   for op, per in plan.tunings_by_format().items()}
        bases = self._impl_bases()
        return (bind_tunings(bases["spmv"], tunings.get("spmv", {})),
                bind_tunings(bases["spmm"], tunings.get("spmm", {})),
                tunings)

    # -- the degradation ladder ----------------------------------------------
    def _breaker(self, key: str, fmt: str, op: str) -> CircuitBreaker:
        bk = (key, fmt, op)
        br = self._breakers.get(bk)
        if br is None:
            br = self._breakers[bk] = CircuitBreaker(
                key=key, fmt=fmt, op=op, failures=self.breaker_failures,
                cooldown_s=self.breaker_cooldown_s, clock=self._now)
        return br

    def _build_guards(self, key: str, entry: MatrixEntry,
                      fmt: str, sharded: bool = False
                      ) -> Dict[str, GuardedImpl]:
        """The per-(key, op) ladders: tuned → reference-format →
        reference-CSR (sharded entries skip the middle rung — their
        reference tier *is* per-shard CSR).  The source matrix is kept on
        the entry purely so the last rung always exists.

        Every rung reads ``entry.matrix`` / ``entry.source`` / ``entry.fn``
        at call time rather than closing over them: a streaming key's
        containers are swapped in place by :meth:`apply_delta`, and the
        ladder must keep serving the *current* matrix across swaps.  The
        jitted reference wrappers take the matrix as an argument, so a
        swap reuses the compiled executable when the block structure is
        unchanged."""
        if not self.guard:
            return {}
        budget_s = self.budget_ms / 1e3 if self.budget_ms else None
        csr_mv = jax.jit(spmv_ref)
        csr_mm = jax.jit(_dispatch.get_impl("csr", "spmm", "reference"))
        rungs: Dict[str, List[Tuple[str, Callable]]] = {
            "spmv": [("tuned", lambda x: entry.fn(entry.matrix, x))],
            "spmm": [("tuned", lambda x: entry.spmm_fn(entry.matrix, x))],
        }
        if not sharded:
            ref_mv = jax.jit(lambda m, x: spmv_hybrid(m, x))
            ref_mm = jax.jit(lambda m, x: spmm_hybrid(m, x))
            rungs["spmv"].append(("reference",
                                  lambda x: ref_mv(entry.matrix, x)))
            rungs["spmm"].append(("reference",
                                  lambda x: ref_mm(entry.matrix, x)))
        rungs["spmv"].append(("csr", lambda x: csr_mv(entry.source, x)))
        rungs["spmm"].append(("csr", lambda x: csr_mm(entry.source, x)))
        return {op: guard_ladder(
            key, op, rungs[op], fmt=fmt,
            breaker=self._breaker(key, fmt, op),
            probe_finite=self.probe_finite, budget_s=budget_s,
            clock=self._now) for op in ("spmv", "spmm")}

    # -- registration --------------------------------------------------------
    def _lint_registered_plan(self, key: str, plan: Any,
                              strict: bool) -> Any:
        """Static lint of a caller-supplied plan before it is bound.

        A plan that fails lint (misaligned geometry, broken partition,
        over-budget tile — see ``docs/analysis.md``) is refused with a
        typed :class:`~repro.analyze.findings.PlanLintError` under
        ``strict``; otherwise it is dropped (counted, evented) and
        registration proceeds as if no plan was supplied, rebuilding
        fresh.  The lint is jax-free and runs on ``plan.to_dict()``."""
        if plan is None:
            return None
        errs = [f for f in _lint_plan(plan.to_dict()) if f.severity == "error"]
        if not errs:
            return plan
        tel = _obs.get()
        if tel.enabled:
            tel.counter("service.plan_lint", key=key, strict=strict).inc()
            tel.event("service.plan_lint", key=key, strict=strict,
                      errors=[f.render() for f in errs])
        err = PlanLintError(
            f"plan for {key!r} failed lint with {len(errs)} error(s):\n"
            + "\n".join(f.render() for f in errs), errs)
        if strict:
            raise err
        _swallow("plan_lint", err)
        return None

    def register(self, key: str, csr: CSR, expected_iterations: int = 100,
                 measure_baseline: bool = True, batch: int = 1,
                 plan: Optional[ExecutionPlan] = None,
                 strict_lint: bool = False,
                 streaming: bool = False,
                 stream_policy: Optional[Any] = None,
                 **build_kw) -> MatrixEntry:
        """Build the per-block-tuned operator for ``csr`` under ``key``.

        ``batch`` is the expected RHS count per call, fed to the
        batch-aware tuner (amortization over ``expected_iterations *
        batch`` products).  ``measure_baseline`` times one whole-matrix CSR
        SpMV and one hybrid SpMV (a few extra calls at registration) so
        ``stats()`` can report true amortization; re-registering a key
        replaces its operator and releases the stale compiled executables.
        With a ``tuner`` set, registration also searches kernel launch
        geometry per block format and bakes the winners into the jitted
        dispatchers — queries reuse them for free.

        ``plan``: a saved :class:`~repro.core.plan.ExecutionPlan`.  When
        its fingerprint matches ``csr``, registration *replays* it — the
        recorded per-block decisions and launch geometry are bound
        directly, skipping both the per-block decision machinery and the
        tuner's search.  A mismatched plan falls back to a full build (and
        re-tune); either way the entry's ``plan`` attribute carries the
        plan this key is serving, so ``register`` without a plan is also
        how plans are *minted* (``svc.register(...).plan.save(path)``).

        A :class:`~repro.core.plan.ShardedPlan` routes to the multi-device
        tier: the entry serves through a bound
        :class:`~repro.sharding.spmv.ShardedPlannedMatrix` (extra
        ``build_kw`` — ``mode``, ``devices``, ``mesh`` — reach its bind).

        Plans carrying ``batch > 1`` seed this key's micro-batch panel
        width (``entry.max_batch``) instead of the service default.

        Every supplied plan is statically linted first
        (:mod:`repro.analyze.planlint`).  ``strict_lint=True`` turns lint
        errors into a raised
        :class:`~repro.analyze.findings.PlanLintError`; by default a
        lint-failing plan is dropped (counted under ``service.plan_lint``)
        and registration rebuilds from scratch — note that a non-strict
        *sharded* plan failing lint therefore degrades to a single-device
        build.

        Without a supplied plan, a fingerprint-keyed plan cache is
        consulted first — and behind it the persistent ``plan_store``
        (shared across processes): re-registering a matrix whose structure
        matches a previous registration, *anywhere in the fleet*, replays
        the stored plan with zero re-tuning; a fresh build writes its plan
        back.  Hits/misses land in ``stats()['plan_cache']`` /
        ``stats()['plan_store']``.

        ``streaming=True`` marks the key *dynamic* (docs/streaming.md):
        the entry carries a :class:`~repro.stream.drift.DriftSketch` and a
        :class:`~repro.stream.drift.ReplanPolicy` (override with
        ``stream_policy``), and :meth:`apply_delta` may be called to
        mutate the matrix in place.  Sharded plans do not support
        streaming."""
        csr.validate()       # malformed input fails here, typed, not as
        #                      garbage inside a kernel (MatrixValidationError)
        plan = self._lint_registered_plan(key, plan, strict_lint)
        if isinstance(plan, ShardedPlan):
            if streaming:
                raise ValueError(
                    "streaming=True is not supported for sharded plans")
            return self._register_sharded(
                key, csr, plan, expected_iterations=expected_iterations,
                measure_baseline=measure_baseline, batch=batch, **build_kw)
        # keep the prior operator serving until the replacement is ready —
        # it is popped and released only at the swap below, so concurrent
        # spmv/spmm/submit against this key never see a registration gap
        prior = self.entries.get(key)
        builds = prior.builds + 1 if prior is not None else 1
        tel = _obs.get()
        cache_key = store_key = None
        if plan is None:
            cache_key = self._plan_cache_key(csr, expected_iterations,
                                             batch, build_kw)
            cached = self._plan_cache.get(cache_key)
            hit = (cached is not None and cached.fingerprint is not None
                   and cached.fingerprint.matches(csr))
            if hit:
                plan = cached
                self._plan_cache_hits += 1
            else:
                self._plan_cache_misses += 1
            if tel.enabled:
                tel.counter("service.plan_cache", key=key, hit=hit).inc()
            if plan is None and self.plan_store is not None:
                # fleet-level fallback behind the in-process cache: a
                # corrupted entry is quarantined inside get() and reads
                # as a miss — never raised to the caller
                store_key = self._store_key(cache_key)
                stored = self.plan_store.get(store_key, fingerprint=csr)
                if stored is not None and not isinstance(stored,
                                                         ShardedPlan):
                    plan = stored
                if tel.enabled:
                    tel.counter("service.plan_store", key=key,
                                hit=plan is not None).inc()
        plan_matched = (plan is not None and plan.fingerprint is not None
                        and plan.fingerprint.matches(csr))
        if tel.enabled and plan is not None:
            tel.counter("service.plan_replay", key=key,
                        hit=plan_matched).inc()
            tel.event("service.plan_replay", key=key, hit=plan_matched)
        t0 = self._now()
        with tel.span("service.register", key=key, n=csr.n_rows,
                      nnz=csr.nnz, batch=batch,
                      plan_matched=plan_matched) as reg_span:
            hyb, report, impls, spmm_impls, tunings, entry_plan, \
                plan_matched = self._build_operator(
                    key, csr, plan, plan_matched, expected_iterations,
                    batch, build_kw, tel)
            fn = jax.jit(lambda m, x: spmv_hybrid(m, x, impls=impls))
            spmm_fn = jax.jit(
                lambda m, x: spmm_hybrid(m, x, impls=spmm_impls))
            t_build = self._now() - t0
            reg_span.set(t_build=t_build, n_blocks=hyb.n_blocks)
        t_csr = t_hyb = 0.0
        if measure_baseline:
            x0 = jnp.ones((csr.n_cols,), jnp.float32)
            t_csr = time_fn(jax.jit(spmv_ref), csr, x0, iters=1,
                            warmup=1)
            t_hyb = time_fn(fn, hyb, x0, iters=1, warmup=1)
        entry = MatrixEntry(matrix=hyb, report=report, fn=fn,
                            spmm_fn=spmm_fn, t_build=t_build, t_csr=t_csr,
                            t_hybrid=t_hyb, builds=builds, tunings=tunings,
                            plan=entry_plan, from_plan=plan_matched,
                            source=csr,
                            max_batch=(plan.batch if plan is not None
                                       and plan.batch > 1 else None))
        entry.guards = self._build_guards(key, entry, fmt="hybrid")
        if streaming:
            self._attach_streaming(entry, csr, expected_iterations,
                                   measure_baseline, batch, stream_policy,
                                   build_kw)
        if cache_key is not None and entry_plan is not None \
                and not plan_matched:
            self._plan_cache[cache_key] = entry_plan
            while len(self._plan_cache) > self.plan_cache_max:
                self._plan_cache.pop(next(iter(self._plan_cache)))
            if self.plan_store is not None:
                # tune once per fleet: publish the freshly minted plan
                if store_key is None:
                    store_key = self._store_key(cache_key)
                try:
                    self.plan_store.put(store_key, entry_plan)
                except OSError as e:
                    # a full/readonly disk must not fail registration —
                    # the plan still serves from memory
                    _swallow("plan_store_put", e)
        self.entries[key] = entry
        if prior is not None:
            # the old operator was valid to the end: serve its queued
            # vectors before releasing it rather than failing their futures
            try:
                self._flush_entry(prior, key=key, cause="reregister")
            except (RuntimeError, ValueError, TypeError,
                    ArithmeticError) as e:
                # the panel's futures already carry the exception; the
                # swallow is accounted, not silent
                _swallow("reregister_flush", e)
            self._release(key, prior)
        return entry

    def _build_operator(self, key: str, csr: CSR, plan, plan_matched: bool,
                        expected_iterations: int, batch: int,
                        build_kw: Dict[str, Any], tel):
        """Materialize-or-build with degrade-don't-die semantics: a plan
        replay or hybrid build whose *transform* fails (``transform.raise``
        fault, or an organic conversion bug) falls back to a single-block
        reference-CSR registration — serving correct results at baseline
        speed beats not serving."""
        try:
            if plan_matched:
                hyb, report = plan.materialize(csr)
                impls, spmm_impls, tunings = self._plan_impls(hyb, plan)
                return (hyb, report, impls, spmm_impls, tunings, plan,
                        plan_matched)
            hyb, report = build_hybrid(
                csr, strategy=self.strategy, db=self.db,
                model=self.model, policy=self.policy,
                expected_iterations=expected_iterations,
                batch=batch, **build_kw)
            impls, spmm_impls, tunings = self._tuned_impls(hyb)
            entry_plan = self._derive_plan(csr, hyb, report, tunings,
                                           expected_iterations, batch,
                                           build_kw)
            return (hyb, report, impls, spmm_impls, tunings, entry_plan,
                    plan_matched)
        except (RuntimeError, ValueError, TypeError, KeyError) as e:
            if tel.enabled:
                tel.counter("service.fallback", key=key, op="register",
                            rung="csr").inc()
                tel.event("service.register_degraded", key=key,
                          error=repr(e))
            csr_plan = ExecutionPlan(
                fmt="csr", rule="degraded", tier="reference",
                batch=max(int(batch), 1),
                expected_iterations=max(int(expected_iterations), 1),
                fingerprint=PlanFingerprint.of(csr))
            hyb, report = csr_plan.materialize(csr)
            return (hyb, report, self.impls, self.spmm_impls, {},
                    csr_plan, False)

    def _derive_plan(self, csr: CSR, hyb, report, tunings,
                     expected_iterations: int, batch: int,
                     build_kw: Optional[Dict[str, Any]] = None
                     ) -> Optional[ExecutionPlan]:
        """Package a fresh registration as a portable hybrid
        :class:`ExecutionPlan`: the per-block sub-plans minted by
        ``build_hybrid`` plus the tuner's per-format geometry winners.
        Saving it and passing it back to ``register(..., plan=...)`` on
        the same matrix replays the build with zero re-tuning."""
        subs = [d.plan for d in report.decisions]
        if any(s is None for s in subs):
            return None
        tier = "kernel" if self.tuner is not None else "reference"
        for sub in subs:
            sub.tier = tier
            for op, per in tunings.items():
                if sub.fmt in per:
                    sub.geometry[op] = per[sub.fmt]
        blocks = [BlockPlan(rows=d.rows, plan=sub)
                  for d, sub in zip(report.decisions, subs)]
        # record the build kwargs (partitioner knobs, block formats) so a
        # fingerprint-mismatched replay re-partitions under the same
        # recipe the plan was minted with, not the library defaults
        params = {**(build_kw or {}), "strategy": self.strategy,
                  "sort_rows": not hyb.identity_perm}
        fp = PlanFingerprint.of(csr)
        return ExecutionPlan(
            fmt="hybrid", rule=subs[0].rule if subs else "cost_model",
            tier=tier, batch=max(int(batch), 1),
            expected_iterations=max(int(expected_iterations), 1),
            transform=TransformRecipe("hybrid", params),
            fingerprint=fp,
            machine=self.db.machine if self.db is not None else "cost_model",
            d_mat=fp.d_mat, d_star=float("nan"), blocks=blocks)

    # -- plan cache / store / sharded registration ---------------------------
    def _plan_cache_key(self, csr: CSR, expected_iterations: int,
                        batch: int, build_kw: Dict[str, Any]) -> Tuple:
        """Structure + registration knobs: a cached plan only replays for
        a matrix with identical structure registered the same way."""
        fp = PlanFingerprint.of(csr)
        return (fp.n, fp.nnz, fp.sig, int(batch), int(expected_iterations),
                self.strategy,
                tuple(sorted((k, repr(v)) for k, v in build_kw.items())))

    @staticmethod
    def _store_key(cache_key: Tuple) -> str:
        """The plan cache's identity, made process-portable: the tuple is
        ints/strings only, so its repr is stable across interpreters."""
        return hashlib.sha256(repr(cache_key).encode("utf-8")).hexdigest()

    def _register_sharded(self, key: str, csr: CSR, plan: ShardedPlan,
                          expected_iterations: int = 100,
                          measure_baseline: bool = True, batch: int = 1,
                          **bind_kw) -> MatrixEntry:
        """The multi-device registration path: bind the ShardedPlan (per
        its recorded partition recipe and per-shard plans) and serve the
        key through the resulting ShardedPlannedMatrix."""
        prior = self.entries.get(key)
        builds = prior.builds + 1 if prior is not None else 1
        matched = plan.matches(csr)
        tel = _obs.get()
        if tel.enabled:
            tel.counter("service.plan_replay", key=key, hit=matched).inc()
            tel.event("service.plan_replay", key=key, hit=matched,
                      sharded=True)
        t0 = self._now()
        with tel.span("service.register", key=key, n=csr.n_rows,
                      nnz=csr.nnz, batch=batch, plan_matched=matched,
                      sharded=True) as reg_span:
            spm = plan.bind(csr, db=self.db, **bind_kw)

            def fn(m, x):
                return m.spmv(x)

            def spmm_fn(m, x):
                return m.spmm(x)

            t_build = self._now() - t0
            reg_span.set(t_build=t_build, n_blocks=spm.n_shards,
                         mode=spm.mode)
        t_csr = t_hyb = 0.0
        if measure_baseline:
            x0 = jnp.ones((csr.n_cols,), jnp.float32)
            t_csr = time_fn(jax.jit(spmv_ref), csr, x0, iters=1, warmup=1)
            t_hyb = time_fn(fn, spm, x0, iters=1, warmup=1)
        entry = MatrixEntry(matrix=spm, report=_ShardedReport(spm), fn=fn,
                            spmm_fn=spmm_fn, t_build=t_build, t_csr=t_csr,
                            t_hybrid=t_hyb, builds=builds, tunings={},
                            plan=plan, from_plan=matched,
                            source=csr,
                            max_batch=plan.batch if plan.batch > 1
                            else None)
        entry.guards = self._build_guards(key, entry, fmt="sharded",
                                          sharded=True)
        self.entries[key] = entry
        if prior is not None:
            try:
                self._flush_entry(prior, key=key, cause="reregister")
            except (RuntimeError, ValueError, TypeError,
                    ArithmeticError) as e:
                _swallow("reregister_flush", e)
            self._release(key, prior)
        return entry

    # -- streaming (repro.stream) --------------------------------------------
    def _attach_streaming(self, entry: MatrixEntry, csr: CSR,
                          expected_iterations: int, measure_baseline: bool,
                          batch: int, stream_policy: Optional[Any],
                          build_kw: Dict[str, Any]) -> None:
        """Arm a freshly registered entry for :meth:`apply_delta`: an exact
        drift sketch of the matrix as registered, a re-plan policy priced
        against the service's tuning DB, and the registration knobs a
        drift-triggered re-registration must replay."""
        from repro.stream.drift import DriftSketch, ReplanPolicy
        entry.streaming = True
        entry.sketch = DriftSketch.of(csr)
        entry.stream_policy = stream_policy if stream_policy is not None \
            else ReplanPolicy(db=self.db, batch=batch,
                              default_k=float(expected_iterations))
        entry.stream_kw = {"expected_iterations": expected_iterations,
                           "measure_baseline": measure_baseline,
                           "batch": batch, **build_kw}

    def apply_delta(self, key: str, delta: Any) -> Any:
        """Absorb one :class:`~repro.stream.delta.DeltaBatch` into a
        ``streaming=True`` key and return the
        :class:`~repro.stream.delta.DeltaApplyResult`.

        The pending micro-batch panel is flushed first (``cause="delta"``)
        so queued futures are served against the matrix they were
        submitted for — deltas serialize with the flush queue.  A
        single-block CSR/SELL operator is updated *incrementally*
        (O(Δnnz) tail appends, per-slice SELL rebuilds) by swapping the
        entry's containers in place — the compiled dispatchers and guard
        ladders read the entry dynamically, so no rebind happens and the
        per-``(key, fmt, op)`` circuit breakers keep their state.  Any
        other operator shape degrades to a CSR apply plus a full
        re-registration (recorded as a fallback).  After the apply, the
        drift sketch folds in the row-length changes and the policy's
        hysteresis + streaming-amortization rule decides whether the
        paper's threshold now picks a different format; if so the key is
        re-registered under its original knobs (``stream.replan``)."""
        from repro.stream.delta import INCREMENTAL_FORMATS
        from repro.stream.delta import apply_delta as _apply_delta
        entry = self.entries[key]
        if not entry.streaming:
            raise ValueError(
                f"matrix {key!r} was not registered with streaming=True")
        try:
            self._flush_entry(entry, key=key, cause="delta")
        except (RuntimeError, ValueError, TypeError,
                ArithmeticError) as e:
            # the panel's futures already carry the exception; the delta
            # must still land or the key's state forks from its writers
            _swallow("delta_flush", e)
        hyb = entry.matrix
        leaf = (getattr(hyb, "n_blocks", 0) == 1
                and getattr(hyb, "identity_perm", False)
                and hyb.formats[0] in INCREMENTAL_FORMATS)
        if leaf:
            fmt = hyb.formats[0]
            params: Dict[str, Any] = {}
            if entry.plan is not None and entry.plan.transform is not None:
                params = dict(entry.plan.transform.params or {})
            res = _apply_delta(entry.source, delta,
                               container=hyb.blocks[0], fmt=fmt,
                               transform_params=params, key=key)
            perm = hyb.perm
            if res.csr.n_rows != int(perm.shape[0]):  # rows appended
                perm = np.arange(res.csr.n_rows, dtype=np.int32)
            new_hyb = hyb.__class__(
                perm=perm, blocks=(res.container,), row_offsets=(0,),
                formats=(fmt,), shape=res.csr.shape, nnz=res.csr.nnz,
                identity_perm=True)
            with entry.lock:
                entry.matrix = new_hyb
                entry.source = res.csr
                entry.deltas += 1
            entry.sketch.update(res)
        else:
            # multi-block (or non-incremental leaf) operators re-partition
            # wholesale: apply to the source CSR, then rebuild the operator
            res = _apply_delta(entry.source, delta, fmt="csr", key=key)
            res.fallback = True
            res.fallback_reason = res.fallback_reason or "nonleaf"
            res.mode = "rebuild"
            # the rebuild re-derives the sketch exactly from the new
            # matrix, so no incremental update on top of it
            entry = self._replan_streaming(key, entry, res.csr,
                                           deltas=entry.deltas + 1)
        pol = entry.stream_policy
        pol.note_update()
        current_fmt = entry.plan.fmt if entry.plan is not None else "csr"
        dec = pol.decide(entry.sketch.d_mat, current_fmt=current_fmt,
                         key=key)
        if dec.replan:
            entry = self._replan_streaming(key, entry, entry.source,
                                           deltas=entry.deltas,
                                           decision=dec)
        entry.last_stream_decision = dec
        return res

    def _replan_streaming(self, key: str, entry: MatrixEntry, csr: CSR,
                          deltas: int, decision: Optional[Any] = None
                          ) -> MatrixEntry:
        """Re-register a streaming key under its original knobs.  The new
        entry inherits the policy (its k̂ estimate and cooldown survive)
        and the delta/replan counters; the sketch is re-derived exactly
        from the post-delta matrix.  Circuit breakers live on the service
        keyed by ``(key, fmt, op)`` and are untouched — a breaker opened
        on the tuned rung stays open across the re-plan."""
        old_policy, old_replans = entry.stream_policy, entry.replans
        old_fmt = entry.plan.fmt if entry.plan is not None else "csr"
        new = self.register(key, csr, streaming=True,
                            stream_policy=old_policy, **entry.stream_kw)
        new.deltas = deltas
        new.replans = old_replans
        if decision is not None:
            new.replans += 1
            old_policy.deltas_since_replan = 0
            tel = _obs.get()
            if tel.enabled:
                tel.counter("stream.replans", key=key).inc()
                tel.event("stream.replan", key=key, old_fmt=old_fmt,
                          new_fmt=new.plan.fmt if new.plan is not None
                          else "csr", d_mat=decision.d_mat,
                          d_star=decision.d_star, k_hat=decision.k_hat,
                          reason=decision.reason)
        return new

    # -- direct paths --------------------------------------------------------
    def _run(self, entry: MatrixEntry, op: str, x: jax.Array) -> jax.Array:
        """One guarded (or raw) operator application."""
        g = entry.guards.get(op)
        if g is not None:
            return jax.block_until_ready(g(x))
        fn = entry.fn if op == "spmv" else entry.spmm_fn
        return jax.block_until_ready(fn(entry.matrix, x))

    def spmv(self, key: str, x: jax.Array) -> jax.Array:
        entry = self.entries[key]
        t0 = self._now()
        y = self._run(entry, "spmv", jnp.asarray(x))
        dt = self._now() - t0
        with entry.lock:
            entry.n_calls += 1
            entry.t_serve += dt
            if entry.stream_policy is not None:
                entry.stream_policy.note_query()
        tel = _obs.get()
        if tel.enabled:
            tel.histogram("service.query_latency_s", key=key,
                          op="spmv").observe(dt)
        return y

    def spmm(self, key: str, x: jax.Array) -> jax.Array:
        """Y = A @ X with X an (n_cols, B) panel — one call, B products."""
        entry = self.entries[key]
        x = jnp.asarray(x)
        if x.ndim != 2:
            raise ValueError(f"spmm expects (n_cols, B); got {x.shape}")
        t0 = self._now()
        y = self._run(entry, "spmm", x)
        dt = self._now() - t0
        with entry.lock:
            entry.n_spmm_calls += 1
            entry.n_spmm_cols += int(x.shape[1])
            entry.t_serve += dt
            if entry.stream_policy is not None:
                # k̂ counts *products*: a B-wide panel is B queries
                entry.stream_policy.note_query(int(x.shape[1]))
        tel = _obs.get()
        if tel.enabled:
            tel.histogram("service.query_latency_s", key=key,
                          op="spmm").observe(dt)
        return y

    # -- micro-batching queue ------------------------------------------------
    def _admit(self, entry: MatrixEntry, key: str, now: float) -> None:
        """Admission control under ``entry.lock``: bounded depth per the
        configured policy, plus deadline-aware rejection when the
        predicted wait (panels ahead × recent flush latency) already
        exceeds ``deadline_ms``.  Raises :class:`AdmissionError`."""
        tel = _obs.get()
        depth = len(entry.pending)
        limit = self.max_queue
        if limit is not None and depth >= limit:
            if self.admission == "shed_oldest":
                fut, _, t_enq = entry.pending.pop(0)
                entry.shed += 1
                fut.set_exception(AdmissionError(
                    f"request shed after {(now - t_enq) * 1e3:.1f}ms: "
                    f"queue for {key!r} at depth bound {limit}"))
                if tel.enabled:
                    tel.counter("service.admission", key=key,
                                action="shed").inc()
            else:                       # "reject" (and unknown values)
                if tel.enabled:
                    tel.counter("service.admission", key=key,
                                action="reject").inc()
                raise AdmissionError(
                    f"queue for {key!r} is at its depth bound "
                    f"({limit}); retry later or flush")
        if self.deadline_ms is not None and entry.flush_ema_s > 0.0:
            panel = entry.max_batch or self.max_batch
            panels_ahead = len(entry.pending) // max(panel, 1) + 1
            predicted_ms = panels_ahead * entry.flush_ema_s * 1e3
            if predicted_ms > self.deadline_ms:
                if tel.enabled:
                    tel.counter("service.admission", key=key,
                                action="deadline").inc()
                raise AdmissionError(
                    f"predicted wait {predicted_ms:.1f}ms exceeds the "
                    f"{self.deadline_ms}ms deadline for {key!r}")

    def submit(self, key: str, x: jax.Array) -> "Future":
        """Enqueue one SpMV; resolved by ``flush`` (auto at ``max_batch``,
        or as soon as the oldest pending future is past ``deadline_ms``)
        through a single SpMM call per matrix.

        With ``max_queue`` set, a full queue is handled per the
        ``admission`` policy: ``reject`` raises :class:`AdmissionError`,
        ``shed_oldest`` fails the oldest pending future to make room,
        ``block`` flushes synchronously until there is room."""
        entry = self.entries[key]
        x = jnp.asarray(x)
        if x.shape != (entry.matrix.n_cols,):
            # reject here so one bad vector can never poison a whole panel
            raise ValueError(f"expected x of shape ({entry.matrix.n_cols},); "
                             f"got {x.shape}")
        if self.max_queue is not None and self.admission == "block":
            # make room by serving, not by waiting: each flush drains the
            # queue entirely, so one pass always admits
            while True:
                with entry.lock:
                    if entry.dead:
                        raise EvictedError(f"matrix {key!r} was evicted")
                    if len(entry.pending) < self.max_queue:
                        break
                tel = _obs.get()
                if tel.enabled:
                    tel.counter("service.admission", key=key,
                                action="block").inc()
                self._flush_entry(entry, key=key, cause="admission")
        fut: Future = Future()
        now = self._now()
        with entry.lock:
            if entry.dead:
                # racing evict/re-register: never enqueue onto a released
                # entry — nothing would ever flush it
                raise EvictedError(f"matrix {key!r} was evicted")
            self._admit(entry, key, now)
            entry.pending.append((fut, x, now))
            depth = len(entry.pending)
            full = depth >= (entry.max_batch or self.max_batch)
            overdue = (self.deadline_ms is not None and
                       (now - entry.pending[0][2]) * 1e3 >= self.deadline_ms)
        tel = _obs.get()
        if tel.enabled:
            tel.gauge("service.queue_depth", key=key).set(depth)
        if full or overdue:
            self._flush_entry(entry, key=key,
                              cause="max_batch" if full else "deadline")
        return fut

    def poll(self) -> int:
        """Deadline sweep for serving loops: flush every matrix whose
        oldest pending future has waited past ``deadline_ms``.  Returns the
        number of vectors served (0 when no deadline is configured)."""
        if self.deadline_ms is None:
            return 0
        now = self._now()
        served = 0
        for k in list(self.entries):
            e = self.entries.get(k)
            if e is None:
                continue
            with e.lock:
                due = bool(e.pending) and \
                    (now - e.pending[0][2]) * 1e3 >= self.deadline_ms
            if due:
                served += self._flush_entry(e, key=k, cause="deadline")
        return served

    def flush(self, key: Optional[str] = None) -> int:
        """Serve all pending vectors (of ``key``, or every matrix) in one
        SpMM per matrix.  Returns the number of vectors served — the last
        micro-batch may be ragged (fewer than ``max_batch`` columns)."""
        if key is not None:
            entries = [(key, self.entries[key])]
        else:  # tolerate evictions racing the snapshot
            entries = [(k, e) for k in list(self.entries)
                       if (e := self.entries.get(k)) is not None]
        served, first_err = 0, None
        for k, e in entries:
            try:
                served += self._flush_entry(e, key=k, cause="explicit")
            except Exception as err:
                # that panel's futures already carry the exception; keep
                # serving the other matrices and re-raise at the end
                if first_err is None:
                    first_err = err
        if first_err is not None:
            raise first_err
        return served

    def pending_count(self, key: str) -> int:
        return len(self.entries[key].pending)

    def _flush_entry(self, entry: MatrixEntry, key: str = "",
                     cause: str = "explicit") -> int:
        with entry.lock:
            batch, entry.pending = entry.pending, []
        if not batch:
            return 0
        b = len(batch)
        tel = _obs.get()
        with tel.span("service.flush", key=key, cause=cause, batch=b):
            try:
                X = jnp.stack([x for _, x, _ in batch], axis=1)  # (n_cols, b)
                panel = entry.max_batch or self.max_batch
                if self.pad_batches and b < panel:
                    X = jnp.pad(X, ((0, 0), (0, panel - b)))
                t0 = self._now()
                Y = self._run(entry, "spmm", X)
            except Exception as e:
                # never strand a future: the whole panel fails together
                for fut, _, _ in batch:
                    fut.set_exception(e)
                raise
            dt = self._now() - t0
        if tel.enabled:
            tel.counter("service.flush", key=key, cause=cause).inc()
            tel.gauge("service.queue_depth", key=key).set(0)
            tel.histogram("service.flush_latency_s", key=key).observe(dt)
            tel.event("service.flush", key=key, cause=cause, batch=b,
                      t_spmm=dt)
        with entry.lock:
            entry.n_spmm_calls += 1
            entry.n_spmm_cols += b
            entry.t_serve += dt
            if entry.stream_policy is not None:
                entry.stream_policy.note_query(b)
            # the admission controller's wait predictor: a slow-moving EMA
            # of flush latency (zero-cost under FakeClock — dt stays 0)
            entry.flush_ema_s = (dt if entry.flush_ema_s == 0.0
                                 else 0.3 * dt + 0.7 * entry.flush_ema_s)
        for i, (fut, _, _) in enumerate(batch):
            fut.set_result(Y[:, i])
        return b

    # -- lifecycle -----------------------------------------------------------
    def evict(self, key: str) -> None:
        """Drop a matrix and release its compiled dispatchers."""
        entry = self.entries.pop(key, None)
        if entry is not None:
            self._release(key, entry)

    def _release(self, key: str, entry: MatrixEntry) -> None:
        with entry.lock:
            entry.dead = True
            stranded, entry.pending = entry.pending, []
        if stranded:
            tel = _obs.get()
            if tel.enabled:
                tel.counter("service.evicted_futures", key=key).inc(
                    len(stranded))
        for fut, _, _ in stranded:
            fut.set_exception(EvictedError(
                f"matrix {key!r} evicted with requests pending"))
        for fn in (entry.fn, entry.spmm_fn):
            clear = getattr(fn, "clear_cache", None)
            if callable(clear):
                clear()
        # drop the jitted closures so the executables are collectable even
        # if a caller keeps the MatrixEntry alive
        entry.fn = entry.spmm_fn = _evicted
        entry.guards = {}
        entry.source = None

    def _entry_telemetry(self, key: str) -> Dict[str, Any]:
        """This key's slice of the process telemetry (query-latency
        summaries, flush-cause counts, queue depth, plan-replay hits);
        empty when telemetry is disabled."""
        tel = _obs.get()
        if not tel.enabled:
            return {}
        out: Dict[str, Any] = {}
        for kind, name, labels, m in tel.metrics():
            if labels.get("key") != key:
                continue
            rest = {k: v for k, v in labels.items() if k != "key"}
            mkey = _obs.format_metric(name, rest)
            out[mkey] = m.summary() if kind == "histogram" else m.value
        return out

    def stats(self) -> Dict[str, Dict[str, Any]]:
        """Per-matrix observability: block formats, build/serve time,
        compile counts, micro-batch throughput, guard/breaker health, and
        amortization — the paper's k*B*(t_crs - t_f) > t_trans with k*B
        the products served so far (None when the baseline was not
        measured).  With telemetry enabled each entry also carries its
        ``"telemetry"`` slice — latency-histogram summaries, flush-cause
        counters, queue depth.  ``"guard"`` maps op → ladder snapshot
        (per-rung serve counts, failures, breaker state machine)."""
        out = {}
        for key, e in self.entries.items():
            products = e.n_calls + e.n_spmm_cols
            saved = (products * (e.t_csr - e.t_hybrid)
                     if e.t_csr > 0 else None)
            nb = getattr(e.matrix, "nbytes", None)
            out[key] = {
                "n_blocks": e.matrix.n_blocks,
                "formats": e.formats(),
                "bytes": int(nb()) if callable(nb) else memory_bytes(
                    e.matrix),
                "t_build_s": e.t_build,
                "n_calls": e.n_calls,
                "n_spmm_calls": e.n_spmm_calls,
                "n_spmm_cols": e.n_spmm_cols,
                "pending": len(e.pending),
                "shed": e.shed,
                "builds": e.builds,
                "compiled": e.compile_count(),
                "tuned": {op: {f: g.to_dict() for f, g in per.items()}
                          for op, per in e.tunings.items() if per},
                "plan": (None if e.plan is None else {
                    # ShardedPlan carries axis/strategy instead of
                    # rule/tier/machine — surface whichever it has
                    "rule": getattr(e.plan, "rule", None),
                    "tier": getattr(e.plan, "tier", None),
                    "machine": getattr(e.plan, "machine", None),
                    "axis": getattr(e.plan, "axis", None),
                    "strategy": getattr(e.plan, "strategy", None),
                    "n_shards": getattr(e.plan, "n_shards", None),
                    "schema_version": e.plan.schema_version,
                    "batch": e.plan.batch,
                    "from_plan": e.from_plan,   # registration replayed one
                }),
                "guard": {op: g.snapshot() for op, g in e.guards.items()},
                "t_serve_s": e.t_serve,
                "amortized": (None if saved is None
                              else saved >= e.t_build),
                "telemetry": self._entry_telemetry(key),
            }
            if e.streaming:
                out[key]["streaming"] = {
                    "deltas": e.deltas,
                    "replans": e.replans,
                    "d_mat": e.sketch.d_mat if e.sketch is not None
                    else None,
                    "k_hat": (e.stream_policy.k_hat
                              if e.stream_policy is not None else None),
                    "last_decision": (e.last_stream_decision.reason
                                      if e.last_stream_decision is not None
                                      else None),
                }
        # reserved keys (no matrix may register under them): service-wide
        # plan-cache / plan-store / breaker health — consumers index
        # stats() by matrix key
        out["plan_cache"] = {"size": len(self._plan_cache),
                             "hits": self._plan_cache_hits,
                             "misses": self._plan_cache_misses}
        if self.plan_store is not None:
            out["plan_store"] = self.plan_store.stats()
        if self._breakers:
            out["breakers"] = {
                "/".join(bk): br.snapshot()
                for bk, br in self._breakers.items()}
        return out


class _ShardedReport:
    """HybridReport-shaped shim for sharded entries: format counts over
    the per-shard plans, per-shard decision dicts as ``decisions``."""

    def __init__(self, spm: Any):
        self.decisions = spm.report()
        self._formats = spm.plan.shard_formats()

    def format_counts(self) -> Dict[str, int]:
        counts: Dict[str, int] = {}
        for f in self._formats:
            counts[f] = counts.get(f, 0) + 1
        return counts


def _evicted(m, x):
    raise EvictedError("this matrix entry was evicted; re-register it")


__all__ = ["SpMVService", "MatrixEntry", "AdmissionError", "EvictedError"]
