"""Synthetic benchmark-matrix suite reproducing Table 1 of the paper.

The UF Sparse Matrix Collection is not reachable in this offline container,
so each of the paper's 22 matrices is *synthesized* to match its published
(N, NNZ, mu, sigma, D_mat) row-statistics exactly in expectation:

  * low-variation matrices (D_mat < 0.8): row lengths ~ round(N(mu, sigma)),
    clipped to [1, n] — FEM/banded character (chem_master, wang, epb, ...);
  * heavy-tailed matrices (memplus D=3.10, torso1 D=5.72): a two-point row-
    length mixture (a few very long rows among short ones) whose parameters
    are solved analytically from (mu, sigma) — this reproduces exactly the
    structure that makes ELL explode (the paper removed torso1's ELL run for
    memory overflow; our generator reproduces that pathology).

Row totals are then exactly adjusted to hit NNZ.  Column patterns are
contiguous bands centered on the diagonal (optionally hash-scattered), so
all row indices are unique per row.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .formats import CSR, MatrixStats
from .transform import csr_from_rows


@dataclass(frozen=True)
class MatrixSpec:
    no: int
    name: str
    n: int
    nnz: int
    mu: float
    sigma: float
    d_mat: float
    field: str
    scatter: bool = False   # hash-scattered columns instead of a band


TABLE1: Tuple[MatrixSpec, ...] = (
    MatrixSpec(1, "chipcool0", 20082, 281150, 14.00, 2.69, 0.19, "2D/3D"),
    MatrixSpec(2, "chem_master1", 40401, 201201, 4.98, 0.14, 0.02, "2D/3D"),
    MatrixSpec(3, "torso1", 116158, 8516500, 73.31, 419.58, 5.72, "2D/3D"),
    MatrixSpec(4, "torso2", 115067, 1033473, 8.91, 0.58, 0.06, "2D/3D"),
    MatrixSpec(5, "torso3", 259156, 4429042, 17.09, 4.39, 0.25, "2D/3D"),
    MatrixSpec(6, "memplus", 17758, 126150, 7.10, 22.03, 3.10,
               "Electric circuit", scatter=True),
    MatrixSpec(7, "ex19", 12005, 259879, 21.64, 12.28, 0.56, "Fluid dynamics"),
    MatrixSpec(8, "poisson3Da", 13514, 352762, 26.10, 13.76, 0.52,
               "Fluid dynamics"),
    MatrixSpec(9, "poisson3Db", 85623, 2374949, 27.73, 14.71, 0.53,
               "Fluid dynamics"),
    MatrixSpec(10, "airfoil_2d", 14214, 259688, 18.26, 3.94, 0.21,
               "Fluid dynamics"),
    MatrixSpec(11, "viscoplastic2", 32769, 381326, 11.63, 13.95, 1.19,
               "Materials", scatter=True),
    MatrixSpec(12, "xenon1", 48600, 1181120, 24.30, 4.25, 0.17, "Materials"),
    MatrixSpec(13, "xenon2", 157464, 3866688, 24.55, 4.06, 0.16, "Materials"),
    MatrixSpec(14, "wang3", 26064, 177168, 6.79, 0.43, 0.06, "Semiconductor"),
    MatrixSpec(15, "wang4", 26068, 177196, 6.79, 0.43, 0.06, "Semiconductor"),
    MatrixSpec(16, "ec132", 51993, 380415, 7.31, 3.35, 0.45, "Semiconductor"),
    MatrixSpec(17, "sme3Da", 12504, 874887, 69.96, 34.92, 0.49, "Structural"),
    MatrixSpec(18, "sme3Db", 29067, 2081063, 71.59, 37.06, 0.51, "Structural"),
    MatrixSpec(19, "sme3Dc", 42930, 3148656, 73.34, 36.98, 0.50, "Structural"),
    MatrixSpec(20, "epb1", 14734, 95053, 6.45, 0.57, 0.08, "Thermal"),
    MatrixSpec(21, "epb2", 25228, 175027, 6.93, 6.38, 0.92, "Thermal",
               scatter=True),
    MatrixSpec(22, "epb3", 84617, 463625, 5.47, 0.54, 0.10, "Thermal"),
)


# ---------------------------------------------------------------------------
# row-length models
# ---------------------------------------------------------------------------
def _lengths_normal(rng: np.random.Generator, n: int, mu: float,
                    sigma: float) -> np.ndarray:
    lens = np.rint(rng.normal(mu, sigma, size=n)).astype(np.int64)
    return np.clip(lens, 1, n)


def _lengths_two_point(n: int, mu: float, sigma: float) -> np.ndarray:
    """Deterministic two-point mixture matching (mu, sigma) exactly:
    f*B + (1-f)*S = mu ;  f*B^2 + (1-f)*S^2 = sigma^2 + mu^2."""
    s = max(1, int(round(mu / 2)))
    m2 = sigma * sigma + mu * mu
    big = (m2 - s * s) / max(mu - s, 1e-9)          # B = E[L^2]-S^2 / E[L]-S
    f = (mu - s) / max(big - s, 1e-9)
    big = int(min(round(big), n))                    # ELL width cap = n
    n_big = max(1, int(round(f * n)))
    lens = np.full(n, s, dtype=np.int64)
    # spread long rows evenly so bands don't collide
    idx = np.linspace(0, n - 1, n_big).astype(np.int64)
    lens[idx] = big
    return lens


def _adjust_total(lens: np.ndarray, target_nnz: int, n: int) -> np.ndarray:
    """Exactly hit the target total by +/-1 adjustments on random rows."""
    lens = lens.copy()
    diff = int(target_nnz - lens.sum())
    if diff == 0:
        return lens
    step = 1 if diff > 0 else -1
    k = abs(diff)
    order = np.argsort(lens) if step > 0 else np.argsort(-lens)
    i = 0
    while k > 0:
        r = order[i % n]
        new = lens[r] + step
        if 1 <= new <= n:
            lens[r] = new
            k -= 1
        i += 1
    return lens


# ---------------------------------------------------------------------------
# column patterns
# ---------------------------------------------------------------------------
def _band_cols(i: int, length: int, n: int) -> np.ndarray:
    start = min(max(i - length // 2, 0), n - length)
    return np.arange(start, start + length, dtype=np.int32)


_PRIMES = (1000003, 411451, 611953)


def _scatter_cols(i: int, length: int, n: int, salt: int) -> np.ndarray:
    """Unique pseudo-random columns: i + k*h (mod n) with gcd(h, n) = 1."""
    h = _PRIMES[salt % len(_PRIMES)]
    while np.gcd(h, n) != 1:
        h += 2
    return ((i + np.arange(length, dtype=np.int64) * h) % n).astype(np.int32)


# ---------------------------------------------------------------------------
# matrix synthesis
# ---------------------------------------------------------------------------
def synthesize(spec: MatrixSpec, scale: float = 1.0, seed: int = 0,
               pad: int = 8) -> CSR:
    """Generate a CSR matrix matching ``spec``'s row statistics.

    ``scale`` < 1 shrinks N (and NNZ proportionally) for quick CPU timing
    runs while preserving mu/sigma/D_mat — the statistics the AT method keys
    on are scale-invariant."""
    rng = np.random.default_rng(seed + spec.no)
    n = max(int(round(spec.n * scale)), 64)
    nnz = max(int(round(spec.nnz * scale)), n)
    if spec.d_mat >= 0.8:
        lens = _lengths_two_point(n, spec.mu, spec.sigma)
    else:
        lens = _lengths_normal(rng, n, spec.mu, spec.sigma)
    lens = _adjust_total(lens, nnz, n)
    lens = np.minimum(lens, n)

    row_cols: List[np.ndarray] = []
    row_vals: List[np.ndarray] = []
    for i in range(n):
        L = int(lens[i])
        cols = (_scatter_cols(i, L, n, spec.no) if spec.scatter
                else _band_cols(i, L, n))
        row_cols.append(cols)
        row_vals.append(np.full(L, 1.0, dtype=np.float32))
    csr = csr_from_rows(row_cols, row_vals, n_cols=n, pad=pad)
    # deterministic value pattern (diag-dominant-ish), cheap:
    vals = np.asarray(csr.data).copy()
    vals[:csr.nnz] = 1.0 + 0.01 * (np.arange(csr.nnz) % 7)
    return CSR(data=vals, cols=csr.cols, indptr=csr.indptr,
               shape=csr.shape, nnz=csr.nnz)


def paper_suite(scale: float = 1.0, seed: int = 0,
                include: Optional[Sequence[str]] = None,
                skip_ell_overflow: bool = False) -> List[Tuple[str, CSR]]:
    """The 22-matrix Table-1 suite.  ``skip_ell_overflow`` drops torso1,
    mirroring the paper ("the overflow memory space is in the ELL format ...
    we removed the data")."""
    out = []
    for spec in TABLE1:
        if include is not None and spec.name not in include:
            continue
        if skip_ell_overflow and spec.name == "torso1":
            continue
        out.append((spec.name, synthesize(spec, scale=scale, seed=seed)))
    return out


def synthesize_power_law(n: int = 8192, mu: float = 8.0, alpha: float = 2.0,
                         seed: int = 0, random_values: bool = False) -> CSR:
    """Beyond Table 1: Zipf-ish row lengths (most rows short, a few huge) —
    the heavy-tail structure that stalls whole-matrix ELL via max_row
    padding, used by the partition subsystem's benchmarks and tests."""
    rng = np.random.default_rng(seed)
    lens = np.minimum((rng.pareto(alpha, size=n) + 1) * mu / 2, n // 2)
    lens = np.maximum(lens.astype(np.int64), 1)
    row_cols: List[np.ndarray] = []
    row_vals: List[np.ndarray] = []
    for i in range(n):
        L = int(lens[i])
        start = min(max(i - L // 2, 0), n - L)
        row_cols.append(np.arange(start, start + L, dtype=np.int32))
        row_vals.append(rng.normal(size=L).astype(np.float32)
                        if random_values else np.full(L, 1.0, np.float32))
    return csr_from_rows(row_cols, row_vals, n_cols=n, pad=8)


def verify_suite(scale: float = 1.0, rtol: float = 0.25) -> List[str]:
    """Return a list of mismatch messages (empty = all stats reproduced)."""
    msgs = []
    for spec in TABLE1:
        st = MatrixStats.of(synthesize(spec, scale=scale))
        for field, want, got in (("mu", spec.mu, st.mu),
                                 ("d_mat", spec.d_mat, st.d_mat)):
            if abs(got - want) > rtol * max(want, 0.05):
                msgs.append(f"{spec.name}.{field}: want {want}, got {got:.3f}")
    return msgs


__all__ = ["MatrixSpec", "TABLE1", "synthesize", "synthesize_power_law",
           "paper_suite", "verify_suite"]
