"""One plan to rule them all: the serializable :class:`ExecutionPlan` API.

The paper's method is a single pipeline — profile the machine (off-line),
read the matrix's D_mat, decide the format, transform at run time, launch —
but the reproduction grew it as four disjoint contracts: the ``decide_*``
family + :class:`~repro.core.autotune.TuningDB`, the
:class:`~repro.core.kernel_tune.KernelTuner`/``TileGeometry`` layer,
``TRANSFORMS_HOST`` recipes, and per-consumer wiring in ``AutoTunedSpMV``
and ``SpMVService``.  Like AlphaSparse's "operator designs" and
SELL-C-sigma's single parametrised format, the decision artifact itself
should be first class and portable: tune once, save the plan, replay it on
any matrix with the same structure.

This module provides exactly that:

  * :class:`ExecutionPlan` — one versioned, JSON-serializable object
    capturing everything between a CSR source and a launched kernel:
    decision rule + chosen format, transform recipe (name + params, e.g.
    SELL slice rows or BCSR block size), per-op
    :class:`~repro.core.kernel_tune.TileGeometry` (including per-bucket
    SELL tables), batch axis, execution tier (reference/kernel), and the
    fingerprint of the matrix it was tuned on.  Hybrid plans carry one
    leaf sub-plan per row block (:class:`BlockPlan`).
  * :class:`Planner` — the single entry point that subsumes
    ``decide_paper`` / ``decide_generalized`` / ``decide_cost_model``
    behind a ``rule=`` strategy and composes the
    :class:`~repro.core.kernel_tune.KernelTuner`, so format selection and
    launch geometry come out of one call.
  * :class:`PlannedMatrix` — ``plan.bind(csr)``: the plan applied to a
    concrete matrix; ``y = P @ x`` serves SpMV (1-D x) and SpMM
    ((n_cols, B) panels) through one ``__matmul__``.

Persistence mirrors the TuningDB JSON conventions
(``save``/``load``/``to_json``/``from_json``) with a strict
``schema_version`` check: a plan written by a future schema is rejected
with :class:`PlanSchemaError` instead of being half-read.  Binding a plan
to a matrix whose fingerprint differs from the one it was tuned on keeps
the format decision but re-resolves launch geometry — the D_mat-keyed
``nearest_geometry`` fallback when a TuningDB is at hand, else the plan's
own geometry stripped of its matrix-specific slab bound.
"""
from __future__ import annotations

import functools
import json
import time
from dataclasses import asdict, dataclass, field, replace
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

import repro.obs as _obs

from . import dispatch as _dispatch
from .autotune import (MachineModel, TuningDB, decide_cost_model,
                       decide_generalized, decide_paper)
from .formats import CSR, MatrixStats, memory_bytes, validate_container
from .kernel_tune import KernelTuner, TileGeometry, _structure_sig

SCHEMA_VERSION = 1

#: recipe params recorded explicitly so a saved plan replays the same
#: transformation even if the library's defaults later change
DEFAULT_RECIPE_PARAMS: Dict[str, Dict[str, Any]] = {
    "sell": {"slice_rows": 128, "width_quantum": 8},
    "bcsr": {"block": 8},
}

#: formats whose kernels carry a data-dependent slab-coverage bound that
#: must be (re)derived per concrete matrix
_SLAB_FORMATS = ("csr", "ccs", "bcsr")


def _finite_or_none(v: float) -> Optional[float]:
    """Non-finite floats (NaN d_star on cost-model/hybrid plans, inf d_mat
    on degenerate matrices) serialize as null so the artifact stays strict
    RFC-compliant JSON for non-Python consumers."""
    return float(v) if np.isfinite(v) else None


def _nan_if_none(v: Any) -> float:
    return float("nan") if v is None else float(v)


class PlanError(ValueError):
    """Malformed or unusable ExecutionPlan payload."""


class PlanSchemaError(PlanError):
    """Schema-version mismatch: written by a different plan schema."""


# ---------------------------------------------------------------------------
# fingerprint + transform recipe
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class PlanFingerprint:
    """Structural identity of the matrix a plan was tuned on.

    ``sig`` is the CRC of the index-pointer array (same fingerprint the
    kernel tuner memoizes on): two matrices share a fingerprint iff their
    CSR index structure is byte-identical, which is exactly the condition
    under which a matrix-specific slab-coverage bound remains valid."""
    n: int
    nnz: int
    mu: float
    sigma: float
    d_mat: float
    sig: int = 0

    @staticmethod
    def from_stats(stats: MatrixStats, sig: int) -> "PlanFingerprint":
        return PlanFingerprint(n=stats.n, nnz=stats.nnz, mu=stats.mu,
                               sigma=stats.sigma, d_mat=stats.d_mat,
                               sig=sig)

    @staticmethod
    def of(csr: CSR) -> "PlanFingerprint":
        return PlanFingerprint.from_stats(MatrixStats.of(csr),
                                          _structure_sig(csr))

    def matches(self, other: Any) -> bool:
        """Exact structural match (same rows, nnz, and index structure).
        Dimensions are compared before paying for the CRC pass."""
        if self.sig == 0:
            return False
        if isinstance(other, PlanFingerprint):
            return (self.n == other.n and self.nnz == other.nnz
                    and self.sig == other.sig)
        if (self.n != int(getattr(other, "n_rows", -1))
                or self.nnz != int(getattr(other, "nnz", -1))):
            return False
        return self.sig == _structure_sig(other)


@dataclass
class TransformRecipe:
    """Name + params of the run-time transformation (host path)."""
    name: str
    params: Dict[str, Any] = field(default_factory=dict)

    def apply(self, csr: CSR) -> Any:
        return apply_transform(self.name, csr, **self.params)

    def to_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "params": dict(self.params)}

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "TransformRecipe":
        return TransformRecipe(name=d["name"],
                               params=dict(d.get("params", {})))


def apply_transform(name: str, csr: CSR, **params) -> Any:
    """Materialize ``name`` from a CSR source with explicit recipe params
    (the parameter-aware face of ``TRANSFORMS_HOST``)."""
    from . import transform as T
    if name == "csr":
        return csr
    if name == "ell_row":
        return T.host_csr_to_ell(csr, order="row", **params)
    if name == "ell_col":
        return T.host_csr_to_ell(csr, order="col", **params)
    if name == "sell":
        return T.host_csr_to_sell(csr, **params)
    if name == "bcsr":
        return T.host_csr_to_bcsr(csr, **params)
    if name == "coo_row":
        return T.host_csr_to_coo_row(csr)
    if name == "coo_col":
        return T.host_csr_to_coo_col(csr)
    if name == "ccs":
        return T.host_csr_to_ccs(csr)
    if name in T.TRANSFORMS_HOST:  # hybrid / future registrations
        return T.TRANSFORMS_HOST[name](csr, **params) if params \
            else T.TRANSFORMS_HOST[name](csr)
    raise PlanError(f"unknown transform {name!r}")


# ---------------------------------------------------------------------------
# the plan
# ---------------------------------------------------------------------------
@dataclass
class BlockPlan:
    """One hybrid row block: the permuted row range it covers and the leaf
    plan (format + recipe + geometry) that serves it."""
    rows: Tuple[int, int]
    plan: "ExecutionPlan"

    def to_dict(self) -> Dict[str, Any]:
        return {"rows": list(self.rows), "plan": self.plan.to_dict()}

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "BlockPlan":
        return BlockPlan(rows=(int(d["rows"][0]), int(d["rows"][1])),
                         plan=ExecutionPlan.from_dict(d["plan"]))


@dataclass
class ExecutionPlan:
    """Everything between a CSR source and a launched kernel, in one
    versioned, JSON-serializable artifact.

    ``geometry`` maps op name (``"spmv"``/``"spmm"``) to the tuned
    :class:`TileGeometry` (absent op = default launch).  ``blocks`` is the
    per-row-block sub-plan list of a hybrid plan (``None`` for leaves)."""
    fmt: str
    rule: str = "cost_model"
    tier: str = "reference"            # "reference" | "kernel"
    batch: int = 1
    expected_iterations: int = 100
    transform: TransformRecipe = None  # defaults to fmt with no params
    geometry: Dict[str, TileGeometry] = field(default_factory=dict)
    fingerprint: Optional[PlanFingerprint] = None
    machine: str = ""
    d_mat: float = 0.0
    d_star: float = 0.0
    expected_gain: float = 0.0
    blocks: Optional[List[BlockPlan]] = None
    schema_version: int = SCHEMA_VERSION

    def __post_init__(self):
        if self.transform is None:
            self.transform = TransformRecipe(
                self.fmt, dict(DEFAULT_RECIPE_PARAMS.get(self.fmt, {})))

    # -- views ---------------------------------------------------------------
    @property
    def is_hybrid(self) -> bool:
        return self.fmt == "hybrid" or bool(self.blocks)

    def block_formats(self) -> Tuple[str, ...]:
        return tuple(bp.plan.fmt for bp in self.blocks or ())

    def tunings_by_format(self) -> Dict[str, Dict[str, TileGeometry]]:
        """``{op: {format: TileGeometry}}`` — the shape the serving layer
        binds into per-block impl tables.  For a hybrid plan the per-block
        sub-plans are collapsed per format (first block of each format
        wins, matching how one jitted per-format impl serves all sibling
        blocks); leaf plans contribute their own geometry."""
        out: Dict[str, Dict[str, TileGeometry]] = {}
        for bp in self.blocks or ():
            for op, g in bp.plan.geometry.items():
                out.setdefault(op, {}).setdefault(bp.plan.fmt, g)
        for op, g in self.geometry.items():
            out.setdefault(op, {})[self.fmt] = g
        return out

    # -- persistence ---------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {
            "schema_version": self.schema_version,
            "fmt": self.fmt, "rule": self.rule, "tier": self.tier,
            "batch": self.batch,
            "expected_iterations": self.expected_iterations,
            "transform": self.transform.to_dict(),
            "geometry": {op: g.to_dict()
                         for op, g in self.geometry.items()},
            "machine": self.machine,
            "d_mat": _finite_or_none(self.d_mat),
            "d_star": _finite_or_none(self.d_star),
            "expected_gain": _finite_or_none(self.expected_gain),
        }
        if self.fingerprint is not None:
            d["fingerprint"] = {k: (_finite_or_none(v)
                                    if isinstance(v, float) else v)
                                for k, v in asdict(self.fingerprint).items()}
        if self.blocks is not None:
            d["blocks"] = [bp.to_dict() for bp in self.blocks]
        return d

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "ExecutionPlan":
        if not isinstance(d, dict):
            raise PlanError(f"ExecutionPlan payload must be an object; "
                            f"got {type(d).__name__}")
        ver = d.get("schema_version")
        if ver != SCHEMA_VERSION:
            raise PlanSchemaError(
                f"unsupported ExecutionPlan schema_version={ver!r}; this "
                f"build reads version {SCHEMA_VERSION}.  Re-plan with "
                f"repro.Planner (old plans are cheap to regenerate — the "
                f"expensive TuningDB is versioned separately).")
        try:
            fp = d.get("fingerprint")
            blocks = d.get("blocks")
            if fp is not None:
                fp = {k: (_nan_if_none(v) if k in ("mu", "sigma", "d_mat")
                          else v) for k, v in fp.items()}
            return ExecutionPlan(
                fmt=d["fmt"], rule=d["rule"], tier=d["tier"],
                batch=int(d["batch"]),
                expected_iterations=int(d["expected_iterations"]),
                transform=TransformRecipe.from_dict(d["transform"]),
                geometry={op: TileGeometry.from_dict(g)
                          for op, g in d.get("geometry", {}).items()},
                fingerprint=PlanFingerprint(**fp) if fp else None,
                machine=d.get("machine", ""),
                d_mat=_nan_if_none(d.get("d_mat", 0.0)),
                d_star=_nan_if_none(d.get("d_star")),
                expected_gain=_nan_if_none(d.get("expected_gain", 0.0)),
                blocks=[BlockPlan.from_dict(b) for b in blocks]
                if blocks is not None else None,
                schema_version=int(ver),
            )
        except PlanError:
            raise
        except (KeyError, TypeError, ValueError) as e:
            raise PlanError(f"malformed ExecutionPlan payload: {e!r}") from e

    def to_json(self) -> str:
        # allow_nan=False: non-finite values were mapped to null in
        # to_dict; anything that slips through should fail loudly here
        # rather than emit a Python-only artifact
        return json.dumps(self.to_dict(), indent=1, allow_nan=False)

    @staticmethod
    def from_json(s: str) -> "ExecutionPlan":
        try:
            obj = json.loads(s)
        except json.JSONDecodeError as e:
            raise PlanError(f"ExecutionPlan payload is not valid JSON: {e}") \
                from e
        return ExecutionPlan.from_dict(obj)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @staticmethod
    def load(path: str) -> "ExecutionPlan":
        with open(path) as f:
            return ExecutionPlan.from_json(f.read())

    # -- materialization -----------------------------------------------------
    def materialize(self, csr: CSR):
        """Replay the recorded per-block decisions on ``csr`` and return
        ``(HybridMatrix, HybridReport)`` — no decision machinery re-runs.
        Leaf plans wrap into a single-block hybrid container so one code
        path serves both shapes (the serving layer's native form)."""
        from repro.partition.hybrid import (BlockDecision, HybridMatrix,
                                            HybridReport, slice_csr,
                                            take_rows_csr)
        if not self.blocks:
            t0 = time.perf_counter()
            obj = self.transform.apply(csr)
            dt = time.perf_counter() - t0
            hyb = HybridMatrix(
                perm=np.arange(csr.n_rows, dtype=np.int32),
                blocks=(obj,), row_offsets=(0,), formats=(self.fmt,),
                shape=csr.shape, nnz=csr.nnz, identity_perm=True)
            report = HybridReport(
                strategy="plan", n_blocks=1, t_partition=0.0,
                t_transform=dt,
                decisions=[BlockDecision(
                    fmt=self.fmt, rows=(0, csr.n_rows), d_mat=self.d_mat,
                    nnz=csr.nnz, bytes=memory_bytes(obj), t_transform=dt,
                    plan=self)])
            return hyb, report

        if self.blocks[-1].rows[1] != csr.n_rows:
            raise PlanError(
                f"plan's blocks cover {self.blocks[-1].rows[1]} rows but "
                f"the matrix has {csr.n_rows}; re-plan for this matrix")
        sort_rows = bool(self.transform.params.get(
            "sort_rows", self.transform.params.get("strategy") == "variance"))
        t0 = time.perf_counter()
        if sort_rows:
            lens = csr.row_lengths().astype(np.int64)
            perm = np.argsort(-lens, kind="stable").astype(np.int32)
        else:
            perm = np.arange(csr.n_rows, dtype=np.int32)
        t_partition = time.perf_counter() - t0

        blocks, fmts, offsets, decisions = [], [], [], []
        t_transform = 0.0
        for bp in self.blocks:
            s, e = bp.rows
            sub = (take_rows_csr(csr, perm[s:e]) if sort_rows
                   else slice_csr(csr, s, e))
            t1 = time.perf_counter()
            obj = bp.plan.transform.apply(sub)
            dt = time.perf_counter() - t1
            t_transform += dt
            blocks.append(obj)
            fmts.append(bp.plan.fmt)
            offsets.append(s)
            decisions.append(BlockDecision(
                fmt=bp.plan.fmt, rows=bp.rows, d_mat=bp.plan.d_mat,
                nnz=sub.nnz, bytes=memory_bytes(obj), t_transform=dt,
                plan=bp.plan))
        hyb = HybridMatrix(perm=perm, blocks=tuple(blocks),
                           row_offsets=tuple(offsets), formats=tuple(fmts),
                           shape=csr.shape, nnz=csr.nnz,
                           identity_perm=not sort_rows)
        report = HybridReport(
            strategy=str(self.transform.params.get("strategy", "plan")),
            n_blocks=len(blocks), t_partition=t_partition,
            t_transform=t_transform, decisions=decisions)
        return hyb, report

    # -- binding -------------------------------------------------------------
    def bind(self, csr: CSR, *, db: Optional[TuningDB] = None,
             tier: Optional[str] = None, interpret: Optional[bool] = None,
             impls: Optional[Dict[str, Callable]] = None,
             spmm_impls: Optional[Dict[str, Callable]] = None,
             jit: bool = True) -> "PlannedMatrix":
        """Apply the plan to a concrete matrix: transform, resolve impls at
        the plan's tier, attach launch geometry, and return a
        :class:`PlannedMatrix` serving ``P @ x``.

        If ``csr``'s fingerprint differs from the one the plan was tuned
        on, the format decision is kept but geometry is re-resolved: via
        ``db.best_geometry`` (the D_mat-keyed ``nearest_geometry``
        fallback) when a TuningDB is supplied, else the plan's own
        geometry stripped of its matrix-specific slab-coverage bound.
        ``impls``/``spmm_impls`` are opaque per-format overrides (used
        as-is, no geometry attached) for compatibility with the old
        ``AutoTunedSpMV`` call sites."""
        tier = tier or self.tier
        csr.validate()       # fail loudly here, not as garbage in a kernel
        matched = (self.fingerprint is not None
                   and self.fingerprint.matches(csr))
        if self.is_hybrid:
            return self._bind_hybrid(csr, matched, tier=tier, db=db,
                                     interpret=interpret, jit=jit,
                                     impls=impls, spmm_impls=spmm_impls)

        # reuse the object the tuner already materialized for this exact
        # source (identity-keyed: a same-structure matrix with different
        # values must still re-transform); consumed once so the plan never
        # pins matrix-sized arrays past its first bind
        cache = self.__dict__.pop("_mat_cache", None)
        matrix = (cache[1] if cache is not None and cache[0] is csr
                  else self.transform.apply(csr))
        # check the *transformed* container too: a buggy or bit-rotted
        # transform fails here, not as garbage indices inside a kernel
        validate_container(matrix)
        d_mat_new: Optional[float] = None  # computed once, only if needed
        overrides = {"spmv": impls or {}, "spmm": spmm_impls or {}}
        fns: Dict[str, Callable] = {}
        used: Dict[str, Any] = {}
        tiers: Dict[str, str] = {}
        for op in ("spmv", "spmm"):
            g = self.geometry.get(op)
            if not matched and g is not None:
                alt = None
                if db is not None:
                    if d_mat_new is None:
                        d_mat_new = MatrixStats.of(csr).d_mat
                    alt = db.best_geometry(self.fmt, d_mat_new, op=op,
                                           batch=self.batch)
                g = alt if alt is not None else g.without_slab_bound()
            if self.fmt in overrides[op]:
                fn, found = overrides[op][self.fmt], "override"
            else:
                fn, found = _dispatch.resolve_impl(self.fmt, op, tier=tier)
            if found == "kernel":
                if self.fmt in _SLAB_FORMATS:
                    # the bound is exact for *this* matrix at the effective
                    # launch — derived here so the jitted dispatcher keeps
                    # a tight launch instead of the traced full sweep
                    from repro.kernels.ops import exact_slab_bound
                    base = g if g is not None else TileGeometry()
                    spb = exact_slab_bound(matrix, base)
                    g = replace(base.without_slab_bound(),
                                slabs_per_block=spb)
                kw: Dict[str, Any] = {}
                if g is not None:
                    kw["tuning"] = g
                if interpret is not None:
                    kw["interpret"] = interpret
                if kw:
                    fn = functools.partial(fn, **kw)
            fns[op] = fn
            used[op] = g
            tiers[op] = found
        return PlannedMatrix(self, csr, matrix, fns, used, tiers,
                             fingerprint_matched=matched, jit=jit)

    def _bind_hybrid(self, csr: CSR, matched: bool, *,
                     tier: str, db: Optional[TuningDB],
                     interpret: Optional[bool], jit: bool,
                     impls: Optional[Dict[str, Callable]] = None,
                     spmm_impls: Optional[Dict[str, Callable]] = None
                     ) -> "PlannedMatrix":
        if matched and self.blocks:
            hyb, report = self.materialize(csr)
        else:
            # different structure: keep the recipe (strategy, sorting) but
            # re-partition and re-decide per block on the new matrix
            from repro.partition.hybrid import build_hybrid
            hyb, report = build_hybrid(
                csr, db=db, batch=self.batch,
                expected_iterations=self.expected_iterations,
                **self.transform.params)
        for blk in hyb.blocks:
            validate_container(blk)
        tunings = self.tunings_by_format()
        if not matched:
            tunings = {op: {f: g.without_slab_bound()
                            for f, g in per.items()}
                       for op, per in tunings.items()}
        by_fmt = blocks_by_format(hyb)
        overrides = {"spmv": impls or {}, "spmm": spmm_impls or {}}
        fns, used, tiers = {}, {}, {}
        for op in ("spmv", "spmm"):
            per = dict(tunings.get(op, {}))
            if "hybrid" in overrides[op]:
                fn, found = overrides[op]["hybrid"], "override"
            else:
                fn, found = _dispatch.resolve_impl("hybrid", op, tier=tier)
            if found == "kernel":
                per = rederive_slab_bounds(per, by_fmt)
                kw: Dict[str, Any] = {}
                if per:
                    kw["tuning"] = per
                if interpret is not None:
                    kw["interpret"] = interpret
                if kw:
                    fn = functools.partial(fn, **kw)
            fns[op] = fn
            used[op] = per or None
            tiers[op] = found
        return PlannedMatrix(self, csr, hyb, fns, used, tiers,
                             fingerprint_matched=matched, report=report,
                             jit=jit)


def blocks_by_format(hyb: Any) -> Dict[str, List[Any]]:
    """Group a hybrid container's blocks by their format name."""
    by_fmt: Dict[str, List[Any]] = {}
    for blk, f in zip(hyb.blocks, hyb.formats):
        by_fmt.setdefault(f, []).append(blk)
    return by_fmt


def _accepts_tuning(fn: Callable) -> bool:
    """Whether ``fn`` takes a ``tuning=`` kwarg (kernel-tier wrappers do;
    user-supplied reference impls typically don't)."""
    import inspect
    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):
        return True
    return ("tuning" in sig.parameters
            or any(p.kind == p.VAR_KEYWORD
                   for p in sig.parameters.values()))


def bind_tunings(impls: Dict[str, Callable],
                 tunings: Dict[str, TileGeometry]) -> Dict[str, Callable]:
    """``{fmt: impl}`` with each format's tuned geometry partially applied.
    Impls that don't accept ``tuning=`` (custom overrides) pass through
    untouched rather than blowing up at first call inside a jitted
    dispatcher."""
    return {f: (functools.partial(fn, tuning=tunings[f])
                if f in tunings and _accepts_tuning(fn) else fn)
            for f, fn in impls.items()}


def rederive_slab_bounds(per_fmt: Dict[str, TileGeometry],
                         blocks_by_fmt: Dict[str, List[Any]]
                         ) -> Dict[str, TileGeometry]:
    """Re-derive the CSR/CCS/BCSR slab-coverage bound of each per-format
    geometry over *all* concrete blocks of that format (sibling blocks
    share one jitted per-format impl, so the baked bound must cover the
    worst of them — a larger bound only adds masked slabs)."""
    out = dict(per_fmt)
    for f, g in per_fmt.items():
        blks = blocks_by_fmt.get(f)
        if blks and f in _SLAB_FORMATS:
            from repro.kernels.ops import exact_slab_bound
            spb = max(exact_slab_bound(b, g) for b in blks)
            out[f] = replace(g.without_slab_bound(), slabs_per_block=spb)
    return out


# ---------------------------------------------------------------------------
# the bound operator
# ---------------------------------------------------------------------------
class PlannedMatrix:
    """A plan applied to a concrete matrix.  ``y = P @ x`` dispatches on
    x's rank: 1-D serves SpMV, ``(n_cols, B)`` serves SpMM — both through
    jit-compiled dispatchers built once at bind time."""

    def __init__(self, plan: ExecutionPlan, source: CSR, matrix: Any,
                 fns: Dict[str, Callable], tunings: Dict[str, Any],
                 tiers: Dict[str, str], fingerprint_matched: bool,
                 report: Any = None, jit: bool = True):
        self.plan = plan
        self.source = source
        self.matrix = matrix
        self.report = report
        self.tunings = tunings            # geometry actually bound, per op
        self.tiers = tiers                # tier each op resolved to
        self.fingerprint_matched = fingerprint_matched
        self._fns = ({op: jax.jit(lambda m, v, _f=f: _f(m, v))
                      for op, f in fns.items()} if jit else dict(fns))

    @property
    def fmt(self) -> str:
        return self.plan.fmt

    @property
    def shape(self) -> Tuple[int, int]:
        return self.source.shape

    @property
    def n_rows(self) -> int:
        return self.source.shape[0]

    @property
    def n_cols(self) -> int:
        return self.source.shape[1]

    def spmv(self, x: jax.Array) -> jax.Array:
        x = jnp.asarray(x)
        if x.ndim != 1:
            raise ValueError(f"spmv expects x of shape ({self.n_cols},); "
                             f"got {x.shape}")
        return self._fns["spmv"](self.matrix, x)

    def spmm(self, x: jax.Array) -> jax.Array:
        x = jnp.asarray(x)
        if x.ndim != 2:
            raise ValueError(f"spmm expects x of shape ({self.n_cols}, B); "
                             f"got {x.shape}")
        return self._fns["spmm"](self.matrix, x)

    def __matmul__(self, x: jax.Array) -> jax.Array:
        x = jnp.asarray(x)
        return self.spmv(x) if x.ndim == 1 else self.spmm(x)

    def __call__(self, x: jax.Array) -> jax.Array:
        return self @ x

    def __repr__(self) -> str:
        return (f"PlannedMatrix(fmt={self.fmt!r}, shape={self.shape}, "
                f"tier={self.plan.tier!r}, "
                f"fingerprint_matched={self.fingerprint_matched})")


# ---------------------------------------------------------------------------
# helper shared with the partition layer
# ---------------------------------------------------------------------------
def leaf_plan(csr: CSR, stats: MatrixStats, fmt: str, rule: str,
              batch: int = 1, expected_iterations: int = 100,
              machine: str = "", tier: str = "reference",
              d_star: float = float("nan"),
              expected_gain: float = 0.0) -> ExecutionPlan:
    """A leaf plan for one (sub-)matrix — what ``build_hybrid`` emits per
    row block (geometry is attached later by the Planner / service).
    Reuses the caller's already-computed ``stats`` so per-block minting
    never doubles the stats pass."""
    fp = PlanFingerprint.from_stats(stats, _structure_sig(csr))
    return ExecutionPlan(
        fmt=fmt, rule=rule, tier=tier, batch=max(int(batch), 1),
        expected_iterations=max(int(expected_iterations), 1),
        transform=TransformRecipe(fmt,
                                  dict(DEFAULT_RECIPE_PARAMS.get(fmt, {}))),
        fingerprint=fp, machine=machine,
        d_mat=stats.d_mat, d_star=d_star, expected_gain=expected_gain)


# ---------------------------------------------------------------------------
# the sharded plan — per-device slabs, one ExecutionPlan per shard
# ---------------------------------------------------------------------------
SHARDED_SCHEMA_VERSION = 1


def _shard_lens(csr: CSR, axis: str) -> np.ndarray:
    """Work vector the partitioners cut: nnz per row (row sharding) or
    nnz per column (column sharding)."""
    if axis == "row":
        return csr.row_lengths().astype(np.int64)
    if axis == "col":
        cols = np.asarray(csr.cols)[:csr.nnz]
        return np.bincount(cols, minlength=csr.n_cols).astype(np.int64)
    raise PlanError(f"unknown sharding axis {axis!r}; one of ('row', 'col')")


def shard_boundaries(csr: CSR, n_shards: int, axis: str = "row",
                     strategy: str = "balanced_nnz",
                     **strategy_kw) -> np.ndarray:
    """Exactly ``n_shards + 1`` slab boundaries along ``axis`` via the
    partition strategies lifted to device-count granularity."""
    from repro.partition.strategies import partition_for_devices
    return partition_for_devices(_shard_lens(csr, axis), n_shards,
                                 strategy=strategy, **strategy_kw)


def slice_shard(csr: CSR, s: int, e: int, axis: str = "row") -> CSR:
    """The [s, e) slab of ``csr`` along the sharding axis: a row slab with
    the full column space, or a column slab with the full row space."""
    from repro.partition.hybrid import slice_csr, slice_csr_cols
    return (slice_csr(csr, s, e) if axis == "row"
            else slice_csr_cols(csr, s, e))


@dataclass
class ShardedPlan:
    """The distributed decision artifact: one :class:`ExecutionPlan` per
    device slab plus the partition recipe and mesh shape that produced
    them — everything needed to replay a multi-device SpMV/SpMM with zero
    re-tuning.

    ``shards[i].rows`` is the [start, end) slab of shard ``i`` along
    ``axis`` ("row": row slab, full column space, outputs concatenate;
    "col": column slab, full row space, partial outputs psum-reduce), and
    ``shards[i].plan`` is the per-shard plan the :class:`Planner` minted
    on that slab — each device gets its own format + launch geometry.
    Serialization mirrors :class:`ExecutionPlan` (versioned strict JSON;
    a future schema raises :class:`PlanSchemaError`)."""
    shards: List[BlockPlan]
    axis: str = "row"                   # "row" | "col"
    strategy: str = "balanced_nnz"
    params: Dict[str, Any] = field(default_factory=dict)
    mesh_shape: Tuple[int, ...] = ()    # defaults to (n_shards,)
    mesh_axis: str = "shards"
    batch: int = 1
    fingerprint: Optional[PlanFingerprint] = None  # whole-matrix identity
    schema_version: int = SHARDED_SCHEMA_VERSION

    def __post_init__(self):
        if not self.shards:
            raise PlanError("ShardedPlan needs at least one shard")
        if self.axis not in ("row", "col"):
            raise PlanError(f"unknown sharding axis {self.axis!r}")
        if not self.mesh_shape:
            self.mesh_shape = (len(self.shards),)

    # -- views ---------------------------------------------------------------
    @property
    def n_shards(self) -> int:
        return len(self.shards)

    def boundaries(self) -> np.ndarray:
        b = [bp.rows[0] for bp in self.shards] + [self.shards[-1].rows[1]]
        return np.asarray(b, dtype=np.int64)

    def shard_formats(self) -> Tuple[str, ...]:
        return tuple(bp.plan.fmt for bp in self.shards)

    def matches(self, csr: CSR) -> bool:
        return (self.fingerprint is not None
                and self.fingerprint.matches(csr))

    # -- persistence ---------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {
            "kind": "sharded_plan",
            "schema_version": self.schema_version,
            "axis": self.axis, "strategy": self.strategy,
            "params": dict(self.params),
            "mesh_shape": list(self.mesh_shape),
            "mesh_axis": self.mesh_axis,
            "batch": self.batch,
            "shards": [bp.to_dict() for bp in self.shards],
        }
        if self.fingerprint is not None:
            d["fingerprint"] = {k: (_finite_or_none(v)
                                    if isinstance(v, float) else v)
                                for k, v in asdict(self.fingerprint).items()}
        return d

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "ShardedPlan":
        if not isinstance(d, dict):
            raise PlanError(f"ShardedPlan payload must be an object; "
                            f"got {type(d).__name__}")
        ver = d.get("schema_version")
        if ver != SHARDED_SCHEMA_VERSION:
            raise PlanSchemaError(
                f"unsupported ShardedPlan schema_version={ver!r}; this "
                f"build reads version {SHARDED_SCHEMA_VERSION}")
        try:
            fp = d.get("fingerprint")
            if fp is not None:
                fp = {k: (_nan_if_none(v) if k in ("mu", "sigma", "d_mat")
                          else v) for k, v in fp.items()}
            return ShardedPlan(
                shards=[BlockPlan.from_dict(b) for b in d["shards"]],
                axis=d["axis"], strategy=d["strategy"],
                params=dict(d.get("params", {})),
                mesh_shape=tuple(int(s) for s in d.get("mesh_shape", ())),
                mesh_axis=d.get("mesh_axis", "shards"),
                batch=int(d.get("batch", 1)),
                fingerprint=PlanFingerprint(**fp) if fp else None,
                schema_version=int(ver))
        except PlanError:
            raise
        except (KeyError, TypeError, ValueError) as e:
            raise PlanError(f"malformed ShardedPlan payload: {e!r}") from e

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=1, allow_nan=False)

    @staticmethod
    def from_json(s: str) -> "ShardedPlan":
        try:
            obj = json.loads(s)
        except json.JSONDecodeError as e:
            raise PlanError(f"ShardedPlan payload is not valid JSON: {e}") \
                from e
        return ShardedPlan.from_dict(obj)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @staticmethod
    def load(path: str) -> "ShardedPlan":
        with open(path) as f:
            return ShardedPlan.from_json(f.read())

    # -- binding -------------------------------------------------------------
    def bind(self, csr: CSR, **kw) -> Any:
        """Apply the sharded plan to a concrete matrix and return a
        :class:`~repro.sharding.spmv.ShardedPlannedMatrix` serving
        ``P @ x`` / ``P @ X`` across the mesh.  A fingerprint mismatch
        keeps the recipe (axis, strategy, shard count, per-shard formats)
        but re-partitions on the new matrix; see
        :func:`repro.sharding.spmv.build_sharded`."""
        from repro.sharding.spmv import build_sharded
        csr.validate()
        return build_sharded(csr, plan=self, **kw)


# ---------------------------------------------------------------------------
# the planner
# ---------------------------------------------------------------------------
class Planner:
    """One call from CSR to a portable plan.

    ``rule``: ``"paper"`` (the D_mat < D* threshold rule — needs a
    TuningDB), ``"generalized"`` (argmin predicted total time over the
    db's formats), ``"cost_model"`` (measurement-free roofline model), or
    ``"auto"`` (generalized when a db is present, else cost model).

    ``tier``: ``"reference"`` | ``"kernel"`` | ``"auto"`` (kernel when a
    launch-geometry source — a :class:`KernelTuner` or a TuningDB with
    recorded geometries — is at hand, else reference).

    With a ``tuner``, planning also runs the kernel launch-geometry search
    for the chosen format (per op; SpMM at the plan's batch), so format
    selection and tile shapes come out of the same call and ship in the
    same artifact.

    >>> planner = Planner(db=db, tuner=KernelTuner(db=db))
    >>> plan = planner.plan(csr, batch=8, expected_iterations=1000)
    >>> plan.save("plan.json")                 # portable artifact
    >>> P = ExecutionPlan.load("plan.json").bind(csr)
    >>> y = P @ x; Y = P @ X                   # SpMV and SpMM
    """

    def __init__(self, db: Optional[TuningDB] = None,
                 model: Optional[MachineModel] = None,
                 tuner: Optional[KernelTuner] = None,
                 policy: Optional[Any] = None,
                 rule: str = "auto", tier: str = "auto",
                 strategy: str = "variance", lint: bool = True,
                 lint_vmem_budget: Optional[int] = None):
        self.db = db
        self.model = model
        self.tuner = tuner
        self.policy = policy
        self.rule = rule
        self.tier = tier
        self.strategy = strategy
        self.lint = lint
        self.lint_vmem_budget = lint_vmem_budget

    def _self_check(self, plan):
        """Run the static plan lint (``repro.analyze.planlint``) on every
        plan this planner mints — the artifact contract is enforced at
        the mint, not only on replay.  Lint errors are a planner bug, so
        they raise :class:`PlanError`; warnings only count/emit
        telemetry.  Disable with ``Planner(lint=False)``."""
        if not self.lint:
            return plan
        from repro.analyze.planlint import lint_plan as _lint_plan
        findings = _lint_plan(plan.to_dict(),
                              vmem_budget=self.lint_vmem_budget)
        if findings:
            errs = [f for f in findings if f.severity == "error"]
            tel = _obs.get()
            if tel.enabled:
                for f in findings:
                    tel.counter("plan.lint", rule=f.rule,
                                severity=f.severity).inc()
                tel.event("plan.lint", errors=len(errs),
                          warnings=len(findings) - len(errs),
                          first=findings[0].render())
            if errs:
                raise PlanError(
                    "planner self-check failed — the minted plan does "
                    "not satisfy the artifact contract:\n"
                    + "\n".join(f.render() for f in errs))
        return plan

    # -- decision ------------------------------------------------------------
    def _resolve_rule(self, rule: Optional[str]) -> str:
        rule = rule or self.rule
        if rule == "auto":
            return "generalized" if self.db is not None else "cost_model"
        return rule

    def _decide(self, stats: MatrixStats, rule: str,
                formats: Optional[Sequence[str]], k: int, batch: int):
        if rule == "paper":
            if self.db is None:
                raise PlanError("rule='paper' needs a TuningDB (the "
                                "off-line phase's D* thresholds)")
            return decide_paper(self.db, stats,
                                fmt=(formats or ("ell_row",))[0])
        if rule == "generalized":
            if self.db is None:
                raise PlanError("rule='generalized' needs a TuningDB")
            budget = (self.policy.budget_ratio if self.policy is not None
                      else float("inf"))
            return decide_generalized(self.db, stats, k, formats=formats,
                                      memory_budget_ratio=budget,
                                      batch=batch)
        if rule == "cost_model":
            return decide_cost_model(self.model or MachineModel(), stats, k,
                                     formats=formats or ("ell_row", "sell"),
                                     batch=batch)
        raise PlanError(f"unknown rule {rule!r}; one of "
                        "('paper', 'generalized', 'cost_model', 'auto')")

    def _resolve_tier(self, tier: Optional[str]) -> str:
        tier = tier or self.tier
        if tier == "auto":
            has_geo = (self.tuner is not None
                       or bool(getattr(self.db, "geometries", None)))
            return "kernel" if has_geo else "reference"
        if tier not in ("reference", "kernel"):
            raise PlanError(f"unknown tier {tier!r}")
        return tier

    # -- planning ------------------------------------------------------------
    def plan(self, csr: CSR, *, batch: int = 1,
             expected_iterations: int = 100, rule: Optional[str] = None,
             formats: Optional[Sequence[str]] = None,
             tier: Optional[str] = None, fmt: Optional[str] = None,
             partition: Optional[str] = None,
             **partition_kw) -> ExecutionPlan:
        """Decide, tune, and package: one call from a CSR matrix to a
        portable :class:`ExecutionPlan`.

        ``fmt`` forces the format (rule recorded as ``"fixed"``);
        ``partition`` forces a hybrid plan under the named partition
        strategy (extra ``partition_kw`` reach ``build_hybrid``)."""
        batch = max(int(batch), 1)
        k = max(int(expected_iterations), 1)
        stats = MatrixStats.of(csr)
        tier_used = self._resolve_tier(tier)
        rule_used = self._resolve_rule(rule)
        tel = _obs.get()

        with tel.span("plan.plan", rule=rule_used, tier=tier_used,
                      batch=batch, expected_iterations=k, n=stats.n,
                      nnz=stats.nnz, d_mat=stats.d_mat) as plan_span:
            if partition is not None:
                plan_span.set(fmt="hybrid")
                return self._self_check(
                    self._plan_hybrid(csr, stats, rule_used, batch, k,
                                      tier_used, strategy=partition,
                                      formats=formats, **partition_kw))
            if fmt is not None:
                chosen, rule_used = fmt, "fixed"
                d_star, gain = float("nan"), 0.0
                if tel.enabled:
                    # the rule paths emit inside decide_*; the forced-format
                    # path must still land on the decision table
                    tel.counter("plan.decisions", rule="fixed",
                                fmt=chosen).inc()
                    tel.event("plan.decision", rule="fixed", fmt=chosen,
                              d_mat=stats.d_mat, d_star=d_star,
                              expected_gain=gain)
            else:
                decision = self._decide(stats, rule_used, formats, k, batch)
                chosen = decision.fmt
                d_star, gain = decision.d_star, decision.expected_gain
            plan_span.set(fmt=chosen)
            if chosen == "hybrid":
                return self._self_check(
                    self._plan_hybrid(csr, stats, rule_used, batch, k,
                                      tier_used, strategy=self.strategy,
                                      formats=formats, **partition_kw))
            if partition_kw:
                # build_hybrid would raise on unknown kwargs; the leaf path
                # must not silently swallow them instead
                raise PlanError(
                    f"unexpected arguments {sorted(partition_kw)}: partition "
                    f"options apply only to hybrid plans (pass "
                    f"partition=...)")

            plan = ExecutionPlan(
                fmt=chosen, rule=rule_used, tier=tier_used, batch=batch,
                expected_iterations=k,
                transform=TransformRecipe(
                    chosen, dict(DEFAULT_RECIPE_PARAMS.get(chosen, {}))),
                fingerprint=PlanFingerprint.from_stats(stats,
                                                       _structure_sig(csr)),
                machine=self._machine(),
                d_mat=stats.d_mat, d_star=d_star, expected_gain=gain)
            if tier_used == "kernel":
                plan.geometry = self._tune_leaf(csr, stats, plan)
            return self._self_check(plan)

    def build(self, csr: CSR, **plan_kw) -> PlannedMatrix:
        """``plan(csr) .bind(csr)`` in one call."""
        return self.plan(csr, **plan_kw).bind(csr, db=self.db)

    def plan_or_load(self, csr: CSR, store: Any, **plan_kw
                     ) -> ExecutionPlan:
        """Check a :class:`~repro.core.plan_store.PlanStore` before
        planning: a stored plan whose fingerprint matches ``csr`` (under
        the same planning knobs) replays with zero tuner invocations; a
        miss — or a corrupted/stale entry, which the store quarantines
        rather than raises — plans fresh and writes the result back, so
        the whole fleet tunes a structure once."""
        fp = PlanFingerprint.of(csr)
        key = store.key_for(fp, **plan_kw)
        cached = store.get(key, fingerprint=fp)
        if cached is not None:
            return cached
        plan = self.plan(csr, **plan_kw)
        store.put(key, plan)
        return plan

    def plan_sharded(self, csr: CSR, *, n_shards: int, axis: str = "row",
                     strategy: str = "balanced_nnz", batch: int = 1,
                     strategy_kw: Optional[Dict[str, Any]] = None,
                     **plan_kw) -> ShardedPlan:
        """Partition ``csr`` into ``n_shards`` device slabs along ``axis``
        and run :meth:`plan` independently on each — every shard gets its
        own format + launch geometry decision on *its* slab's statistics.

        The result is a portable :class:`ShardedPlan`; bind it with
        :meth:`ShardedPlan.bind` (or hand it to ``SpMVService.register``)
        to execute across a device mesh."""
        n_shards = int(n_shards)
        strategy_kw = dict(strategy_kw or {})
        tel = _obs.get()
        with tel.span("plan.plan_sharded", n_shards=n_shards, axis=axis,
                      strategy=strategy, nnz=csr.nnz) as sp:
            b = shard_boundaries(csr, n_shards, axis=axis,
                                 strategy=strategy, **strategy_kw)
            shards: List[BlockPlan] = []
            for s, e in zip(b[:-1], b[1:]):
                sub = slice_shard(csr, int(s), int(e), axis=axis)
                shards.append(BlockPlan(
                    rows=(int(s), int(e)),
                    plan=self.plan(sub, batch=batch, **plan_kw)))
            if tel.enabled:
                nnzs = np.array([bp.plan.fingerprint.nnz for bp in shards],
                                dtype=np.float64)
                imbalance = float(nnzs.max() / max(nnzs.mean(), 1.0))
                tel.gauge("sharded.load_imbalance").set(imbalance)
                sp.set(imbalance=imbalance)
            stats = MatrixStats.of(csr)
            return self._self_check(ShardedPlan(
                shards=shards, axis=axis, strategy=strategy,
                params=strategy_kw, mesh_shape=(n_shards,), batch=batch,
                fingerprint=PlanFingerprint.from_stats(
                    stats, _structure_sig(csr))))

    def build_sharded(self, csr: CSR, **kw) -> Any:
        """``plan_sharded(csr) .bind(csr)`` in one call."""
        bind_kw = {k: kw.pop(k) for k in ("mode", "devices", "mesh")
                   if k in kw}
        return self.plan_sharded(csr, **kw).bind(csr, db=self.db, **bind_kw)

    def _machine(self) -> str:
        return self.db.machine if self.db is not None else "cost_model"

    def _ops_for(self, batch: int) -> Tuple[str, ...]:
        return ("spmv",) if batch <= 1 else ("spmv", "spmm")

    def _tune_leaf(self, csr: CSR, stats: MatrixStats,
                   plan: ExecutionPlan) -> Dict[str, TileGeometry]:
        """Launch geometry for a leaf plan: the tuner's real search when
        one is at hand, else the db's D_mat-keyed nearest recorded
        winner."""
        geometry: Dict[str, TileGeometry] = {}
        if self.tuner is not None:
            obj = plan.transform.apply(csr)
            # bind(csr) on the same source object reuses this instead of
            # paying the host transform a second time
            plan._mat_cache = (csr, obj)
            for op in self._ops_for(plan.batch):
                b = 1 if op == "spmv" else plan.batch
                try:
                    rec = self.tuner.tune(obj, op=op, batch=b, stats=stats)
                except (KeyError, TypeError):
                    continue
                geometry[op] = rec.geometry
        elif self.db is not None:
            for op in self._ops_for(plan.batch):
                b = 1 if op == "spmv" else plan.batch
                g = self.db.best_geometry(plan.fmt, stats.d_mat, op=op,
                                          batch=b)
                if g is not None:
                    geometry[op] = g
        return geometry

    def _plan_hybrid(self, csr: CSR, stats: MatrixStats, rule_used: str,
                     batch: int, k: int, tier: str, strategy: str,
                     sort_rows: Optional[bool] = None,
                     formats: Optional[Sequence[str]] = None,
                     **kw) -> ExecutionPlan:
        from repro.partition.hybrid import build_hybrid
        if sort_rows is None:
            sort_rows = strategy == "variance"
        if formats is not None:
            # the caller's restriction applies per block; a block can't
            # nest another hybrid container
            kw["formats"] = tuple(f for f in formats if f != "hybrid")
        hyb, report = build_hybrid(
            csr, strategy=strategy, db=self.db,
            rule=("paper" if rule_used == "paper" else "auto"),
            model=self.model, policy=self.policy, expected_iterations=k,
            sort_rows=sort_rows, batch=batch, **kw)

        sub_plans = [d.plan for d in report.decisions]
        for sub in sub_plans:
            sub.tier = tier
            sub.machine = self._machine()
        if tier == "kernel":
            self._tune_blocks(hyb, sub_plans, batch)
        blocks = [BlockPlan(rows=d.rows, plan=sub)
                  for d, sub in zip(report.decisions, sub_plans)]
        params = {"strategy": strategy, "sort_rows": sort_rows, **kw}
        return ExecutionPlan(
            fmt="hybrid", rule=rule_used, tier=tier, batch=batch,
            expected_iterations=k,
            transform=TransformRecipe("hybrid", params),
            fingerprint=PlanFingerprint.from_stats(stats,
                                                   _structure_sig(csr)),
            machine=self._machine(),
            d_mat=stats.d_mat, d_star=float("nan"), blocks=blocks)

    def _tune_blocks(self, hyb: Any, sub_plans: List[ExecutionPlan],
                     batch: int) -> None:
        """Per-block-format launch geometry, the serving layer's way: one
        search per (op, format) on the biggest block of that format, slab
        bounds re-derived over all sibling blocks, winner attached to
        every sub-plan of that format."""
        by_fmt = blocks_by_format(hyb)
        for op in self._ops_for(batch):
            b = 1 if op == "spmv" else batch
            per_fmt: Dict[str, TileGeometry] = {}
            for f, blks in by_fmt.items():
                if self.tuner is not None:
                    big = max(blks, key=lambda x: getattr(x, "nnz", 0))
                    try:
                        rec = self.tuner.tune(big, op=op, batch=b)
                    except (KeyError, TypeError):
                        continue
                    per_fmt[f] = rec.geometry
                elif self.db is not None:
                    d_mat = next((s.d_mat for s in sub_plans
                                  if s.fmt == f), 0.0)
                    g = self.db.best_geometry(f, d_mat, op=op, batch=b)
                    if g is not None:
                        per_fmt[f] = g
            per_fmt = rederive_slab_bounds(per_fmt, by_fmt)
            for sub in sub_plans:
                if sub.fmt in per_fmt:
                    sub.geometry[op] = per_fmt[sub.fmt]


__all__ = [
    "SCHEMA_VERSION", "SHARDED_SCHEMA_VERSION", "DEFAULT_RECIPE_PARAMS",
    "PlanError", "PlanSchemaError", "PlanFingerprint", "TransformRecipe",
    "apply_transform", "BlockPlan", "ExecutionPlan", "PlannedMatrix",
    "ShardedPlan", "shard_boundaries", "slice_shard",
    "Planner", "leaf_plan", "blocks_by_format", "bind_tunings",
    "rederive_slab_bounds",
]
