"""Memory-budget auto-tuning policy (paper §2.2, second drawback).

"Approximately 2x or more of memory space is needed in comparison with
using CRS.  To solve this memory problem, we proposed the 'auto-tuning
policy' for memory space from user requirements" — realized here as a
filter over candidate formats given a user byte budget."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Sequence

from .formats import CSR, MatrixStats, memory_bytes
from .transform import pad_to_multiple


@dataclass(frozen=True)
class MemoryPolicy:
    """``budget_ratio``: allowed bytes(fmt)/bytes(csr).  inf = unrestricted.
    ``hard_bytes``: absolute cap (e.g. free VMEM/HBM), 0 = ignore."""
    budget_ratio: float = 2.0
    hard_bytes: int = 0

    def estimate_bytes(self, fmt: str, stats: MatrixStats,
                       val_bytes: int = 4, idx_bytes: int = 4) -> int:
        n, nnz = stats.n, stats.nnz
        if fmt == "csr":
            return nnz * (val_bytes + idx_bytes) + (n + 1) * idx_bytes
        if fmt.startswith("coo"):
            return nnz * (val_bytes + 2 * idx_bytes)
        if fmt.startswith("ell"):
            return n * stats.max_row * (val_bytes + idx_bytes)
        if fmt == "sell":
            # sigma-sort removes inter-slice padding: ~ nnz rounded up
            w = pad_to_multiple(max(int(stats.mu + stats.sigma), 1), 8)
            return n * w * (val_bytes + idx_bytes) + n * idx_bytes
        if fmt == "hybrid":
            # each block independently passes this policy against its own
            # CSR footprint, and CSR is always a candidate, so the whole
            # matrix is bounded by ~CSR plus per-block indptr/perm overhead
            csr = nnz * (val_bytes + idx_bytes) + (n + 1) * idx_bytes
            return int(1.05 * csr) + n * idx_bytes
        raise KeyError(fmt)

    def allowed(self, formats: Sequence[str], csr: CSR) -> Dict[str, bool]:
        stats = MatrixStats.of(csr)
        base = memory_bytes(csr)
        out = {}
        for f in formats:
            b = self.estimate_bytes(f, stats)
            ok = b <= self.budget_ratio * base
            if self.hard_bytes:
                ok = ok and b <= self.hard_bytes
            out[f] = ok
        return out


__all__ = ["MemoryPolicy"]
