"""The paper's auto-tuning method: off-line D_mat–R graph, on-line decision.

Definitions (paper §2.2):
    SP_f   = t_crs / t_f            (eq. 1 — SpMV speedup of format f)
    TT_f   = t_trans_f / t_crs      (eq. 2*)
    R_f    = SP_f / TT_f            (eq. 3)
    D_mat  = sigma / mu             (eq. 4 — nnz-per-row coeff. of variation)

(*) The paper prints eq. (2) as ``t_crs / t_trans`` but its own worked
example ("cost of 1.0 ... 10x speedup ... if and only if the transformation
time to SpMV in CRS is 10") and Fig. 7 ("overheads ... 0.01x-0.51x", low =
cheap) require ``TT = t_trans / t_crs``.  We implement the self-consistent
version and note the typo here.

Off-line phase: run the benchmark suite on this machine, record
(D_mat^i, R_f^i) per matrix and format, and set per format
``D*_f = max { D_mat^i : R_f^i >= c }`` (c = 1.0 by default).

On-line phase: compute D_mat of the input (cheap — one pass over IRP) and
transform to the best format iff ``D_mat < D*``.

Beyond the paper (flagged ``generalized``):
  * multi-format selection (argmin of predicted total time) instead of the
    binary ELL-vs-CRS rule;
  * amortization over an expected iteration count k —
    transform iff  k (t_crs - t_f) > t_trans_f  (the paper's c generalizes
    to c = 1/k in its own cost algebra);
  * a measurement-free roofline cost model to pre-seed decisions on a new
    machine before any off-line data exists.
"""
from __future__ import annotations

import json
import time
from dataclasses import asdict, dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

import repro.obs as _obs

from .formats import CSR, MatrixStats, memory_bytes
from .spmv import spmm, spmv
from .transform import TRANSFORMS_HOST

DEFAULT_FORMATS = ("ell_row", "ell_col", "coo_row", "coo_col", "sell",
                   "hybrid")


# ---------------------------------------------------------------------------
# timing
# ---------------------------------------------------------------------------
def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Best-of-`iters` wall time of ``fn(*args)`` with device sync."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def time_host(fn: Callable, *args, iters: int = 3) -> float:
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        best = min(best, time.perf_counter() - t0)
    return best


# ---------------------------------------------------------------------------
# records
# ---------------------------------------------------------------------------
@dataclass
class FormatMeasurement:
    t_spmv: float      # seconds per SpMV in this format
    t_trans: float     # seconds for CRS -> format transformation
    sp: float          # t_crs / t_spmv
    tt: float          # t_trans / t_crs
    r: float           # sp / tt
    mem_ratio: float   # bytes(format) / bytes(csr)


@dataclass
class OfflineRecord:
    name: str
    n: int
    nnz: int
    mu: float
    sigma: float
    d_mat: float
    t_crs: float
    batch: int = 1     # right-hand sides per timed call (1 = SpMV, B = SpMM)
    formats: Dict[str, FormatMeasurement] = field(default_factory=dict)


@dataclass
class TuningDB:
    """The machine-specific product of the off-line phase.

    ``geometries`` holds the kernel launch-geometry winners recorded by
    ``core.kernel_tune.KernelTuner`` — persisted alongside the
    ``OfflineRecord``\\s so one file ships both halves of the auto-tuning
    state (format thresholds *and* launch geometry)."""
    machine: str
    c: float
    records: List[OfflineRecord]
    d_star: Dict[str, float]          # per format
    geometries: List = field(default_factory=list)  # GeometryRecord

    # -- persistence ---------------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({
            "machine": self.machine, "c": self.c,
            "d_star": self.d_star,
            "records": [
                {**{k: v for k, v in asdict(r).items() if k != "formats"},
                 "formats": {f: asdict(m) for f, m in r.formats.items()}}
                for r in self.records
            ],
            "geometries": [g.to_dict() for g in self.geometries],
        }, indent=1)

    @staticmethod
    def from_json(s: str) -> "TuningDB":
        from .kernel_tune import GeometryRecord
        obj = json.loads(s)
        recs = []
        for r in obj["records"]:
            fmts = {f: FormatMeasurement(**m) for f, m in r.pop("formats").items()}
            recs.append(OfflineRecord(**r, formats=fmts))
        geoms = [GeometryRecord.from_dict(g)
                 for g in obj.get("geometries", [])]
        return TuningDB(machine=obj["machine"], c=obj["c"], records=recs,
                        d_star=obj["d_star"], geometries=geoms)

    # -- tuned launch geometry ----------------------------------------------
    def best_geometry(self, fmt: str, d_mat: float, op: str = "spmv",
                      batch: Optional[int] = None):
        """Nearest recorded launch-geometry winner for an unseen matrix
        (D_mat-keyed, preferring batch-matched records); None if nothing
        was recorded for (fmt, op)."""
        from .kernel_tune import nearest_geometry
        return nearest_geometry(self.geometries, fmt, op, d_mat=d_mat,
                                batch=batch)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @staticmethod
    def load(path: str) -> "TuningDB":
        with open(path) as f:
            return TuningDB.from_json(f.read())

    # -- the D_mat–R graph ----------------------------------------------------
    def graph(self, fmt: str) -> List[Tuple[float, float]]:
        """(D_mat^i, R_f^i) points, sorted by D_mat — the paper's Fig. 8."""
        pts = [(r.d_mat, r.formats[fmt].r) for r in self.records
               if fmt in r.formats]
        return sorted(pts)

    def predict(self, fmt: str, d_mat: float,
                batch: Optional[int] = None) -> Dict[str, float]:
        """Nearest-neighbours (in log D) prediction of (sp, tt) for a new
        matrix — the generalized on-line model.

        ``batch``: prefer records measured at the same RHS count (SpMM
        measurements).  When none exist, fall back to all records and
        rescale each record's ``tt`` from its own measured batch to the
        queried one (``tt`` is relative to one t_crs *call*, so a call B
        products wide carries t_trans / B per unit batch); the result is
        reported with ``batch_matched=False`` but its ``tt`` is already in
        per-``batch``-call units either way."""
        recs = [r for r in self.records if fmt in r.formats]
        matched = True
        if batch is not None and recs:
            exact = [r for r in recs if r.batch == batch]
            matched = bool(exact)
            recs = exact or recs
        if not recs:
            return {"sp": 1.0, "tt": float("inf"), "batch_matched": False}

        def tt_of(r: OfflineRecord) -> float:
            tt = r.formats[fmt].tt
            if batch is not None and not matched:
                tt *= r.batch / max(batch, 1)
            return tt

        d = np.array([max(r.d_mat, 1e-9) for r in recs])
        w = 1.0 / (1e-9 + np.abs(np.log(d) - np.log(max(d_mat, 1e-9))))
        w /= w.sum()
        sp = float(sum(wi * r.formats[fmt].sp for wi, r in zip(w, recs)))
        tt = float(sum(wi * tt_of(r) for wi, r in zip(w, recs)))
        return {"sp": sp, "tt": tt, "batch_matched": matched}


# ---------------------------------------------------------------------------
# off-line phase
# ---------------------------------------------------------------------------
def offline_phase(
    suite: Sequence[Tuple[str, CSR]],
    formats: Sequence[str] = DEFAULT_FORMATS,
    c: float = 1.0,
    machine: str = "cpu",
    spmv_impls: Optional[Dict[str, Callable]] = None,
    iters: int = 5,
    make_x: Optional[Callable[[CSR], jax.Array]] = None,
    batch: int = 1,
    spmm_impls: Optional[Dict[str, Callable]] = None,
    tuner: Optional[Any] = None,
) -> TuningDB:
    """Measure the suite, build the D_mat–R graph, learn D* per format.

    ``spmv_impls`` maps format name -> callable(fmt_obj, x); defaults to the
    pure-jnp references (the Pallas kernels are plugged in by the caller —
    e.g. benchmarks pass ``repro.kernels.ops`` wrappers).

    ``batch``: number of right-hand sides per timed call.  ``batch > 1``
    times the SpMM path with an ``(n_cols, batch)`` panel instead of SpMV,
    so the resulting D_mat–R graph (and the D* thresholds learned from it)
    reflect that one transformation is amortized over ``k * batch``
    products.  Records carry the batch they were measured at.  With
    ``batch > 1`` overrides come from ``spmm_impls`` (callables taking the
    panel); ``spmv_impls`` is SpMV-only and is ignored then.

    ``tuner``: a ``core.kernel_tune.KernelTuner``.  When given (with kernel
    impls), every format whose impl was overridden is launch-geometry-tuned
    on each matrix *before* it is timed, so the measured ``t_f`` (and
    ``t_crs``) are post-tuning speeds — the ``k * B * (t_crs - t_f) >
    t_trans`` rule then sees what the serving path will actually run.  The
    tuner's winners ship in the returned db's ``geometries``.
    """
    import jax.numpy as jnp

    batch = max(int(batch), 1)
    if batch > 1 and spmv_impls and not spmm_impls:
        raise ValueError(
            "offline_phase(batch > 1) times the SpMM path; pass the panel "
            "callables via spmm_impls (spmv_impls is SpMV-only)")
    default_op = spmv if batch == 1 else spmm
    op_name = "spmv" if batch == 1 else "spmm"
    impls = (spmv_impls if batch == 1 else spmm_impls) or {}

    def tuned(fn, fmt_obj, stats, x):
        """Bind the per-matrix tuned launch geometry onto an overridden
        kernel impl (reference impls take no geometry and pass through)."""
        if tuner is None:
            return fn
        import functools
        try:
            rec = tuner.tune(fmt_obj, op=op_name, batch=batch, impl=fn,
                             x=x, stats=stats)
        except (KeyError, TypeError):
            return fn
        return functools.partial(fn, tuning=rec.geometry)

    tel = _obs.get()
    records: List[OfflineRecord] = []
    for name, csr in suite:
        stats = MatrixStats.of(csr)
        if make_x is not None:
            x = make_x(csr)
        elif batch == 1:
            x = jnp.ones((csr.n_cols,), jnp.float32)
        else:
            x = jnp.ones((csr.n_cols, batch), jnp.float32)
        with tel.span("offline.matrix", matrix=name, n=stats.n,
                      nnz=stats.nnz, d_mat=stats.d_mat, batch=batch):
            csr_fn = impls.get("csr", default_op)
            if "csr" in impls:
                csr_fn = tuned(csr_fn, csr, stats, x)
            jit_csr = jax.jit(lambda m, v, fn=csr_fn: fn(m, v))
            t_crs = time_fn(jit_csr, csr, x, iters=iters)
            if tel.enabled:
                tel.histogram("offline.t_crs_s").observe(t_crs)
            rec = OfflineRecord(name=name, n=stats.n, nnz=stats.nnz,
                                mu=stats.mu, sigma=stats.sigma,
                                d_mat=stats.d_mat, t_crs=t_crs, batch=batch)
            base_mem = memory_bytes(csr)
            for f in formats:
                trans = TRANSFORMS_HOST[f]
                t_trans = time_host(trans, csr)
                fmt_obj = trans(csr)
                f_fn = impls.get(f, default_op)
                if f in impls:
                    f_fn = tuned(f_fn, fmt_obj, stats, x)
                jit_f = jax.jit(lambda m, v, fn=f_fn: fn(m, v))
                t_f = time_fn(jit_f, fmt_obj, x, iters=iters)
                sp = t_crs / t_f
                tt = t_trans / t_crs
                rec.formats[f] = FormatMeasurement(
                    t_spmv=t_f, t_trans=t_trans, sp=sp, tt=tt,
                    r=sp / tt if tt > 0 else float("inf"),
                    mem_ratio=memory_bytes(fmt_obj) / base_mem,
                )
                if tel.enabled:
                    tel.histogram("offline.t_trans_s", fmt=f).observe(t_trans)
                    tel.histogram("offline.t_spmv_s", fmt=f).observe(t_f)
                    tel.event("offline.measure", matrix=name, fmt=f,
                              batch=batch, d_mat=stats.d_mat, t_crs=t_crs,
                              t_f=t_f, t_trans=t_trans, sp=sp, tt=tt,
                              r=rec.formats[f].r)
        records.append(rec)

    d_star = {}
    for f in formats:
        qual = [r.d_mat for r in records
                if f in r.formats and r.formats[f].r >= c]
        d_star[f] = max(qual) if qual else 0.0
    return TuningDB(machine=machine, c=c, records=records, d_star=d_star,
                    geometries=list(tuner.records) if tuner is not None
                    else [])


# ---------------------------------------------------------------------------
# on-line phase
# ---------------------------------------------------------------------------
@dataclass
class Decision:
    fmt: str                  # chosen format ("csr" = stay)
    d_mat: float
    d_star: float
    rule: str                 # "paper" | "generalized" | "cost_model"
    expected_gain: float = 0.0  # predicted fraction of time saved


def _emit_decision(dec: Decision, **extra: Any) -> Decision:
    """Record an on-line decision as a ``plan.decision`` event + counter —
    every rule firing becomes a replayable point on the D_mat–R graph."""
    tel = _obs.get()
    if tel.enabled:
        tel.counter("plan.decisions", rule=dec.rule, fmt=dec.fmt).inc()
        tel.event("plan.decision", rule=dec.rule, fmt=dec.fmt,
                  d_mat=dec.d_mat, d_star=dec.d_star,
                  expected_gain=dec.expected_gain, **extra)
    return dec


def decide_paper(db: TuningDB, stats: MatrixStats, fmt: str = "ell_row") -> Decision:
    """The paper's on-line rule: transform iff D_mat < D*."""
    ds = db.d_star.get(fmt, 0.0)
    chosen = fmt if stats.d_mat < ds else "csr"
    return _emit_decision(Decision(fmt=chosen, d_mat=stats.d_mat, d_star=ds,
                                   rule="paper"))


def decide_generalized(db: TuningDB, stats: MatrixStats,
                       expected_iterations: int = 100,
                       formats: Optional[Sequence[str]] = None,
                       memory_budget_ratio: float = float("inf"),
                       batch: int = 1) -> Decision:
    """Beyond-paper: pick argmin over formats of predicted total time for k
    iterations, k*t_f + t_trans_f, subject to a memory budget (paper §2.2's
    'auto-tuning policy' drawback).

    ``batch``: right-hand sides per call.  Each call carries B products, so
    a transformation paid once is amortized over ``k * B`` of them — the
    rule becomes ``k * B * (t_crs - t_f) > t_trans``.  ``predict`` hands
    back tt already rescaled to per-B-call units (preferring records
    measured at this batch, else rescaling by each record's own batch)."""
    k = max(expected_iterations, 1)
    b = max(batch, 1)
    best_fmt, best_cost, best_ds = "csr", float(k), 0.0  # unit: t_crs/call
    for f in formats or db.d_star.keys():
        pred = db.predict(f, stats.d_mat, batch=b)
        recs = [r.formats[f].mem_ratio for r in db.records if f in r.formats]
        if recs and float(np.median(recs)) > memory_budget_ratio:
            continue
        cost = k / max(pred["sp"], 1e-9) + pred["tt"]
        if cost < best_cost:
            best_fmt, best_cost, best_ds = f, cost, db.d_star.get(f, 0.0)
    return _emit_decision(
        Decision(fmt=best_fmt, d_mat=stats.d_mat, d_star=best_ds,
                 rule="generalized",
                 expected_gain=1.0 - best_cost / float(k)),
        expected_iterations=k, batch=b)


# ---------------------------------------------------------------------------
# measurement-free roofline cost model (beyond paper)
# ---------------------------------------------------------------------------
@dataclass
class MachineModel:
    """Bandwidth/latency model used to pre-seed decisions on a new machine.

    ``segment_penalty`` models the segmented-reduction inefficiency of
    CSR/COO on vector hardware: the effective vector length is the row
    length (~mu, tiny), while ELL reduces dense (rows, width) panels at
    full lane width — the mechanism behind the paper's 151x ES2 result,
    and equally behind the TPU VPU's preference for ELL."""
    stream_bw: float = 819e9      # bytes/s contiguous (TPU v5e HBM)
    gather_bw: float = 819e9 / 8  # bytes/s random-gather effective
    val_bytes: int = 4
    idx_bytes: int = 4
    segment_penalty: float = 3.0  # CSR/COO segmented-reduce inefficiency

    def t_spmv(self, fmt: str, stats: MatrixStats,
               width: Optional[int] = None, batch: int = 1) -> float:
        """Seconds per call.  ``batch`` B > 1 models an SpMM call carrying an
        (n_cols, B) panel: the matrix stream is paid once per call while the
        x gathers (and output writes, folded into the same term) scale with
        B — which is exactly why SpMM amortizes better than B SpMVs."""
        b = max(batch, 1)
        n, nnz = stats.n, stats.nnz
        if fmt == "csr" or fmt.startswith("coo"):
            stream = nnz * (self.val_bytes + self.idx_bytes) + n * self.idx_bytes
            gather = nnz * self.val_bytes            # x[] gathers
            return self.segment_penalty * (
                stream / self.stream_bw + b * gather / self.gather_bw)
        if fmt.startswith("ell") or fmt == "sell":
            w = width if width is not None else int(round(stats.mu + 3 * stats.sigma)) or 1
            if fmt == "sell":
                w = int(round(stats.mu)) or 1        # sigma-sort removes most pad
            padded = n * w
            stream = padded * (self.val_bytes + self.idx_bytes)
            gather = padded * self.val_bytes
            return stream / self.stream_bw + b * gather / self.gather_bw
        if fmt == "hybrid":
            # per-block tuning keeps regular blocks at SELL-like width ~mu
            # and drops the heavy tail into CSR/COO; model as SELL plus a
            # small per-block dispatch/reassembly overhead
            return 1.05 * self.t_spmv("sell", stats, batch=b)
        raise KeyError(fmt)

    def t_trans(self, fmt: str, stats: MatrixStats) -> float:
        # transformation streams CSR once and writes the new format once
        # (independent of how many RHS later ride on the result)
        return 2.0 * self.t_spmv(fmt, stats, batch=1)


def decide_cost_model(model: MachineModel, stats: MatrixStats,
                      expected_iterations: int = 100,
                      formats: Sequence[str] = ("ell_row", "sell"),
                      batch: int = 1) -> Decision:
    k = max(expected_iterations, 1)
    b = max(batch, 1)
    t_crs = model.t_spmv("csr", stats, batch=b)
    best_fmt, best_cost = "csr", k * t_crs
    for f in formats:
        cost = k * model.t_spmv(f, stats, batch=b) + model.t_trans(f, stats)
        if cost < best_cost:
            best_fmt, best_cost = f, cost
    return _emit_decision(
        Decision(fmt=best_fmt, d_mat=stats.d_mat, d_star=float("nan"),
                 rule="cost_model",
                 expected_gain=1.0 - best_cost / (k * t_crs)),
        expected_iterations=k, batch=b)


# ---------------------------------------------------------------------------
# the user-facing auto-tuned operator — deprecated shim over the Planner
# ---------------------------------------------------------------------------
class AutoTunedSpMV:
    """Deprecated: use :class:`repro.Planner` / :class:`repro.ExecutionPlan`.

    This wrapper predates the unified plan API and ignored kernel launch
    geometry and the batch axis entirely.  It now routes through
    :class:`~repro.core.plan.Planner`, so it picks up the tuned
    ``TileGeometry`` (when the TuningDB carries recorded geometries, or a
    ``tuner`` is passed) and serves SpMM panels through the same
    ``__call__`` — but new code should hold the :class:`ExecutionPlan`
    directly::

        plan = Planner(db=db).plan(csr)     # portable, serializable
        P = plan.bind(csr)
        y = P @ x                           # SpMV; P @ X serves SpMM
    """

    def __init__(self, csr: CSR, db: Optional[TuningDB] = None,
                 expected_iterations: int = 100,
                 rule: str = "paper",
                 machine_model: Optional[MachineModel] = None,
                 spmv_impls: Optional[Dict[str, Callable]] = None,
                 tuner: Optional[Any] = None):
        import warnings
        warnings.warn(
            "AutoTunedSpMV is deprecated; use repro.Planner — "
            "plan = Planner(db=db).plan(csr); y = plan.bind(csr) @ x",
            DeprecationWarning, stacklevel=2)
        from .plan import Planner
        if db is None:
            rule_eff = "cost_model"
        elif rule == "paper":
            rule_eff = "paper"
        else:
            rule_eff = "generalized"
        planner = Planner(db=db, model=machine_model, tuner=tuner,
                          rule=rule_eff)
        self.plan = planner.plan(csr, expected_iterations=expected_iterations)
        self.bound = self.plan.bind(csr, db=db, impls=spmv_impls)
        self.csr = csr
        self.stats = MatrixStats.of(csr)
        self.decision = Decision(fmt=self.plan.fmt, d_mat=self.plan.d_mat,
                                 d_star=self.plan.d_star,
                                 rule=self.plan.rule,
                                 expected_gain=self.plan.expected_gain)
        self.matrix = self.bound.matrix

    def __call__(self, x: jax.Array) -> jax.Array:
        # rank dispatch: 1-D x serves SpMV, (n_cols, B) panels serve SpMM
        return self.bound @ x


__all__ = [
    "DEFAULT_FORMATS", "time_fn", "time_host",
    "FormatMeasurement", "OfflineRecord", "TuningDB",
    "offline_phase", "Decision", "decide_paper", "decide_generalized",
    "MachineModel", "decide_cost_model", "AutoTunedSpMV",
]
