"""Kernel launch-geometry auto-tuning: extend AT from format choice down to
the hot loop.

The paper's auto-tuner stops at *format selection*; the Pallas tier then
used to launch every kernel with one hard-coded tile shape.  AlphaSparse
(arXiv:2212.10432) shows per-matrix design-space search over launch
parameters dominates any single fixed schedule, and SELL-C-sigma
(arXiv:1307.6209) shows tile/chunk geometry is the decisive knob on
wide-SIMD hardware.  This module is the launch-parameter half of that
argument for our stack:

  * :class:`TileGeometry` — the knobs every kernel wrapper in
    ``kernels/ops.py`` accepts per call (``tuning=``): ``block_rows`` /
    ``block_w`` (ELL band tiles, BCSR row tiles), ``block_k`` (SpMM RHS
    tile), ``block_nnz`` (COO/CSR nnz slab) and ``slabs_per_block`` (the
    CSR/BCSR static slab-coverage bound — data-dependent, so only the
    tuner, holding the concrete matrix, can supply it to traced callers);
  * :func:`candidate_geometries` — the bounded per-(format, op) search
    grid (``block_rows in {8..512}``, ``block_w in {8,128,256}``, ...);
  * :class:`KernelTuner` — times real launches per candidate, memoizes the
    winner per ``(format, op, batch, matrix profile)``, records into the
    existing :class:`~repro.core.autotune.TuningDB` (persisted next to the
    ``OfflineRecord``\\s), and answers unseen matrices with a
    D_mat-keyed nearest-neighbour fallback.

The timing loop is injectable (``timer=``) so tests tune deterministically
without a clock.
"""
from __future__ import annotations

import time
from dataclasses import asdict, dataclass, replace
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from . import dispatch as _dispatch
from .formats import CSR, MatrixStats

__all__ = [
    "TileGeometry", "GeometryRecord", "candidate_geometries",
    "nearest_geometry", "KernelTuner",
]


# ---------------------------------------------------------------------------
# the geometry pytree-of-knobs
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TileGeometry:
    """Per-call launch geometry; ``None`` fields fall back to the wrapper's
    built-in default.  Hashable so it can ride through static closures."""
    block_rows: Optional[int] = None   # ELL/CSR row tile; BCSR block-row tile
    block_w: Optional[int] = None      # ELL band (lane) tile
    block_k: Optional[int] = None      # SpMM right-hand-side tile
    block_nnz: Optional[int] = None    # COO/CSR nnz slab; BCSR blocks/slab
    slabs_per_block: Optional[int] = None  # CSR/BCSR static coverage bound

    def to_dict(self) -> Dict[str, Any]:
        return {k: v for k, v in asdict(self).items() if v is not None}

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "TileGeometry":
        return TileGeometry(**d)

    def without_slab_bound(self) -> "TileGeometry":
        """Strip the data-dependent coverage bound — required when a
        geometry learned on one matrix is applied to another under trace
        (the bound would silently drop entries; without it the CSR/BCSR
        kernels fall back to the always-correct full sweep, and concrete
        callers recompute the exact bound anyway)."""
        return replace(self, slabs_per_block=None)


@dataclass
class GeometryRecord:
    """One tuning outcome: the winning geometry for (format, op, batch) on
    a matrix profile, plus the measured win over the default launch.

    ``sig`` fingerprints the index structure (CRC of the pointer array)
    when it was concrete at tune time: two same-sized matrices must not
    share a memoized record, because the winning geometry can carry a
    matrix-specific slab-coverage bound."""
    fmt: str
    op: str
    batch: int
    n: int
    nnz: int
    d_mat: float
    geometry: TileGeometry
    t_best: float
    t_default: float
    sig: int = 0

    @property
    def speedup(self) -> float:
        return self.t_default / self.t_best if self.t_best > 0 else 1.0

    def to_dict(self) -> Dict[str, Any]:
        d = asdict(self)
        d["geometry"] = self.geometry.to_dict()
        return d

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "GeometryRecord":
        d = dict(d)
        d["geometry"] = TileGeometry.from_dict(d["geometry"])
        return GeometryRecord(**d)


# ---------------------------------------------------------------------------
# the bounded search grid
# ---------------------------------------------------------------------------
ROW_TILES = (8, 32, 128, 256, 512)
W_TILES = (8, 128, 256)
K_TILES = (8, 128)
NNZ_TILES = (1024, 4096, 16384, 65536)
CSR_ROW_TILES = (64, 128, 256, 512)
CSR_NNZ_TILES = (1024, 4096, 16384, 65536)
BCSR_ROW_TILES = (8, 32, 64)
BCSR_NNZ_TILES = (128, 512, 2048)
# the whole-nnz "one slab" boundary candidate is capped so a slab's VAL +
# ICOL stay ~2 MiB — comfortably inside VMEM next to the pinned x
MAX_SLAB = 262144
MAX_BLOCK_SLAB = 8192


def _align8(n: int) -> int:
    return max(8, 8 * ((int(n) + 7) // 8))


def _nnz_tiles(base, nnz_pad: int, cap: int):
    """Slab-size candidates: the base grid clamped to the matrix, plus the
    whole-nnz single-slab boundary (itself clamped to the VMEM cap)."""
    if not nnz_pad:
        return sorted(base)
    whole = min(_align8(nnz_pad), cap)
    return sorted({min(bn, whole) for bn in base} | {whole})


def candidate_geometries(fmt: str, op: str = "spmv", *, n_rows: int = 0,
                         width: int = 0, nnz_pad: int = 0,
                         batch: int = 1) -> List[TileGeometry]:
    """The bounded launch-geometry grid for one (format, op).

    Candidates are pre-clamped to the matrix profile (a 512-row tile on a
    100-row matrix is the same launch as a 128-row one) and de-duplicated,
    so the tuner never times the same effective launch twice."""
    ks = tuple(sorted({min(k, _align8(batch)) for k in K_TILES})) \
        if op == "spmm" else (None,)
    geoms: List[TileGeometry] = []
    if fmt.startswith("ell") or fmt == "sell":
        rows = {min(r, _align8(n_rows)) for r in ROW_TILES} if n_rows \
            else set(ROW_TILES)
        ws = {min(w, _align8(width)) for w in W_TILES} if width \
            else set(W_TILES)
        for r in sorted(rows):
            for w in sorted(ws):
                for k in ks:
                    geoms.append(TileGeometry(block_rows=r, block_w=w,
                                              block_k=k))
    elif fmt.startswith("coo"):
        for bn in _nnz_tiles(NNZ_TILES, nnz_pad, MAX_SLAB):
            for k in ks:
                geoms.append(TileGeometry(block_nnz=bn, block_k=k))
    elif fmt == "csr":
        rows = {min(r, _align8(n_rows)) for r in CSR_ROW_TILES} if n_rows \
            else set(CSR_ROW_TILES)
        if n_rows:
            # the single-row-block boundary (output tile capped for VMEM)
            rows.add(min(_align8(n_rows), MAX_SLAB))
        for r in sorted(rows):
            for bn in _nnz_tiles(CSR_NNZ_TILES, nnz_pad, MAX_SLAB):
                for k in ks:
                    geoms.append(TileGeometry(block_rows=r, block_nnz=bn,
                                              block_k=k))
    elif fmt == "bcsr":
        rows = {min(r, max(1, n_rows)) for r in BCSR_ROW_TILES} if n_rows \
            else set(BCSR_ROW_TILES)
        bns = set(_nnz_tiles(BCSR_NNZ_TILES, nnz_pad, MAX_BLOCK_SLAB))
        for r in sorted(rows):
            for bn in sorted(bns):
                for k in ks:
                    geoms.append(TileGeometry(block_rows=r, block_nnz=bn,
                                              block_k=k))
    else:
        return []
    seen, out = set(), []
    for g in geoms:
        key = (g.block_rows, g.block_w, g.block_k, g.block_nnz)
        if key not in seen:
            seen.add(key)
            out.append(g)
    return out


# ---------------------------------------------------------------------------
# nearest-neighbour fallback over recorded geometries
# ---------------------------------------------------------------------------
def nearest_geometry(records: Sequence[GeometryRecord], fmt: str,
                     op: str = "spmv", d_mat: float = 0.0,
                     batch: Optional[int] = None) -> Optional[TileGeometry]:
    """D_mat-keyed (log-space) nearest neighbour among recorded winners.

    The returned geometry is stripped of its slab-coverage bound — that
    bound is only valid for the matrix it was measured on."""
    recs = [r for r in records if r.fmt == fmt and r.op == op]
    if batch is not None:
        exact = [r for r in recs if r.batch == batch]
        recs = exact or recs
    if not recs:
        return None
    q = np.log(max(d_mat, 1e-9))
    best = min(recs, key=lambda r: abs(np.log(max(r.d_mat, 1e-9)) - q))
    return best.geometry.without_slab_bound()


# ---------------------------------------------------------------------------
# matrix profiling (best effort per format)
# ---------------------------------------------------------------------------
def _structure_sig(obj: Any) -> int:
    """CRC fingerprint of the concrete index-pointer structure (0 when the
    object has none, or it is abstract).  Part of the memo identity: the
    winning geometry's slab-coverage bound is only valid for the exact
    structure it was measured on."""
    ip = getattr(obj, "indptr", None)
    if ip is None or isinstance(ip, jax.core.Tracer):
        return 0
    import zlib
    return zlib.crc32(np.ascontiguousarray(np.asarray(ip)).tobytes()) or 1


def _profile_of(obj: Any, stats: Optional[MatrixStats] = None
                ) -> Tuple[int, int, float, int]:
    sig = _structure_sig(obj)
    if stats is not None:
        return int(stats.n), int(stats.nnz), float(stats.d_mat), sig
    n = int(getattr(obj, "n_rows", 0))
    nnz = int(getattr(obj, "nnz", 0))
    d_mat = 0.0
    if isinstance(obj, CSR):
        ip = getattr(obj, "indptr", None)
        if ip is not None and not isinstance(ip, jax.core.Tracer):
            d_mat = float(MatrixStats.of(obj).d_mat)
    return n, nnz, d_mat, sig


def _width_of(obj: Any) -> int:
    w = getattr(obj, "width", None)
    if w is not None:
        return int(w)
    widths = getattr(obj, "widths", None)   # BucketedELL
    if widths:
        return int(max(widths))
    return 0


def _slab_bound_for(obj: Any, g: TileGeometry) -> Optional[int]:
    """Exact slab coverage bound for a CSR/BCSR candidate, computable only
    with the concrete index structure in hand."""
    ip = getattr(obj, "indptr", None)
    if ip is None or isinstance(ip, jax.core.Tracer):
        return None
    from repro.kernels.csr_spmv import slabs_needed
    br = g.block_rows or (256 if isinstance(obj, CSR) else 32)
    bn = g.block_nnz or (2048 if isinstance(obj, CSR) else 512)
    return slabs_needed(np.asarray(ip), br, bn)


# ---------------------------------------------------------------------------
# the tuner
# ---------------------------------------------------------------------------
def _real_timer(iters: int, warmup: int) -> Callable:
    def timer(thunk: Callable[[], Any], geometry: Optional[TileGeometry]
              ) -> float:
        for _ in range(warmup):
            thunk()
        best = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            thunk()
            best = min(best, time.perf_counter() - t0)
        return best
    return timer


class KernelTuner:
    """Searches :func:`candidate_geometries` by timing real launches.

    ``db``: an :class:`~repro.core.autotune.TuningDB` to read/record
    geometry winners in (its ``geometries`` list is shared, so saving the
    db persists the tuner's work).  ``timer(thunk, geometry) -> seconds``
    is injectable for deterministic tests.
    """

    def __init__(self, db: Optional[Any] = None,
                 interpret: Optional[bool] = None,
                 iters: int = 3, warmup: int = 1,
                 timer: Optional[Callable] = None,
                 max_candidates: Optional[int] = None):
        self.db = db
        self.interpret = interpret
        self.records: List[GeometryRecord] = (
            db.geometries if db is not None
            and getattr(db, "geometries", None) is not None else [])
        if db is not None and getattr(db, "geometries", None) is None:
            db.geometries = self.records
        self._timer = timer or _real_timer(iters, warmup)
        self.max_candidates = max_candidates
        self._memo: Dict[Tuple, GeometryRecord] = {
            self._key(r.fmt, r.op, r.batch, (r.n, r.nnz, r.d_mat, r.sig)): r
            for r in self.records}

    @staticmethod
    def _key(fmt: str, op: str, batch: int,
             profile: Tuple[int, int, float, int]):
        return (fmt, op, batch, profile[0], profile[1],
                round(profile[2], 6), profile[3])

    # -- search --------------------------------------------------------------
    def tune(self, obj: Any, op: str = "spmv", batch: int = 1,
             impl: Optional[Callable] = None, x: Optional[jax.Array] = None,
             stats: Optional[MatrixStats] = None,
             force: bool = False) -> GeometryRecord:
        """Time every candidate launch of ``obj``'s kernel and return (and
        memoize) the winner.  The default launch is always a candidate, so
        ``t_best <= t_default`` by construction."""
        import jax.numpy as jnp

        fmt = _dispatch.format_of(obj)
        profile = _profile_of(obj, stats)
        key = self._key(fmt, op, batch, profile)
        if not force and key in self._memo:
            return self._memo[key]

        if impl is None:
            impl = _dispatch.get_impl(fmt, op, tier="kernel", fallback=False)
        if x is None:
            shape = (obj.n_cols,) if op == "spmv" else (obj.n_cols,
                                                        max(batch, 1))
            x = jnp.ones(shape, jnp.float32)

        cands: List[Optional[TileGeometry]] = [None]
        # BCSR row tiles count *block* rows; everything else scalar rows
        grid_rows = int(getattr(obj, "n_block_rows", profile[0]) or 0)
        grid = candidate_geometries(
            fmt, op, n_rows=grid_rows, width=_width_of(obj),
            nnz_pad=int(getattr(obj, "nnz_pad",
                                getattr(obj, "nblocks_pad", 0)) or 0),
            batch=batch)
        if self.max_candidates is not None:
            grid = grid[: self.max_candidates]
        cands.extend(grid)

        times: List[Tuple[float, Optional[TileGeometry]]] = []
        for g in cands:
            gg = g
            if g is not None and fmt in ("csr", "bcsr"):
                spb = _slab_bound_for(obj, g)
                if spb is not None:
                    gg = replace(g, slabs_per_block=spb)
            fn = jax.jit(lambda m, v, _f=impl, _g=gg:
                         _f(m, v, interpret=self.interpret, tuning=_g))
            thunk = lambda _fn=fn: jax.block_until_ready(_fn(obj, x))
            times.append((float(self._timer(thunk, gg)), gg))

        t_default = times[0][0]
        t_best, best_g = min(times, key=lambda tg: tg[0])
        rec = GeometryRecord(
            fmt=fmt, op=op, batch=max(batch, 1), n=profile[0],
            nnz=profile[1], d_mat=profile[2], sig=profile[3],
            geometry=best_g if best_g is not None else TileGeometry(),
            t_best=t_best, t_default=t_default)
        self._memo[key] = rec
        self.records.append(rec)
        return rec

    # -- lookup --------------------------------------------------------------
    def best(self, obj: Any = None, op: str = "spmv", batch: int = 1,
             fmt: Optional[str] = None, d_mat: Optional[float] = None,
             stats: Optional[MatrixStats] = None
             ) -> Optional[TileGeometry]:
        """Memoized winner for this exact profile, else the D_mat-keyed
        nearest-neighbour among recorded winners (slab bound stripped),
        else ``None`` (caller uses the default launch)."""
        if obj is not None:
            fmt = fmt or _dispatch.format_of(obj)
            profile = _profile_of(obj, stats)
            rec = self._memo.get(self._key(fmt, op, max(batch, 1), profile))
            if rec is not None:
                return rec.geometry
            if d_mat is None:
                d_mat = profile[2]
        if fmt is None:
            raise ValueError("best() needs a matrix object or a format name")
        return nearest_geometry(self.records, fmt, op,
                                d_mat=d_mat or 0.0, batch=max(batch, 1))

    # -- binding helpers -----------------------------------------------------
    def bind(self, impls: Dict[str, Callable],
             tunings: Dict[str, TileGeometry]) -> Dict[str, Callable]:
        """``{fmt: impl}`` with each format's tuned geometry partially
        applied (formats without a tuned geometry pass through)."""
        import functools
        return {f: (functools.partial(fn, tuning=tunings[f])
                    if f in tunings else fn)
                for f, fn in impls.items()}
