"""Kernel launch-geometry auto-tuning: extend AT from format choice down to
the hot loop.

The paper's auto-tuner stops at *format selection*; the Pallas tier then
used to launch every kernel with one hard-coded tile shape.  AlphaSparse
(arXiv:2212.10432) shows per-matrix design-space search over launch
parameters dominates any single fixed schedule, and SELL-C-sigma
(arXiv:1307.6209) shows tile/chunk geometry is the decisive knob on
wide-SIMD hardware.  This module is the launch-parameter half of that
argument for our stack:

  * :class:`TileGeometry` — the knobs every kernel wrapper in
    ``kernels/ops.py`` accepts per call (``tuning=``): ``block_rows`` /
    ``block_w`` (ELL band tiles, BCSR row tiles), ``block_k`` (SpMM RHS
    tile), ``block_nnz`` (COO/CSR nnz slab) and ``slabs_per_block`` (the
    CSR/BCSR static slab-coverage bound — data-dependent, so only the
    tuner, holding the concrete matrix, can supply it to traced callers);
  * :func:`candidate_geometries` — the bounded per-(format, op) search
    grid (``block_rows in {8..512}``, ``block_w in {8,128,256}``, ...);
  * :class:`KernelTuner` — times real launches per candidate, memoizes the
    winner per ``(format, op, batch, matrix profile)``, records into the
    existing :class:`~repro.core.autotune.TuningDB` (persisted next to the
    ``OfflineRecord``\\s), and answers unseen matrices with a
    D_mat-keyed nearest-neighbour fallback.

The timing loop is injectable (``timer=``) so tests tune deterministically
without a clock.
"""
from __future__ import annotations

import time
from dataclasses import asdict, dataclass, replace
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

import repro.obs as _obs

from . import dispatch as _dispatch
from .formats import CCS, CSR, MatrixStats

__all__ = [
    "TileGeometry", "GeometryRecord", "GRID_FORMATS",
    "candidate_geometries", "nearest_geometry", "KernelTuner",
]


# ---------------------------------------------------------------------------
# the geometry pytree-of-knobs
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TileGeometry:
    """Per-call launch geometry; ``None`` fields fall back to the wrapper's
    built-in default.  Hashable so it can ride through static closures.

    ``block_rows`` is the *segmented-axis* tile: rows for ELL/CSR (and
    block rows for BCSR), columns for CCS — one knob, because no kernel
    tiles both axes independently.

    ``buckets`` is the SELL per-bucket table: ``((width, TileGeometry),
    ...)`` pairs keyed by bucket *width*, so one persisted geometry carries
    a different tile shape for every bucket of the container (SELL-C-σ's
    point: chunk geometry is per-chunk).  Bucket widths absent from the
    table fall back to the top-level knobs."""
    block_rows: Optional[int] = None   # ELL/CSR row tile; CCS col tile; BCSR block-row tile
    block_w: Optional[int] = None      # ELL band (lane) tile
    block_k: Optional[int] = None      # SpMM right-hand-side tile
    block_nnz: Optional[int] = None    # COO/CSR/CCS nnz slab; BCSR blocks/slab
    slabs_per_block: Optional[int] = None  # CSR/CCS/BCSR static coverage bound
    buckets: Optional[Tuple[Tuple[int, "TileGeometry"], ...]] = None  # SELL

    _KNOBS = ("block_rows", "block_w", "block_k", "block_nnz",
              "slabs_per_block")

    def to_dict(self) -> Dict[str, Any]:
        d = {k: getattr(self, k) for k in self._KNOBS
             if getattr(self, k) is not None}
        if self.buckets is not None:
            d["buckets"] = [[w, g.to_dict()] for w, g in self.buckets]
        return d

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "TileGeometry":
        d = dict(d)
        buckets = d.pop("buckets", None)
        g = TileGeometry(**d)
        if buckets is not None:
            g = replace(g, buckets=tuple(
                (int(w), TileGeometry.from_dict(gd)) for w, gd in buckets))
        return g

    def broadcast(self) -> "TileGeometry":
        """The top-level knobs alone (per-bucket table stripped) — what a
        bucket whose width is missing from the table launches with."""
        return replace(self, buckets=None)

    def without_slab_bound(self) -> "TileGeometry":
        """Strip the data-dependent coverage bound — required when a
        geometry learned on one matrix is applied to another under trace
        (the bound would silently drop entries; without it the CSR/CCS/BCSR
        kernels fall back to the always-correct full sweep, and concrete
        callers recompute the exact bound anyway).  Applies through the
        per-bucket table too."""
        buckets = self.buckets
        if buckets is not None:
            buckets = tuple((w, g.without_slab_bound()) for w, g in buckets)
        return replace(self, slabs_per_block=None, buckets=buckets)


@dataclass
class GeometryRecord:
    """One tuning outcome: the winning geometry for (format, op, batch) on
    a matrix profile, plus the measured win over the default launch.

    ``sig`` fingerprints the index structure (CRC of the pointer array)
    when it was concrete at tune time: two same-sized matrices must not
    share a memoized record, because the winning geometry can carry a
    matrix-specific slab-coverage bound.

    ``bucket_w`` marks a SELL per-bucket component record (the winner for
    the bucket of that width); ``None`` is a whole-matrix record — for
    SELL that aggregate's geometry carries the composed per-bucket table,
    and only aggregates feed the nearest-neighbour fallback."""
    fmt: str
    op: str
    batch: int
    n: int
    nnz: int
    d_mat: float
    geometry: TileGeometry
    t_best: float
    t_default: float
    sig: int = 0
    bucket_w: Optional[int] = None

    @property
    def speedup(self) -> float:
        return self.t_default / self.t_best if self.t_best > 0 else 1.0

    def to_dict(self) -> Dict[str, Any]:
        d = asdict(self)
        d["geometry"] = self.geometry.to_dict()
        if self.bucket_w is None:
            d.pop("bucket_w")
        return d

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "GeometryRecord":
        d = dict(d)
        d["geometry"] = TileGeometry.from_dict(d["geometry"])
        return GeometryRecord(**d)


# ---------------------------------------------------------------------------
# the bounded search grid
# ---------------------------------------------------------------------------
ROW_TILES = (8, 32, 128, 256, 512)
W_TILES = (8, 128, 256)
K_TILES = (8, 128)
NNZ_TILES = (1024, 4096, 16384, 65536)
CSR_ROW_TILES = (64, 128, 256, 512)
CSR_NNZ_TILES = (1024, 4096, 16384, 65536)
BCSR_ROW_TILES = (8, 32, 64)
BCSR_NNZ_TILES = (128, 512, 2048)
# the whole-nnz "one slab" boundary candidate is capped so a slab's VAL +
# ICOL stay ~2 MiB — comfortably inside VMEM next to the pinned x
MAX_SLAB = 262144
MAX_BLOCK_SLAB = 8192

#: every format with a bounded candidate grid below — the kernel tier's
#: tunable surface.  Kept as a plain literal tuple so the static registry
#: audit (``repro.analyze``) can read it without importing jax; the
#: ``candidate_geometries`` gate uses it, so a kernel registered without a
#: grid entry is caught both here and by the audit.
GRID_FORMATS = ("ell_row", "ell_col", "sell", "coo_row", "coo_col",
                "csr", "ccs", "bcsr")


def _align8(n: int) -> int:
    return max(8, 8 * ((int(n) + 7) // 8))


def _nnz_tiles(base, nnz_pad: int, cap: int):
    """Slab-size candidates: the base grid clamped to the matrix, plus the
    whole-nnz single-slab boundary (itself clamped to the VMEM cap)."""
    if not nnz_pad:
        return sorted(base)
    whole = min(_align8(nnz_pad), cap)
    return sorted({min(bn, whole) for bn in base} | {whole})


def candidate_geometries(fmt: str, op: str = "spmv", *, n_rows: int = 0,
                         width: int = 0, nnz_pad: int = 0,
                         batch: int = 1) -> List[TileGeometry]:
    """The bounded launch-geometry grid for one (format, op).

    Candidates are pre-clamped to the matrix profile (a 512-row tile on a
    100-row matrix is the same launch as a 128-row one) and de-duplicated,
    so the tuner never times the same effective launch twice."""
    if fmt not in GRID_FORMATS:
        return []
    ks = tuple(sorted({min(k, _align8(batch)) for k in K_TILES})) \
        if op == "spmm" else (None,)
    geoms: List[TileGeometry] = []
    if fmt.startswith("ell") or fmt == "sell":
        rows = {min(r, _align8(n_rows)) for r in ROW_TILES} if n_rows \
            else set(ROW_TILES)
        ws = {min(w, _align8(width)) for w in W_TILES} if width \
            else set(W_TILES)
        for r in sorted(rows):
            for w in sorted(ws):
                for k in ks:
                    geoms.append(TileGeometry(block_rows=r, block_w=w,
                                              block_k=k))
    elif fmt.startswith("coo"):
        for bn in _nnz_tiles(NNZ_TILES, nnz_pad, MAX_SLAB):
            for k in ks:
                geoms.append(TileGeometry(block_nnz=bn, block_k=k))
    elif fmt in ("csr", "ccs"):
        # same segmented-slab grid for both; ``n_rows`` is the segmented
        # axis length, so CCS callers pass the *column* count
        rows = {min(r, _align8(n_rows)) for r in CSR_ROW_TILES} if n_rows \
            else set(CSR_ROW_TILES)
        if n_rows:
            # the single-segment-block boundary (tile capped for VMEM)
            rows.add(min(_align8(n_rows), MAX_SLAB))
        for r in sorted(rows):
            for bn in _nnz_tiles(CSR_NNZ_TILES, nnz_pad, MAX_SLAB):
                for k in ks:
                    geoms.append(TileGeometry(block_rows=r, block_nnz=bn,
                                              block_k=k))
    elif fmt == "bcsr":
        rows = {min(r, max(1, n_rows)) for r in BCSR_ROW_TILES} if n_rows \
            else set(BCSR_ROW_TILES)
        bns = set(_nnz_tiles(BCSR_NNZ_TILES, nnz_pad, MAX_BLOCK_SLAB))
        for r in sorted(rows):
            for bn in sorted(bns):
                for k in ks:
                    geoms.append(TileGeometry(block_rows=r, block_nnz=bn,
                                              block_k=k))
    else:
        return []
    seen, out = set(), []
    for g in geoms:
        key = (g.block_rows, g.block_w, g.block_k, g.block_nnz)
        if key not in seen:
            seen.add(key)
            out.append(g)
    return out


# ---------------------------------------------------------------------------
# nearest-neighbour fallback over recorded geometries
# ---------------------------------------------------------------------------
def nearest_geometry(records: Sequence[GeometryRecord], fmt: str,
                     op: str = "spmv", d_mat: float = 0.0,
                     batch: Optional[int] = None) -> Optional[TileGeometry]:
    """D_mat-keyed (log-space) nearest neighbour among recorded winners.

    The returned geometry is stripped of its slab-coverage bound — that
    bound is only valid for the matrix it was measured on.  SELL
    per-bucket component records (``bucket_w`` set) are skipped: the
    whole-matrix aggregate already carries the composed bucket table."""
    recs = [r for r in records if r.fmt == fmt and r.op == op
            and getattr(r, "bucket_w", None) is None]
    if batch is not None:
        exact = [r for r in recs if r.batch == batch]
        recs = exact or recs
    if not recs:
        return None
    q = np.log(max(d_mat, 1e-9))
    best = min(recs, key=lambda r: abs(np.log(max(r.d_mat, 1e-9)) - q))
    return best.geometry.without_slab_bound()


# ---------------------------------------------------------------------------
# matrix profiling (best effort per format)
# ---------------------------------------------------------------------------
def _structure_sig(obj: Any) -> int:
    """CRC fingerprint of the concrete index-pointer structure (0 when the
    object has none, or it is abstract).  Part of the memo identity: the
    winning geometry's slab-coverage bound is only valid for the exact
    structure it was measured on."""
    ip = getattr(obj, "indptr", None)
    if ip is None or isinstance(ip, jax.core.Tracer):
        return 0
    import zlib
    return zlib.crc32(np.ascontiguousarray(np.asarray(ip)).tobytes()) or 1


def _profile_of(obj: Any, stats: Optional[MatrixStats] = None
                ) -> Tuple[int, int, float, int]:
    sig = _structure_sig(obj)
    if stats is not None:
        return int(stats.n), int(stats.nnz), float(stats.d_mat), sig
    n = int(getattr(obj, "n_rows", 0))
    nnz = int(getattr(obj, "nnz", 0))
    d_mat = 0.0
    ip = getattr(obj, "indptr", None)
    if ip is not None and not isinstance(ip, jax.core.Tracer):
        if isinstance(obj, CSR):
            d_mat = float(MatrixStats.of(obj).d_mat)
        elif isinstance(obj, CCS):
            # the column-space analogue: nnz-per-column variation is what
            # shapes the column-segmented launch
            lens = np.diff(np.asarray(ip)).astype(np.float64)
            mu = float(lens.mean()) if lens.size else 0.0
            d_mat = float(lens.std() / mu) if mu > 0 else 0.0
    return n, nnz, d_mat, sig


def _width_of(obj: Any) -> int:
    w = getattr(obj, "width", None)
    if w is not None:
        return int(w)
    widths = getattr(obj, "widths", None)   # BucketedELL
    if widths:
        return int(max(widths))
    return 0


def _slab_bound_for(obj: Any, g: TileGeometry) -> Optional[int]:
    """Exact slab coverage bound for a CSR/CCS/BCSR candidate, computable
    only with the concrete index structure in hand (for CCS the pointer is
    the column pointer — same arithmetic)."""
    ip = getattr(obj, "indptr", None)
    if ip is None or isinstance(ip, jax.core.Tracer):
        return None
    from repro.kernels.csr_spmv import slabs_needed
    segmented = isinstance(obj, (CSR, CCS))
    br = g.block_rows or (256 if segmented else 32)
    bn = g.block_nnz or (2048 if segmented else 512)
    return slabs_needed(np.asarray(ip), br, bn)


# ---------------------------------------------------------------------------
# the tuner
# ---------------------------------------------------------------------------
def _real_timer(iters: int, warmup: int) -> Callable:
    def timer(thunk: Callable[[], Any], geometry: Optional[TileGeometry]
              ) -> float:
        for _ in range(warmup):
            thunk()
        best = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            thunk()
            best = min(best, time.perf_counter() - t0)
        return best
    return timer


class KernelTuner:
    """Searches :func:`candidate_geometries` by timing real launches.

    ``db``: an :class:`~repro.core.autotune.TuningDB` to read/record
    geometry winners in (its ``geometries`` list is shared, so saving the
    db persists the tuner's work).  ``timer(thunk, geometry) -> seconds``
    is injectable for deterministic tests.
    """

    def __init__(self, db: Optional[Any] = None,
                 interpret: Optional[bool] = None,
                 iters: int = 3, warmup: int = 1,
                 timer: Optional[Callable] = None,
                 max_candidates: Optional[int] = None):
        self.db = db
        self.interpret = interpret
        self.records: List[GeometryRecord] = (
            db.geometries if db is not None
            and getattr(db, "geometries", None) is not None else [])
        if db is not None and getattr(db, "geometries", None) is None:
            db.geometries = self.records
        self._timer = timer or _real_timer(iters, warmup)
        self.max_candidates = max_candidates
        # memo maps key -> *index* into self.records, so a forced re-tune
        # replaces the superseded record in place instead of accumulating
        # duplicates in the shared (persisted) list
        self._memo: Dict[Tuple, int] = self._build_memo()

    def _build_memo(self) -> Dict[Tuple, int]:
        memo = {
            self._key(r.fmt, r.op, r.batch, (r.n, r.nnz, r.d_mat, r.sig),
                      getattr(r, "bucket_w", None)): i
            for i, r in enumerate(self.records)}
        if len(memo) != len(self.records):
            # a db persisted before re-tunes replaced in place can carry
            # stale duplicates; keep the last record per key (the freshest
            # winner) so nearest_geometry can't resurrect a superseded one
            # — compact through the slice so the db's list alias heals too
            self.records[:] = [self.records[i] for i in sorted(memo.values())]
            return self._build_memo()
        return memo

    @staticmethod
    def _key(fmt: str, op: str, batch: int,
             profile: Tuple[int, int, float, int],
             bucket_w: Optional[int] = None):
        return (fmt, op, batch, profile[0], profile[1],
                round(profile[2], 6), profile[3], bucket_w)

    def _record(self, key: Tuple, rec: GeometryRecord) -> GeometryRecord:
        """Memoize ``rec`` under ``key``, replacing any superseded record
        in place (keeps one record per key across forced re-tunes, and
        keeps ``self.records`` aliased with the db's list)."""
        idx = self._memo.get(key)
        if idx is None:
            self._memo[key] = len(self.records)
            self.records.append(rec)
        else:
            self.records[idx] = rec
        tel = _obs.get()
        if tel.enabled:
            attrs = dict(fmt=rec.fmt, op=rec.op, batch=rec.batch,
                         t_best=rec.t_best, t_default=rec.t_default,
                         speedup=rec.speedup,
                         geometry=rec.geometry.to_dict())
            if rec.bucket_w is not None:
                attrs["bucket_w"] = rec.bucket_w
            tel.event("tune.winner", **attrs)
        return rec

    # -- search --------------------------------------------------------------
    def tune(self, obj: Any, op: str = "spmv", batch: int = 1,
             impl: Optional[Callable] = None, x: Optional[jax.Array] = None,
             stats: Optional[MatrixStats] = None,
             force: bool = False) -> GeometryRecord:
        """Time every candidate launch of ``obj``'s kernel and return (and
        memoize) the winner.  The default launch is always a candidate, so
        ``t_best <= t_default`` by construction.

        SELL containers are tuned *per bucket*: each bucket width gets its
        own candidate sweep (timed on that bucket's ELL launch alone), the
        per-width winners are memoized as component records, and the
        returned aggregate's geometry composes them into a
        ``TileGeometry.buckets`` table."""
        import jax.numpy as jnp

        fmt = _dispatch.format_of(obj)
        profile = _profile_of(obj, stats)
        batch = max(batch, 1)
        key = self._key(fmt, op, batch, profile)
        idx = self._memo.get(key)
        if not force and idx is not None:
            tel = _obs.get()
            if tel.enabled:
                tel.counter("tune.memo_hit", fmt=fmt, op=op).inc()
            return self.records[idx]

        if impl is None:
            impl = _dispatch.get_impl(fmt, op, tier="kernel", fallback=False)
        if x is None:
            shape = (obj.n_cols,) if op == "spmv" else (obj.n_cols, batch)
            x = jnp.ones(shape, jnp.float32)

        if fmt == "sell":
            with _obs.span("tune.sweep", fmt=fmt, op=op, batch=batch,
                           d_mat=profile[2]):
                return self._tune_sell(obj, op, batch, impl, x, profile,
                                       key, force)

        cands: List[Optional[TileGeometry]] = [None]
        if fmt == "ccs":
            # the segmented axis is the *column* axis
            grid_rows = int(getattr(obj, "n_cols", 0) or 0)
        else:
            # BCSR row tiles count *block* rows; everything else scalar rows
            grid_rows = int(getattr(obj, "n_block_rows", profile[0]) or 0)
        grid = candidate_geometries(
            fmt, op, n_rows=grid_rows, width=_width_of(obj),
            nnz_pad=int(getattr(obj, "nnz_pad",
                                getattr(obj, "nblocks_pad", 0)) or 0),
            batch=batch)
        if self.max_candidates is not None:
            grid = grid[: self.max_candidates]
        cands.extend(grid)

        with _obs.span("tune.sweep", fmt=fmt, op=op, batch=batch,
                       d_mat=profile[2]) as sweep:
            times: List[Tuple[float, Optional[TileGeometry]]] = []
            for g in cands:
                gg = g
                if g is not None and fmt in ("csr", "ccs", "bcsr"):
                    spb = _slab_bound_for(obj, g)
                    if spb is not None:
                        gg = replace(g, slabs_per_block=spb)
                times.append((self._time_launch(impl, obj, x, gg,
                                                fmt=fmt, op=op), gg))

            t_default = times[0][0]
            t_best, best_g = min(times, key=lambda tg: tg[0])
            sweep.set(candidates=len(cands), t_best=t_best,
                      t_default=t_default)
        rec = GeometryRecord(
            fmt=fmt, op=op, batch=batch, n=profile[0],
            nnz=profile[1], d_mat=profile[2], sig=profile[3],
            geometry=best_g if best_g is not None else TileGeometry(),
            t_best=t_best, t_default=t_default)
        return self._record(key, rec)

    def _time_launch(self, impl: Callable, obj: Any, x: jax.Array,
                     g: Optional[TileGeometry], **span_attrs: Any) -> float:
        fn = jax.jit(lambda m, v, _f=impl, _g=g:
                     _f(m, v, interpret=self.interpret, tuning=_g))
        thunk = lambda _fn=fn: jax.block_until_ready(_fn(obj, x))
        with _obs.span("tune.candidate",
                       geometry=g.to_dict() if g is not None else {},
                       **span_attrs) as sp:
            t = float(self._timer(thunk, g))
            sp.set(t=t)
        return t

    def _tune_sell(self, obj: Any, op: str, batch: int, impl: Callable,
                   x: jax.Array, profile: Tuple[int, int, float, int],
                   key: Tuple, force: bool) -> GeometryRecord:
        """Per-bucket SELL search (SELL-C-sigma's per-chunk geometry).

        Bucket widths are distinct by construction (equal-width neighbours
        merge at transform time), so each width is searched once on its own
        bucket — an ELL launch over (bucket_rows, width) — and memoized as
        a component record keyed by ``bucket_w``.  The aggregate then times
        the composed per-bucket table against the all-defaults launch, so
        its ``t_best <= t_default`` stays true by construction."""
        ell_impl = _dispatch.get_impl("ell_row", op, tier="kernel",
                                      fallback=False)
        table: List[Tuple[int, TileGeometry]] = []
        for b in obj.buckets:
            bkey = self._key("sell", op, batch, profile,
                             bucket_w=int(b.width))
            bidx = self._memo.get(bkey)
            if not force and bidx is not None:
                table.append((int(b.width), self.records[bidx].geometry))
                continue
            grid = candidate_geometries("sell", op, n_rows=b.n_rows,
                                        width=b.width, batch=batch)
            if self.max_candidates is not None:
                grid = grid[: self.max_candidates]
            times = [(self._time_launch(ell_impl, b, x, g, fmt="sell",
                                        op=op, bucket_w=int(b.width)), g)
                     for g in [None] + grid]
            t_default = times[0][0]
            t_best, best_g = min(times, key=lambda tg: tg[0])
            brec = GeometryRecord(
                fmt="sell", op=op, batch=batch, n=profile[0],
                nnz=profile[1], d_mat=profile[2], sig=profile[3],
                bucket_w=int(b.width),
                geometry=best_g if best_g is not None else TileGeometry(),
                t_best=t_best, t_default=t_default)
            self._record(bkey, brec)
            table.append((int(b.width), brec.geometry))

        cands: List[Optional[TileGeometry]] = [None]
        if table:
            cands.append(TileGeometry(buckets=tuple(table)))
        times = [(self._time_launch(impl, obj, x, g, fmt="sell", op=op), g)
                 for g in cands]
        t_default = times[0][0]
        t_best, best_g = min(times, key=lambda tg: tg[0])
        rec = GeometryRecord(
            fmt="sell", op=op, batch=batch, n=profile[0], nnz=profile[1],
            d_mat=profile[2], sig=profile[3],
            geometry=best_g if best_g is not None else TileGeometry(),
            t_best=t_best, t_default=t_default)
        return self._record(key, rec)

    # -- lookup --------------------------------------------------------------
    def best(self, obj: Any = None, op: str = "spmv", batch: int = 1,
             fmt: Optional[str] = None, d_mat: Optional[float] = None,
             stats: Optional[MatrixStats] = None
             ) -> Optional[TileGeometry]:
        """Memoized winner for this exact profile, else the D_mat-keyed
        nearest-neighbour among recorded winners (slab bound stripped),
        else ``None`` (caller uses the default launch)."""
        if obj is not None:
            fmt = fmt or _dispatch.format_of(obj)
            profile = _profile_of(obj, stats)
            idx = self._memo.get(self._key(fmt, op, max(batch, 1), profile))
            if idx is not None:
                return self.records[idx].geometry
            if d_mat is None:
                d_mat = profile[2]
        if fmt is None:
            raise ValueError("best() needs a matrix object or a format name")
        return nearest_geometry(self.records, fmt, op,
                                d_mat=d_mat or 0.0, batch=max(batch, 1))

    # -- binding helpers -----------------------------------------------------
    def bind(self, impls: Dict[str, Callable],
             tunings: Dict[str, TileGeometry]) -> Dict[str, Callable]:
        """``{fmt: impl}`` with each format's tuned geometry partially
        applied (formats without a tuned geometry — or whose impl doesn't
        accept ``tuning=`` — pass through).  Delegates to the shared
        :func:`repro.core.plan.bind_tunings`."""
        from .plan import bind_tunings
        return bind_tunings(impls, tunings)
