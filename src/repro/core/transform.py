"""Run-time sparse-format transformations (paper §2.1).

Two implementation paths:

* ``host_*`` — numpy, executed at library-call time exactly like the paper's
  Fortran code.  ``host_csr_to_ccs_paper`` is a literal loop-for-loop
  translation of the paper's counting algorithm and is used as the oracle
  for the vectorized versions.
* ``device_*`` — pure ``jnp``, jit-able, so the transformation itself can run
  on the accelerator and be costed on the roofline.  Static output widths /
  nnz pads are trace-time constants (computed host-side from the matrix
  stats, which are known at call time — same run-time model as the paper).
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

import repro.obs as _obs

from .formats import CSR, CCS, COO, ELL, BucketedELL


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------
def pad_to_multiple(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _traced(fmt: str):
    """Wrap a host conversion in a ``transform`` span carrying the target
    format, matrix size, and any simple keyword parameters — so t_trans
    shows up per conversion in every trace, not just in offline records."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(m, *a, **kw):
            # deterministic fault point for chaos tests: a conversion that
            # "fails" here exercises the service's degrade-to-CSR path
            # (the CSR identity is not _traced, so fallbacks stay clean)
            from repro.serve import faults as _faults
            _faults.maybe_raise("transform.raise")
            tel = _obs.get()
            if not tel.enabled:
                return fn(m, *a, **kw)
            attrs = {"fmt": fmt,
                     "n_rows": int(getattr(m, "n_rows", 0) or 0),
                     "nnz": int(getattr(m, "nnz", 0) or 0)}
            attrs.update((k, v) for k, v in kw.items()
                         if isinstance(v, (bool, int, float, str)))
            with tel.span("transform", **attrs):
                return fn(m, *a, **kw)
        return wrapper
    return deco


def _pad1(x: np.ndarray, n_pad: int, fill=0) -> np.ndarray:
    out = np.full((n_pad,), fill, dtype=x.dtype)
    out[: x.shape[0]] = x
    return out


# ---------------------------------------------------------------------------
# construction from dense / random (host)
# ---------------------------------------------------------------------------
def csr_from_dense(dense: np.ndarray, pad: int = 1) -> CSR:
    dense = np.asarray(dense)
    n_rows, n_cols = dense.shape
    rows, cols = np.nonzero(dense)
    data = dense[rows, cols]
    nnz = data.shape[0]
    indptr = np.zeros(n_rows + 1, dtype=np.int32)
    np.add.at(indptr, rows + 1, 1)
    indptr = np.cumsum(indptr).astype(np.int32)
    nnz_pad = max(pad_to_multiple(nnz, pad), pad)
    return CSR(
        data=_pad1(data.astype(dense.dtype), nnz_pad),
        cols=_pad1(cols.astype(np.int32), nnz_pad),
        indptr=indptr,
        shape=(n_rows, n_cols),
        nnz=nnz,
    )


def csr_from_rows(row_cols: Sequence[np.ndarray], row_vals: Sequence[np.ndarray],
                  n_cols: int, pad: int = 1, dtype=np.float32) -> CSR:
    """Build CSR from per-row (cols, vals) lists — the suite generator path."""
    n_rows = len(row_cols)
    lens = np.fromiter((len(c) for c in row_cols), count=n_rows, dtype=np.int64)
    nnz = int(lens.sum())
    indptr = np.zeros(n_rows + 1, dtype=np.int32)
    np.cumsum(lens, out=indptr[1:])
    cols = (np.concatenate(row_cols).astype(np.int32) if nnz
            else np.zeros(0, np.int32))
    data = (np.concatenate(row_vals).astype(dtype) if nnz
            else np.zeros(0, dtype))
    nnz_pad = max(pad_to_multiple(nnz, pad), pad)
    return CSR(data=_pad1(data, nnz_pad), cols=_pad1(cols, nnz_pad),
               indptr=indptr, shape=(n_rows, n_cols), nnz=nnz)


# ---------------------------------------------------------------------------
# incremental CSR edits (streaming substrate; see repro.stream.delta)
# ---------------------------------------------------------------------------
def csr_append_rows(m: CSR, row_cols: Sequence[np.ndarray],
                    row_vals: Sequence[np.ndarray], *,
                    in_place: bool = True, growth: float = 2.0,
                    lens: Optional[np.ndarray] = None) -> CSR:
    """Append whole rows at the tail in O(Δnnz).

    When the existing ``nnz_pad`` slack can hold the new nonzeros (and
    ``in_place`` is allowed) the data/cols buffers are written in place and
    **shared** with the input; otherwise fresh buffers are allocated with
    ``growth``× headroom so repeated appends amortize.  Only the indptr is
    ever rebuilt (O(n) int copy).

    ``row_cols``/``row_vals`` are per-row arrays — or, with ``lens``
    given, single already-flattened arrays (the memoized form a caller
    that appends the same batch shape repeatedly can reuse)."""
    flat = isinstance(row_cols, np.ndarray)
    if lens is None:
        if flat:
            raise ValueError("flattened row_cols requires explicit lens")
        k = len(row_cols)
        lens = np.fromiter((len(c) for c in row_cols), count=k,
                           dtype=np.int64)
    else:
        k = int(np.asarray(lens).shape[0])
    if k == 0:
        return m
    n_rows, n_cols = m.shape
    d = int(lens.sum())
    new_nnz = m.nnz + d
    ip = np.asarray(m.indptr)
    new_ip = np.empty(n_rows + k + 1, dtype=ip.dtype)
    new_ip[: n_rows + 1] = ip
    new_ip[n_rows + 1:] = m.nnz + np.cumsum(lens)
    data, cols = np.asarray(m.data), np.asarray(m.cols)
    if in_place and new_nnz <= m.nnz_pad:
        out_d, out_c = data, cols
    else:
        new_pad = max(new_nnz, int(growth * m.nnz_pad))
        out_d = np.empty(new_pad, dtype=data.dtype)
        out_c = np.empty(new_pad, dtype=cols.dtype)
        out_d[: m.nnz] = data[: m.nnz]
        out_c[: m.nnz] = cols[: m.nnz]
        # only the slack needs the (0, 0) pad convention; [nnz, new_nnz)
        # is overwritten by the appended entries below
        out_d[new_nnz:] = 0
        out_c[new_nnz:] = 0
    if d:
        out_d[m.nnz:new_nnz] = row_vals if flat else np.concatenate(
            [np.asarray(v, dtype=out_d.dtype) for v in row_vals])
        out_c[m.nnz:new_nnz] = row_cols if flat else np.concatenate(
            [np.asarray(c, dtype=out_c.dtype) for c in row_cols])
    return CSR(data=out_d, cols=out_c, indptr=new_ip,
               shape=(n_rows + k, n_cols), nnz=new_nnz)


def csr_set_values(m: CSR, rows: np.ndarray, cols: np.ndarray,
                   vals: np.ndarray, *, in_place: bool = True):
    """Overwrite existing nonzeros in O(Δ · row_len).

    Returns ``(csr, hit)`` where ``hit[i]`` is False when ``(rows[i],
    cols[i])`` has no stored entry (the caller routes misses to
    :func:`csr_splice` as inserts).  With ``in_place`` the value buffer is
    mutated and the input CSR object itself is returned."""
    rows = np.asarray(rows, dtype=np.int64)
    cols_q = np.asarray(cols, dtype=np.int64)
    ip = np.asarray(m.indptr)
    mc = np.asarray(m.cols)
    nq = rows.shape[0]
    pos = np.full(nq, -1, dtype=np.int64)
    if nq:
        # one flat probe over every queried row's segment (no per-query
        # Python loop): cell i of query q probes mc[ip[rows[q]] + i]
        s = ip[rows].astype(np.int64)
        seg = (ip[rows + 1] - ip[rows]).astype(np.int64)
        offs = np.concatenate([[0], np.cumsum(seg)])
        total = int(offs[-1])
        if total:
            ridx = np.repeat(np.arange(nq, dtype=np.int64), seg)
            flat = s[ridx] + (np.arange(total, dtype=np.int64) - offs[ridx])
            mi = np.flatnonzero(mc[flat] == cols_q[ridx])
            if mi.size:
                # first stored match per query, as cells are row-major
                q_first, first_idx = np.unique(ridx[mi], return_index=True)
                pos[q_first] = flat[mi[first_idx]]
    hit = pos >= 0
    if not hit.any():
        return m, hit
    data = np.asarray(m.data)
    if not in_place:
        data = data.copy()
    data[pos[hit]] = np.asarray(vals, dtype=data.dtype)[hit]
    if in_place:
        return m, hit
    return CSR(data=data, cols=np.asarray(m.cols), indptr=ip,
               shape=m.shape, nnz=m.nnz), hit


def csr_splice(m: CSR,
               insert_rows: np.ndarray, insert_cols: np.ndarray,
               insert_vals: np.ndarray,
               delete_rows: np.ndarray, delete_cols: np.ndarray) -> CSR:
    """Insert/delete individual nonzeros via one vectorized memmove.

    O(nnz) — far cheaper than any format re-transform, but not O(Δ); the
    streaming layer records it as its own apply mode.  Deletes of absent
    entries are ignored; inserts land at their row's end (CSR does not
    require column order within a row)."""
    n_rows = m.n_rows
    nnz = m.nnz
    live_d = np.asarray(m.data)[:nnz]
    live_c = np.asarray(m.cols)[:nnz]
    ip = np.asarray(m.indptr).astype(np.int64)
    delete_rows = np.asarray(delete_rows, dtype=np.int64)
    if delete_rows.shape[0]:
        delete_cols = np.asarray(delete_cols, dtype=np.int64)
        keep = np.ones(nnz, dtype=bool)
        del_counts = np.zeros(n_rows, dtype=np.int64)
        for r, c in zip(delete_rows, delete_cols):
            s, e = int(ip[r]), int(ip[r + 1])
            idx = np.nonzero(live_c[s:e] == c)[0]
            if idx.size and keep[s + int(idx[0])]:
                keep[s + int(idx[0])] = False
                del_counts[r] += 1
        live_d, live_c = live_d[keep], live_c[keep]
        ip = ip - np.concatenate([[0], np.cumsum(del_counts)])
    insert_rows = np.asarray(insert_rows, dtype=np.int64)
    if insert_rows.shape[0]:
        order = np.argsort(insert_rows, kind="stable")
        ir = insert_rows[order]
        ic = np.asarray(insert_cols, dtype=np.int64)[order]
        iv = np.asarray(insert_vals)[order]
        live_d = np.insert(live_d, ip[ir + 1], iv.astype(live_d.dtype))
        live_c = np.insert(live_c, ip[ir + 1], ic.astype(live_c.dtype))
        add = np.bincount(ir, minlength=n_rows)
        ip = ip + np.concatenate([[0], np.cumsum(add)])
    new_nnz = int(live_d.shape[0])
    new_pad = max(m.nnz_pad, new_nnz)
    return CSR(data=_pad1(live_d, new_pad), cols=_pad1(live_c, new_pad),
               indptr=ip.astype(np.asarray(m.indptr).dtype),
               shape=m.shape, nnz=new_nnz)


# ---------------------------------------------------------------------------
# CRS -> COO-Row (host): trivial, row ids from IRP (paper: "easy" direction)
# ---------------------------------------------------------------------------
@_traced("coo_row")
def host_csr_to_coo_row(m: CSR) -> COO:
    ip = np.asarray(m.indptr)
    lens = ip[1:] - ip[:-1]
    rows = np.repeat(np.arange(m.n_rows, dtype=np.int32), lens)
    return COO(data=np.asarray(m.data).copy(),
               rows=_pad1(rows, m.nnz_pad),
               cols=np.asarray(m.cols).copy(),
               shape=m.shape, nnz=m.nnz, order="row")


# ---------------------------------------------------------------------------
# CRS -> CCS (host): the paper's Phase-I counting algorithm.
# ---------------------------------------------------------------------------
def host_csr_to_ccs_paper(m: CSR) -> CCS:
    """Literal translation of the paper's Fortran (§2.1) — O(n + nnz) loops.

    Used as the oracle for the vectorized version; quadratic-free but slow in
    Python, so tests call it on small matrices only.
    """
    n, nnz = m.n_rows, m.nnz
    VAL = np.asarray(m.data)
    ICOL = np.asarray(m.cols)
    IRP = np.asarray(m.indptr)
    # === Count the number of non-zero columns.
    NC_IRP = np.zeros(m.n_cols, dtype=np.int64)
    for i in range(n):
        for j_ptr in range(IRP[i], IRP[i + 1]):
            NC_IRP[ICOL[j_ptr]] += 1
    # === Set IRP.
    IRP_T = np.zeros(m.n_cols + 1, dtype=np.int64)
    IRP_T[0] = 0
    for j in range(1, m.n_cols + 1):
        IRP_T[j] = IRP_T[j - 1] + NC_IRP[j - 1]
    cursor = IRP_T[:-1].copy()
    # === Set row numbers (paper stores ICOL_T(K) = I, i.e. the row index).
    VAL_T = np.zeros(nnz, dtype=VAL.dtype)
    IROW_T = np.zeros(nnz, dtype=np.int32)
    for i in range(n):
        for j_ptr in range(IRP[i], IRP[i + 1]):
            jj = ICOL[j_ptr]
            k = cursor[jj]
            cursor[jj] += 1
            VAL_T[k] = VAL[j_ptr]
            IROW_T[k] = i
    return CCS(data=_pad1(VAL_T, m.nnz_pad), rows=_pad1(IROW_T, m.nnz_pad),
               indptr=IRP_T.astype(np.int32), shape=m.shape, nnz=nnz)


@_traced("ccs")
def host_csr_to_ccs(m: CSR) -> CCS:
    """Vectorized counting sort — same output order as the paper's algorithm
    (stable within a column by row index, because CSR scans rows in order)."""
    nnz = m.nnz
    cols = np.asarray(m.cols)[:nnz]
    data = np.asarray(m.data)[:nnz]
    ip = np.asarray(m.indptr)
    rows = np.repeat(np.arange(m.n_rows, dtype=np.int32), ip[1:] - ip[:-1])
    counts = np.bincount(cols, minlength=m.n_cols)
    indptr = np.zeros(m.n_cols + 1, dtype=np.int32)
    np.cumsum(counts, out=indptr[1:])
    order = np.argsort(cols, kind="stable")
    return CCS(data=_pad1(data[order], m.nnz_pad),
               rows=_pad1(rows[order], m.nnz_pad),
               indptr=indptr, shape=m.shape, nnz=nnz)


# ---------------------------------------------------------------------------
# CRS -> COO-Column (host): Phase II on top of CCS (paper: "easy" given CCS)
# ---------------------------------------------------------------------------
@_traced("coo_col")
def host_csr_to_coo_col(m: CSR) -> COO:
    ccs = host_csr_to_ccs(m)
    ip = np.asarray(ccs.indptr)
    lens = ip[1:] - ip[:-1]
    cols = np.repeat(np.arange(m.n_cols, dtype=np.int32), lens)
    return COO(data=np.asarray(ccs.data).copy(),
               rows=np.asarray(ccs.rows).copy(),
               cols=_pad1(cols, m.nnz_pad),
               shape=m.shape, nnz=m.nnz, order="col")


# ---------------------------------------------------------------------------
# CRS -> ELL (host)
# ---------------------------------------------------------------------------
@_traced("ell")
def host_csr_to_ell(m: CSR, order: str = "row",
                    width: Optional[int] = None) -> ELL:
    ip = np.asarray(m.indptr)
    lens = ip[1:] - ip[:-1]
    w = int(width if width is not None else (lens.max() if len(lens) else 0))
    w = max(w, 1)
    n = m.n_rows
    data = np.zeros((n, w), dtype=np.asarray(m.data).dtype)
    cols = np.zeros((n, w), dtype=np.int32)
    # gather positions: pos[r, k] = indptr[r] + k, valid where k < len(r)
    pos = ip[:-1, None] + np.arange(w)[None, :]
    valid = np.arange(w)[None, :] < lens[:, None]
    src_d = np.asarray(m.data)
    src_c = np.asarray(m.cols)
    np.copyto(data, src_d[np.clip(pos, 0, m.nnz_pad - 1)], where=valid)
    np.copyto(cols, src_c[np.clip(pos, 0, m.nnz_pad - 1)], where=valid)
    if not valid.all():
        data[~valid] = 0
        cols[~valid] = 0
    if order == "col":
        data, cols = np.ascontiguousarray(data.T), np.ascontiguousarray(cols.T)
    nnz_kept = int(np.minimum(lens, w).sum())
    return ELL(data=data, cols=cols, shape=m.shape, nnz=nnz_kept, order=order)


# ---------------------------------------------------------------------------
# CRS -> BucketedELL (beyond paper; SELL-C-sigma TPU adaptation)
# ---------------------------------------------------------------------------
@_traced("sell")
def host_csr_to_sell(m: CSR, slice_rows: int = 128,
                     width_quantum: int = 8) -> BucketedELL:
    """Sort rows by length, group into slices of ``slice_rows`` rows, round
    each slice's width up to ``width_quantum`` and merge equal-width
    neighboring slices into buckets.  Each bucket is a dense ELL block."""
    ip = np.asarray(m.indptr)
    lens = ip[1:] - ip[:-1]
    n = m.n_rows
    perm = np.argsort(-lens, kind="stable").astype(np.int32)  # longest first
    sorted_lens = lens[perm]
    src_d, src_c = np.asarray(m.data), np.asarray(m.cols)

    # slice boundaries -> per-slice rounded widths -> merge equal-width runs
    starts = list(range(0, n, slice_rows))
    widths = [pad_to_multiple(max(int(sorted_lens[s:min(s + slice_rows, n)].max()), 1),
                              width_quantum) for s in starts]
    merged: list = []  # (start, end, w)
    for s, w in zip(starts, widths):
        e = min(s + slice_rows, n)
        if merged and merged[-1][2] == w:
            merged[-1] = (merged[-1][0], e, w)
        else:
            merged.append((s, e, w))

    buckets = []
    offsets = []
    for start, end, w in merged:
        rows_here = perm[start:end]
        b_n = end - start
        data = np.zeros((b_n, w), dtype=src_d.dtype)
        cols = np.zeros((b_n, w), dtype=np.int32)
        pos = ip[rows_here][:, None] + np.arange(w)[None, :]
        valid = np.arange(w)[None, :] < lens[rows_here][:, None]
        np.copyto(data, src_d[np.clip(pos, 0, m.nnz_pad - 1)], where=valid)
        np.copyto(cols, src_c[np.clip(pos, 0, m.nnz_pad - 1)], where=valid)
        nnz_b = int(valid.sum())
        buckets.append(ELL(data=data, cols=cols, shape=(b_n, m.n_cols),
                           nnz=nnz_b, order="row"))
        offsets.append(start)
    return BucketedELL(perm=perm, buckets=tuple(buckets),
                       row_offsets=tuple(offsets), shape=m.shape, nnz=m.nnz)


# ---------------------------------------------------------------------------
# device transformations (pure jnp; static widths / pads)
# ---------------------------------------------------------------------------
def device_csr_to_ell(m: CSR, width: int, order: str = "row") -> ELL:
    """jit-able CRS->ELL.  ``width`` must be a static (host-known) bound —
    available at call time from MatrixStats, per the paper's run-time model."""
    ip = jnp.asarray(m.indptr)
    lens = ip[1:] - ip[:-1]
    pos = ip[:-1, None] + jnp.arange(width, dtype=ip.dtype)[None, :]
    valid = jnp.arange(width)[None, :] < lens[:, None]
    posc = jnp.clip(pos, 0, m.nnz_pad - 1)
    data = jnp.where(valid, jnp.asarray(m.data)[posc], 0)
    cols = jnp.where(valid, jnp.asarray(m.cols)[posc], 0)
    if order == "col":
        data, cols = data.T, cols.T
    return ELL(data=data, cols=cols, shape=m.shape, nnz=m.nnz, order=order)


def device_csr_to_coo_row(m: CSR) -> COO:
    """jit-able CRS->COO-Row: row ids by binary search over IRP."""
    ip = jnp.asarray(m.indptr)
    k = jnp.arange(m.nnz_pad, dtype=ip.dtype)
    rows = jnp.searchsorted(ip, k, side="right") - 1
    rows = jnp.where(k < m.nnz, rows, 0).astype(jnp.int32)
    return COO(data=jnp.asarray(m.data), rows=rows,
               cols=jnp.asarray(m.cols), shape=m.shape, nnz=m.nnz,
               order="row")


def device_csr_to_coo_col(m: CSR) -> COO:
    """jit-able CRS->COO-Column: sentinel-keyed stable sort = counting sort.

    Padded entries get key n_cols so they stay at the tail, preserving the
    padding invariant."""
    coo = device_csr_to_coo_row(m)
    k = jnp.arange(m.nnz_pad)
    key = jnp.where(k < m.nnz, jnp.asarray(coo.cols), m.n_cols)
    order = jnp.argsort(key, stable=True)
    return COO(data=coo.data[order], rows=coo.rows[order],
               cols=jnp.where(k < m.nnz, coo.cols[order], 0),
               shape=m.shape, nnz=m.nnz, order="col")


def device_csr_to_ccs(m: CSR) -> CCS:
    """jit-able Phase-I (CRS->CCS), the paper's bottleneck transformation."""
    coo = device_csr_to_coo_col(m)
    counts = jnp.zeros(m.n_cols, jnp.int32).at[jnp.asarray(m.cols)].add(
        (jnp.arange(m.nnz_pad) < m.nnz).astype(jnp.int32))
    indptr = jnp.concatenate([jnp.zeros(1, jnp.int32),
                              jnp.cumsum(counts).astype(jnp.int32)])
    return CCS(data=coo.data, rows=coo.rows, indptr=indptr,
               shape=m.shape, nnz=m.nnz)


@_traced("hybrid")
def _host_csr_to_hybrid(m: CSR, **kw):
    # lazy import: repro.partition imports this module at load time
    from repro.partition import host_csr_to_hybrid
    return host_csr_to_hybrid(m, **kw)


TRANSFORMS_HOST = {
    "bcsr": lambda m: host_csr_to_bcsr(m),
    "hybrid": _host_csr_to_hybrid,
    "ccs": host_csr_to_ccs,
    "coo_row": host_csr_to_coo_row,
    "coo_col": host_csr_to_coo_col,
    "ell_row": lambda m: host_csr_to_ell(m, order="row"),
    "ell_col": lambda m: host_csr_to_ell(m, order="col"),
    "sell": host_csr_to_sell,
    "csr": lambda m: m,
}

__all__ = [
    "pad_to_multiple", "csr_from_dense", "csr_from_rows",
    "csr_append_rows", "csr_set_values", "csr_splice",
    "host_csr_to_coo_row", "host_csr_to_ccs_paper", "host_csr_to_ccs",
    "host_csr_to_coo_col", "host_csr_to_ell", "host_csr_to_sell",
    "device_csr_to_ell", "device_csr_to_coo_row", "device_csr_to_coo_col",
    "device_csr_to_ccs", "host_csr_to_bcsr", "TRANSFORMS_HOST",
]


# ---------------------------------------------------------------------------
# CRS -> BCSR (paper's named future work; see formats.BCSR)
# ---------------------------------------------------------------------------
@_traced("bcsr")
def host_csr_to_bcsr(m: CSR, block: int = 8) -> "BCSR":
    """Group nonzeros into b x b dense blocks (CSR order over block rows)."""
    from .formats import BCSR
    b = block
    n_rows, n_cols = m.shape
    nbr = (n_rows + b - 1) // b
    ip = np.asarray(m.indptr)
    cols = np.asarray(m.cols)[: m.nnz]
    data = np.asarray(m.data)[: m.nnz]
    rows = np.repeat(np.arange(n_rows, dtype=np.int64), ip[1:] - ip[:-1])
    br, bc = rows // b, cols // b
    key = br * ((n_cols + b - 1) // b) + bc
    order = np.argsort(key, kind="stable")
    key_s = key[order]
    uniq, starts = np.unique(key_s, return_index=True)
    nblocks = len(uniq)
    blocks = np.zeros((max(nblocks, 1), b, b), dtype=data.dtype)
    block_cols = np.zeros(max(nblocks, 1), dtype=np.int32)
    indptr = np.zeros(nbr + 1, dtype=np.int32)
    ends = np.append(starts[1:], len(key_s))
    nbc = (n_cols + b - 1) // b
    for bi, (u, s0, s1) in enumerate(zip(uniq, starts, ends)):
        sel = order[s0:s1]
        np.add.at(blocks[bi], (rows[sel] % b, cols[sel] % b), data[sel])
        block_cols[bi] = u % nbc
        indptr[u // nbc + 1] += 1
    indptr = np.cumsum(indptr).astype(np.int32)
    return BCSR(data=blocks, block_cols=block_cols, indptr=indptr,
                shape=m.shape, nnz=m.nnz, block=b)
