"""Reference SpMV / SpMM per format — pure jnp, jit-able.

These are the *semantic oracles* for the Pallas kernels in
``repro.kernels`` and the measurable implementations the auto-tuner times.

Parallelization mapping (paper §3 -> TPU):
  * COO outer-loop + per-thread YY reduction  -> ``segment_sum`` (XLA builds
    the reduction tree; ``indices_are_sorted`` encodes row- vs col-order).
  * ELL-Row inner/outer parallelization       -> a single gather + row
    reduction; XLA/GSPMD parallelizes rows (outer) and the mesh can shard
    the band axis (inner) — both of the paper's schedules fall out of one
    expression with different sharding constraints.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .formats import BucketedELL, CCS, COO, CSR, ELL


# ---------------------------------------------------------------------------
# CSR (paper's CRS baseline)
# ---------------------------------------------------------------------------
def spmv_csr(m: CSR, x: jax.Array) -> jax.Array:
    """y = A @ x with A in CSR.  Row ids via binary search (static nnz_pad)."""
    ip = jnp.asarray(m.indptr)
    k = jnp.arange(m.nnz_pad)
    rows = jnp.searchsorted(ip, k, side="right") - 1
    rows = jnp.clip(rows, 0, m.n_rows - 1)
    contrib = jnp.asarray(m.data) * x[jnp.asarray(m.cols)]
    return jax.ops.segment_sum(contrib, rows, num_segments=m.n_rows,
                               indices_are_sorted=True)


def spmm_csr(m: CSR, x: jax.Array) -> jax.Array:
    """Multi-vector right-hand side: x (n_cols, k) -> (n_rows, k)."""
    ip = jnp.asarray(m.indptr)
    kk = jnp.arange(m.nnz_pad)
    rows = jnp.clip(jnp.searchsorted(ip, kk, side="right") - 1, 0, m.n_rows - 1)
    contrib = jnp.asarray(m.data)[:, None] * x[jnp.asarray(m.cols)]
    return jax.ops.segment_sum(contrib, rows, num_segments=m.n_rows,
                               indices_are_sorted=True)


# ---------------------------------------------------------------------------
# COO (row- or column-ordered; order only affects reduction hints)
# ---------------------------------------------------------------------------
def spmv_coo(m: COO, x: jax.Array) -> jax.Array:
    contrib = jnp.asarray(m.data) * x[jnp.asarray(m.cols)]
    return jax.ops.segment_sum(contrib, jnp.asarray(m.rows),
                               num_segments=m.n_rows,
                               indices_are_sorted=(m.order == "row"))


# ---------------------------------------------------------------------------
# CCS — column-major scatter (paper's Phase-I product)
# ---------------------------------------------------------------------------
def spmv_ccs(m: CCS, x: jax.Array) -> jax.Array:
    ip = jnp.asarray(m.indptr)
    k = jnp.arange(m.nnz_pad)
    cols = jnp.clip(jnp.searchsorted(ip, k, side="right") - 1, 0, m.n_cols - 1)
    contrib = jnp.asarray(m.data) * x[cols]
    return jnp.zeros(m.n_rows, x.dtype).at[jnp.asarray(m.rows)].add(contrib)


# ---------------------------------------------------------------------------
# ELL — the vector-friendly format (paper's ES2 hero, TPU hero here)
# ---------------------------------------------------------------------------
def spmv_ell(m: ELL, x: jax.Array) -> jax.Array:
    data, cols = jnp.asarray(m.data), jnp.asarray(m.cols)
    if m.order == "col":
        data, cols = data.T, cols.T
    return (data * x[cols]).sum(axis=1)


def spmm_ell(m: ELL, x: jax.Array) -> jax.Array:
    data, cols = jnp.asarray(m.data), jnp.asarray(m.cols)
    if m.order == "col":
        data, cols = data.T, cols.T
    # (rows, width, k) contract width
    return jnp.einsum("rw,rwk->rk", data, x[cols])


# ---------------------------------------------------------------------------
# BucketedELL (SELL-C-sigma adaptation)
# ---------------------------------------------------------------------------
def spmv_sell(m: BucketedELL, x: jax.Array) -> jax.Array:
    y = jnp.zeros(m.n_rows, x.dtype)
    perm = jnp.asarray(m.perm)
    for off, b in zip(m.row_offsets, m.buckets):
        yb = spmv_ell(b, x)
        y = y.at[perm[off:off + b.n_rows]].set(yb)
    return y


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------
def spmv(m, x: jax.Array) -> jax.Array:
    from .formats import BCSR
    from repro.partition import HybridMatrix, spmv_hybrid  # lazy: no cycle
    if isinstance(m, HybridMatrix):
        return spmv_hybrid(m, x)
    if isinstance(m, BCSR):
        return spmv_bcsr(m, x)
    if isinstance(m, CSR):
        return spmv_csr(m, x)
    if isinstance(m, COO):
        return spmv_coo(m, x)
    if isinstance(m, CCS):
        return spmv_ccs(m, x)
    if isinstance(m, ELL):
        return spmv_ell(m, x)
    if isinstance(m, BucketedELL):
        return spmv_sell(m, x)
    raise TypeError(f"unknown sparse format: {type(m)}")


def spmv_dense(dense: jax.Array, x: jax.Array) -> jax.Array:
    return dense @ x


__all__ = ["spmv", "spmv_csr", "spmm_csr", "spmv_coo", "spmv_ccs",
           "spmv_ell", "spmm_ell", "spmv_sell", "spmv_dense"]


def spmv_bcsr(m, x: jax.Array) -> jax.Array:
    """y = A @ x, A in BCSR: a stream of b x b dense block matvecs —
    gathered x block-slices times block tiles, segment-summed per block
    row (the MXU-tile form of the paper's anticipated cache blocking)."""
    from .formats import BCSR
    assert isinstance(m, BCSR)
    b = m.block
    nbr = m.n_block_rows
    ip = jnp.asarray(m.indptr)
    k = jnp.arange(m.nblocks_pad)
    brow = jnp.clip(jnp.searchsorted(ip, k, side="right") - 1, 0, nbr - 1)
    ncb = (m.n_cols + b - 1) // b
    x_pad = jnp.pad(x, (0, ncb * b - m.n_cols))
    x_blocks = x_pad.reshape(ncb, b)[jnp.asarray(m.block_cols)]  # (nb, b)
    contrib = jnp.einsum("kij,kj->ki", jnp.asarray(m.data), x_blocks)
    y = jax.ops.segment_sum(contrib, brow, num_segments=nbr,
                            indices_are_sorted=True)             # (nbr, b)
    return y.reshape(nbr * b)[: m.n_rows]
