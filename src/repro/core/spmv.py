"""Reference SpMV / SpMM per format — pure jnp, jit-able.

These are the *semantic oracles* for the Pallas kernels in
``repro.kernels`` and the measurable implementations the auto-tuner times.
Every (format, op) pair defined here is registered in
``repro.core.dispatch`` — the single dispatch source of truth.

Parallelization mapping (paper §3 -> TPU):
  * COO outer-loop + per-thread YY reduction  -> ``segment_sum`` (XLA builds
    the reduction tree; ``indices_are_sorted`` encodes row- vs col-order).
  * ELL-Row inner/outer parallelization       -> a single gather + row
    reduction; XLA/GSPMD parallelizes rows (outer) and the mesh can shard
    the band axis (inner) — both of the paper's schedules fall out of one
    expression with different sharding constraints.

SpMM convention: ``x`` is a column panel ``(n_cols, B)`` and the result is
``(n_rows, B)`` — one transformed matrix amortized over ``k * B`` products
(the batch-parallel strengthening of the paper's ``k (t_crs - t_f) >
t_trans`` rule).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import dispatch as _dispatch
from .formats import BCSR, BucketedELL, CCS, COO, CSR, ELL


# ---------------------------------------------------------------------------
# CSR (paper's CRS baseline)
# ---------------------------------------------------------------------------
def _csr_expanded_rows(m: CSR) -> jax.Array:
    ip = jnp.asarray(m.indptr)
    k = jnp.arange(m.nnz_pad)
    rows = jnp.searchsorted(ip, k, side="right") - 1
    return jnp.clip(rows, 0, m.n_rows - 1)


def spmv_csr(m: CSR, x: jax.Array) -> jax.Array:
    """y = A @ x with A in CSR.  Row ids via binary search (static nnz_pad)."""
    rows = _csr_expanded_rows(m)
    contrib = jnp.asarray(m.data) * x[jnp.asarray(m.cols)]
    return jax.ops.segment_sum(contrib, rows, num_segments=m.n_rows,
                               indices_are_sorted=True)


def spmm_csr(m: CSR, x: jax.Array) -> jax.Array:
    """Multi-vector right-hand side: x (n_cols, k) -> (n_rows, k)."""
    rows = _csr_expanded_rows(m)
    contrib = jnp.asarray(m.data)[:, None] * x[jnp.asarray(m.cols)]
    return jax.ops.segment_sum(contrib, rows, num_segments=m.n_rows,
                               indices_are_sorted=True)


# ---------------------------------------------------------------------------
# COO (row- or column-ordered; order only affects reduction hints)
# ---------------------------------------------------------------------------
def spmv_coo(m: COO, x: jax.Array) -> jax.Array:
    contrib = jnp.asarray(m.data) * x[jnp.asarray(m.cols)]
    return jax.ops.segment_sum(contrib, jnp.asarray(m.rows),
                               num_segments=m.n_rows,
                               indices_are_sorted=(m.order == "row"))


def spmm_coo(m: COO, x: jax.Array) -> jax.Array:
    contrib = jnp.asarray(m.data)[:, None] * x[jnp.asarray(m.cols)]
    return jax.ops.segment_sum(contrib, jnp.asarray(m.rows),
                               num_segments=m.n_rows,
                               indices_are_sorted=(m.order == "row"))


# ---------------------------------------------------------------------------
# CCS — column-major scatter (paper's Phase-I product)
# ---------------------------------------------------------------------------
def _ccs_expanded_cols(m: CCS) -> jax.Array:
    ip = jnp.asarray(m.indptr)
    k = jnp.arange(m.nnz_pad)
    return jnp.clip(jnp.searchsorted(ip, k, side="right") - 1, 0, m.n_cols - 1)


def spmv_ccs(m: CCS, x: jax.Array) -> jax.Array:
    contrib = jnp.asarray(m.data) * x[_ccs_expanded_cols(m)]
    return jnp.zeros(m.n_rows, x.dtype).at[jnp.asarray(m.rows)].add(contrib)


def spmm_ccs(m: CCS, x: jax.Array) -> jax.Array:
    contrib = jnp.asarray(m.data)[:, None] * x[_ccs_expanded_cols(m)]
    return jnp.zeros((m.n_rows, x.shape[1]),
                     x.dtype).at[jnp.asarray(m.rows)].add(contrib)


# ---------------------------------------------------------------------------
# ELL — the vector-friendly format (paper's ES2 hero, TPU hero here)
# ---------------------------------------------------------------------------
def spmv_ell(m: ELL, x: jax.Array) -> jax.Array:
    data, cols = jnp.asarray(m.data), jnp.asarray(m.cols)
    if m.order == "col":
        data, cols = data.T, cols.T
    return (data * x[cols]).sum(axis=1)


def spmm_ell(m: ELL, x: jax.Array) -> jax.Array:
    data, cols = jnp.asarray(m.data), jnp.asarray(m.cols)
    if m.order == "col":
        data, cols = data.T, cols.T
    # (rows, width, k) contract width
    return jnp.einsum("rw,rwk->rk", data, x[cols])


# ---------------------------------------------------------------------------
# BucketedELL (SELL-C-sigma adaptation)
# ---------------------------------------------------------------------------
def spmv_sell(m: BucketedELL, x: jax.Array) -> jax.Array:
    # an all-zero matrix may carry an empty bucket list: the product is
    # exactly zeros of (n_rows,) in x's dtype, not an error
    y = jnp.zeros(m.n_rows, x.dtype)
    perm = jnp.asarray(m.perm)
    for off, b in zip(m.row_offsets, m.buckets):
        yb = spmv_ell(b, x)
        y = y.at[perm[off:off + b.n_rows]].set(yb.astype(y.dtype))
    return y


def spmm_sell(m: BucketedELL, x: jax.Array) -> jax.Array:
    y = jnp.zeros((m.n_rows, x.shape[1]), x.dtype)
    perm = jnp.asarray(m.perm)
    for off, b in zip(m.row_offsets, m.buckets):
        yb = spmm_ell(b, x)
        y = y.at[perm[off:off + b.n_rows]].set(yb.astype(y.dtype))
    return y


# ---------------------------------------------------------------------------
# BCSR — b x b dense block matvecs (MXU-tile form of cache blocking)
# ---------------------------------------------------------------------------
def _bcsr_gather(m: BCSR, x: jax.Array):
    b = m.block
    nbr = m.n_block_rows
    ip = jnp.asarray(m.indptr)
    k = jnp.arange(m.nblocks_pad)
    brow = jnp.clip(jnp.searchsorted(ip, k, side="right") - 1, 0, nbr - 1)
    ncb = (m.n_cols + b - 1) // b
    pads = [(0, ncb * b - m.n_cols)] + [(0, 0)] * (x.ndim - 1)
    x_pad = jnp.pad(x, pads)
    x_blocks = x_pad.reshape((ncb, b) + x.shape[1:])[jnp.asarray(m.block_cols)]
    return brow, x_blocks


def spmv_bcsr(m: BCSR, x: jax.Array) -> jax.Array:
    """y = A @ x, A in BCSR: a stream of b x b dense block matvecs —
    gathered x block-slices times block tiles, segment-summed per block
    row (the MXU-tile form of the paper's anticipated cache blocking)."""
    brow, x_blocks = _bcsr_gather(m, x)                       # (nb, b)
    contrib = jnp.einsum("kij,kj->ki", jnp.asarray(m.data), x_blocks)
    y = jax.ops.segment_sum(contrib, brow, num_segments=m.n_block_rows,
                            indices_are_sorted=True)          # (nbr, b)
    return y.reshape(m.n_block_rows * m.block)[: m.n_rows]


def spmm_bcsr(m: BCSR, x: jax.Array) -> jax.Array:
    brow, x_blocks = _bcsr_gather(m, x)                       # (nb, b, k)
    contrib = jnp.einsum("kij,kjc->kic", jnp.asarray(m.data), x_blocks)
    y = jax.ops.segment_sum(contrib, brow, num_segments=m.n_block_rows,
                            indices_are_sorted=True)          # (nbr, b, k)
    return y.reshape(m.n_block_rows * m.block, x.shape[1])[: m.n_rows]


# ---------------------------------------------------------------------------
# dispatch — resolved through the core/dispatch registry
# ---------------------------------------------------------------------------
def spmv(m, x: jax.Array) -> jax.Array:
    """y = A @ x for any registered sparse format."""
    return _dispatch.dispatch(m, x, op="spmv")


def spmm(m, x: jax.Array) -> jax.Array:
    """Y = A @ X, X (n_cols, B), for any registered sparse format."""
    return _dispatch.spmm(m, x)


def spmv_dense(dense: jax.Array, x: jax.Array) -> jax.Array:
    return dense @ x


# ---------------------------------------------------------------------------
# registration: formats (predicate-narrowed where one class serves two
# names) and the reference-tier implementations defined above.  The hybrid
# container registers itself in repro/partition/hybrid.py.
# ---------------------------------------------------------------------------
_dispatch.register_format("csr", CSR)
_dispatch.register_format("ccs", CCS)
_dispatch.register_format("coo_col", COO, lambda m: m.order == "col")
_dispatch.register_format("coo_row", COO)
_dispatch.register_format("ell_col", ELL, lambda m: m.order == "col")
_dispatch.register_format("ell_row", ELL)
_dispatch.register_format("sell", BucketedELL)
_dispatch.register_format("bcsr", BCSR)

for _fmt, _spmv_fn, _spmm_fn in (
    ("csr", spmv_csr, spmm_csr),
    ("coo_row", spmv_coo, spmm_coo),
    ("coo_col", spmv_coo, spmm_coo),
    ("ccs", spmv_ccs, spmm_ccs),
    ("ell_row", spmv_ell, spmm_ell),
    ("ell_col", spmv_ell, spmm_ell),
    ("sell", spmv_sell, spmm_sell),
    ("bcsr", spmv_bcsr, spmm_bcsr),
):
    _dispatch.register_impl(_fmt, "spmv", _spmv_fn)
    _dispatch.register_impl(_fmt, "spmm", _spmm_fn)


__all__ = ["spmv", "spmm", "spmv_csr", "spmm_csr", "spmv_coo", "spmm_coo",
           "spmv_ccs", "spmm_ccs", "spmv_ell", "spmm_ell", "spmv_sell",
           "spmm_sell", "spmv_bcsr", "spmm_bcsr", "spmv_dense"]
