"""Crash-safe persistent plan store: tune once per fleet, not per replica.

A fleet of serving replicas all paying the tuner's search for the same
matrix is the paper's amortization rule applied at the wrong granularity —
``t_trans`` (and the launch-geometry sweep) should be paid once per
*matrix structure per machine class*, then shared.  :class:`PlanStore` is
that shared layer: a fingerprint-keyed on-disk directory of serialized
:class:`~repro.core.plan.ExecutionPlan` / ``ShardedPlan`` artifacts that
any number of processes read and write concurrently.

Durability contract (what "crash-safe" means here):

* **Atomic writes** — entries are written to a same-directory temp file
  and published with ``os.replace``; a reader never observes a torn or
  partial JSON, and two racing writers leave one intact winner.
* **Checksummed payloads** — each entry is an envelope carrying the
  sha256 of its canonical payload JSON; a flipped bit anywhere fails
  verification on load.
* **Quarantine, never raise** — a corrupted, truncated, checksum-failing,
  or schema-incompatible entry is moved to a ``.bad/`` subdirectory (with
  a reason suffix) and reported through ``repro.obs``; ``get`` returns
  ``None`` and the caller re-tunes.  A broken store entry can cost one
  re-tune; it must never take a replica down.
* **Bounded growth** — with ``max_entries`` set, every ``put`` finishes
  with an LRU sweep (recency = file mtime, refreshed on every hit) that
  unlinks the coldest entries down to the cap and counts them under
  ``store.evict``.  Unbounded by default: a shared fleet store is usually
  curated by capacity, not time.

On-disk layout (see ``docs/robustness.md``)::

    <root>/
      <key>.json          # envelope: {store_version, sha256, plan}
      .bad/
        <key>.json.<reason>.<n>   # quarantined entries, kept for forensics

``key`` is a sha256 hex digest over the matrix fingerprint plus the
registration knobs (batch, expected_iterations, strategy, build kwargs) —
the same identity the in-process plan cache uses, made process-portable.

The ``store.corrupt`` fault point (:mod:`repro.serve.faults`) scribbles
over an entry right after :meth:`PlanStore.put` publishes it, so the
checksum/quarantine path is exercised end-to-end in CI.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
import threading
from typing import Any, Dict, Optional, Tuple

import repro.obs as _obs

STORE_VERSION = 1

#: quarantine subdirectory name
BAD_DIR = ".bad"


def _canonical(payload: Dict[str, Any]) -> str:
    """The byte-stable JSON the checksum covers."""
    return json.dumps(payload, sort_keys=True, separators=(",", ":"))


def _sha256(s: str) -> str:
    return hashlib.sha256(s.encode("utf-8")).hexdigest()


def fingerprint_key(fingerprint: Any, **knobs: Any) -> str:
    """Deterministic store key: sha256 over the matrix's structural
    fingerprint (n, nnz, indptr CRC) and the registration knobs.  ``repr``
    of plain values is stable across processes; callers should pass only
    ints/floats/strings/tuples."""
    fp = {"n": int(getattr(fingerprint, "n", 0)),
          "nnz": int(getattr(fingerprint, "nnz", 0)),
          "sig": int(getattr(fingerprint, "sig", 0))}
    body = _canonical({"fp": fp, "knobs": {k: repr(v) for k, v in
                                           sorted(knobs.items())}})
    return _sha256(body)


class PlanStore:
    """Fingerprint-keyed on-disk plan store shared across processes.

    >>> store = PlanStore("/var/lib/repro/plans")
    >>> key = store.key_for(csr, batch=8)
    >>> plan = store.get(key)            # None on miss/corruption
    >>> if plan is None:
    ...     plan = planner.plan(csr, batch=8)
    ...     store.put(key, plan)

    ``SpMVService(plan_store=...)`` does exactly this around every
    registration; :meth:`Planner.plan_or_load` does it for direct
    planning.
    """

    def __init__(self, root: str, create: bool = True,
                 max_entries: Optional[int] = None):
        if max_entries is not None and int(max_entries) < 1:
            raise ValueError(
                f"max_entries must be >= 1 or None; got {max_entries}")
        self.root = str(root)
        self.max_entries = None if max_entries is None else int(max_entries)
        if create:
            os.makedirs(self.root, exist_ok=True)
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.writes = 0
        self.quarantined = 0
        self.evictions = 0

    # -- keys + paths --------------------------------------------------------
    def key_for(self, csr_or_fp: Any, **knobs: Any) -> str:
        """Store key for a matrix (or a prebuilt fingerprint) under the
        given registration knobs."""
        from repro.core.plan import PlanFingerprint
        fp = (csr_or_fp if isinstance(csr_or_fp, PlanFingerprint)
              else PlanFingerprint.of(csr_or_fp))
        return fingerprint_key(fp, **knobs)

    def path_for(self, key: str) -> str:
        safe = "".join(c for c in key if c.isalnum() or c in "-_.")
        if not safe:
            raise ValueError(f"unusable store key {key!r}")
        return os.path.join(self.root, safe + ".json")

    def keys(self) -> Tuple[str, ...]:
        try:
            names = os.listdir(self.root)
        except OSError:
            return ()
        return tuple(sorted(n[:-5] for n in names if n.endswith(".json")))

    def __len__(self) -> int:
        return len(self.keys())

    # -- write ---------------------------------------------------------------
    def put(self, key: str, plan: Any) -> str:
        """Serialize ``plan`` under ``key`` atomically; returns the final
        path.  Concurrent writers are safe: each writes its own temp file
        and the last ``os.replace`` wins whole."""
        payload = plan.to_dict()
        envelope = {"store_version": STORE_VERSION,
                    "sha256": _sha256(_canonical(payload)),
                    "plan": payload}
        path = self.path_for(key)
        fd, tmp = tempfile.mkstemp(prefix=".tmp-", suffix=".json",
                                   dir=self.root)
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(envelope, f, indent=1, allow_nan=False)
                f.write("\n")
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)      # atomic publish
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        with self._lock:
            self.writes += 1
        tel = _obs.get()
        if tel.enabled:
            tel.counter("store.write").inc()
            tel.event("store.write", key=key, path=path)
        # deterministic corruption hook: scribble over the entry we just
        # published so the *next* reader exercises checksum + quarantine
        from repro.serve import faults as _faults
        if _faults.should_fire("store.corrupt"):
            with open(path, "r+") as f:
                f.seek(0)
                f.write('{"store_version": 1, "sha256": "corrupted')
        if self.max_entries is not None:
            self._evict(keep=path)
        return path

    def _evict(self, keep: Optional[str] = None) -> int:
        """LRU-by-mtime sweep down to ``max_entries``: hits refresh an
        entry's mtime, so the entries deleted first are the ones no
        replica has read or written recently.  ``keep`` (the just-written
        path) is never evicted even if a clock oddity makes it look old.
        Unlinked, not quarantined — eviction is capacity policy, not
        corruption forensics.  Returns the number of entries removed."""
        try:
            names = [n for n in os.listdir(self.root) if n.endswith(".json")]
        except OSError:
            return 0
        aged = []
        for n in names:
            p = os.path.join(self.root, n)
            try:
                aged.append((os.path.getmtime(p), p))
            except OSError:
                continue                   # raced a concurrent evictor
        excess = len(aged) - self.max_entries
        if excess <= 0:
            return 0
        tel = _obs.get()
        removed = 0
        for _, p in sorted(aged):
            if removed >= excess:
                break
            if p == keep:
                continue
            try:
                os.unlink(p)
            except OSError:
                continue                   # another writer won the race
            removed += 1
            if tel.enabled:
                tel.counter("store.evict").inc()
                tel.event("store.evict", path=p)
        with self._lock:
            self.evictions += removed
        return removed

    # -- read ----------------------------------------------------------------
    def get(self, key: str, fingerprint: Any = None) -> Optional[Any]:
        """Load and verify the entry under ``key``.  Returns the plan, or
        ``None`` when the key is absent **or** the entry is unusable —
        unusable entries are quarantined, never raised.  With a
        ``fingerprint`` the loaded plan must structurally match it (a
        stale entry for a different matrix is treated as a miss, not
        quarantined — it may be valid for its own matrix)."""
        path = self.path_for(key)
        tel = _obs.get()
        try:
            with open(path) as f:
                raw = f.read()
        except FileNotFoundError:
            with self._lock:
                self.misses += 1
            if tel.enabled:
                tel.counter("store.miss").inc()
            return None
        except OSError as e:
            with self._lock:
                self.misses += 1
            if tel.enabled:
                tel.counter("store.miss").inc()
                tel.event("store.read_error", key=key, error=repr(e))
            return None

        plan = self._verify(key, path, raw)
        if plan is None:
            with self._lock:
                self.misses += 1
            if tel.enabled:
                tel.counter("store.miss").inc()
            return None
        if fingerprint is not None:
            fp = getattr(plan, "fingerprint", None)
            if fp is None or not fp.matches(fingerprint):
                with self._lock:
                    self.misses += 1
                if tel.enabled:
                    tel.counter("store.miss").inc()
                    tel.event("store.stale", key=key)
                return None
        with self._lock:
            self.hits += 1
        if tel.enabled:
            tel.counter("store.hit").inc()
        try:
            os.utime(path)       # refresh recency for the LRU evictor
        except OSError:
            pass                 # evicted/quarantined between read and touch
        return plan

    def _verify(self, key: str, path: str, raw: str) -> Optional[Any]:
        """Envelope → checksum → schema; any failure quarantines."""
        from repro.core.plan import (ExecutionPlan, PlanError, ShardedPlan)
        try:
            env = json.loads(raw)
        except json.JSONDecodeError:
            return self._quarantine(key, path, "not_json")
        if not isinstance(env, dict) or "plan" not in env \
                or "sha256" not in env:
            return self._quarantine(key, path, "bad_envelope")
        if int(env.get("store_version", -1)) != STORE_VERSION:
            return self._quarantine(key, path, "store_version")
        payload = env["plan"]
        if not isinstance(payload, dict):
            return self._quarantine(key, path, "bad_payload")
        if _sha256(_canonical(payload)) != env["sha256"]:
            return self._quarantine(key, path, "checksum")
        try:
            if payload.get("kind") == "sharded_plan":
                plan = ShardedPlan.from_dict(payload)
            else:
                plan = ExecutionPlan.from_dict(payload)
        except PlanError:
            # PlanSchemaError included: written by a different plan
            # schema — stale, not servable by this build
            return self._quarantine(key, path, "schema")
        # schema-valid but semantically infeasible (misaligned geometry,
        # broken partition, over-budget tile): the static plan lint —
        # jax-free, so a store sweep never pays a backend import
        from repro.analyze.planlint import lint_plan as _lint_plan
        if any(f.severity == "error" for f in _lint_plan(payload)):
            return self._quarantine(key, path, "lint")
        return plan

    def _quarantine(self, key: str, path: str, reason: str) -> None:
        """Move a bad entry aside (never delete — forensics) and report.
        Racing quarantines of the same file are tolerated."""
        bad_dir = os.path.join(self.root, BAD_DIR)
        try:
            os.makedirs(bad_dir, exist_ok=True)
            base = os.path.basename(path) + "." + reason
            dest = os.path.join(bad_dir, base)
            n = 0
            while os.path.exists(dest):
                n += 1
                dest = os.path.join(bad_dir, f"{base}.{n}")
            os.replace(path, dest)
        except OSError:
            dest = None                # raced another quarantine; fine
        with self._lock:
            self.quarantined += 1
        tel = _obs.get()
        if tel.enabled:
            tel.counter("store.quarantine", reason=reason).inc()
            tel.event("store.quarantine", key=key, reason=reason,
                      moved_to=dest)
        return None

    # -- observability -------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        with self._lock:
            return {"root": self.root, "entries": len(self),
                    "hits": self.hits, "misses": self.misses,
                    "writes": self.writes,
                    "quarantined": self.quarantined,
                    "evictions": self.evictions,
                    "max_entries": self.max_entries}

    def __repr__(self) -> str:
        return (f"PlanStore(root={self.root!r}, entries={len(self)}, "
                f"hits={self.hits}, misses={self.misses})")


__all__ = ["STORE_VERSION", "BAD_DIR", "PlanStore", "fingerprint_key"]
