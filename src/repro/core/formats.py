"""Sparse-matrix storage formats as JAX pytrees.

The paper (Katagiri & Sato) studies run-time transformation between CRS
(a.k.a. CSR), COO (row- and column-ordered) and ELL.  We represent each
format as a registered-dataclass pytree whose array leaves may be numpy
(host) or jax.Array (device), with all *structural* metadata (shape, true
nnz, storage order, pad width) static so the objects cross ``jit``
boundaries with static shapes — the TPU adaptation of the paper's
call-time transformation model (§2 of DESIGN.md).

Padding conventions (needed because XLA requires static shapes):
  * CSR/COO: nnz padded up to ``pad_to`` with (row=0, col=0, val=0) entries —
    harmless for SpMV since the value is zero.
  * ELL: ``data``/``cols`` are dense ``(n_rows, width)`` (row order) or
    ``(width, n_rows)`` (column order, the paper's "ELL-Col" storage);
    missing band entries hold (col=0, val=0) exactly as the paper describes
    ("the value of zero is inserted in the position of missing band parts").
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Tuple, Union

import jax
import numpy as np

Array = Any  # np.ndarray | jax.Array


class MatrixValidationError(ValueError):
    """A sparse container's structural invariants do not hold (malformed
    indptr, out-of-range indices, wrong dtypes).  Raised at the trust
    boundaries — ``SpMVService.register`` and ``plan.bind`` — so corrupt
    input fails loudly there instead of as NaN/garbage deep inside a
    kernel (see docs/robustness.md)."""


def _register(cls, data_fields, meta_fields):
    jax.tree_util.register_dataclass(cls, data_fields=list(data_fields),
                                     meta_fields=list(meta_fields))
    return cls


def _np(x) -> np.ndarray:
    return np.asarray(x)


# ---------------------------------------------------------------------------
# CSR — the paper's CRS: VAL(1:nnz), ICOL(1:nnz), IRP(1:n+1)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class CSR:
    data: Array      # (nnz_pad,)  = VAL
    cols: Array      # (nnz_pad,)  = ICOL
    indptr: Array    # (n_rows+1,) = IRP
    shape: Tuple[int, int]
    nnz: int         # true nnz (<= nnz_pad)

    @property
    def n_rows(self) -> int:
        return self.shape[0]

    @property
    def n_cols(self) -> int:
        return self.shape[1]

    @property
    def nnz_pad(self) -> int:
        return int(self.data.shape[0])

    def row_lengths(self) -> np.ndarray:
        ip = _np(self.indptr)
        return ip[1:] - ip[:-1]

    def todense(self) -> np.ndarray:
        out = np.zeros(self.shape, dtype=_np(self.data).dtype)
        ip, cols, data = _np(self.indptr), _np(self.cols), _np(self.data)
        for i in range(self.n_rows):
            s, e = ip[i], ip[i + 1]
            # duplicate (i, j) entries accumulate, matching SpMV semantics
            np.add.at(out[i], cols[s:e], data[s:e])
        return out

    def validate(self) -> "CSR":
        """Check the CSR structural invariants; raises
        :class:`MatrixValidationError` on the first violation, returns
        ``self`` for chaining.  One O(n + nnz) numpy pass — cheap at the
        register/bind boundary relative to the transform it gates."""
        ip = _np(self.indptr)
        cols = _np(self.cols)
        data = _np(self.data)
        if ip.ndim != 1 or ip.shape[0] != self.n_rows + 1:
            raise MatrixValidationError(
                f"indptr must have shape ({self.n_rows + 1},); "
                f"got {ip.shape}")
        if not np.issubdtype(ip.dtype, np.integer):
            raise MatrixValidationError(
                f"indptr must be an integer array; got dtype {ip.dtype}")
        if not np.issubdtype(cols.dtype, np.integer):
            raise MatrixValidationError(
                f"cols must be an integer array; got dtype {cols.dtype}")
        if int(ip[0]) != 0:
            raise MatrixValidationError(
                f"indptr[0] must be 0; got {int(ip[0])}")
        if np.any(ip[1:] < ip[:-1]):
            i = int(np.argmax(ip[1:] < ip[:-1]))
            raise MatrixValidationError(
                f"indptr must be monotone non-decreasing; "
                f"indptr[{i + 1}]={int(ip[i + 1])} < "
                f"indptr[{i}]={int(ip[i])}")
        if int(ip[-1]) != self.nnz:
            raise MatrixValidationError(
                f"indptr[-1] must equal nnz={self.nnz}; "
                f"got {int(ip[-1])}")
        if self.nnz > self.nnz_pad:
            raise MatrixValidationError(
                f"nnz={self.nnz} exceeds storage nnz_pad={self.nnz_pad}")
        if cols.shape != data.shape:
            raise MatrixValidationError(
                f"cols and data must share a shape; "
                f"got {cols.shape} vs {data.shape}")
        if self.nnz > 0:
            live = cols[: self.nnz]
            lo, hi = int(live.min()), int(live.max())
            if lo < 0 or hi >= self.n_cols:
                raise MatrixValidationError(
                    f"column indices must lie in [0, {self.n_cols}); "
                    f"found range [{lo}, {hi}]")
        return self


_register(CSR, ("data", "cols", "indptr"), ("shape", "nnz"))


# ---------------------------------------------------------------------------
# CCS — compressed column storage (paper's Phase-I target)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class CCS:
    data: Array      # (nnz_pad,)
    rows: Array      # (nnz_pad,) row index of each stored value
    indptr: Array    # (n_cols+1,)
    shape: Tuple[int, int]
    nnz: int

    @property
    def n_rows(self) -> int:
        return self.shape[0]

    @property
    def n_cols(self) -> int:
        return self.shape[1]

    @property
    def nnz_pad(self) -> int:
        return int(self.data.shape[0])

    def todense(self) -> np.ndarray:
        out = np.zeros(self.shape, dtype=_np(self.data).dtype)
        ip, rows, data = _np(self.indptr), _np(self.rows), _np(self.data)
        for j in range(self.n_cols):
            s, e = ip[j], ip[j + 1]
            np.add.at(out[:, j], rows[s:e], data[s:e])
        return out

    def validate(self) -> "CCS":
        """CSR's invariants mirrored over columns: ``indptr`` segments the
        column axis and ``rows`` must stay inside the row space."""
        ip = _np(self.indptr)
        rows = _np(self.rows)
        data = _np(self.data)
        if ip.ndim != 1 or ip.shape[0] != self.n_cols + 1:
            raise MatrixValidationError(
                f"indptr must have shape ({self.n_cols + 1},); "
                f"got {ip.shape}")
        if not np.issubdtype(ip.dtype, np.integer):
            raise MatrixValidationError(
                f"indptr must be an integer array; got dtype {ip.dtype}")
        if not np.issubdtype(rows.dtype, np.integer):
            raise MatrixValidationError(
                f"rows must be an integer array; got dtype {rows.dtype}")
        if int(ip[0]) != 0:
            raise MatrixValidationError(
                f"indptr[0] must be 0; got {int(ip[0])}")
        if np.any(ip[1:] < ip[:-1]):
            j = int(np.argmax(ip[1:] < ip[:-1]))
            raise MatrixValidationError(
                f"indptr must be monotone non-decreasing; "
                f"indptr[{j + 1}]={int(ip[j + 1])} < "
                f"indptr[{j}]={int(ip[j])}")
        if int(ip[-1]) != self.nnz:
            raise MatrixValidationError(
                f"indptr[-1] must equal nnz={self.nnz}; got {int(ip[-1])}")
        if self.nnz > self.nnz_pad:
            raise MatrixValidationError(
                f"nnz={self.nnz} exceeds storage nnz_pad={self.nnz_pad}")
        if rows.shape != data.shape:
            raise MatrixValidationError(
                f"rows and data must share a shape; "
                f"got {rows.shape} vs {data.shape}")
        if self.nnz > 0:
            live = rows[: self.nnz]
            lo, hi = int(live.min()), int(live.max())
            if lo < 0 or hi >= self.n_rows:
                raise MatrixValidationError(
                    f"row indices must lie in [0, {self.n_rows}); "
                    f"found range [{lo}, {hi}]")
        return self


_register(CCS, ("data", "rows", "indptr"), ("shape", "nnz"))


# ---------------------------------------------------------------------------
# COO — VAL, ICOL, IROW; `order` records sortedness ("row" | "col" | None)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class COO:
    data: Array      # (nnz_pad,)
    rows: Array      # (nnz_pad,)
    cols: Array      # (nnz_pad,)
    shape: Tuple[int, int]
    nnz: int
    order: Union[str, None] = "row"

    @property
    def n_rows(self) -> int:
        return self.shape[0]

    @property
    def n_cols(self) -> int:
        return self.shape[1]

    @property
    def nnz_pad(self) -> int:
        return int(self.data.shape[0])

    def todense(self) -> np.ndarray:
        out = np.zeros(self.shape, dtype=_np(self.data).dtype)
        np.add.at(out, (_np(self.rows), _np(self.cols)), _np(self.data))
        return out

    def validate(self) -> "COO":
        """Bounds, dtypes, and the sortedness the ``order`` tag promises
        (the segmented COO kernels rely on it for run detection)."""
        data = _np(self.data)
        rows = _np(self.rows)
        cols = _np(self.cols)
        if self.order not in ("row", "col", None):
            raise MatrixValidationError(
                f"order must be 'row', 'col', or None; got {self.order!r}")
        if not (data.ndim == rows.ndim == cols.ndim == 1):
            raise MatrixValidationError(
                "data/rows/cols must be 1-D arrays")
        if not (data.shape == rows.shape == cols.shape):
            raise MatrixValidationError(
                f"data/rows/cols must share a shape; got {data.shape}, "
                f"{rows.shape}, {cols.shape}")
        for name, arr in (("rows", rows), ("cols", cols)):
            if not np.issubdtype(arr.dtype, np.integer):
                raise MatrixValidationError(
                    f"{name} must be an integer array; got dtype "
                    f"{arr.dtype}")
        if self.nnz > self.nnz_pad:
            raise MatrixValidationError(
                f"nnz={self.nnz} exceeds storage nnz_pad={self.nnz_pad}")
        if self.nnz > 0:
            for name, arr, bound in (("rows", rows, self.n_rows),
                                     ("cols", cols, self.n_cols)):
                live = arr[: self.nnz]
                lo, hi = int(live.min()), int(live.max())
                if lo < 0 or hi >= bound:
                    raise MatrixValidationError(
                        f"{name} indices must lie in [0, {bound}); "
                        f"found range [{lo}, {hi}]")
            key = rows if self.order == "row" else \
                cols if self.order == "col" else None
            if key is not None:
                live = key[: self.nnz]
                if np.any(live[1:] < live[:-1]):
                    i = int(np.argmax(live[1:] < live[:-1]))
                    raise MatrixValidationError(
                        f"order={self.order!r} promises sorted "
                        f"{self.order} indices; violated at entry "
                        f"{i + 1} ({int(live[i + 1])} < {int(live[i])})")
        return self


_register(COO, ("data", "rows", "cols"), ("shape", "nnz", "order"))


# ---------------------------------------------------------------------------
# ELL — VAL(1:n, 1:nz): dense padded band storage.
#   order == "row": data[r, k] is the k-th stored entry of row r
#                   (paper's ELL-Row; TPU-friendly: row-major, width minor).
#   order == "col": data[k, r] — the paper's ELL-Col / inner-parallel layout.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ELL:
    data: Array      # (n_rows, width) or (width, n_rows)
    cols: Array      # same shape as data; padded entries point at column 0
    shape: Tuple[int, int]
    nnz: int
    order: str = "row"

    @property
    def n_rows(self) -> int:
        return self.shape[0]

    @property
    def n_cols(self) -> int:
        return self.shape[1]

    @property
    def width(self) -> int:
        return int(self.data.shape[1] if self.order == "row" else self.data.shape[0])

    def todense(self) -> np.ndarray:
        data = _np(self.data)
        cols = _np(self.cols)
        if self.order == "col":
            data, cols = data.T, cols.T
        out = np.zeros(self.shape, dtype=data.dtype)
        rows = np.broadcast_to(np.arange(self.n_rows)[:, None], data.shape)
        np.add.at(out, (rows.ravel(), cols.ravel()), data.ravel())
        return out

    def validate(self) -> "ELL":
        """Band-storage invariants.  Note the band ``width`` may exceed
        ``n_cols``: the transform quantum-pads it (multiples of 8), so
        only the *index* range is bounded, not the width."""
        data = _np(self.data)
        cols = _np(self.cols)
        if self.order not in ("row", "col"):
            raise MatrixValidationError(
                f"order must be 'row' or 'col'; got {self.order!r}")
        if data.ndim != 2 or data.shape != cols.shape:
            raise MatrixValidationError(
                f"data and cols must be 2-D with one shape; got "
                f"{data.shape} vs {cols.shape}")
        if not np.issubdtype(cols.dtype, np.integer):
            raise MatrixValidationError(
                f"cols must be an integer array; got dtype {cols.dtype}")
        row_axis = data.shape[0] if self.order == "row" else data.shape[1]
        if row_axis != self.n_rows:
            raise MatrixValidationError(
                f"{self.order}-order storage must span n_rows="
                f"{self.n_rows} on its row axis; got {row_axis}")
        if self.nnz > self.n_rows * max(self.width, 0):
            raise MatrixValidationError(
                f"nnz={self.nnz} cannot fit n_rows={self.n_rows} x "
                f"width={self.width} band storage")
        if cols.size and self.n_cols > 0:
            # padded entries point at column 0, so every slot is bounded
            lo, hi = int(cols.min()), int(cols.max())
            if lo < 0 or hi >= self.n_cols:
                raise MatrixValidationError(
                    f"column indices must lie in [0, {self.n_cols}); "
                    f"found range [{lo}, {hi}]")
        return self


_register(ELL, ("data", "cols"), ("shape", "nnz", "order"))


# ---------------------------------------------------------------------------
# BucketedELL — beyond-paper SELL-C-σ adaptation (DESIGN.md §2).
# Rows are sorted by length (σ-sort over the whole matrix), grouped into
# width buckets; each bucket is a dense ELL block over a contiguous slice of
# the *permuted* row space.  `perm[i]` = original row of permuted row i.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class BucketedELL:
    perm: Array                 # (n_rows,) permuted -> original row index
    buckets: Tuple[ELL, ...]    # each over (bucket_rows, n_cols)
    row_offsets: Tuple[int, ...]  # static: start row (permuted) of each bucket
    shape: Tuple[int, int]
    nnz: int

    @property
    def n_rows(self) -> int:
        return self.shape[0]

    @property
    def n_cols(self) -> int:
        return self.shape[1]

    @property
    def widths(self) -> Tuple[int, ...]:
        return tuple(b.width for b in self.buckets)

    def padded_nnz(self) -> int:
        return sum(int(np.prod(b.data.shape)) for b in self.buckets)

    def todense(self) -> np.ndarray:
        out = np.zeros(self.shape, dtype=_np(self.buckets[0].data).dtype)
        perm = _np(self.perm)
        for off, b in zip(self.row_offsets, self.buckets):
            dense_b = b.todense()  # (bucket_rows, n_cols)
            rows = perm[off:off + dense_b.shape[0]]
            out[rows] += dense_b
        return out

    def validate(self) -> "BucketedELL":
        """SELL invariants: ``perm`` is a permutation, buckets tile the
        permuted row space contiguously, widths are distinct and
        monotone non-increasing (widest bucket first — the sort order
        the transform emits and the per-bucket tuner keys on), and the
        bucket nnz sums to the whole."""
        perm = _np(self.perm)
        if perm.ndim != 1 or perm.shape[0] != self.n_rows:
            raise MatrixValidationError(
                f"perm must have shape ({self.n_rows},); got {perm.shape}")
        if not np.issubdtype(perm.dtype, np.integer):
            raise MatrixValidationError(
                f"perm must be an integer array; got dtype {perm.dtype}")
        if not np.array_equal(np.sort(perm),
                              np.arange(self.n_rows, dtype=perm.dtype)):
            raise MatrixValidationError(
                "perm is not a permutation of the row indices")
        if len(self.row_offsets) != len(self.buckets):
            raise MatrixValidationError(
                f"{len(self.buckets)} buckets but "
                f"{len(self.row_offsets)} row offsets")
        if not self.buckets:
            raise MatrixValidationError("SELL container has no buckets")
        if self.row_offsets[0] != 0:
            raise MatrixValidationError(
                f"row_offsets must start at 0; got {self.row_offsets[0]}")
        end = 0
        for i, (off, b) in enumerate(zip(self.row_offsets, self.buckets)):
            if off != end:
                raise MatrixValidationError(
                    f"bucket {i} starts at permuted row {off}, expected "
                    f"{end} (buckets must tile contiguously)")
            if b.shape[1] != self.n_cols:
                raise MatrixValidationError(
                    f"bucket {i} spans {b.shape[1]} columns, expected "
                    f"{self.n_cols}")
            end = off + b.n_rows
            b.validate()
        if end != self.n_rows:
            raise MatrixValidationError(
                f"buckets cover {end} permuted rows, expected "
                f"{self.n_rows}")
        widths = self.widths
        for a, b_ in zip(widths, widths[1:]):
            if b_ >= a:
                raise MatrixValidationError(
                    f"bucket widths must be distinct and strictly "
                    f"decreasing (widest first); got {widths}")
        if sum(b.nnz for b in self.buckets) != self.nnz:
            raise MatrixValidationError(
                f"bucket nnz sums to "
                f"{sum(b.nnz for b in self.buckets)}, expected {self.nnz}")
        return self


_register(BucketedELL, ("perm", "buckets"), ("row_offsets", "shape", "nnz"))


# ---------------------------------------------------------------------------
# Statistics — the paper's D_mat = sigma / mu (eq. 4)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class MatrixStats:
    n: int
    nnz: int
    mu: float        # mean nnz per row
    sigma: float     # stddev nnz per row (population, as in the paper)
    d_mat: float     # sigma / mu
    max_row: int
    min_row: int

    @staticmethod
    def of(mat: "CSR") -> "MatrixStats":
        lens = mat.row_lengths().astype(np.float64)
        mu = float(lens.mean())
        sigma = float(lens.std())
        return MatrixStats(
            n=mat.n_rows, nnz=mat.nnz, mu=mu, sigma=sigma,
            d_mat=sigma / mu if mu > 0 else float("inf"),
            max_row=int(lens.max()), min_row=int(lens.min()),
        )


def memory_bytes(fmt) -> int:
    """Storage footprint of a format instance (index + value arrays)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(fmt):
        total += int(np.prod(leaf.shape)) * _np(leaf).dtype.itemsize
    return total


def validate_container(obj):
    """Run a container's :meth:`validate` when it has one (every format
    in this module does; the hybrid container validates per block at its
    own boundary).  Returns ``obj`` for chaining — the shared entry point
    ``plan.bind`` uses after each transform."""
    check = getattr(obj, "validate", None)
    if callable(check):
        check()
    return obj


# FORMAT_NAMES is derived from the dispatch registry (module __getattr__
# below) so it can never again go stale against the registered formats —
# it used to be a hand-maintained literal that silently omitted bcsr/ccs.
def __getattr__(name: str):
    if name == "FORMAT_NAMES":
        from . import dispatch
        return tuple(dispatch.registered_formats("spmv"))
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "CSR", "CCS", "COO", "ELL", "BucketedELL", "MatrixStats",
    "MatrixValidationError", "memory_bytes", "validate_container",
    "FORMAT_NAMES",
]


# ---------------------------------------------------------------------------
# BCSR — the paper's named future work ("evaluating the transformation to
# other formats, such as BCSR, which enables cache blocking").  b x b dense
# blocks in CSR order: on TPU each block is an MXU-shaped tile, so BCSR
# SpMV becomes a stream of tiny dense matmuls — the cache-blocking the
# paper anticipates, mapped to VMEM tiles.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class BCSR:
    data: Array        # (nblocks_pad, b, b)
    block_cols: Array  # (nblocks_pad,) block-column indices
    indptr: Array      # (n_block_rows + 1,)
    shape: Tuple[int, int]
    nnz: int           # true scalar nnz represented
    block: int         # b

    @property
    def n_rows(self) -> int:
        return self.shape[0]

    @property
    def n_cols(self) -> int:
        return self.shape[1]

    @property
    def n_block_rows(self) -> int:
        return int(self.indptr.shape[0]) - 1

    @property
    def nblocks_pad(self) -> int:
        return int(self.data.shape[0])

    def todense(self) -> np.ndarray:
        b = self.block
        nbr = self.n_block_rows
        out = np.zeros((nbr * b, self.n_cols + (-self.n_cols) % b),
                       dtype=_np(self.data).dtype)
        ip = _np(self.indptr)
        bc = _np(self.block_cols)
        dat = _np(self.data)
        for i in range(nbr):
            for p in range(ip[i], ip[i + 1]):
                j = bc[p]
                out[i * b:(i + 1) * b, j * b:(j + 1) * b] += dat[p]
        return out[: self.n_rows, : self.n_cols]

    def validate(self) -> "BCSR":
        """CSR invariants lifted to the block grid: ``indptr`` segments
        ``ceil(n_rows / b)`` block rows, stored tiles are dense ``b x b``,
        and block columns stay inside ``ceil(n_cols / b)``."""
        b = self.block
        if not isinstance(b, int) or b < 1:
            raise MatrixValidationError(
                f"block size must be a positive int; got {b!r}")
        ip = _np(self.indptr)
        bc = _np(self.block_cols)
        data = _np(self.data)
        nbr = -(-self.n_rows // b) if self.n_rows else 0
        if data.ndim != 3 or data.shape[1:] != (b, b):
            raise MatrixValidationError(
                f"data must be (nblocks_pad, {b}, {b}) dense tiles; "
                f"got {data.shape}")
        if ip.ndim != 1 or ip.shape[0] != nbr + 1:
            raise MatrixValidationError(
                f"indptr must have shape ({nbr + 1},) for n_rows="
                f"{self.n_rows}, block={b}; got {ip.shape}")
        for name, arr in (("indptr", ip), ("block_cols", bc)):
            if not np.issubdtype(arr.dtype, np.integer):
                raise MatrixValidationError(
                    f"{name} must be an integer array; got dtype "
                    f"{arr.dtype}")
        if int(ip[0]) != 0:
            raise MatrixValidationError(
                f"indptr[0] must be 0; got {int(ip[0])}")
        if np.any(ip[1:] < ip[:-1]):
            i = int(np.argmax(ip[1:] < ip[:-1]))
            raise MatrixValidationError(
                f"indptr must be monotone non-decreasing; "
                f"indptr[{i + 1}]={int(ip[i + 1])} < "
                f"indptr[{i}]={int(ip[i])}")
        nblocks = int(ip[-1]) if ip.size else 0
        if nblocks > self.nblocks_pad:
            raise MatrixValidationError(
                f"indptr stores {nblocks} blocks but only "
                f"{self.nblocks_pad} are allocated")
        if bc.shape != (self.nblocks_pad,):
            raise MatrixValidationError(
                f"block_cols must have shape ({self.nblocks_pad},); "
                f"got {bc.shape}")
        if self.nnz > nblocks * b * b:
            raise MatrixValidationError(
                f"nnz={self.nnz} cannot fit {nblocks} dense {b}x{b} "
                f"blocks")
        if nblocks > 0:
            nbc = -(-self.n_cols // b)
            live = bc[:nblocks]
            lo, hi = int(live.min()), int(live.max())
            if lo < 0 or hi >= nbc:
                raise MatrixValidationError(
                    f"block-column indices must lie in [0, {nbc}); "
                    f"found range [{lo}, {hi}]")
        return self


_register(BCSR, ("data", "block_cols", "indptr"), ("shape", "nnz", "block"))


def bcsr_fill_ratio(m: "BCSR") -> float:
    """nnz / stored scalars — the density of the chosen blocks (the BCSR
    analogue of ELL's padding ratio; drives the same AT cost algebra)."""
    stored = m.nblocks_pad * m.block * m.block
    return m.nnz / stored if stored else 0.0
