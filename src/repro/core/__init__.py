"""Core: the paper's contribution — sparse formats, run-time transformation,
SpMV references, and the D_mat–R_ell auto-tuning method."""
from .formats import (BucketedELL, CCS, COO, CSR, ELL, MatrixStats,
                      memory_bytes)
from .transform import (csr_from_dense, csr_from_rows, device_csr_to_ccs,
                        device_csr_to_coo_col, device_csr_to_coo_row,
                        device_csr_to_ell, host_csr_to_ccs,
                        host_csr_to_ccs_paper, host_csr_to_coo_col,
                        host_csr_to_coo_row, host_csr_to_ell,
                        host_csr_to_sell, TRANSFORMS_HOST)
from . import dispatch
from .spmv import (spmm, spmv, spmv_bcsr, spmv_ccs, spmv_coo, spmv_csr,
                   spmv_dense, spmv_ell, spmv_sell, spmm_bcsr, spmm_ccs,
                   spmm_coo, spmm_csr, spmm_ell, spmm_sell)
from .autotune import (AutoTunedSpMV, Decision, MachineModel, TuningDB,
                       decide_cost_model, decide_generalized, decide_paper,
                       offline_phase, time_fn)
from .kernel_tune import (GeometryRecord, KernelTuner, TileGeometry,
                          candidate_geometries, nearest_geometry)
from .plan import (BlockPlan, ExecutionPlan, PlanError, PlanFingerprint,
                   PlanSchemaError, PlannedMatrix, Planner, TransformRecipe,
                   apply_transform)
from .suite import TABLE1, paper_suite, synthesize, verify_suite
from .policy import MemoryPolicy
