"""Single source of truth for sparse-operator dispatch.

Before this module existed the mapping *format -> implementation* lived in
three places at once: an ``isinstance`` chain in ``core/spmv.py``, the
``KERNEL_SPMV_IMPLS`` dict in ``kernels/ops.py``, and a per-block
``isinstance`` chain inside ``partition/hybrid.py``.  Adding a format (or a
new operation such as SpMM) meant editing all three and hoping they agreed.

Now there is one registry, keyed by ``(format, op)`` with two implementation
tiers:

  * ``"reference"`` — pure-jnp semantic oracles (``core/spmv.py``,
    ``partition/hybrid.py`` for the hybrid container);
  * ``"kernel"``    — Pallas TPU kernels and their padding wrappers
    (``kernels/ops.py``).

``op`` is ``"spmv"`` (single right-hand side, ``x: (n_cols,)``) or
``"spmm"`` (multi-RHS panel, ``x: (n_cols, B)``) — the batch-parallel form
that strengthens the paper's amortization rule to
``k * B * (t_crs - t_f) > t_trans``.

Registration happens at import time of the providing modules; lookups lazily
import them, so this module itself has no dependency on any format or kernel
code and there are no import cycles.  A new format or op is registered in
exactly one place: the module that defines its implementations calls
``register_format`` / ``register_impl``.
"""
from __future__ import annotations

import importlib
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import repro.obs as _obs

OPS = ("spmv", "spmm")
TIERS = ("reference", "kernel")

# (format, op, tier) -> callable(fmt_obj, x, **kw)
_IMPLS: Dict[Tuple[str, str, str], Callable] = {}
# registration-ordered (name, class, predicate) for format_of()
_FORMAT_TYPES: List[Tuple[str, type, Optional[Callable[[Any], bool]]]] = []

# modules whose import populates the registry, per tier
_PROVIDERS = {
    "reference": ("repro.core.spmv", "repro.partition.hybrid"),
    "kernel": ("repro.core.spmv", "repro.partition.hybrid",
               "repro.kernels.ops"),
}
_loaded: set = set()


def _ensure_loaded(tier: str) -> None:
    for mod in _PROVIDERS[tier]:
        if mod not in _loaded:
            # mark loaded only on success so a failed provider import is
            # retried (and stays loud) instead of silently degrading every
            # later kernel-tier lookup to the reference fallback; re-entry
            # during a provider's own import is safe — import_module
            # returns the in-progress module from sys.modules
            importlib.import_module(mod)
            _loaded.add(mod)


# ---------------------------------------------------------------------------
# registration (called by the providing modules at import time)
# ---------------------------------------------------------------------------
def register_format(name: str, cls: type,
                    predicate: Optional[Callable[[Any], bool]] = None) -> None:
    """Map a pytree class (optionally narrowed by ``predicate``, e.g. COO
    order) to a format name.  First matching registration wins."""
    _FORMAT_TYPES.append((name, cls, predicate))


def register_impl(fmt: str, op: str, fn: Callable,
                  tier: str = "reference") -> Callable:
    if op not in OPS:
        raise KeyError(f"unknown op {op!r}; one of {OPS}")
    if tier not in TIERS:
        raise KeyError(f"unknown tier {tier!r}; one of {TIERS}")
    _IMPLS[(fmt, op, tier)] = fn
    return fn


# ---------------------------------------------------------------------------
# lookup
# ---------------------------------------------------------------------------
def format_of(obj: Any) -> str:
    """Format name of a sparse container instance."""
    _ensure_loaded("reference")
    for name, cls, pred in _FORMAT_TYPES:
        if isinstance(obj, cls) and (pred is None or pred(obj)):
            return name
    raise TypeError(f"unknown sparse format: {type(obj)}")


def resolve_impl(fmt: str, op: str = "spmv", tier: str = "reference",
                 fallback: bool = True) -> Tuple[Callable, str]:
    """Like :func:`get_impl` but also reports which tier actually resolved
    — callers attaching kernel-only arguments (the ``tuning=`` launch
    geometry) must know whether the fallback landed on the reference tier."""
    _ensure_loaded(tier)
    fn = _IMPLS.get((fmt, op, tier))
    found = tier
    if fn is None and fallback and tier != "reference":
        _ensure_loaded("reference")
        fn = _IMPLS.get((fmt, op, "reference"))
        found = "reference"
    if fn is None:
        raise KeyError(f"no {tier} implementation registered for "
                       f"({fmt!r}, {op!r})")
    tel = _obs.get()
    if tel.enabled:
        tel.counter("dispatch.resolve", fmt=fmt, op=op, tier=found).inc()
    return fn, found


def get_impl(fmt: str, op: str = "spmv", tier: str = "reference",
             fallback: bool = True) -> Callable:
    """Implementation for ``(fmt, op)`` at ``tier``.

    ``fallback=True`` lets a missing kernel-tier entry resolve to the
    reference tier (not every format has a Pallas kernel)."""
    return resolve_impl(fmt, op, tier, fallback)[0]


def has_impl(fmt: str, op: str = "spmv", tier: str = "reference") -> bool:
    _ensure_loaded(tier)
    return (fmt, op, tier) in _IMPLS


def registered_formats(op: Optional[str] = None,
                       tier: str = "reference") -> Tuple[str, ...]:
    """Format names with at least one (or the given op's) registration."""
    _ensure_loaded(tier)
    seen: List[str] = []
    for (f, o, t) in _IMPLS:
        if t == tier and (op is None or o == op) and f not in seen:
            seen.append(f)
    return tuple(seen)


def impl_table(op: str = "spmv", tier: str = "reference",
               fallback: bool = False,
               exclude: Sequence[str] = ()) -> Dict[str, Callable]:
    """``{format: callable}`` view of the registry for one (op, tier).

    With ``fallback=True`` every format known to the reference tier appears,
    kernel entries taking precedence."""
    _ensure_loaded(tier)
    out: Dict[str, Callable] = {}
    if fallback and tier != "reference":
        out.update(impl_table(op, "reference"))
    for (f, o, t), fn in _IMPLS.items():
        if o == op and t == tier and f not in exclude:
            out[f] = fn
    for f in exclude:
        out.pop(f, None)
    return out


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------
def dispatch(obj: Any, x, op: str = "spmv", tier: str = "reference",
             tuning: Any = None, **kw):
    """Resolve ``obj``'s format and apply its ``op`` implementation.

    ``tuning`` is the per-call launch-geometry hint (a
    ``core.kernel_tune.TileGeometry``, or a ``{format: TileGeometry}`` dict
    for the hybrid container); it is forwarded only when the lookup lands
    on the kernel tier — reference implementations have no launch geometry
    and a kernel-tier request may legitimately fall back to one."""
    fn, found = resolve_impl(format_of(obj), op, tier)
    if tuning is not None and found == "kernel":
        kw["tuning"] = tuning
    return fn(obj, x, **kw)


def spmv(m, x, tier: str = "reference", tuning: Any = None):
    return dispatch(m, x, op="spmv", tier=tier, tuning=tuning)


def spmm(m, x, tier: str = "reference", tuning: Any = None):
    if getattr(x, "ndim", 2) != 2:
        raise ValueError(f"spmm expects x of shape (n_cols, B); got "
                         f"{getattr(x, 'shape', None)}")
    return dispatch(m, x, op="spmm", tier=tier, tuning=tuning)


__all__ = ["OPS", "TIERS", "register_format", "register_impl", "format_of",
           "get_impl", "resolve_impl", "has_impl", "registered_formats",
           "impl_table", "dispatch", "spmv", "spmm"]
