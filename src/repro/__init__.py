"""repro — auto-tuned run-time sparse-format transformation for SpMV
(Katagiri & Sato) built out as a multi-pod JAX training/serving framework.

The public API is the plan pipeline (see ``repro.api`` and
``docs/plans.md``)::

    from repro import Planner, ExecutionPlan

    plan = Planner(db=db).plan(csr)      # decide + tune, one artifact
    plan.save("plan.json")
    P = ExecutionPlan.load("plan.json").bind(csr)
    y = P @ x

Attribute access is lazy so ``import repro`` stays lightweight; the full
surface lives in :mod:`repro.api`.
"""

__version__ = "0.1.0"

# lazily re-exported from repro.api (keeps `import repro` free of jax)
_API_EXPORTS = (
    "Planner", "ExecutionPlan", "PlannedMatrix", "BlockPlan",
    "ShardedPlan", "ShardedPlannedMatrix", "build_sharded",
    "TransformRecipe", "PlanFingerprint", "PlanError", "PlanSchemaError",
    "SpMVService", "TuningDB", "KernelTuner", "TileGeometry",
    "offline_phase", "MachineModel", "MatrixStats", "csr_from_dense",
    "csr_from_rows", "obs", "Telemetry",
)

__all__ = ["__version__", "api", *_API_EXPORTS]


def __getattr__(name: str):
    import importlib
    if name == "obs":
        # resolved directly (not via repro.api) so the stdlib-only
        # telemetry surface never drags jax into the importing process
        return importlib.import_module("repro.obs")
    if name in _API_EXPORTS or name == "api":
        api = importlib.import_module("repro.api")
        return api if name == "api" else getattr(api, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
