"""repro — auto-tuned run-time sparse-format transformation for SpMV
(Katagiri & Sato) built out as a multi-pod JAX training/serving framework."""

__version__ = "0.1.0"
