from .gpipe import bubble_fraction, pipeline_forward, reference_forward
