"""GPipe-style pipeline parallelism over a 'pipe' mesh axis.

shard_map + collective_permute: each device owns one stage's parameters
(stacked leaf layout, leading stage dim sharded over 'pipe').  The
schedule runs M + P - 1 ticks; on each tick every device applies its stage
to the microbatch it holds and permutes activations one stage forward —
the classic GPipe fill/drain bubble with P-1 idle slots.

This is the optional large-depth axis (DESIGN.md §6): the graded meshes
use (data, model); 'pipe' composes on top for 1000+-node layouts, e.g.
(pipe=4, data=16, model=8).  Forward-only here covers the serving and
bubble-analysis use cases; training composes this with jax.grad through
shard_map."""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def pipeline_forward(stage_params: Any, x_micro: jax.Array, *,
                     stage_fn: Callable[[Any, jax.Array], jax.Array],
                     mesh, axis: str = "pipe") -> jax.Array:
    """stage_params: tree with leading dim = n_stages (sharded over axis);
    x_micro: (M, mb, ...) microbatches (replicated).  Returns (M, mb, ...)
    outputs of the final stage."""
    n_stages = dict(mesh.shape)[axis]
    M = x_micro.shape[0]

    def per_device(params_local, xs):
        # params_local: leading dim 1 (this device's stage)
        params1 = jax.tree.map(lambda a: a[0], params_local)
        stage = jax.lax.axis_index(axis)
        ticks = M + n_stages - 1
        # mark carries as device-varying over the pipe axis (shard_map vma;
        # jax < 0.5 has no pcast and no vma tracking — replication is fine)
        pcast = getattr(jax.lax, "pcast", None)
        vary = (lambda v: pcast(v, (axis,), to="varying")) if pcast \
            else (lambda v: v)
        buf = vary(jnp.zeros_like(xs[0]))
        outs = vary(jnp.zeros_like(xs))

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any remain)
            mb_idx = jnp.clip(t, 0, M - 1)
            incoming = jnp.where(stage == 0,
                                 jnp.where(t < M, 1, 0), 0)
            inp = jnp.where(incoming, xs[mb_idx], buf)
            y = stage_fn(params1, inp)
            # last stage records its finished microbatch (t - (P-1))
            done_idx = t - (n_stages - 1)
            record = jnp.logical_and(stage == n_stages - 1, done_idx >= 0)
            ci = jnp.clip(done_idx, 0, M - 1)
            outs = outs.at[ci].set(jnp.where(record, y, outs[ci]))
            # shift activations forward one stage
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = jax.lax.ppermute(y, axis, perm)
            return buf, outs

        _, outs = jax.lax.fori_loop(0, ticks, tick, (buf, outs))
        # deliver final outputs from the last stage to everyone
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis)
        return outs

    pspec = jax.tree.map(lambda _: P(axis), stage_params)
    return shard_map(per_device, mesh=mesh,
                     in_specs=(pspec, P()), out_specs=P())(
        stage_params, x_micro)


def reference_forward(stage_params: Any, x_micro: jax.Array, *,
                      stage_fn: Callable[[Any, jax.Array], jax.Array]
                      ) -> jax.Array:
    """Sequential oracle: apply all stages to every microbatch."""
    n_stages = jax.tree.leaves(stage_params)[0].shape[0]

    def run_one(x):
        for s in range(n_stages):
            p = jax.tree.map(lambda a: a[s], stage_params)
            x = stage_fn(p, x)
        return x

    return jax.vmap(run_one)(x_micro)


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    """GPipe bubble overhead: (P-1)/(M+P-1)."""
    return (n_stages - 1) / (n_micro + n_stages - 1)


__all__ = ["pipeline_forward", "reference_forward", "bubble_fraction"]
