"""minitron-8b [dense] — width/depth-pruned Nemotron-4 [arXiv:2407.14679].
256k vocab -> sparse embedding-gradient path qualifies (DESIGN §4)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=16384, vocab_size=256000,
    layer_pattern=("attn",),
    sparse_autotune=True,
)
