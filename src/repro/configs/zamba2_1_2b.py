"""zamba2-1.2b [hybrid] — Mamba-2 backbone with a *shared* attention block
invoked every 6th layer [arXiv:2411.15242].  The shared block's parameters
are deliberately NOT stacked per repetition — one param set reused at every
occurrence, matching Zamba's weight sharing.  38 layers = 6 x (5 mamba +
1 mamba+shared-attn) + 2 remainder mamba."""
from .base import ModelConfig

CONFIG = ModelConfig(
    use_seq_sp=False,  # recurrent: time scan needs the full sequence locally
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32000,
    layer_pattern=("mamba",) * 5 + ("mamba_attn",),
    ssm_state=64, ssm_conv=4, ssm_expand=2, ssm_head_dim=64,
)
