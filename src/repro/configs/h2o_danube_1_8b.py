"""h2o-danube-1.8b [dense] — llama+mistral mix with sliding-window attention
[arXiv:2401.16818]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b", family="dense",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8,
    d_ff=6912, vocab_size=32000,
    layer_pattern=("local",), window=4096,
)
