"""internvl2-2b [vlm] — InternViT + InternLM2 backbone [arXiv:2404.16821].

The ViT frontend is a stub per spec: input_specs() supplies precomputed
patch embeddings (frontend_len tokens) prepended to the text sequence."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab_size=92553,
    layer_pattern=("attn",),
    frontend="vit", frontend_len=256,
)
