"""gemma3-12b [dense] — 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt scaled family].  Local layers: 1024-token sliding
window, rope theta 10k; global layers: full attention, rope theta 1M.
Huge vocab (262144) -> sparse embedding-gradient path qualifies (DESIGN §4)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8,
    d_ff=15360, vocab_size=262144,
    layer_pattern=("local",) * 5 + ("attn",),
    window=1024, rope_theta=1e4, rope_theta_global=1e6,
    attn_logit_softcap=0.0,
    sparse_autotune=True,
)
