"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks, 7:1 ratio [arXiv:2405.04517].

d_ff=0: xLSTM blocks carry their own up/down projections (no separate FFN).
48 layers = 6 repetitions of (7 mLSTM + 1 sLSTM)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    use_seq_sp=False,  # recurrent: time scan needs the full sequence locally
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    layer_pattern=("mlstm",) * 7 + ("slstm",),
    mlstm_expand=2,
)
