"""Model/config system: one frozen dataclass describes any architecture in
the zoo; per-arch files in this package instantiate it.

``layer_pattern`` is the *period* of block kinds that repeats through the
depth (lax.scan over repetitions keeps the HLO O(period) — DESIGN.md §7).
Remainder layers (n_layers % period) are applied unrolled with their own
(unstacked) parameters.

Block kinds:
  attn        — global attention + MLP
  local       — sliding-window attention + MLP
  moe         — attention + mixture-of-experts FFN
  local_moe   — SWA attention + MoE FFN (mixtral)
  mamba       — Mamba-2 (SSD) block
  mamba_attn  — Mamba-2 block followed by the *shared* attention block
                (zamba2: one attention param set reused at every occurrence)
  mlstm       — xLSTM mLSTM block (matrix memory, parallel/chunk form)
  slstm       — xLSTM sLSTM block (scalar memory, true recurrence)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import jax.numpy as jnp

ATTN_KINDS = ("attn", "local", "moe", "local_moe")
SSM_KINDS = ("mamba", "mamba_attn")
XLSTM_KINDS = ("mlstm", "slstm")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    layer_pattern: Tuple[str, ...] = ("attn",)
    d_head: Optional[int] = None    # default d_model // n_heads
    # attention
    qk_norm: bool = False
    rope_theta: float = 1e4
    rope_theta_global: Optional[float] = None   # gemma3 global layers
    window: int = 4096              # SWA window for "local*" kinds
    attn_logit_softcap: float = 0.0
    flash_kv_chunk: int = 1024      # flash-attention KV block (§Perf knob)
    swa_banded: bool = False        # banded SWA flash (§Perf: exact and a
                                    # 6.4x FLOP cut single-device, but the
                                    # dynamic_slice over seq-sharded KV
                                    # breaks GSPMD propagation — measured
                                    # 2x WORSE per-device compute on the
                                    # 16x16 mesh; off by default)
    # moe
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_dispatch: str = "ell"       # "ell" | "csr" | "auto" (paper AT rule)
    # ssm (mamba2)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    # xlstm
    mlstm_expand: int = 2
    # frontends (vlm/audio stubs — precomputed embeddings via input_specs)
    frontend: Optional[str] = None  # "vit" | "audio"
    frontend_len: int = 0
    # misc
    use_seq_sp: bool = True         # sequence-parallel residual stream.
                                    # §Perf: WRONG for recurrent archs —
                                    # the time scan needs the full sequence
                                    # locally, so seq-SP forces a gather +
                                    # re-scatter of q/k/v/gates per layer
    kv_quant: bool = False          # int8 KV cache (serving)
    embed_tp_lookup: bool = False   # §Perf: shard embed table over model on
                                    # d (local gather) instead of vocab
                                    # (kills the GSPMD full-table remat)
    xlstm_shard_recurrent: bool = True  # §Perf: False = replicate small
                                        # recurrent weights (no per-step AR)
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    remat: str = "full"             # none | dots | full (full = recompute; only scan-rep carries saved)
    sparse_autotune: bool = False   # paper-technique integrations enabled
    # sharding-driven head padding (resolved; see resolve_for_tp)
    pad_heads_to: Optional[int] = None
    pad_kv_heads_to: Optional[int] = None

    # ---- derived ----------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def eff_heads(self) -> int:
        return self.pad_heads_to or self.n_heads

    @property
    def eff_kv_heads(self) -> int:
        return self.pad_kv_heads_to or self.n_kv_heads

    @property
    def q_per_kv(self) -> int:
        assert self.eff_heads % self.eff_kv_heads == 0, \
            (self.eff_heads, self.eff_kv_heads)
        return self.eff_heads // self.eff_kv_heads

    @property
    def period(self) -> int:
        return len(self.layer_pattern)

    @property
    def scan_reps(self) -> int:
        return self.n_layers // self.period

    @property
    def remainder_pattern(self) -> Tuple[str, ...]:
        return self.layer_pattern[: self.n_layers % self.period]

    @property
    def compute_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    @property
    def is_recurrent(self) -> bool:
        """True if decode state is O(1) in context length (SSM/xLSTM)."""
        return all(k in SSM_KINDS + XLSTM_KINDS for k in self.layer_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: recurrent, or attention is windowed except
        a bounded number of global layers (DESIGN.md §5)."""
        if self.is_recurrent:
            return True
        kinds = set(self.layer_pattern)
        return bool(kinds & {"local", "local_moe", "mamba", "mamba_attn",
                             "mlstm", "slstm"})

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- TP head padding (exact-preserving; DESIGN.md §6) ------------------
    def resolve_for_tp(self, tp: int) -> "ModelConfig":
        """Pad head counts so they divide the tensor-parallel degree.

        * GQA kv padding replicates each kv head r times (exactness: a
          replicated kv head splits its query group — identical math);
        * MHA q/kv padding adds zero-projection heads (o-proj columns zero —
          identical math).  Only shapes matter for lowering; the exactness
          argument documents why the padded model is the same function."""
        if not any(k in ATTN_KINDS for k in self.layer_pattern + ("attn",)):
            return self
        kv, h = self.n_kv_heads, self.n_heads
        if kv % tp == 0 and h % tp == 0:
            return self
        kv_p = kv if kv % tp == 0 else ((kv + tp - 1) // tp) * tp
        if kv_p % kv == 0 or kv == h:
            # GQA replication (integer factor) or MHA zero-padding
            h_p = ((h + kv_p - 1) // kv_p) * kv_p if kv == h else h
            h_p = h_p if h_p % tp == 0 else ((h_p + tp - 1) // tp) * tp
            if h_p % kv_p != 0:
                h_p = ((h_p + kv_p - 1) // kv_p) * kv_p
            return self.replace(pad_heads_to=h_p, pad_kv_heads_to=kv_p)
        return self.replace(pad_kv_heads_to=kv_p,
                            pad_heads_to=((h + kv_p - 1) // kv_p) * kv_p)


# ---------------------------------------------------------------------------
# input shapes (assigned cells)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests: tiny widths/depths,
    few experts, small vocab — one full period of the layer pattern."""
    n_layers = max(len(cfg.layer_pattern), 2)
    if cfg.n_layers % len(cfg.layer_pattern):
        n_layers += cfg.n_layers % len(cfg.layer_pattern) and 1
    return cfg.replace(
        n_layers=len(cfg.layer_pattern) * 2 + len(cfg.remainder_pattern),
        d_model=64, n_heads=4, n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        d_head=16, d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        n_experts=min(cfg.n_experts, 4), top_k=min(cfg.top_k, 2),
        ssm_state=16 if cfg.ssm_state else 0,
        window=32, frontend_len=8 if cfg.frontend else 0,
        dtype="float32", remat="none",
        pad_heads_to=None, pad_kv_heads_to=None,
    )


__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "smoke_config",
           "ATTN_KINDS", "SSM_KINDS", "XLSTM_KINDS"]
