"""Architecture registry: ``get_config("<id>")`` with hyphen/underscore
tolerance; ``ARCH_IDS`` lists the ten assigned architectures."""
from importlib import import_module

from .base import (ModelConfig, ShapeConfig, SHAPES, smoke_config,
                   ATTN_KINDS, SSM_KINDS, XLSTM_KINDS)

ARCH_IDS = (
    "internvl2-2b", "dbrx-132b", "mixtral-8x22b", "xlstm-1.3b",
    "gemma3-12b", "h2o-danube-1.8b", "minitron-8b", "qwen3-1.7b",
    "zamba2-1.2b", "musicgen-medium",
)

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    key = arch.replace("_", "-")
    if key not in _MODULES:
        # tolerate exact module-style names too
        matches = [a for a in ARCH_IDS if a.replace("-", "_").replace(".", "_")
                   == arch]
        if not matches:
            raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
        key = matches[0]
    mod = import_module(f"repro.configs.{_MODULES[key]}")
    return mod.CONFIG


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
