"""dbrx-132b [moe] — 16 experts top-4, fine-grained [hf:databricks/dbrx-base].

MoE dispatch is the paper-technique integration point: the token->expert
dispatch matrix is ELL (fixed capacity, padded) vs CSR (dropless); the
D_mat = sigma/mu of tokens-per-expert drives the run-time choice."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab_size=100352,
    layer_pattern=("moe",),
    n_experts=16, top_k=4,
    sparse_autotune=True,
)
