"""musicgen-medium [audio] — decoder-only transformer over EnCodec tokens
[arXiv:2306.05284].  The EnCodec frontend is a stub per spec: input_specs()
supplies precomputed conditioning frame embeddings (frontend_len tokens)
prepended to the codec-token sequence; vocab=2048 is the codebook size."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab_size=2048,
    layer_pattern=("attn",),
    frontend="audio", frontend_len=64,
)
