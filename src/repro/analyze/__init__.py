"""``repro.analyze`` — static analysis for plans, registries, and source.

Three passes behind one CLI (``python -m repro.analyze``), all jax-free
and stdlib-only so they run before any launch and inside a bare CI job:

* :mod:`.planlint` — lint ``ExecutionPlan`` / ``ShardedPlan`` JSON
  (rules ``RPL0xx``): schema, geometry alignment, slab bounds, a VMEM
  footprint model, SELL bucket tables, hybrid/sharded partitions.
  Wired into :class:`~repro.core.plan_store.PlanStore` loads (errors
  quarantine with reason ``"lint"``), ``SpMVService.register
  (strict_lint=)``, and the ``Planner``'s self-check.
* :mod:`.registry` — audit the dispatch registry against the transform
  table, the tuner grid, and the documented telemetry vocabulary
  (``RPR0xx``).
* :mod:`.astlint` — repo-contract source lint (``RPA0xx``) with
  ``# repro: noqa[RPAxxx]`` waivers.

The rule catalog lives in ``docs/analysis.md``.
"""
from .astlint import lint_paths, lint_source
from .findings import ERROR, WARN, Finding, PlanLintError, errors, \
    has_errors, render
from .planlint import DEFAULT_VMEM_BUDGET, lint_envelope, lint_plan, \
    lint_text
from .registry import audit

__all__ = ["ERROR", "WARN", "Finding", "PlanLintError", "errors",
           "has_errors", "render", "DEFAULT_VMEM_BUDGET", "lint_plan",
           "lint_envelope", "lint_text", "audit", "lint_source",
           "lint_paths"]
