"""The shared finding model for every ``repro.analyze`` pass.

All three passes (plan lint, registry audit, AST lint) report through one
:class:`Finding` shape so the CLI, the :class:`~repro.core.plan_store
.PlanStore` quarantine hook, and ``SpMVService.register(strict_lint=)``
consume a single vocabulary: ``severity`` is ``"error"`` (the artifact or
source must not ship) or ``"warn"`` (suspicious but servable).

This module is stdlib-only by contract — it sits underneath the jax-free
CLI path (rule RPA003 enforces that mechanically).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, List, Sequence, Tuple

ERROR = "error"
WARN = "warn"


@dataclass(frozen=True)
class Finding:
    """One lint/audit result.

    ``rule`` is the stable identifier (``RPL0xx`` plan lint, ``RPR0xx``
    registry audit, ``RPA0xx`` AST lint — catalog in docs/analysis.md).
    ``where`` locates it: a file path for source rules, a JSON path
    (``shards[2].plan.geometry.spmv``) for plan rules.  ``line`` is
    1-based for source findings, 0 when not applicable."""
    rule: str
    severity: str
    message: str
    where: str = ""
    line: int = 0

    def render(self) -> str:
        loc = self.where or "<input>"
        if self.line:
            loc = f"{loc}:{self.line}"
        return f"{loc}: {self.rule} [{self.severity}] {self.message}"


class PlanLintError(ValueError):
    """A plan artifact failed lint at a trust boundary that was asked to
    be strict (``SpMVService.register(strict_lint=True)``).  Carries the
    findings so callers can log or display them."""

    def __init__(self, message: str, findings: Sequence[Finding] = ()):
        super().__init__(message)
        self.findings: Tuple[Finding, ...] = tuple(findings)


def errors(findings: Iterable[Finding]) -> List[Finding]:
    return [f for f in findings if f.severity == ERROR]


def has_errors(findings: Iterable[Finding]) -> bool:
    return any(f.severity == ERROR for f in findings)


def render(findings: Iterable[Finding]) -> str:
    return "\n".join(f.render() for f in findings)


__all__ = ["ERROR", "WARN", "Finding", "PlanLintError", "errors",
           "has_errors", "render"]
