"""Cross-registry consistency audit (RPR0xx) — AST-extracted, jax-free.

``core/dispatch.py`` made the ``(format, op) x tier`` registry the single
source of truth, but three adjacent tables can still drift from it: the
host transform table (``core/transform.py::TRANSFORMS_HOST``), the tuner's
candidate-grid surface (``core/kernel_tune.py::GRID_FORMATS``), and the
telemetry vocabulary documented in ``docs/observability.md``.  Each drift
has a concrete failure mode — a registered format the planner cannot
transform to, a kernel the tuner silently serves with default geometry, a
dashboard watching an event name that nothing emits.

Everything here is read **statically**: provider modules are located by
parsing the ``_PROVIDERS`` literal in ``dispatch.py`` and their
``register_format`` / ``register_impl`` calls (including the
loop-over-tuple-literal idiom the providers use) are lifted from the AST,
never imported — so the audit runs in the jax-free CI job.
``FORMAT_NAMES`` needs no separate check: it is derived from the dispatch
registry at runtime, so auditing ``register_format`` covers it.

Rules:

  RPR001  every ``register_format`` name has reference-tier SpMV and SpMM
  RPR002  every kernel-tier impl is on the tuner's ``GRID_FORMATS``
          surface (hybrid composes tuned blocks and is exempt); a grid
          entry with no kernel is a stale-grid WARN
  RPR003  every reference-SpMV format has a ``TRANSFORMS_HOST`` recipe;
          a recipe with no impl is a WARN
  RPR004  every format with an impl is registered via ``register_format``
  RPR005  telemetry names emitted in ``src/`` appear in the
          ``docs/observability.md`` vocabulary (documented-but-silent
          names are WARNs)
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .findings import ERROR, WARN, Finding

_TEL_METHODS = ("counter", "gauge", "histogram", "event", "span")
_DOTTED = re.compile(r"`([a-z_][a-z0-9_]*(?:\.[a-z0-9_*]+)+)`")


def _parse(path: Path) -> Optional[ast.Module]:
    try:
        return ast.parse(path.read_text(encoding="utf-8"), str(path))
    except (OSError, SyntaxError):
        return None


def _const_str(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def _call_name(call: ast.Call) -> str:
    fn = call.func
    if isinstance(fn, ast.Attribute):
        return fn.attr
    if isinstance(fn, ast.Name):
        return fn.id
    return ""


# ---------------------------------------------------------------------------
# extraction
# ---------------------------------------------------------------------------
def providers(dispatch_path: Path) -> Dict[str, Tuple[str, ...]]:
    """The ``_PROVIDERS`` tier -> module-names literal from dispatch.py."""
    tree = _parse(dispatch_path)
    if tree is None:
        return {}
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id == "_PROVIDERS"
                and isinstance(node.value, ast.Dict)):
            continue
        out: Dict[str, Tuple[str, ...]] = {}
        for k, v in zip(node.value.keys, node.value.values):
            tier = _const_str(k) if k is not None else None
            if tier is None or not isinstance(v, (ast.Tuple, ast.List)):
                continue
            mods = [_const_str(e) for e in v.elts]
            out[tier] = tuple(m for m in mods if m)
        return out
    return {}


def registrations(path: Path) -> Tuple[Set[str], Set[Tuple[str, str, str]]]:
    """``(formats, impls)`` registered by one provider module.

    ``formats`` are ``register_format`` names; ``impls`` are
    ``(fmt, op, tier)`` triples from direct ``register_impl`` calls and
    from the ``for _fmt, ... in ((...), ...)`` registration loops."""
    formats: Set[str] = set()
    impls: Set[Tuple[str, str, str]] = set()
    tree = _parse(path)
    if tree is None:
        return formats, impls

    def impl_call(call: ast.Call, fmt_var: Optional[str]) -> None:
        if _call_name(call) != "register_impl" or len(call.args) < 3:
            return
        op = _const_str(call.args[1])
        if op is None:
            return
        tier = "reference"
        for kw in call.keywords:
            if kw.arg == "tier":
                tier = _const_str(kw.value) or tier
        fmt = _const_str(call.args[0])
        if fmt is not None:
            impls.add((fmt, op, tier))
        elif (fmt_var is not None and isinstance(call.args[0], ast.Name)
              and call.args[0].id == fmt_var):
            impls.add(("<loop>", op, tier))

    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            if _call_name(node) == "register_format" and node.args:
                name = _const_str(node.args[0])
                if name:
                    formats.add(name)
            impl_call(node, None)
        if not (isinstance(node, ast.For)
                and isinstance(node.target, ast.Tuple)
                and node.target.elts
                and isinstance(node.target.elts[0], ast.Name)
                and isinstance(node.iter, (ast.Tuple, ast.List))):
            continue
        fmt_var = node.target.elts[0].id
        fmts = []
        for elt in node.iter.elts:
            if isinstance(elt, (ast.Tuple, ast.List)) and elt.elts:
                fmt = _const_str(elt.elts[0])
                if fmt:
                    fmts.append(fmt)
        loop_impls: Set[Tuple[str, str]] = set()
        for inner in ast.walk(node):
            if isinstance(inner, ast.Call):
                before = {i for i in impls if i[0] == "<loop>"}
                impl_call(inner, fmt_var)
                for placeholder in {i for i in impls
                                    if i[0] == "<loop>"} - before:
                    loop_impls.add(placeholder[1:])
        impls = {i for i in impls if i[0] != "<loop>"}
        for fmt in fmts:
            for op, tier in loop_impls:
                impls.add((fmt, op, tier))
    return formats, impls


def dict_literal_keys(path: Path, name: str) -> Optional[Set[str]]:
    """String keys of a module-level ``name = { ... }`` assignment."""
    tree = _parse(path)
    if tree is None:
        return None
    for node in ast.walk(tree):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id == name
                and isinstance(node.value, ast.Dict)):
            keys = {_const_str(k) for k in node.value.keys
                    if k is not None}
            return {k for k in keys if k}
    return None


def tuple_literal(path: Path, name: str) -> Optional[Tuple[str, ...]]:
    """Elements of a module-level ``name = ("a", "b", ...)`` assignment."""
    tree = _parse(path)
    if tree is None:
        return None
    for node in ast.walk(tree):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id == name
                and isinstance(node.value, (ast.Tuple, ast.List))):
            elts = [_const_str(e) for e in node.value.elts]
            return tuple(e for e in elts if e)
    return None


def emitted_telemetry(src: Path) -> Dict[str, List[str]]:
    """Dotted names passed to ``.counter/.gauge/.histogram/.event/.span``
    anywhere under ``src`` -> the files that emit them."""
    out: Dict[str, List[str]] = {}
    for path in sorted(src.rglob("*.py")):
        tree = _parse(path)
        if tree is None:
            continue
        for node in ast.walk(tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in _TEL_METHODS and node.args):
                continue
            name = _const_str(node.args[0])
            if name and "." in name:
                out.setdefault(name, []).append(str(path))
    return out


def documented_telemetry(doc_path: Path) -> Optional[Set[str]]:
    """Dotted names from the first cell of the vocabulary tables in the
    '## Event vocabulary' section of docs/observability.md."""
    try:
        text = doc_path.read_text(encoding="utf-8")
    except OSError:
        return None
    names: Set[str] = set()
    in_section = False
    for line in text.splitlines():
        if line.startswith("## "):
            in_section = line.strip() == "## Event vocabulary"
            continue
        if not in_section or not line.startswith("|"):
            continue
        first_cell = line.split("|")[1] if line.count("|") >= 2 else ""
        names.update(_DOTTED.findall(first_cell))
    return names


# ---------------------------------------------------------------------------
# the audit
# ---------------------------------------------------------------------------
def audit(src: str = "src",
          docs: str = "docs/observability.md") -> List[Finding]:
    root = Path(src)
    findings: List[Finding] = []

    def err(rule: str, where: str, msg: str) -> None:
        findings.append(Finding(rule, ERROR, msg, where=where))

    def warn(rule: str, where: str, msg: str) -> None:
        findings.append(Finding(rule, WARN, msg, where=where))

    dispatch_path = root / "repro" / "core" / "dispatch.py"
    provs = providers(dispatch_path)
    if not provs:
        err("RPR001", str(dispatch_path),
            "could not extract _PROVIDERS — the audit has no registry "
            "to check")
        return findings

    formats: Set[str] = set()
    impls: Set[Tuple[str, str, str]] = set()
    for tier, mods in provs.items():
        for mod in mods:
            path = root / Path(*mod.split(".")).with_suffix(".py")
            if not path.is_file():
                err("RPR001", str(dispatch_path),
                    f"_PROVIDERS[{tier!r}] names {mod!r} but "
                    f"{path} does not exist")
                continue
            f, i = registrations(path)
            formats |= f
            impls |= i

    dispatch_src = str(dispatch_path)

    # RPR001: registered formats have both reference ops
    for fmt in sorted(formats):
        for op in ("spmv", "spmm"):
            if (fmt, op, "reference") not in impls:
                err("RPR001", dispatch_src,
                    f"format {fmt!r} is registered but has no "
                    f"reference-tier {op} implementation")

    # RPR004: impls belong to registered formats
    for fmt in sorted({f for (f, _, _) in impls}):
        if fmt not in formats:
            err("RPR004", dispatch_src,
                f"implementations registered for {fmt!r} but no "
                f"register_format call maps a container class to it")

    # RPR002: kernel tier <-> tuner grid surface
    kt_path = root / "repro" / "core" / "kernel_tune.py"
    grid = tuple_literal(kt_path, "GRID_FORMATS")
    if grid is None:
        err("RPR002", str(kt_path),
            "could not extract GRID_FORMATS — the kernel tier cannot be "
            "checked against the tuner's grid surface")
    else:
        kernel_fmts = {f for (f, _, t) in impls if t == "kernel"}
        for fmt in sorted(kernel_fmts):
            # hybrid has no grid of its own: it composes its blocks'
            # tuned geometries
            if fmt not in grid and fmt != "hybrid":
                err("RPR002", str(kt_path),
                    f"kernel-tier {fmt!r} has no candidate grid in "
                    f"GRID_FORMATS — the tuner would always serve it "
                    f"default geometry")
        for fmt in grid:
            if fmt not in kernel_fmts:
                warn("RPR002", str(kt_path),
                     f"GRID_FORMATS lists {fmt!r} but no kernel-tier "
                     f"implementation is registered (stale grid entry)")

    # RPR003: reference spmv <-> host transform recipes
    tr_path = root / "repro" / "core" / "transform.py"
    recipes = dict_literal_keys(tr_path, "TRANSFORMS_HOST")
    if recipes is None:
        err("RPR003", str(tr_path),
            "could not extract TRANSFORMS_HOST — transform coverage "
            "cannot be checked")
    else:
        ref_spmv = {f for (f, op, t) in impls
                    if op == "spmv" and t == "reference"}
        for fmt in sorted(ref_spmv):
            if fmt not in recipes:
                err("RPR003", str(tr_path),
                    f"format {fmt!r} is servable but TRANSFORMS_HOST has "
                    f"no CRS->{fmt} recipe — the planner cannot reach it")
        for fmt in sorted(recipes):
            if fmt not in ref_spmv:
                warn("RPR003", str(tr_path),
                     f"TRANSFORMS_HOST recipe {fmt!r} has no reference "
                     f"spmv implementation")

    # RPR005: telemetry vocabulary
    doc_path = Path(docs)
    documented = documented_telemetry(doc_path)
    if documented is None:
        err("RPR005", str(doc_path),
            "could not read the telemetry vocabulary")
        return findings
    emitted = emitted_telemetry(root)
    for name in sorted(emitted):
        if name not in documented:
            err("RPR005", emitted[name][0],
                f"telemetry name {name!r} is emitted but missing from "
                f"the vocabulary in {doc_path}")
    for name in sorted(documented):
        if name not in emitted:
            warn("RPR005", str(doc_path),
                 f"telemetry name {name!r} is documented but nothing in "
                 f"{src} emits it")
    return findings


__all__ = ["audit", "providers", "registrations", "dict_literal_keys",
           "tuple_literal", "emitted_telemetry", "documented_telemetry"]
