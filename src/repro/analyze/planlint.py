"""Static lint for ExecutionPlan / ShardedPlan JSON artifacts (RPL0xx).

``ExecutionPlan.from_dict`` checks the schema version and field *presence*
— by design it stays permissive about values, because a plan that parses
is still just a suggestion until ``bind`` meets a concrete matrix.  But a
fleet replaying :class:`~repro.core.plan_store.PlanStore` artifacts wants
infeasible geometry rejected *before* any launch (the paper's whole
premise, applied to the artifact itself): a mis-aligned tile or an
under-provisioned slab bound is knowable from the JSON alone.

This module lints the raw payload dict — **no jax import, no bind, no
repro.core import** — so the same checks run in the jax-free CLI
(``python -m repro.analyze lint-plan``), inside ``PlanStore`` loads
(errors quarantine with reason ``"lint"``), at
``SpMVService.register(strict_lint=)``, and as the ``Planner``'s
self-check on every plan it mints.  The structural constants here
(geometry knobs, 8-alignment, slab arithmetic, recipe defaults) mirror
``core/kernel_tune.py`` / ``kernels/ops.py``; the registry audit and
tests keep them from drifting.

Rule catalog (docs/analysis.md):

  RPL001  schema shape: required/unknown fields, types, schema_version
  RPL002  TileGeometry: unknown knobs, positivity, 8-alignment
          (BCSR block-row tiles may legitimately clamp below 8 -> WARN)
  RPL003  slab-coverage bound vs the static lower bound implied by the
          recorded fingerprint (CSR/BCSR; CCS has no column count to
          bound against)
  RPL004  per-(format, op) geometry-driven VMEM footprint vs budget
  RPL005  SELL bucket table vs the transform recipe (width quantum,
          duplicate widths, bucket count vs slice_rows)
  RPL006  hybrid block structure: contiguous cover from row 0, last end
          == fingerprint n, no nested hybrid, per-block fingerprints
  RPL007  sharded partition: shard spans contiguous, row-axis spans sum
          to nrows, per-shard fingerprints present, nnz conservation,
          mesh shape
  RPL008  transform recipe: name matches fmt, param types
  RPL009  fingerprint self-consistency (mu ~ nnz/n, d_mat ~ sigma/mu)
  RPL010  streaming artifacts (repro.stream): DeltaBatch JSON bounds
          and stream_plan envelopes (nested plan lint, policy ranges,
          sketch consistency)
"""
from __future__ import annotations

import hashlib
import json
import sys
from typing import Any, Dict, List, Optional

from .findings import ERROR, WARN, Finding

#: default ceiling for the geometry-driven VMEM working set (RPL004).
#: Most TPU cores have ~16 MiB of VMEM; the model below deliberately
#: counts only the knob-driven tiles (see docs/analysis.md), so a plan
#: over this budget cannot fit regardless of the matrix it binds.
DEFAULT_VMEM_BUDGET = 16 * 2 ** 20

#: VMEM ceiling for TPU generations with larger on-chip provisioning
#: (v4 and later parts); only used when the running process can prove
#: it is on one (see :func:`default_vmem_budget`)
LARGE_VMEM_BUDGET = 128 * 2 ** 20


def default_vmem_budget() -> int:
    """The RPL004 budget for *this* process's backend.

    This module must stay importable (and linting) without jax — the CLI
    and ``PlanStore`` sweeps run jax-free — so jax is never imported
    here; it is only *queried* when something else already imported it
    (``sys.modules``).  Without jax, or on cpu/gpu backends, or on any
    TPU generation this heuristic does not recognize, the conservative
    16 MiB core budget applies; known v4+ TPU device kinds get the
    larger provisioning.  ``lint_plan(vmem_budget=...)`` always wins
    over this default."""
    jax_mod = sys.modules.get("jax")
    if jax_mod is None:
        return DEFAULT_VMEM_BUDGET
    try:
        dev = jax_mod.devices()[0]
        if getattr(dev, "platform", "") != "tpu":
            return DEFAULT_VMEM_BUDGET
        kind = str(getattr(dev, "device_kind", "")).lower()
    except (RuntimeError, IndexError, AttributeError, ValueError):
        # backend init failure must read as "unknown", not crash a lint
        return DEFAULT_VMEM_BUDGET
    if any(gen in kind for gen in ("v4", "v5", "v6", "v7")):
        return LARGE_VMEM_BUDGET
    return DEFAULT_VMEM_BUDGET

#: mirrors core.plan.SCHEMA_VERSION / SHARDED_SCHEMA_VERSION (the
#: registry audit's job is to notice if these ever drift)
SCHEMA_VERSION = 1
SHARDED_SCHEMA_VERSION = 1
#: mirrors stream.delta.DELTA_SCHEMA_VERSION /
#: stream.drift.STREAM_PLAN_SCHEMA_VERSION (same drift discipline)
DELTA_SCHEMA_VERSION = 1
STREAM_PLAN_SCHEMA_VERSION = 1

KNOWN_FORMATS = ("csr", "ccs", "coo_row", "coo_col", "ell_row", "ell_col",
                 "sell", "bcsr", "hybrid")
KNOWN_OPS = ("spmv", "spmm")
KNOWN_TIERS = ("reference", "kernel")

GEOM_KNOBS = ("block_rows", "block_w", "block_k", "block_nnz",
              "slabs_per_block")
#: knobs each format's kernel wrappers actually read (kernels/ops.py)
_FMT_KNOBS = {
    "ell_row": {"block_rows", "block_w", "block_k"},
    "ell_col": {"block_rows", "block_w", "block_k"},
    "sell": {"block_rows", "block_w", "block_k"},
    "coo_row": {"block_nnz", "block_k"},
    "coo_col": {"block_nnz", "block_k"},
    "csr": {"block_rows", "block_nnz", "block_k", "slabs_per_block"},
    "ccs": {"block_rows", "block_nnz", "block_k", "slabs_per_block"},
    "bcsr": {"block_rows", "block_nnz", "block_k", "slabs_per_block"},
}
#: wrapper defaults used when a knob is absent (kernels/ops.py)
_DEFAULT_BR = {"bcsr": 32}          # others: 256
_DEFAULT_BN = {"bcsr": 512}         # others: 2048
_DEFAULT_BW = 128
_DEFAULT_BK = 128

_EXEC_KEYS = {"schema_version", "fmt", "rule", "tier", "batch",
              "expected_iterations", "transform", "geometry", "machine",
              "d_mat", "d_star", "expected_gain", "fingerprint", "blocks"}
_EXEC_REQUIRED = ("schema_version", "fmt", "rule", "tier", "batch",
                  "expected_iterations", "transform", "geometry")
_SHARDED_KEYS = {"kind", "schema_version", "axis", "strategy", "params",
                 "mesh_shape", "mesh_axis", "batch", "shards",
                 "fingerprint"}
_FP_KEYS = ("n", "nnz", "mu", "sigma", "d_mat", "sig")


def _ceil(a: int, b: int) -> int:
    return -(-int(a) // max(int(b), 1))


def _align8(n: int) -> int:
    return max(8, 8 * ((int(n) + 7) // 8))


def _is_int(v: Any) -> bool:
    return isinstance(v, int) and not isinstance(v, bool)


def _is_num(v: Any) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


class _Lint:
    def __init__(self, vmem_budget: int):
        self.vmem_budget = int(vmem_budget)
        self.findings: List[Finding] = []

    def add(self, rule: str, severity: str, where: str, msg: str) -> None:
        self.findings.append(Finding(rule=rule, severity=severity,
                                     message=msg, where=where))

    def err(self, rule: str, where: str, msg: str) -> None:
        self.add(rule, ERROR, where, msg)

    def warn(self, rule: str, where: str, msg: str) -> None:
        self.add(rule, WARN, where, msg)

    # -- fingerprint (RPL009) ------------------------------------------------
    def fingerprint(self, fp: Any, where: str) -> Optional[Dict[str, Any]]:
        """Validate a fingerprint dict; returns it when structurally
        usable (n/nnz ints) so callers can cross-check against it."""
        w = f"{where}fingerprint"
        if not isinstance(fp, dict):
            self.err("RPL001", w, f"fingerprint must be an object; got "
                                  f"{type(fp).__name__}")
            return None
        for k in fp:
            if k not in _FP_KEYS:
                self.warn("RPL001", w, f"unknown fingerprint field {k!r}")
        for k in ("n", "nnz", "sig"):
            if not _is_int(fp.get(k)):
                self.err("RPL009", w, f"fingerprint.{k} must be an "
                                      f"integer; got {fp.get(k)!r}")
                return None
        n, nnz = fp["n"], fp["nnz"]
        if n < 0 or nnz < 0:
            self.err("RPL009", w, f"fingerprint has negative dimensions "
                                  f"(n={n}, nnz={nnz})")
            return None
        if nnz > 0 and n == 0:
            self.err("RPL009", w, f"nnz={nnz} with n=0 rows")
            return None
        for k in ("mu", "sigma", "d_mat"):
            v = fp.get(k)
            if v is not None and not _is_num(v):
                self.err("RPL009", w, f"fingerprint.{k} must be a number "
                                      f"or null; got {v!r}")
        mu = fp.get("mu")
        if _is_num(mu) and n > 0:
            expect = nnz / n
            if abs(mu - expect) > 1e-6 * max(1.0, expect):
                self.warn("RPL009", w, f"mu={mu:g} but nnz/n={expect:g}")
        sigma, d_mat = fp.get("sigma"), fp.get("d_mat")
        if _is_num(mu) and _is_num(sigma) and _is_num(d_mat) and mu > 0:
            expect = sigma / mu
            if abs(d_mat - expect) > 1e-6 * max(1.0, expect):
                self.warn("RPL009", w,
                          f"d_mat={d_mat:g} but sigma/mu={expect:g}")
        return fp

    # -- geometry (RPL002) ---------------------------------------------------
    def _knobs(self, gd: Dict[str, Any], fmt: str, where: str,
               allow_buckets: bool) -> None:
        relevant = _FMT_KNOBS.get(fmt, set(GEOM_KNOBS))
        for k, v in gd.items():
            if k == "buckets":
                if not allow_buckets:
                    self.warn("RPL002", where, "per-bucket table on a "
                                               "non-SELL geometry")
                self._buckets(v, where)
                continue
            if k not in GEOM_KNOBS:
                self.err("RPL002", where, f"unknown geometry field {k!r}")
                continue
            if not _is_int(v) or v < 1:
                self.err("RPL002", where,
                         f"{k}={v!r} must be a positive integer")
                continue
            if k != "slabs_per_block" and v % 8:
                if fmt == "bcsr" and k == "block_rows":
                    # the BCSR grid clamps row tiles to the block-row
                    # count, which may legitimately fall below 8
                    self.warn("RPL002", where,
                              f"{k}={v} is not 8-aligned (BCSR block-row "
                              f"tiles may clamp below the lane width)")
                else:
                    self.err("RPL002", where, f"{k}={v} is not 8-aligned")
            if k not in relevant:
                self.warn("RPL002", where,
                          f"{k} is not used by the {fmt!r} kernels")

    def _buckets(self, buckets: Any, where: str) -> List[int]:
        w = f"{where}.buckets"
        if not isinstance(buckets, list):
            self.err("RPL002", w, f"buckets must be a list; got "
                                  f"{type(buckets).__name__}")
            return []
        widths: List[int] = []
        for i, pair in enumerate(buckets):
            if (not isinstance(pair, (list, tuple)) or len(pair) != 2
                    or not _is_int(pair[0]) or pair[0] < 1
                    or not isinstance(pair[1], dict)):
                self.err("RPL002", f"{w}[{i}]",
                         "bucket entries must be [width, geometry] pairs")
                continue
            widths.append(pair[0])
            self._knobs(pair[1], "sell", f"{w}[{i}]", allow_buckets=False)
        return widths

    def geometry(self, geo: Any, fmt: str, where: str,
                 fp: Optional[Dict[str, Any]], tier: str,
                 params: Dict[str, Any], batch: int) -> None:
        w = f"{where}geometry"
        if not isinstance(geo, dict):
            self.err("RPL001", w, f"geometry must be an object; got "
                                  f"{type(geo).__name__}")
            return
        for op, gd in geo.items():
            wo = f"{w}.{op}"
            if op not in KNOWN_OPS:
                self.err("RPL002", wo,
                         f"unknown op {op!r}; one of {KNOWN_OPS}")
            if not isinstance(gd, dict):
                self.err("RPL002", wo, f"op geometry must be an object; "
                                       f"got {type(gd).__name__}")
                continue
            if fmt == "hybrid":
                self.warn("RPL006", wo, "hybrid plans carry geometry on "
                                        "their block sub-plans, not at "
                                        "the top level")
                continue
            self._knobs(gd, fmt, wo, allow_buckets=(fmt == "sell"))
            self._slab_bound(gd, fmt, wo, fp, params)
            if tier == "kernel":
                self._vmem(gd, fmt, op, wo, params, batch)

    # -- slab bound (RPL003) -------------------------------------------------
    def _slab_bound(self, gd: Dict[str, Any], fmt: str, where: str,
                    fp: Optional[Dict[str, Any]],
                    params: Dict[str, Any]) -> None:
        spb = gd.get("slabs_per_block")
        if not _is_int(spb) or fmt not in ("csr", "bcsr"):
            # CCS segments columns; the fingerprint has no column count
            # to bound against
            return
        if fp is None:
            self.warn("RPL003", where, "slabs_per_block recorded but the "
                                       "plan has no fingerprint to check "
                                       "it against")
            return
        n, nnz = fp["n"], fp["nnz"]
        br = gd.get("block_rows") or _DEFAULT_BR.get(fmt, 256)
        bn = gd.get("block_nnz") or _DEFAULT_BN.get(fmt, 2048)
        if not _is_int(br) or not _is_int(bn) or br < 1 or bn < 1:
            return                      # RPL002 already reported
        if fmt == "bcsr":
            b = params.get("block")
            b = b if _is_int(b) and b >= 1 else 8
            segments = _ceil(_ceil(n, b), br)    # block-row tiles
            units = _ceil(nnz, b * b)            # >= stored blocks
        else:
            segments = _ceil(n, br)              # row tiles
            units = nnz
        # every launch sweeps segments * spb slabs of bn units each; the
        # recorded structure needs at least ceil(units / (segments * bn))
        # slabs per segment block no matter how the rows distribute
        need = max(1, _ceil(units, max(segments, 1) * bn)) if units else 1
        if spb < need:
            self.err("RPL003", where,
                     f"slabs_per_block={spb} cannot cover the recorded "
                     f"structure: n={n}, nnz={nnz} needs at least {need} "
                     f"slabs per block at block_rows={br}, block_nnz={bn}")

    # -- VMEM footprint (RPL004) ----------------------------------------------
    def _vmem(self, gd: Dict[str, Any], fmt: str, op: str, where: str,
              params: Dict[str, Any], batch: int) -> None:
        size = _footprint(gd, fmt, op, params, batch)
        if size is not None and size > self.vmem_budget:
            self.err("RPL004", where,
                     f"geometry-driven VMEM footprint ~{size / 2**20:.1f} "
                     f"MiB exceeds the {self.vmem_budget / 2**20:.0f} MiB "
                     f"budget")

    # -- SELL recipe vs bucket table (RPL005) ----------------------------------
    def _sell(self, d: Dict[str, Any], where: str,
              fp: Optional[Dict[str, Any]]) -> None:
        params = _params_of(d)
        quantum = params.get("width_quantum", 8)
        slice_rows = params.get("slice_rows", 128)
        if not _is_int(quantum) or quantum < 1:
            self.err("RPL008", f"{where}transform",
                     f"width_quantum={quantum!r} must be a positive "
                     f"integer")
            quantum = 8
        if not _is_int(slice_rows) or slice_rows < 1:
            self.err("RPL008", f"{where}transform",
                     f"slice_rows={slice_rows!r} must be a positive "
                     f"integer")
            slice_rows = 128
        geo = d.get("geometry")
        if not isinstance(geo, dict):
            return
        for op, gd in geo.items():
            if not isinstance(gd, dict) or "buckets" not in gd:
                continue
            w = f"{where}geometry.{op}.buckets"
            widths = [p[0] for p in gd["buckets"]
                      if isinstance(p, (list, tuple)) and len(p) == 2
                      and _is_int(p[0])]
            seen = set()
            for wd in widths:
                if wd % quantum:
                    self.err("RPL005", w,
                             f"bucket width {wd} is not a multiple of the "
                             f"recipe's width_quantum={quantum}")
                if wd in seen:
                    self.err("RPL005", w, f"duplicate bucket width {wd}")
                seen.add(wd)
            if any(b > a for a, b in zip(widths, widths[1:])):
                self.warn("RPL005", w,
                          "bucket widths are not sorted descending (the "
                          "transform emits them widest-first)")
            if fp is not None and widths:
                max_buckets = max(1, _ceil(fp["n"], slice_rows))
                if len(widths) > max_buckets:
                    self.err("RPL005", w,
                             f"{len(widths)} buckets but slice_rows="
                             f"{slice_rows} over n={fp['n']} rows yields "
                             f"at most {max_buckets}")

    # -- transform recipe (RPL008) ---------------------------------------------
    def transform(self, d: Dict[str, Any], fmt: str, where: str) -> None:
        t = d.get("transform")
        w = f"{where}transform"
        if not isinstance(t, dict) or not isinstance(t.get("name"), str):
            self.err("RPL001", w, "transform must be an object with a "
                                  "string 'name'")
            return
        name = t["name"]
        params = t.get("params", {})
        if not isinstance(params, dict):
            self.err("RPL001", w, f"transform.params must be an object; "
                                  f"got {type(params).__name__}")
            return
        if name not in KNOWN_FORMATS:
            self.err("RPL008", w, f"unknown transform {name!r}; one of "
                                  f"{KNOWN_FORMATS}")
        elif name != fmt:
            self.err("RPL008", w,
                     f"transform {name!r} cannot produce fmt {fmt!r} — "
                     f"bind would dispatch the wrong container")
        if name == "bcsr":
            b = params.get("block", 8)
            if not _is_int(b) or b < 1:
                self.err("RPL008", w, f"block={b!r} must be a positive "
                                      f"integer")
        if name in ("csr", "ccs", "coo_row", "coo_col") and params:
            self.warn("RPL008", w,
                      f"the {name!r} transform takes no params; got "
                      f"{sorted(params)}")

    # -- whole plans -----------------------------------------------------------
    def exec_plan(self, d: Dict[str, Any], where: str,
                  allow_hybrid: bool = True) -> Optional[Dict[str, Any]]:
        """Lint one ExecutionPlan payload; returns its fingerprint dict
        (when usable) so containers can cross-check partitions."""
        for k in d:
            if k not in _EXEC_KEYS:
                self.warn("RPL001", f"{where}{k}", "unknown plan field")
        missing = [k for k in _EXEC_REQUIRED if k not in d]
        if missing:
            self.err("RPL001", where or "plan",
                     f"missing required fields {missing}")
            return None
        if d["schema_version"] != SCHEMA_VERSION:
            self.err("RPL001", f"{where}schema_version",
                     f"unsupported schema_version={d['schema_version']!r};"
                     f" this linter reads version {SCHEMA_VERSION}")
        fmt = d["fmt"]
        if not isinstance(fmt, str) or fmt not in KNOWN_FORMATS:
            self.err("RPL001", f"{where}fmt",
                     f"unknown format {fmt!r}; one of {KNOWN_FORMATS}")
            return None
        if d["tier"] not in KNOWN_TIERS:
            self.err("RPL001", f"{where}tier",
                     f"unknown tier {d['tier']!r}; one of {KNOWN_TIERS}")
        if not isinstance(d["rule"], str):
            self.err("RPL001", f"{where}rule", "rule must be a string")
        batch = d["batch"]
        if not _is_int(batch) or batch < 1:
            self.err("RPL001", f"{where}batch",
                     f"batch={batch!r} must be a positive integer")
            batch = 1
        k_iter = d["expected_iterations"]
        if not _is_int(k_iter) or k_iter < 1:
            self.err("RPL001", f"{where}expected_iterations",
                     f"expected_iterations={k_iter!r} must be a positive "
                     f"integer")
        for key in ("d_mat", "d_star", "expected_gain"):
            v = d.get(key)
            if v is not None and not _is_num(v):
                self.err("RPL001", f"{where}{key}",
                         f"must be a number or null; got {v!r}")

        fp = None
        if d.get("fingerprint") is not None:
            fp = self.fingerprint(d["fingerprint"], where)
        self.transform(d, fmt, where)
        tier = d["tier"] if d["tier"] in KNOWN_TIERS else "reference"
        self.geometry(d.get("geometry"), fmt, where, fp, tier,
                      _params_of(d), batch)
        if fmt == "sell":
            self._sell(d, where, fp)

        blocks = d.get("blocks")
        if fmt == "hybrid":
            if not allow_hybrid:
                self.err("RPL006", where or "plan",
                         "hybrid plans cannot nest inside hybrid blocks")
            if not isinstance(blocks, list) or not blocks:
                self.err("RPL006", where or "plan",
                         "hybrid plan has no blocks")
                return fp
            self._hybrid_blocks(blocks, where, fp)
        elif blocks:
            self.err("RPL006", f"{where}blocks",
                     f"leaf plan (fmt={fmt!r}) carries hybrid blocks")
        return fp

    def _hybrid_blocks(self, blocks: List[Any], where: str,
                       fp: Optional[Dict[str, Any]]) -> None:
        prev_end, nnz_sum, all_fp = 0, 0, True
        for i, blk in enumerate(blocks):
            w = f"{where}blocks[{i}]"
            if not isinstance(blk, dict) or "rows" not in blk \
                    or "plan" not in blk:
                self.err("RPL006", w, "block entries must be objects with "
                                      "'rows' and 'plan'")
                return
            rows = blk["rows"]
            if (not isinstance(rows, list) or len(rows) != 2
                    or not all(_is_int(r) for r in rows)):
                self.err("RPL006", f"{w}.rows",
                         f"rows must be an [start, end) integer pair; "
                         f"got {rows!r}")
                return
            s, e = rows
            if s != prev_end or e <= s:
                self.err("RPL006", f"{w}.rows",
                         f"blocks must tile rows contiguously from 0; "
                         f"block {i} covers [{s}, {e}) after row "
                         f"{prev_end}")
            prev_end = e
            if not isinstance(blk["plan"], dict):
                self.err("RPL006", f"{w}.plan", "block plan must be an "
                                                "object")
                continue
            sub_fp = self.exec_plan(blk["plan"], f"{w}.plan.",
                                    allow_hybrid=False)
            if sub_fp is None:
                if blk["plan"].get("fingerprint") is None:
                    self.warn("RPL006", f"{w}.plan",
                              "block sub-plan has no fingerprint")
                all_fp = False
                continue
            nnz_sum += sub_fp["nnz"]
            if sub_fp["n"] != e - s:
                self.err("RPL006", f"{w}.plan.fingerprint",
                         f"sub-plan was minted on {sub_fp['n']} rows but "
                         f"its block spans [{s}, {e})")
        if fp is not None:
            if prev_end != fp["n"]:
                self.err("RPL006", f"{where}blocks",
                         f"blocks cover {prev_end} rows but the plan's "
                         f"fingerprint has n={fp['n']}")
            if all_fp and nnz_sum != fp["nnz"]:
                self.err("RPL006", f"{where}blocks",
                         f"block fingerprints sum to nnz={nnz_sum} but "
                         f"the plan's fingerprint has nnz={fp['nnz']}")

    def sharded(self, d: Dict[str, Any], where: str) -> None:
        for k in d:
            if k not in _SHARDED_KEYS:
                self.warn("RPL001", f"{where}{k}", "unknown plan field")
        if d.get("schema_version") != SHARDED_SCHEMA_VERSION:
            self.err("RPL001", f"{where}schema_version",
                     f"unsupported ShardedPlan schema_version="
                     f"{d.get('schema_version')!r}")
        axis = d.get("axis")
        if axis not in ("row", "col"):
            self.err("RPL007", f"{where}axis",
                     f"unknown sharding axis {axis!r}; one of "
                     f"('row', 'col')")
            axis = "row"
        if not isinstance(d.get("strategy"), str):
            self.err("RPL001", f"{where}strategy",
                     "strategy must be a string")
        batch = d.get("batch", 1)
        if not _is_int(batch) or batch < 1:
            self.err("RPL001", f"{where}batch",
                     f"batch={batch!r} must be a positive integer")
        fp = None
        if d.get("fingerprint") is not None:
            fp = self.fingerprint(d["fingerprint"], where)
        shards = d.get("shards")
        if not isinstance(shards, list) or not shards:
            self.err("RPL007", f"{where}shards",
                     "sharded plan has no shards")
            return
        mesh = d.get("mesh_shape", [])
        if isinstance(mesh, list) and mesh:
            if not all(_is_int(m) and m >= 1 for m in mesh):
                self.err("RPL001", f"{where}mesh_shape",
                         f"mesh_shape must be positive integers; got "
                         f"{mesh!r}")
            else:
                prod = 1
                for m in mesh:
                    prod *= m
                if prod != len(shards):
                    self.warn("RPL007", f"{where}mesh_shape",
                              f"mesh_shape {mesh} addresses {prod} "
                              f"devices but the plan has {len(shards)} "
                              f"shards")
        prev_end, nnz_sum, all_fp = 0, 0, True
        for i, sh in enumerate(shards):
            w = f"{where}shards[{i}]"
            if not isinstance(sh, dict) or "rows" not in sh \
                    or "plan" not in sh:
                self.err("RPL007", w, "shard entries must be objects "
                                      "with 'rows' and 'plan'")
                return
            rows = sh["rows"]
            if (not isinstance(rows, list) or len(rows) != 2
                    or not all(_is_int(r) for r in rows)):
                self.err("RPL007", f"{w}.rows",
                         f"rows must be an [start, end) integer pair; "
                         f"got {rows!r}")
                return
            s, e = rows
            if s != prev_end or e <= s:
                self.err("RPL007", f"{w}.rows",
                         f"shards must tile the {axis} axis contiguously "
                         f"from 0; shard {i} covers [{s}, {e}) after "
                         f"{prev_end}")
            prev_end = e
            if not isinstance(sh["plan"], dict):
                self.err("RPL007", f"{w}.plan", "shard plan must be an "
                                                "object")
                continue
            sub_fp = self.exec_plan(sh["plan"], f"{w}.plan.")
            if sub_fp is None:
                all_fp = False
                if sh["plan"].get("fingerprint") is None:
                    self.err("RPL007", f"{w}.plan",
                             "per-shard fingerprint missing — a replayed "
                             "shard cannot verify its slab")
                continue
            nnz_sum += sub_fp["nnz"]
            if axis == "row" and sub_fp["n"] != e - s:
                self.err("RPL007", f"{w}.plan.fingerprint",
                         f"shard plan was minted on {sub_fp['n']} rows "
                         f"but its slab spans [{s}, {e})")
            if axis == "col" and fp is not None \
                    and sub_fp["n"] != fp["n"]:
                self.err("RPL007", f"{w}.plan.fingerprint",
                         f"column shards keep the full row space "
                         f"(n={fp['n']}) but shard {i} has "
                         f"n={sub_fp['n']}")
        if fp is not None:
            if axis == "row" and prev_end != fp["n"]:
                self.err("RPL007", f"{where}shards",
                         f"shard spans cover {prev_end} rows but the "
                         f"plan's fingerprint has n={fp['n']}")
            if all_fp and nnz_sum != fp["nnz"]:
                self.err("RPL007", f"{where}shards",
                         f"shard fingerprints sum to nnz={nnz_sum} but "
                         f"the plan's fingerprint has nnz={fp['nnz']}")

    # -- streaming artifacts (RPL010) ------------------------------------------
    def _int_list(self, v: Any, where: str, what: str,
                  upper: Optional[int] = None) -> Optional[int]:
        """Check a JSON list of non-negative ints (optionally bounded
        above); returns its length, or None when unusable."""
        if not isinstance(v, list):
            self.err("RPL010", where, f"{what} must be a list; got "
                                      f"{type(v).__name__}")
            return None
        for i, x in enumerate(v):
            if not _is_int(x) or x < 0:
                self.err("RPL010", f"{where}[{i}]",
                         f"{what} entries must be non-negative integers; "
                         f"got {x!r}")
                return None
            if upper is not None and x >= upper:
                self.err("RPL010", f"{where}[{i}]",
                         f"{what} index {x} out of range [0, {upper})")
                return None
        return len(v)

    def delta_batch(self, d: Dict[str, Any], where: str) -> None:
        """A serialized :class:`~repro.stream.delta.DeltaBatch`: the
        bounds that make ``apply_delta`` safe, checkable from JSON."""
        known = {"kind", "schema_version", "n_cols", "appends", "updates",
                 "deletes"}
        for k in d:
            if k not in known:
                self.warn("RPL001", f"{where}{k}", "unknown delta field")
        if d.get("schema_version") != DELTA_SCHEMA_VERSION:
            self.err("RPL010", f"{where}schema_version",
                     f"unsupported delta schema_version="
                     f"{d.get('schema_version')!r}; this linter reads "
                     f"version {DELTA_SCHEMA_VERSION}")
        n_cols = d.get("n_cols")
        if not _is_int(n_cols) or n_cols < 1:
            self.err("RPL010", f"{where}n_cols",
                     f"n_cols={n_cols!r} must be a positive integer")
            n_cols = None
        appends = d.get("appends", [])
        if not isinstance(appends, list):
            self.err("RPL010", f"{where}appends",
                     f"appends must be a list; got "
                     f"{type(appends).__name__}")
        else:
            for i, pair in enumerate(appends):
                w = f"{where}appends[{i}]"
                if not isinstance(pair, (list, tuple)) or len(pair) != 2:
                    self.err("RPL010", w, "append entries must be "
                                          "[cols, vals] pairs")
                    continue
                cols, vals = pair
                nc = self._int_list(cols, f"{w}.cols", "append cols",
                                    upper=n_cols)
                if not isinstance(vals, list):
                    self.err("RPL010", f"{w}.vals",
                             f"append vals must be a list; got "
                             f"{type(vals).__name__}")
                elif not all(_is_num(v) for v in vals):
                    self.err("RPL010", f"{w}.vals",
                             "append vals must be numbers")
                elif nc is not None and len(vals) != nc:
                    self.err("RPL010", w,
                             f"append row has {nc} cols but "
                             f"{len(vals)} vals")
        for section, fields in (("updates", ("rows", "cols", "vals")),
                                ("deletes", ("rows", "cols"))):
            sec = d.get(section, {})
            w = f"{where}{section}"
            if not isinstance(sec, dict):
                self.err("RPL010", w, f"{section} must be an object; got "
                                      f"{type(sec).__name__}")
                continue
            lens = {}
            for f in fields:
                v = sec.get(f, [])
                if f == "vals":
                    if not isinstance(v, list) \
                            or not all(_is_num(x) for x in v):
                        self.err("RPL010", f"{w}.{f}",
                                 f"{section}.{f} must be a list of "
                                 f"numbers")
                        continue
                    lens[f] = len(v)
                else:
                    n = self._int_list(v, f"{w}.{f}", f"{section}.{f}",
                                       upper=(n_cols if f == "cols"
                                              else None))
                    if n is not None:
                        lens[f] = n
            if len(set(lens.values())) > 1:
                self.err("RPL010", w,
                         f"{section} coordinate lists disagree on "
                         f"length: { {f: n for f, n in lens.items()} }")

    def stream_plan(self, d: Dict[str, Any], where: str) -> None:
        """A ``stream_plan`` artifact
        (:meth:`~repro.stream.drift.StreamingPlannedMatrix.to_dict`): the
        wrapped ExecutionPlan gets the full RPL001–RPL009 pass, plus the
        drift-policy and sketch ranges the re-plan trigger relies on."""
        known = {"kind", "schema_version", "key", "plan", "sketch",
                 "policy", "counters"}
        for k in d:
            if k not in known:
                self.warn("RPL001", f"{where}{k}", "unknown stream_plan "
                                                   "field")
        if d.get("schema_version") != STREAM_PLAN_SCHEMA_VERSION:
            self.err("RPL010", f"{where}schema_version",
                     f"unsupported stream_plan schema_version="
                     f"{d.get('schema_version')!r}; this linter reads "
                     f"version {STREAM_PLAN_SCHEMA_VERSION}")
        plan = d.get("plan")
        if not isinstance(plan, dict):
            self.err("RPL010", f"{where}plan",
                     "stream_plan must embed its ExecutionPlan object")
        else:
            self.exec_plan(plan, f"{where}plan.")
        sketch = d.get("sketch")
        fp_n = None
        if not isinstance(sketch, dict):
            self.err("RPL010", f"{where}sketch",
                     "stream_plan must embed its drift sketch")
        else:
            for f in ("n", "nnz", "updates"):
                if not _is_int(sketch.get(f)) or sketch[f] < 0:
                    self.err("RPL010", f"{where}sketch.{f}",
                             f"sketch.{f} must be a non-negative "
                             f"integer; got {sketch.get(f)!r}")
            if not _is_num(sketch.get("sum_sq")) \
                    or sketch["sum_sq"] < 0:
                self.err("RPL010", f"{where}sketch.sum_sq",
                         f"sketch.sum_sq must be a non-negative number; "
                         f"got {sketch.get('sum_sq')!r}")
            hist_n = self._int_list(sketch.get("hist", []),
                                    f"{where}sketch.hist", "sketch.hist")
            if hist_n is not None and _is_int(sketch.get("n")):
                total = sum(sketch["hist"])
                if total != sketch["n"]:
                    self.err("RPL010", f"{where}sketch.hist",
                             f"row-length histogram sums to {total} but "
                             f"the sketch tracks n={sketch['n']} rows")
                fp_n = sketch["n"]
        if isinstance(plan, dict) and fp_n is not None:
            pf = plan.get("fingerprint")
            if isinstance(pf, dict) and _is_int(pf.get("n")) \
                    and pf["n"] != fp_n:
                self.warn("RPL010", f"{where}sketch",
                          f"sketch tracks n={fp_n} rows but the embedded "
                          f"plan was minted on n={pf['n']} — deltas have "
                          f"outgrown the plan (expected between re-plans)")
        policy = d.get("policy")
        if isinstance(policy, dict):
            hyst = policy.get("hysteresis")
            if not _is_num(hyst) or not (0.0 <= hyst < 1.0):
                self.err("RPL010", f"{where}policy.hysteresis",
                         f"hysteresis={hyst!r} must be a number in "
                         f"[0, 1) — at 1 the dead-band swallows the "
                         f"whole boundary")
            for f in ("retransform_factor", "k_hat"):
                v = policy.get(f)
                if v is not None and (not _is_num(v) or v < 0):
                    self.err("RPL010", f"{where}policy.{f}",
                             f"{f}={v!r} must be a non-negative number")
            b = policy.get("batch")
            if b is not None and (not _is_int(b) or b < 1):
                self.err("RPL010", f"{where}policy.batch",
                         f"batch={b!r} must be a positive integer")
            mdb = policy.get("min_deltas_between")
            if mdb is not None and (not _is_int(mdb) or mdb < 0):
                self.err("RPL010", f"{where}policy.min_deltas_between",
                         f"min_deltas_between={mdb!r} must be a "
                         f"non-negative integer")
        elif policy is not None:
            self.err("RPL010", f"{where}policy",
                     f"policy must be an object; got "
                     f"{type(policy).__name__}")
        counters = d.get("counters")
        if isinstance(counters, dict):
            for f, v in counters.items():
                if not _is_int(v) or v < 0:
                    self.err("RPL010", f"{where}counters.{f}",
                             f"counter {f}={v!r} must be a non-negative "
                             f"integer")


def _params_of(d: Dict[str, Any]) -> Dict[str, Any]:
    t = d.get("transform")
    if isinstance(t, dict) and isinstance(t.get("params"), dict):
        return t["params"]
    return {}


def _footprint(gd: Dict[str, Any], fmt: str, op: str,
               params: Dict[str, Any], batch: int) -> Optional[int]:
    """Geometry-driven VMEM working set in bytes, per launch step.

    Counts the buffers whose size the TileGeometry knobs choose — value /
    index slab tiles, segment-pointer windows, and the output tile.  The
    pinned operand ``x`` is excluded: its residency is matrix-shaped
    (``n_cols``), which the plan does not record, and no knob can shrink
    it.  f32 values and i32 indices, 4 bytes each."""
    def knob(name: str, default: int) -> Optional[int]:
        v = gd.get(name, default)
        return v if _is_int(v) and v >= 1 else None

    k = 1
    if op == "spmm":
        bk = knob("block_k", min(_DEFAULT_BK, _align8(max(batch, 1))))
        if bk is None:
            return None
        k = bk
    if fmt in ("ell_row", "ell_col", "sell"):
        br, bw = knob("block_rows", 256), knob("block_w", _DEFAULT_BW)
        if br is None or bw is None:
            return None
        size = br * bw * 8 + bw * k * 4 + br * k * 4
        buckets = gd.get("buckets")
        if fmt == "sell" and isinstance(buckets, list):
            for pair in buckets:
                if (isinstance(pair, (list, tuple)) and len(pair) == 2
                        and isinstance(pair[1], dict)):
                    sub = _footprint(pair[1], "ell_row", op, params, batch)
                    if sub is not None:
                        size = max(size, sub)
        return size
    if fmt in ("coo_row", "coo_col"):
        bn = knob("block_nnz", 65536)
        return None if bn is None else bn * 12 + k * 4
    if fmt in ("csr", "ccs"):
        br = knob("block_rows", _DEFAULT_BR.get(fmt, 256))
        bn = knob("block_nnz", _DEFAULT_BN.get(fmt, 2048))
        if br is None or bn is None:
            return None
        return bn * 8 + (br + 1) * 4 + br * k * 4
    if fmt == "bcsr":
        b = params.get("block")
        b = b if _is_int(b) and b >= 1 else 8
        br = knob("block_rows", _DEFAULT_BR["bcsr"])
        bn = knob("block_nnz", _DEFAULT_BN["bcsr"])
        if br is None or bn is None:
            return None
        return bn * (b * b * 4 + 4) + (br + 1) * 4 + br * b * k * 4
    return None


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------
def lint_plan(payload: Any,
              vmem_budget: Optional[int] = None) -> List[Finding]:
    """Lint a plan payload dict — ExecutionPlan, ShardedPlan, or a
    streaming artifact (``delta_batch`` / ``stream_plan``), routed on
    ``kind``.  Returns findings; empty means clean.  ``vmem_budget``
    defaults to :func:`default_vmem_budget` — the running backend's
    provisioning when knowable, 16 MiB otherwise."""
    lint = _Lint(vmem_budget if vmem_budget is not None
                 else default_vmem_budget())
    if not isinstance(payload, dict):
        lint.err("RPL001", "plan", f"plan payload must be a JSON object; "
                                   f"got {type(payload).__name__}")
        return lint.findings
    kind = payload.get("kind")
    if kind == "sharded_plan":
        lint.sharded(payload, "")
    elif kind == "delta_batch":
        lint.delta_batch(payload, "")
    elif kind == "stream_plan":
        lint.stream_plan(payload, "")
    else:
        lint.exec_plan(payload, "")
    return lint.findings


def lint_envelope(env: Any,
                  vmem_budget: Optional[int] = None) -> List[Finding]:
    """Lint a :class:`~repro.core.plan_store.PlanStore` envelope
    (``{store_version, sha256, plan}``) — checksum verified here with the
    same canonical-JSON convention the store writes, then the payload is
    linted."""
    if (not isinstance(env, dict) or "plan" not in env
            or "sha256" not in env):
        return [Finding("RPL001", ERROR, "not a plan-store envelope "
                        "(missing 'plan'/'sha256')", where="envelope")]
    findings: List[Finding] = []
    if env.get("store_version") != 1:
        findings.append(Finding(
            "RPL001", ERROR, f"unsupported store_version="
            f"{env.get('store_version')!r}", where="envelope"))
    canonical = json.dumps(env["plan"], sort_keys=True,
                           separators=(",", ":"))
    digest = hashlib.sha256(canonical.encode("utf-8")).hexdigest()
    if digest != env["sha256"]:
        findings.append(Finding(
            "RPL001", ERROR, "envelope sha256 does not match the payload "
            "(bit rot or a tampered entry)", where="envelope"))
    findings.extend(lint_plan(env["plan"], vmem_budget=vmem_budget))
    return findings


def lint_text(text: str,
              vmem_budget: Optional[int] = None) -> List[Finding]:
    """Lint raw JSON text: auto-detects bare plan payloads vs store
    envelopes."""
    try:
        obj = json.loads(text)
    except json.JSONDecodeError as e:
        return [Finding("RPL001", ERROR, f"not valid JSON: {e}")]
    if isinstance(obj, dict) and "sha256" in obj and "plan" in obj:
        return lint_envelope(obj, vmem_budget=vmem_budget)
    return lint_plan(obj, vmem_budget=vmem_budget)


__all__ = ["DEFAULT_VMEM_BUDGET", "LARGE_VMEM_BUDGET", "KNOWN_FORMATS",
           "KNOWN_OPS", "KNOWN_TIERS", "GEOM_KNOBS",
           "default_vmem_budget", "lint_plan", "lint_envelope",
           "lint_text"]
