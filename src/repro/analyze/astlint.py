"""AST lint for repo-specific reliability rules (RPA0xx).

Generic linters (ruff runs in CI already) do not know this codebase's
contracts: errors swallowed on the serving path must be *counted*
(``service.swallowed_errors``), serve-side time must come through the
injectable clock so deadline tests stay deterministic, ``repro.obs`` and
``repro.analyze`` are jax-free by design, and any wall-clock measurement
of jax work that skips ``block_until_ready`` times dispatch instead of
execution — the exact trap the paper's ``t_f``/``t_crs`` methodology
exists to avoid.  This pass encodes those contracts.

Rules (catalog with examples in docs/analysis.md):

  RPA001  bare/blind ``except`` whose handler neither re-raises nor
          accounts for the error (a counter ``.inc()``, a call whose
          name mentions swallow/fail, or an assignment to an
          error-named binding)
  RPA002  direct ``time.time()`` / ``perf_counter()`` / ``monotonic()``
          *calls* in ``serve/`` — referencing them as injectable-clock
          defaults is fine; calling them bypasses the injected clock
  RPA003  ``jax`` imports inside declared jax-free packages
          (``repro/obs``, ``repro/analyze``)
  RPA004  a function that samples the clock twice around jax/jnp work
          with no ``block_until_ready`` in sight
  RPA005  mutable default arguments

Waivers: ``# repro: noqa[RPA001]`` (or bare ``# repro: noqa``) on the
flagged line or the line above suppresses the finding.  Waivers are
deliberately scoped to this pass — plan lint and the registry audit
check artifacts and cross-file consistency, where a source-line waiver
has no meaning.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set

from .findings import ERROR, Finding

#: packages that must never import jax (enforced mechanically; the
#: docstrings of repro/obs and repro/analyze declare it)
JAX_FREE_PACKAGES = ("repro/obs", "repro/analyze")

_NOQA = re.compile(r"#.*?repro:\s*noqa(?:\[([A-Za-z0-9, ]+)\])?")
_TIME_ATTRS = {"time", "perf_counter", "perf_counter_ns", "monotonic",
               "monotonic_ns", "process_time"}
_TIME_NAMES = {"perf_counter", "perf_counter_ns", "monotonic",
               "monotonic_ns"}
_ERRORISH = ("error", "err", "drop", "swallow", "fail")


def _waivers(source: str) -> Dict[int, Optional[Set[str]]]:
    """line -> waived rule set (None = all rules) from noqa comments."""
    out: Dict[int, Optional[Set[str]]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _NOQA.search(line)
        if not m:
            continue
        if m.group(1) is None:
            out[i] = None
        else:
            out[i] = {r.strip().upper() for r in m.group(1).split(",")}
    return out


def _waived(waivers: Dict[int, Optional[Set[str]]], rule: str,
            line: int) -> bool:
    for ln in (line, line - 1):
        if ln in waivers:
            rules = waivers[ln]
            if rules is None or rule in rules:
                return True
    return False


def _call_name(call: ast.Call) -> str:
    fn = call.func
    if isinstance(fn, ast.Attribute):
        return fn.attr
    if isinstance(fn, ast.Name):
        return fn.id
    return ""


def _is_timing_call(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    fn = node.func
    if (isinstance(fn, ast.Attribute) and isinstance(fn.value, ast.Name)
            and fn.value.id == "time" and fn.attr in _TIME_ATTRS):
        return True
    return isinstance(fn, ast.Name) and fn.id in _TIME_NAMES


def _is_blind_except(handler: ast.ExceptHandler) -> bool:
    t = handler.type
    if t is None:
        return True
    names = t.elts if isinstance(t, ast.Tuple) else [t]
    return any(isinstance(n, ast.Name)
               and n.id in ("Exception", "BaseException") for n in names)


def _accounts_error(handler: ast.ExceptHandler) -> bool:
    """Does the handler body visibly re-raise or account for the error?"""
    for node in ast.walk(handler):
        if isinstance(node, ast.Raise):
            return True
        if isinstance(node, ast.Call):
            name = _call_name(node).lower()
            if name == "inc" or "swallow" in name or "fail" in name:
                return True
        if isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for tgt in targets:
                tname = ""
                if isinstance(tgt, ast.Name):
                    tname = tgt.id
                elif isinstance(tgt, ast.Attribute):
                    tname = tgt.attr
                if any(tok in tname.lower() for tok in _ERRORISH):
                    return True
    return False


def _jax_import(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Import):
        for alias in node.names:
            if alias.name == "jax" or alias.name.startswith("jax."):
                return alias.name
    if isinstance(node, ast.ImportFrom) and node.module:
        if node.module == "jax" or node.module.startswith("jax."):
            return node.module
    return None


def _references_jax(fn: ast.AST) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and node.id in ("jax", "jnp"):
            return True
    return False


def _has_block_until_ready(fn: ast.AST) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) \
                and _call_name(node) == "block_until_ready":
            return True
    return False


# ---------------------------------------------------------------------------
# per-file lint
# ---------------------------------------------------------------------------
def lint_source(source: str, path: str = "<input>") -> List[Finding]:
    findings: List[Finding] = []
    try:
        tree = ast.parse(source, path)
    except SyntaxError as e:
        return [Finding("RPA000", ERROR, f"does not parse: {e.msg}",
                        where=path, line=e.lineno or 0)]
    waivers = _waivers(source)
    posix = Path(path).as_posix()
    in_serve = "/serve/" in posix or posix.startswith("serve/")
    jax_free = any(pkg in posix for pkg in JAX_FREE_PACKAGES)

    def add(rule: str, line: int, msg: str) -> None:
        if not _waived(waivers, rule, line):
            findings.append(Finding(rule, ERROR, msg, where=path,
                                    line=line))

    for node in ast.walk(tree):
        if isinstance(node, ast.ExceptHandler) and _is_blind_except(node):
            if not _accounts_error(node):
                add("RPA001", node.lineno,
                    "blind except swallows the error without re-raising "
                    "or accounting for it (counter .inc(), a "
                    "swallow/fail helper, or an error-named binding)")
        if in_serve and _is_timing_call(node):
            add("RPA002", node.lineno,
                "direct clock call on the serving path — route time "
                "through the injectable clock (SpMVService(clock=...)) "
                "so deadline logic stays testable")
        if jax_free:
            mod = _jax_import(node)
            if mod is not None:
                add("RPA003", node.lineno,
                    f"import of {mod!r} inside a declared jax-free "
                    f"package ({', '.join(JAX_FREE_PACKAGES)})")
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            samples = sum(1 for n in ast.walk(node) if _is_timing_call(n))
            if (samples >= 2 and _references_jax(node)
                    and not _has_block_until_ready(node)):
                add("RPA004", node.lineno,
                    f"{node.name!r} samples the clock {samples}x around "
                    f"jax work without block_until_ready — it times "
                    f"dispatch, not execution")
            for default in [*node.args.defaults,
                            *node.args.kw_defaults]:
                if default is None:
                    continue
                mutable = isinstance(default,
                                     (ast.List, ast.Dict, ast.Set))
                if (isinstance(default, ast.Call)
                        and isinstance(default.func, ast.Name)
                        and default.func.id in ("list", "dict", "set")):
                    mutable = True
                if mutable:
                    add("RPA005", default.lineno,
                        f"mutable default argument in {node.name!r} is "
                        f"shared across calls — default to None and "
                        f"materialize inside")
    findings.sort(key=lambda f: (f.line, f.rule))
    return findings


def lint_paths(paths: Iterable[str]) -> List[Finding]:
    """Lint ``.py`` files and directories (recursively)."""
    findings: List[Finding] = []
    for p in paths:
        path = Path(p)
        files = sorted(path.rglob("*.py")) if path.is_dir() else [path]
        for f in files:
            try:
                source = f.read_text(encoding="utf-8")
            except OSError as e:
                findings.append(Finding("RPA000", ERROR,
                                        f"unreadable: {e}", where=str(f)))
                continue
            findings.extend(lint_source(source, str(f)))
    return findings


__all__ = ["JAX_FREE_PACKAGES", "lint_source", "lint_paths"]
