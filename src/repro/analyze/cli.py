"""``python -m repro.analyze`` — the jax-free static-analysis CLI.

    python -m repro.analyze lint-plan plan.json [...] [--vmem-budget MiB]
    python -m repro.analyze audit [--src src] [--docs docs/observability.md]
    python -m repro.analyze lint-src src/ [more paths ...]

Exit codes: 0 clean (warnings allowed unless ``--strict-warn``), 1 at
least one ERROR finding, 2 usage error.  ``lint-plan`` accepts both bare
plan payloads and ``PlanStore`` envelopes (``{store_version, sha256,
plan}``) and verifies the checksum on the latter.  None of the
subcommands import jax — CI runs all three on a bare interpreter.
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional, Sequence

from . import astlint, planlint, registry
from .findings import Finding, has_errors, render


def _report(findings: List[Finding], strict_warn: bool,
            label: str) -> int:
    if findings:
        print(render(findings))
    bad = has_errors(findings) or (strict_warn and findings)
    n_err = sum(1 for f in findings if f.severity == "error")
    n_warn = len(findings) - n_err
    print(f"{label}: {n_err} error(s), {n_warn} warning(s)")
    return 1 if bad else 0


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analyze",
        description="static analysis for plans, registries, and source "
                    "(jax-free)")
    parser.add_argument("--strict-warn", action="store_true",
                        help="exit nonzero on warnings too")
    sub = parser.add_subparsers(dest="cmd")

    p_plan = sub.add_parser("lint-plan",
                            help="lint ExecutionPlan/ShardedPlan/"
                                 "stream-artifact JSON (bare payloads "
                                 "or store envelopes)")
    p_plan.add_argument("paths", nargs="+", metavar="plan.json")
    p_plan.add_argument("--vmem-budget", type=float, default=None,
                        metavar="MIB",
                        help="VMEM budget for RPL004 in MiB (default: "
                             "queried from the running backend when jax "
                             "is importable — 16 on CPU/GPU/unknown, 128 "
                             "on TPU v4+ — and 16 in jax-free runs)")

    p_audit = sub.add_parser("audit",
                             help="cross-registry + telemetry-vocabulary "
                                  "consistency audit")
    p_audit.add_argument("--src", default="src")
    p_audit.add_argument("--docs", default="docs/observability.md")

    p_src = sub.add_parser("lint-src", help="AST lint (rules RPA0xx)")
    p_src.add_argument("paths", nargs="+", metavar="path")

    args = parser.parse_args(argv)
    if args.cmd is None:
        parser.print_help()
        return 2

    if args.cmd == "lint-plan":
        budget = None
        if args.vmem_budget is not None:
            if args.vmem_budget <= 0:
                parser.error("--vmem-budget must be positive")
            budget = int(args.vmem_budget * 2 ** 20)
        findings: List[Finding] = []
        for path in args.paths:
            try:
                with open(path, "r", encoding="utf-8") as fh:
                    text = fh.read()
            except OSError as e:
                print(f"{path}: unreadable: {e}", file=sys.stderr)
                return 2
            for f in planlint.lint_text(text, vmem_budget=budget):
                findings.append(Finding(f.rule, f.severity, f.message,
                                        where=f"{path}:{f.where}"
                                        if f.where else path,
                                        line=f.line))
        return _report(findings, args.strict_warn, "lint-plan")

    if args.cmd == "audit":
        return _report(registry.audit(src=args.src, docs=args.docs),
                       args.strict_warn, "audit")

    # lint-src
    return _report(astlint.lint_paths(args.paths), args.strict_warn,
                   "lint-src")


if __name__ == "__main__":
    sys.exit(main())
