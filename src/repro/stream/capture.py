"""Workload capture: record query/update traces as JSONL.

The trace format rides the ``repro.obs`` JSONL conventions (one JSON
object per line, ``read_jsonl``-loadable) so the same tooling that reads
telemetry streams reads workload traces.  Three record kinds:

* ``stream.base``  — the matrix a trace starts from (shape + nnz, for
  replay sanity checks)
* ``stream.query`` — one ``P @ x`` arrival (op + batch width)
* ``stream.delta`` — one :class:`~repro.stream.delta.DeltaBatch`, embedded
  in its JSON form

Timestamps come from an injectable clock (default
``time.perf_counter``) so tests capture with
:class:`repro.obs.FakeClock` deterministically.  See
:mod:`repro.stream.replay` for the consuming side.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Callable, Dict, List, Optional

from repro.obs import read_jsonl

from .delta import DeltaBatch

#: trace format version, stamped on every record
TRACE_VERSION = 1


class TraceCapture:
    """Append-only JSONL workload trace recorder.

    >>> with TraceCapture("/tmp/trace.jsonl") as cap:
    ...     cap.base("web", csr)
    ...     cap.query("web", batch=8)
    ...     cap.delta("web", delta)

    Attach to a :class:`~repro.stream.drift.StreamingPlannedMatrix` via
    ``capture=`` and every apply/query records itself.
    """

    def __init__(self, path: str,
                 clock: Optional[Callable[[], float]] = None):
        self.path = str(path)
        self.clock = clock if clock is not None else time.perf_counter
        self._f = open(self.path, "a")
        self._lock = threading.Lock()
        self.records = 0
        self.dropped = 0

    def _write(self, rec: Dict[str, Any]) -> None:
        # capture rides the serving path: a closed or failing trace file
        # drops the record (counted), it never takes down a query — the
        # same discipline repro.obs applies to its sinks
        with self._lock:
            if self._f.closed:
                self.dropped += 1
                return
            try:
                json.dump(rec, self._f, sort_keys=True)
                self._f.write("\n")
                self._f.flush()
            except (OSError, ValueError):
                self.dropped += 1
                return
            self.records += 1

    # -- record kinds ---------------------------------------------------------
    def base(self, key: str, csr: Any) -> None:
        self._write({"kind": "stream.base", "v": TRACE_VERSION,
                     "t": float(self.clock()), "key": key,
                     "n_rows": int(csr.n_rows), "n_cols": int(csr.n_cols),
                     "nnz": int(csr.nnz)})

    def query(self, key: str, batch: int = 1, op: str = "spmv") -> None:
        self._write({"kind": "stream.query", "v": TRACE_VERSION,
                     "t": float(self.clock()), "key": key,
                     "op": op, "batch": int(batch)})

    def delta(self, key: str, delta: DeltaBatch) -> None:
        self._write({"kind": "stream.delta", "v": TRACE_VERSION,
                     "t": float(self.clock()), "key": key,
                     "delta": delta.to_dict()})

    # -- lifecycle ------------------------------------------------------------
    def close(self) -> None:
        with self._lock:
            if not self._f.closed:
                self._f.close()

    def __enter__(self) -> "TraceCapture":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
        return None


def load_trace(path: str) -> List[Dict[str, Any]]:
    """Load a captured trace, sorted by timestamp (records from several
    concurrent captures interleave correctly)."""
    recs = [r for r in read_jsonl(path)
            if r.get("kind", "").startswith("stream.")]
    return sorted(recs, key=lambda r: float(r.get("t", 0.0)))


__all__ = ["TRACE_VERSION", "TraceCapture", "load_trace"]
