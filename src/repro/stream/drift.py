"""Drift detection + amortized re-planning for mutating matrices.

A bound :class:`~repro.core.plan.ExecutionPlan` froze a format decision at
one ``D_mat = sigma/mu``.  As deltas land, the row-length distribution —
and with it the paper's decision variable — drifts.  This module keeps an
O(Δ)-updatable :class:`DriftSketch` of (mu, sigma, D_mat, row-length
histogram), and a :class:`ReplanPolicy` that re-mints the plan only when
**both** hold:

1. **Boundary crossing** — the paper rule's from-scratch pick at the
   current D_mat differs from the bound plan's format, and D_mat sits
   outside a relative hysteresis band around ``D*`` (so a matrix
   oscillating near the boundary never churns);
2. **Streaming amortization** — the paper's rule
   ``k·B·(t_crs−t_f) > t_trans`` extended with the expected cost of
   *future* re-transforms: ``k̂·(1 − 1/sp) > tt·(1 + E[re-transform])``
   in t_crs-per-call units, with ``k̂`` estimated from the observed
   query/update interarrival ratio and (sp, tt) from
   :meth:`TuningDB.predict`.

:class:`StreamingPlannedMatrix` packages the loop: it wraps a
``PlannedMatrix`` with ``apply(delta)`` / ``@``, updating CSR and SELL
containers incrementally (:mod:`repro.stream.delta`) and re-planning
through the :class:`~repro.core.plan.Planner` when the policy fires.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

import numpy as np

import repro.obs as _obs
from repro.core.formats import CSR
from .delta import (INCREMENTAL_FORMATS, DeltaApplyResult, DeltaBatch,
                    apply_delta)

#: log2 row-length histogram resolution of the sketch
HIST_BUCKETS = 32

STREAM_PLAN_SCHEMA_VERSION = 1


def _hist_index(lens: np.ndarray) -> np.ndarray:
    """Bucket i holds rows with length in [2^(i-1), 2^i); bucket 0 = empty
    rows."""
    lens = np.asarray(lens, dtype=np.int64)
    idx = np.zeros(lens.shape[0], dtype=np.int64)
    pos = lens > 0
    idx[pos] = np.floor(np.log2(lens[pos])).astype(np.int64) + 1
    return np.clip(idx, 0, HIST_BUCKETS - 1)


@dataclass
class DriftSketch:
    """Running (n, Σlen, Σlen², histogram) over row lengths — enough to
    recover mu/sigma/D_mat exactly (population stddev, as the paper uses)
    while each delta costs O(rows touched) to fold in."""

    n: int = 0
    nnz: int = 0
    sum_sq: float = 0.0
    hist: np.ndarray = field(
        default_factory=lambda: np.zeros(HIST_BUCKETS, dtype=np.int64))
    updates: int = 0

    @classmethod
    def of(cls, csr: CSR) -> "DriftSketch":
        ip = np.asarray(csr.indptr)
        lens = (ip[1:] - ip[:-1]).astype(np.int64)
        sk = cls(n=int(csr.n_rows), nnz=int(lens.sum()),
                 sum_sq=float((lens.astype(np.float64) ** 2).sum()))
        np.add.at(sk.hist, _hist_index(lens), 1)
        return sk

    # -- derived --------------------------------------------------------------
    @property
    def mu(self) -> float:
        return self.nnz / self.n if self.n else 0.0

    @property
    def sigma(self) -> float:
        if not self.n:
            return 0.0
        var = self.sum_sq / self.n - self.mu ** 2
        return math.sqrt(max(var, 0.0))

    @property
    def d_mat(self) -> float:
        mu = self.mu
        return self.sigma / mu if mu > 0 else float("inf")

    # -- folding a delta in ---------------------------------------------------
    def update(self, res: DeltaApplyResult) -> "DriftSketch":
        app = np.asarray(res.appended_lens, dtype=np.int64)
        old = np.asarray(res.old_lens, dtype=np.float64)
        new = np.asarray(res.new_lens, dtype=np.float64)
        self.n += int(app.shape[0])
        self.nnz += int(app.sum()) + int(new.sum() - old.sum())
        self.sum_sq += float((app.astype(np.float64) ** 2).sum()) \
            + float((new ** 2).sum() - (old ** 2).sum())
        if app.size:
            np.add.at(self.hist, _hist_index(app), 1)
        if old.size:
            np.add.at(self.hist, _hist_index(old), -1)
            np.add.at(self.hist, _hist_index(new), 1)
        self.updates += 1
        return self

    def to_dict(self) -> Dict[str, Any]:
        return {"n": int(self.n), "nnz": int(self.nnz),
                "sum_sq": float(self.sum_sq),
                "hist": self.hist.tolist(), "updates": int(self.updates)}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "DriftSketch":
        return cls(n=int(d["n"]), nnz=int(d["nnz"]),
                   sum_sq=float(d["sum_sq"]),
                   hist=np.asarray(d.get("hist",
                                         np.zeros(HIST_BUCKETS)),
                                   dtype=np.int64),
                   updates=int(d.get("updates", 0)))


@dataclass
class DriftDecision:
    replan: bool
    target_fmt: str
    reason: str          #: stable | no_boundary | hysteresis | cooldown |
    #: unamortized | replan
    d_mat: float
    d_star: float
    k_hat: float


@dataclass
class ReplanPolicy:
    """When is re-minting the plan worth it?  See the module docstring for
    the two-condition trigger; every evaluation emits a ``stream.drift``
    event so trigger precision is auditable from traces."""

    db: Any = None                      #: TuningDB for D* + (sp, tt)
    fmt: str = "ell_row"                #: the paper rule's candidate format
    d_star: Optional[float] = None      #: override; else ``db.d_star[fmt]``
    hysteresis: float = 0.15            #: relative dead band around D*
    retransform_factor: float = 1.0     #: E[future re-transforms] per plan
    batch: int = 1
    default_k: float = 100.0            #: k̂ before any queries are seen
    ema_alpha: float = 0.3
    min_deltas_between: int = 1         #: replan cooldown, in deltas
    # -- state --
    k_hat: float = 0.0
    queries_since_update: int = 0
    deltas_since_replan: int = 0

    def note_query(self, n: int = 1) -> None:
        self.queries_since_update += n

    def note_update(self) -> None:
        q = float(self.queries_since_update)
        self.k_hat = q if self.k_hat == 0.0 else (
            self.ema_alpha * q + (1.0 - self.ema_alpha) * self.k_hat)
        self.queries_since_update = 0
        self.deltas_since_replan += 1

    def boundary(self) -> float:
        if self.d_star is not None:
            return float(self.d_star)
        if self.db is not None:
            return float(self.db.d_star.get(self.fmt, 0.0))
        return 0.0

    def decide(self, d_mat: float, current_fmt: str,
               key: str = "") -> DriftDecision:
        ds = self.boundary()
        k = self.k_hat if self.k_hat > 0 else self.default_k
        target = self.fmt if d_mat < ds else "csr"

        if ds <= 0:
            reason = "no_boundary"
        elif target == current_fmt:
            reason = "stable"
        elif math.isfinite(d_mat) and abs(d_mat - ds) <= self.hysteresis * ds:
            reason = "hysteresis"
        elif self.deltas_since_replan < self.min_deltas_between:
            reason = "cooldown"
        elif target != "csr" and self.db is not None:
            # moving *into* a transformed format pays a transform now and
            # (retransform_factor ×) again later — charge both up front
            pred = self.db.predict(target, d_mat, batch=self.batch)
            lhs = k * (1.0 - 1.0 / max(pred["sp"], 1e-9))
            rhs = pred["tt"] * (1.0 + self.retransform_factor)
            reason = "replan" if (math.isfinite(rhs) and lhs > rhs) \
                else "unamortized"
        else:
            # moving back to CSR is transform-free: crossing alone decides
            reason = "replan"

        dec = DriftDecision(replan=(reason == "replan"), target_fmt=target,
                            reason=reason, d_mat=float(d_mat),
                            d_star=float(ds), k_hat=float(k))
        tel = _obs.get()
        if tel.enabled:
            tel.event("stream.drift", key=key, current_fmt=current_fmt,
                      target_fmt=target, reason=reason, d_mat=dec.d_mat,
                      d_star=dec.d_star, k_hat=dec.k_hat)
        return dec

    def to_dict(self) -> Dict[str, Any]:
        return {"fmt": self.fmt, "d_star": self.boundary(),
                "hysteresis": float(self.hysteresis),
                "retransform_factor": float(self.retransform_factor),
                "batch": int(self.batch), "k_hat": float(self.k_hat),
                "min_deltas_between": int(self.min_deltas_between)}


class StreamingPlannedMatrix:
    """A :class:`~repro.core.plan.PlannedMatrix` that absorbs deltas.

    ``apply(delta)`` updates the source CSR and — when the bound plan is a
    single-block ``csr``/``sell`` leaf — the serving container in place;
    any other shape falls back to re-minting the plan on the updated
    matrix (a full re-transform, with its cost recorded).  ``@`` delegates
    to the bound matrix while counting queries for the k̂ estimate.
    """

    def __init__(self, csr: CSR, planner: Any, *,
                 plan: Any = None, policy: Optional[ReplanPolicy] = None,
                 capture: Any = None, key: str = "stream",
                 plan_kw: Optional[dict] = None,
                 bind_kw: Optional[dict] = None):
        csr.validate()
        self.planner = planner
        self.key = key
        self.plan_kw = dict(plan_kw or {})
        self.bind_kw = dict(bind_kw or {})
        self.csr = csr
        self.plan = plan if plan is not None \
            else planner.plan(csr, **self.plan_kw)
        self.bound = self.plan.bind(csr, db=planner.db, **self.bind_kw)
        self.policy = policy if policy is not None else ReplanPolicy(
            db=planner.db, batch=int(getattr(self.plan, "batch", 1) or 1))
        self.sketch = DriftSketch.of(csr)
        self.capture = capture
        self.applies = 0
        self.queries = 0
        self.replans = 0
        self.fallbacks = 0
        self.last_decision: Optional[DriftDecision] = None
        if capture is not None:
            capture.base(self.key, csr)

    # -- delta path -----------------------------------------------------------
    def apply(self, delta: DeltaBatch) -> DeltaApplyResult:
        self.applies += 1
        if self.capture is not None:
            self.capture.delta(self.key, delta)
        hyb = self.bound.matrix
        n_blocks = getattr(hyb, "n_blocks", None)
        if n_blocks is None:
            # non-hybrid bind: the plan's container *is* the single leaf
            leaf = self.plan.fmt in INCREMENTAL_FORMATS
            fmt, container = self.plan.fmt, hyb
        else:
            leaf = (n_blocks == 1 and hyb.identity_perm
                    and hyb.formats[0] in INCREMENTAL_FORMATS)
            fmt = hyb.formats[0] if leaf else ""
            container = hyb.blocks[0] if leaf else None
        if leaf:
            res = apply_delta(self.csr, delta, container=container,
                              fmt=fmt, key=self.key,
                              transform_params=dict(
                                  self.plan.transform.params or {}))
            self.csr = res.csr
            self._swap_container(res.container, fmt,
                                 hybrid=n_blocks is not None)
        else:
            # multi-block / non-incremental formats: update the CSR, then
            # pay a full re-materialize (recorded as a fallback rebuild)
            res = apply_delta(self.csr, delta, fmt="csr", key=self.key)
            self.csr = res.csr
            self.plan = self.planner.plan(self.csr, **self.plan_kw)
            self.bound = self.plan.bind(self.csr, db=self.planner.db,
                                        **self.bind_kw)
            res.fallback, res.fallback_reason = True, "nonleaf"
            res.mode = "rebuild"
        if res.fallback:
            self.fallbacks += 1

        self.sketch.update(res)
        self.policy.note_update()
        dec = self.policy.decide(self.sketch.d_mat,
                                 current_fmt=self.plan.fmt, key=self.key)
        self.last_decision = dec
        if dec.replan:
            self._replan()
        return res

    def _swap_container(self, container: Any, fmt: str,
                        hybrid: bool = True) -> None:
        if hybrid:
            from repro.partition.hybrid import HybridMatrix
            container = HybridMatrix(
                perm=np.arange(self.csr.n_rows, dtype=np.int32),
                blocks=(container,), row_offsets=(0,), formats=(fmt,),
                shape=self.csr.shape, nnz=self.csr.nnz, identity_perm=True)
        self.bound.matrix = container
        self.bound.source = self.csr

    def _replan(self) -> None:
        old_fmt = self.plan.fmt
        self.plan = self.planner.plan(self.csr, **self.plan_kw)
        self.bound = self.plan.bind(self.csr, db=self.planner.db,
                                    **self.bind_kw)
        self.policy.deltas_since_replan = 0
        self.replans += 1
        tel = _obs.get()
        if tel.enabled:
            tel.counter("stream.replans", key=self.key).inc()
            tel.event("stream.replan", key=self.key, old_fmt=old_fmt,
                      new_fmt=self.plan.fmt, d_mat=self.sketch.d_mat,
                      replans=self.replans)

    # -- query path -----------------------------------------------------------
    def __matmul__(self, x):
        self.queries += 1
        self.policy.note_query()
        if self.capture is not None:
            xa = np.asarray(x)
            self.capture.query(self.key,
                               batch=int(xa.shape[1]) if xa.ndim == 2 else 1)
        return self.bound @ x

    def __call__(self, x):
        return self @ x

    # -- introspection --------------------------------------------------------
    @property
    def fmt(self) -> str:
        return self.plan.fmt

    @property
    def shape(self):
        return self.csr.shape

    @property
    def d_mat(self) -> float:
        return self.sketch.d_mat

    def to_dict(self) -> Dict[str, Any]:
        """The ``stream_plan`` JSON artifact (linted by RPL010)."""
        return {"kind": "stream_plan",
                "schema_version": STREAM_PLAN_SCHEMA_VERSION,
                "key": self.key,
                "plan": self.plan.to_dict(),
                "sketch": self.sketch.to_dict(),
                "policy": self.policy.to_dict(),
                "counters": {"applies": self.applies,
                             "queries": self.queries,
                             "replans": self.replans,
                             "fallbacks": self.fallbacks}}

    def __repr__(self) -> str:
        return (f"StreamingPlannedMatrix(key={self.key!r}, "
                f"fmt={self.fmt!r}, shape={self.shape}, "
                f"d_mat={self.d_mat:.3f}, applies={self.applies}, "
                f"replans={self.replans})")


__all__ = ["HIST_BUCKETS", "STREAM_PLAN_SCHEMA_VERSION", "DriftSketch",
           "DriftDecision", "ReplanPolicy", "StreamingPlannedMatrix"]
