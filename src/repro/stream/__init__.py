"""repro.stream — dynamic matrices for the tuned serving stack.

Production matrices mutate: graph edges arrive, KV pages fill, MoE
routing shifts.  This package keeps the paper's run-time-transformation
economics honest under mutation:

* :mod:`repro.stream.delta` — :class:`DeltaBatch` edits applied to CSR
  and SELL containers **incrementally** (O(Δnnz) tail appends, per-slice
  SELL rebuilds) with a validated full-re-transform fallback for every
  other format;
* :mod:`repro.stream.drift` — an O(Δ)-updatable (mu, sigma, D_mat)
  sketch, the hysteresis + streaming-amortization re-plan trigger, and
  :class:`StreamingPlannedMatrix` gluing both onto a bound plan;
* :mod:`repro.stream.capture` / :mod:`repro.stream.replay` — JSONL
  workload traces recorded at serve time and replayed through
  ``offline_phase`` so tuning sees the real access pattern.

See ``docs/streaming.md`` for the delta schema, drift rule, and
amortized accounting.
"""
from .capture import TRACE_VERSION, TraceCapture, load_trace
from .delta import (DELTA_SCHEMA_VERSION, INCREMENTAL_FORMATS, DeltaBatch,
                    DeltaApplyResult, apply_delta, random_delta, sell_apply)
from .drift import (HIST_BUCKETS, STREAM_PLAN_SCHEMA_VERSION, DriftDecision,
                    DriftSketch, ReplanPolicy, StreamingPlannedMatrix)
from .replay import ReplayStats, epochs_of, replay, replay_file

__all__ = [
    "DELTA_SCHEMA_VERSION", "INCREMENTAL_FORMATS", "DeltaBatch",
    "DeltaApplyResult", "apply_delta", "random_delta", "sell_apply",
    "HIST_BUCKETS", "STREAM_PLAN_SCHEMA_VERSION", "DriftDecision",
    "DriftSketch", "ReplanPolicy", "StreamingPlannedMatrix",
    "TRACE_VERSION", "TraceCapture", "load_trace",
    "ReplayStats", "epochs_of", "replay", "replay_file",
]
