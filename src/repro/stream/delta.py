"""Incremental transforms: apply a :class:`DeltaBatch` without re-transforming.

The paper's amortization rule ``k·B·(t_crs−t_f) > t_trans`` prices the
transform as a one-time cost — a mutating matrix pays it on every change
unless the transformed container can absorb the change *incrementally*.
This module is that absorber:

* **CSR** — whole-row appends are O(Δnnz) tail writes into the existing
  ``nnz_pad`` slack (:func:`repro.core.transform.csr_append_rows`); value
  overwrites are O(Δ) in-place stores; nnz inserts/deletes degrade to one
  vectorized O(nnz) splice (:func:`~repro.core.transform.csr_splice`) —
  still far below a format re-transform.
* **SELL** (:class:`~repro.core.formats.BucketedELL`) — value updates
  rewrite only the affected row slice; appended or relocated rows rebuild
  only their target bucket; the widest bucket widens in place when a row
  outgrows every bucket.  All :meth:`BucketedELL.validate` invariants
  (permutation, contiguous tiling, strictly decreasing widths, nnz
  accounting) are preserved.
* **Every other format** falls back to a full re-transform from the
  updated CSR, with the cost recorded (``mode="rebuild"``) so the drift
  layer can price it honestly.

Safety: the updated CSR is validated after every apply, the incrementally
updated container goes through ``validate_container``, and a failed
container (including one poisoned by the ``delta.corrupt`` chaos fault)
degrades to a clean full re-transform — a bad delta apply costs time,
never correctness.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

import repro.obs as _obs
from repro.core.formats import (CSR, ELL, BucketedELL, MatrixValidationError,
                                validate_container)
from repro.core.transform import (csr_append_rows, csr_set_values, csr_splice,
                                  pad_to_multiple)
from repro.serve import faults as _faults

#: version stamp carried by the JSON form (lint + capture traces key on it)
DELTA_SCHEMA_VERSION = 1

#: formats apply_delta can update incrementally; everything else rebuilds
INCREMENTAL_FORMATS = ("csr", "sell")


def _empty_i() -> np.ndarray:
    return np.zeros(0, dtype=np.int64)


def _empty_f() -> np.ndarray:
    return np.zeros(0, dtype=np.float32)


@dataclass(frozen=True)
class DeltaBatch:
    """One batch of structural/value changes to a sparse matrix.

    Three change kinds, applied in this order:

    * ``update_*`` — point writes ``A[r, c] = v``: overwrite when the
      entry exists, insert when absent.  Rows must already exist.
    * ``delete_*`` — remove stored entries ``(r, c)``; absent entries are
      ignored (idempotent deletes).
    * ``append_*`` — whole new rows at the tail, as per-row (cols, vals)
      array pairs (the matrix grows by ``len(append_cols)`` rows).

    The column count is fixed: deltas never change ``n_cols``.
    """

    n_cols: int
    append_cols: Tuple[np.ndarray, ...] = ()
    append_vals: Tuple[np.ndarray, ...] = ()
    update_rows: np.ndarray = field(default_factory=_empty_i)
    update_cols: np.ndarray = field(default_factory=_empty_i)
    update_vals: np.ndarray = field(default_factory=_empty_f)
    delete_rows: np.ndarray = field(default_factory=_empty_i)
    delete_cols: np.ndarray = field(default_factory=_empty_i)

    # -- shape ----------------------------------------------------------------
    @property
    def n_appends(self) -> int:
        return len(self.append_cols)

    @property
    def nnz_delta(self) -> int:
        """Upper bound on touched nonzeros (appends + updates + deletes)."""
        app = int(sum(len(c) for c in self.append_cols))
        return app + int(self.update_rows.shape[0]) \
            + int(self.delete_rows.shape[0])

    @property
    def empty(self) -> bool:
        return self.nnz_delta == 0

    def _append_flat(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """``(lens, flat_cols, flat_vals)`` over the appended rows,
        memoized — the batch is frozen, so the flattening is paid once no
        matter how many times the delta is validated or applied."""
        cached = getattr(self, "_flat_cache", None)
        if cached is None:
            k = len(self.append_cols)
            lens = np.fromiter((len(np.asarray(c)) for c in self.append_cols),
                               count=k, dtype=np.int64)
            if k and int(lens.sum()):
                flat_c = np.concatenate(
                    [np.asarray(c, dtype=np.int64) for c in self.append_cols])
                flat_v = np.concatenate(
                    [np.asarray(v, dtype=np.float32)
                     for v in self.append_vals])
            else:
                flat_c, flat_v = _empty_i(), _empty_f()
            cached = (lens, flat_c, flat_v)
            object.__setattr__(self, "_flat_cache", cached)
        return cached

    # -- validation -----------------------------------------------------------
    def validate(self, n_rows: Optional[int] = None) -> "DeltaBatch":
        """Raise :class:`ValueError` on the first malformed field."""
        if self.n_cols <= 0:
            raise ValueError(f"n_cols must be positive; got {self.n_cols}")
        if len(self.append_cols) != len(self.append_vals):
            raise ValueError(
                f"{len(self.append_cols)} appended col rows vs "
                f"{len(self.append_vals)} value rows")
        if self.append_cols and not getattr(self, "_appends_ok", False):
            k = len(self.append_cols)
            v_lens = np.fromiter((len(np.asarray(v))
                                  for v in self.append_vals),
                                 count=k, dtype=np.int64)
            c_lens, allc, _ = self._append_flat()
            bad = np.nonzero(c_lens != v_lens)[0]
            if bad.size:
                i = int(bad[0])
                raise ValueError(f"appended row {i}: {c_lens[i]} cols vs "
                                 f"{v_lens[i]} vals")
            if allc.size:
                if int(allc.min()) < 0 or int(allc.max()) >= self.n_cols:
                    off = int(np.nonzero((allc < 0)
                                         | (allc >= self.n_cols))[0][0])
                    i = int(np.searchsorted(np.cumsum(c_lens), off,
                                            side="right"))
                    raise ValueError(f"appended row {i}: column out of "
                                     f"[0, {self.n_cols})")
            object.__setattr__(self, "_appends_ok", True)
        for name, rows, cols in (("update", self.update_rows,
                                  self.update_cols),
                                 ("delete", self.delete_rows,
                                  self.delete_cols)):
            rows, cols = np.asarray(rows), np.asarray(cols)
            if rows.shape != cols.shape:
                raise ValueError(f"{name}: rows {rows.shape} vs cols "
                                 f"{cols.shape}")
            if rows.size:
                if int(rows.min()) < 0:
                    raise ValueError(f"{name}: negative row index")
                if n_rows is not None and int(rows.max()) >= n_rows:
                    raise ValueError(f"{name}: row {int(rows.max())} out of "
                                     f"[0, {n_rows}) (appended rows cannot "
                                     f"be edited in the same batch)")
                if int(cols.min()) < 0 or int(cols.max()) >= self.n_cols:
                    raise ValueError(f"{name}: column out of "
                                     f"[0, {self.n_cols})")
        if self.update_rows.shape[0] != np.asarray(self.update_vals).shape[0]:
            raise ValueError(
                f"update: {self.update_rows.shape[0]} positions vs "
                f"{np.asarray(self.update_vals).shape[0]} values")
        return self

    # -- (de)serialization ----------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {
            "kind": "delta_batch",
            "schema_version": DELTA_SCHEMA_VERSION,
            "n_cols": int(self.n_cols),
            "appends": [[np.asarray(c).tolist(), np.asarray(v).tolist()]
                        for c, v in zip(self.append_cols, self.append_vals)],
            "updates": {"rows": np.asarray(self.update_rows).tolist(),
                        "cols": np.asarray(self.update_cols).tolist(),
                        "vals": np.asarray(self.update_vals).tolist()},
            "deletes": {"rows": np.asarray(self.delete_rows).tolist(),
                        "cols": np.asarray(self.delete_cols).tolist()},
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "DeltaBatch":
        if d.get("kind") != "delta_batch":
            raise ValueError(f"not a delta_batch payload: "
                             f"kind={d.get('kind')!r}")
        if int(d.get("schema_version", -1)) > DELTA_SCHEMA_VERSION:
            raise ValueError(f"delta schema_version "
                             f"{d.get('schema_version')} is newer than "
                             f"supported {DELTA_SCHEMA_VERSION}")
        ups = d.get("updates") or {}
        dels = d.get("deletes") or {}
        return cls(
            n_cols=int(d["n_cols"]),
            append_cols=tuple(np.asarray(p[0], dtype=np.int64)
                              for p in d.get("appends", ())),
            append_vals=tuple(np.asarray(p[1], dtype=np.float32)
                              for p in d.get("appends", ())),
            update_rows=np.asarray(ups.get("rows", ()), dtype=np.int64),
            update_cols=np.asarray(ups.get("cols", ()), dtype=np.int64),
            update_vals=np.asarray(ups.get("vals", ()), dtype=np.float32),
            delete_rows=np.asarray(dels.get("rows", ()), dtype=np.int64),
            delete_cols=np.asarray(dels.get("cols", ()), dtype=np.int64),
        ).validate()


@dataclass
class DeltaApplyResult:
    """What one :func:`apply_delta` did, priced for the drift layer."""

    csr: CSR                       #: the updated source CSR (validated)
    container: Any                 #: the updated ``fmt`` container
    fmt: str
    mode: str                      #: inplace | append | splice | rebuild
    fallback: bool                 #: True when the incremental path bailed
    fallback_reason: str
    t_apply_s: float
    buckets_rebuilt: int           #: SELL buckets touched structurally
    appended_lens: np.ndarray      #: per appended row nnz
    changed_rows: np.ndarray       #: pre-existing rows whose length changed
    old_lens: np.ndarray
    new_lens: np.ndarray


# ---------------------------------------------------------------------------
# CSR apply
# ---------------------------------------------------------------------------
_MODE_RANK = {"noop": 0, "inplace": 1, "append": 2, "splice": 3,
              "rebuild": 4}


def _apply_csr(m: CSR, delta: DeltaBatch, *, in_place: bool = True):
    """Route the delta through the cheapest CSR edit primitives.

    Returns ``(csr, mode, changed_rows, old_lens, new_lens,
    appended_lens)``; ``changed_rows`` are the pre-existing rows touched
    by updates/deletes (unique, sorted)."""
    if m.n_cols != delta.n_cols:
        raise ValueError(f"delta n_cols={delta.n_cols} vs matrix "
                         f"n_cols={m.n_cols}")
    delta.validate(m.n_rows)
    ip0 = np.asarray(m.indptr)
    changed = np.unique(np.concatenate(
        [np.asarray(delta.update_rows, dtype=np.int64),
         np.asarray(delta.delete_rows, dtype=np.int64)])) \
        if (delta.update_rows.shape[0] or delta.delete_rows.shape[0]) \
        else _empty_i()
    old_lens = (ip0[changed + 1] - ip0[changed]).astype(np.int64) \
        if changed.size else _empty_i()

    cur, modes = m, []
    miss = np.zeros(0, dtype=bool)
    if delta.update_rows.shape[0]:
        cur, hit = csr_set_values(cur, delta.update_rows, delta.update_cols,
                                  delta.update_vals, in_place=in_place)
        if hit.any():
            modes.append("inplace")
        miss = ~hit
    if miss.any() or delta.delete_rows.shape[0]:
        cur = csr_splice(cur,
                         np.asarray(delta.update_rows)[miss],
                         np.asarray(delta.update_cols)[miss],
                         np.asarray(delta.update_vals)[miss],
                         delta.delete_rows, delta.delete_cols)
        modes.append("splice")
    appended_lens, flat_c, flat_v = delta._append_flat()
    if delta.n_appends:
        cur = csr_append_rows(cur, flat_c, flat_v, lens=appended_lens,
                              in_place=in_place)
        modes.append("append")
    mode = max(modes, key=_MODE_RANK.__getitem__) if modes else "noop"
    ip1 = np.asarray(cur.indptr)
    new_lens = (ip1[changed + 1] - ip1[changed]).astype(np.int64) \
        if changed.size else _empty_i()
    return cur, mode, changed, old_lens, new_lens, appended_lens


# ---------------------------------------------------------------------------
# SELL apply
# ---------------------------------------------------------------------------
def sell_apply(sell: BucketedELL, new_csr: CSR, n_old: int,
               changed_rows: np.ndarray, old_lens: np.ndarray,
               new_lens: np.ndarray, appended_lens: np.ndarray, *,
               copy: bool = False, width_quantum: int = 8):
    """Incrementally carry a SELL container to the post-delta matrix.

    ``new_csr`` is the already-updated source; only the affected row
    slices / buckets are rebuilt.  Returns ``(container,
    buckets_rebuilt)``; raises :class:`MatrixValidationError` when the
    container cannot absorb the change (caller rebuilds from scratch)."""
    if not sell.buckets:
        raise MatrixValidationError("SELL container has no buckets")
    nb = len(sell.buckets)
    offsets = list(sell.row_offsets)
    perm = np.asarray(sell.perm)
    ip = np.asarray(new_csr.indptr)
    src_d, src_c = np.asarray(new_csr.data), np.asarray(new_csr.cols)

    b_rows: List[np.ndarray] = [
        perm[offsets[j]: offsets[j] + sell.buckets[j].n_rows].copy()
        for j in range(nb)]
    b_data: List[Optional[np.ndarray]] = [None] * nb
    b_cols: List[Optional[np.ndarray]] = [None] * nb
    b_nnz: List[int] = [int(b.nnz) for b in sell.buckets]
    widths: List[int] = [int(b.width) for b in sell.buckets]
    rebuilt = 0

    def arrays(j: int):
        if b_data[j] is None:
            d = np.asarray(sell.buckets[j].data)
            c = np.asarray(sell.buckets[j].cols)
            if copy:
                d, c = d.copy(), c.copy()
            b_data[j], b_cols[j] = d, c
        return b_data[j], b_cols[j]

    # positions of changed rows under the *original* structure
    inv = np.empty(n_old, dtype=np.int64)
    inv[perm] = np.arange(n_old, dtype=np.int64)
    bounds = np.asarray(offsets + [n_old], dtype=np.int64)

    removals: Dict[int, List[int]] = {}
    removed_nnz: Dict[int, int] = {}
    inserts: List[Tuple[int, int]] = []        # (orig row, new length)
    for r, lo, ln in zip(changed_rows, old_lens, new_lens):
        p = int(inv[int(r)])
        j = int(np.searchsorted(bounds, p, side="right")) - 1
        local = p - offsets[j]
        if int(ln) <= widths[j]:
            # value/shrink rewrite in place: only this row's slice changes
            d, c = arrays(j)
            d[local, :] = 0
            c[local, :] = 0
            s, L = int(ip[int(r)]), int(ln)
            d[local, :L] = src_d[s:s + L]
            c[local, :L] = src_c[s:s + L]
            b_nnz[j] += int(ln) - int(lo)
        else:
            removals.setdefault(j, []).append(local)
            removed_nnz[j] = removed_nnz.get(j, 0) + int(lo)
            inserts.append((int(r), int(ln)))
    for i, ln in enumerate(appended_lens):
        inserts.append((n_old + i, int(ln)))

    for j, locals_ in removals.items():
        d, c = arrays(j)
        keep = np.ones(d.shape[0], dtype=bool)
        keep[np.asarray(locals_, dtype=np.int64)] = False
        b_data[j], b_cols[j] = d[keep], c[keep]
        b_rows[j] = b_rows[j][keep]
        b_nnz[j] -= removed_nnz[j]
        rebuilt += 1

    if inserts:
        longest = max(ln for _, ln in inserts)
        if longest > widths[0]:
            # widen the widest bucket (stays strictly the widest)
            new_w = pad_to_multiple(max(longest, 1), width_quantum)
            d, c = arrays(0)
            nd = np.zeros((d.shape[0], new_w), dtype=d.dtype)
            nc = np.zeros((c.shape[0], new_w), dtype=c.dtype)
            nd[:, : d.shape[1]] = d
            nc[:, : c.shape[1]] = c
            b_data[0], b_cols[0] = nd, nc
            widths[0] = new_w
            rebuilt += 1
        by_target: Dict[int, List[Tuple[int, int]]] = {}
        for r, ln in inserts:
            # narrowest bucket that still fits the row (widths decrease)
            target = 0
            for j in range(nb):
                if widths[j] >= max(ln, 1):
                    target = j
                else:
                    break
            by_target.setdefault(target, []).append((r, ln))
        for j, rows_ in by_target.items():
            d, c = arrays(j)
            k = len(rows_)
            add_d = np.zeros((k, widths[j]), dtype=d.dtype)
            add_c = np.zeros((k, widths[j]), dtype=c.dtype)
            for i, (r, ln) in enumerate(rows_):
                s = int(ip[r])
                add_d[i, :ln] = src_d[s:s + ln]
                add_c[i, :ln] = src_c[s:s + ln]
            b_data[j] = np.concatenate([d, add_d], axis=0)
            b_cols[j] = np.concatenate([c, add_c], axis=0)
            b_rows[j] = np.concatenate(
                [b_rows[j],
                 np.asarray([r for r, _ in rows_], dtype=b_rows[j].dtype)])
            b_nnz[j] += int(sum(ln for _, ln in rows_))
            rebuilt += 1

    keep_idx = [j for j in range(nb) if b_rows[j].shape[0]]
    if not keep_idx:
        raise MatrixValidationError("delta emptied every SELL bucket")
    n_new = new_csr.n_rows
    new_perm = np.concatenate([b_rows[j] for j in keep_idx]).astype(np.int32)
    new_offsets, buckets, off = [], [], 0
    for j in keep_idx:
        d, c = arrays(j)
        buckets.append(ELL(data=d, cols=c,
                           shape=(d.shape[0], new_csr.n_cols),
                           nnz=b_nnz[j], order="row"))
        new_offsets.append(off)
        off += d.shape[0]
    if off != n_new:
        raise MatrixValidationError(
            f"incremental SELL covers {off} rows, expected {n_new}")
    return BucketedELL(perm=new_perm, buckets=tuple(buckets),
                       row_offsets=tuple(new_offsets),
                       shape=new_csr.shape, nnz=new_csr.nnz), rebuilt


# ---------------------------------------------------------------------------
# the orchestrator
# ---------------------------------------------------------------------------
def _copy_csr(m: CSR) -> CSR:
    return CSR(data=np.asarray(m.data).copy(), cols=np.asarray(m.cols).copy(),
               indptr=np.asarray(m.indptr).copy(), shape=m.shape, nnz=m.nnz)


def _poison(container: Any) -> None:
    """The ``delta.corrupt`` fault's effect: break a structural invariant
    so ``validate_container`` must catch it (arrays only — containers are
    frozen dataclasses, their buffers are not)."""
    if isinstance(container, CSR):
        np.asarray(container.indptr)[-1] += 1
    elif isinstance(container, BucketedELL):
        np.asarray(container.perm)[0] = container.n_rows
    else:  # generic: any container with an integer index array
        for name in ("cols", "rows", "block_cols"):
            arr = getattr(container, name, None)
            if arr is not None and np.asarray(arr).size:
                np.asarray(arr).reshape(-1)[0] = -10**6
                break


def apply_delta(csr: CSR, delta: DeltaBatch, *, container: Any = None,
                fmt: str = "csr", transform_params: Optional[dict] = None,
                registry: Optional[_faults.FaultRegistry] = None,
                key: str = "", validate: bool = True) -> DeltaApplyResult:
    """Apply one delta to a source CSR and (optionally) its transformed
    container.

    ``fmt``/``container`` name the bound serving format: ``csr`` and
    ``sell`` are updated incrementally, anything else is rebuilt from the
    updated CSR via the registered host transform (``mode="rebuild"``,
    cost recorded).  When the ``delta.corrupt`` fault is armed the apply
    runs copy-on-write so a poisoned candidate can be thrown away and
    rebuilt cleanly."""
    reg = registry if registry is not None else _faults.get()
    armed = bool(reg.armed("delta.corrupt"))
    t0 = time.perf_counter()
    new_csr, mode, changed, old_lens, new_lens, app_lens = _apply_csr(
        csr, delta, in_place=not armed)
    if validate:
        new_csr.validate()

    fallback, reason, rebuilt = False, "", 0
    params = dict(transform_params or {})
    cand: Any
    if fmt == "csr":
        cand = _copy_csr(new_csr) if armed else new_csr
    elif fmt == "sell" and isinstance(container, BucketedELL):
        try:
            cand, rebuilt = sell_apply(
                container, new_csr, csr.n_rows, changed, old_lens, new_lens,
                app_lens, copy=armed,
                width_quantum=int(params.get("width_quantum", 8)))
        except (MatrixValidationError, ValueError, IndexError) as e:
            cand, fallback, reason = None, True, f"sell:{type(e).__name__}"
    else:
        cand, fallback, reason = None, True, "format"

    if cand is not None and reg.should_fire("delta.corrupt"):
        _poison(cand)
    if cand is not None and validate:
        try:
            validate_container(cand)
        except MatrixValidationError:
            cand, fallback, reason = None, True, "corrupt"

    if cand is None:
        # degrade: full re-transform from the clean, already-updated CSR
        from repro.core.plan import apply_transform
        cand = apply_transform(fmt, new_csr, **params)
        mode = "rebuild"
        if validate:
            validate_container(cand)
    dt = time.perf_counter() - t0

    tel = _obs.get()
    if tel.enabled:
        tel.counter("stream.applies", fmt=fmt, mode=mode).inc()
        if fallback:
            tel.counter("stream.fallbacks", fmt=fmt, reason=reason).inc()
        tel.histogram("stream.apply_s", fmt=fmt).observe(dt)
        tel.event("stream.delta", key=key, fmt=fmt, mode=mode,
                  rows=int(changed.shape[0]), appends=delta.n_appends,
                  nnz_delta=delta.nnz_delta, fallback=fallback,
                  reason=reason, t_apply_s=dt)
    return DeltaApplyResult(csr=new_csr, container=cand, fmt=fmt, mode=mode,
                            fallback=fallback, fallback_reason=reason,
                            t_apply_s=dt, buckets_rebuilt=rebuilt,
                            appended_lens=app_lens, changed_rows=changed,
                            old_lens=old_lens, new_lens=new_lens)


def random_delta(rng: np.random.Generator, csr: CSR, *,
                 n_appends: int = 0, n_updates: int = 0, n_deletes: int = 0,
                 row_len: int = 8) -> DeltaBatch:
    """A randomized delta for tests/benchmarks: appends draw fresh rows of
    ~``row_len`` nonzeros; updates/deletes target uniformly random
    coordinates (updates mix overwrites and inserts organically)."""
    n_rows, n_cols = csr.shape
    app_c, app_v = [], []
    for _ in range(n_appends):
        ln = max(1, min(n_cols, int(rng.integers(1, 2 * row_len + 1))))
        app_c.append(np.sort(rng.choice(n_cols, size=ln,
                                        replace=False)).astype(np.int64))
        app_v.append(rng.standard_normal(ln).astype(np.float32))
    upd_r = rng.integers(0, max(n_rows, 1),
                         size=n_updates).astype(np.int64)
    upd_c = rng.integers(0, n_cols, size=n_updates).astype(np.int64)
    upd_v = rng.standard_normal(n_updates).astype(np.float32)
    # steer half the deletes at stored entries so they actually bite
    del_r, del_c = [], []
    ip = np.asarray(csr.indptr)
    cols = np.asarray(csr.cols)
    for i in range(n_deletes):
        if i % 2 == 0 and csr.nnz:
            k = int(rng.integers(0, csr.nnz))
            r = int(np.searchsorted(ip, k, side="right")) - 1
            del_r.append(r)
            del_c.append(int(cols[k]))
        else:
            del_r.append(int(rng.integers(0, max(n_rows, 1))))
            del_c.append(int(rng.integers(0, n_cols)))
    return DeltaBatch(
        n_cols=n_cols, append_cols=tuple(app_c), append_vals=tuple(app_v),
        update_rows=upd_r, update_cols=upd_c, update_vals=upd_v,
        delete_rows=np.asarray(del_r, dtype=np.int64),
        delete_cols=np.asarray(del_c, dtype=np.int64))


__all__ = ["DELTA_SCHEMA_VERSION", "INCREMENTAL_FORMATS", "DeltaBatch",
           "DeltaApplyResult", "apply_delta", "sell_apply", "random_delta"]
