"""Replay captured workload traces through the off-line tuning phase.

A trace from :class:`~repro.stream.capture.TraceCapture` is a faithful
record of how one matrix actually evolved and was queried.  Replaying it
reconstructs every matrix *epoch* (the state between two deltas that
served at least one query) and hands those epochs to
:func:`repro.core.autotune.offline_phase` as the measurement suite — so
format thresholds and launch geometry are tuned against the real access
pattern instead of a synthetic sweep, and the observed query/update ratio
(k̂) prices the streaming amortization rule with data.
"""
from __future__ import annotations

from collections import Counter
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.formats import CSR

from .capture import load_trace
from .delta import DeltaBatch, apply_delta


def _snapshot(m: CSR) -> CSR:
    return CSR(data=np.asarray(m.data).copy(),
               cols=np.asarray(m.cols).copy(),
               indptr=np.asarray(m.indptr).copy(),
               shape=m.shape, nnz=m.nnz)


@dataclass
class ReplayStats:
    """What the replay saw, for the drift layer's priors."""

    key: str = ""
    n_records: int = 0
    n_queries: int = 0
    n_deltas: int = 0
    n_epochs: int = 0
    dropped_epochs: int = 0          #: epochs over ``max_epochs``, skipped
    k_hat: float = 0.0               #: mean queries per epoch
    batch: int = 1                   #: modal query batch width
    batches: Dict[int, int] = field(default_factory=dict)


def epochs_of(trace: Sequence[Dict[str, Any]], base: CSR,
              key: Optional[str] = None
              ) -> Tuple[List[Tuple[str, CSR, int]], ReplayStats]:
    """Reconstruct the queried matrix epochs of one key's trace.

    Returns ``([(name, csr, n_queries), ...], stats)`` — only epochs that
    served at least one query become suite entries (a burst of deltas with
    no reads between them collapses into one epoch)."""
    if key is None:
        for r in trace:
            if "key" in r:
                key = str(r["key"])
                break
        else:
            key = ""
    cur = _snapshot(base)
    epochs: List[Tuple[str, CSR, int]] = []
    stats = ReplayStats(key=key)
    q_in_epoch = 0

    def close_epoch() -> None:
        nonlocal q_in_epoch
        if q_in_epoch:
            epochs.append((f"{key}@e{len(epochs)}", _snapshot(cur),
                           q_in_epoch))
            q_in_epoch = 0

    for rec in trace:
        if rec.get("key") not in (None, key):
            continue
        stats.n_records += 1
        kind = rec.get("kind")
        if kind == "stream.base":
            if (int(rec.get("n_rows", base.n_rows)) != base.n_rows
                    or int(rec.get("n_cols", base.n_cols)) != base.n_cols):
                raise ValueError(
                    f"trace base {rec.get('n_rows')}x{rec.get('n_cols')} "
                    f"does not match the provided matrix {base.shape}")
        elif kind == "stream.query":
            q_in_epoch += 1
            stats.n_queries += 1
            b = int(rec.get("batch", 1))
            stats.batches[b] = stats.batches.get(b, 0) + 1
        elif kind == "stream.delta":
            close_epoch()
            delta = DeltaBatch.from_dict(rec["delta"])
            cur = apply_delta(cur, delta, fmt="csr").csr
            stats.n_deltas += 1
    close_epoch()

    stats.n_epochs = len(epochs)
    stats.k_hat = stats.n_queries / max(stats.n_epochs, 1)
    if stats.batches:
        stats.batch = Counter(stats.batches).most_common(1)[0][0]
    return epochs, stats


def replay(trace: Sequence[Dict[str, Any]], base: CSR, *,
           key: Optional[str] = None, max_epochs: int = 16,
           **offline_kw) -> Tuple[Any, ReplayStats]:
    """Feed a trace's queried epochs through ``offline_phase``.

    ``offline_kw`` forwards to
    :func:`repro.core.autotune.offline_phase` (``formats``, ``iters``,
    ``machine``, ...); ``batch`` defaults to the trace's modal query
    width.  At most ``max_epochs`` epochs are measured — the heaviest-
    queried ones, so the tuner spends its budget where traffic was — and
    ``stats.dropped_epochs`` reports what the cap skipped."""
    from repro.core.autotune import offline_phase
    epochs, stats = epochs_of(trace, base, key=key)
    if not epochs:
        raise ValueError("trace contains no queried epochs to replay")
    if len(epochs) > max_epochs:
        keep = sorted(sorted(range(len(epochs)),
                             key=lambda i: -epochs[i][2])[:max_epochs])
        stats.dropped_epochs = len(epochs) - len(keep)
        epochs = [epochs[i] for i in keep]
    suite = [(name, csr) for name, csr, _ in epochs]
    offline_kw.setdefault("batch", stats.batch)
    db = offline_phase(suite, **offline_kw)
    return db, stats


def replay_file(path: str, base: CSR, **kw) -> Tuple[Any, ReplayStats]:
    """``replay`` straight from a trace file on disk."""
    return replay(load_trace(path), base, **kw)


__all__ = ["ReplayStats", "epochs_of", "replay", "replay_file"]
