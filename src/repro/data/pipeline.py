"""Deterministic, seekable, host-sharded synthetic token pipeline.

Production posture:
  * every batch is a pure function of (seed, step, host_shard) — restarts
    resume *exactly* (fault tolerance requires a seekable data source);
  * host sharding: each host materializes only its slice of the global
    batch (``host_id``/``num_hosts``);
  * a double-buffering prefetch thread hides host-side generation latency.

The token distribution is a Zipf-like categorical with a deterministic
per-sequence structure, which gives a non-trivial loss curve (the
quickstart example shows steady descent) without any external data."""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    frontend_len: int = 0
    d_model: int = 0              # for frontend embedding stubs


class SyntheticLM:
    """Seekable synthetic LM stream.  ``batch_at(step)`` is pure."""

    def __init__(self, cfg: DataConfig, host_id: int = 0,
                 num_hosts: int = 1):
        assert cfg.global_batch % num_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.local_batch = cfg.global_batch // num_hosts
        # Zipf-ish unigram distribution, fixed per seed
        r = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self._p = p / p.sum()
        self._perm = r.permutation(cfg.vocab_size)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng(
            (c.seed, step, self.host_id, 0xD0D0))
        # markov-ish structure: each sequence repeats a sampled motif with
        # noise, so next-token prediction is learnable
        B, S = self.local_batch, c.seq_len - c.frontend_len
        motif_len = 16
        motifs = self._perm[rng.integers(0, c.vocab_size // 4,
                                         (B, motif_len))]
        reps = (S + 2 * motif_len) // motif_len
        seq = np.tile(motifs, (1, reps))[:, :S + 1]
        noise_mask = rng.random((B, S + 1)) < 0.1
        noise = rng.choice(c.vocab_size, size=(B, S + 1), p=self._p)
        seq = np.where(noise_mask, noise, seq).astype(np.int32)
        out = {"tokens": seq[:, :-1], "labels": seq[:, 1:]}
        if c.frontend_len:
            out["frontend_embeds"] = rng.standard_normal(
                (B, c.frontend_len, c.d_model)).astype(np.float32)
        return out

    def iter_from(self, step: int) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Double-buffered background prefetch with a seekable cursor."""

    def __init__(self, source: SyntheticLM, start_step: int = 0,
                 depth: int = 2):
        self.source = source
        self.step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        s = self.step
        while not self._stop.is_set():
            batch = self.source.batch_at(s)
            while not self._stop.is_set():
                try:
                    self._q.put((s, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            s += 1

    def next(self):
        s, batch = self._q.get()
        self.step = s + 1
        return s, batch

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)


def data_config_for(cfg: ModelConfig, seq_len: int, global_batch: int,
                    seed: int = 0) -> DataConfig:
    return DataConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                      global_batch=global_batch, seed=seed,
                      frontend_len=cfg.frontend_len if cfg.frontend else 0,
                      d_model=cfg.d_model)


__all__ = ["DataConfig", "SyntheticLM", "Prefetcher", "data_config_for"]
