from .pipeline import DataConfig, Prefetcher, SyntheticLM, data_config_for
