"""Fault-tolerant training loop.

Production posture (DESIGN.md §6):
  * periodic async checkpoints (atomic; latest-K kept);
  * ``run_with_restarts``: any step failure (injected or real) restores the
    latest committed checkpoint and resumes — the data pipeline is
    seekable, so the resumed trajectory is bit-exact;
  * step-time watchdog: an EMA of step latency flags stragglers (on a real
    cluster this triggers hot-spare pod swap; here it logs and counts);
  * metrics hook per step.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.configs.base import ModelConfig
from repro.data import Prefetcher, SyntheticLM
from repro.models import model as M
from repro.optim import adamw


@dataclass
class TrainConfig:
    steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_ckpts: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0   # step > factor * EMA -> flagged
    microbatches: int = 1
    seed: int = 0


@dataclass
class TrainState:
    params: Any
    opt_state: adamw.AdamWState
    step: int = 0


class StragglerWatchdog:
    def __init__(self, factor: float):
        self.factor = factor
        self.ema: Optional[float] = None
        self.flagged: List[int] = []

    def observe(self, step: int, dt: float) -> bool:
        slow = self.ema is not None and dt > self.factor * self.ema
        if slow:
            self.flagged.append(step)
        self.ema = dt if self.ema is None else 0.9 * self.ema + 0.1 * dt
        return slow


class Trainer:
    def __init__(self, cfg: ModelConfig, data: SyntheticLM,
                 tc: TrainConfig,
                 opt_cfg: Optional[adamw.AdamWConfig] = None,
                 failure_hook: Optional[Callable[[int], None]] = None):
        self.cfg = cfg
        self.data = data
        self.tc = tc
        self.opt_cfg = opt_cfg or adamw.AdamWConfig(
            total_steps=tc.steps, warmup_steps=max(tc.steps // 20, 1))
        self.failure_hook = failure_hook
        self.ckpt = AsyncCheckpointer(tc.ckpt_dir, keep=tc.keep_ckpts)
        self.watchdog = StragglerWatchdog(tc.straggler_factor)
        self.metrics: List[Dict[str, float]] = []

        from repro.launch.steps import make_train_step
        self._step_fn = jax.jit(
            make_train_step(cfg, self.opt_cfg,
                            microbatches=tc.microbatches))

    # -- state management ----------------------------------------------------
    def init_state(self) -> TrainState:
        params = M.init(self.cfg, jax.random.PRNGKey(self.tc.seed))
        return TrainState(params=params, opt_state=adamw.init(params),
                          step=0)

    def save(self, state: TrainState) -> None:
        self.ckpt.save_async(
            state.step,
            {"params": state.params, "opt": state.opt_state},
            extra={"step": state.step})

    def try_restore(self) -> Optional[TrainState]:
        s = latest_step(self.tc.ckpt_dir)
        if s is None:
            return None
        template = self.init_state()
        tree, extra = restore(self.tc.ckpt_dir, s,
                              {"params": template.params,
                               "opt": template.opt_state})
        return TrainState(params=tree["params"], opt_state=tree["opt"],
                          step=int(extra["step"]))

    # -- the loop -------------------------------------------------------------
    def run(self, state: TrainState,
            until: Optional[int] = None) -> TrainState:
        until = until if until is not None else self.tc.steps
        prefetch = Prefetcher(self.data, start_step=state.step)
        try:
            while state.step < until:
                step_idx, batch = prefetch.next()
                assert step_idx == state.step, "seekable-data invariant"
                if self.failure_hook is not None:
                    self.failure_hook(state.step)  # may raise (injection)
                t0 = time.perf_counter()
                params, opt_state, m = self._step_fn(
                    state.params, state.opt_state,
                    jax.tree.map(jnp.asarray, batch))
                jax.block_until_ready(m["loss"])
                dt = time.perf_counter() - t0
                slow = self.watchdog.observe(state.step, dt)
                state = TrainState(params=params, opt_state=opt_state,
                                   step=state.step + 1)
                rec = {"step": state.step, "loss": float(m["loss"]),
                       "grad_norm": float(m["grad_norm"]),
                       "sec_per_step": dt, "straggler": bool(slow)}
                self.metrics.append(rec)
                if state.step % self.tc.log_every == 0:
                    print(f"[train] step={rec['step']} "
                          f"loss={rec['loss']:.4f} "
                          f"gnorm={rec['grad_norm']:.3f} "
                          f"{dt*1e3:.0f}ms" +
                          (" STRAGGLER" if slow else ""))
                if state.step % self.tc.ckpt_every == 0:
                    self.save(state)
            self.ckpt.wait()
            return state
        finally:
            prefetch.close()


def run_with_restarts(trainer: Trainer, max_restarts: int = 3,
                      until: Optional[int] = None) -> TrainState:
    """The fault-tolerance driver: on any step failure, restore the latest
    committed checkpoint (or reinit) and resume; give up after
    ``max_restarts`` consecutive failures."""
    restarts = 0
    state = trainer.try_restore() or trainer.init_state()
    while True:
        try:
            return trainer.run(state, until=until)
        except Exception as e:  # noqa: BLE001 — any failure triggers restart
            restarts += 1
            print(f"[train] FAILURE at step {state.step}: {e}; "
                  f"restart {restarts}/{max_restarts}")
            if restarts > max_restarts:
                raise
            try:
                trainer.ckpt.wait()
            # the restart path must survive whatever state the failed
            # step left in the checkpointer — repro: noqa[RPA001]
            except Exception:
                pass
            state = trainer.try_restore() or trainer.init_state()


__all__ = ["TrainConfig", "TrainState", "Trainer", "run_with_restarts",
           "StragglerWatchdog"]
