from .loop import TrainConfig, Trainer, TrainState, run_with_restarts
