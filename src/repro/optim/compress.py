"""Gradient compression for bandwidth-constrained (inter-pod) links:
int8 quantized all-reduce with error feedback.

Shape: shard_map over the DP axis; each worker quantizes its local gradient
to int8 against a psum-shared scale, all-reduces in int32, dequantizes and
averages.  Error feedback (Seide et al. / 1-bit SGD lineage) accumulates
the quantization residual locally and re-injects it next step, which keeps
SGD/Adam convergence unbiased in practice.

Wire cost: 1 byte/element instead of 4 (f32) — a 4x cut of the gradient
all-reduce term, aimed at the pod-to-pod links (DESIGN.md §6)."""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def init_error_state(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)


def _quantize(x: jax.Array, scale: jax.Array) -> jax.Array:
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q


def compressed_psum_mean(local_grads: Any, error: Any, axis_name: str
                         ) -> Tuple[Any, Any]:
    """Inside shard_map/pmap: returns (mean_grads, new_error)."""
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        amax = jax.lax.pmax(jnp.max(jnp.abs(g32)), axis_name)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = _quantize(g32, scale)
        new_e = g32 - q.astype(jnp.float32) * scale      # error feedback
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        mean = total.astype(jnp.float32) * scale / n
        return mean.astype(g.dtype), new_e

    out = jax.tree.map(one, local_grads, error)
    means = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    errs = jax.tree.map(lambda t: t[1], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    return means, errs


def make_compressed_allreduce(mesh, axis: str = "data"):
    """Top-level helper: (grads, error) -> (mean grads, error).  Both trees
    carry a leading worker dim sharded over ``axis`` (per-worker gradients
    and per-worker error-feedback residuals)."""
    from jax.experimental.shard_map import shard_map

    def fn(grads_stacked, error_stacked):
        def inner(g, e):
            g_local = jax.tree.map(lambda a: a[0], g)   # drop worker dim
            e_local = jax.tree.map(lambda a: a[0], e)
            m, ne = compressed_psum_mean(g_local, e_local, axis)
            return (jax.tree.map(lambda a: a[None], m),
                    jax.tree.map(lambda a: a[None], ne))
        spec_g = jax.tree.map(lambda _: P(axis), grads_stacked)
        spec_e = jax.tree.map(lambda _: P(axis), error_stacked)
        return shard_map(inner, mesh=mesh,
                         in_specs=(spec_g, spec_e),
                         out_specs=(spec_g, spec_e))(grads_stacked,
                                                     error_stacked)

    return jax.jit(fn)


__all__ = ["init_error_state", "compressed_psum_mean",
           "make_compressed_allreduce"]
