"""AdamW with cosine schedule, global-norm clipping and ZeRO sharding.

Optimizer states are created with ``jax.tree.map`` over the params, so they
inherit the parameter ParamSpec axes — with the default rules (embed->data
FSDP) the m/v moments are automatically ZeRO-sharded: no device holds a
replicated optimizer copy."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array      # () int32
    m: Any               # like params
    v: Any               # like params


class AdamWMixedState(NamedTuple):
    """Mixed precision (§Perf): the *working* parameters are bf16 (so FSDP
    all-gathers move half the bytes); the f32 master copy lives here,
    sharded like the moments (ZeRO)."""
    step: jax.Array
    m: Any
    v: Any
    master: Any          # f32, like params


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def init_mixed(params_f32) -> AdamWMixedState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWMixedState(step=jnp.zeros((), jnp.int32),
                           m=jax.tree.map(zeros, params_f32),
                           v=jax.tree.map(zeros, params_f32),
                           master=jax.tree.map(
                               lambda p: p.astype(jnp.float32), params_f32))


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps) /
                 jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(cfg: AdamWConfig, grads, state: AdamWState, params
           ) -> Tuple[Any, AdamWState, jax.Array]:
    """Returns (new_params, new_state, grad_norm)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        step_ = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v), gnorm


def update_mixed(cfg: AdamWConfig, grads, state: AdamWMixedState,
                 ) -> Tuple[Any, AdamWMixedState, jax.Array]:
    """Mixed-precision step: grads (any dtype) -> f32 master update ->
    fresh bf16 working params.  Returns (params_bf16, state, grad_norm)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(master, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        step_ = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps) + \
            cfg.weight_decay * master
        new_master = master - lr * step_
        return new_master.astype(jnp.bfloat16), new_master, m, v

    out = jax.tree.map(upd, state.master, grads, state.m, state.v)
    pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
    return pick(0), AdamWMixedState(step=step, m=pick(2), v=pick(3),
                                    master=pick(1)), gnorm


__all__ = ["AdamWConfig", "AdamWState", "AdamWMixedState", "init",
           "init_mixed", "update", "update_mixed", "schedule",
           "global_norm"]
