"""Sharded, atomic, async checkpointing with elastic re-shard on restore.

Layout (one directory per step):
    <dir>/step_000042/
        manifest.json      — tree structure, shapes, dtypes, content hashes
        leaf_00000.bin.zst — zstd-compressed raw bytes, one file per leaf
        COMMIT             — written last; a checkpoint without it is
                             ignored (atomic-commit protocol)

Elastic scaling: leaves are stored as *global* arrays; ``restore`` places
them under any target sharding tree (load an N-way-trained checkpoint into
an M-way mesh).  At 1000+-node scale the same manifest format extends to
per-shard files keyed by shard index — the single-process container stores
one file per leaf (noted in DESIGN.md §6).

``AsyncCheckpointer`` moves serialization off the training thread and
keeps the latest K checkpoints (garbage collection)."""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

try:
    import zstandard as zstd
    HAVE_ZSTD = True
except ImportError:          # optional: fall back to uncompressed leaves
    zstd = None
    HAVE_ZSTD = False


def _leaf_paths(tree: Any) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out.append((key, leaf))
    return out, treedef


def save(directory: str, step: int, tree: Any,
         extra: Optional[Dict[str, Any]] = None) -> str:
    """Write an atomic checkpoint; returns the final path."""
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    flat, _ = _leaf_paths(tree)
    cctx = zstd.ZstdCompressor(level=3) if HAVE_ZSTD else None
    codec = "zstd" if HAVE_ZSTD else "none"
    manifest: Dict[str, Any] = {"step": step, "extra": extra or {},
                                "leaves": []}
    for i, (key, leaf) in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        raw = arr.tobytes()
        fname = f"leaf_{i:05d}.bin.zst" if HAVE_ZSTD else f"leaf_{i:05d}.bin"
        with open(os.path.join(tmp, fname), "wb") as f:
            f.write(cctx.compress(raw) if cctx else raw)
        manifest["leaves"].append({
            "key": key, "file": fname, "shape": list(arr.shape),
            "dtype": str(arr.dtype), "codec": codec,
            "sha256": hashlib.sha256(raw).hexdigest(),
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "COMMIT"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def available_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        full = os.path.join(directory, name)
        if (name.startswith("step_") and not name.endswith(".tmp")
                and os.path.exists(os.path.join(full, "COMMIT"))):
            steps.append(int(name.split("_")[1]))
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    steps = available_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: int, target_tree: Any,
            shardings: Any = None, verify: bool = False) -> Tuple[Any, Dict]:
    """Restore into the structure of ``target_tree``; if ``shardings`` is
    given (a matching tree of NamedSharding), leaves are placed sharded —
    the elastic re-shard path."""
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_t, treedef = _leaf_paths(target_tree)
    by_key = {m["key"]: m for m in manifest["leaves"]}
    shard_flat = (jax.tree_util.tree_leaves(shardings)
                  if shardings is not None else [None] * len(flat_t))
    dctx = zstd.ZstdDecompressor() if HAVE_ZSTD else None
    leaves = []
    for (key, tgt), sh in zip(flat_t, shard_flat):
        m = by_key[key]
        codec = m.get("codec", "zstd")  # pre-codec manifests were all zstd
        with open(os.path.join(path, m["file"]), "rb") as f:
            raw = f.read()
        if codec == "zstd":
            if dctx is None:
                raise RuntimeError(
                    f"checkpoint leaf {key} is zstd-compressed but the "
                    "zstandard package is not installed")
            raw = dctx.decompress(raw)
        if verify:
            assert hashlib.sha256(raw).hexdigest() == m["sha256"], key
        arr = np.frombuffer(raw, dtype=np.dtype(m["dtype"])).reshape(
            m["shape"]).copy()
        want_shape = tuple(getattr(tgt, "shape", arr.shape))
        assert tuple(arr.shape) == want_shape, (key, arr.shape, want_shape)
        leaves.append(jax.device_put(arr, sh) if sh is not None
                      else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["extra"]


def gc_keep_last(directory: str, keep: int = 3) -> None:
    steps = available_steps(directory)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:09d}"),
                      ignore_errors=True)


class AsyncCheckpointer:
    """Background-thread checkpointing with at-most-one in flight."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save_async(self, step: int, tree: Any,
                   extra: Optional[Dict[str, Any]] = None) -> None:
        self.wait()
        # materialize on host *before* returning control so the training
        # step can donate/overwrite device buffers safely
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def work():
            try:
                save(self.directory, step, host_tree, extra)
                gc_keep_last(self.directory, self.keep)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()


__all__ = ["save", "restore", "latest_step", "available_steps",
           "gc_keep_last", "AsyncCheckpointer"]
