from .ckpt import (AsyncCheckpointer, available_steps, gc_keep_last,
                   latest_step, restore, save)
