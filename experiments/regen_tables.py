"""Regenerate the §Roofline tables inside EXPERIMENTS.md from the dry-run
artifacts (run after any dry-run refresh)."""
import json, glob, re

def single_pod_table():
    lines = ["| arch | shape | bneck | An.comp | An.mem | An.coll | wHLO.comp | wHLO.coll | RF(TPU) | peak GB |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for f in sorted(glob.glob("experiments/dryrun/*__16x16.json")):
        d = json.load(open(f))
        arch, shape = d["arch"], d["shape"]
        if d["status"] == "skip":
            lines.append(f"| {arch} | {shape} | — | SKIP(design) | | | | | | |")
            continue
        a = d["analytic"]; r = d["roofline"]; m = d["memory"]
        dom = max(a["t_compute"], a["t_memory"], a["t_collective"])
        useful_t = a["model_flops_global"]/256/197e12
        rf = useful_t/dom if dom else 0
        lines.append(
            f"| {arch} | {shape} | {a['bottleneck'][:4]} | "
            f"{a['t_compute']*1e3:.1f} | {a['t_memory']*1e3:.1f} | "
            f"{a['t_collective']*1e3:.1f} | {r['hlo_flops']/197e12*1e3:.1f} | "
            f"{r['collective_bytes']/50e9*1e3:.1f} | {rf:.2f} | "
            f"{m['peak_bytes']/1e9:.1f} |")
    return "\n".join(lines)

def multi_pod_table():
    lines = ["| arch | shape | status | peak GB | An.comp ms | An.coll ms |",
             "|---|---|---|---|---|---|"]
    for f in sorted(glob.glob("experiments/dryrun/*__2x16x16.json")):
        d = json.load(open(f))
        if d["status"] == "skip":
            lines.append(f"| {d['arch']} | {d['shape']} | SKIP(design) | | | |")
            continue
        a = d["analytic"]; m = d["memory"]
        lines.append(f"| {d['arch']} | {d['shape']} | ok | "
                     f"{m['peak_bytes']/1e9:.1f} | {a['t_compute']*1e3:.1f} | "
                     f"{a['t_collective']*1e3:.1f} |")
    return "\n".join(lines)

s = open("EXPERIMENTS.md").read()
s = re.sub(r"\| arch \| shape \| bneck.*?(?=\n\n)", single_pod_table(), s,
           count=1, flags=re.S)
s = re.sub(r"\| arch \| shape \| status.*?(?=\n\n|\n## |\Z)",
           multi_pod_table() + "\n", s, count=1, flags=re.S)
open("EXPERIMENTS.md", "w").write(s)
print("tables regenerated")
