"""Serve a small model with batched requests through the continuous-
batching engine (per-slot lengths, prefill-on-admit, int8 KV optional).

    PYTHONPATH=src python examples/serve_lm.py --requests 6
"""
import argparse
import time

import numpy as np
import jax

from repro.configs import get_config, smoke_config
from repro.models import init
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--slots", type=int, default=3)
    args = ap.parse_args()

    cfg = smoke_config(get_config(args.arch))
    params = init(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(params, cfg, max_batch=args.slots, max_len=128)

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        eng.submit(rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
                   max_new_tokens=args.max_new)
    done = eng.run()
    dt = time.perf_counter() - t0
    total_new = sum(len(r.generated) for r in done.values())
    print(f"arch={cfg.name} slots={args.slots} requests={len(done)}")
    for rid in sorted(done):
        r = done[rid]
        print(f"  req{rid}: prompt_len={len(r.prompt)} "
              f"generated={r.generated}")
    print(f"throughput: {total_new/dt:.1f} tok/s "
          f"({total_new} tokens in {dt:.2f}s, continuous batching)")


if __name__ == "__main__":
    main()
