"""The paper's decision rule inside an MoE LM: dispatch-format auto-tuning.

Shows D_mat (= sigma/mu of tokens-per-expert) computed per step on device
and the lax.cond selection between ELL (capacity) and CSR (dropless)
dispatch — run-time data transformation at zero recompile cost.

    PYTHONPATH=src python examples/moe_autotune.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.models import forward, init
from repro.models.moe import DEFAULT_D_STAR, dispatch_d_mat, route

cfg = smoke_config(get_config("mixtral-8x22b")).replace(
    moe_dispatch="auto", capacity_factor=1.25)
params = init(cfg, jax.random.PRNGKey(0))

print(f"arch={cfg.name} experts={cfg.n_experts} top_k={cfg.top_k} "
      f"dispatch=auto (D*={DEFAULT_D_STAR})")

rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 64)))}

# inspect the routing statistics the rule sees
moe_params = jax.tree.map(lambda a: a[0],
                          params["scan"]["pos0"])["moe"]
x = rng.normal(size=(4 * 64, cfg.d_model)).astype(np.float32)
ids, gw, aux = route(moe_params, jnp.asarray(x), cfg)
d_mat = float(dispatch_d_mat(ids, cfg.n_experts))
print(f"tokens-per-expert D_mat = {d_mat:.3f} -> "
      f"{'ELL (capacity)' if d_mat < DEFAULT_D_STAR else 'CSR (dropless)'}")

logits, aux = jax.jit(lambda p, b: forward(p, b, cfg))(params, batch)
print(f"forward through auto-dispatch ok: logits {logits.shape}, "
      f"load-balance aux={float(aux):.4f}")
