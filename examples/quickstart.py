"""Quickstart: auto-tuned run-time sparse-format transformation in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core import (AutoTunedSpMV, MatrixStats, offline_phase,
                        decide_paper)
from repro.core.suite import paper_suite, synthesize, TABLE1

# ---- off-line phase (once per machine): learn D* from a benchmark suite --
suite = paper_suite(scale=0.02, skip_ell_overflow=True)
db = offline_phase(suite, formats=("ell_row", "sell", "coo_row"),
                   c=1.0, machine="quickstart-cpu", iters=2)
print("learned D* per format:", {k: round(v, 3)
                                 for k, v in db.d_star.items()})

# ---- on-line phase (every library call): D_mat -> format decision --------
for name in ("chem_master1", "memplus"):          # uniform vs heavy-tailed
    spec = next(s for s in TABLE1 if s.name == name)
    A = synthesize(spec, scale=0.05)
    stats = MatrixStats.of(A)
    decision = decide_paper(db, stats, fmt="ell_row")
    print(f"{name}: D_mat={stats.d_mat:.3f}  D*={decision.d_star:.3f}"
          f"  -> {decision.fmt}")

    op = AutoTunedSpMV(A, db=db, rule="paper")    # transforms if profitable
    x = jnp.ones((A.n_cols,), jnp.float32)
    y = op(x)
    print(f"  SpMV ok: ||y||={float(jnp.linalg.norm(y)):.3f} "
          f"(format={op.decision.fmt})")
