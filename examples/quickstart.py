"""Quickstart: auto-tuned run-time sparse-format transformation in ~30 lines.

Off-line, learn the machine's D_mat–R graph once; on-line, one `Planner`
call turns a CSR matrix into a portable `ExecutionPlan` (decision rule +
format + transform recipe + launch geometry) that binds to the matrix and
serves `y = P @ x`.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro import ExecutionPlan, MatrixStats, Planner, offline_phase
from repro.core.suite import paper_suite, synthesize, TABLE1

# ---- off-line phase (once per machine): learn D* from a benchmark suite --
suite = paper_suite(scale=0.02, skip_ell_overflow=True)
db = offline_phase(suite, formats=("ell_row", "sell", "coo_row"),
                   c=1.0, machine="quickstart-cpu", iters=2)
print("learned D* per format:", {k: round(v, 3)
                                 for k, v in db.d_star.items()})

# ---- on-line phase (every library call): D_mat -> plan -> bind -----------
planner = Planner(db=db)
for name in ("chem_master1", "memplus"):          # uniform vs heavy-tailed
    spec = next(s for s in TABLE1 if s.name == name)
    A = synthesize(spec, scale=0.05)
    stats = MatrixStats.of(A)
    plan = planner.plan(A, rule="paper")          # transforms if profitable
    print(f"{name}: D_mat={stats.d_mat:.3f}  D*={plan.d_star:.3f}"
          f"  -> {plan.fmt}")

    # the plan is a portable JSON artifact: save it, reload it anywhere,
    # bind it to the matrix, and serve SpMV (and SpMM) via `@`
    plan2 = ExecutionPlan.from_json(plan.to_json())
    P = plan2.bind(A)
    x = jnp.ones((A.n_cols,), jnp.float32)
    y = P @ x
    print(f"  SpMV ok: ||y||={float(jnp.linalg.norm(y)):.3f} "
          f"(format={P.fmt}, rule={plan2.rule})")

# ---- serving (register once, query many) ---------------------------------
# every query runs through a guarded degradation ladder (tuned ->
# reference -> CSR), so a broken or fault-injected tuned tier degrades
# instead of failing — see docs/robustness.md (REPRO_FAULTS exercises it)
from repro.serve import SpMVService  # noqa: E402

svc = SpMVService(max_batch=4)
A = synthesize(next(s for s in TABLE1 if s.name == "chem_master1"),
               scale=0.05)
svc.register("demo", A, expected_iterations=50, measure_baseline=False)
x = jnp.ones((A.n_cols,), jnp.float32)
y = svc.spmv("demo", x)
futs = [svc.submit("demo", x) for _ in range(3)]
svc.flush()
st = svc.stats()["demo"]
g = st["guard"]["spmv"]
print(f"service ok: ||y||={float(jnp.linalg.norm(y)):.3f} "
      f"served_by={g['served_by']} breaker={g['breaker']['state']}")
