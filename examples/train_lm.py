"""Train an LM end-to-end with the production loop: sharded step, async
checkpoints, fault-tolerant restarts, straggler watchdog.

Default is a CPU-sized model (~20M params) for a few hundred steps; any
assigned architecture runs at smoke or full scale via flags (full scale is
what the multi-pod dry-run lowers).

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --arch zamba2-1.2b --steps 50
"""
import argparse

from repro.configs import get_config, smoke_config
from repro.data import SyntheticLM, data_config_for
from repro.models.model import n_params
from repro.train import TrainConfig, Trainer, run_with_restarts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--width", type=int, default=256,
                    help="d_model of the reduced config")
    args = ap.parse_args()

    cfg = smoke_config(get_config(args.arch)).replace(
        d_model=args.width, d_ff=args.width * 4 if
        get_config(args.arch).d_ff else 0, vocab_size=2048)
    print(f"arch={cfg.name} params={n_params(cfg)/1e6:.1f}M "
          f"layers={cfg.n_layers} pattern={cfg.layer_pattern}")

    data = SyntheticLM(data_config_for(cfg, args.seq, args.batch))
    tc = TrainConfig(steps=args.steps, ckpt_every=max(args.steps // 5, 10),
                     ckpt_dir=args.ckpt_dir, log_every=10)
    trainer = Trainer(cfg, data, tc)
    state = run_with_restarts(trainer)
    first = trainer.metrics[0]["loss"]
    last = trainer.metrics[-1]["loss"]
    print(f"done: step={state.step} loss {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'no improvement'})")
    if trainer.watchdog.flagged:
        print(f"straggler steps flagged: {trainer.watchdog.flagged}")


if __name__ == "__main__":
    main()
