"""End-to-end driver for the paper's use case: an iterative solver whose
SpMV is auto-tuned at run time.

The paper's amortization argument (§2.2): transformation pays off when the
iteration count covers the transformation cost — 'this range is achievable
for many iterative solvers'.  This Conjugate-Gradient solver is exactly
that setting: we report total solve time with CRS vs with the auto-tuned
format, including the transformation overhead.

    PYTHONPATH=src python examples/cg_solver.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import MatrixStats, Planner, csr_from_rows, offline_phase
from repro.core import spmv
from repro.core.suite import paper_suite


def spd_band_matrix(n=20_000, band=9):
    """Symmetric positive-definite banded matrix (uniform rows: low D_mat —
    the regime where the ELL transformation wins)."""
    cols, vals = [], []
    for i in range(n):
        lo, hi = max(0, i - band // 2), min(n, i + band // 2 + 1)
        c = np.arange(lo, hi, dtype=np.int32)
        v = np.where(c == i, float(band + 2), -0.5).astype(np.float32)
        cols.append(c)
        vals.append(v)
    return csr_from_rows(cols, vals, n_cols=n, pad=8)


def cg(matvec, b, iters=150, tol=1e-6):
    x = jnp.zeros_like(b)
    r = b - matvec(x)
    p = r
    rs = jnp.dot(r, r)
    for _ in range(iters):
        Ap = matvec(p)
        alpha = rs / jnp.dot(p, Ap)
        x = x + alpha * p
        r = r - alpha * Ap
        rs_new = jnp.dot(r, r)
        if float(jnp.sqrt(rs_new)) < tol:
            break
        p = r + (rs_new / rs) * p
        rs = rs_new
    return x, float(jnp.sqrt(rs))


def main():
    print("== off-line phase (suite on this machine) ==")
    db = offline_phase(paper_suite(scale=0.02, skip_ell_overflow=True),
                       formats=("ell_row", "sell"), iters=2,
                       machine="cg-example")
    A = spd_band_matrix()
    stats = MatrixStats.of(A)
    b = jnp.ones((A.n_cols,), jnp.float32)
    print(f"matrix: n={stats.n} nnz={stats.nnz} D_mat={stats.d_mat:.3f}")

    print("== CRS baseline ==")
    jit_crs = jax.jit(spmv)
    _ = jit_crs(A, b).block_until_ready()       # compile outside timing
    t0 = time.perf_counter()
    x_crs, res = cg(lambda v: jit_crs(A, v), b)
    t_crs = time.perf_counter() - t0
    print(f"CRS   : {t_crs*1e3:8.1f} ms  residual={res:.2e}")

    print("== auto-tuned (includes run-time transformation) ==")
    t0 = time.perf_counter()
    plan = Planner(db=db).plan(A, rule="generalized",
                               expected_iterations=150)
    P = plan.bind(A, db=db)
    _ = (P @ b).block_until_ready()
    x_at, res = cg(P, b)
    t_at = time.perf_counter() - t0
    print(f"{plan.fmt:6s}: {t_at*1e3:8.1f} ms  residual={res:.2e}  "
          f"(decision rule={plan.rule})")
    print(f"speedup including transformation: {t_crs / t_at:.2f}x")
    np.testing.assert_allclose(np.asarray(x_crs), np.asarray(x_at),
                               rtol=1e-3, atol=1e-4)
    print("solutions agree.")


if __name__ == "__main__":
    main()
