"""Degrade-don't-die serving: the fault-injection harness, the guarded
degradation ladder and its circuit breaker, admission control on the
micro-batch queue, typed eviction, and input validation — all
deterministic (fault registry + FakeClock, no sleeps)."""
import numpy as np
import pytest
import jax.numpy as jnp

import repro.obs as obs
from repro.obs import FakeClock, InMemorySink, Telemetry
from repro.core.formats import CSR, MatrixValidationError
from repro.core.plan import ExecutionPlan
from repro.core.transform import csr_from_dense
from repro.serve import faults
from repro.serve.guard import (CLOSED, HALF_OPEN, OPEN, CircuitBreaker,
                               GuardError, guard_ladder)
from repro.serve.spmv_service import (AdmissionError, EvictedError,
                                      SpMVService)


@pytest.fixture()
def tel():
    t = Telemetry(enabled=True, clock=FakeClock(), sinks=[InMemorySink()])
    prev = obs.set_default(t)
    yield t
    obs.set_default(prev)


@pytest.fixture(autouse=True)
def clean_faults():
    faults.clear()
    yield
    faults.clear()


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(7)


@pytest.fixture(scope="module")
def problem(rng):
    d = (rng.random((80, 64)) < 0.15).astype(np.float32)
    dense = d * rng.normal(1.0, 1.0, size=d.shape).astype(np.float32)
    return dense, csr_from_dense(dense, pad=8)


# ---------------------------------------------------------------------------
# the fault registry
# ---------------------------------------------------------------------------
def test_fault_registry_arm_disarm():
    reg = faults.FaultRegistry()
    assert not reg.armed()
    reg.arm("kernel.raise", prob=1.0)
    assert reg.armed("kernel.raise") and reg.should_fire("kernel.raise")
    reg.disarm("kernel.raise")
    assert not reg.should_fire("kernel.raise")


def test_fault_registry_rejects_unknown_point_and_bad_prob():
    reg = faults.FaultRegistry()
    with pytest.raises(ValueError, match="unknown fault point"):
        reg.arm("kernel.explode")
    with pytest.raises(ValueError):
        reg.arm("kernel.raise", prob=1.5)


def test_fault_probability_is_seeded_and_deterministic():
    a = faults.FaultRegistry()
    b = faults.FaultRegistry()
    for reg in (a, b):
        reg.arm("kernel.raise", prob=0.5, seed=123)
    seq_a = [a.should_fire("kernel.raise") for _ in range(50)]
    seq_b = [b.should_fire("kernel.raise") for _ in range(50)]
    assert seq_a == seq_b
    assert any(seq_a) and not all(seq_a)


def test_arm_from_env_spec_parsing():
    reg = faults.FaultRegistry()
    reg.arm_from_env("kernel.nan:1.0:7,transform.raise")
    assert reg.armed("kernel.nan") and reg.armed("transform.raise")
    with pytest.raises(ValueError):
        faults.FaultRegistry().arm_from_env("not.a.point:1.0")


def test_inject_context_manager_restores():
    with faults.inject("kernel.raise", prob=1.0):
        assert faults.armed("kernel.raise")
        with pytest.raises(faults.InjectedFault):
            faults.maybe_raise("kernel.raise")
    assert not faults.armed("kernel.raise")


def test_clock_skew_point():
    assert faults.skew(1.0) == 1.0
    with faults.inject("clock.skew", prob=1.0):
        assert faults.skew(1.0) == 1.0 + faults.SKEW_S


# ---------------------------------------------------------------------------
# circuit breaker state machine (FakeClock, no sleeps)
# ---------------------------------------------------------------------------
def test_breaker_opens_after_consecutive_failures():
    clk = FakeClock()
    br = CircuitBreaker(failures=3, cooldown_s=10.0, clock=clk)
    assert br.state == CLOSED and br.allow()
    br.record_failure()
    br.record_failure()
    assert br.state == CLOSED          # 2 < 3
    br.record_failure()
    assert br.state == OPEN
    assert not br.allow()              # cooldown not elapsed


def test_breaker_success_resets_consecutive_count():
    br = CircuitBreaker(failures=3, clock=FakeClock())
    br.record_failure()
    br.record_failure()
    br.record_success()
    br.record_failure()
    br.record_failure()
    assert br.state == CLOSED          # never 3 in a row


def test_breaker_half_open_probe_closes_on_success():
    clk = FakeClock()
    br = CircuitBreaker(failures=1, cooldown_s=10.0, clock=clk)
    br.record_failure()
    assert br.state == OPEN
    clk.advance(10.0)
    assert br.allow()                  # the single probe
    assert br.state == HALF_OPEN
    assert not br.allow()              # no second probe while in flight
    br.record_success()
    assert br.state == CLOSED and br.allow()


def test_breaker_half_open_probe_failure_reopens():
    clk = FakeClock()
    br = CircuitBreaker(failures=1, cooldown_s=5.0, clock=clk)
    br.record_failure()
    clk.advance(5.0)
    assert br.allow()
    br.record_failure()                # probe failed
    assert br.state == OPEN
    assert not br.allow()              # cooldown restarted
    assert br.opens == 2


# ---------------------------------------------------------------------------
# the guarded ladder
# ---------------------------------------------------------------------------
def test_ladder_serves_top_rung_when_healthy():
    g = guard_ladder("k", "spmv",
                     [("tuned", lambda x: x + 1), ("csr", lambda x: x + 2)],
                     probe_finite=False)
    assert g(jnp.zeros(3))[0] == 1
    assert g.snapshot()["served_by"] == {"tuned": 1, "csr": 0}


def test_ladder_demotes_on_exception():
    def boom(x):
        raise RuntimeError("broken kernel")
    g = guard_ladder("k", "spmv",
                     [("tuned", boom), ("csr", lambda x: x + 2)])
    y = g(jnp.zeros(3))
    assert y[0] == 2
    snap = g.snapshot()
    assert snap["failures"] == {"tuned/exception": 1}
    assert snap["fallback_calls"] == 1


def test_ladder_demotes_on_non_finite_output():
    g = guard_ladder("k", "spmv",
                     [("tuned", lambda x: x * jnp.nan),
                      ("csr", lambda x: x + 2)])
    assert g(jnp.zeros(3))[0] == 2
    assert g.snapshot()["failures"] == {"tuned/non_finite": 1}


def test_last_rung_is_the_unprobed_oracle():
    # a non-finite final rung is served as-is: there is nothing below it
    g = guard_ladder("k", "spmv", [("csr", lambda x: x * jnp.nan)])
    assert bool(jnp.isnan(g(jnp.ones(3)))[0])


def test_ladder_budget_demotes_slow_rung():
    clk = FakeClock(tick=1.0)          # every clock read advances 1s
    g = guard_ladder("k", "spmv",
                     [("tuned", lambda x: x + 1), ("csr", lambda x: x + 2)],
                     budget_s=0.5, probe_finite=False, clock=clk)
    assert g(jnp.zeros(3))[0] == 2     # tuned "took" 1s > 0.5s budget
    assert g.snapshot()["failures"] == {"tuned/budget": 1}


def test_ladder_raises_guard_error_when_every_rung_fails():
    def boom(x):
        raise ValueError("nope")
    g = guard_ladder("k", "spmv", [("tuned", boom), ("csr", boom)])
    with pytest.raises(GuardError) as ei:
        g(jnp.zeros(3))
    assert [r for r, _ in ei.value.causes] == ["tuned", "csr"]


def test_open_breaker_short_circuits_the_top_rung():
    calls = {"tuned": 0}

    def tuned(x):
        calls["tuned"] += 1
        raise RuntimeError("still broken")

    clk = FakeClock()
    br = CircuitBreaker(failures=2, cooldown_s=30.0, clock=clk)
    g = guard_ladder("k", "spmv",
                     [("tuned", tuned), ("csr", lambda x: x)],
                     breaker=br)
    for _ in range(5):
        g(jnp.ones(3))
    # rung 0 ran only until the breaker opened
    assert calls["tuned"] == 2
    assert g.snapshot()["short_circuits"] == 3
    assert g.snapshot()["breaker"]["state"] == OPEN


# ---------------------------------------------------------------------------
# chaos invariants through the service: faults at probability 1.0 never
# change served results
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("point", ["kernel.raise", "kernel.nan"])
def test_service_results_survive_kernel_faults(problem, rng, point, tel):
    dense, csr = problem
    svc = SpMVService(max_batch=4)
    svc.register("m", csr, measure_baseline=False)
    x = rng.normal(size=64).astype(np.float32)
    X = rng.normal(size=(64, 3)).astype(np.float32)
    with faults.inject(point, prob=1.0, seed=0):
        y = svc.spmv("m", x)
        Y = svc.spmm("m", X)
        f = svc.submit("m", x)
        svc.flush("m")
    np.testing.assert_allclose(np.asarray(y), dense @ x,
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(Y), dense @ X,
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(f.result()), dense @ x,
                               rtol=2e-4, atol=2e-4)
    g = svc.stats()["m"]["guard"]["spmv"]
    assert g["served_by"]["reference"] >= 1
    fb = {k: v for k, v in tel.snapshot()["counters"].items()
          if k.startswith("service.fallback")}
    assert fb and sum(fb.values()) >= 3


def test_breaker_opens_in_stats_and_probe_restores_tuned_tier(problem, rng):
    dense, csr = problem
    clk = FakeClock()
    svc = SpMVService(clock=clk, breaker_failures=2,
                      breaker_cooldown_s=10.0, max_batch=4)
    svc.register("m", csr, measure_baseline=False)
    x = rng.normal(size=64).astype(np.float32)

    with faults.inject("kernel.raise", prob=1.0):
        for _ in range(3):
            np.testing.assert_allclose(np.asarray(svc.spmv("m", x)),
                                       dense @ x, rtol=2e-4, atol=2e-4)
    g = svc.stats()["m"]["guard"]["spmv"]
    assert g["breaker"]["state"] == OPEN
    assert g["short_circuits"] == 1    # third call skipped the tuned rung

    # faults cleared but the breaker is still cooling: served degraded,
    # no tuned attempts
    tuned_before = g["served_by"]["tuned"]
    np.testing.assert_allclose(np.asarray(svc.spmv("m", x)), dense @ x,
                               rtol=2e-4, atol=2e-4)
    g = svc.stats()["m"]["guard"]["spmv"]
    assert g["served_by"]["tuned"] == tuned_before
    assert g["breaker"]["state"] == OPEN

    # past the cooldown the half-open probe runs clean and restores tuned
    clk.advance(10.0)
    np.testing.assert_allclose(np.asarray(svc.spmv("m", x)), dense @ x,
                               rtol=2e-4, atol=2e-4)
    g = svc.stats()["m"]["guard"]["spmv"]
    assert g["breaker"]["state"] == CLOSED
    assert g["served_by"]["tuned"] == tuned_before + 1


def test_register_degrades_to_csr_when_transform_faults(problem, rng, tel):
    dense, csr = problem
    svc = SpMVService()
    with faults.inject("transform.raise", prob=1.0):
        entry = svc.register("m", csr, measure_baseline=False)
    assert entry.plan is not None and entry.plan.rule == "degraded"
    assert entry.matrix.formats == ("csr",)
    x = rng.normal(size=64).astype(np.float32)
    np.testing.assert_allclose(np.asarray(svc.spmv("m", x)), dense @ x,
                               rtol=2e-4, atol=2e-4)
    fb = [k for k in tel.snapshot()["counters"]
          if k.startswith("service.fallback") and "op=register" in k]
    assert fb


def test_sharded_dispatch_per_shard_guards(problem, rng):
    dense, csr = problem
    from repro.sharding.spmv import build_sharded
    spm = build_sharded(csr, n_shards=2, mode="dispatch")
    assert len(spm.shard_guards) == 2
    x = rng.normal(size=64).astype(np.float32)
    with faults.inject("kernel.raise", prob=1.0):
        y = spm.spmv(x)
    np.testing.assert_allclose(np.asarray(y), dense @ x,
                               rtol=2e-4, atol=2e-4)
    for shard in spm.guard_report():
        assert shard["spmv"]["served_by"]["csr"] == 1


def test_guard_off_switch_serves_raw(problem, rng):
    dense, csr = problem
    svc = SpMVService(guard=False)
    svc.register("m", csr, measure_baseline=False)
    assert svc.stats()["m"]["guard"] == {}
    with faults.inject("kernel.raise", prob=1.0):
        # no ladder: the fault point is only threaded through guards, so
        # the raw path serves normally
        x = rng.normal(size=64).astype(np.float32)
        np.testing.assert_allclose(np.asarray(svc.spmv("m", x)), dense @ x,
                                   rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------
def test_admission_reject_bounds_queue_depth(problem, rng):
    dense, csr = problem
    svc = SpMVService(max_batch=16, max_queue=2, admission="reject")
    svc.register("m", csr, measure_baseline=False)
    x = rng.normal(size=64).astype(np.float32)
    f1, f2 = svc.submit("m", x), svc.submit("m", x)
    with pytest.raises(AdmissionError):
        svc.submit("m", x)
    assert svc.pending_count("m") == 2
    svc.flush("m")
    for f in (f1, f2):
        np.testing.assert_allclose(np.asarray(f.result()), dense @ x,
                                   rtol=2e-4, atol=2e-4)


def test_admission_shed_oldest_fails_the_oldest_future(problem, rng):
    dense, csr = problem
    svc = SpMVService(max_batch=16, max_queue=2, admission="shed_oldest")
    svc.register("m", csr, measure_baseline=False)
    x = rng.normal(size=64).astype(np.float32)
    f1, f2 = svc.submit("m", x), svc.submit("m", x)
    f3 = svc.submit("m", x)            # sheds f1, enqueues f3
    with pytest.raises(AdmissionError):
        f1.result(timeout=0)
    assert svc.pending_count("m") == 2
    assert svc.stats()["m"]["shed"] == 1
    svc.flush("m")
    for f in (f2, f3):
        np.testing.assert_allclose(np.asarray(f.result()), dense @ x,
                                   rtol=2e-4, atol=2e-4)


def test_admission_block_flushes_to_make_room(problem, rng):
    dense, csr = problem
    svc = SpMVService(max_batch=16, max_queue=2, admission="block")
    svc.register("m", csr, measure_baseline=False)
    x = rng.normal(size=64).astype(np.float32)
    f1, f2 = svc.submit("m", x), svc.submit("m", x)
    f3 = svc.submit("m", x)            # flushes f1+f2 synchronously
    assert f1.done() and f2.done()
    assert svc.pending_count("m") == 1
    svc.flush("m")
    for f in (f1, f2, f3):
        np.testing.assert_allclose(np.asarray(f.result()), dense @ x,
                                   rtol=2e-4, atol=2e-4)


def test_admission_deadline_rejects_predicted_late_requests(problem, rng):
    _, csr = problem
    svc = SpMVService(max_batch=4, deadline_ms=5.0, clock=FakeClock())
    entry = svc.register("m", csr, measure_baseline=False)
    entry.flush_ema_s = 0.010          # recent flushes took 10ms > 5ms
    x = rng.normal(size=64).astype(np.float32)
    with pytest.raises(AdmissionError, match="predicted wait"):
        svc.submit("m", x)
    assert svc.pending_count("m") == 0


def test_eviction_fails_outstanding_futures_typed(problem, rng):
    _, csr = problem
    svc = SpMVService(max_batch=16)
    svc.register("m", csr, measure_baseline=False)
    x = rng.normal(size=64).astype(np.float32)
    f = svc.submit("m", x)
    svc.evict("m")
    with pytest.raises(EvictedError):
        f.result(timeout=0)
    # typed, but still a KeyError for callers that treated it as one
    assert issubclass(EvictedError, KeyError)
    with pytest.raises(KeyError):
        svc.submit("m", x)


def test_reregister_keeps_serving_queued_vectors(problem, rng):
    dense, csr = problem
    svc = SpMVService(max_batch=16)
    svc.register("m", csr, measure_baseline=False)
    x = rng.normal(size=64).astype(np.float32)
    f = svc.submit("m", x)
    svc.register("m", csr, measure_baseline=False)   # replaces the entry
    np.testing.assert_allclose(np.asarray(f.result(timeout=0)), dense @ x,
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# input validation
# ---------------------------------------------------------------------------
def _bad_csr(problem, **patch):
    _, good = problem
    kw = dict(data=np.asarray(good.data).copy(),
              cols=np.asarray(good.cols).copy(),
              indptr=np.asarray(good.indptr).copy(),
              shape=good.shape, nnz=good.nnz)
    kw.update(patch)
    return CSR(**kw)


def test_validate_accepts_well_formed(problem):
    _, csr = problem
    assert csr.validate() is csr


def test_validate_rejects_nonmonotone_indptr(problem):
    bad_ip = np.asarray(problem[1].indptr).copy()
    bad_ip[3], bad_ip[4] = bad_ip[4], bad_ip[3] + 1
    with pytest.raises(MatrixValidationError, match="monoton"):
        _bad_csr(problem, indptr=bad_ip).validate()


def test_validate_rejects_wrong_first_and_last_indptr(problem):
    ip = np.asarray(problem[1].indptr).copy()
    ip[0] = 1
    with pytest.raises(MatrixValidationError):
        _bad_csr(problem, indptr=ip).validate()
    ip2 = np.asarray(problem[1].indptr).copy()
    ip2[-1] = problem[1].nnz + 3
    with pytest.raises(MatrixValidationError):
        _bad_csr(problem, indptr=ip2).validate()


def test_validate_rejects_out_of_range_and_float_indices(problem):
    cols = np.asarray(problem[1].cols).copy()
    cols[0] = problem[1].n_cols + 5
    with pytest.raises(MatrixValidationError, match="range"):
        _bad_csr(problem, cols=cols).validate()
    with pytest.raises(MatrixValidationError, match="dtype"):
        _bad_csr(problem,
                 indptr=np.asarray(problem[1].indptr,
                                   dtype=np.float32)).validate()


def test_service_register_rejects_malformed_matrix(problem):
    bad_ip = np.asarray(problem[1].indptr).copy()
    bad_ip[0] = 2
    bad = _bad_csr(problem, indptr=bad_ip)
    svc = SpMVService()
    with pytest.raises(MatrixValidationError):
        svc.register("m", bad)
    assert "m" not in svc.entries


def test_plan_bind_rejects_malformed_matrix(problem):
    _, csr = problem
    plan = ExecutionPlan(fmt="csr")
    cols = np.asarray(csr.cols).copy()
    if csr.nnz:
        cols[0] = -2
    bad = _bad_csr(problem, cols=cols)
    with pytest.raises(MatrixValidationError):
        plan.bind(bad)


def test_swallowed_errors_are_counted(problem, tel):
    _, csr = problem
    svc = SpMVService()
    entry = svc.register("m", csr, measure_baseline=False)
    svc.evict("m")
    entry.compile_count()              # evicted stubs have no jit cache
    swallowed = [k for k in tel.snapshot()["counters"]
                 if k.startswith("service.swallowed_errors")]
    assert swallowed
