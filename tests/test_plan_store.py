"""The crash-safe persistent plan store: atomic checksummed round trips,
quarantine-never-raise on every corruption class, concurrency (racing
writers, mid-race readers), and the service/planner integration — a second
process registers with zero tuner invocations."""
import json
import os
import threading

import numpy as np
import pytest

import repro.obs as obs
from repro.obs import FakeClock, InMemorySink, Telemetry
from repro.core.kernel_tune import KernelTuner
from repro.core.autotune import TuningDB
from repro.core.plan import ExecutionPlan, PlanFingerprint, Planner
from repro.core.plan_store import BAD_DIR, PlanStore, fingerprint_key
from repro.core.transform import csr_from_dense
from repro.serve import faults
from repro.serve.spmv_service import SpMVService


@pytest.fixture()
def tel():
    t = Telemetry(enabled=True, clock=FakeClock(), sinks=[InMemorySink()])
    prev = obs.set_default(t)
    yield t
    obs.set_default(prev)


@pytest.fixture(autouse=True)
def clean_faults():
    faults.clear()
    yield
    faults.clear()


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(11)


@pytest.fixture(scope="module")
def problem(rng):
    d = (rng.random((60, 140)) < 0.12).astype(np.float32)
    dense = d * rng.normal(1.0, 1.0, size=d.shape).astype(np.float32)
    return dense, csr_from_dense(dense, pad=8)


def make_plan(csr, fmt="ell_row") -> ExecutionPlan:
    return ExecutionPlan(fmt=fmt, fingerprint=PlanFingerprint.of(csr))


def fake_timer(prefer_rows=32):
    calls = []

    def timer(thunk, g):
        thunk()
        calls.append(g)
        if g is None:
            return 1.0
        return 0.5 + abs((g.block_rows or prefer_rows) - prefer_rows) * 1e-3

    timer.calls = calls
    return timer


# ---------------------------------------------------------------------------
# round trips + keys
# ---------------------------------------------------------------------------
def test_round_trip(problem, tmp_path):
    _, csr = problem
    store = PlanStore(str(tmp_path / "plans"))
    plan = make_plan(csr)
    key = store.key_for(csr, batch=4)
    path = store.put(key, plan)
    assert os.path.exists(path)
    loaded = store.get(key)
    assert loaded is not None
    assert loaded.to_dict() == plan.to_dict()
    assert store.stats()["hits"] == 1 and store.stats()["writes"] == 1
    assert len(store) == 1


def test_keys_are_deterministic_and_knob_sensitive(problem):
    _, csr = problem
    fp = PlanFingerprint.of(csr)
    assert fingerprint_key(fp, batch=4) == fingerprint_key(fp, batch=4)
    assert fingerprint_key(fp, batch=4) != fingerprint_key(fp, batch=8)
    assert fingerprint_key(fp) != fingerprint_key(fp, strategy="variance")


def test_missing_key_is_a_miss_not_an_error(tmp_path):
    store = PlanStore(str(tmp_path))
    assert store.get("0" * 64) is None
    assert store.stats()["misses"] == 1


def test_fingerprint_mismatch_is_a_miss_not_quarantine(problem, rng,
                                                       tmp_path):
    _, csr = problem
    other = csr_from_dense(
        (rng.random((30, 140)) < 0.2).astype(np.float32), pad=8)
    store = PlanStore(str(tmp_path))
    key = store.key_for(csr)
    store.put(key, make_plan(csr))
    assert store.get(key, fingerprint=other) is None
    # the entry is valid for its own matrix: still on disk, not .bad
    assert store.get(key, fingerprint=csr) is not None
    assert store.stats()["quarantined"] == 0


def test_atomic_write_leaves_no_temp_files(problem, tmp_path):
    _, csr = problem
    store = PlanStore(str(tmp_path))
    for i in range(5):
        store.put(store.key_for(csr, i=i), make_plan(csr))
    leftovers = [n for n in os.listdir(str(tmp_path))
                 if n.startswith(".tmp-")]
    assert leftovers == []


# ---------------------------------------------------------------------------
# corruption -> quarantine, never raise
# ---------------------------------------------------------------------------
def corrupt_file(store, key, raw):
    with open(store.path_for(key), "w") as f:
        f.write(raw)


@pytest.mark.parametrize("raw,reason", [
    ('{"store_version": 1, "sha256": "tru', "not_json"),       # torn write
    ('{"something": "else"}', "bad_envelope"),
    ('{"store_version": 99, "sha256": "x", "plan": {}}', "store_version"),
    ('{"store_version": 1, "sha256": "x", "plan": []}', "bad_payload"),
    ('{"store_version": 1, "sha256": "wrong", "plan": {"fmt": "csr"}}',
     "checksum"),
])
def test_each_corruption_class_quarantines(problem, tmp_path, raw, reason,
                                           tel):
    _, csr = problem
    store = PlanStore(str(tmp_path))
    key = store.key_for(csr)
    store.put(key, make_plan(csr))
    corrupt_file(store, key, raw)
    assert store.get(key) is None                 # never raises
    assert not os.path.exists(store.path_for(key))
    bad = os.listdir(os.path.join(str(tmp_path), BAD_DIR))
    assert len(bad) == 1 and reason in bad[0]
    assert store.stats()["quarantined"] == 1
    events = [e for e in tel.sinks[0].named("store.quarantine")
              if e["type"] == "event"]
    assert events and events[0]["attrs"]["reason"] == reason
    # the slot is reusable after quarantine
    store.put(key, make_plan(csr))
    assert store.get(key) is not None


def test_schema_incompatible_payload_quarantines(problem, tmp_path):
    _, csr = problem
    store = PlanStore(str(tmp_path))
    key = store.key_for(csr)
    store.put(key, make_plan(csr))
    with open(store.path_for(key)) as f:
        env = json.load(f)
    env["plan"]["schema_version"] = 999           # a future writer
    import hashlib
    env["sha256"] = hashlib.sha256(json.dumps(
        env["plan"], sort_keys=True,
        separators=(",", ":")).encode()).hexdigest()
    corrupt_file(store, key, json.dumps(env))
    assert store.get(key) is None
    bad = os.listdir(os.path.join(str(tmp_path), BAD_DIR))
    assert len(bad) == 1 and "schema" in bad[0]


def test_store_corrupt_fault_point_round_trip(problem, tmp_path):
    _, csr = problem
    store = PlanStore(str(tmp_path))
    key = store.key_for(csr)
    with faults.inject("store.corrupt", prob=1.0):
        store.put(key, make_plan(csr))
    assert store.get(key) is None                 # checksum catches it
    assert store.stats()["quarantined"] == 1
    store.put(key, make_plan(csr))                # clean rewrite recovers
    assert store.get(key) is not None


# ---------------------------------------------------------------------------
# concurrency: racing writers, readers mid-race
# ---------------------------------------------------------------------------
def test_racing_same_key_writers_leave_one_intact_entry(problem, tmp_path):
    _, csr = problem
    root = str(tmp_path)
    key = PlanStore(root).key_for(csr)
    errors = []

    def writer(fmt):
        store = PlanStore(root)       # each thread: its own handle
        try:
            for _ in range(30):
                store.put(key, make_plan(csr, fmt=fmt))
        except Exception as e:        # pragma: no cover - the assertion
            errors.append(e)

    ts = [threading.Thread(target=writer, args=(f,))
          for f in ("ell_row", "coo_row")]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert errors == []
    final = PlanStore(root).get(key)
    assert final is not None and final.fmt in ("ell_row", "coo_row")
    assert PlanStore(root).stats()["quarantined"] == 0


def test_reader_never_sees_torn_json_mid_race(problem, tmp_path):
    _, csr = problem
    root = str(tmp_path)
    key = PlanStore(root).key_for(csr)
    PlanStore(root).put(key, make_plan(csr))      # ensure first read hits
    stop = threading.Event()
    tears = []

    def reader():
        store = PlanStore(root)
        while not stop.is_set():
            plan = store.get(key)
            if plan is None:          # a torn write would quarantine
                tears.append("miss")

    def writer():
        store = PlanStore(root)
        for i in range(60):
            store.put(key, make_plan(csr, fmt="ell_row" if i % 2
                                      else "coo_row"))

    rt = threading.Thread(target=reader)
    rt.start()
    writer()
    stop.set()
    rt.join()
    assert tears == []
    assert PlanStore(root).stats()["quarantined"] == 0


# ---------------------------------------------------------------------------
# planner + service integration
# ---------------------------------------------------------------------------
def test_planner_plan_or_load_round_trips(problem, tmp_path):
    _, csr = problem
    store = PlanStore(str(tmp_path))
    planner = Planner()
    p1 = planner.plan_or_load(csr, store)
    assert store.stats()["writes"] == 1
    p2 = planner.plan_or_load(csr, store)
    assert store.stats()["hits"] == 1
    assert p2.to_dict() == p1.to_dict()


def test_second_service_registers_with_zero_tuner_invocations(problem,
                                                              tmp_path):
    _, csr = problem
    root = str(tmp_path / "fleet")

    def service(timer):
        db = TuningDB(machine="svc", c=1.0, records=[], d_star={})
        return SpMVService(
            tuner=KernelTuner(db=db, timer=timer, interpret=True),
            plan_store=PlanStore(root), max_batch=4)

    t1 = fake_timer()
    svc1 = service(t1)
    e1 = svc1.register("a", csr, measure_baseline=False)
    assert len(t1.calls) > 0 and not e1.from_plan
    assert svc1.plan_store.stats()["writes"] == 1

    # "another replica": fresh service, fresh tuner, same store directory
    t2 = fake_timer()
    svc2 = service(t2)
    e2 = svc2.register("whatever", csr, measure_baseline=False)
    assert e2.from_plan
    assert len(t2.calls) == 0, "plan-store hit must skip tuning entirely"
    assert svc2.plan_store.stats()["hits"] == 1
    assert e2.matrix.formats == e1.matrix.formats
    assert "plan_store" in svc2.stats()


def test_service_survives_corrupted_store_entry(problem, rng, tmp_path,
                                                tel):
    dense, csr = problem
    root = str(tmp_path / "fleet")
    svc1 = SpMVService(plan_store=PlanStore(root))
    svc1.register("a", csr, measure_baseline=False)
    store = PlanStore(root)
    key = store.keys()[0]
    corrupt_file(store, key, "garbage{{{")

    # never raises: the corrupt entry quarantines, the service re-tunes
    svc2 = SpMVService(plan_store=PlanStore(root))
    e2 = svc2.register("b", csr, measure_baseline=False)
    assert not e2.from_plan
    assert svc2.plan_store.stats()["quarantined"] == 1
    x = rng.normal(size=140).astype(np.float32)
    np.testing.assert_allclose(np.asarray(svc2.spmv("b", x)), dense @ x,
                               rtol=2e-4, atol=2e-4)
    events = [e for e in tel.sinks[0].named("store.quarantine")
              if e["type"] == "event"]
    assert events
    # the re-tuned plan was written back over the quarantined slot
    assert PlanStore(root).get(key) is not None


def test_store_full_disk_does_not_fail_registration(problem, monkeypatch,
                                                    tmp_path, tel):
    _, csr = problem
    store = PlanStore(str(tmp_path))

    def full_disk(key, plan):
        raise OSError(28, "No space left on device")

    monkeypatch.setattr(store, "put", full_disk)
    svc = SpMVService(plan_store=store)
    entry = svc.register("a", csr, measure_baseline=False)
    assert entry is not None           # registration served from memory
    swallowed = [k for k in tel.snapshot()["counters"]
                 if k.startswith("service.swallowed_errors")
                 and "plan_store_put" in k]
    assert swallowed
