"""Partitioned hybrid-format SpMV: strategies, per-block decisions,
HybridMatrix correctness vs the dense/CSR reference, format integration,
and the serve path."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (MatrixStats, csr_from_dense, memory_bytes,
                        offline_phase, spmv)
from repro.core.formats import FORMAT_NAMES
from repro.core.policy import MemoryPolicy
from repro.core.suite import TABLE1, synthesize, synthesize_power_law
from repro.core.transform import TRANSFORMS_HOST
from repro.partition import (PARTITIONERS, build_hybrid, choose_block_format,
                             host_csr_to_hybrid, partition_balanced_nnz,
                             partition_fixed, partition_variance, slice_csr,
                             spmm_hybrid, spmv_hybrid, take_rows_csr)
from repro.serve import SpMVService


def random_dense(rng, n_rows, n_cols, density):
    d = (rng.random((n_rows, n_cols)) < density).astype(np.float32)
    return d * rng.normal(1.0, 1.0, size=d.shape).astype(np.float32)


def power_law_csr(n=2048, alpha=1.8, seed=0):
    return synthesize_power_law(n=n, alpha=alpha, seed=seed,
                                random_values=True)


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(11)


# ---------------------------------------------------------------------------
# partitioning strategies
# ---------------------------------------------------------------------------
def _check_boundaries(b, n):
    assert b[0] == 0 and b[-1] == n
    assert np.all(np.diff(b) > 0)


@pytest.mark.parametrize("name", sorted(PARTITIONERS))
def test_strategy_boundaries_valid(rng, name):
    for n in (1, 7, 64, 1000):
        lens = rng.integers(1, 50, size=n)
        _check_boundaries(PARTITIONERS[name](lens), n)


def test_fixed_blocks():
    b = partition_fixed(np.ones(100), block_rows=32)
    np.testing.assert_array_equal(b, [0, 32, 64, 96, 100])


def test_balanced_nnz_equalizes_work(rng):
    # one huge row among many small: balanced split isolates it
    lens = np.full(1000, 5, dtype=np.int64)
    lens[500] = 5000
    b = partition_balanced_nnz(lens, n_blocks=4)
    per_block = [lens[s:e].sum() for s, e in zip(b[:-1], b[1:])]
    # no block exceeds ~a half of total (perfect balance impossible with
    # one dominant row, but the split must not lump everything together)
    assert len(b) >= 3
    assert max(per_block) <= 0.75 * lens.sum()


def test_variance_split_isolates_tail():
    # sorted lengths: 100 heavy rows then 900 uniform rows
    lens = np.concatenate([np.full(100, 500), np.full(900, 5)]).astype(np.int64)
    b = partition_variance(lens, max_blocks=8, min_rows=50)
    _check_boundaries(b, 1000)
    # some cut must separate heavy from light within min_rows slack
    assert any(abs(int(c) - 100) <= 50 for c in b[1:-1])
    # within-block variance collapses vs whole-matrix variance
    sse = sum(float(np.var(lens[s:e]) * (e - s)) for s, e in zip(b[:-1], b[1:]))
    assert sse < 0.1 * float(np.var(lens) * 1000)


# ---------------------------------------------------------------------------
# CSR slicing
# ---------------------------------------------------------------------------
def test_slice_and_take_rows(rng):
    dense = random_dense(rng, 60, 40, 0.2)
    m = csr_from_dense(dense, pad=8)
    sub = slice_csr(m, 10, 35)
    np.testing.assert_allclose(sub.todense(), dense[10:35], rtol=1e-6)
    rows = np.array([3, 1, 59, 17])
    sub2 = take_rows_csr(m, rows)
    np.testing.assert_allclose(sub2.todense(), dense[rows], rtol=1e-6)


# ---------------------------------------------------------------------------
# hybrid correctness vs dense
# ---------------------------------------------------------------------------
STRATEGY_KW = [("fixed", {"block_rows": 64}),
               ("balanced_nnz", {"n_blocks": 4}),
               ("variance", {"max_blocks": 6, "min_rows": 16})]


@pytest.mark.parametrize("strategy,kw", STRATEGY_KW,
                         ids=[s for s, _ in STRATEGY_KW])
def test_hybrid_spmv_matches_dense(rng, strategy, kw):
    dense = random_dense(rng, 300, 200, 0.08)
    m = csr_from_dense(dense, pad=8)
    hyb, rep = build_hybrid(m, strategy=strategy, **kw)
    assert rep.n_blocks == hyb.n_blocks == len(hyb.formats)
    np.testing.assert_allclose(hyb.todense(), dense, rtol=1e-5, atol=1e-6)
    x = jnp.asarray(rng.normal(size=200).astype(np.float32))
    y = jax.jit(spmv)(hyb, x)   # generic dispatch, jitted
    np.testing.assert_allclose(np.asarray(y), dense @ np.asarray(x),
                               rtol=1e-4, atol=1e-4)
    X = jnp.asarray(rng.normal(size=(200, 5)).astype(np.float32))
    Y = spmm_hybrid(hyb, X)
    np.testing.assert_allclose(np.asarray(Y), dense @ np.asarray(X),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("mname", ["memplus", "chem_master1", "torso1",
                                   "epb2"])
def test_hybrid_matches_csr_on_suite(rng, mname):
    spec = [s for s in TABLE1 if s.name == mname][0]
    m = synthesize(spec, scale=0.02)
    hyb, _ = build_hybrid(m, strategy="variance", max_blocks=8, min_rows=32)
    x = jnp.asarray(rng.normal(size=m.n_cols).astype(np.float32))
    want = np.asarray(spmv(m, x))
    got = np.asarray(spmv_hybrid(hyb, x))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5 *
                               max(1.0, float(np.abs(want).max())))


def test_hybrid_kernel_path_matches(rng):
    from repro.kernels import ops
    m = power_law_csr(n=512, alpha=1.8, seed=3)
    hyb, _ = build_hybrid(m, strategy="variance", max_blocks=6, min_rows=32)
    x = jnp.asarray(rng.normal(size=m.n_cols).astype(np.float32))
    want = np.asarray(spmv(m, x))
    got = np.asarray(ops.spmv_hybrid(hyb, x, interpret=True))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4 *
                               max(1.0, float(np.abs(want).max())))


# ---------------------------------------------------------------------------
# acceptance: skewed matrix -> >= 2 distinct block formats, bounded memory
# ---------------------------------------------------------------------------
def test_skewed_matrix_gets_multiple_formats():
    m = power_law_csr(n=2048, alpha=1.8, seed=0)
    hyb, rep = build_hybrid(m, strategy="variance", max_blocks=16,
                            min_rows=64)
    assert len(set(hyb.formats)) >= 2, rep.format_counts()
    # per-block budget filtering keeps the whole thing near CSR footprint
    assert memory_bytes(hyb) <= MemoryPolicy().budget_ratio * \
        memory_bytes(m) * 1.1
    # transformation-time accounting is populated
    assert rep.t_transform > 0 and all(d.t_transform >= 0
                                       for d in rep.decisions)
    assert sum(d.nnz for d in rep.decisions) == m.nnz


def test_memory_policy_filters_block_candidates():
    # a block with one huge row among short ones: ELL must be filtered out
    skewed = MatrixStats(n=1000, nnz=6000, mu=6.0, sigma=80.0, d_mat=13.3,
                         max_row=900, min_row=1)
    fmt = choose_block_format(skewed, policy=MemoryPolicy(budget_ratio=2.0))
    assert fmt not in ("ell_row", "ell_col")
    uniform = MatrixStats(n=1000, nnz=6000, mu=6.0, sigma=0.1, d_mat=0.017,
                          max_row=7, min_row=5)
    fmt_u = choose_block_format(uniform, policy=MemoryPolicy(budget_ratio=2.0))
    assert fmt_u in ("ell_row", "ell_col", "sell")
    # an absolute hard cap below any candidate forces the CSR fallback
    fmt_h = choose_block_format(
        uniform, policy=MemoryPolicy(budget_ratio=2.0, hard_bytes=1))
    assert fmt_h == "csr"


# ---------------------------------------------------------------------------
# first-class format integration
# ---------------------------------------------------------------------------
def test_hybrid_registered_everywhere():
    from repro.kernels.ops import KERNEL_SPMV_IMPLS
    assert "hybrid" in FORMAT_NAMES
    assert "hybrid" in TRANSFORMS_HOST
    assert "hybrid" in KERNEL_SPMV_IMPLS
    assert MemoryPolicy().estimate_bytes(
        "hybrid", MatrixStats(n=10, nnz=50, mu=5, sigma=1, d_mat=0.2,
                              max_row=7, min_row=3)) > 0


def test_offline_phase_measures_hybrid(rng):
    dense = random_dense(rng, 128, 128, 0.1)
    m = csr_from_dense(dense, pad=8)
    db = offline_phase([("rand", m)], formats=("hybrid", "ell_row"),
                       iters=1, machine="test")
    meas = db.records[0].formats["hybrid"]
    assert meas.t_spmv > 0 and meas.t_trans > 0
    assert np.isfinite(meas.r)
    assert "hybrid" in db.d_star


def test_host_csr_to_hybrid_via_transforms(rng):
    dense = random_dense(rng, 100, 80, 0.1)
    m = csr_from_dense(dense, pad=8)
    hyb = TRANSFORMS_HOST["hybrid"](m)
    np.testing.assert_allclose(hyb.todense(), dense, rtol=1e-5, atol=1e-6)
    assert host_csr_to_hybrid(m).shape == m.shape


# ---------------------------------------------------------------------------
# serve path
# ---------------------------------------------------------------------------
def test_spmv_service(rng):
    dense = random_dense(rng, 200, 200, 0.05)
    m = csr_from_dense(dense, pad=8)
    svc = SpMVService()
    entry = svc.register("m0", m, expected_iterations=500)
    assert entry.matrix.n_blocks >= 1
    x = rng.normal(size=200).astype(np.float32)
    for _ in range(3):
        y = svc.spmv("m0", jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(y), dense @ x, rtol=1e-4,
                               atol=1e-4)
    st = svc.stats()["m0"]
    assert st["n_calls"] == 3 and st["t_build_s"] > 0
    assert sum(st["formats"].values()) == st["n_blocks"]
    svc.evict("m0")
    assert "m0" not in svc.entries
