"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs; plus
prefill+decode == forward consistency (validates every cache path)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.models import (decode_step, forward, init, init_caches, loss_fn,
                          model_spec, n_params, prefill)
from repro.sharding.rules import init_params

B, S = 2, 32


def make_batch(cfg, key, seq=S, batch=B):
    kt, kf = jax.random.split(key)
    text = seq - (cfg.frontend_len if cfg.frontend else 0)
    out = {
        "tokens": jax.random.randint(kt, (batch, text), 0, cfg.vocab_size),
        "labels": jax.random.randint(kt, (batch, text), 0, cfg.vocab_size),
    }
    if cfg.frontend:
        out["frontend_embeds"] = jax.random.normal(
            kf, (batch, cfg.frontend_len, cfg.d_model), jnp.float32)
    return out


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_loss(arch, key):
    cfg = smoke_config(get_config(arch))
    params = init(cfg, key)
    batch = make_batch(cfg, key)
    logits, aux = jax.jit(lambda p, b: forward(p, b, cfg))(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss = jax.jit(lambda p, b: loss_fn(p, b, cfg))(params, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "gemma3-12b", "dbrx-132b",
                                  "zamba2-1.2b", "xlstm-1.3b"])
def test_smoke_train_step_grads(arch, key):
    """One gradient step must produce finite grads for every param."""
    cfg = smoke_config(get_config(arch))
    params = init(cfg, key)
    batch = make_batch(cfg, key)
    grads = jax.jit(jax.grad(lambda p: loss_fn(p, batch, cfg)))(params)
    for leaf in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch, key):
    """logits(prefill S-1 tokens, then decode token S-1) must equal
    logits(forward over S tokens)[:, -1] — exercises every cache kind
    (linear KV, ring KV, SSM state, mLSTM/sLSTM state, shared-attn KV)."""
    cfg = smoke_config(get_config(arch))
    if cfg.n_experts:
        # capacity drops depend on token count; make ELL effectively dropless
        cfg = cfg.replace(capacity_factor=float(cfg.n_experts))
    params = init(cfg, key)
    batch = make_batch(cfg, key)
    full_logits, _ = jax.jit(lambda p, b: forward(p, b, cfg))(params, batch)

    text = batch["tokens"]
    pre_batch = dict(batch, tokens=text[:, :-1],
                     labels=batch["labels"][:, :-1])
    caches = init_caches(cfg, B, S, jnp.float32)
    _, caches = jax.jit(lambda p, b, c: prefill(p, b, c, cfg))(
        params, pre_batch, caches)
    step_logits, _ = jax.jit(
        lambda p, t, c, n: decode_step(p, t, c, n, cfg))(
        params, text[:, -1:], caches, jnp.asarray(S - 1, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(step_logits[:, 0], np.float32),
        np.asarray(full_logits[:, -1], np.float32), rtol=2e-3, atol=2e-3)


def test_moe_dispatch_paths_agree(key):
    """ELL (capacity, dropless-sized) and CSR (ragged) dispatch compute the
    same function; 'auto' picks one of them via the paper's D_mat rule."""
    cfg = smoke_config(get_config("dbrx-132b")).replace(
        capacity_factor=4.0)  # = n_experts -> no drops
    params = init(cfg, key)
    batch = make_batch(cfg, key)
    l_ell, _ = jax.jit(lambda p, b: forward(
        p, b, cfg.replace(moe_dispatch="ell")))(params, batch)
    l_csr, _ = jax.jit(lambda p, b: forward(
        p, b, cfg.replace(moe_dispatch="csr")))(params, batch)
    l_auto, _ = jax.jit(lambda p, b: forward(
        p, b, cfg.replace(moe_dispatch="auto")))(params, batch)
    np.testing.assert_allclose(np.asarray(l_ell), np.asarray(l_csr),
                               rtol=2e-3, atol=2e-3)
    close_to_ell = np.allclose(np.asarray(l_auto), np.asarray(l_ell),
                               rtol=2e-3, atol=2e-3)
    close_to_csr = np.allclose(np.asarray(l_auto), np.asarray(l_csr),
                               rtol=2e-3, atol=2e-3)
    assert close_to_ell or close_to_csr


def test_spec_and_params_structure_match(key):
    from repro.sharding.rules import ParamSpec
    cfg = smoke_config(get_config("gemma3-12b"))
    spec = model_spec(cfg)
    params = init_params(jax.random.PRNGKey(1), spec)
    spec_def = jax.tree.structure(spec,
                                  is_leaf=lambda x: isinstance(x, ParamSpec))
    assert spec_def == jax.tree.structure(params)
    # and every param shape matches its spec
    specs = jax.tree.leaves(spec, is_leaf=lambda x: isinstance(x, ParamSpec))
    vals = jax.tree.leaves(params)
    for s, v in zip(specs, vals):
        assert tuple(s.shape) == tuple(v.shape)
        assert len(s.axes) == v.ndim


def test_full_param_counts_sane():
    """Full (unreduced) configs must be in the advertised size class."""
    approx = {"dbrx-132b": 132e9, "mixtral-8x22b": 141e9,
              "gemma3-12b": 12e9, "minitron-8b": 8e9, "qwen3-1.7b": 1.7e9,
              "xlstm-1.3b": 1.3e9, "zamba2-1.2b": 1.2e9}
    from repro.models import n_params
    for arch, want in approx.items():
        got = n_params(get_config(arch))
        assert 0.5 * want < got < 2.1 * want, (arch, got, want)


def test_int8_kv_cache_close_to_exact(key):
    """Quantized serving cache (int8 + per-token-head scales) must track the
    exact decode logits closely (production serving config)."""
    cfg = smoke_config(get_config("qwen3-1.7b"))
    params = init(cfg, key)
    batch = make_batch(cfg, key)
    text = batch["tokens"]

    def run(quant):
        c = cfg.replace(kv_quant=quant)
        caches = init_caches(c, B, S, jnp.float32)
        _, caches = prefill(params, dict(batch, tokens=text[:, :-1]),
                            caches, c)
        logits, _ = decode_step(params, text[:, -1:], caches,
                                jnp.asarray(S - 1, jnp.int32), c)
        return np.asarray(logits[:, 0], np.float32)

    exact, quantized = run(False), run(True)
    # int8 KV: small relative error on logits
    denom = np.maximum(np.abs(exact).max(), 1e-6)
    assert np.max(np.abs(exact - quantized)) / denom < 0.05


def test_flash_swa_matches_masked_flash(key):
    """The banded SWA path must equal the full masked flash path."""
    from repro.models.attention import flash_attention, flash_attention_swa
    rng = np.random.default_rng(3)
    B, S, KV, G, Dh, W, C = 2, 256, 2, 2, 16, 64, 32
    q = jnp.asarray(rng.normal(size=(B, S, KV, G, Dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, KV, Dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, KV, Dh)).astype(np.float32))
    want = flash_attention(q, k, v, window=W, kv_chunk=C)
    got = flash_attention_swa(q, k, v, window=W, q_chunk=C)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_moe_learn_d_star():
    """The off-line rule for dispatch: D* = max D_mat where ELL is faster
    than CSR AND drops stay under the quality budget."""
    from repro.models.moe import learn_d_star
    points = [(0.05, 1.0, 4.0, 0.00),   # balanced: ELL wins, no drops
              (0.50, 1.0, 4.0, 0.03),   # mild skew: still qualifies
              (0.90, 1.0, 4.0, 0.28),   # drops exceed budget
              (1.20, 5.0, 4.0, 0.35)]   # ELL slower AND droppy
    assert learn_d_star(points) == 0.50
    assert learn_d_star(points, max_drop_frac=0.3) == 0.90
    assert learn_d_star([(1.0, 5.0, 4.0, 0.5)]) == 0.0


def test_ring_cache_rollover_multistep(key):
    """Decode step-by-step PAST the sliding window: the ring cache wraps and
    the modular key_pos bookkeeping must keep logits equal to a fresh
    full-sequence forward at every step."""
    cfg = smoke_config(get_config("h2o-danube-1.8b")).replace(window=16)
    params = init(cfg, key)
    S_total, S_pre = 48, 24
    toks = jax.random.randint(key, (B, S_total), 0, cfg.vocab_size)

    caches = init_caches(cfg, B, S_total, jnp.float32)
    _, caches = prefill(params, {"tokens": toks[:, :S_pre]}, caches, cfg)
    for t in range(S_pre, S_total):           # decode 24 steps, wrap at 16
        step_logits, caches = decode_step(
            params, toks[:, t:t + 1], caches, jnp.asarray(t, jnp.int32),
            cfg)
        if t in (S_pre, S_pre + cfg.window - 1, S_total - 1):
            full_logits, _ = forward(
                params, {"tokens": toks[:, :t + 1]}, cfg)
            np.testing.assert_allclose(
                np.asarray(step_logits[:, 0], np.float32),
                np.asarray(full_logits[:, -1], np.float32),
                rtol=3e-3, atol=3e-3, err_msg=f"step {t}")
