"""Batch-parallel SpMM across the stack: the (format, op) dispatch
registry, per-format SpMM parity against the dense oracle, the SELL
empty-bucket regression, the batch-aware auto-tuner, and the micro-batched
serving queue."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import dispatch, spmm, spmv
from repro.core.autotune import (FormatMeasurement, MachineModel,
                                 OfflineRecord, TuningDB,
                                 decide_generalized, offline_phase)
from repro.core.formats import FORMAT_NAMES, BucketedELL, MatrixStats
from repro.core.transform import (TRANSFORMS_HOST, csr_from_dense,
                                  host_csr_to_sell)
from repro.serve import SpMVService

# every registered format (FORMAT_NAMES is now derived from the registry,
# so the literal here is a deliberate second witness, not a copy)
ALL_FORMATS = ("csr", "coo_row", "coo_col", "ccs", "ell_row", "ell_col",
               "sell", "bcsr", "hybrid")


def random_dense(rng, n_rows, n_cols, density):
    d = (rng.random((n_rows, n_cols)) < density).astype(np.float32)
    return d * rng.normal(1.0, 1.0, size=d.shape).astype(np.float32)


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(23)


@pytest.fixture(scope="module")
def problem(rng):
    dense = random_dense(rng, 96, 72, 0.12)
    return dense, csr_from_dense(dense, pad=8)


# ---------------------------------------------------------------------------
# dispatch registry: the single source of truth
# ---------------------------------------------------------------------------
def test_every_format_registered_for_both_ops():
    for f in ALL_FORMATS:
        for op in dispatch.OPS:
            assert dispatch.has_impl(f, op, tier="reference"), (f, op)
    assert set(FORMAT_NAMES) <= set(dispatch.registered_formats("spmm"))


def test_format_of_roundtrip(problem):
    _, m = problem
    for f in ALL_FORMATS:
        assert dispatch.format_of(TRANSFORMS_HOST[f](m)) == f


def test_kernel_tables_are_registry_views():
    from repro.kernels import ops
    from repro.kernels.ops import KERNEL_SPMM_IMPLS, KERNEL_SPMV_IMPLS
    assert KERNEL_SPMV_IMPLS == dispatch.impl_table("spmv", "kernel")
    assert KERNEL_SPMM_IMPLS == dispatch.impl_table("spmm", "kernel")
    # a format without a kernel-tier entry falls back to the reference tier
    assert not dispatch.has_impl("dense", "spmm", tier="kernel")
    dispatch.register_impl("dense", "spmm", lambda m, x: m @ x)
    try:
        assert dispatch.get_impl("dense", "spmm", tier="kernel") \
            is dispatch.get_impl("dense", "spmm", tier="reference")
    finally:
        dispatch._IMPLS.pop(("dense", "spmm", "reference"))
    # ccs, bcsr and csr are served by native kernels, not fallbacks/detours
    assert dispatch.get_impl("ccs", "spmm", tier="kernel") \
        is not dispatch.get_impl("ccs", "spmm", tier="reference")
    assert dispatch.get_impl("bcsr", "spmm", tier="kernel") \
        is not dispatch.get_impl("bcsr", "spmm", tier="reference")
    assert dispatch.get_impl("csr", "spmv", tier="kernel") is ops.spmv_csr
    assert dispatch.get_impl("csr", "spmv", tier="kernel") \
        is not ops.spmv_csr_via_coo


def test_unknown_format_and_op_raise(problem):
    _, m = problem
    with pytest.raises(TypeError):
        dispatch.format_of(object())
    with pytest.raises(KeyError):
        dispatch.register_impl("csr", "spmv_t", lambda m, x: x)
    with pytest.raises(ValueError):
        dispatch.spmm(m, jnp.ones((72,)))


# ---------------------------------------------------------------------------
# SpMM parity: every registered format vs the dense A @ X oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fmt", ALL_FORMATS)
@pytest.mark.parametrize("batch", [1, 3, 128])
def test_spmm_matches_dense_oracle(problem, rng, fmt, batch):
    dense, m = problem
    obj = TRANSFORMS_HOST[fmt](m)
    X = jnp.asarray(rng.normal(size=(m.n_cols, batch)).astype(np.float32))
    Y = spmm(obj, X)
    assert Y.shape == (m.n_rows, batch)
    np.testing.assert_allclose(np.asarray(Y), dense @ np.asarray(X),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("fmt", ALL_FORMATS)
def test_spmm_b1_consistent_with_spmv(problem, rng, fmt):
    dense, m = problem
    obj = TRANSFORMS_HOST[fmt](m)
    x = jnp.asarray(rng.normal(size=m.n_cols).astype(np.float32))
    y = spmv(obj, x)
    Y = spmm(obj, x[:, None])
    np.testing.assert_allclose(np.asarray(Y[:, 0]), np.asarray(y),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("fmt", ["csr", "coo_row", "ell_row", "ell_col",
                                 "sell", "hybrid"])
def test_spmm_kernel_tier_matches_dense(problem, rng, fmt):
    dense, m = problem
    obj = TRANSFORMS_HOST[fmt](m)
    X = jnp.asarray(rng.normal(size=(m.n_cols, 3)).astype(np.float32))
    Y = dispatch.spmm(obj, X, tier="kernel")
    np.testing.assert_allclose(np.asarray(Y), dense @ np.asarray(X),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# SELL empty-bucket regression (all-zero matrix)
# ---------------------------------------------------------------------------
def test_sell_empty_buckets_return_zeros():
    from repro.kernels import ops
    x = jnp.ones((9,), jnp.float32)
    empty = BucketedELL(perm=np.arange(12, dtype=np.int32), buckets=(),
                        row_offsets=(), shape=(12, 9), nnz=0)
    for fn in (ops.spmv_sell, spmv):
        y = fn(empty, x)
        assert y.shape == (12,) and y.dtype == x.dtype
        assert not np.any(np.asarray(y))
    for fn in (ops.spmm_sell, spmm):
        Y = fn(empty, jnp.ones((9, 4), jnp.float32))
        assert Y.shape == (12, 4) and not np.any(np.asarray(Y))


def test_sell_all_zero_matrix_via_transform():
    from repro.kernels import ops
    z = csr_from_dense(np.zeros((12, 9), np.float32), pad=8)
    sell = host_csr_to_sell(z)
    x = jnp.ones((9,), jnp.float32)
    for got in (ops.spmv_sell(sell, x), spmv(sell, x)):
        assert got.shape == (12,) and got.dtype == x.dtype
        assert not np.any(np.asarray(got))


# ---------------------------------------------------------------------------
# batch-aware auto-tuner
# ---------------------------------------------------------------------------
def test_machine_model_batch_scales_gathers():
    st = MatrixStats(n=1000, nnz=5000, mu=5, sigma=1, d_mat=0.2,
                     max_row=8, min_row=3)
    mm = MachineModel()
    for fmt in ("csr", "coo_row", "ell_row", "sell", "hybrid"):
        t1, t8 = mm.t_spmv(fmt, st, batch=1), mm.t_spmv(fmt, st, batch=8)
        # matrix stream amortizes: dearer per call, cheaper per product
        assert t1 < t8 < 8 * t1, fmt


def test_decide_generalized_batch_amortizes_transform():
    # transform worth 30 CSR-SpMVs, speedup 2x: k=20 single-vector calls
    # cannot amortize it, but 20 calls x 16 RHS can (k*B rule)
    st = MatrixStats(n=1000, nnz=5000, mu=5, sigma=1, d_mat=0.2,
                     max_row=8, min_row=3)
    rec = OfflineRecord(name="a", n=1000, nnz=5000, mu=5, sigma=1,
                        d_mat=0.2, t_crs=1.0,
                        formats={"ell_row": FormatMeasurement(
                            t_spmv=0.5, t_trans=30.0, sp=2.0, tt=30.0,
                            r=2.0 / 30, mem_ratio=1.5)})
    db = TuningDB(machine="t", c=1.0, records=[rec],
                  d_star={"ell_row": 0.5})
    assert decide_generalized(db, st, 20, formats=["ell_row"]).fmt == "csr"
    assert decide_generalized(db, st, 20, formats=["ell_row"],
                              batch=16).fmt == "ell_row"


def test_predict_rescales_tt_across_batches():
    # records measured at batch=4, queried at batch=8: tt is per-4-wide
    # call, so the per-8-wide-call overhead is tt * 4/8 — not tt / 8
    meas = FormatMeasurement(t_spmv=0.5, t_trans=30.0, sp=2.0, tt=7.5,
                             r=2.0 / 7.5, mem_ratio=1.5)
    rec = OfflineRecord(name="a", n=1000, nnz=5000, mu=5, sigma=1,
                        d_mat=0.2, t_crs=1.0, batch=4,
                        formats={"ell_row": meas})
    db = TuningDB(machine="t", c=1.0, records=[rec],
                  d_star={"ell_row": 0.5})
    assert db.predict("ell_row", 0.2, batch=4)["tt"] == pytest.approx(7.5)
    pred = db.predict("ell_row", 0.2, batch=8)
    assert not pred["batch_matched"]
    assert pred["tt"] == pytest.approx(7.5 * 4 / 8)
    # legacy call without a batch axis is untouched
    assert db.predict("ell_row", 0.2)["tt"] == pytest.approx(7.5)


def test_offline_phase_with_batch(rng):
    dense = random_dense(rng, 64, 64, 0.1)
    m = csr_from_dense(dense, pad=8)
    db = offline_phase([("r", m)], formats=("ell_row",), iters=1, batch=3)
    rec = db.records[0]
    assert rec.batch == 3
    meas = rec.formats["ell_row"]
    assert meas.t_spmv > 0 and np.isfinite(meas.r)
    # records round-trip with their batch axis
    assert TuningDB.from_json(db.to_json()).records[0].batch == 3
    # batch-matched prediction is preferred over the global fallback
    assert db.predict("ell_row", rec.d_mat, batch=3)["batch_matched"]
    assert not db.predict("ell_row", rec.d_mat, batch=64)["batch_matched"]


# ---------------------------------------------------------------------------
# serving: direct SpMM + the micro-batching queue
# ---------------------------------------------------------------------------
def test_service_spmm_and_microbatch_queue(rng):
    dense = random_dense(rng, 100, 80, 0.1)
    m = csr_from_dense(dense, pad=8)
    svc = SpMVService(max_batch=4)
    svc.register("m", m, expected_iterations=200, batch=8)

    X = rng.normal(size=(80, 5)).astype(np.float32)
    Y = svc.spmm("m", jnp.asarray(X))
    np.testing.assert_allclose(np.asarray(Y), dense @ X, rtol=1e-4,
                               atol=1e-4)

    # 6 submits with max_batch=4: one auto-flush, then a ragged tail of 2
    futs = [svc.submit("m", jnp.asarray(X[:, i % 5])) for i in range(6)]
    assert svc.pending_count("m") == 2
    assert svc.flush("m") == 2
    for i, f in enumerate(futs):
        np.testing.assert_allclose(np.asarray(f.result()),
                                   dense @ X[:, i % 5],
                                   rtol=1e-4, atol=1e-4)
    st = svc.stats()["m"]
    assert st["n_spmm_calls"] == 3 and st["n_spmm_cols"] == 11
    assert st["pending"] == 0 and st["builds"] == 1


def test_service_flush_all_and_empty(rng):
    dense = random_dense(rng, 40, 30, 0.2)
    m = csr_from_dense(dense, pad=8)
    svc = SpMVService(max_batch=8)
    svc.register("a", m, measure_baseline=False)
    svc.register("b", m, measure_baseline=False)
    assert svc.flush() == 0
    fa = svc.submit("a", jnp.ones((30,), jnp.float32))
    fb = svc.submit("b", jnp.ones((30,), jnp.float32))
    assert svc.flush() == 2
    np.testing.assert_allclose(np.asarray(fa.result()),
                               dense @ np.ones(30, np.float32),
                               rtol=1e-4, atol=1e-4)
    assert fb.done()


def test_service_submit_rejects_bad_shape_and_flush_fails_whole_panel(rng):
    dense = random_dense(rng, 40, 30, 0.2)
    m = csr_from_dense(dense, pad=8)
    # guard=False: with the degradation ladder on, a failing SpMM is
    # served by a fallback rung instead of raising (tests/test_guard.py);
    # this test pins the raw failure-propagation contract underneath it
    svc = SpMVService(max_batch=8, guard=False)
    svc.register("m", m, measure_baseline=False)
    with pytest.raises(ValueError):
        svc.submit("m", jnp.ones((31,), jnp.float32))   # wrong n_cols
    # a failing SpMM must resolve every queued future with the exception,
    # never strand one
    fut = svc.submit("m", jnp.ones((30,), jnp.float32))
    svc.entries["m"].spmm_fn = _boom
    # a healthy second matrix must still be served by the same flush()
    dense2 = random_dense(rng, 40, 30, 0.2)
    svc.register("ok", csr_from_dense(dense2, pad=8),
                 measure_baseline=False)
    x2 = np.arange(30, dtype=np.float32)
    fut2 = svc.submit("ok", jnp.asarray(x2))
    with pytest.raises(RuntimeError):
        svc.flush()
    with pytest.raises(RuntimeError):
        fut.result(timeout=0)
    np.testing.assert_allclose(np.asarray(fut2.result(timeout=0)),
                               dense2 @ x2, rtol=1e-4, atol=1e-4)


def _boom(m, x):
    raise RuntimeError("kernel failure")


def test_service_reregister_drains_pending_first(rng):
    dense = random_dense(rng, 40, 30, 0.2)
    m = csr_from_dense(dense, pad=8)
    svc = SpMVService(max_batch=8)
    svc.register("m", m, measure_baseline=False)
    x = np.arange(30, dtype=np.float32)
    fut = svc.submit("m", jnp.asarray(x))
    svc.register("m", m, measure_baseline=False)   # drains, then rebuilds
    np.testing.assert_allclose(np.asarray(fut.result(timeout=0)), dense @ x,
                               rtol=1e-4, atol=1e-4)
    assert svc.stats()["m"]["builds"] == 2


def test_service_evict_releases_and_reregister_counts(rng):
    dense = random_dense(rng, 50, 50, 0.1)
    m = csr_from_dense(dense, pad=8)
    svc = SpMVService()
    e1 = svc.register("m", m, measure_baseline=False)
    svc.spmv("m", jnp.ones((50,), jnp.float32))
    assert svc.stats()["m"]["compiled"] >= 1
    e2 = svc.register("m", m, measure_baseline=False)   # replaces e1
    assert e2 is not e1 and svc.stats()["m"]["builds"] == 2
    # the stale entry's dispatchers are released
    with pytest.raises(RuntimeError):
        e1.fn(e1.matrix, jnp.ones((50,), jnp.float32))
    fut = svc.submit("m", jnp.ones((50,), jnp.float32))
    svc.evict("m")
    assert "m" not in svc.entries
    with pytest.raises(KeyError):
        fut.result(timeout=0)


def test_service_deadline_flush_and_poll(rng):
    from repro.obs import FakeClock

    dense = random_dense(rng, 40, 30, 0.2)
    m = csr_from_dense(dense, pad=8)
    # deadline ages are read off the service's injected clock, so the whole
    # policy is tested deterministically — no sleeps, no scheduler jitter
    clk = FakeClock()
    svc = SpMVService(max_batch=64, deadline_ms=1.0, clock=clk)
    svc.register("m", m, measure_baseline=False)
    x = np.arange(30, dtype=np.float32)
    f1 = svc.submit("m", jnp.asarray(x))
    assert not f1.done()                      # queue far below max_batch
    clk.advance(0.005)                        # 5 ms > the 1 ms deadline
    # the next submit sees the oldest future past its deadline and flushes
    f2 = svc.submit("m", jnp.asarray(x))
    assert f1.done() and f2.done()
    np.testing.assert_allclose(np.asarray(f1.result(timeout=0)), dense @ x,
                               rtol=1e-4, atol=1e-4)
    # poll() sweeps overdue queues without new traffic
    f3 = svc.submit("m", jnp.asarray(x))
    assert svc.poll() == 0                    # not yet overdue
    clk.advance(0.0015)                       # now past the deadline
    assert svc.poll() == 1 and f3.done()
    # no deadline configured -> poll is a no-op and nothing auto-flushes
    clk2 = FakeClock()
    svc2 = SpMVService(max_batch=64, clock=clk2)
    svc2.register("m", m, measure_baseline=False)
    f4 = svc2.submit("m", jnp.asarray(x))
    clk2.advance(0.005)
    svc2.submit("m", jnp.asarray(x))
    assert svc2.poll() == 0 and not f4.done()
    assert svc2.flush("m") == 2


def test_service_register_with_tuner_serves_tuned_kernels(rng):
    from repro.core.kernel_tune import KernelTuner

    def fake_timer(thunk, g):
        thunk()
        return 1.0 if g is None else 0.5

    dense = random_dense(rng, 96, 64, 0.15)
    m = csr_from_dense(dense, pad=8)
    svc = SpMVService(tuner=KernelTuner(timer=fake_timer, interpret=True),
                      max_batch=4)
    svc.register("m", m, measure_baseline=False)
    st = svc.stats()["m"]
    assert st["tuned"].get("spmv"), st  # a geometry won per block format
    x = rng.normal(size=64).astype(np.float32)
    np.testing.assert_allclose(np.asarray(svc.spmv("m", jnp.asarray(x))),
                               dense @ x, rtol=1e-4, atol=1e-4)
    X = rng.normal(size=(64, 4)).astype(np.float32)
    np.testing.assert_allclose(np.asarray(svc.spmm("m", jnp.asarray(X))),
                               dense @ X, rtol=1e-4, atol=1e-4)


def test_service_sell_blocks_carry_per_bucket_geometry(rng):
    """A sell block registered through the service is tuned per bucket:
    the baked geometry carries a width-keyed table, and queries serve
    through it (the serve-side half of the per-bucket SELL story)."""
    from repro.core.autotune import MachineModel
    from repro.core.kernel_tune import KernelTuner
    from repro.core.policy import MemoryPolicy

    def width_timer(thunk, g):
        thunk()
        return 1.0 if g is None else 0.5 - (g.block_w or 0) * 1e-3

    # skewed rows so the sell transform produces a real bucket structure
    dense = np.zeros((128, 96), np.float32)
    for r in range(16):
        dense[r, rng.choice(96, 50, replace=False)] = rng.normal(size=50)
    for r in range(16, 128):
        dense[r, rng.choice(96, 6, replace=False)] = rng.normal(size=6)
    m = csr_from_dense(dense, pad=8)
    svc = SpMVService(tuner=KernelTuner(timer=width_timer, interpret=True),
                      strategy="fixed",
                      # steer the block decision onto sell: csr priced out,
                      # sell's padded footprint allowed
                      model=MachineModel(segment_penalty=1e4),
                      policy=MemoryPolicy(budget_ratio=10.0))
    svc.register("m", m, measure_baseline=False, formats=("sell",))
    st = svc.stats()["m"]
    assert st["formats"] == {"sell": 1}, st["formats"]
    for op in ("spmv", "spmm"):
        tuned = st["tuned"][op].get("sell")
        assert tuned is not None and tuned.get("buckets"), (op, tuned)
    x = rng.normal(size=96).astype(np.float32)
    np.testing.assert_allclose(np.asarray(svc.spmv("m", jnp.asarray(x))),
                               dense @ x, rtol=1e-4, atol=1e-4)
    X = rng.normal(size=(96, 4)).astype(np.float32)
    np.testing.assert_allclose(np.asarray(svc.spmm("m", jnp.asarray(X))),
                               dense @ X, rtol=1e-4, atol=1e-4)
