"""Format + transformation correctness: round trips, the paper's CRS->CCS
algorithm vs its vectorized/device versions, property tests via hypothesis."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (MatrixStats, csr_from_dense, host_csr_to_ccs,
                        host_csr_to_ccs_paper, host_csr_to_coo_col,
                        host_csr_to_coo_row, host_csr_to_ell,
                        host_csr_to_sell, device_csr_to_ccs,
                        device_csr_to_coo_col, device_csr_to_coo_row,
                        device_csr_to_ell, memory_bytes)
from repro.core.suite import synthesize, TABLE1


def random_dense(rng, n_rows, n_cols, density):
    d = (rng.random((n_rows, n_cols)) < density).astype(np.float32)
    return d * rng.normal(1.0, 1.0, size=d.shape).astype(np.float32)


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(0)


# ---------------------------------------------------------------------------
# dense round trips
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape,density", [((7, 5), 0.3), ((64, 64), 0.05),
                                           ((33, 129), 0.15), ((1, 8), 0.5),
                                           ((128, 16), 0.9)])
def test_csr_roundtrip(rng, shape, density):
    dense = random_dense(rng, *shape, density)
    m = csr_from_dense(dense, pad=8)
    np.testing.assert_allclose(m.todense(), dense, rtol=1e-6)


@pytest.mark.parametrize("transform", [host_csr_to_coo_row,
                                       host_csr_to_coo_col,
                                       host_csr_to_ell,
                                       host_csr_to_sell,
                                       host_csr_to_ccs])
def test_transform_preserves_matrix(rng, transform):
    dense = random_dense(rng, 50, 40, 0.12)
    m = csr_from_dense(dense, pad=8)
    np.testing.assert_allclose(transform(m).todense(), dense, rtol=1e-6)


def test_ell_col_order(rng):
    dense = random_dense(rng, 20, 30, 0.2)
    m = csr_from_dense(dense)
    ell = host_csr_to_ell(m, order="col")
    assert ell.data.shape[1] == 20  # (width, n_rows)
    np.testing.assert_allclose(ell.todense(), dense, rtol=1e-6)


def test_ell_width_truncation(rng):
    dense = random_dense(rng, 16, 16, 0.5)
    m = csr_from_dense(dense)
    ell = host_csr_to_ell(m, width=2)
    assert ell.width == 2
    assert ell.nnz <= m.nnz
    # every stored entry must be a real matrix entry
    d = ell.todense()
    mask = d != 0
    np.testing.assert_allclose(d[mask], dense[mask], rtol=1e-6)


# ---------------------------------------------------------------------------
# the paper's CRS->CCS counting algorithm is the oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape,density", [((9, 9), 0.3), ((17, 40), 0.1),
                                           ((40, 17), 0.25)])
def test_ccs_matches_paper_algorithm(rng, shape, density):
    dense = random_dense(rng, *shape, density)
    m = csr_from_dense(dense, pad=4)
    ref = host_csr_to_ccs_paper(m)
    fast = host_csr_to_ccs(m)
    np.testing.assert_array_equal(np.asarray(ref.indptr),
                                  np.asarray(fast.indptr))
    np.testing.assert_array_equal(np.asarray(ref.rows)[:m.nnz],
                                  np.asarray(fast.rows)[:m.nnz])
    np.testing.assert_allclose(np.asarray(ref.data)[:m.nnz],
                               np.asarray(fast.data)[:m.nnz])


# ---------------------------------------------------------------------------
# device (jit) transformations == host transformations
# ---------------------------------------------------------------------------
def test_device_ell_matches_host(rng):
    dense = random_dense(rng, 48, 32, 0.2)
    m = csr_from_dense(dense, pad=8)
    host = host_csr_to_ell(m)
    dev = jax.jit(lambda mm: device_csr_to_ell(mm, width=host.width))(m)
    np.testing.assert_allclose(np.asarray(dev.data), host.data, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(dev.cols), host.cols)


def test_device_coo_row_matches_host(rng):
    dense = random_dense(rng, 31, 31, 0.15)
    m = csr_from_dense(dense, pad=8)
    host = host_csr_to_coo_row(m)
    dev = jax.jit(device_csr_to_coo_row)(m)
    np.testing.assert_array_equal(np.asarray(dev.rows)[:m.nnz],
                                  host.rows[:m.nnz])


def test_device_coo_col_and_ccs(rng):
    dense = random_dense(rng, 25, 37, 0.2)
    m = csr_from_dense(dense, pad=8)
    host = host_csr_to_coo_col(m)
    dev = jax.jit(device_csr_to_coo_col)(m)
    np.testing.assert_array_equal(np.asarray(dev.cols)[:m.nnz],
                                  host.cols[:m.nnz])
    np.testing.assert_array_equal(np.asarray(dev.rows)[:m.nnz],
                                  host.rows[:m.nnz])
    np.testing.assert_allclose(np.asarray(dev.data)[:m.nnz],
                               host.data[:m.nnz])
    dccs = jax.jit(device_csr_to_ccs)(m)
    np.testing.assert_array_equal(np.asarray(dccs.indptr),
                                  np.asarray(host_csr_to_ccs(m).indptr))


# ---------------------------------------------------------------------------
# property tests
# ---------------------------------------------------------------------------
@settings(deadline=None, max_examples=30)
@given(n_rows=st.integers(1, 40), n_cols=st.integers(1, 40),
       density=st.floats(0.01, 0.9), seed=st.integers(0, 2**31 - 1))
def test_property_all_transforms_preserve_spmv(n_rows, n_cols, density, seed):
    """Invariant: every format transformation preserves A @ x."""
    r = np.random.default_rng(seed)
    dense = random_dense(r, n_rows, n_cols, density)
    m = csr_from_dense(dense, pad=4)
    x = r.normal(size=n_cols).astype(np.float32)
    want = dense @ x
    for tr in (host_csr_to_coo_row, host_csr_to_coo_col, host_csr_to_ell,
               host_csr_to_sell, host_csr_to_ccs):
        got = tr(m).todense() @ x
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@settings(deadline=None, max_examples=20)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 60))
def test_property_dmat_scale_invariant(seed, n):
    """D_mat is invariant under value scaling (depends only on structure)."""
    r = np.random.default_rng(seed)
    dense = random_dense(r, n, n, 0.2)
    if (dense != 0).sum() == 0:
        return
    m1 = csr_from_dense(dense)
    m2 = csr_from_dense(dense * 7.5)
    assert MatrixStats.of(m1).d_mat == pytest.approx(MatrixStats.of(m2).d_mat)


# ---------------------------------------------------------------------------
# suite reproduces Table 1 statistics
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("spec", TABLE1, ids=lambda s: s.name)
def test_suite_matches_table1(spec):
    scale = min(1.0, 4000 / spec.n)  # keep CI fast; stats are scale-invariant
    m = synthesize(spec, scale=scale)
    st_ = MatrixStats.of(m)
    assert st_.mu == pytest.approx(spec.mu, rel=0.2)
    assert st_.d_mat == pytest.approx(spec.d_mat, rel=0.3, abs=0.03)


def test_sell_memory_bounded(rng):
    """sigma-sorted bucketing must not blow up memory vs plain ELL."""
    spec = [s for s in TABLE1 if s.name == "memplus"][0]
    m = synthesize(spec, scale=0.2)
    ell = host_csr_to_ell(m)
    sell = host_csr_to_sell(m)
    assert sell.padded_nnz() <= np.prod(ell.data.shape)
    assert memory_bytes(sell) <= memory_bytes(ell) * 1.05


# ---------------------------------------------------------------------------
# BCSR (the paper's named future work)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape,density,block", [
    ((32, 32), 0.2, 8), ((65, 40), 0.1, 8), ((16, 16), 0.9, 4),
    ((100, 64), 0.05, 16)])
def test_bcsr_roundtrip_and_spmv(rng, shape, density, block):
    from repro.core.transform import host_csr_to_bcsr
    from repro.core.spmv import spmv_bcsr
    from repro.core.formats import bcsr_fill_ratio
    dense = random_dense(rng, *shape, density)
    m = csr_from_dense(dense, pad=4)
    bm = host_csr_to_bcsr(m, block=block)
    np.testing.assert_allclose(bm.todense(), dense, rtol=1e-6)
    x = rng.normal(size=shape[1]).astype(np.float32)
    got = jax.jit(spmv_bcsr)(bm, jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), dense @ x,
                               rtol=2e-4, atol=2e-4)
    assert 0 < bcsr_fill_ratio(bm) <= 1.0


def test_bcsr_fill_ratio_tracks_structure(rng):
    """Banded matrices fill blocks densely; scattered ones don't — the
    statistic the AT method would threshold on for BCSR (like D_mat for
    ELL)."""
    from repro.core.transform import host_csr_to_bcsr
    from repro.core.formats import bcsr_fill_ratio
    from repro.core.suite import synthesize, TABLE1
    banded = synthesize([s for s in TABLE1 if s.name == "chem_master1"][0],
                        scale=0.03)
    scattered = synthesize([s for s in TABLE1 if s.name == "memplus"][0],
                           scale=0.03)
    fb = bcsr_fill_ratio(host_csr_to_bcsr(banded, block=4))
    fs = bcsr_fill_ratio(host_csr_to_bcsr(scattered, block=4))
    assert fb > fs
