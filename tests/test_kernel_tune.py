"""Kernel launch-geometry auto-tuner: deterministic search, persistence,
nearest-neighbour fallback, and the per-call tuning hint."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import dispatch
from repro.core.autotune import TuningDB
from repro.core.kernel_tune import (GeometryRecord, KernelTuner, TileGeometry,
                                    candidate_geometries, nearest_geometry)
from repro.core.transform import (csr_from_dense, host_csr_to_bcsr,
                                  host_csr_to_coo_row, host_csr_to_ell)


@pytest.fixture(scope="module")
def problem():
    rng = np.random.default_rng(11)
    dense = ((rng.random((150, 120)) < 0.1) *
             rng.normal(size=(150, 120))).astype(np.float32)
    return dense, csr_from_dense(dense, pad=8)


def fake_timer(prefer_rows=32, prefer_nnz=1024):
    """Deterministic cost model: still executes each candidate once (so the
    sweep validates every launch), but 'times' it by geometry alone."""
    calls = []

    def timer(thunk, g):
        thunk()
        calls.append(g)
        if g is None:
            return 1.0
        cost = 0.5
        cost += abs((g.block_rows or prefer_rows) - prefer_rows) * 1e-3
        cost += abs((g.block_nnz or prefer_nnz) - prefer_nnz) * 1e-6
        return cost

    timer.calls = calls
    return timer


# ---------------------------------------------------------------------------
# candidate grids
# ---------------------------------------------------------------------------
def test_candidates_bounded_and_deduped():
    for fmt in ("ell_row", "coo_row", "csr", "bcsr", "sell"):
        for op in ("spmv", "spmm"):
            cands = candidate_geometries(fmt, op, n_rows=150, width=20,
                                         nnz_pad=1800, batch=16)
            assert 0 < len(cands) <= 40, (fmt, op, len(cands))
            keys = [(g.block_rows, g.block_w, g.block_k, g.block_nnz)
                    for g in cands]
            assert len(keys) == len(set(keys)), (fmt, op)
    assert candidate_geometries("ccs", "spmv") == []


def test_candidates_clamped_to_profile():
    cands = candidate_geometries("ell_row", "spmv", n_rows=20, width=10)
    assert all(g.block_rows <= 24 for g in cands)
    assert all(g.block_w <= 16 for g in cands)


# ---------------------------------------------------------------------------
# deterministic tuning + memoization
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("transform,fmt", [
    (lambda m: m, "csr"),
    (host_csr_to_coo_row, "coo_row"),
    (host_csr_to_ell, "ell_row"),
    (lambda m: host_csr_to_bcsr(m, block=8), "bcsr"),
], ids=["csr", "coo_row", "ell_row", "bcsr"])
def test_tune_is_deterministic_with_fake_timer(problem, transform, fmt):
    _, m = problem
    obj = transform(m)
    recs = [KernelTuner(timer=fake_timer(), interpret=True).tune(obj)
            for _ in range(2)]
    assert recs[0].fmt == fmt
    assert recs[0].geometry == recs[1].geometry
    assert recs[0].t_best <= recs[0].t_default
    assert recs[0].speedup >= 1.0


def test_tune_memoizes_per_profile(problem):
    _, m = problem
    timer = fake_timer()
    tuner = KernelTuner(timer=timer, interpret=True)
    r1 = tuner.tune(m)
    n_timed = len(timer.calls)
    r2 = tuner.tune(m)
    assert r2 is r1 and len(timer.calls) == n_timed  # no re-timing
    assert tuner.best(m) == r1.geometry


def test_csr_winner_carries_exact_slab_bound(problem):
    _, m = problem
    rec = KernelTuner(timer=fake_timer(), interpret=True).tune(m)
    from repro.kernels.csr_spmv import slabs_needed
    g = rec.geometry
    assert g.slabs_per_block == slabs_needed(m.indptr, g.block_rows,
                                             g.block_nnz)


# ---------------------------------------------------------------------------
# TuningDB persistence + nearest-neighbour fallback
# ---------------------------------------------------------------------------
def test_tuningdb_geometry_roundtrip(problem):
    _, m = problem
    db = TuningDB(machine="t", c=1.0, records=[], d_star={})
    tuner = KernelTuner(db=db, timer=fake_timer(), interpret=True)
    rec = tuner.tune(m)
    assert db.geometries, "tuner must record into the shared db"
    db2 = TuningDB.from_json(db.to_json())
    assert db2.geometries[0].geometry == rec.geometry
    assert db2.geometries[0].d_mat == rec.d_mat
    # a fresh tuner seeded from the reloaded db answers from memo
    tuner2 = KernelTuner(db=db2)
    assert tuner2.best(m) == rec.geometry


def test_tuningdb_json_backcompat():
    """Old dbs (no geometries key) still load."""
    db = TuningDB(machine="t", c=1.0, records=[], d_star={})
    import json
    obj = json.loads(db.to_json())
    obj.pop("geometries")
    db2 = TuningDB.from_json(json.dumps(obj))
    assert db2.geometries == []


def test_nearest_geometry_is_dmat_keyed():
    mk = lambda d, rows: GeometryRecord(
        fmt="ell_row", op="spmv", batch=1, n=100, nnz=1000, d_mat=d,
        geometry=TileGeometry(block_rows=rows, slabs_per_block=7),
        t_best=1.0, t_default=2.0)
    recs = [mk(0.05, 8), mk(3.0, 256)]
    low = nearest_geometry(recs, "ell_row", "spmv", d_mat=0.08)
    high = nearest_geometry(recs, "ell_row", "spmv", d_mat=2.0)
    assert low.block_rows == 8 and high.block_rows == 256
    # the data-dependent coverage bound never travels to another matrix
    assert low.slabs_per_block is None
    assert nearest_geometry(recs, "coo_row", "spmv", d_mat=1.0) is None


def test_nearest_geometry_prefers_batch_match():
    mk = lambda b, rows: GeometryRecord(
        fmt="ell_row", op="spmm", batch=b, n=100, nnz=1000, d_mat=1.0,
        geometry=TileGeometry(block_rows=rows), t_best=1.0, t_default=2.0)
    recs = [mk(8, 8), mk(128, 256)]
    assert nearest_geometry(recs, "ell_row", "spmm", d_mat=1.0,
                            batch=128).block_rows == 256


# ---------------------------------------------------------------------------
# the per-call tuning hint through dispatch
# ---------------------------------------------------------------------------
def test_dispatch_tuning_hint_matches_reference(problem):
    dense, m = problem
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=120).astype(np.float32))
    g = TileGeometry(block_rows=64, block_nnz=1024)
    got = dispatch.spmv(m, x, tier="kernel", tuning=g)
    np.testing.assert_allclose(np.asarray(got), dense @ np.asarray(x),
                               rtol=2e-4, atol=2e-4)
    # reference tier ignores the hint instead of crashing
    ref = dispatch.spmv(m, x, tier="reference", tuning=g)
    np.testing.assert_allclose(np.asarray(ref), dense @ np.asarray(x),
                               rtol=2e-4, atol=2e-4)


def test_offline_phase_records_geometries(problem):
    _, m = problem
    from repro.core.autotune import offline_phase
    from repro.kernels import ops
    tuner = KernelTuner(timer=fake_timer(), interpret=True)
    db = offline_phase([("m0", m)], formats=("ell_row",), iters=1,
                       spmv_impls=ops.KERNEL_SPMV_IMPLS, tuner=tuner,
                       machine="fake")
    assert {g.fmt for g in db.geometries} == {"csr", "ell_row"}
    assert db.best_geometry("ell_row", d_mat=1.0) is not None
