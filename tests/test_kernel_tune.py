"""Kernel launch-geometry auto-tuner: deterministic search, persistence,
nearest-neighbour fallback, and the per-call tuning hint."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import dispatch
from repro.core.autotune import TuningDB
from repro.core.kernel_tune import (GeometryRecord, KernelTuner, TileGeometry,
                                    candidate_geometries, nearest_geometry)
from repro.core.transform import (csr_from_dense, host_csr_to_bcsr,
                                  host_csr_to_coo_row, host_csr_to_ell)


@pytest.fixture(scope="module")
def problem():
    rng = np.random.default_rng(11)
    dense = ((rng.random((150, 120)) < 0.1) *
             rng.normal(size=(150, 120))).astype(np.float32)
    return dense, csr_from_dense(dense, pad=8)


def fake_timer(prefer_rows=32, prefer_nnz=1024):
    """Deterministic cost model: still executes each candidate once (so the
    sweep validates every launch), but 'times' it by geometry alone."""
    calls = []

    def timer(thunk, g):
        thunk()
        calls.append(g)
        if g is None:
            return 1.0
        cost = 0.5
        cost += abs((g.block_rows or prefer_rows) - prefer_rows) * 1e-3
        cost += abs((g.block_nnz or prefer_nnz) - prefer_nnz) * 1e-6
        return cost

    timer.calls = calls
    return timer


# ---------------------------------------------------------------------------
# candidate grids
# ---------------------------------------------------------------------------
def test_candidates_bounded_and_deduped():
    for fmt in ("ell_row", "coo_row", "csr", "ccs", "bcsr", "sell"):
        for op in ("spmv", "spmm"):
            cands = candidate_geometries(fmt, op, n_rows=150, width=20,
                                         nnz_pad=1800, batch=16)
            assert 0 < len(cands) <= 40, (fmt, op, len(cands))
            keys = [(g.block_rows, g.block_w, g.block_k, g.block_nnz)
                    for g in cands]
            assert len(keys) == len(set(keys)), (fmt, op)
    # formats without a tunable kernel stay out of the search
    assert candidate_geometries("hybrid", "spmv") == []


def test_candidates_clamped_to_profile():
    cands = candidate_geometries("ell_row", "spmv", n_rows=20, width=10)
    assert all(g.block_rows <= 24 for g in cands)
    assert all(g.block_w <= 16 for g in cands)


# ---------------------------------------------------------------------------
# deterministic tuning + memoization
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("transform,fmt", [
    (lambda m: m, "csr"),
    (host_csr_to_coo_row, "coo_row"),
    (host_csr_to_ell, "ell_row"),
    (lambda m: host_csr_to_bcsr(m, block=8), "bcsr"),
], ids=["csr", "coo_row", "ell_row", "bcsr"])
def test_tune_is_deterministic_with_fake_timer(problem, transform, fmt):
    _, m = problem
    obj = transform(m)
    recs = [KernelTuner(timer=fake_timer(), interpret=True).tune(obj)
            for _ in range(2)]
    assert recs[0].fmt == fmt
    assert recs[0].geometry == recs[1].geometry
    assert recs[0].t_best <= recs[0].t_default
    assert recs[0].speedup >= 1.0


@pytest.mark.slow
def test_tune_memoizes_per_profile(problem):
    _, m = problem
    timer = fake_timer()
    tuner = KernelTuner(timer=timer, interpret=True)
    r1 = tuner.tune(m)
    n_timed = len(timer.calls)
    r2 = tuner.tune(m)
    assert r2 is r1 and len(timer.calls) == n_timed  # no re-timing
    assert tuner.best(m) == r1.geometry


def test_csr_winner_carries_exact_slab_bound(problem):
    _, m = problem
    rec = KernelTuner(timer=fake_timer(), interpret=True).tune(m)
    from repro.kernels.csr_spmv import slabs_needed
    g = rec.geometry
    assert g.slabs_per_block == slabs_needed(m.indptr, g.block_rows,
                                             g.block_nnz)


def test_ccs_tunes_like_every_other_format(problem):
    """CCS has a native kernel + candidate grid: the tuner searches it,
    the winner carries the exact column-pointer slab bound, and the
    geometry round-trips through the db."""
    from repro.core.transform import host_csr_to_ccs
    from repro.kernels.csr_spmv import slabs_needed
    _, m = problem
    ccs = host_csr_to_ccs(m)
    db = TuningDB(machine="t", c=1.0, records=[], d_star={})
    tuner = KernelTuner(db=db, timer=fake_timer(), interpret=True)
    rec = tuner.tune(ccs)
    assert rec.fmt == "ccs" and rec.speedup >= 1.0
    g = rec.geometry
    assert g.slabs_per_block == slabs_needed(ccs.indptr, g.block_rows,
                                             g.block_nnz)
    db2 = TuningDB.from_json(db.to_json())
    assert KernelTuner(db=db2).best(ccs) == g


@pytest.mark.slow
def test_force_retune_replaces_record_in_place(problem):
    """force=True supersedes the memoized record instead of appending a
    duplicate — a re-tuned db keeps one record per key across save/load,
    and nearest_geometry can never resurrect the stale loser."""
    _, m = problem
    db = TuningDB(machine="t", c=1.0, records=[], d_star={})
    tuner = KernelTuner(db=db, timer=fake_timer(prefer_rows=64),
                        interpret=True)
    r1 = tuner.tune(m)
    assert r1.geometry.block_rows == 64
    # the machine "changed its mind": re-tune now prefers a different tile
    tuner._timer = fake_timer(prefer_rows=128)
    r2 = tuner.tune(m, force=True)
    assert r2.geometry.block_rows == 128
    assert len(db.geometries) == 1, "re-tune must not accumulate duplicates"
    db2 = TuningDB.from_json(db.to_json())
    assert len(db2.geometries) == 1
    assert db2.geometries[0].geometry == r2.geometry
    # the NN fallback sees only the fresh winner
    assert (nearest_geometry(db2.geometries, "csr", "spmv",
                             d_mat=r2.d_mat).block_rows == 128)


# ---------------------------------------------------------------------------
# per-bucket SELL geometry
# ---------------------------------------------------------------------------
def width_loving_timer():
    """Prefers the widest band tile a launch offers: buckets of different
    widths then *must* record different winners (their clamped candidate
    grids top out at different block_w)."""
    def timer(thunk, g):
        thunk()
        if g is None:
            return 1.0
        return 0.5 - (g.block_w or 0) * 1e-3
    return timer


def test_legacy_duplicate_records_healed_on_load():
    """A db persisted by the old append-only force=True path carries
    stale duplicates; seeding a tuner from it must keep only the last
    (freshest) record per key, through the db's own list."""
    mk = lambda rows: GeometryRecord(
        fmt="csr", op="spmv", batch=1, n=100, nnz=1000, d_mat=1.0,
        geometry=TileGeometry(block_rows=rows), t_best=1.0, t_default=2.0,
        sig=7)
    db = TuningDB(machine="t", c=1.0, records=[], d_star={},
                  geometries=[mk(64), mk(256)])   # stale loser first
    tuner = KernelTuner(db=db)
    assert len(db.geometries) == 1
    assert db.geometries[0].geometry.block_rows == 256
    assert tuner.best(fmt="csr", d_mat=1.0).block_rows == 256
    assert (nearest_geometry(db.geometries, "csr", "spmv",
                             d_mat=1.0).block_rows == 256)


@pytest.mark.slow
def test_sell_buckets_record_distinct_geometries():
    """Two buckets of different widths each get their own candidate sweep
    and record distinct winning geometries, composed into the aggregate's
    per-bucket table and persisted through the TuningDB."""
    from repro.core.transform import csr_from_dense, host_csr_to_sell
    from repro.kernels import ops
    rng = np.random.default_rng(5)
    # 32 long rows (~60 nnz) + 64 short rows (~10 nnz): two SELL buckets
    dense = np.zeros((96, 128), np.float32)
    for r in range(32):
        cols = rng.choice(128, size=60, replace=False)
        dense[r, cols] = rng.normal(size=60)
    for r in range(32, 96):
        cols = rng.choice(128, size=10, replace=False)
        dense[r, cols] = rng.normal(size=10)
    m = csr_from_dense(dense, pad=8)
    sell = host_csr_to_sell(m, slice_rows=32, width_quantum=8)
    assert len(sell.buckets) >= 2
    db = TuningDB(machine="t", c=1.0, records=[], d_star={})
    tuner = KernelTuner(db=db, timer=width_loving_timer(), interpret=True)
    rec = tuner.tune(sell)

    comps = {g.bucket_w: g for g in db.geometries
             if g.fmt == "sell" and g.bucket_w is not None}
    assert set(comps) == set(sell.widths)
    winners = {w: comps[w].geometry for w in comps}
    assert len(set(winners.values())) >= 2, \
        "buckets of different widths must be able to win different tiles"
    # each bucket's winner saturates its own band, not a broadcast one
    for w, g in winners.items():
        assert g.block_w == w, (w, g)

    # the aggregate's geometry carries the composed table...
    table = dict(rec.geometry.buckets)
    assert table == winners
    # ...the per-bucket component records stay out of the NN fallback...
    nn = nearest_geometry(db.geometries, "sell", "spmv", d_mat=rec.d_mat)
    assert nn is not None and nn.buckets is not None
    # ...and tune -> persist -> reload -> serve is bit-exact
    db2 = TuningDB.from_json(db.to_json())
    g2 = KernelTuner(db=db2).best(sell)
    assert g2 == rec.geometry
    x = rng.normal(size=128).astype(np.float32)
    got = ops.spmv_sell(sell, jnp.asarray(x), interpret=True, tuning=g2)
    np.testing.assert_allclose(np.asarray(got), dense @ x,
                               rtol=2e-4, atol=2e-4)


def test_sell_tune_memoizes_per_bucket():
    """A second tune() answers every bucket from the memo (no re-timing)."""
    from repro.core.transform import csr_from_dense, host_csr_to_sell
    rng = np.random.default_rng(6)
    dense = ((rng.random((64, 50)) < 0.2) *
             rng.normal(size=(64, 50))).astype(np.float32)
    sell = host_csr_to_sell(csr_from_dense(dense, pad=8), slice_rows=16)
    timer = fake_timer()
    tuner = KernelTuner(timer=timer, interpret=True)
    r1 = tuner.tune(sell)
    n_timed = len(timer.calls)
    r2 = tuner.tune(sell)
    assert r2 is r1 and len(timer.calls) == n_timed


# ---------------------------------------------------------------------------
# TuningDB persistence + nearest-neighbour fallback
# ---------------------------------------------------------------------------
def test_tuningdb_geometry_roundtrip(problem):
    _, m = problem
    db = TuningDB(machine="t", c=1.0, records=[], d_star={})
    tuner = KernelTuner(db=db, timer=fake_timer(), interpret=True)
    rec = tuner.tune(m)
    assert db.geometries, "tuner must record into the shared db"
    db2 = TuningDB.from_json(db.to_json())
    assert db2.geometries[0].geometry == rec.geometry
    assert db2.geometries[0].d_mat == rec.d_mat
    # a fresh tuner seeded from the reloaded db answers from memo
    tuner2 = KernelTuner(db=db2)
    assert tuner2.best(m) == rec.geometry


def test_tuningdb_json_backcompat():
    """Old dbs (no geometries key) still load."""
    db = TuningDB(machine="t", c=1.0, records=[], d_star={})
    import json
    obj = json.loads(db.to_json())
    obj.pop("geometries")
    db2 = TuningDB.from_json(json.dumps(obj))
    assert db2.geometries == []


def test_nearest_geometry_is_dmat_keyed():
    mk = lambda d, rows: GeometryRecord(
        fmt="ell_row", op="spmv", batch=1, n=100, nnz=1000, d_mat=d,
        geometry=TileGeometry(block_rows=rows, slabs_per_block=7),
        t_best=1.0, t_default=2.0)
    recs = [mk(0.05, 8), mk(3.0, 256)]
    low = nearest_geometry(recs, "ell_row", "spmv", d_mat=0.08)
    high = nearest_geometry(recs, "ell_row", "spmv", d_mat=2.0)
    assert low.block_rows == 8 and high.block_rows == 256
    # the data-dependent coverage bound never travels to another matrix
    assert low.slabs_per_block is None
    assert nearest_geometry(recs, "coo_row", "spmv", d_mat=1.0) is None


def test_nearest_geometry_prefers_batch_match():
    mk = lambda b, rows: GeometryRecord(
        fmt="ell_row", op="spmm", batch=b, n=100, nnz=1000, d_mat=1.0,
        geometry=TileGeometry(block_rows=rows), t_best=1.0, t_default=2.0)
    recs = [mk(8, 8), mk(128, 256)]
    assert nearest_geometry(recs, "ell_row", "spmm", d_mat=1.0,
                            batch=128).block_rows == 256


# ---------------------------------------------------------------------------
# the per-call tuning hint through dispatch
# ---------------------------------------------------------------------------
def test_dispatch_tuning_hint_matches_reference(problem):
    dense, m = problem
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=120).astype(np.float32))
    g = TileGeometry(block_rows=64, block_nnz=1024)
    got = dispatch.spmv(m, x, tier="kernel", tuning=g)
    np.testing.assert_allclose(np.asarray(got), dense @ np.asarray(x),
                               rtol=2e-4, atol=2e-4)
    # reference tier ignores the hint instead of crashing
    ref = dispatch.spmv(m, x, tier="reference", tuning=g)
    np.testing.assert_allclose(np.asarray(ref), dense @ np.asarray(x),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.slow
def test_offline_phase_records_geometries(problem):
    _, m = problem
    from repro.core.autotune import offline_phase
    from repro.kernels import ops
    tuner = KernelTuner(timer=fake_timer(), interpret=True)
    db = offline_phase([("m0", m)], formats=("ell_row",), iters=1,
                       spmv_impls=ops.KERNEL_SPMV_IMPLS, tuner=tuner,
                       machine="fake")
    assert {g.fmt for g in db.geometries} == {"csr", "ell_row"}
    assert db.best_geometry("ell_row", d_mat=1.0) is not None
