"""Multi-device features on 8 host-platform devices, run in subprocesses so
the main test process keeps its single-device view (per spec, XLA_FLAGS
must not be set globally)."""
import os
import subprocess
import sys
import textwrap


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=480)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nERR:\n{out.stderr}"
    return out.stdout


def test_pipeline_parallel_matches_sequential():
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.pipeline import (pipeline_forward, reference_forward,
                                    bubble_fraction)
        mesh = make_mesh((4,), ("pipe",))
        P_, M, mb, d = 4, 6, 2, 16
        k = jax.random.PRNGKey(0)
        params = {"w": jax.random.normal(k, (P_, d, d)) * 0.3,
                  "b": jax.random.normal(k, (P_, d)) * 0.1}
        stage_fn = lambda p, x: jnp.tanh(x @ p["w"] + p["b"])
        x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))
        got = pipeline_forward(params, x, stage_fn=stage_fn, mesh=mesh)
        want = reference_forward(params, x, stage_fn=stage_fn)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
        assert abs(bubble_fraction(4, 6) - 3/9) < 1e-9
        print("PIPELINE_OK")
    """)


def test_int8_compressed_allreduce_close_to_exact():
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.optim.compress import (init_error_state,
                                          make_compressed_allreduce)
        mesh = make_mesh((8,), ("data",))
        W = 8
        k = jax.random.PRNGKey(0)
        grads = {"w": jax.random.normal(k, (W, 64, 32)),
                 "b": jax.random.normal(k, (W, 32))}
        err = init_error_state(grads)   # per-worker residuals (stacked)
        fn = make_compressed_allreduce(mesh, "data")
        mean_c, err1 = fn(grads, err)
        want = jax.tree.map(lambda a: a.mean(0, keepdims=True)
                            .repeat(W, 0), grads)
        for g, w in zip(jax.tree.leaves(mean_c), jax.tree.leaves(want)):
            rel = np.abs(np.asarray(g) - np.asarray(w)).max() / \
                np.abs(np.asarray(w)).max()
            assert rel < 0.02, rel      # int8 quantization error bound
        # error feedback state is nonzero (residual captured)
        assert any(float(jnp.abs(e).max()) > 0
                   for e in jax.tree.leaves(err1))
        print("COMPRESS_OK")
    """)


def test_elastic_checkpoint_reshard():
    """Save under a 4-device mesh, restore under an 8-device mesh with
    different sharding — elastic scaling."""
    run_with_devices("""
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.checkpoint import save, restore
        d = tempfile.mkdtemp()
        mesh4 = make_mesh((4, 2), ("data", "model"))
        sh4 = NamedSharding(mesh4, P("data", "model"))
        x = jax.device_put(jnp.arange(64.0).reshape(8, 8), sh4)
        save(d, 1, {"x": x})
        mesh8 = make_mesh((8,), ("data",))
        sh8 = NamedSharding(mesh8, P(None, "data"))
        got, _ = restore(d, 1, {"x": jax.ShapeDtypeStruct((8, 8),
                                                          jnp.float32)},
                         shardings={"x": sh8})
        np.testing.assert_array_equal(np.asarray(got["x"]),
                                      np.arange(64.0).reshape(8, 8))
        assert got["x"].sharding.spec == P(None, "data")
        print("ELASTIC_OK")
    """)


def test_rules_elastic_across_mesh_shapes():
    """The same logical rules lower on 1x1, 2x2x2 and 8x1 meshes."""
    run_with_devices("""
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.sharding.rules import RULES_1POD
        for shape, axes in [((1, 1), ("data", "model")),
                            ((2, 2, 2), ("pod", "data", "model")),
                            ((8, 1), ("data", "model")),
                            ((8,), ("data",))]:
            mesh = make_mesh(shape, axes)
            spec = RULES_1POD.spec_for(("batch", "seq", "embed"), mesh,
                                       (16, 32, 64))
            ns = jax.sharding.NamedSharding(mesh, spec)  # validates
        print("RULES_OK")
    """)


def test_moe_dispatch_sharded_equivalence():
    """The MoE ELL dispatch gives identical results under 1 device and
    under an (data, model) sharded mesh."""
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.configs import get_config, smoke_config
        from repro.models import init, forward
        cfg = smoke_config(get_config("dbrx-132b")).replace(
            n_layers=2, capacity_factor=4.0)
        params = init(cfg, jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                              (4, 32), 0, cfg.vocab_size)}
        base, _ = forward(params, batch, cfg)
        mesh = make_mesh((2, 4), ("data", "model"))
        with mesh:
            sharded, _ = jax.jit(lambda p, b: forward(p, b, cfg))(params,
                                                                  batch)
        np.testing.assert_allclose(np.asarray(base, np.float32),
                                   np.asarray(sharded, np.float32),
                                   rtol=2e-3, atol=2e-3)
        print("MOE_SHARD_OK")
    """)
